"""L1 Pallas kernels: the HBMC vectorized triangular substitution (§4.3).

One ``pallas_call`` per (color, direction): the grid runs over the color's
level-1 blocks — the multithreading axis of the paper — and the kernel body
performs the ``bs`` sequential steps, each a ``w``-wide vector operation
over the level-2 block lanes (the SIMD axis). On TPU the natural mapping is
one level-1 block's slabs in VMEM per grid step with the ``w`` lanes on the
VPU minor dimension; here the kernels run with ``interpret=True`` (the CPU
PJRT plugin cannot execute Mosaic custom-calls) so the same HLO runs
anywhere, which is the property the AOT path needs.

Hardware adaptation note (DESIGN.md §Hardware-Adaptation): the paper's
AVX-512 gather becomes a jnp ``take`` from the already-computed vector; the
in-block couplings are lane-diagonal by the HBMC level-2 theorem, so they
are plain element-wise FMAs — no cross-lane traffic at all, which is the
TPU-friendly restatement of the paper's key structural insight.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _color_kernel(off_val_ref, off_col_ref, in_coef_ref, dinv_ref, rseg_ref,
                  prev_ref, out_ref, *, bs: int, w: int, reverse: bool):
    """Solve all level-2 steps of one level-1 block.

    Block shapes (leading grid axis of size 1 squeezed by indexing):
      off_val/off_col: (1, bs, K, w); in_coef: (1, bs, bs, w);
      dinv/rseg/out:   (1, bs, w);    prev: full (n,) vector.
    """
    prev = prev_ref[...]  # already-computed colors (full vector)
    acc = [None] * bs
    steps = range(bs - 1, -1, -1) if reverse else range(bs)
    for l in steps:
        t = rseg_ref[0, l]  # (w,)
        cols = off_col_ref[0, l]  # (K, w)
        vals = off_val_ref[0, l]
        t = t - jnp.sum(vals * prev[cols], axis=0)
        inner = range(l + 1, bs) if reverse else range(l)
        for m in inner:
            t = t - in_coef_ref[0, l, m] * acc[m]
        acc[l] = t * dinv_ref[0, l]
    out_ref[0] = jnp.stack(acc)


def color_substitution(off_val, off_col, in_coef, dinv, rseg, prev, *,
                       bs: int, w: int, reverse: bool):
    """Run one color's substitution: returns the color's (nl1, bs, w) block.

    ``prev`` is the full-length vector holding every already-finished
    color (zeros elsewhere); ``rseg`` is the color's rhs slice reshaped to
    (nl1, bs, w).
    """
    nl1, _, kmax, _ = off_val.shape
    n = prev.shape[0]
    grid = (nl1,)
    kernel = functools.partial(_color_kernel, bs=bs, w=w, reverse=reverse)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, kmax, w), lambda k: (k, 0, 0, 0)),
            pl.BlockSpec((1, bs, kmax, w), lambda k: (k, 0, 0, 0)),
            pl.BlockSpec((1, bs, bs, w), lambda k: (k, 0, 0, 0)),
            pl.BlockSpec((1, bs, w), lambda k: (k, 0, 0)),
            pl.BlockSpec((1, bs, w), lambda k: (k, 0, 0)),
            pl.BlockSpec((n,), lambda k: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bs, w), lambda k: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nl1, bs, w), prev.dtype),
        interpret=True,
    )(off_val, off_col, in_coef, dinv, rseg, prev)


def make_precond_apply(data):
    """Build ``z = (L Lᵀ)⁻¹ r`` over the full HBMC schedule.

    ``data`` is a ``ref.HbmcData``; its numpy arrays become baked constants
    of the traced function, so the AOT executable takes only ``r``.
    """
    bs, w, n = data.bs, data.w, data.n
    color_ptr = data.color_ptr
    ncolors = data.num_colors

    def apply(r):
        r = jnp.asarray(r)
        dt = r.dtype
        y = jnp.zeros(n, dtype=dt)
        for c in range(ncolors):
            cd = data.fwd[c]
            lo, hi = color_ptr[c], color_ptr[c + 1]
            rseg = jax.lax.dynamic_slice(r, (lo,), (hi - lo,)).reshape(-1, bs, w)
            blk = color_substitution(
                jnp.asarray(cd.off_val, dtype=dt), jnp.asarray(cd.off_col),
                jnp.asarray(cd.in_coef, dtype=dt), jnp.asarray(cd.dinv, dtype=dt),
                rseg, y, bs=bs, w=w, reverse=False,
            )
            y = jax.lax.dynamic_update_slice(y, blk.reshape(-1), (lo,))
        z = jnp.zeros(n, dtype=dt)
        for c in range(ncolors - 1, -1, -1):
            cd = data.bwd[c]
            lo, hi = color_ptr[c], color_ptr[c + 1]
            yseg = jax.lax.dynamic_slice(y, (lo,), (hi - lo,)).reshape(-1, bs, w)
            blk = color_substitution(
                jnp.asarray(cd.off_val, dtype=dt), jnp.asarray(cd.off_col),
                jnp.asarray(cd.in_coef, dtype=dt), jnp.asarray(cd.dinv, dtype=dt),
                yseg, z, bs=bs, w=w, reverse=True,
            )
            z = jax.lax.dynamic_update_slice(z, blk.reshape(-1), (lo,))
        return z

    return apply
