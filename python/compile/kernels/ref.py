"""Pure numpy oracles for the L1 kernels.

Everything the Pallas kernels compute is specified here twice:

* *serial* reference: row-by-row CSR substitution (ordering-agnostic),
* *structured* reference: the HBMC color/block/step schedule in plain
  numpy, exactly the arithmetic the Pallas kernel performs.

pytest asserts ``pallas == structured == serial`` so a failure localizes to
either the schedule construction or the kernel body.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import scipy.sparse as sp


# --------------------------------------------------------------------------
# IC(0) factorization (mirror of rust/src/factor/ic0.rs, up-looking rows)
# --------------------------------------------------------------------------

def ic0(a: sp.csr_matrix, shift: float = 0.0) -> tuple[sp.csr_matrix, np.ndarray]:
    """IC(0): returns (strict lower L, diag l_ii); raises on breakdown."""
    a = sp.csr_matrix(a)
    a.sort_indices()
    n = a.shape[0]
    lower = sp.tril(a, k=-1, format="csr")
    lower.sort_indices()
    lval = lower.data.astype(np.float64).copy()
    adiag = a.diagonal()
    diag = np.zeros(n)
    diag_inv = np.zeros(n)
    scratch = np.zeros(n)
    in_row = np.zeros(n, dtype=bool)
    indptr, indices = lower.indptr, lower.indices
    for i in range(n):
        cols = indices[indptr[i]:indptr[i + 1]]
        avals = lval[indptr[i]:indptr[i + 1]]
        scratch[cols] = avals
        in_row[cols] = True
        dii = adiag[i] * (1.0 + shift)
        for j in cols:
            s = scratch[j]
            jcols = indices[indptr[j]:indptr[j + 1]]
            jvals = lval[indptr[j]:indptr[j + 1]]
            mask = in_row[jcols]
            if mask.any():
                s -= np.dot(jvals[mask], scratch[jcols[mask]])
            lij = s * diag_inv[j]
            scratch[j] = lij
            dii -= lij * lij
        if dii <= 0.0 or not np.isfinite(dii):
            scratch[cols] = 0.0
            in_row[cols] = False
            raise FloatingPointError(f"ic0 breakdown at row {i}: {dii}")
        diag[i] = np.sqrt(dii)
        diag_inv[i] = 1.0 / diag[i]
        lval[indptr[i]:indptr[i + 1]] = scratch[cols]
        scratch[cols] = 0.0
        in_row[cols] = False
    out = sp.csr_matrix((lval, indices.copy(), indptr.copy()), shape=(n, n))
    return out, diag


# --------------------------------------------------------------------------
# Serial substitutions
# --------------------------------------------------------------------------

def forward_serial(lower: sp.csr_matrix, diag: np.ndarray, r: np.ndarray) -> np.ndarray:
    """Solve L y = r (L = strict ``lower`` + ``diag``)."""
    n = len(diag)
    y = np.zeros(n)
    indptr, indices, data = lower.indptr, lower.indices, lower.data
    for i in range(n):
        s = r[i] - np.dot(data[indptr[i]:indptr[i + 1]], y[indices[indptr[i]:indptr[i + 1]]])
        y[i] = s / diag[i]
    return y


def backward_serial(lower: sp.csr_matrix, diag: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Solve L^T z = y."""
    upper = sp.csr_matrix(lower.T)
    upper.sort_indices()
    n = len(diag)
    z = np.zeros(n)
    indptr, indices, data = upper.indptr, upper.indices, upper.data
    for i in range(n - 1, -1, -1):
        s = y[i] - np.dot(data[indptr[i]:indptr[i + 1]], z[indices[indptr[i]:indptr[i + 1]]])
        z[i] = s / diag[i]
    return z


def precond_serial(lower: sp.csr_matrix, diag: np.ndarray, r: np.ndarray) -> np.ndarray:
    return backward_serial(lower, diag, forward_serial(lower, diag, r))


# --------------------------------------------------------------------------
# HBMC schedule construction (consumed by both ref and the Pallas kernel)
# --------------------------------------------------------------------------

@dataclass
class ColorData:
    """Per-color padded arrays for one substitution direction.

    Shapes: ``off_val``/``off_col`` (nl1, bs, K, w) -- out-of-block entries
    (gathered from the already-computed vector); ``in_coef`` (nl1, bs, bs, w)
    -- in-block lane-diagonal couplings (``in_coef[k1, l, m, j]`` multiplies
    step ``m``'s lane ``j`` while computing step ``l``); ``dinv`` (nl1, bs, w).
    K >= 1 always (padded with zero entries pointing at row 0).
    """

    off_val: np.ndarray
    off_col: np.ndarray
    in_coef: np.ndarray
    dinv: np.ndarray
    row0: int  # first global row of this color


@dataclass
class HbmcData:
    n: int
    bs: int
    w: int
    num_colors: int
    color_ptr: list
    fwd: list
    bwd: list


def build_hbmc_data(lower: sp.csr_matrix, diag: np.ndarray, color_ptr: list,
                    bs: int, w: int) -> HbmcData:
    """Split L / L^T into the per-color HBMC schedule arrays."""
    n = len(diag)
    upper = sp.csr_matrix(lower.T)
    upper.sort_indices()
    ncolors = len(color_ptr) - 1
    dinv_full = 1.0 / diag

    def build_dir(tri: sp.csr_matrix, is_fwd: bool) -> list:
        out = []
        indptr, indices, data = tri.indptr, tri.indices, tri.data
        for c in range(ncolors):
            lo, hi = color_ptr[c], color_ptr[c + 1]
            nl1 = (hi - lo) // (bs * w)
            rows_off = []
            kmax = 1
            for row in range(lo, hi):
                l1 = (row - lo) // (bs * w)
                blk_lo = lo + l1 * bs * w
                blk_hi = blk_lo + bs * w
                offs = []
                for p in range(indptr[row], indptr[row + 1]):
                    col, val = int(indices[p]), float(data[p])
                    if blk_lo <= col < blk_hi:
                        continue  # in-block: handled by in_coef
                    offs.append((col, val))
                rows_off.append(offs)
                kmax = max(kmax, len(offs))
            off_val = np.zeros((nl1, bs, kmax, w))
            off_col = np.zeros((nl1, bs, kmax, w), dtype=np.int32)
            in_coef = np.zeros((nl1, bs, bs, w))
            dinv = np.zeros((nl1, bs, w))
            for row in range(lo, hi):
                local = row - lo
                k1, rem = divmod(local, bs * w)
                l, j = divmod(rem, w)
                for t, (col, val) in enumerate(rows_off[local]):
                    off_val[k1, l, t, j] = val
                    off_col[k1, l, t, j] = col
                blk_lo = lo + k1 * bs * w
                for p in range(indptr[row], indptr[row + 1]):
                    col, val = int(indices[p]), float(data[p])
                    if blk_lo <= col < blk_lo + bs * w:
                        m, jj = divmod(col - blk_lo, w)
                        assert jj == j, "level-2 block not lane-diagonal"
                        assert (m < l) if is_fwd else (m > l)
                        in_coef[k1, l, m, j] = val
                dinv[k1, l, j] = dinv_full[row]
            out.append(ColorData(off_val, off_col, in_coef, dinv, lo))
        return out

    return HbmcData(
        n=n, bs=bs, w=w, num_colors=ncolors, color_ptr=list(color_ptr),
        fwd=build_dir(lower, True), bwd=build_dir(upper, False),
    )


# --------------------------------------------------------------------------
# Structured reference (numpy twin of the Pallas kernel)
# --------------------------------------------------------------------------

def _color_step(cd: ColorData, data: HbmcData, rhs: np.ndarray, out: np.ndarray,
                reverse: bool) -> np.ndarray:
    bs, w = data.bs, data.w
    nl1 = cd.off_val.shape[0]
    out = out.copy()
    steps = range(bs - 1, -1, -1) if reverse else range(bs)
    for k1 in range(nl1):
        acc = np.zeros((bs, w))
        for l in steps:
            row0 = cd.row0 + k1 * bs * w + l * w
            t = rhs[row0:row0 + w].copy()
            g = out[cd.off_col[k1, l]]  # (K, w) gather
            t -= np.sum(cd.off_val[k1, l] * g, axis=0)
            for m in (range(l + 1, bs) if reverse else range(l)):
                t -= cd.in_coef[k1, l, m] * acc[m]
            acc[l] = t * cd.dinv[k1, l]
        for l in range(bs):
            row0 = cd.row0 + k1 * bs * w + l * w
            out[row0:row0 + w] = acc[l]
    return out


def forward_structured(data: HbmcData, r: np.ndarray) -> np.ndarray:
    y = np.zeros(data.n)
    for c in range(data.num_colors):
        y = _color_step(data.fwd[c], data, r, y, reverse=False)
    return y


def backward_structured(data: HbmcData, y_in: np.ndarray) -> np.ndarray:
    z = np.zeros(data.n)
    for c in range(data.num_colors - 1, -1, -1):
        z = _color_step(data.bwd[c], data, y_in, z, reverse=True)
    return z


# --------------------------------------------------------------------------
# SELL (slice = c) construction + SpMV reference
# --------------------------------------------------------------------------

def sell_from_csr(a: sp.csr_matrix, c: int) -> tuple[np.ndarray, np.ndarray]:
    """Uniform-K SELL arrays: returns (val, col) of shape (nslices, K, c).

    Rows are NOT sigma-sorted (trisolve-safe layout). K is the global max
    row length (simplifies the AOT kernel's static shapes); padding points
    at the row itself with value 0.
    """
    a = sp.csr_matrix(a)
    a.sort_indices()
    n = a.shape[0]
    assert n % c == 0, "pad the matrix to a multiple of c first"
    nslices = n // c
    kmax = max(1, int(np.diff(a.indptr).max()))
    val = np.zeros((nslices, kmax, c))
    col = np.zeros((nslices, kmax, c), dtype=np.int32)
    for i in range(n):
        s, lane = divmod(i, c)
        col[s, :, lane] = i  # safe self-gather padding
        lo, hi = a.indptr[i], a.indptr[i + 1]
        col[s, :hi - lo, lane] = a.indices[lo:hi]
        val[s, :hi - lo, lane] = a.data[lo:hi]
    return val, col


def spmv_sell_ref(val: np.ndarray, col: np.ndarray, x: np.ndarray) -> np.ndarray:
    nslices, kmax, c = val.shape
    out = np.zeros(nslices * c)
    for s in range(nslices):
        acc = np.zeros(c)
        for k in range(kmax):
            acc += val[s, k] * x[col[s, k]]
        out[s * c:(s + 1) * c] = acc
    return out
