"""L1 Pallas kernel: SELL-w sparse matrix-vector product (§4.4.2).

Grid over slices; each grid step computes the ``w`` rows of one slice as a
``w``-wide packed accumulation (the SELL format's whole point). Uniform
slice width K (global max row length) keeps the AOT shapes static;
padding entries carry value 0 and a safe self-column.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _spmv_kernel(val_ref, col_ref, x_ref, out_ref, *, kmax: int):
    x = x_ref[...]
    vals = val_ref[0]  # (K, w)
    cols = col_ref[0]
    acc = jnp.sum(vals * x[cols], axis=0)  # (w,)
    out_ref[0] = acc


def spmv_sell(val, col, x):
    """``y = A x`` with SELL arrays (nslices, K, w)."""
    nslices, kmax, w = val.shape
    n = x.shape[0]
    assert n == nslices * w
    kernel = functools.partial(_spmv_kernel, kmax=kmax)
    out = pl.pallas_call(
        kernel,
        grid=(nslices,),
        in_specs=[
            pl.BlockSpec((1, kmax, w), lambda k: (k, 0, 0)),
            pl.BlockSpec((1, kmax, w), lambda k: (k, 0, 0)),
            pl.BlockSpec((n,), lambda k: (0,)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((nslices, w), x.dtype),
        interpret=True,
    )(val, col, x)
    return out.reshape(-1)


def make_spmv(val, col):
    """Bake the matrix arrays; returns ``x ↦ A x``."""
    val_c = jnp.asarray(val)
    col_c = jnp.asarray(col)

    def apply(x):
        return spmv_sell(val_c, col_c, jnp.asarray(x))

    return apply
