"""Parallel orderings (MC / BMC / HBMC) — python oracle.

Deterministic mirror of the rust implementation (``rust/src/ordering``):
same greedy coloring (visit order = natural index, smallest unused color),
same min-index blocking heuristic of Iwashita et al. 2012 (seed = minimal
unassigned node, grow by minimal-index unassigned neighbor), same HBMC
secondary interleave (paper §4.2, Fig. 4.3). ``aot.py`` bakes the resulting
permutation into ``artifacts/golden.txt`` and the rust test
``golden_cross_layer.rs`` asserts both implementations agree node-for-node.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import scipy.sparse as sp

DUMMY = -1


def adjacency(a: sp.csr_matrix) -> list[np.ndarray]:
    """Symmetrized neighbor lists (sorted, diagonal removed)."""
    a = sp.csr_matrix(a)
    sym = (a + a.T).tocsr()
    n = sym.shape[0]
    out = []
    for i in range(n):
        nbr = sym.indices[sym.indptr[i]:sym.indptr[i + 1]]
        out.append(np.sort(nbr[nbr != i]).astype(np.int64))
    return out


def greedy_color(neighbors: list[np.ndarray]) -> tuple[np.ndarray, int]:
    """Greedy coloring in natural order; smallest unused color."""
    n = len(neighbors)
    color = np.full(n, -1, dtype=np.int64)
    ncolors = 0
    for v in range(n):
        used = {color[u] for u in neighbors[v] if color[u] >= 0}
        c = 0
        while c in used:
            c += 1
        color[v] = c
        ncolors = max(ncolors, c + 1)
    return color, ncolors


def build_blocks(neighbors: list[np.ndarray], bs: int) -> list[list[int]]:
    """Min-index greedy blocking (paper §5.1 / ref [13] simplest heuristic)."""
    n = len(neighbors)
    assigned = np.zeros(n, dtype=bool)
    blocks: list[list[int]] = []
    next_start = 0
    while next_start < n:
        if assigned[next_start]:
            next_start += 1
            continue
        seed = next_start
        assigned[seed] = True
        block = [seed]
        frontier = {int(u) for u in neighbors[seed] if not assigned[u]}
        while len(block) < bs and frontier:
            v = min(frontier)
            frontier.remove(v)
            assigned[v] = True
            block.append(v)
            for u in neighbors[v]:
                if not assigned[u]:
                    frontier.add(int(u))
        blocks.append(block)
    return blocks


def block_graph(neighbors: list[np.ndarray], blocks: list[list[int]]) -> list[set[int]]:
    n = len(neighbors)
    block_of = np.full(n, -1, dtype=np.int64)
    for bi, b in enumerate(blocks):
        for v in b:
            block_of[v] = bi
    out: list[set[int]] = [set() for _ in blocks]
    for bi, b in enumerate(blocks):
        for v in b:
            for u in neighbors[v]:
                bu = int(block_of[u])
                if bu != bi:
                    out[bi].add(bu)
    return out


@dataclass
class BmcOrdering:
    """BMC result; mirrors ``rust/src/ordering/bmc.rs``."""

    new_of_old: np.ndarray  # (n_old,) int64 → index in augmented space
    n_new: int
    bs: int
    num_colors: int
    color_ptr: list[int]
    blocks_per_color: list[int]


def bmc_order(a: sp.csr_matrix, bs: int) -> BmcOrdering:
    nbrs = adjacency(a)
    blocks = build_blocks(nbrs, bs)
    bg = block_graph(nbrs, blocks)
    bcolor, ncolors = greedy_color([np.array(sorted(g), dtype=np.int64) for g in bg])
    groups: list[list[int]] = [[] for _ in range(ncolors)]
    for bi, c in enumerate(bcolor):
        groups[int(c)].append(bi)

    n = len(nbrs)
    new_of_old = np.full(n, -1, dtype=np.int64)
    color_ptr = [0]
    blocks_per_color = []
    nxt = 0
    for g in groups:
        for bi in g:
            for slot, v in enumerate(blocks[bi]):
                new_of_old[v] = nxt + slot
            nxt += bs  # short blocks leave dummy slots
        color_ptr.append(nxt)
        blocks_per_color.append(len(g))
    return BmcOrdering(new_of_old, nxt, bs, ncolors, color_ptr, blocks_per_color)


@dataclass
class HbmcOrdering:
    """HBMC result; mirrors ``rust/src/ordering/hbmc.rs``."""

    new_of_old: np.ndarray  # original → HBMC augmented index
    n_new: int
    bs: int
    w: int
    num_colors: int
    color_ptr: list[int]
    l1_per_color: list[int]
    bmc: BmcOrdering
    secondary: np.ndarray = field(default_factory=lambda: np.empty(0, dtype=np.int64))


def hbmc_order(a: sp.csr_matrix, bs: int, w: int) -> HbmcOrdering:
    bmc = bmc_order(a, bs)
    return hbmc_from_bmc(bmc, w)


def hbmc_from_bmc(bmc: BmcOrdering, w: int) -> HbmcOrdering:
    bs = bmc.bs
    ncolors = bmc.num_colors
    color_ptr = [0]
    l1_per_color = []
    for c in range(ncolors):
        nb = -(-bmc.blocks_per_color[c] // w) * w  # round up to multiple of w
        l1_per_color.append(nb // w)
        color_ptr.append(color_ptr[c] + nb * bs)
    n_hbmc = color_ptr[-1]

    # Secondary reordering (Fig. 4.3): BMC slot (c, k, l) →
    # color_ptr[c] + (k // w)·bs·w + l·w + (k mod w).
    secondary = np.full(bmc.n_new, -1, dtype=np.int64)
    for c in range(ncolors):
        for k in range(bmc.blocks_per_color[c]):
            for l in range(bs):
                src = bmc.color_ptr[c] + k * bs + l
                dst = color_ptr[c] + (k // w) * bs * w + l * w + (k % w)
                secondary[src] = dst

    new_of_old = np.where(bmc.new_of_old >= 0, secondary[bmc.new_of_old], -1)
    return HbmcOrdering(
        new_of_old, n_hbmc, bs, w, ncolors, color_ptr, l1_per_color, bmc, secondary
    )


def permute_padded(a: sp.csr_matrix, new_of_old: np.ndarray, n_new: int) -> sp.csr_matrix:
    """``A' = P A Pᵀ`` into a padded space; dummy slots get identity rows."""
    a = sp.coo_matrix(a)
    rows = new_of_old[a.row]
    cols = new_of_old[a.col]
    data = list(a.data)
    rows = list(rows)
    cols = list(cols)
    hit = np.zeros(n_new, dtype=bool)
    hit[new_of_old] = True
    for i in np.nonzero(~hit)[0]:
        rows.append(i)
        cols.append(i)
        data.append(1.0)
    out = sp.coo_matrix((data, (rows, cols)), shape=(n_new, n_new)).tocsr()
    out.sum_duplicates()
    out.sort_indices()
    return out


def er_condition_holds(a: sp.csr_matrix, new_of_old: np.ndarray) -> bool:
    """Eq. (3.5): every connected pair keeps its relative order."""
    for i, nbr in enumerate(adjacency(a)):
        for j in nbr:
            if j > i and new_of_old[i] >= new_of_old[j]:
                return False
    return True


def orderings_equivalent(a: sp.csr_matrix, p1: np.ndarray, p2: np.ndarray) -> bool:
    """Identical ordering graphs (§3.1)."""
    for i, nbr in enumerate(adjacency(a)):
        for j in nbr:
            if j > i and ((p1[i] < p1[j]) != (p2[i] < p2[j])):
                return False
    return True
