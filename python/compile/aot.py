"""AOT build: lower the L2/L1 stack to HLO **text** artifacts + goldens.

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts

Emits into the output directory:

* ``precond_hbmc.hlo.txt`` — z = (LLᵀ)⁻¹ r (Pallas HBMC trisolve inside)
* ``spmv_sell.hlo.txt``    — y = A x (Pallas SELL SpMV inside)
* ``pcg_step.hlo.txt``     — one fused PCG iteration
* ``meta.txt``             — canonical-problem metadata (kvtext)
* ``golden.txt``           — cross-layer golden vectors + the python HBMC
  permutation (rust tests assert its ordering machinery agrees exactly)
* ``manifest.json``        — human-readable build summary

HLO *text* is the interchange format: jax ≥ 0.5 serializes protos with
64-bit instruction ids that xla_extension 0.5.1 (the published ``xla``
crate's XLA) rejects; the text parser reassigns ids. See
``/opt/xla-example/README.md``.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from . import ordering, problems
from .kernels import ref
from .model import CanonicalModel

# Canonical problem: 16×16 five-point grid (Fig. 4.5's setting), bs=4, w=4.
NX, NY = 16, 16
BS, W = 4, 4
SEED = 20260710


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default elides big
    # constant tensors as `{...}`, which the 0.5.1 text parser silently
    # reads back as zeros — the baked matrix would vanish.
    return comp.as_hlo_text(True)


def kv_lines(pairs) -> str:
    out = []
    for k, v in pairs:
        if isinstance(v, (list, tuple, np.ndarray)):
            arr = np.asarray(v).reshape(-1)
            if arr.dtype.kind == "f":
                body = " ".join(f"{x:.17e}" for x in arr)
            else:
                body = " ".join(str(int(x)) for x in arr)
            out.append(f"{k} = {body}")
        elif isinstance(v, float):
            out.append(f"{k} = {v:.17e}")
        else:
            out.append(f"{k} = {v}")
    return "\n".join(out) + "\n"


def build_canonical():
    """Canonical problem + HBMC ordering + model; returns all pieces."""
    a = problems.laplace2d(NX, NY)
    ord_ = ordering.hbmc_order(a, BS, W)
    a_perm = ordering.permute_padded(a, ord_.new_of_old, ord_.n_new)
    model = CanonicalModel(a_perm, ord_.color_ptr, BS, W)
    return a, ord_, a_perm, model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    a, ord_, a_perm, model = build_canonical()
    n, n_aug = a.shape[0], ord_.n_new
    rng = np.random.default_rng(SEED)

    # ---- golden vectors (computed via the numpy structured reference and
    # cross-checked against the jax/Pallas path before writing) ----------
    r = rng.uniform(-1.0, 1.0, size=n_aug)
    y_ref = ref.forward_structured(model.data, r)
    z_ref = ref.backward_structured(model.data, y_ref)
    z_jax = np.asarray(model.precond_apply(jnp.asarray(r)))
    assert np.max(np.abs(z_jax - z_ref)) < 1e-11, "pallas != structured ref"
    z_serial = ref.precond_serial(model.lower, model.diag, r)
    assert np.max(np.abs(z_ref - z_serial)) < 1e-11, "structured != serial"

    x = rng.uniform(-1.0, 1.0, size=n_aug)
    spmv_y_ref = np.asarray(a_perm @ x)
    spmv_y_jax = np.asarray(model.spmv(jnp.asarray(x)))
    assert np.max(np.abs(spmv_y_jax - spmv_y_ref)) < 1e-11, "pallas spmv != csr"

    # A short PCG run for the pcg_step golden.
    b = np.asarray(a_perm @ np.ones(n_aug))
    xx = np.zeros(n_aug)
    rr_vec = b - a_perm @ xx
    zz = ref.precond_serial(model.lower, model.diag, rr_vec)
    pp = zz.copy()
    rz = float(rr_vec @ zz)
    state = (jnp.asarray(xx), jnp.asarray(rr_vec), jnp.asarray(pp), jnp.asarray(rz))
    rr_history = []
    for _ in range(5):
        out = model.pcg_step(*state)
        rr_history.append(float(out[5]))
        state = (out[0], out[1], out[3], out[4])
    assert rr_history[-1] < rr_history[0], "pcg_step must reduce the residual"

    # ---- lower to HLO text ---------------------------------------------
    spec = jax.ShapeDtypeStruct((n_aug,), jnp.float64)
    scalar = jax.ShapeDtypeStruct((), jnp.float64)

    def precond_fn(rv):
        return (model.precond_apply(rv),)

    def spmv_fn(xv):
        return (model.spmv(xv),)

    def pcg_fn(xv, rv, pv, rzv):
        return model.pcg_step(xv, rv, pv, rzv)

    artifacts = {
        "precond_hbmc": jax.jit(precond_fn).lower(spec),
        "spmv_sell": jax.jit(spmv_fn).lower(spec),
        "pcg_step": jax.jit(pcg_fn).lower(spec, spec, spec, scalar),
    }
    sizes = {}
    for name, lowered in artifacts.items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        sizes[name] = len(text)
        print(f"wrote {path} ({len(text)} chars)")

    # ---- meta.txt --------------------------------------------------------
    meta = kv_lines([
        ("n_orig", n),
        ("n_aug", n_aug),
        ("bs", BS),
        ("w", W),
        ("num_colors", ord_.num_colors),
        ("color_ptr", ord_.color_ptr),
        ("nx", NX),
        ("ny", NY),
        ("seed", SEED),
    ])
    with open(os.path.join(args.out, "meta.txt"), "w") as f:
        f.write("# canonical AOT problem metadata (kvtext)\n" + meta)

    # ---- golden.txt ------------------------------------------------------
    coo = a.tocoo()
    golden = kv_lines([
        ("n", n),
        ("n_aug", n_aug),
        ("bs", BS),
        ("w", W),
        ("num_colors", ord_.num_colors),
        ("color_ptr", ord_.color_ptr),
        ("mat_rows", coo.row),
        ("mat_cols", coo.col),
        ("mat_vals", coo.data),
        ("hbmc_new_of_old", ord_.new_of_old),
        ("bmc_new_of_old", ord_.bmc.new_of_old),
        ("bmc_color_ptr", ord_.bmc.color_ptr),
        ("factor_diag", model.diag),
        ("precond_r", r),
        ("precond_z", z_ref),
        ("spmv_x", x),
        ("spmv_y", spmv_y_ref),
        ("pcg_rr_history", np.asarray(rr_history)),
    ])
    with open(os.path.join(args.out, "golden.txt"), "w") as f:
        f.write("# cross-layer golden data (kvtext)\n" + golden)

    # ---- manifest --------------------------------------------------------
    manifest = {
        "canonical_problem": {
            "grid": [NX, NY], "n": n, "n_aug": n_aug, "bs": BS, "w": W,
            "num_colors": ord_.num_colors,
        },
        "artifacts": {f"{k}.hlo.txt": v for k, v in sizes.items()},
        "format": "HLO text (xla_extension 0.5.1-compatible)",
        "pallas": "interpret=True (CPU PJRT)",
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out}/manifest.json, meta.txt, golden.txt")


if __name__ == "__main__":
    main()
