"""Build-time problem generators (python mirrors of ``rust/src/gen``).

Only the canonical AOT problem and small test problems live here; the full
dataset suite is rust-side. ``laplace2d`` matches
``hbmc::gen::fdm::laplace2d(nx, ny, 0.0, seed)`` exactly (constant
coefficients, 1e-2 diagonal regularization) so goldens agree bit-for-bit.
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp


def laplace2d(nx: int, ny: int) -> sp.csr_matrix:
    """Constant-coefficient 5-point Laplacian, diag += 1e-2 (rust parity)."""
    n = nx * ny

    def idx(x: int, y: int) -> int:
        return y * nx + x

    rows, cols, vals = [], [], []
    diag = np.zeros(n)
    for y in range(ny):
        for x in range(nx):
            if x + 1 < nx:
                i, j = idx(x, y), idx(x + 1, y)
                rows += [i, j]
                cols += [j, i]
                vals += [-1.0, -1.0]
                diag[i] += 1.0
                diag[j] += 1.0
            if y + 1 < ny:
                i, j = idx(x, y), idx(x, y + 1)
                rows += [i, j]
                cols += [j, i]
                vals += [-1.0, -1.0]
                diag[i] += 1.0
                diag[j] += 1.0
    for i in range(n):
        rows.append(i)
        cols.append(i)
        vals.append(diag[i] + 1e-2)
    a = sp.coo_matrix((vals, (rows, cols)), shape=(n, n)).tocsr()
    a.sum_duplicates()
    a.sort_indices()
    return a


def random_spd(n: int, extra_per_row: int, seed: int) -> sp.csr_matrix:
    """Diagonally dominant random SPD matrix for kernel sweeps."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    diag = np.full(n, 1e-2)
    for i in range(n):
        for _ in range(extra_per_row):
            j = int(rng.integers(0, n))
            if j == i:
                continue
            v = -float(rng.uniform(0.1, 1.0))
            rows += [i, j]
            cols += [j, i]
            vals += [v, v]
            diag[i] += -v
            diag[j] += -v
    rows += list(range(n))
    cols += list(range(n))
    vals += list(diag + 1.0)
    a = sp.coo_matrix((vals, (rows, cols)), shape=(n, n)).tocsr()
    a.sum_duplicates()
    a.sort_indices()
    return a
