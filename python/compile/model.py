"""L2: the JAX compute graph of the ICCG building blocks, calling the L1
Pallas kernels. Lowered once by ``aot.py``; never imported at runtime.

Exports three jit-able functions over a canonical HBMC problem:

* ``precond_apply(r) -> z``        — IC(0) preconditioner (Pallas trisolve),
* ``spmv(x) -> A x``               — SELL SpMV (Pallas),
* ``pcg_step(x, r, z, p, rz)``     — one fused PCG iteration using both.

All matrix/factor/schedule data are baked constants, so the AOT
executables take only the iteration vectors — the L3 rust loop feeds them
through PJRT with zero python involvement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import ref
from .kernels.hbmc_trisolve import make_precond_apply
from .kernels.spmv_sell import make_spmv


class CanonicalModel:
    """Bundle of baked-constant ICCG building blocks."""

    def __init__(self, a_perm, color_ptr, bs: int, w: int, shift: float = 0.0):
        self.n = a_perm.shape[0]
        self.bs, self.w = bs, w
        self.color_ptr = list(color_ptr)
        lower, diag = ref.ic0(a_perm, shift)
        self.lower, self.diag = lower, diag
        self.data = ref.build_hbmc_data(lower, diag, self.color_ptr, bs, w)
        self.precond_apply = make_precond_apply(self.data)
        sell_val, sell_col = ref.sell_from_csr(a_perm, w)
        self.spmv = make_spmv(sell_val, sell_col)

    def pcg_step(self, x, r, p, rz):
        """One preconditioned-CG iteration (state in, state out).

        State is ``(x, r, p, rz)`` — ``z`` is recomputed internally each
        step (it would be a dead input, which jax's lowering eliminates).
        Returns ``(x', r', z', p', rz', rr')`` where ``rr' = r'ᵀr'`` lets
        the rust loop check convergence without an extra reduction.
        """
        q = self.spmv(p)
        alpha = rz / jnp.dot(p, q)
        x = x + alpha * p
        r = r - alpha * q
        z = self.precond_apply(r)
        rz_new = jnp.dot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        rr = jnp.dot(r, r)
        return x, r, z, p, rz_new, rr
