"""L2 model + AOT lowering tests: the PCG step converges, the HLO text is
parser-safe (no elided constants!) and the artifact bundle is complete."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile import aot, ordering, problems
from compile.kernels import ref
from compile.model import CanonicalModel


@pytest.fixture(scope="module")
def small_model():
    a = problems.laplace2d(8, 8)
    ord_ = ordering.hbmc_order(a, 4, 4)
    ap = ordering.permute_padded(a, ord_.new_of_old, ord_.n_new)
    return ap, ord_, CanonicalModel(ap, ord_.color_ptr, 4, 4)


class TestModel:
    def test_pcg_step_converges(self, small_model):
        ap, ord_, m = small_model
        n = ap.shape[0]
        b = np.asarray(ap @ np.ones(n))
        x = jnp.zeros(n)
        r = jnp.asarray(b)
        z = m.precond_apply(r)
        p = z
        rz = jnp.dot(r, z)
        bb = float(b @ b)
        rrs = []
        for _ in range(40):
            x, r, z, p, rz, rr = m.pcg_step(x, r, p, rz)
            rrs.append(float(rr))
            if rrs[-1] / bb < 1e-18:
                break
        assert rrs[-1] < 1e-14 * bb
        np.testing.assert_allclose(np.asarray(x), np.ones(n), atol=1e-6)

    def test_pcg_step_matches_reference_iteration(self, small_model):
        ap, ord_, m = small_model
        n = ap.shape[0]
        rng = np.random.default_rng(9)
        b = rng.uniform(-1, 1, n)
        # One step by hand with the serial oracle.
        x0 = np.zeros(n)
        r0 = b.copy()
        z0 = ref.precond_serial(m.lower, m.diag, r0)
        p0 = z0.copy()
        rz0 = float(r0 @ z0)
        q = np.asarray(ap @ p0)
        alpha = rz0 / float(p0 @ q)
        x1 = x0 + alpha * p0
        r1 = r0 - alpha * q
        z1 = ref.precond_serial(m.lower, m.diag, r1)
        # Model step.
        xs, rs, zs, ps, rzs, rr = m.pcg_step(
            jnp.asarray(x0), jnp.asarray(r0), jnp.asarray(p0), jnp.asarray(rz0))
        np.testing.assert_allclose(np.asarray(xs), x1, atol=1e-12)
        np.testing.assert_allclose(np.asarray(rs), r1, atol=1e-12)
        np.testing.assert_allclose(np.asarray(zs), z1, atol=1e-12)
        assert float(rr) == pytest.approx(float(r1 @ r1), rel=1e-12)


class TestHloText:
    def test_no_elided_constants(self, small_model):
        ap, ord_, m = small_model
        n = ap.shape[0]
        spec = jax.ShapeDtypeStruct((n,), jnp.float64)
        lowered = jax.jit(lambda r: (m.precond_apply(r),)).lower(spec)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        # The 0.5.1 parser reads `{...}` as zeros — must never appear.
        assert "{...}" not in text
        assert f"f64[{n}]" in text

    def test_spmv_hlo_wellformed(self, small_model):
        ap, ord_, m = small_model
        n = ap.shape[0]
        spec = jax.ShapeDtypeStruct((n,), jnp.float64)
        text = aot.to_hlo_text(jax.jit(lambda x: (m.spmv(x),)).lower(spec))
        assert "gather" in text and "HloModule" in text
        assert "{...}" not in text


class TestAotBundle:
    def test_full_build(self, tmp_path):
        import sys
        argv = sys.argv
        sys.argv = ["aot", "--out", str(tmp_path)]
        try:
            aot.main()
        finally:
            sys.argv = argv
        for f in ["precond_hbmc.hlo.txt", "spmv_sell.hlo.txt", "pcg_step.hlo.txt",
                  "meta.txt", "golden.txt", "manifest.json"]:
            assert (tmp_path / f).exists(), f
        meta = dict(
            line.split(" = ")
            for line in (tmp_path / "meta.txt").read_text().splitlines()
            if " = " in line
        )
        assert int(meta["n_orig"]) == aot.NX * aot.NY
        assert int(meta["bs"]) == aot.BS
        golden = (tmp_path / "golden.txt").read_text()
        assert "precond_r" in golden and "hbmc_new_of_old" in golden
