"""Tests for the numpy oracles themselves (ref.py) — the ground everything
else stands on."""

import numpy as np
import pytest
import scipy.sparse as sp
from hypothesis import given, settings, strategies as st

from compile import ordering, problems
from compile.kernels import ref


def tridiag(n):
    return sp.diags([-np.ones(n - 1), 2.0 * np.ones(n), -np.ones(n - 1)],
                    [-1, 0, 1], format="csr")


class TestIc0:
    def test_tridiagonal_is_exact_cholesky(self):
        a = tridiag(8)
        lower, diag = ref.ic0(a)
        l_full = lower.toarray() + np.diag(diag)
        assert np.allclose(l_full @ l_full.T, a.toarray(), atol=1e-12)

    def test_shift_scales_diagonal(self):
        a = tridiag(5)
        _, d0 = ref.ic0(a, 0.0)
        _, d3 = ref.ic0(a, 0.3)
        assert d3[0] == pytest.approx(np.sqrt(2.0 * 1.3))
        assert d3[0] > d0[0]

    def test_breakdown_raises(self):
        # Singular Neumann Laplacian.
        n = 5
        a = sp.diags([-np.ones(n - 1),
                      np.array([1.0, 2, 2, 2, 1]),
                      -np.ones(n - 1)], [-1, 0, 1], format="csr")
        with pytest.raises(FloatingPointError):
            ref.ic0(a, 0.0)
        lower, diag = ref.ic0(a, 0.3)  # shifted succeeds
        assert np.all(diag > 0)

    @given(st.integers(5, 40), st.integers(1, 4), st.integers(0, 100))
    @settings(max_examples=15, deadline=None)
    def test_random_spd_factors(self, n, extra, seed):
        a = problems.random_spd(n, extra, seed)
        lower, diag = ref.ic0(a)
        assert np.all(diag > 0)
        assert lower.nnz == sp.tril(a, k=-1).nnz


class TestSerialSolves:
    def test_forward_backward_roundtrip(self):
        a = problems.laplace2d(6, 6)
        lower, diag = ref.ic0(a)
        rng = np.random.default_rng(3)
        r = rng.uniform(-1, 1, 36)
        y = ref.forward_serial(lower, diag, r)
        # L y == r
        l_full = lower.toarray() + np.diag(diag)
        assert np.allclose(l_full @ y, r, atol=1e-12)
        z = ref.backward_serial(lower, diag, y)
        assert np.allclose(l_full.T @ z, y, atol=1e-12)

    def test_precond_is_spd_map(self):
        a = problems.laplace2d(5, 5)
        lower, diag = ref.ic0(a)
        rng = np.random.default_rng(4)
        u = rng.uniform(-1, 1, 25)
        v = rng.uniform(-1, 1, 25)
        # Symmetry of M⁻¹: uᵀ M⁻¹ v == vᵀ M⁻¹ u
        mu = ref.precond_serial(lower, diag, u)
        mv = ref.precond_serial(lower, diag, v)
        assert np.dot(u, mv) == pytest.approx(np.dot(v, mu), rel=1e-10)


class TestStructured:
    @pytest.mark.parametrize("bs,w", [(2, 2), (4, 4), (8, 2), (2, 8)])
    def test_structured_equals_serial(self, bs, w):
        a = problems.laplace2d(8, 6)
        ord_ = ordering.hbmc_order(a, bs, w)
        ap = ordering.permute_padded(a, ord_.new_of_old, ord_.n_new)
        lower, diag = ref.ic0(ap)
        data = ref.build_hbmc_data(lower, diag, ord_.color_ptr, bs, w)
        rng = np.random.default_rng(5)
        r = rng.uniform(-1, 1, ord_.n_new)
        y_serial = ref.forward_serial(lower, diag, r)
        y_struct = ref.forward_structured(data, r)
        np.testing.assert_allclose(y_struct, y_serial, atol=1e-12)
        z_serial = ref.backward_serial(lower, diag, y_serial)
        z_struct = ref.backward_structured(data, y_struct)
        np.testing.assert_allclose(z_struct, z_serial, atol=1e-12)

    @given(st.integers(3, 10), st.integers(3, 10),
           st.sampled_from([2, 4]), st.sampled_from([2, 4]), st.integers(0, 50))
    @settings(max_examples=12, deadline=None)
    def test_structured_equals_serial_hypothesis(self, nx, ny, bs, w, seed):
        a = problems.laplace2d(nx, ny)
        ord_ = ordering.hbmc_order(a, bs, w)
        ap = ordering.permute_padded(a, ord_.new_of_old, ord_.n_new)
        lower, diag = ref.ic0(ap)
        data = ref.build_hbmc_data(lower, diag, ord_.color_ptr, bs, w)
        rng = np.random.default_rng(seed)
        r = rng.uniform(-1, 1, ord_.n_new)
        z1 = ref.precond_serial(lower, diag, r)
        z2 = ref.backward_structured(data, ref.forward_structured(data, r))
        np.testing.assert_allclose(z2, z1, atol=1e-11)


class TestSell:
    def test_spmv_matches_csr(self):
        a = problems.random_spd(32, 3, 7)
        val, col = ref.sell_from_csr(a, 4)
        rng = np.random.default_rng(8)
        x = rng.uniform(-1, 1, 32)
        np.testing.assert_allclose(ref.spmv_sell_ref(val, col, x), a @ x, atol=1e-12)

    def test_requires_multiple_of_c(self):
        a = problems.random_spd(10, 2, 1)
        with pytest.raises(AssertionError):
            ref.sell_from_csr(a, 4)

    def test_padding_is_harmless(self):
        # A matrix with an empty row pattern beyond diagonal.
        a = sp.eye(8, format="csr")
        val, col = ref.sell_from_csr(sp.csr_matrix(a), 4)
        x = np.arange(8.0)
        np.testing.assert_allclose(ref.spmv_sell_ref(val, col, x), x)
