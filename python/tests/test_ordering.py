"""Ordering machinery tests (python oracle side).

The same invariants are asserted in rust unit tests; cross-implementation
agreement is pinned by the golden test (rust/tests/golden_cross_layer.rs).
"""

import numpy as np
import pytest
import scipy.sparse as sp
from hypothesis import given, settings, strategies as st

from compile import ordering, problems


def grid(nx, ny):
    return problems.laplace2d(nx, ny)


class TestAdjacency:
    def test_grid_degrees(self):
        nbrs = ordering.adjacency(grid(4, 4))
        assert len(nbrs[0]) == 2  # corner
        assert len(nbrs[5]) == 4  # interior

    def test_symmetrizes(self):
        a = sp.csr_matrix(np.array([[1.0, 0.0], [2.0, 1.0]]))
        nbrs = ordering.adjacency(a)
        assert list(nbrs[0]) == [1]
        assert list(nbrs[1]) == [0]

    def test_no_self_loops(self):
        nbrs = ordering.adjacency(grid(5, 5))
        for i, nb in enumerate(nbrs):
            assert i not in nb


class TestColoring:
    def test_grid_is_bipartite(self):
        nbrs = ordering.adjacency(grid(6, 6))
        color, nc = ordering.greedy_color(nbrs)
        assert nc == 2
        for i, nb in enumerate(nbrs):
            assert all(color[j] != color[i] for j in nb)

    @given(st.integers(2, 40), st.integers(0, 5), st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_proper_on_random(self, n, extra, seed):
        a = problems.random_spd(n, extra, seed)
        nbrs = ordering.adjacency(a)
        color, nc = ordering.greedy_color(nbrs)
        maxdeg = max((len(nb) for nb in nbrs), default=0)
        assert nc <= maxdeg + 1
        for i, nb in enumerate(nbrs):
            assert all(color[j] != color[i] for j in nb)


class TestBlocking:
    def test_partition(self):
        nbrs = ordering.adjacency(grid(7, 5))
        blocks = ordering.build_blocks(nbrs, 4)
        seen = sorted(v for b in blocks for v in b)
        assert seen == list(range(35))
        assert all(len(b) <= 4 for b in blocks)

    def test_chain_blocks_contiguous(self):
        n = 12
        a = sp.diags([-np.ones(n - 1), 2 * np.ones(n), -np.ones(n - 1)],
                     [-1, 0, 1], format="csr")
        blocks = ordering.build_blocks(ordering.adjacency(a), 4)
        assert blocks[0] == [0, 1, 2, 3]
        assert blocks[1] == [4, 5, 6, 7]


class TestBmc:
    def test_block_independence(self):
        a = grid(8, 8)
        ord_ = ordering.bmc_order(a, 4)
        ap = ordering.permute_padded(a, ord_.new_of_old, ord_.n_new)
        coo = ap.tocoo()
        for c in range(ord_.num_colors):
            lo, hi = ord_.color_ptr[c], ord_.color_ptr[c + 1]
            mask = (coo.row >= lo) & (coo.row < hi) & (coo.col >= lo) & (coo.col < hi)
            rows, cols = coo.row[mask], coo.col[mask]
            # same color → same block (or diagonal)
            assert np.all(((rows - lo) // 4 == (cols - lo) // 4))

    def test_color_sizes_multiple_of_bs(self):
        ord_ = ordering.bmc_order(grid(9, 9), 8)
        for c in range(ord_.num_colors):
            assert (ord_.color_ptr[c + 1] - ord_.color_ptr[c]) % 8 == 0


class TestHbmc:
    def test_equivalent_to_bmc(self):
        a = grid(10, 10)
        ord_ = ordering.hbmc_order(a, 4, 4)
        assert ordering.orderings_equivalent(a, ord_.bmc.new_of_old, ord_.new_of_old)

    def test_level2_lane_diagonal(self):
        a = grid(12, 8)
        bs, w = 4, 4
        ord_ = ordering.hbmc_order(a, bs, w)
        ap = ordering.permute_padded(a, ord_.new_of_old, ord_.n_new)
        coo = ap.tocoo()
        bw = bs * w
        for c in range(ord_.num_colors):
            lo, hi = ord_.color_ptr[c], ord_.color_ptr[c + 1]
            mask = ((coo.row >= lo) & (coo.row < hi) & (coo.col >= lo)
                    & (coo.col < hi) & (coo.row != coo.col))
            rows, cols = coo.row[mask] - lo, coo.col[mask] - lo
            assert np.all(rows // bw == cols // bw), "same-color cross-l1 edge"
            assert np.all(rows % w == cols % w), "cross-lane edge in level-1 block"

    def test_interleave_matches_fig_4_3(self):
        # First level-1 block: new index = l*w + k for block k, slot l.
        a = grid(16, 4)
        bs, w = 2, 4
        ord_ = ordering.hbmc_order(a, bs, w)
        bmc = ord_.bmc
        assert bmc.blocks_per_color[0] >= w
        for k in range(w):
            for l in range(bs):
                src = bmc.color_ptr[0] + k * bs + l
                assert ord_.secondary[src] == l * w + k

    @given(st.integers(3, 14), st.integers(3, 14),
           st.sampled_from([2, 4, 8]), st.sampled_from([2, 4]))
    @settings(max_examples=15, deadline=None)
    def test_hbmc_invariants_hypothesis(self, nx, ny, bs, w):
        a = grid(nx, ny)
        ord_ = ordering.hbmc_order(a, bs, w)
        # Injective permutation over real nodes.
        vals = ord_.new_of_old
        assert len(set(vals.tolist())) == a.shape[0]
        # Color sizes multiples of bs*w.
        for c in range(ord_.num_colors):
            assert (ord_.color_ptr[c + 1] - ord_.color_ptr[c]) % (bs * w) == 0
        # ER equivalence with BMC.
        assert ordering.orderings_equivalent(a, ord_.bmc.new_of_old, vals)


class TestErCondition:
    def test_identity_holds(self):
        a = grid(5, 5)
        assert ordering.er_condition_holds(a, np.arange(25))

    def test_swap_of_neighbors_fails(self):
        a = grid(5, 1)
        p = np.arange(5)
        p[[0, 1]] = p[[1, 0]]
        assert not ordering.er_condition_holds(a, p)

    def test_padded_spread_holds(self):
        a = grid(3, 1)
        p = np.array([0, 4, 9])
        assert ordering.er_condition_holds(a, p)
