"""L1 Pallas kernels vs the numpy oracles — the core correctness signal of
the build path. Hypothesis sweeps problem shapes, block sizes, widths and
dtypes (system-prompt contract for this repo)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import ordering, problems
from compile.kernels import ref
from compile.kernels.hbmc_trisolve import color_substitution, make_precond_apply
from compile.kernels.spmv_sell import make_spmv, spmv_sell


def setup_problem(nx, ny, bs, w, seed=0):
    a = problems.laplace2d(nx, ny)
    ord_ = ordering.hbmc_order(a, bs, w)
    ap = ordering.permute_padded(a, ord_.new_of_old, ord_.n_new)
    lower, diag = ref.ic0(ap)
    data = ref.build_hbmc_data(lower, diag, ord_.color_ptr, bs, w)
    rng = np.random.default_rng(seed)
    r = rng.uniform(-1, 1, ord_.n_new)
    return ap, ord_, lower, diag, data, r


class TestColorKernel:
    def test_single_color_forward(self):
        _, ord_, lower, diag, data, r = setup_problem(8, 8, 4, 4)
        cd = data.fwd[0]
        lo, hi = data.color_ptr[0], data.color_ptr[1]
        y0 = np.zeros(data.n)
        blk = color_substitution(
            jnp.asarray(cd.off_val), jnp.asarray(cd.off_col),
            jnp.asarray(cd.in_coef), jnp.asarray(cd.dinv),
            jnp.asarray(r[lo:hi].reshape(-1, 4, 4)), jnp.asarray(y0),
            bs=4, w=4, reverse=False,
        )
        # Compare against the structured numpy twin for the same color.
        y_ref = ref._color_step(cd, data, r, y0, reverse=False)
        np.testing.assert_allclose(np.asarray(blk).reshape(-1), y_ref[lo:hi], atol=1e-13)


class TestPrecondApply:
    @pytest.mark.parametrize("bs,w", [(2, 2), (4, 4), (2, 8), (8, 2)])
    def test_matches_serial(self, bs, w):
        _, ord_, lower, diag, data, r = setup_problem(8, 6, bs, w)
        apply = make_precond_apply(data)
        z = np.asarray(apply(jnp.asarray(r)))
        z_ref = ref.precond_serial(lower, diag, r)
        np.testing.assert_allclose(z, z_ref, atol=1e-12)

    @given(st.integers(4, 10), st.integers(4, 10),
           st.sampled_from([2, 4]), st.sampled_from([2, 4]), st.integers(0, 40))
    @settings(max_examples=8, deadline=None)
    def test_matches_serial_hypothesis(self, nx, ny, bs, w, seed):
        _, ord_, lower, diag, data, r = setup_problem(nx, ny, bs, w, seed)
        apply = make_precond_apply(data)
        z = np.asarray(apply(jnp.asarray(r)))
        z_ref = ref.precond_serial(lower, diag, r)
        np.testing.assert_allclose(z, z_ref, atol=1e-11)

    def test_float32_tolerance(self):
        # The kernel is dtype-generic; f32 runs lose ~7 digits as expected.
        _, ord_, lower, diag, data, r = setup_problem(6, 6, 2, 4)
        apply = make_precond_apply(data)
        z64 = np.asarray(apply(jnp.asarray(r)))
        z32 = np.asarray(apply(jnp.asarray(r, dtype=jnp.float32)))
        assert z32.dtype == np.float32
        np.testing.assert_allclose(z32, z64, rtol=2e-4, atol=2e-4)

    def test_jit_compatible(self):
        _, ord_, lower, diag, data, r = setup_problem(6, 6, 2, 2)
        apply = jax.jit(make_precond_apply(data))
        z1 = np.asarray(apply(jnp.asarray(r)))
        z2 = ref.precond_serial(lower, diag, r)
        np.testing.assert_allclose(z1, z2, atol=1e-12)


class TestSpmvKernel:
    @pytest.mark.parametrize("w", [2, 4, 8])
    def test_matches_csr(self, w):
        n = 48
        a = problems.random_spd(n, 3, 11)
        val, col = ref.sell_from_csr(a, w)
        rng = np.random.default_rng(12)
        x = rng.uniform(-1, 1, n)
        y = np.asarray(spmv_sell(jnp.asarray(val), jnp.asarray(col), jnp.asarray(x)))
        np.testing.assert_allclose(y, a @ x, atol=1e-12)

    @given(st.integers(2, 12), st.sampled_from([2, 4]), st.integers(1, 4),
           st.integers(0, 99))
    @settings(max_examples=10, deadline=None)
    def test_matches_csr_hypothesis(self, slices, w, extra, seed):
        n = slices * w
        a = problems.random_spd(n, extra, seed)
        val, col = ref.sell_from_csr(a, w)
        rng = np.random.default_rng(seed + 1)
        x = rng.uniform(-1, 1, n)
        y = np.asarray(spmv_sell(jnp.asarray(val), jnp.asarray(col), jnp.asarray(x)))
        np.testing.assert_allclose(y, a @ x, atol=1e-11)

    def test_baked_spmv(self):
        a = problems.laplace2d(4, 4)
        # n = 16, multiple of 4.
        val, col = ref.sell_from_csr(a, 4)
        spmv = make_spmv(val, col)
        x = np.arange(16.0)
        np.testing.assert_allclose(np.asarray(spmv(x)), a @ x, atol=1e-12)
