//! End-to-end driver: the full paper protocol on a real (scaled) workload.
//!
//! Runs the five-dataset suite through MC / BMC / HBMC(crs) / HBMC(sell),
//! regenerating the shapes of Table 5.2 (iteration equivalence), Table 5.3
//! (execution times) and the §5.2.1/§5.2.2 statistics in one pass, and
//! prints a machine-readable summary block that `EXPERIMENTS.md` records.
//!
//! Run: `cargo run --release --example suite_sweep [-- full]`
//! (`full` uses the paper-scale generators; default is `small`.)

use hbmc::api::{SolveRequest, SolverService};
use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::report::{pct, secs, Table};
use hbmc::gen::suite;

fn main() -> anyhow::Result<()> {
    let scale = if std::env::args().any(|a| a == "full") { Scale::Full } else { Scale::Small };
    let bs = 32usize;
    let w = 8usize;
    println!("suite sweep at scale {:?}, bs={bs}, w={w}\n", scale);

    // One service serves the whole sweep: each dataset registered once,
    // each solver variant a per-request config override.
    let service = SolverService::with_capacity(SolverConfig::default(), 8)?;

    let mut table = Table::new(
        "ICCG suite sweep (rtol 1e-7)",
        &["dataset", "n", "solver", "iters", "time", "trisolve", "spmv", "simd"],
    );
    let mut summary: Vec<String> = Vec::new();
    let mut hbmc_wins = 0usize;
    let mut cells = 0usize;

    for d in suite::all(scale) {
        let n = d.n();
        let handle = service.register_matrix(d.matrix);
        let mut times = std::collections::HashMap::new();
        let mut iters = std::collections::HashMap::new();
        for (label, ordering, spmv) in [
            ("MC", OrderingKind::Mc, SpmvKind::Crs),
            ("BMC", OrderingKind::Bmc, SpmvKind::Crs),
            ("HBMC(crs)", OrderingKind::Hbmc, SpmvKind::Crs),
            ("HBMC(sell)", OrderingKind::Hbmc, SpmvKind::Sell),
        ] {
            let cfg = SolverConfig {
                ordering,
                bs,
                w,
                spmv,
                shift: d.shift,
                rtol: 1e-7,
                max_iters: 100_000,
                ..Default::default()
            };
            // `require_convergence` turns a stalled run into a typed
            // `HbmcError::NotConverged` instead of a bad table row.
            let req = SolveRequest::new().with_config(cfg).require_convergence();
            let rep = service.solve_with(handle, &d.b, &req)?.report;
            times.insert(label, rep.solve_seconds);
            iters.insert(label, rep.iterations);
            table.push_row(vec![
                d.name.clone(),
                n.to_string(),
                label.to_string(),
                rep.iterations.to_string(),
                secs(rep.solve_seconds),
                secs(rep.kernel("trisolve")),
                secs(rep.kernel("spmv")),
                pct(rep.plan.simd_ratio),
            ]);
        }
        // The paper's headline checks.
        assert!(
            iters["BMC"].abs_diff(iters["HBMC(crs)"]) <= 2 + iters["BMC"] / 20,
            "{}: equivalence broken",
            d.name
        );
        for hb in ["HBMC(crs)", "HBMC(sell)"] {
            cells += 1;
            if times[hb] <= times["BMC"] {
                hbmc_wins += 1;
            }
        }
        summary.push(format!(
            "{}: iters(MC={} BMC={} HBMC={}), time(MC={:.3} BMC={:.3} Hcrs={:.3} Hsell={:.3}), speedup(Hsell/BMC)={:.2}x",
            d.name, iters["MC"], iters["BMC"], iters["HBMC(crs)"],
            times["MC"], times["BMC"], times["HBMC(crs)"], times["HBMC(sell)"],
            times["BMC"] / times["HBMC(sell)"],
        ));
    }

    print!("{}", table.render());
    println!("\n== summary (for EXPERIMENTS.md) ==");
    for s in &summary {
        println!("{s}");
    }
    println!(
        "HBMC beats-or-ties BMC in {hbmc_wins}/{cells} cells (paper: 13/15 over 3 machines)"
    );
    Ok(())
}
