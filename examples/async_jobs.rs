//! Async job API walkthrough: `submit` → `JobHandle` (poll / wait /
//! cancel / deadline), and cross-request micro-batching — several client
//! threads each submit one right-hand side for the same matrix, and the
//! service dispatcher coalesces them into wide batches on one session.
//!
//! Run: `cargo run --release --example async_jobs`

use std::sync::{Arc, Barrier};
use std::thread;
use std::time::Duration;

use hbmc::prelude::*;

fn main() -> anyhow::Result<()> {
    let dataset = hbmc::gen::suite::dataset("g3_circuit", Scale::Tiny);
    println!("problem: {} (n = {}, nnz = {})", dataset.name, dataset.n(), dataset.nnz());

    // Queue tuning rides on the config: hold an under-full batch open up
    // to 50 ms, coalescing at most 16 jobs into one dispatched sweep.
    let cfg = SolverConfig::builder()
        .ordering(OrderingKind::Hbmc)
        .bs(8)
        .w(4)
        .rtol(1e-7)
        .max_batch(16)
        .max_wait(Duration::from_millis(50))
        .build()?;
    let service = Arc::new(SolverService::with_config(cfg)?);
    let handle = service.register_matrix(dataset.matrix.clone());

    // --- 1. submit / poll / wait -------------------------------------------
    let job = service.submit(handle, &dataset.b, &SolveRequest::new())?;
    println!("\njob #{} submitted; state = {:?}", job.id(), job.poll());
    let out = job.wait()?;
    println!("job done: {} iters, relres {:.3e}", out.report.iterations, out.report.final_relres);

    // --- 2. cross-request micro-batching -----------------------------------
    // Eight "clients" each submit ONE rhs for the same (matrix, config)
    // key at the same moment; the dispatcher runs them as a few wide
    // batches instead of eight sessions.
    const CLIENTS: usize = 8;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let service = Arc::clone(&service);
            let barrier = Arc::clone(&barrier);
            let rhs: Vec<f64> = dataset.b.iter().map(|v| v * (1.0 + c as f64)).collect();
            thread::spawn(move || {
                barrier.wait();
                service
                    .submit(handle, &rhs, &SolveRequest::new())
                    .and_then(|job| job.wait())
                    .map(|out| out.report.iterations)
            })
        })
        .collect();
    for (c, t) in workers.into_iter().enumerate() {
        let iters = t.join().expect("client thread")?;
        println!("client {c}: converged in {iters} iters");
    }
    // One call replaces ad-hoc stat prints: every ServiceStats counter
    // plus the queue-wait / batch-width / solve-time histogram quantiles,
    // in the same shape `hbmc stats` prints on the command line. (The
    // machine-readable twin is `service.metrics_text()` — Prometheus text
    // exposition, served over HTTP by `hbmc serve --metrics-addr`.)
    println!("\n{}", service.stats_text());

    // --- 3. cancellation ----------------------------------------------------
    // A queued job can be cancelled before dispatch; `wait` then returns
    // the typed `HbmcError::Cancelled`. (Running jobs always finish.)
    let victim = service.submit(handle, &dataset.b, &SolveRequest::new())?;
    if victim.cancel() {
        match victim.wait() {
            Err(HbmcError::Cancelled) => println!("\ncancelled job surfaced HbmcError::Cancelled"),
            other => println!("\ncancel raced dispatch; job finished anyway: {other:?}"),
        }
    } else {
        let _ = victim.wait();
        println!("\ncancel lost the race — job already dispatched (it still finished cleanly)");
    }

    // --- 4. deadlines -------------------------------------------------------
    // A zero budget is rejected synchronously at submit — no handle, no
    // queue traffic:
    match service.submit(handle, &dataset.b, &SolveRequest::new().deadline(Duration::ZERO)) {
        Err(HbmcError::DeadlineExceeded { budget }) => {
            println!("zero-budget submit rejected synchronously (budget {budget:?})");
        }
        other => println!("unexpected zero-deadline outcome: {other:?}"),
    }
    // A positive budget enqueues, but if it is spent by the time the
    // dispatcher claims the job, the job is *shed*: it never runs, fails
    // typed, and ticks `ServiceStats::shed` (and `hbmc_shed_total` in the
    // Prometheus exposition).
    let hopeless = service.submit(
        handle,
        &dataset.b,
        &SolveRequest::new().deadline(Duration::from_nanos(1)),
    )?;
    match hopeless.wait() {
        Err(HbmcError::DeadlineExceeded { budget }) => {
            println!(
                "expired job shed without running (budget {budget:?}; shed so far = {})",
                service.stats().shed
            );
        }
        other => println!("unexpected deadline outcome: {other:?}"),
    }

    Ok(())
}
