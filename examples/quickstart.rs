//! Quickstart: solve one system with the HBMC ICCG solver and print the
//! paper-relevant metrics.
//!
//! Run: `cargo run --release --example quickstart`

use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::driver::solve;
use hbmc::gen::suite;

fn main() -> anyhow::Result<()> {
    // 1. A test problem — the G3_circuit-class generator (see DESIGN.md §3).
    let dataset = suite::dataset("g3_circuit", Scale::Small);
    println!(
        "problem: {} (n = {}, nnz = {}, {:.1} nnz/row)",
        dataset.name,
        dataset.n(),
        dataset.nnz(),
        dataset.nnz_per_row()
    );

    // 2. Configure the paper's headline solver: HBMC ordering with SELL
    //    SpMV, block size 32, SIMD width 8 (AVX-512 path when available).
    let cfg = SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 32,
        w: 8,
        spmv: SpmvKind::Sell,
        threads: 1,
        rtol: 1e-7,
        ..Default::default()
    };

    // 3. Solve A x = b.
    let report = solve(&dataset.matrix, &dataset.b, &cfg)?;
    println!("\nconfig   : {}", report.config_label);
    println!("kernel   : {}", report.setup.kernel_path);
    println!("colors   : {} (syncs/substitution = {})",
        report.setup.num_colors, report.syncs_per_substitution);
    println!("iters    : {} (converged = {})", report.iterations, report.converged);
    println!("time     : {:.3} s solve | {:.3} s ordering | {:.3} s factor",
        report.solve_seconds, report.setup.ordering_seconds, report.setup.factor_seconds);
    for (k, s) in &report.kernel_seconds {
        println!("  {k:<9} {s:.3} s");
    }
    println!("simd     : {:.1}% packed FP ops", 100.0 * report.simd_ratio);
    if let Some(o) = report.sell_overhead {
        println!("sell     : {:+.1}% stored elements vs CRS", 100.0 * (o - 1.0));
    }

    // 4. The rhs was A·1 — verify the solution.
    let err = report.solution.iter().map(|x| (x - 1.0).abs()).fold(0.0, f64::max);
    println!("max |x-1|: {err:.2e}");
    anyhow::ensure!(report.converged && err < 1e-4);
    Ok(())
}
