//! Quickstart: the two-phase plan/session API — build one `SolverPlan`,
//! open a `SolveSession`, and serve several right-hand sides off the same
//! setup, printing the paper-relevant metrics.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::session::SolveSession;
use hbmc::gen::suite;
use hbmc::solver::plan::SolverPlan;

fn main() -> anyhow::Result<()> {
    // 1. A test problem — the G3_circuit-class generator (see DESIGN.md §3).
    let dataset = suite::dataset("g3_circuit", Scale::Small);
    println!(
        "problem: {} (n = {}, nnz = {}, {:.1} nnz/row)",
        dataset.name,
        dataset.n(),
        dataset.nnz(),
        dataset.nnz_per_row()
    );

    // 2. Configure the paper's headline solver: HBMC ordering with SELL
    //    SpMV, block size 32, SIMD width 8 (AVX-512 path when available).
    let cfg = SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 32,
        w: 8,
        spmv: SpmvKind::Sell,
        threads: 1,
        rtol: 1e-7,
        ..Default::default()
    };

    // 3. Phase 1 — the plan: ordering + IC(0) factorization + SELL
    //    construction, paid exactly once per (matrix, config) pair.
    let plan = Arc::new(SolverPlan::build(&dataset.matrix, &cfg)?);
    println!("\nconfig   : {}", cfg.label());
    println!("kernel   : {}", plan.setup.kernel_path);
    println!(
        "colors   : {} (syncs/substitution = {})",
        plan.setup.num_colors,
        plan.trisolver.syncs_per_sweep()
    );
    println!(
        "setup    : {:.3} s ({:.3} ordering | {:.3} factor | {:.3} storage)",
        plan.setup.setup_seconds(),
        plan.setup.ordering_seconds,
        plan.setup.factor_seconds,
        plan.setup.storage_seconds
    );
    println!("simd     : {:.1}% packed FP ops", 100.0 * plan.ops.simd_ratio());
    if let Some(o) = plan.sell_overhead() {
        println!("sell     : {:+.1}% stored elements vs CRS", 100.0 * (o - 1.0));
    }

    // 4. Phase 2 — the session: one persistent thread pool, many solves
    //    amortizing the plan (the rhs was A·1, so x* = 1 scaled).
    let session = SolveSession::new(plan);
    let mut total = 0.0;
    for k in 1..=3u32 {
        let b: Vec<f64> = dataset.b.iter().map(|v| v * k as f64).collect();
        let out = session.solve(&b)?;
        let err = out
            .x
            .iter()
            .map(|x| (x - k as f64).abs())
            .fold(0.0, f64::max);
        println!(
            "\nsolve[{}] : iters = {} (converged = {}), {:.3} s, max |x - {k}| = {err:.2e}",
            out.report.solve_index,
            out.report.iterations,
            out.report.converged,
            out.report.solve_seconds
        );
        for (kernel, s) in &out.report.kernel_seconds {
            println!("  {kernel:<9} {s:.3} s");
        }
        anyhow::ensure!(out.report.converged && err < 1e-3);
        total += out.report.solve_seconds;
    }
    println!(
        "\namortization: setup {:.3} s once, {} solves {:.3} s total",
        session.plan().setup.setup_seconds(),
        session.solves_completed(),
        total
    );
    Ok(())
}
