//! Quickstart: the typed front door — a validated `SolverConfig` from the
//! builder, one `SolverService`, a registered matrix behind a
//! `MatrixHandle`, and several right-hand sides served off one cached
//! plan, printing the paper-relevant metrics.
//!
//! Run: `cargo run --release --example quickstart`

use hbmc::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. A test problem — the G3_circuit-class generator (see DESIGN.md §3).
    let dataset = hbmc::gen::suite::dataset("g3_circuit", Scale::Small);
    println!(
        "problem: {} (n = {}, nnz = {}, {:.1} nnz/row)",
        dataset.name,
        dataset.n(),
        dataset.nnz(),
        dataset.nnz_per_row()
    );

    // 2. Configure the paper's headline solver through the validating
    //    builder: HBMC ordering with SELL SpMV, block size 32, SIMD width 8
    //    (AVX-512 path when available). An invalid combination — say
    //    bs not a multiple of w — would fail here, not in a kernel.
    let cfg = SolverConfig::builder()
        .ordering(OrderingKind::Hbmc)
        .bs(32)
        .w(8)
        .spmv(SpmvKind::Sell)
        .threads(1)
        .rtol(1e-7)
        .build()?;

    // 3. The service façade: register the matrix once, get a handle. The
    //    plan (ordering + IC(0) factorization + SELL construction) is
    //    built lazily on first use and cached for every solve after.
    let service = SolverService::with_config(cfg.clone())?;
    let handle = service.register_matrix(dataset.matrix);
    let plan = service.plan(handle, &cfg)?;
    println!("\nconfig   : {}", cfg.label());
    println!("kernel   : {}", plan.setup.kernel_path);
    println!(
        "colors   : {} (syncs/substitution = {})",
        plan.setup.num_colors,
        plan.trisolver.syncs_per_sweep()
    );
    println!(
        "setup    : {:.3} s ({:.3} ordering | {:.3} factor | {:.3} storage)",
        plan.setup.setup_seconds(),
        plan.setup.ordering_seconds,
        plan.setup.factor_seconds,
        plan.setup.storage_seconds
    );
    println!("simd     : {:.1}% packed FP ops", 100.0 * plan.ops.simd_ratio());
    if let Some(o) = plan.sell_overhead() {
        println!("sell     : {:+.1}% stored elements vs CRS", 100.0 * (o - 1.0));
    }

    // 4. Serve right-hand sides through the handle — every solve after the
    //    first is a plan-cache hit (the rhs was A·1, so x* = 1 scaled).
    //    `require_convergence` turns a stalled solve into a typed error.
    let req = SolveRequest::new().require_convergence();
    let mut total = 0.0;
    for k in 1..=3u32 {
        let b: Vec<f64> = dataset.b.iter().map(|v| v * k as f64).collect();
        let out = service.solve_with(handle, &b, &req)?;
        let err = out
            .x
            .iter()
            .map(|x| (x - k as f64).abs())
            .fold(0.0, f64::max);
        println!(
            "\nsolve[{}] : iters = {} (converged = {}), {:.3} s, max |x - {k}| = {err:.2e}",
            k - 1,
            out.report.iterations,
            out.report.converged,
            out.report.solve_seconds
        );
        for (kernel, s) in &out.report.kernel_seconds {
            println!("  {kernel:<9} {s:.3} s");
        }
        anyhow::ensure!(err < 1e-3);
        total += out.report.solve_seconds;
    }
    let stats = service.stats();
    println!(
        "\namortization: setup {:.3} s once ({} plan build), {} solves {total:.3} s total \
         (cache: {} hits / {} misses)",
        plan.setup.setup_seconds(),
        stats.builds,
        stats.solves,
        stats.cache.hits,
        stats.cache.misses,
    );
    Ok(())
}
