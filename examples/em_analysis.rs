//! Electromagnetic field analysis — the paper's motivating application
//! (§5.1, eq. 5.1): a finite edge-element discretization of the
//! eddy-current problem ∇×(ν ∇×A) = J₀ on the IEEJ-like benchmark,
//! solved with the **shifted ICCG method (σ = 0.3)** because the
//! curl-curl operator is only semi-definite.
//!
//! Compares MC, BMC and HBMC on the same system, reproducing the paper's
//! protocol for the `Ieej` dataset row of Tables 5.2/5.3.
//!
//! Run: `cargo run --release --example em_analysis`

use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::driver::solve;
use hbmc::coordinator::report::{secs, Table};
use hbmc::gen::suite;

fn main() -> anyhow::Result<()> {
    let d = suite::dataset("ieej", Scale::Small);
    println!(
        "eddy-current system: n = {} edges, nnz = {} ({:.1}/row), shift σ = {}",
        d.n(),
        d.nnz(),
        d.nnz_per_row(),
        d.shift
    );

    // Plain IC(0) on the semi-definite operator is fragile — demonstrate
    // that the shifted factorization is what makes ICCG robust here
    // (the auto-shift fallback rescues σ=0 by escalating).
    let mut table = Table::new(
        "shifted ICCG on the IEEJ-class eddy-current system",
        &["solver", "iters", "time (s)", "syncs/sub", "shift used"],
    );
    for (label, ordering, spmv, bs) in [
        ("MC", OrderingKind::Mc, SpmvKind::Crs, 32usize),
        ("BMC (bs=32)", OrderingKind::Bmc, SpmvKind::Crs, 32),
        ("HBMC crs (bs=32)", OrderingKind::Hbmc, SpmvKind::Crs, 32),
        ("HBMC sell (bs=32)", OrderingKind::Hbmc, SpmvKind::Sell, 32),
    ] {
        let cfg = SolverConfig {
            ordering,
            bs,
            w: 8,
            spmv,
            shift: d.shift,
            rtol: 1e-7,
            ..Default::default()
        };
        let rep = solve(&d.matrix, &d.b, &cfg)?;
        anyhow::ensure!(rep.converged, "{label} did not converge");
        table.push_row(vec![
            label.to_string(),
            rep.iterations.to_string(),
            secs(rep.solve_seconds),
            rep.plan.syncs_per_substitution.to_string(),
            format!("{}", rep.plan.setup.shift_used),
        ]);
    }
    print!("{}", table.render());
    println!("\nNote: BMC and HBMC rows have identical iteration counts — the");
    println!("equivalence theorem (§4.2.1) — while HBMC vectorizes the substitutions.");
    Ok(())
}
