//! Hybrid three-layer demo: the **rust CG loop** (L3) drives the
//! **AOT-compiled JAX graph** (L2) containing the **Pallas HBMC kernels**
//! (L1) through PJRT — python is not involved at runtime.
//!
//! Steps:
//! 1. load `artifacts/` (built once by `make artifacts`),
//! 2. verify the PJRT SpMV and preconditioner against both the python
//!    goldens and this crate's own CPU kernels on the canonical problem,
//! 3. run a full PCG solve where *every* SpMV and preconditioner
//!    application executes inside the PJRT executable,
//! 4. cross-check iterations against the pure-rust solver.
//!
//! Run: `cargo run --release --example hybrid_pjrt`

use anyhow::Result;

use hbmc::runtime::artifacts::{canonical_matrix, ArtifactSet};
use hbmc::runtime::hybrid::{HybridPcgStep, HybridPrecond, HybridSpmv};
use hbmc::runtime::pjrt::PjrtRuntime;
use hbmc::solver::blas1::{dot, norm2};
use hbmc::util::max_abs_diff;

fn main() -> Result<()> {
    let arts = ArtifactSet::locate()?;
    let meta = arts.meta()?;
    let golden = arts.golden()?;
    let n_aug = meta.usize("n_aug")?;
    println!(
        "canonical problem: n_aug={} bs={} w={} colors={}",
        n_aug,
        meta.usize("bs")?,
        meta.usize("w")?,
        meta.usize("num_colors")?
    );

    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // --- 1. SpMV cross-check -------------------------------------------
    let spmv = HybridSpmv::load(&rt, &arts)?;
    let x = golden.f64_vec("spmv_x")?;
    let y_expect = golden.f64_vec("spmv_y")?;
    let y = spmv.apply(&x)?;
    let err = max_abs_diff(&y, &y_expect);
    println!("[1/4] PJRT spmv_sell vs python golden:   {err:.3e}");
    anyhow::ensure!(err < 1e-10, "spmv mismatch");

    // --- 2. Preconditioner cross-check ----------------------------------
    let pre = HybridPrecond::load(&rt, &arts)?;
    let r = golden.f64_vec("precond_r")?;
    let z_expect = golden.f64_vec("precond_z")?;
    let z = pre.apply(&r)?;
    let err = max_abs_diff(&z, &z_expect);
    println!("[2/4] PJRT precond_hbmc vs python golden: {err:.3e}");
    anyhow::ensure!(err < 1e-10, "precond mismatch");

    // --- 3. Full PCG with all compute on PJRT ----------------------------
    let step = HybridPcgStep::load(&rt, &arts)?;
    let a = canonical_matrix(&golden)?; // original matrix (for the rust twin)
    let mut b_aug = vec![0.0; n_aug];
    {
        // b = A_perm · 1 — recompute through the PJRT SpMV itself.
        let ones = vec![1.0; n_aug];
        b_aug.copy_from_slice(&spmv.apply(&ones)?);
    }
    let bnorm = norm2(&b_aug);
    let mut x = vec![0.0; n_aug];
    let mut r = b_aug.clone();
    let z0 = pre.apply(&r)?;
    let mut p = z0.clone();
    let mut rz = dot(&r, &z0);
    let mut iters = 0usize;
    let rtol = 1e-8;
    for _ in 0..500 {
        let (x2, r2, _z2, p2, rz2, rr) = step.step(&x, &r, &p, rz)?;
        x = x2;
        r = r2;
        p = p2;
        rz = rz2;
        iters += 1;
        if rr.sqrt() / bnorm < rtol {
            break;
        }
    }
    let relres = norm2(&r) / bnorm;
    println!("[3/4] PJRT-driven PCG: iters={iters} relres={relres:.3e}");
    anyhow::ensure!(relres < rtol, "hybrid PCG did not converge");
    // Solution of the augmented system restricted to real slots is 1.
    let err1 = x.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
    println!("      max |x - 1| = {err1:.3e}");

    // --- 4. Pure-rust twin for iteration parity --------------------------
    let cfg = hbmc::config::SolverConfig {
        ordering: hbmc::config::OrderingKind::Hbmc,
        bs: meta.usize("bs")?,
        w: meta.usize("w")?,
        spmv: hbmc::config::SpmvKind::Sell,
        rtol,
        ..Default::default()
    };
    let rep = hbmc::coordinator::driver::solve(&a, &{
        let mut b = vec![0.0; a.n()];
        a.mul_vec(&vec![1.0; a.n()], &mut b);
        b
    }, &cfg)?;
    println!(
        "[4/4] pure-rust twin: iters={} (PJRT loop: {iters}) — orderings agree within ±2",
        rep.iterations
    );
    anyhow::ensure!(
        (rep.iterations as i64 - iters as i64).abs() <= 2,
        "iteration counts diverge: rust {} vs hybrid {iters}",
        rep.iterations
    );
    println!("hybrid_pjrt OK — all three layers compose");
    Ok(())
}
