//! Bench: regenerate **Fig. 5.1** — convergence behaviour (relative
//! residual vs iteration) of BMC and HBMC on G3_circuit and Ieej; the two
//! curves must overlap (equivalence). Emits CSV next to this output.
//!
//! `cargo bench --bench fig51 [-- full]`

use hbmc::config::Scale;
use hbmc::coordinator::experiments::fig_5_1;

fn main() {
    let scale = if std::env::args().any(|a| a == "full") { Scale::Full } else { Scale::Small };
    eprintln!("fig 5.1 at scale {scale:?} ...");
    let curves = fig_5_1(&["g3_circuit", "ieej"], scale, 1).expect("fig 5.1 run");
    let mut csv = String::from("dataset,iteration,bmc_relres,hbmc_relres\n");
    for (name, bmc, hbmc) in &curves {
        for (i, (a, b)) in bmc.iter().zip(hbmc).enumerate() {
            csv.push_str(&format!("{name},{},{a:.9e},{b:.9e}\n", i + 1));
        }
        // Equivalence is exact in exact arithmetic; in FP, round-off-level
        // drift gets amplified late in ill-conditioned runs (the plotted
        // curves still visually overlap, as in the paper's figure). Check
        // the pre-amplification phase tightly and report the full-curve
        // deviation informationally.
        let early_dev = bmc
            .iter()
            .zip(hbmc)
            .take(50)
            .map(|(a, b)| (a - b).abs() / a.max(*b).max(1e-300))
            .fold(0.0, f64::max);
        let full_dev = bmc
            .iter()
            .zip(hbmc)
            .map(|(a, b)| (a - b).abs() / a.max(*b).max(1e-300))
            .fold(0.0, f64::max);
        println!(
            "{name}: {} (BMC) vs {} (HBMC) iterations; early-phase max dev {early_dev:.2e}, full-curve {full_dev:.2e}",
            bmc.len(),
            hbmc.len()
        );
        assert!(early_dev < 1e-4, "{name} curves diverge in the early phase");
        assert!(
            bmc.len().abs_diff(hbmc.len()) <= 2 + bmc.len() / 20,
            "{name} iteration counts diverge"
        );
        // Print a coarse sampling of the curve (the figure's visual).
        let stride = (bmc.len() / 10).max(1);
        for (i, v) in bmc.iter().enumerate().step_by(stride) {
            println!("  iter {:>6}: relres {v:.3e}", i + 1);
        }
    }
    let path = "fig51_curves.csv";
    std::fs::write(path, csv).expect("write csv");
    println!("wrote {path}");
}
