//! Bench: regenerate **Table 5.3 (a/b/c)** — ICCG execution time for MC,
//! BMC, HBMC(crs_spmv), HBMC(sell_spmv) × bs ∈ {8, 16, 32} on the five
//! datasets, for one of the three node presets standing in for the
//! paper's machines (Table 4.1).
//!
//! Matching the paper's split of ordering/factorization (setup) vs
//! iteration time, each cell's plan is built once outside the timed
//! iteration loop (the driver reports them separately), and a companion
//! setup-seconds table is printed after each execution-time table.
//!
//! `cargo bench --bench table53 [-- --node knl|bdw|skx] [-- full]`
//! (no flag = all three nodes, i.e. 5.3a + 5.3b + 5.3c).

use hbmc::config::{NodePreset, Scale};
use hbmc::coordinator::experiments::table_5_3;
use hbmc::coordinator::report::{secs, Table};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let scale = if args.iter().any(|a| a == "full") { Scale::Full } else { Scale::Small };
    let nodes: Vec<NodePreset> = match args.iter().position(|a| a == "--node") {
        Some(i) => vec![args[i + 1].parse().expect("node preset")],
        None => NodePreset::all().to_vec(),
    };
    for node in nodes {
        eprintln!("table 5.3 for {} at scale {scale:?} ...", node.describe());
        let (table, cells) = table_5_3(node, scale, 1).expect("table 5.3 run");
        print!("{}", table.render());

        // Setup (ordering + factorization + storage) seconds, reported
        // separately from the iteration times above — the amortized part.
        let mut setup_table = Table::new(
            &format!("setup seconds (one plan per cell), node preset {}", node.describe()),
            &["Dataset", "solver", "bs", "ordering", "factor", "storage", "total"],
        );
        let mut iter_total = 0.0;
        let mut setup_total = 0.0;
        for c in &cells {
            let s = &c.report.plan.setup;
            iter_total += c.report.solve_seconds;
            setup_total += s.setup_seconds();
            setup_table.push_row(vec![
                c.dataset.clone(),
                c.solver.clone(),
                if c.bs == 0 { "-".into() } else { c.bs.to_string() },
                secs(s.ordering_seconds),
                secs(s.factor_seconds),
                secs(s.storage_seconds),
                secs(s.setup_seconds()),
            ]);
        }
        print!("{}", setup_table.render());
        println!(
            "totals: setup {:.3}s vs iteration {:.3}s — setup amortizes to 0 as solves/plan grows\n",
            setup_total, iter_total
        );

        // Paper-shape checks printed per node.
        let mut hbmc_wins = 0usize;
        let mut cases = 0usize;
        for d in hbmc::gen::suite::NAMES {
            let best_bmc = cells
                .iter()
                .filter(|c| c.dataset == d && c.solver == "BMC")
                .map(|c| c.report.solve_seconds)
                .fold(f64::INFINITY, f64::min);
            for solver in ["HBMC(crs)", "HBMC(sell)"] {
                let best = cells
                    .iter()
                    .filter(|c| c.dataset == d && c.solver == solver)
                    .map(|c| c.report.solve_seconds)
                    .fold(f64::INFINITY, f64::min);
                cases += 1;
                if best <= best_bmc {
                    hbmc_wins += 1;
                }
            }
        }
        println!("paper check — HBMC best ≤ BMC best in {hbmc_wins}/{cases} dataset-cells\n");
    }
}
