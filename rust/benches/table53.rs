//! Bench: regenerate **Table 5.3 (a/b/c)** — ICCG execution time for MC,
//! BMC, HBMC(crs_spmv), HBMC(sell_spmv) × bs ∈ {8, 16, 32} on the five
//! datasets, for one of the three node presets standing in for the
//! paper's machines (Table 4.1).
//!
//! `cargo bench --bench table53 [-- --node knl|bdw|skx] [-- full]`
//! (no flag = all three nodes, i.e. 5.3a + 5.3b + 5.3c).

use hbmc::config::{NodePreset, Scale};
use hbmc::coordinator::experiments::table_5_3;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let scale = if args.iter().any(|a| a == "full") { Scale::Full } else { Scale::Small };
    let nodes: Vec<NodePreset> = match args.iter().position(|a| a == "--node") {
        Some(i) => vec![NodePreset::parse(&args[i + 1]).expect("node preset")],
        None => NodePreset::all().to_vec(),
    };
    for node in nodes {
        eprintln!("table 5.3 for {} at scale {scale:?} ...", node.name());
        let (table, cells) = table_5_3(node, scale, 1).expect("table 5.3 run");
        print!("{}", table.render());

        // Paper-shape checks printed per node.
        let mut hbmc_wins = 0usize;
        let mut cases = 0usize;
        for d in hbmc::gen::suite::NAMES {
            let best_bmc = cells
                .iter()
                .filter(|c| c.dataset == d && c.solver == "BMC")
                .map(|c| c.report.solve_seconds)
                .fold(f64::INFINITY, f64::min);
            for solver in ["HBMC(crs)", "HBMC(sell)"] {
                let best = cells
                    .iter()
                    .filter(|c| c.dataset == d && c.solver == solver)
                    .map(|c| c.report.solve_seconds)
                    .fold(f64::INFINITY, f64::min);
                cases += 1;
                if best <= best_bmc {
                    hbmc_wins += 1;
                }
            }
        }
        println!("paper check — HBMC best ≤ BMC best in {hbmc_wins}/{cases} dataset-cells\n");
    }
}
