//! CRS vs symmetric-CSR SpMV inside the fused CG loop — the memory-traffic
//! experiment behind `SpmvKind::SymmCsr`. Emits `BENCH_symmspmv.json`:
//! per-engine model bytes/iteration (matrix and total, from
//! [`SpmvTraffic::model`]), measured SpMV-phase seconds, effective GFLOP/s
//! and model bandwidth, plus the two headline ratios (symm/crs matrix
//! bytes, crs/symm SpMV-phase time per iteration).
//!
//! `cargo bench --bench symmspmv [-- --quick]`
//!
//! Quick mode (`--quick` or `HBMC_BENCH_QUICK=1`) runs the Tiny dataset at
//! up to 2 threads for CI; the full run uses the largest generated suite
//! at every available core.

use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::metrics::SpmvTraffic;
use hbmc::coordinator::pool::Pool;
use hbmc::gen::suite;
use hbmc::solver::plan::{ExecOptions, SolverPlan};

struct EngineRun {
    label: &'static str,
    iterations: usize,
    solve_seconds: f64,
    spmv_seconds: f64,
    traffic: SpmvTraffic,
    nnz: usize,
    dispatches: u64,
}

impl EngineRun {
    /// Measured SpMV GFLOP/s (both engines do the full 2·nnz flops).
    fn gflops(&self) -> f64 {
        2.0 * self.nnz as f64 * self.iterations as f64 / self.spmv_seconds / 1e9
    }

    /// Model bytes moved per second of SpMV phase — the bandwidth the
    /// traffic model implies, comparable against the machine's roofline.
    fn model_gbps(&self) -> f64 {
        self.traffic.total_bytes() as f64 * self.iterations as f64 / self.spmv_seconds / 1e9
    }

    fn json(&self) -> String {
        format!(
            "    {{\"label\": \"{}\", \"iterations\": {}, \"solve_seconds\": {:.6e}, \
             \"spmv_seconds\": {:.6e}, \"dispatches\": {}, \
             \"model_matrix_bytes_per_iter\": {}, \"model_total_bytes_per_iter\": {}, \
             \"spmv_gflops\": {:.4}, \"model_bandwidth_gbps\": {:.4}}}",
            self.label,
            self.iterations,
            self.solve_seconds,
            self.spmv_seconds,
            self.dispatches,
            self.traffic.matrix_bytes,
            self.traffic.total_bytes(),
            self.gflops(),
            self.model_gbps(),
        )
    }
}

fn run_engine(
    d: &hbmc::gen::Dataset,
    spmv: SpmvKind,
    label: &'static str,
    threads: usize,
) -> EngineRun {
    let cfg = SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 8,
        w: 4,
        spmv,
        threads,
        shift: d.shift,
        rtol: 1e-6,
        ..Default::default()
    };
    let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan build");
    let traffic = SpmvTraffic::model(cfg.spmv, plan.setup.n_aug, plan.setup.spmv_elements, cfg.w);
    let pool = Pool::new(threads);
    let opts = ExecOptions::default(); // fused single-dispatch path
    let _ = plan.execute(&pool, &d.b, &opts).expect("warmup");
    let mut o = plan.execute(&pool, &d.b, &opts).expect("solve");
    for _ in 0..2 {
        let t = plan.execute(&pool, &d.b, &opts).expect("solve");
        if t.cg.solve_seconds < o.cg.solve_seconds {
            o = t;
        }
    }
    assert!(o.cg.converged, "bench solve must converge");
    EngineRun {
        label,
        iterations: o.cg.iterations.max(1),
        solve_seconds: o.cg.solve_seconds,
        spmv_seconds: o.cg.times.get("spmv").as_secs_f64().max(1e-12),
        traffic,
        nnz: d.nnz(),
        dispatches: o.dispatches,
    }
}

fn main() {
    let quick =
        std::env::args().any(|a| a == "--quick") || std::env::var("HBMC_BENCH_QUICK").is_ok();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let (scale, threads) = if quick { (Scale::Tiny, cores.min(2)) } else { (Scale::Full, cores) };
    let d = suite::dataset("g3_circuit", scale);
    println!(
        "symm-spmv bench: {} n={} nnz={} threads={threads} ({})",
        d.name,
        d.n(),
        d.nnz(),
        if quick { "quick" } else { "full" }
    );

    let crs = run_engine(&d, SpmvKind::Crs, "hbmc-crs-fused", threads);
    let symm = run_engine(&d, SpmvKind::SymmCsr, "hbmc-symmcsr-fused", threads);

    let matrix_bytes_ratio = symm.traffic.matrix_bytes as f64 / crs.traffic.matrix_bytes as f64;
    let spmv_speedup = (crs.spmv_seconds / crs.iterations as f64)
        / (symm.spmv_seconds / symm.iterations as f64);
    let json = format!(
        "{{\n  \"bench\": \"symmspmv\",\n  \"provenance\": \"measured: symmspmv bench\",\n  \
         \"dataset\": \"{}\",\n  \"n\": {},\n  \"nnz\": {},\n  \"threads\": {threads},\n  \
         \"engines\": [\n{},\n{}\n  ],\n  \
         \"matrix_bytes_ratio_symm_vs_crs\": {matrix_bytes_ratio:.4},\n  \
         \"spmv_phase_speedup_symm_vs_crs\": {spmv_speedup:.4}\n}}\n",
        d.name,
        d.n(),
        d.nnz(),
        crs.json(),
        symm.json(),
    );
    let path = hbmc::util::bench_artifact_path("BENCH_symmspmv.json");
    std::fs::write(&path, &json).expect("write BENCH_symmspmv.json");
    println!("{json}");
    println!(
        "matrix bytes: symm/crs = {matrix_bytes_ratio:.3}; \
         spmv phase: crs/symm per-iter = {spmv_speedup:.3}x"
    );
    println!("wrote {}", path.display());
}
