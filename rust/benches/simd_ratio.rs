//! Bench: regenerate the **§5.2.1 SIMD statistic** — the share of packed
//! floating-point operations per CG iteration for BMC vs HBMC (the paper
//! measured 99.7% vs 12.7% with VTune on G3_circuit/Skylake; we count the
//! same quantity analytically from the data structures, see
//! `coordinator::metrics`). Also measures the *measured* speed of the
//! vectorized (AVX) vs scalar HBMC substitution kernel, which is the
//! physical consequence of that statistic.
//!
//! `cargo bench --bench simd_ratio`

use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::experiments::simd_ratio_stat;
use hbmc::coordinator::pool::Pool;
use hbmc::factor::ic0::ic0_auto;
use hbmc::factor::split::{SellTriFactors, TriFactors};
use hbmc::gen::suite;
use hbmc::ordering::hbmc::hbmc_order;
use hbmc::solver::trisolve_hbmc::{self, HbmcMeta, KernelPath};
use hbmc::util::timer::bench_secs;
use std::time::Duration;

fn main() {
    let scale = if std::env::args().any(|a| a == "full") { Scale::Full } else { Scale::Small };
    print!("{}", simd_ratio_stat(scale, 1).expect("simd stat").render());

    println!("\n== measured: HBMC substitution kernel, scalar vs AVX path ==");
    let d = suite::dataset("g3_circuit", scale);
    let cfg = SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 32,
        w: 8,
        spmv: SpmvKind::Sell,
        shift: d.shift,
        ..Default::default()
    };
    let ord = hbmc_order(&d.matrix, cfg.bs, cfg.w);
    let b = d.matrix.permute_sym(&ord.perm);
    let f = ic0_auto(&b, 0.0).expect("ic0");
    let tri = TriFactors::from_ic(&f);
    let sell = SellTriFactors::from_tri(&tri, cfg.w);
    let meta = HbmcMeta::from_ordering(&ord);
    let pool = Pool::new(1);
    let n = b.n();
    let r = vec![1.0f64; n];
    let mut y = vec![0.0f64; n];

    let avail = trisolve_hbmc::select_path(8, true);
    for path in [KernelPath::Scalar, avail] {
        let (best, mean) = bench_secs(5, Duration::from_millis(400), || {
            trisolve_hbmc::forward(&meta, &sell, &r, &mut y, &pool, path);
        });
        let gfs = 2.0 * sell.fwd.stored_elements() as f64 / best / 1e9;
        println!(
            "forward substitution [{:>10}]: best {best:.6}s mean {mean:.6}s  ({gfs:.2} GFLOP/s)",
            path.name()
        );
        if path == avail && avail != KernelPath::Scalar {
            // no-op marker; speedup printed below
        }
    }
    if avail != KernelPath::Scalar {
        let (s_best, _) = bench_secs(5, Duration::from_millis(400), || {
            trisolve_hbmc::forward(&meta, &sell, &r, &mut y, &pool, KernelPath::Scalar);
        });
        let (v_best, _) = bench_secs(5, Duration::from_millis(400), || {
            trisolve_hbmc::forward(&meta, &sell, &r, &mut y, &pool, avail);
        });
        println!(
            "vectorization speedup ({}) = {:.2}x",
            avail.name(),
            s_best / v_best
        );
    }
}
