//! Ablation benches (DESIGN.md experiments A1/A2 — the paper's §7 "future
//! work" knobs, measured):
//!
//! * A1 — SELL slice-size / σ-sorting effect on stored elements and SpMV
//!   time (the §5.2.2 Audikw_1 pathology and its remedy),
//! * A2 — block size `bs` and width `w` sweep beyond the paper's grid:
//!   iterations (convergence cost of larger blocks) and substitution time.
//!
//! `cargo bench --bench ablation`

use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::driver::solve;
use hbmc::coordinator::report::{secs, Table};
use hbmc::gen::suite;
use hbmc::sparse::sell::Sell;

fn main() {
    let scale = if std::env::args().any(|a| a == "full") { Scale::Full } else { Scale::Small };

    // ---- A1: SELL layout ablation on the imbalanced dataset --------------
    let mut t1 = Table::new(
        "A1 — SELL stored-element overhead vs slice size / σ (audikw_1-class)",
        &["layout", "stored elems", "overhead vs CRS"],
    );
    let d = suite::dataset("audikw_1", scale);
    let nnz = d.matrix.nnz();
    t1.push_row(vec!["CRS".into(), nnz.to_string(), "+0.0%".into()]);
    for c in [4usize, 8, 16] {
        let s = Sell::from_csr(&d.matrix, c);
        t1.push_row(vec![
            format!("SELL-{c}"),
            s.stored_elements().to_string(),
            format!("{:+.1}%", 100.0 * (s.overhead_vs(nnz) - 1.0)),
        ]);
    }
    for sigma in [32usize, 128, 1024] {
        let s = Sell::from_csr_sigma(&d.matrix, 8, sigma);
        t1.push_row(vec![
            format!("SELL-8-σ{sigma}"),
            s.stored_elements().to_string(),
            format!("{:+.1}%", 100.0 * (s.overhead_vs(nnz) - 1.0)),
        ]);
    }
    print!("{}", t1.render());

    // ---- A2: bs × w sweep --------------------------------------------------
    let mut t2 = Table::new(
        "A2 — HBMC bs × w sweep on g3_circuit (iterations & time)",
        &["bs", "w", "colors", "iters", "time (s)"],
    );
    let d = suite::dataset("g3_circuit", scale);
    for bs in [4usize, 8, 16, 32, 64] {
        for w in [4usize, 8] {
            if bs % w != 0 {
                // HBMC requires bs to be a multiple of w (SolverConfig
                // validation); the grid point is unrepresentable.
                continue;
            }
            let cfg = SolverConfig {
                ordering: OrderingKind::Hbmc,
                bs,
                w,
                spmv: SpmvKind::Sell,
                shift: d.shift,
                rtol: 1e-7,
                ..Default::default()
            };
            let rep = solve(&d.matrix, &d.b, &cfg).expect("solve");
            t2.push_row(vec![
                bs.to_string(),
                w.to_string(),
                rep.plan.setup.num_colors.to_string(),
                rep.iterations.to_string(),
                secs(rep.solve_seconds),
            ]);
        }
    }
    print!("{}", t2.render());

    // ---- A2b: thread-count sweep (functional on this 1-core host) --------
    let mut t3 = Table::new(
        "A2b — thread sweep (1 physical core: verifies scheduling, not scaling)",
        &["threads", "iters", "time (s)", "syncs/sub"],
    );
    for threads in [1usize, 2, 4] {
        let cfg = SolverConfig {
            ordering: OrderingKind::Hbmc,
            bs: 32,
            w: 8,
            threads,
            spmv: SpmvKind::Sell,
            shift: d.shift,
            rtol: 1e-7,
            ..Default::default()
        };
        let rep = solve(&d.matrix, &d.b, &cfg).expect("solve");
        t3.push_row(vec![
            threads.to_string(),
            rep.iterations.to_string(),
            secs(rep.solve_seconds),
            rep.plan.syncs_per_substitution.to_string(),
        ]);
    }
    print!("{}", t3.render());
}
