//! Micro-benchmarks of the individual solver kernels (trisolve variants,
//! SpMV variants, BLAS-1) — the per-kernel numbers behind Table 5.3's
//! end-to-end times, and the harness used by the §Perf optimization loop.
//!
//! `cargo bench --bench kernels [-- full]`

use hbmc::config::Scale;
use hbmc::coordinator::pool::Pool;
use hbmc::factor::ic0::ic0_auto;
use hbmc::factor::split::{SellTriFactors, TriFactors};
use hbmc::gen::suite;
use hbmc::ordering::bmc::bmc_order;
use hbmc::ordering::hbmc::{hbmc_from_bmc, hbmc_order};
use hbmc::ordering::mc::mc_order;
use hbmc::solver::spmv::{spmv_crs, spmv_sell};
use hbmc::solver::trisolve_hbmc::{self, HbmcMeta};
use hbmc::solver::{trisolve_bmc, trisolve_mc, trisolve_serial};
use hbmc::sparse::sell::Sell;
use hbmc::util::timer::bench_secs;
use std::time::Duration;

fn main() {
    let scale = if std::env::args().any(|a| a == "full") { Scale::Full } else { Scale::Small };
    let d = suite::dataset("g3_circuit", scale);
    let a = &d.matrix;
    let n0 = a.n();
    println!("kernel microbench on {} (n={n0}, nnz={})\n", d.name, a.nnz());
    let pool = Pool::new(1);
    let budget = Duration::from_millis(300);

    // --- SpMV ------------------------------------------------------------
    {
        let x = vec![1.0f64; n0];
        let mut y = vec![0.0f64; n0];
        let (crs, _) = bench_secs(5, budget, || spmv_crs(a, &x, &mut y, &pool));
        let sell = Sell::from_csr(a, 8);
        let (sel, _) = bench_secs(5, budget, || spmv_sell(&sell, &x, &mut y, &pool));
        let sells = Sell::from_csr_sigma(a, 8, 64);
        let (sels, _) = bench_secs(5, budget, || spmv_sell(&sells, &x, &mut y, &pool));
        let gf = |t: f64, elems: usize| 2.0 * elems as f64 / t / 1e9;
        println!("spmv crs      : {crs:.6}s ({:.2} GFLOP/s)", gf(crs, a.nnz()));
        println!(
            "spmv sell-8   : {sel:.6}s ({:.2} GFLOP/s, {:+.1}% pad)",
            gf(sel, sell.stored_elements()),
            100.0 * (sell.overhead_vs(a.nnz()) - 1.0)
        );
        println!(
            "spmv sell-8 σ : {sels:.6}s ({:.2} GFLOP/s, {:+.1}% pad)",
            gf(sels, sells.stored_elements()),
            100.0 * (sells.overhead_vs(a.nnz()) - 1.0)
        );
    }

    // --- Triangular solves -------------------------------------------------
    println!("\nforward+backward substitution (one preconditioner application):");
    {
        // natural / serial
        let f = ic0_auto(a, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let r = vec![1.0f64; n0];
        let mut s = vec![0.0f64; n0];
        let mut z = vec![0.0f64; n0];
        let (t, _) = bench_secs(3, budget, || trisolve_serial::apply(&tri, &r, &mut s, &mut z));
        println!("serial (natural)        : {t:.6}s");
    }
    {
        let mc = mc_order(a);
        let b = a.permute_sym(&mc.perm);
        let f = ic0_auto(&b, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let n = b.n();
        let r = vec![1.0f64; n];
        let mut s = vec![0.0f64; n];
        let mut z = vec![0.0f64; n];
        let (t, _) = bench_secs(3, budget, || {
            trisolve_mc::forward(&tri, &mc.color_ptr, &r, &mut s, &pool);
            trisolve_mc::backward(&tri, &mc.color_ptr, &s, &mut z, &pool);
        });
        println!("MC ({:>3} colors)         : {t:.6}s", mc.num_colors);
    }
    for bs in [8usize, 16, 32] {
        let ord = bmc_order(a, bs);
        let b = a.permute_sym(&ord.perm);
        let f = ic0_auto(&b, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let n = b.n();
        let r = vec![1.0f64; n];
        let mut s = vec![0.0f64; n];
        let mut z = vec![0.0f64; n];
        let (t, _) = bench_secs(3, budget, || {
            trisolve_bmc::forward(&tri, &ord.color_ptr, bs, &r, &mut s, &pool);
            trisolve_bmc::backward(&tri, &ord.color_ptr, bs, &s, &mut z, &pool);
        });
        println!("BMC bs={bs:<2} ({:>2} colors)   : {t:.6}s", ord.num_colors);

        let hord = hbmc_from_bmc(ord, 8);
        let bh = a.permute_sym(&hord.perm);
        let fh = ic0_auto(&bh, 0.0).unwrap();
        let trih = TriFactors::from_ic(&fh);
        let sellh = SellTriFactors::from_tri(&trih, 8);
        let meta = HbmcMeta::from_ordering(&hord);
        let nh = bh.n();
        let rh = vec![1.0f64; nh];
        let mut sh = vec![0.0f64; nh];
        let mut zh = vec![0.0f64; nh];
        let path = trisolve_hbmc::select_path(8, true);
        let (t, _) = bench_secs(3, budget, || {
            trisolve_hbmc::forward(&meta, &sellh, &rh, &mut sh, &pool, path);
            trisolve_hbmc::backward(&meta, &sellh, &sh, &mut zh, &pool, path);
        });
        println!("HBMC bs={bs:<2} w=8 [{:>10}]: {t:.6}s", path.name());
    }

    // --- scaling in w ------------------------------------------------------
    println!("\nHBMC forward substitution vs SIMD width (bs=16):");
    for w in [2usize, 4, 8, 16] {
        let ord = hbmc_order(a, 16, w);
        let b = a.permute_sym(&ord.perm);
        let f = ic0_auto(&b, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let sell = SellTriFactors::from_tri(&tri, w);
        let meta = HbmcMeta::from_ordering(&ord);
        let n = b.n();
        let r = vec![1.0f64; n];
        let mut y = vec![0.0f64; n];
        let path = trisolve_hbmc::select_path(w, true);
        let (t, _) = bench_secs(3, budget, || {
            trisolve_hbmc::forward(&meta, &sell, &r, &mut y, &pool, path);
        });
        println!("  w={w:<2} [{:>10}]: {t:.6}s", path.name());
    }
}
