//! Micro-benchmarks of the individual solver kernels (trisolve variants,
//! SpMV variants) — the per-kernel numbers behind Table 5.3's end-to-end
//! times, and the harness used by the §Perf optimization loop.
//!
//! Honest setup/iteration split: every triangular-solver variant is built
//! as one [`SolverPlan`] **outside** the timed region; its setup seconds
//! (ordering / factorization / storage) are reported separately from the
//! per-application kernel time, matching the paper's Table 5.3 protocol.
//!
//! `cargo bench --bench kernels [-- full | -- --quick]`
//!
//! Quick mode (`--quick` arg or `HBMC_BENCH_QUICK=1`): a CI-friendly run
//! that solves the Tiny dataset through both execution paths and emits
//! `BENCH_iter.json` (iters/s, dispatches/solve, syncs/iter for fused vs
//! legacy) so the perf trajectory is recorded as a CI artifact.

use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::pool::Pool;
use hbmc::gen::suite;
use hbmc::solver::plan::{ExecOptions, SolverPlan};
use hbmc::solver::spmv::{spmv_crs, spmv_sell, spmv_symm, SymmSpmv};
use hbmc::sparse::sell::Sell;
use hbmc::util::timer::bench_secs;
use std::time::Duration;

/// One measured configuration for the quick-mode JSON artifact.
fn quick_entry(d: &hbmc::gen::Dataset, spmv: SpmvKind, legacy: bool) -> String {
    let cfg = SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 8,
        w: 4,
        spmv,
        shift: d.shift,
        rtol: 1e-6,
        ..Default::default()
    };
    let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan build");
    let pool = Pool::new(1);
    let opts = ExecOptions { legacy_loop: legacy, ..Default::default() };
    // Warm once, then measure the median-ish of 3.
    let _ = plan.execute(&pool, &d.b, &opts).expect("warmup");
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..3 {
        let o = plan.execute(&pool, &d.b, &opts).expect("solve");
        if o.cg.solve_seconds < best {
            best = o.cg.solve_seconds;
            out = Some(o);
        }
    }
    let o = out.expect("at least one solve");
    assert!(o.cg.converged, "quick bench solve must converge");
    let iters = o.cg.iterations.max(1);
    let label = format!(
        "hbmc-{}-{}",
        match spmv {
            SpmvKind::Crs => "crs",
            SpmvKind::Sell => "sell",
            SpmvKind::SymmCsr => "symmcsr",
        },
        if legacy { "legacy" } else { "fused" }
    );
    format!(
        "    {{\"label\": \"{label}\", \"iterations\": {iters}, \"solve_seconds\": {best:.6e}, \
         \"iters_per_sec\": {:.3}, \"dispatches_per_solve\": {}, \"syncs_per_iter\": {:.2}}}",
        iters as f64 / best,
        o.dispatches,
        o.pool_syncs as f64 / iters as f64,
    )
}

/// Quick mode: solve fused vs legacy, write `BENCH_iter.json`, skip the
/// long microbench sections.
fn quick_main() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let mut entries = Vec::new();
    for spmv in [SpmvKind::Crs, SpmvKind::Sell, SpmvKind::SymmCsr] {
        for legacy in [false, true] {
            entries.push(quick_entry(&d, spmv, legacy));
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"kernels-quick\",\n  \
         \"provenance\": \"measured: kernels quick bench\",\n  \
         \"dataset\": \"{}\",\n  \"n\": {},\n  \
         \"nnz\": {},\n  \"configs\": [\n{}\n  ]\n}}\n",
        d.name,
        d.n(),
        d.nnz(),
        entries.join(",\n")
    );
    // Stable name at the repo root (CWD here is the package dir, rust/).
    let path = hbmc::util::bench_artifact_path("BENCH_iter.json");
    std::fs::write(&path, &json).expect("write BENCH_iter.json");
    println!("{json}");
    println!("wrote {}", path.display());
    quick_level(&d);
}

/// Quick mode, level-vs-HBMC artifact: one substitution-kernel timing and
/// one end-to-end solve for the level-scheduled path next to the HBMC
/// reference, written to `BENCH_level.json`.
fn quick_level(d: &hbmc::gen::Dataset) {
    let pool = Pool::new(1);
    let budget = Duration::from_millis(150);
    let mut entries = Vec::new();
    for (label, cfg) in [
        (
            "level-crs",
            SolverConfig {
                ordering: OrderingKind::Level,
                spmv: SpmvKind::Crs,
                shift: d.shift,
                rtol: 1e-6,
                ..Default::default()
            },
        ),
        (
            "hbmc-crs",
            SolverConfig {
                ordering: OrderingKind::Hbmc,
                bs: 8,
                w: 4,
                spmv: SpmvKind::Crs,
                shift: d.shift,
                rtol: 1e-6,
                ..Default::default()
            },
        ),
    ] {
        let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan build");
        let n = plan.n_aug();
        let r = vec![1.0f64; n];
        let mut s = vec![0.0f64; n];
        let mut z = vec![0.0f64; n];
        let (apply, _) = bench_secs(3, budget, || plan.trisolver.apply(&r, &mut s, &mut z, &pool));
        let out = plan.execute(&pool, &d.b, &ExecOptions::default()).expect("solve");
        assert!(out.cg.converged, "quick level bench solve must converge");
        entries.push(format!(
            "    {{\"label\": \"{label}\", \"stages\": {}, \"syncs_per_sweep\": {}, \
             \"apply_seconds\": {apply:.6e}, \"iterations\": {}, \"solve_seconds\": {:.6e}}}",
            plan.trisolver.num_colors(),
            plan.trisolver.syncs_per_sweep(),
            out.cg.iterations,
            out.cg.solve_seconds,
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"level-vs-hbmc\",\n  \
         \"provenance\": \"measured: kernels quick bench (level section)\",\n  \
         \"dataset\": \"{}\",\n  \"n\": {},\n  \
         \"nnz\": {},\n  \"configs\": [\n{}\n  ]\n}}\n",
        d.name,
        d.n(),
        d.nnz(),
        entries.join(",\n")
    );
    let path = hbmc::util::bench_artifact_path("BENCH_level.json");
    std::fs::write(&path, &json).expect("write BENCH_level.json");
    println!("{json}");
    println!("wrote {}", path.display());
}

fn main() {
    if std::env::args().any(|a| a == "--quick") || std::env::var("HBMC_BENCH_QUICK").is_ok() {
        quick_main();
        return;
    }
    let scale = if std::env::args().any(|a| a == "full") { Scale::Full } else { Scale::Small };
    let d = suite::dataset("g3_circuit", scale);
    let a = &d.matrix;
    let n0 = a.n();
    println!("kernel microbench on {} (n={n0}, nnz={})\n", d.name, a.nnz());
    let pool = Pool::new(1);
    let budget = Duration::from_millis(300);

    // --- SpMV ------------------------------------------------------------
    {
        let x = vec![1.0f64; n0];
        let mut y = vec![0.0f64; n0];
        let (crs, _) = bench_secs(5, budget, || spmv_crs(a, &x, &mut y, &pool));
        let sell = Sell::from_csr(a, 8);
        let (sel, _) = bench_secs(5, budget, || spmv_sell(&sell, &x, &mut y, &pool));
        let sells = Sell::from_csr_sigma(a, 8, 64);
        let (sels, _) = bench_secs(5, budget, || spmv_sell(&sells, &x, &mut y, &pool));
        let symm = SymmSpmv::build(a).expect("suite matrices are exactly symmetric");
        let (sym, _) = bench_secs(5, budget, || spmv_symm(&symm, &x, &mut y, &pool));
        let gf = |t: f64, elems: usize| 2.0 * elems as f64 / t / 1e9;
        println!("spmv crs      : {crs:.6}s ({:.2} GFLOP/s)", gf(crs, a.nnz()));
        // Symmetric storage does the full 2·nnz flops from ~half the bytes.
        println!(
            "spmv symmcsr  : {sym:.6}s ({:.2} GFLOP/s, {:.0}% of crs matrix bytes)",
            gf(sym, a.nnz()),
            100.0 * symm.matrix().stored_elements() as f64 / a.nnz() as f64
        );
        println!(
            "spmv sell-8   : {sel:.6}s ({:.2} GFLOP/s, {:+.1}% pad)",
            gf(sel, sell.stored_elements()),
            100.0 * (sell.overhead_vs(a.nnz()) - 1.0)
        );
        println!(
            "spmv sell-8 σ : {sels:.6}s ({:.2} GFLOP/s, {:+.1}% pad)",
            gf(sels, sells.stored_elements()),
            100.0 * (sells.overhead_vs(a.nnz()) - 1.0)
        );
    }

    // --- Triangular solves, one plan per variant ---------------------------
    println!("\nforward+backward substitution (one preconditioner application;");
    println!("plan built once outside the timed region, setup shown separately):");
    let mk = |ordering, bs: usize, w: usize| SolverConfig {
        ordering,
        bs,
        w,
        spmv: SpmvKind::Crs,
        shift: d.shift,
        ..Default::default()
    };
    let mut variants: Vec<(String, SolverConfig)> = vec![
        ("serial (natural)".into(), mk(OrderingKind::Natural, 1, 1)),
        ("level (natural)".into(), mk(OrderingKind::Level, 1, 1)),
        ("MC".into(), mk(OrderingKind::Mc, 1, 1)),
    ];
    for bs in [8usize, 16, 32] {
        variants.push((format!("BMC bs={bs}"), mk(OrderingKind::Bmc, bs, 8)));
        variants.push((format!("HBMC bs={bs} w=8"), mk(OrderingKind::Hbmc, bs, 8)));
    }
    let mut total_setup = 0.0;
    for (label, cfg) in &variants {
        // Setup phase — NOT timed by the kernel loop below.
        let plan = SolverPlan::build(a, cfg).expect("plan build");
        let setup = plan.setup.setup_seconds();
        total_setup += setup;
        let n = plan.n_aug();
        let r = vec![1.0f64; n];
        let mut s = vec![0.0f64; n];
        let mut z = vec![0.0f64; n];
        let (t, _) = bench_secs(3, budget, || plan.trisolver.apply(&r, &mut s, &mut z, &pool));
        println!(
            "{label:<22} [{:>10}]: {t:.6}s/apply | setup {setup:.3}s \
             (ordering {:.3} + factor {:.3} + storage {:.3}), {} colors",
            plan.setup.kernel_path,
            plan.setup.ordering_seconds,
            plan.setup.factor_seconds,
            plan.setup.storage_seconds,
            plan.setup.num_colors,
        );
    }
    println!("total setup across variants: {total_setup:.3}s (paid once per plan, amortized over solves)");

    // --- scaling in w ------------------------------------------------------
    println!("\nHBMC forward substitution vs SIMD width (bs=16; plans prebuilt):");
    for w in [2usize, 4, 8, 16] {
        let plan = SolverPlan::build(a, &mk(OrderingKind::Hbmc, 16, w)).expect("plan build");
        let n = plan.n_aug();
        let r = vec![1.0f64; n];
        let mut y = vec![0.0f64; n];
        let (t, _) = bench_secs(3, budget, || plan.trisolver.forward(&r, &mut y, &pool));
        println!(
            "  w={w:<2} [{:>10}]: {t:.6}s (setup {:.3}s)",
            plan.setup.kernel_path,
            plan.setup.setup_seconds()
        );
    }
}
