//! Serving-throughput bench: the payoff of the asynchronous job queue.
//!
//! Workload: M concurrent clients, each firing K single-RHS requests for
//! the *same* (matrix, config) key — the ROADMAP's "heavy traffic, few
//! matrices" shape. Three serving strategies over identical work:
//!
//! 1. `sequential`   — one thread, K·M blocking `solve` calls (baseline;
//!    every call is its own dispatched batch of width 1),
//! 2. `threads`      — M threads, blocking `solve` calls that ride the
//!    queue and coalesce *implicitly*,
//! 3. `submit/wait`  — M threads submit everything up front, then wait;
//!    maximal opportunity for the dispatcher to form wide batches.
//!
//! The plan is warmed before every timed region: this bench measures
//! phase-2 serving, not setup. Batching statistics are printed per
//! strategy so the width → throughput relation is visible. Every solve
//! rides the fused single-dispatch CG loop, so `ServiceStats::dispatches`
//! should track `solves` one-to-one.
//!
//! `cargo bench --bench serving [-- full | -- --quick]`
//!
//! Quick mode (`--quick` arg or `HBMC_BENCH_QUICK=1`): a CI-friendly
//! shrunk workload that also writes `BENCH_serving.json` (solves/s and
//! dispatches/solve per strategy, repo-root stable name) as a
//! perf-trajectory artifact.
//!
//! `HBMC_PROFILE=<store.json>` runs the whole workload under the tuned
//! profile stored for this matrix + machine (`hbmc tune` output), so the
//! serving trajectory can be tracked for the production configuration as
//! well as the fixed reference one.

use std::sync::{Arc, Barrier};
use std::thread;
use std::time::{Duration, Instant};

use hbmc::api::{ServiceStats, SolveRequest, SolverService};
use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::gen::{suite, Dataset};
use hbmc::tune::ProfileStore;

struct Workload {
    clients: usize,
    requests: usize,
}

fn service_for(cfg: &SolverConfig, d: &Dataset) -> (Arc<SolverService>, hbmc::api::MatrixHandle) {
    let service = Arc::new(SolverService::with_config(cfg.clone()).expect("valid config"));
    let handle = service.register_matrix_arc(Arc::new(d.matrix.clone()));
    // Warm the plan: the timed region below is pure serving.
    service.solve(handle, &d.b).expect("warmup solve");
    (service, handle)
}

fn rhs_for(d: &Dataset, i: usize) -> Vec<f64> {
    let f = 1.0 + (i % 7) as f64;
    d.b.iter().map(|v| v * f).collect()
}

/// Print one strategy's stats; returns (solves/s, dispatches/solve) for
/// the quick-mode JSON.
fn report(
    label: &str,
    wall: f64,
    service: &SolverService,
    warm: ServiceStats,
    w: &Workload,
) -> (f64, f64) {
    // Subtract the warmup solve's batch from every counter so the printed
    // width/coalescing numbers describe exactly the timed region.
    let st = service.stats();
    let batches = st.batches - warm.batches;
    let rhs = st.batched_rhs - warm.batched_rhs;
    let coalesced = st.coalesced_rhs - warm.coalesced_rhs;
    let solves = st.solves - warm.solves;
    let dispatches = st.dispatches - warm.dispatches;
    let width = if batches == 0 { 0.0 } else { rhs as f64 / batches as f64 };
    let total = (w.clients * w.requests) as f64;
    let per_solve = if solves == 0 { 0.0 } else { dispatches as f64 / solves as f64 };
    println!(
        "{label:<12} {wall:.3}s  ({:.1} solves/s)  batches={batches} mean_width={width:.2} \
         coalesced_rhs={coalesced} dispatches/solve={per_solve:.2}",
        total / wall,
    );
    (total / wall, per_solve)
}

fn main() {
    let quick =
        std::env::args().any(|a| a == "--quick") || std::env::var("HBMC_BENCH_QUICK").is_ok();
    let scale = if std::env::args().any(|a| a == "full") {
        Scale::Small
    } else {
        Scale::Tiny
    };
    let w = if quick {
        Workload { clients: QUICK_CLIENTS, requests: QUICK_REQUESTS }
    } else {
        Workload { clients: CLIENTS, requests: REQUESTS }
    };
    let d = suite::dataset("g3_circuit", scale);
    let mut cfg = SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 8,
        w: 4,
        spmv: SpmvKind::Sell,
        rtol: 1e-7,
        ..Default::default()
    };
    // HBMC_PROFILE=<store.json>: run the whole bench under the tuned
    // profile for this matrix + machine (produced by `hbmc tune`), so the
    // serving numbers track what production would actually run.
    if let Some(store_path) = std::env::var_os("HBMC_PROFILE") {
        let store = ProfileStore::open(&store_path).expect("readable profile store");
        match store.lookup(&d.matrix) {
            Some(p) => {
                cfg = p.apply_to(&cfg);
                println!("profile: {} from {store_path:?}", p.label());
            }
            None => println!("profile: none for this matrix/machine in {store_path:?}"),
        }
    }
    cfg.queue.max_batch = w.clients * w.requests;
    cfg.queue.max_wait = Duration::from_millis(2);
    println!(
        "serving bench on {} (n={}, nnz={}): {} clients x {} requests, \
         max_batch={} max_wait={:?}\n",
        d.name,
        d.n(),
        d.nnz(),
        w.clients,
        w.requests,
        cfg.queue.max_batch,
        cfg.queue.max_wait
    );

    let mut json_entries: Vec<String> = Vec::new();
    let mut record = |label: &str, (rate, per_solve): (f64, f64)| {
        json_entries.push(format!(
            "    {{\"strategy\": \"{label}\", \"solves_per_sec\": {rate:.3}, \
             \"dispatches_per_solve\": {per_solve:.2}}}"
        ));
    };

    // 1. Sequential blocking baseline — with a zero flush window, so the
    //    baseline measures solving, not the batching delay (a lone
    //    blocking caller gains nothing from holding a window open).
    {
        let mut cfg_seq = cfg.clone();
        cfg_seq.queue.max_wait = Duration::ZERO;
        let (service, handle) = service_for(&cfg_seq, &d);
        let warm = service.stats();
        let t0 = Instant::now();
        for i in 0..w.clients * w.requests {
            let out = service.solve(handle, &rhs_for(&d, i)).expect("solve");
            assert!(out.report.converged);
        }
        record("sequential", report("sequential", t0.elapsed().as_secs_f64(), &service, warm, &w));
    }

    // 2. Concurrent blocking callers (implicit coalescing).
    {
        let (service, handle) = service_for(&cfg, &d);
        let warm = service.stats();
        let barrier = Arc::new(Barrier::new(w.clients));
        let t0 = Instant::now();
        let workers: Vec<_> = (0..w.clients)
            .map(|c| {
                let service = Arc::clone(&service);
                let barrier = Arc::clone(&barrier);
                let rhss: Vec<Vec<f64>> =
                    (0..w.requests).map(|k| rhs_for(&d, c * w.requests + k)).collect();
                thread::spawn(move || {
                    barrier.wait();
                    for rhs in &rhss {
                        let out = service.solve(handle, rhs).expect("solve");
                        assert!(out.report.converged);
                    }
                })
            })
            .collect();
        for t in workers {
            t.join().expect("client thread");
        }
        record("threads", report("threads", t0.elapsed().as_secs_f64(), &service, warm, &w));
    }

    // 3. Submit everything, then wait (explicit async fan-in).
    let queue_wait_us: (u64, u64); // (p50, p99) over the fan-in strategy
    {
        let (service, handle) = service_for(&cfg, &d);
        let warm = service.stats();
        let barrier = Arc::new(Barrier::new(w.clients));
        let t0 = Instant::now();
        let workers: Vec<_> = (0..w.clients)
            .map(|c| {
                let service = Arc::clone(&service);
                let barrier = Arc::clone(&barrier);
                let rhss: Vec<Vec<f64>> =
                    (0..w.requests).map(|k| rhs_for(&d, c * w.requests + k)).collect();
                thread::spawn(move || {
                    barrier.wait();
                    let req = SolveRequest::new();
                    let jobs: Vec<_> = rhss
                        .iter()
                        .map(|rhs| service.submit(handle, rhs, &req).expect("submit"))
                        .collect();
                    for job in jobs {
                        let out = job.wait().expect("wait");
                        assert!(out.report.converged);
                    }
                })
            })
            .collect();
        for t in workers {
            t.join().expect("client thread");
        }
        record(
            "submit/wait",
            report("submit/wait", t0.elapsed().as_secs_f64(), &service, warm, &w),
        );
        let snap = service.metrics_snapshot();
        let qw = snap.histogram("hbmc_queue_wait_microseconds").expect("queue-wait histogram");
        queue_wait_us = (qw.quantile(0.5).unwrap_or(0), qw.quantile(0.99).unwrap_or(0));
        println!(
            "queue wait   p50={}µs p99={}µs over {} dispatched jobs",
            queue_wait_us.0, queue_wait_us.1, qw.count
        );
    }

    // 4. Overload flood: the same fan-in traffic against a deliberately
    //    tiny bounded queue — backpressure must reject fast and typed,
    //    and the rejected/shed counts join the perf trajectory so an
    //    admission-control regression is as visible as a throughput one.
    let (overloaded, shed) = {
        let mut cfg_over = cfg.clone();
        cfg_over.queue.max_queue_depth = Some(4);
        cfg_over.queue.max_wait = Duration::from_millis(50);
        let (service, handle) = service_for(&cfg_over, &d);
        // One already-expired job exercises the shed path deterministically.
        let doomed = service
            .submit(handle, &d.b, &SolveRequest::new().deadline(Duration::from_nanos(1)))
            .expect("submit doomed job");
        let mut accepted = Vec::new();
        let mut rejected = 0u64;
        let t0 = Instant::now();
        for i in 0..w.clients * w.requests {
            match service.submit(handle, &rhs_for(&d, i), &SolveRequest::new()) {
                Ok(job) => accepted.push(job),
                Err(hbmc::api::HbmcError::Overloaded { .. }) => rejected += 1,
                Err(e) => panic!("flood must only fail Overloaded: {e}"),
            }
        }
        let submit_wall = t0.elapsed().as_secs_f64();
        assert!(doomed.wait().is_err(), "1ns-budget job must be shed");
        for job in accepted {
            assert!(job.wait().expect("accepted job").report.converged);
        }
        let st = service.stats();
        println!(
            "overload     {submit_wall:.3}s submit wall  depth_limit=4 \
             rejected={rejected} shed={} (typed, non-blocking)",
            st.shed
        );
        (st.overloaded, st.shed)
    };
    println!("admission    overloaded={overloaded} shed={shed}");

    if quick {
        let json = format!(
            "{{\n  \"bench\": \"serving-quick\",\n  \
             \"provenance\": \"measured: serving quick bench\",\n  \"dataset\": \"{}\",\n  \
             \"clients\": {},\n  \
             \"requests\": {},\n  \"strategies\": [\n{}\n  ],\n  \
             \"queue_wait_p50_us\": {},\n  \"queue_wait_p99_us\": {},\n  \
             \"overloaded\": {},\n  \"shed\": {}\n}}\n",
            d.name,
            w.clients,
            w.requests,
            json_entries.join(",\n"),
            queue_wait_us.0,
            queue_wait_us.1,
            overloaded,
            shed
        );
        // Stable name at the repo root (CWD here is the package dir).
        let path = hbmc::util::bench_artifact_path("BENCH_serving.json");
        std::fs::write(&path, &json).expect("write BENCH_serving.json");
        println!("\n{json}");
        println!("wrote {}", path.display());
    }
}

const CLIENTS: usize = 4;
const REQUESTS: usize = 6;
const QUICK_CLIENTS: usize = 2;
const QUICK_REQUESTS: usize = 3;
