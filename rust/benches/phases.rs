//! In-region kernel-phase profile across the five orderings — the flight
//! recorder's perf-trajectory artifact. For each ordering the bench runs
//! the fused solve with profiling OFF (best of 3) and ON (best of 3),
//! records the overhead ratio, and drains the profiled run's per-phase
//! shares, barrier-wait imbalance and coverage into `BENCH_phases.json`.
//! The HBMC run's span timeline is additionally written as
//! `TRACE_phases.json` — a ready-to-open chrome://tracing document that CI
//! uploads next to the numbers.
//!
//! `cargo bench --bench phases [-- --quick]`
//!
//! Quick mode (`--quick` or `HBMC_BENCH_QUICK=1`) runs the Tiny dataset at
//! up to 2 threads for CI; the full run uses Small scale at up to 4.

use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::pool::Pool;
use hbmc::gen::suite;
use hbmc::obs::{chrome_trace_json, PhaseProfile, PHASE_NAMES};
use hbmc::solver::plan::{ExecOptions, SolverPlan};

struct OrderingRun {
    label: String,
    iterations: usize,
    plain_seconds: f64,
    profiled_seconds: f64,
    profile: PhaseProfile,
}

impl OrderingRun {
    /// Profiled wall over unprofiled wall — the recorder's cost. The
    /// acceptance budget is < 1.05; quick-mode solves are tiny, so noise
    /// dominates and the gate only consumes the cross-ordering maximum.
    fn overhead_ratio(&self) -> f64 {
        self.profiled_seconds / self.plain_seconds.max(1e-12)
    }

    fn json(&self) -> String {
        let shares = self.profile.phase_shares();
        let share_members = PHASE_NAMES
            .iter()
            .zip(&shares)
            .map(|(name, s)| format!("\"{name}\": {s:.4}"))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "    {{\"label\": \"{}\", \"iterations\": {}, \"solve_seconds\": {:.6e}, \
             \"profiled_solve_seconds\": {:.6e}, \"profile_overhead_ratio\": {:.4}, \
             \"coverage\": {:.4}, \"barrier_wait_imbalance\": {:.4}, \
             \"dropped_spans\": {}, \"phase_shares\": {{{share_members}}}}}",
            self.label,
            self.iterations,
            self.plain_seconds,
            self.profiled_seconds,
            self.overhead_ratio(),
            self.profile.coverage(),
            self.profile.barrier_wait_imbalance(),
            self.profile.dropped(),
        )
    }
}

/// Best-of-3 fused solve; returns (best wall seconds, the best outcome).
fn best_of_3(
    plan: &SolverPlan,
    pool: &Pool,
    b: &[f64],
    opts: &ExecOptions,
) -> (f64, hbmc::solver::plan::SolveOutcome) {
    let mut best = plan.execute(pool, b, opts).expect("solve");
    for _ in 0..2 {
        let o = plan.execute(pool, b, opts).expect("solve");
        if o.cg.solve_seconds < best.cg.solve_seconds {
            best = o;
        }
    }
    assert!(best.cg.converged, "phase bench solve must converge");
    (best.cg.solve_seconds, best)
}

fn run_ordering(d: &hbmc::gen::Dataset, ordering: OrderingKind, threads: usize) -> OrderingRun {
    let cfg = SolverConfig {
        ordering,
        bs: 8,
        w: 4,
        spmv: SpmvKind::Crs,
        threads,
        shift: d.shift,
        rtol: 1e-6,
        ..Default::default()
    };
    let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan build");
    let pool = Pool::new(threads);
    let plain = ExecOptions::default();
    let profiled = ExecOptions { profile: true, ..Default::default() };
    let _ = plan.execute(&pool, &d.b, &plain).expect("warmup");
    let (plain_seconds, plain_out) = best_of_3(&plan, &pool, &d.b, &plain);
    let (profiled_seconds, prof_out) = best_of_3(&plan, &pool, &d.b, &profiled);
    assert!(plain_out.profile.is_none(), "profile off must not record");
    let profile = prof_out.profile.expect("profiled fused solve carries a profile");
    OrderingRun {
        label: ordering.to_string(),
        iterations: prof_out.cg.iterations.max(1),
        plain_seconds,
        profiled_seconds,
        profile,
    }
}

fn main() {
    let quick =
        std::env::args().any(|a| a == "--quick") || std::env::var("HBMC_BENCH_QUICK").is_ok();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let (scale, threads) =
        if quick { (Scale::Tiny, cores.min(2)) } else { (Scale::Small, cores.min(4)) };
    let d = suite::dataset("g3_circuit", scale);
    println!(
        "phase bench: {} n={} nnz={} threads={threads} ({})",
        d.name,
        d.n(),
        d.nnz(),
        if quick { "quick" } else { "full" }
    );

    let orderings = [
        OrderingKind::Natural,
        OrderingKind::Mc,
        OrderingKind::Bmc,
        OrderingKind::Hbmc,
        OrderingKind::Level,
    ];
    let mut runs = Vec::new();
    for ordering in orderings {
        let run = run_ordering(&d, ordering, threads);
        println!(
            "{:<8} iters={:<4} plain {:.6}s profiled {:.6}s (x{:.3}) coverage {:.1}% \
             imbalance {:.2}",
            run.label,
            run.iterations,
            run.plain_seconds,
            run.profiled_seconds,
            run.overhead_ratio(),
            100.0 * run.profile.coverage(),
            run.profile.barrier_wait_imbalance(),
        );
        runs.push(run);
    }

    // The chrome-trace sample comes from the paper's headline ordering.
    let hbmc_run = runs
        .iter()
        .find(|r| r.label == OrderingKind::Hbmc.to_string())
        .expect("HBMC ran");
    let trace_path = hbmc::util::bench_artifact_path("TRACE_phases.json");
    std::fs::write(&trace_path, chrome_trace_json(&hbmc_run.profile))
        .expect("write TRACE_phases.json");

    let max_overhead = runs.iter().map(OrderingRun::overhead_ratio).fold(0.0, f64::max);
    let min_coverage = runs.iter().map(|r| r.profile.coverage()).fold(f64::INFINITY, f64::min);
    let entries = runs.iter().map(OrderingRun::json).collect::<Vec<_>>().join(",\n");
    let json = format!(
        "{{\n  \"bench\": \"phases-quick\",\n  \
         \"provenance\": \"measured: phases quick bench\",\n  \
         \"dataset\": \"{}\",\n  \"n\": {},\n  \"nnz\": {},\n  \"threads\": {threads},\n  \
         \"orderings\": [\n{entries}\n  ],\n  \
         \"max_profile_overhead_ratio\": {max_overhead:.4},\n  \
         \"min_coverage\": {min_coverage:.4}\n}}\n",
        d.name,
        d.n(),
        d.nnz(),
    );
    let path = hbmc::util::bench_artifact_path("BENCH_phases.json");
    std::fs::write(&path, &json).expect("write BENCH_phases.json");
    println!("{json}");
    println!("wrote {} and {}", path.display(), trace_path.display());
}
