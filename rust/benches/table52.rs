//! Bench: regenerate **Table 5.2** — ICCG iteration counts for MC / BMC /
//! HBMC (bs = 32) over the five datasets, checking the BMC ≡ HBMC
//! equivalence column-for-column.
//!
//! `cargo bench --bench table52 [-- full]`

use hbmc::config::Scale;
use hbmc::coordinator::experiments::table_5_2;

fn main() {
    let scale = if std::env::args().any(|a| a == "full") { Scale::Full } else { Scale::Small };
    eprintln!("table 5.2 at scale {scale:?} (threads=1) ...");
    let (table, raw) = table_5_2(scale, 1).expect("table 5.2 run");
    print!("{}", table.render());
    // Exact in exact arithmetic; FP reassociation may shift the rtol
    // crossing by one (the paper's Audikw_1 row: 1714 vs 1715).
    let equal = raw.iter().all(|r| r[1].abs_diff(r[2]) <= 2 + r[1] / 20);
    println!("\npaper check — BMC == HBMC iterations (±1) on every dataset: {equal}");
    println!(
        "paper check — MC worst on {}/{} datasets",
        raw.iter().filter(|r| r[0] >= r[1]).count(),
        raw.len()
    );
    assert!(equal, "equivalence violated");
}
