//! The unified triangular-solver abstraction: one [`TriSolver`] trait with
//! five implementations — four ordering-specific ones wrapping the
//! free-function kernel paths (`trisolve_serial` / `trisolve_mc` /
//! `trisolve_bmc` / `trisolve_hbmc`) plus the level-scheduled wavefront
//! path (`trisolve_level`, natural ordering + DAG schedule) — so the CG
//! loop, the plan builder and the benches all dispatch through one object
//! instead of per-ordering match arms.
//!
//! Implementations are immutable once built and `Send + Sync`: a plan
//! holding one behind an `Arc` can serve many concurrent sessions.

use crate::coordinator::pool::{Pool, SyncSlice};
use crate::factor::split::{SellTriFactors, TriFactors};
use crate::solver::trisolve_hbmc::{HbmcMeta, KernelPath};
use crate::solver::{blas1, trisolve_bmc, trisolve_hbmc, trisolve_mc, trisolve_serial};

/// An IC(0) substitution engine `z = (L Lᵀ)⁻¹ r` specialized to one
/// parallel ordering.
pub trait TriSolver: Send + Sync {
    /// Forward substitution `L y = r`.
    fn forward(&self, r: &[f64], y: &mut [f64], pool: &Pool);

    /// Backward substitution `Lᵀ z = y`.
    fn backward(&self, y: &[f64], z: &mut [f64], pool: &Pool);

    /// Forward-sweep body executed by worker `tid` from *inside* an
    /// already open pool region (the single-dispatch CG loop). Every
    /// thread of the region must call it with identical arguments; color
    /// barriers happen inside, and the **caller** must place a
    /// [`Pool::phase_barrier`] after the call before `y` is read across
    /// threads.
    ///
    /// Default: thread 0 runs the plain [`TriSolver::forward`] serially
    /// while the others fall through to the caller's phase barrier —
    /// correct only for implementations whose `forward` never dispatches
    /// on the pool (the serial and identity solvers). Implementations that
    /// parallelize their sweeps MUST override with a real worker body.
    fn forward_worker(&self, r: &[f64], ys: &SyncSlice<f64>, pool: &Pool, tid: usize, nt: usize) {
        let _ = nt;
        if tid == 0 {
            // SAFETY: region phase contract — no other thread touches `y`
            // until the caller's trailing barrier.
            let y = unsafe { std::slice::from_raw_parts_mut(ys.as_mut_ptr(), ys.len()) };
            self.forward(r, y, pool);
        }
    }

    /// Backward-sweep body for worker `tid`; same contract as
    /// [`TriSolver::forward_worker`].
    fn backward_worker(&self, y: &[f64], zs: &SyncSlice<f64>, pool: &Pool, tid: usize, nt: usize) {
        let _ = nt;
        if tid == 0 {
            // SAFETY: see `forward_worker`.
            let z = unsafe { std::slice::from_raw_parts_mut(zs.as_mut_ptr(), zs.len()) };
            self.backward(y, z, pool);
        }
    }

    /// Colors in the ordering (1 when unordered/serial).
    fn num_colors(&self) -> usize;

    /// Thread synchronizations per substitution sweep (= `n_c − 1`).
    fn syncs_per_sweep(&self) -> usize {
        self.num_colors().saturating_sub(1)
    }

    /// Inner kernel identifier ("scalar", "avx2-w4", "avx512-w8"); "n/a"
    /// for paths without a selectable kernel.
    fn kernel_path(&self) -> &'static str {
        "n/a"
    }

    /// Stored elements of both substitution triangles in their chosen
    /// format (SELL padding included for HBMC) — feeds the §5.2.2 metric.
    fn tri_elements(&self) -> usize;

    /// Strategy name for reports.
    fn name(&self) -> &'static str;

    /// Full preconditioner application `z = (L Lᵀ)⁻¹ r`; `scratch` holds
    /// the forward-substitution result.
    fn apply(&self, r: &[f64], scratch: &mut [f64], z: &mut [f64], pool: &Pool) {
        self.forward(r, scratch, pool);
        self.backward(scratch, z, pool);
    }
}

/// Identity "preconditioner" (plain CG) — diagnostic baseline.
pub struct IdentityPrecond;

impl TriSolver for IdentityPrecond {
    fn forward(&self, r: &[f64], y: &mut [f64], _pool: &Pool) {
        y.copy_from_slice(r);
    }

    fn backward(&self, y: &[f64], z: &mut [f64], _pool: &Pool) {
        z.copy_from_slice(y);
    }

    fn forward_worker(&self, r: &[f64], ys: &SyncSlice<f64>, _pool: &Pool, tid: usize, nt: usize) {
        let nc = blas1::num_chunks(r.len());
        blas1::copy_chunks(r, ys, Pool::chunk(nc, tid, nt));
    }

    fn backward_worker(&self, y: &[f64], zs: &SyncSlice<f64>, _pool: &Pool, tid: usize, nt: usize) {
        let nc = blas1::num_chunks(y.len());
        blas1::copy_chunks(y, zs, Pool::chunk(nc, tid, nt));
    }

    fn num_colors(&self) -> usize {
        1
    }

    fn tri_elements(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str {
        "identity"
    }
}

/// Serial substitutions under natural ordering (also the correctness
/// oracle the parallel variants are tested against).
pub struct SerialTriSolver {
    pub tri: TriFactors,
}

impl SerialTriSolver {
    pub fn new(tri: TriFactors) -> SerialTriSolver {
        SerialTriSolver { tri }
    }
}

impl TriSolver for SerialTriSolver {
    fn forward(&self, r: &[f64], y: &mut [f64], _pool: &Pool) {
        trisolve_serial::forward(&self.tri, r, y);
    }

    fn backward(&self, y: &[f64], z: &mut [f64], _pool: &Pool) {
        trisolve_serial::backward(&self.tri, y, z);
    }

    fn num_colors(&self) -> usize {
        1
    }

    fn tri_elements(&self) -> usize {
        self.tri.lower.nnz() + self.tri.upper.nnz()
    }

    fn name(&self) -> &'static str {
        "ic0-serial"
    }
}

/// Nodal multi-color substitutions (the paper's "MC" baseline).
pub struct McTriSolver {
    pub tri: TriFactors,
    pub color_ptr: Vec<usize>,
}

impl McTriSolver {
    pub fn new(tri: TriFactors, color_ptr: Vec<usize>) -> McTriSolver {
        McTriSolver { tri, color_ptr }
    }
}

impl TriSolver for McTriSolver {
    fn forward(&self, r: &[f64], y: &mut [f64], pool: &Pool) {
        trisolve_mc::forward(&self.tri, &self.color_ptr, r, y, pool);
    }

    fn backward(&self, y: &[f64], z: &mut [f64], pool: &Pool) {
        trisolve_mc::backward(&self.tri, &self.color_ptr, y, z, pool);
    }

    fn forward_worker(&self, r: &[f64], ys: &SyncSlice<f64>, pool: &Pool, tid: usize, nt: usize) {
        trisolve_mc::forward_worker(&self.tri, &self.color_ptr, r, ys, pool, tid, nt);
    }

    fn backward_worker(&self, y: &[f64], zs: &SyncSlice<f64>, pool: &Pool, tid: usize, nt: usize) {
        trisolve_mc::backward_worker(&self.tri, &self.color_ptr, y, zs, pool, tid, nt);
    }

    fn num_colors(&self) -> usize {
        self.color_ptr.len() - 1
    }

    fn tri_elements(&self) -> usize {
        self.tri.lower.nnz() + self.tri.upper.nnz()
    }

    fn name(&self) -> &'static str {
        "ic0-mc"
    }
}

/// Block multi-color substitutions (the paper's "BMC" baseline).
pub struct BmcTriSolver {
    pub tri: TriFactors,
    pub color_ptr: Vec<usize>,
    pub bs: usize,
}

impl BmcTriSolver {
    pub fn new(tri: TriFactors, color_ptr: Vec<usize>, bs: usize) -> BmcTriSolver {
        BmcTriSolver { tri, color_ptr, bs }
    }
}

impl TriSolver for BmcTriSolver {
    fn forward(&self, r: &[f64], y: &mut [f64], pool: &Pool) {
        trisolve_bmc::forward(&self.tri, &self.color_ptr, self.bs, r, y, pool);
    }

    fn backward(&self, y: &[f64], z: &mut [f64], pool: &Pool) {
        trisolve_bmc::backward(&self.tri, &self.color_ptr, self.bs, y, z, pool);
    }

    fn forward_worker(&self, r: &[f64], ys: &SyncSlice<f64>, pool: &Pool, tid: usize, nt: usize) {
        trisolve_bmc::forward_worker(&self.tri, &self.color_ptr, self.bs, r, ys, pool, tid, nt);
    }

    fn backward_worker(&self, y: &[f64], zs: &SyncSlice<f64>, pool: &Pool, tid: usize, nt: usize) {
        trisolve_bmc::backward_worker(&self.tri, &self.color_ptr, self.bs, y, zs, pool, tid, nt);
    }

    fn num_colors(&self) -> usize {
        self.color_ptr.len() - 1
    }

    fn tri_elements(&self) -> usize {
        self.tri.lower.nnz() + self.tri.upper.nnz()
    }

    fn name(&self) -> &'static str {
        "ic0-bmc"
    }
}

/// Hierarchical block multi-color substitutions — the paper's vectorized
/// kernel (§4.3) over SELL-w triangles.
pub struct HbmcTriSolver {
    pub meta: HbmcMeta,
    pub sell: SellTriFactors,
    pub path: KernelPath,
}

impl HbmcTriSolver {
    pub fn new(meta: HbmcMeta, sell: SellTriFactors, path: KernelPath) -> HbmcTriSolver {
        HbmcTriSolver { meta, sell, path }
    }
}

impl TriSolver for HbmcTriSolver {
    fn forward(&self, r: &[f64], y: &mut [f64], pool: &Pool) {
        trisolve_hbmc::forward(&self.meta, &self.sell, r, y, pool, self.path);
    }

    fn backward(&self, y: &[f64], z: &mut [f64], pool: &Pool) {
        trisolve_hbmc::backward(&self.meta, &self.sell, y, z, pool, self.path);
    }

    fn forward_worker(&self, r: &[f64], ys: &SyncSlice<f64>, pool: &Pool, tid: usize, nt: usize) {
        trisolve_hbmc::forward_worker(&self.meta, &self.sell, r, ys, pool, tid, nt, self.path);
    }

    fn backward_worker(&self, y: &[f64], zs: &SyncSlice<f64>, pool: &Pool, tid: usize, nt: usize) {
        trisolve_hbmc::backward_worker(&self.meta, &self.sell, y, zs, pool, tid, nt, self.path);
    }

    fn num_colors(&self) -> usize {
        self.meta.num_colors
    }

    fn kernel_path(&self) -> &'static str {
        self.path.name()
    }

    fn tri_elements(&self) -> usize {
        self.sell.stored_elements()
    }

    fn name(&self) -> &'static str {
        "ic0-hbmc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;
    use crate::ordering::bmc::bmc_order;
    use crate::ordering::hbmc::hbmc_order;
    use crate::ordering::mc::mc_order;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> crate::sparse::csr::Csr {
        let mut rng = Rng::new(seed);
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 8.0);
            for _ in 0..3 {
                let j = rng.below(n);
                if j != i {
                    c.push_sym(i, j, -0.4);
                }
            }
        }
        c.to_csr()
    }

    /// Each implementation must equal the serial oracle on its own
    /// reordered system (they compute the same `M⁻¹ r` for that matrix).
    #[test]
    fn all_implementations_agree_with_serial_oracle() {
        let a0 = random_spd(140, 61);
        let pool = Pool::new(2);

        let cases: Vec<(Box<dyn TriSolver>, crate::sparse::csr::Csr)> = vec![
            {
                let mc = mc_order(&a0);
                let a = a0.permute_sym(&mc.perm);
                let tri = TriFactors::from_ic(&ic0(&a, 0.0).unwrap());
                (Box::new(McTriSolver::new(tri, mc.color_ptr)) as Box<dyn TriSolver>, a)
            },
            {
                let ord = bmc_order(&a0, 8);
                let a = a0.permute_sym(&ord.perm);
                let tri = TriFactors::from_ic(&ic0(&a, 0.0).unwrap());
                (Box::new(BmcTriSolver::new(tri, ord.color_ptr, 8)) as Box<dyn TriSolver>, a)
            },
            {
                let ord = hbmc_order(&a0, 8, 4);
                let a = a0.permute_sym(&ord.perm);
                let tri = TriFactors::from_ic(&ic0(&a, 0.0).unwrap());
                let sell = SellTriFactors::from_tri(&tri, 4);
                let meta = HbmcMeta::from_ordering(&ord);
                (Box::new(HbmcTriSolver::new(meta, sell, KernelPath::Scalar)) as Box<dyn TriSolver>, a)
            },
        ];

        for (solver, a) in &cases {
            let n = a.n();
            let tri = TriFactors::from_ic(&ic0(a, 0.0).unwrap());
            let r: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
            let mut scratch = vec![0.0; n];
            let mut z_ref = vec![0.0; n];
            trisolve_serial::apply(&tri, &r, &mut scratch, &mut z_ref);
            let mut s = vec![0.0; n];
            let mut z = vec![0.0; n];
            solver.apply(&r, &mut s, &mut z, &pool);
            assert!(
                crate::util::max_abs_diff(&z, &z_ref) < 1e-12,
                "{} deviates from serial oracle",
                solver.name()
            );
            assert_eq!(solver.syncs_per_sweep(), solver.num_colors() - 1);
            assert!(solver.tri_elements() > 0);
        }
    }

    #[test]
    fn serial_solver_reports_no_syncs() {
        let a = random_spd(40, 7);
        let tri = TriFactors::from_ic(&ic0(&a, 0.0).unwrap());
        let s = SerialTriSolver::new(tri);
        assert_eq!(s.num_colors(), 1);
        assert_eq!(s.syncs_per_sweep(), 0);
        assert_eq!(s.kernel_path(), "n/a");
        assert_eq!(s.name(), "ic0-serial");
    }

    #[test]
    fn identity_copies() {
        let p = IdentityPrecond;
        let pool = Pool::new(1);
        let r = vec![1.0, -2.0, 3.0];
        let mut s = vec![0.0; 3];
        let mut z = vec![0.0; 3];
        p.apply(&r, &mut s, &mut z, &pool);
        assert_eq!(z, r);
        assert_eq!(p.name(), "identity");
        assert_eq!(p.tri_elements(), 0);
    }

    #[test]
    fn hbmc_solver_reports_its_kernel_path() {
        let a0 = random_spd(120, 9);
        let ord = hbmc_order(&a0, 4, 4);
        let a = a0.permute_sym(&ord.perm);
        let tri = TriFactors::from_ic(&ic0(&a, 0.0).unwrap());
        let sell = SellTriFactors::from_tri(&tri, 4);
        let s = HbmcTriSolver::new(HbmcMeta::from_ordering(&ord), sell, KernelPath::Scalar);
        assert_eq!(s.kernel_path(), "scalar");
        assert_eq!(s.num_colors(), ord.num_colors);
    }
}
