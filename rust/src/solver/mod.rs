//! Solver kernels and the two-phase solve pipeline: triangular
//! substitutions (serial / MC / BMC / HBMC / level-scheduled) behind the
//! unified [`trisolve::TriSolver`] trait, sparse matrix-vector products (CRS &
//! SELL), BLAS-1 helpers, the preconditioned CG iteration, the immutable
//! setup product [`plan::SolverPlan`] and the assembled [`iccg::IccgSolver`]
//! convenience wrapper.

pub mod blas1;
pub mod cg;
pub mod gs;
pub mod iccg;
pub mod plan;
pub mod spmv;
pub mod trisolve;
pub mod trisolve_bmc;
pub mod trisolve_hbmc;
pub mod trisolve_level;
pub mod trisolve_mc;
pub mod trisolve_serial;
