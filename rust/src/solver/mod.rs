//! Solver kernels: triangular substitutions (serial / MC / BMC / HBMC),
//! sparse matrix-vector products (CRS & SELL), BLAS-1 helpers, the
//! preconditioned CG iteration and the assembled ICCG solver.

pub mod blas1;
pub mod cg;
pub mod gs;
pub mod iccg;
pub mod precond;
pub mod spmv;
pub mod trisolve_bmc;
pub mod trisolve_hbmc;
pub mod trisolve_mc;
pub mod trisolve_serial;
