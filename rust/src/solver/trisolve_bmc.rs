//! Multithreaded substitutions under block multi-color ordering (the
//! paper's "BMC" baseline, ref. [13]). Blocks of one color are independent
//! → parallel over blocks; *inside* a block the rows are processed
//! sequentially, which is exactly the data dependence that prevents SIMD
//! vectorization and motivates HBMC (§1, §4).

use crate::coordinator::pool::{Pool, SyncSlice};
use crate::factor::split::TriFactors;

/// Forward substitution `L y = r` under BMC ordering with block size `bs`.
pub fn forward(
    tri: &TriFactors,
    color_ptr: &[usize],
    bs: usize,
    r: &[f64],
    y: &mut [f64],
    pool: &Pool,
) {
    let n = tri.n();
    assert_eq!(r.len(), n);
    assert_eq!(y.len(), n);
    let ys = SyncSlice::new(y);
    pool.run(&|tid, nt| {
        forward_worker(tri, color_ptr, bs, r, &ys, pool, tid, nt);
    });
}

/// Forward-sweep body for worker `tid`, callable from inside an already
/// open pool region. Performs exactly `n_c − 1` color barriers; the caller
/// supplies any trailing barrier before `y` is read across threads.
#[allow(clippy::too_many_arguments)]
pub fn forward_worker(
    tri: &TriFactors,
    color_ptr: &[usize],
    bs: usize,
    r: &[f64],
    ys: &SyncSlice<f64>,
    pool: &Pool,
    tid: usize,
    nt: usize,
) {
    let ncolors = color_ptr.len() - 1;
    let row_ptr = tri.lower.row_ptr();
    let cols = tri.lower.cols();
    let vals = tri.lower.vals();
    for c in 0..ncolors {
        let (lo, hi) = (color_ptr[c], color_ptr[c + 1]);
        let nblocks = (hi - lo) / bs;
        let blocks = Pool::chunk(nblocks, tid, nt);
        for b in blocks {
            let row0 = lo + b * bs;
            for i in row0..row0 + bs {
                let mut s = r[i];
                for k in row_ptr[i] as usize..row_ptr[i + 1] as usize {
                    s -= vals[k] * unsafe { ys.get(cols[k] as usize) };
                }
                unsafe { ys.set(i, s * tri.diag_inv[i]) };
            }
        }
        if c + 1 < ncolors {
            pool.color_barrier();
        }
    }
}

/// Backward substitution `Lᵀ z = y` under BMC ordering (colors and
/// in-block rows reversed).
pub fn backward(
    tri: &TriFactors,
    color_ptr: &[usize],
    bs: usize,
    y: &[f64],
    z: &mut [f64],
    pool: &Pool,
) {
    let n = tri.n();
    assert_eq!(y.len(), n);
    assert_eq!(z.len(), n);
    let zs = SyncSlice::new(z);
    pool.run(&|tid, nt| {
        backward_worker(tri, color_ptr, bs, y, &zs, pool, tid, nt);
    });
}

/// Backward-sweep body for worker `tid` (see [`forward_worker`]).
#[allow(clippy::too_many_arguments)]
pub fn backward_worker(
    tri: &TriFactors,
    color_ptr: &[usize],
    bs: usize,
    y: &[f64],
    zs: &SyncSlice<f64>,
    pool: &Pool,
    tid: usize,
    nt: usize,
) {
    let ncolors = color_ptr.len() - 1;
    let row_ptr = tri.upper.row_ptr();
    let cols = tri.upper.cols();
    let vals = tri.upper.vals();
    for c in (0..ncolors).rev() {
        let (lo, hi) = (color_ptr[c], color_ptr[c + 1]);
        let nblocks = (hi - lo) / bs;
        let blocks = Pool::chunk(nblocks, tid, nt);
        for b in blocks {
            let row0 = lo + b * bs;
            for i in (row0..row0 + bs).rev() {
                let mut s = y[i];
                for k in row_ptr[i] as usize..row_ptr[i + 1] as usize {
                    s -= vals[k] * unsafe { zs.get(cols[k] as usize) };
                }
                unsafe { zs.set(i, s * tri.diag_inv[i]) };
            }
        }
        if c > 0 {
            pool.color_barrier();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;
    use crate::ordering::bmc::bmc_order;
    use crate::solver::trisolve_serial;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> crate::sparse::csr::Csr {
        let mut rng = Rng::new(seed);
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 8.0);
            for _ in 0..3 {
                let j = rng.below(n);
                if j != i {
                    c.push_sym(i, j, -0.4);
                }
            }
        }
        c.to_csr()
    }

    #[test]
    fn bmc_substitutions_match_serial() {
        let a0 = random_spd(130, 17);
        for &bs in &[4usize, 8, 16] {
            let ord = bmc_order(&a0, bs);
            let a = a0.permute_sym(&ord.perm);
            let f = ic0(&a, 0.0).unwrap();
            let tri = TriFactors::from_ic(&f);
            let n = a.n();
            let mut rng = Rng::new(18);
            let r: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();

            let mut y_ref = vec![0.0; n];
            trisolve_serial::forward(&tri, &r, &mut y_ref);
            let mut z_ref = vec![0.0; n];
            trisolve_serial::backward(&tri, &y_ref, &mut z_ref);

            for nt in [1usize, 3] {
                let pool = Pool::new(nt);
                let mut y = vec![0.0; n];
                forward(&tri, &ord.color_ptr, bs, &r, &mut y, &pool);
                assert!(
                    crate::util::max_abs_diff(&y, &y_ref) < 1e-13,
                    "fwd bs={bs} nt={nt}"
                );
                let mut z = vec![0.0; n];
                backward(&tri, &ord.color_ptr, bs, &y, &mut z, &pool);
                assert!(
                    crate::util::max_abs_diff(&z, &z_ref) < 1e-13,
                    "bwd bs={bs} nt={nt}"
                );
            }
        }
    }

    #[test]
    fn dummy_rows_stay_zero() {
        // A padded system: dummy slots must remain 0 through both sweeps
        // when the rhs is 0 there (identity diagonal, no coupling).
        let a0 = random_spd(30, 3); // 30 % 8 != 0 → dummies with bs=8
        let ord = bmc_order(&a0, 8);
        let a = a0.permute_sym(&ord.perm);
        assert!(a.n() > 30, "fixture must pad");
        let f = ic0(&a, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let r = ord.perm.apply_vec(&vec![1.0; 30], 0.0);
        let pool = Pool::new(1);
        let mut y = vec![0.0; a.n()];
        forward(&tri, &ord.color_ptr, 8, &r, &mut y, &pool);
        let mut z = vec![0.0; a.n()];
        backward(&tri, &ord.color_ptr, 8, &y, &mut z, &pool);
        for i in 0..a.n() {
            if ord.perm.old_of_new(i).is_none() {
                assert_eq!(z[i], 0.0, "dummy row {i} polluted");
            }
        }
    }
}
