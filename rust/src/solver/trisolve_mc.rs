//! Multithreaded substitutions under nodal multi-color ordering (the
//! paper's "MC" baseline). Rows of one color are mutually independent, so
//! each color is a parallel loop over rows; every off-diagonal reference
//! goes to an already-finished color. `n_c − 1` barriers per substitution.

use crate::coordinator::pool::{Pool, SyncSlice};
use crate::factor::split::TriFactors;

/// Forward substitution `L y = r` under MC ordering.
pub fn forward(tri: &TriFactors, color_ptr: &[usize], r: &[f64], y: &mut [f64], pool: &Pool) {
    let n = tri.n();
    assert_eq!(r.len(), n);
    assert_eq!(y.len(), n);
    let ys = SyncSlice::new(y);
    pool.run(&|tid, nt| {
        forward_worker(tri, color_ptr, r, &ys, pool, tid, nt);
    });
}

/// Forward-sweep body for worker `tid`, callable from inside an already
/// open pool region (the single-dispatch CG loop). Performs exactly
/// `n_c − 1` color barriers; the caller supplies any trailing barrier
/// before `y` is read across threads.
pub fn forward_worker(
    tri: &TriFactors,
    color_ptr: &[usize],
    r: &[f64],
    ys: &SyncSlice<f64>,
    pool: &Pool,
    tid: usize,
    nt: usize,
) {
    let ncolors = color_ptr.len() - 1;
    let row_ptr = tri.lower.row_ptr();
    let cols = tri.lower.cols();
    let vals = tri.lower.vals();
    for c in 0..ncolors {
        let (lo, hi) = (color_ptr[c], color_ptr[c + 1]);
        let rows = Pool::chunk(hi - lo, tid, nt);
        for i in lo + rows.start..lo + rows.end {
            let mut s = r[i];
            for k in row_ptr[i] as usize..row_ptr[i + 1] as usize {
                s -= vals[k] * unsafe { ys.get(cols[k] as usize) };
            }
            unsafe { ys.set(i, s * tri.diag_inv[i]) };
        }
        if c + 1 < ncolors {
            pool.color_barrier();
        }
    }
}

/// Backward substitution `Lᵀ z = y` under MC ordering (colors reversed).
pub fn backward(tri: &TriFactors, color_ptr: &[usize], y: &[f64], z: &mut [f64], pool: &Pool) {
    let n = tri.n();
    assert_eq!(y.len(), n);
    assert_eq!(z.len(), n);
    let zs = SyncSlice::new(z);
    pool.run(&|tid, nt| {
        backward_worker(tri, color_ptr, y, &zs, pool, tid, nt);
    });
}

/// Backward-sweep body for worker `tid` (see [`forward_worker`]).
pub fn backward_worker(
    tri: &TriFactors,
    color_ptr: &[usize],
    y: &[f64],
    zs: &SyncSlice<f64>,
    pool: &Pool,
    tid: usize,
    nt: usize,
) {
    let ncolors = color_ptr.len() - 1;
    let row_ptr = tri.upper.row_ptr();
    let cols = tri.upper.cols();
    let vals = tri.upper.vals();
    for c in (0..ncolors).rev() {
        let (lo, hi) = (color_ptr[c], color_ptr[c + 1]);
        let rows = Pool::chunk(hi - lo, tid, nt);
        for i in lo + rows.start..lo + rows.end {
            let mut s = y[i];
            for k in row_ptr[i] as usize..row_ptr[i + 1] as usize {
                s -= vals[k] * unsafe { zs.get(cols[k] as usize) };
            }
            unsafe { zs.set(i, s * tri.diag_inv[i]) };
        }
        if c > 0 {
            pool.color_barrier();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;
    use crate::ordering::mc::mc_order;
    use crate::solver::trisolve_serial;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn grid(nx: usize, ny: usize) -> crate::sparse::csr::Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    #[test]
    fn mc_substitutions_match_serial() {
        let a0 = grid(9, 7);
        let mc = mc_order(&a0);
        let a = a0.permute_sym(&mc.perm);
        let f = ic0(&a, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let n = a.n();
        let mut rng = Rng::new(4);
        let r: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();

        let mut y_ref = vec![0.0; n];
        trisolve_serial::forward(&tri, &r, &mut y_ref);
        let mut z_ref = vec![0.0; n];
        trisolve_serial::backward(&tri, &y_ref, &mut z_ref);

        for nt in [1usize, 2, 4] {
            let pool = Pool::new(nt);
            let mut y = vec![0.0; n];
            forward(&tri, &mc.color_ptr, &r, &mut y, &pool);
            assert!(crate::util::max_abs_diff(&y, &y_ref) < 1e-13, "fwd nt={nt}");
            let mut z = vec![0.0; n];
            backward(&tri, &mc.color_ptr, &y, &mut z, &pool);
            assert!(crate::util::max_abs_diff(&z, &z_ref) < 1e-13, "bwd nt={nt}");
        }
    }

    #[test]
    fn sync_count_is_colors_minus_one() {
        let a0 = grid(8, 8);
        let mc = mc_order(&a0);
        let a = a0.permute_sym(&mc.perm);
        let f = ic0(&a, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let n = a.n();
        let pool = Pool::new(2);
        pool.reset_sync_count();
        let r = vec![1.0; n];
        let mut y = vec![0.0; n];
        forward(&tri, &mc.color_ptr, &r, &mut y, &pool);
        assert_eq!(pool.sync_count() as usize, mc.num_colors - 1);
    }
}
