//! Preconditioner dispatch: one enum wrapping the four substitution
//! strategies so the CG loop is ordering-agnostic.

use crate::coordinator::pool::Pool;
use crate::factor::split::{SellTriFactors, TriFactors};
use crate::solver::trisolve_hbmc::{HbmcMeta, KernelPath};
use crate::solver::{trisolve_bmc, trisolve_hbmc, trisolve_mc, trisolve_serial};

/// IC(0) preconditioner `M⁻¹ = (L Lᵀ)⁻¹` with an ordering-specific
/// substitution strategy.
pub enum Preconditioner {
    /// Identity (plain CG) — diagnostic baseline.
    Identity,
    /// Serial substitutions (natural ordering).
    Serial(TriFactors),
    /// Nodal multi-color.
    Mc { tri: TriFactors, color_ptr: Vec<usize> },
    /// Block multi-color.
    Bmc { tri: TriFactors, color_ptr: Vec<usize>, bs: usize },
    /// Hierarchical block multi-color (vectorized).
    Hbmc { meta: HbmcMeta, sell: SellTriFactors, path: KernelPath },
}

impl Preconditioner {
    /// `z = M⁻¹ r`; `scratch` holds the forward-substitution result.
    pub fn apply(&self, r: &[f64], scratch: &mut [f64], z: &mut [f64], pool: &Pool) {
        match self {
            Preconditioner::Identity => z.copy_from_slice(r),
            Preconditioner::Serial(tri) => {
                trisolve_serial::forward(tri, r, scratch);
                trisolve_serial::backward(tri, scratch, z);
            }
            Preconditioner::Mc { tri, color_ptr } => {
                trisolve_mc::forward(tri, color_ptr, r, scratch, pool);
                trisolve_mc::backward(tri, color_ptr, scratch, z, pool);
            }
            Preconditioner::Bmc { tri, color_ptr, bs } => {
                trisolve_bmc::forward(tri, color_ptr, *bs, r, scratch, pool);
                trisolve_bmc::backward(tri, color_ptr, *bs, scratch, z, pool);
            }
            Preconditioner::Hbmc { meta, sell, path } => {
                trisolve_hbmc::forward(meta, sell, r, scratch, pool, *path);
                trisolve_hbmc::backward(meta, sell, scratch, z, pool, *path);
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Preconditioner::Identity => "identity",
            Preconditioner::Serial(_) => "ic0-serial",
            Preconditioner::Mc { .. } => "ic0-mc",
            Preconditioner::Bmc { .. } => "ic0-bmc",
            Preconditioner::Hbmc { .. } => "ic0-hbmc",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;
    use crate::ordering::bmc::bmc_order;
    use crate::ordering::hbmc::hbmc_order;
    use crate::ordering::mc::mc_order;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    #[test]
    fn all_variants_agree_on_their_own_orderings() {
        // Each variant must equal the serial oracle on its own reordered
        // system (they compute the same M⁻¹ r for that matrix).
        let n = 140;
        let mut rng = Rng::new(61);
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 8.0);
            for _ in 0..3 {
                let j = rng.below(n);
                if j != i {
                    c.push_sym(i, j, -0.4);
                }
            }
        }
        let a0 = c.to_csr();
        let pool = Pool::new(2);

        // MC
        let mc = mc_order(&a0);
        let amc = a0.permute_sym(&mc.perm);
        let tri = TriFactors::from_ic(&ic0(&amc, 0.0).unwrap());
        let r: Vec<f64> = (0..amc.n()).map(|i| (i as f64 * 0.1).sin()).collect();
        let mut s1 = vec![0.0; amc.n()];
        let mut z_ref = vec![0.0; amc.n()];
        trisolve_serial::apply(&tri, &r, &mut s1, &mut z_ref);
        let p = Preconditioner::Mc { tri, color_ptr: mc.color_ptr.clone() };
        let mut z = vec![0.0; amc.n()];
        p.apply(&r, &mut s1, &mut z, &pool);
        assert!(crate::util::max_abs_diff(&z, &z_ref) < 1e-12);

        // BMC
        let ord = bmc_order(&a0, 8);
        let ab = a0.permute_sym(&ord.perm);
        let tri = TriFactors::from_ic(&ic0(&ab, 0.0).unwrap());
        let r: Vec<f64> = (0..ab.n()).map(|i| (i as f64 * 0.1).cos()).collect();
        let mut s2 = vec![0.0; ab.n()];
        let mut z_ref = vec![0.0; ab.n()];
        trisolve_serial::apply(&tri, &r, &mut s2, &mut z_ref);
        let p = Preconditioner::Bmc { tri, color_ptr: ord.color_ptr.clone(), bs: 8 };
        let mut z = vec![0.0; ab.n()];
        p.apply(&r, &mut s2, &mut z, &pool);
        assert!(crate::util::max_abs_diff(&z, &z_ref) < 1e-12);

        // HBMC
        let ord = hbmc_order(&a0, 8, 4);
        let ah = a0.permute_sym(&ord.perm);
        let tri = TriFactors::from_ic(&ic0(&ah, 0.0).unwrap());
        let sell = SellTriFactors::from_tri(&tri, 4);
        let meta = HbmcMeta::from_ordering(&ord);
        let r: Vec<f64> = (0..ah.n()).map(|i| (i as f64 * 0.07).sin()).collect();
        let mut s3 = vec![0.0; ah.n()];
        let mut z_ref = vec![0.0; ah.n()];
        trisolve_serial::apply(&tri, &r, &mut s3, &mut z_ref);
        let p = Preconditioner::Hbmc { meta, sell, path: KernelPath::Scalar };
        let mut z = vec![0.0; ah.n()];
        p.apply(&r, &mut s3, &mut z, &pool);
        assert!(crate::util::max_abs_diff(&z, &z_ref) < 1e-12);
    }

    #[test]
    fn identity_copies() {
        let p = Preconditioner::Identity;
        let pool = Pool::new(1);
        let r = vec![1.0, -2.0, 3.0];
        let mut s = vec![0.0; 3];
        let mut z = vec![0.0; 3];
        p.apply(&r, &mut s, &mut z, &pool);
        assert_eq!(z, r);
        assert_eq!(p.name(), "identity");
    }
}
