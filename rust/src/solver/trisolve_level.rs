//! Multithreaded substitutions under level scheduling (the fifth path):
//! natural ordering, parallelism from the factor's dependency DAG.
//!
//! The [`CoarsenedSchedule`] drives both sweeps: `Barrier` segments run
//! level-by-level with nnz-balanced row grains split by
//! [`split_point`](crate::schedule::levels::split_point) over the
//! schedule's weight prefixes; `Serial` segments run on thread 0 in index
//! order (ascending forward, descending backward — always topologically
//! valid because every dependency points past the sweep direction).
//! Exactly `stages() − 1` barriers per sweep, mirroring the MC solver's
//! `n_c − 1` discipline so the fused loop's sync accounting carries over
//! unchanged.
//!
//! Bitwise determinism across runs *and* thread counts is structural:
//! substitution has no reductions — each `y[i]` is produced by exactly one
//! row, whose inner loop walks the factor row in CSR order regardless of
//! which thread owns it. With the identity permutation the arithmetic is
//! therefore identical to the serial natural-ordering solve, nonzero by
//! nonzero, which is what pins the ICCG iteration count to the serial
//! baseline.

use crate::coordinator::pool::{Pool, SyncSlice};
use crate::factor::split::TriFactors;
use crate::schedule::coarsen::{CoarsenedSchedule, SegmentMode};
use crate::schedule::levels::split_point;
use crate::solver::trisolve::TriSolver;

/// Forward substitution `L y = r` under the level schedule.
pub fn forward(
    tri: &TriFactors,
    sched: &CoarsenedSchedule,
    r: &[f64],
    y: &mut [f64],
    pool: &Pool,
) {
    let n = tri.n();
    assert_eq!(r.len(), n);
    assert_eq!(y.len(), n);
    let ys = SyncSlice::new(y);
    pool.run(&|tid, nt| {
        forward_worker(tri, sched, r, &ys, pool, tid, nt);
    });
}

/// Forward-sweep body for worker `tid`, callable from inside an already
/// open pool region (the single-dispatch CG loop). Performs exactly
/// `sched.stages() − 1` barriers; the caller supplies any trailing
/// barrier before `y` is read across threads.
pub fn forward_worker(
    tri: &TriFactors,
    sched: &CoarsenedSchedule,
    r: &[f64],
    ys: &SyncSlice<f64>,
    pool: &Pool,
    tid: usize,
    nt: usize,
) {
    let row_ptr = tri.lower.row_ptr();
    let cols = tri.lower.cols();
    let vals = tri.lower.vals();
    let solve_row = |i: usize| {
        let mut s = r[i];
        for k in row_ptr[i] as usize..row_ptr[i + 1] as usize {
            s -= vals[k] * unsafe { ys.get(cols[k] as usize) };
        }
        unsafe { ys.set(i, s * tri.diag_inv[i]) };
    };
    let nseg = sched.segments.len();
    for (s, seg) in sched.segments.iter().enumerate() {
        match seg.mode {
            SegmentMode::Barrier => {
                for l in seg.level_lo..seg.level_hi {
                    let (lo, hi) = (sched.level_ptr[l], sched.level_ptr[l + 1]);
                    let a = split_point(&sched.fwd_prefix, lo, hi, tid, nt);
                    let b = split_point(&sched.fwd_prefix, lo, hi, tid + 1, nt);
                    for p in a..b {
                        solve_row(sched.rows[p] as usize);
                    }
                    if l + 1 < seg.level_hi {
                        pool.color_barrier();
                    }
                }
            }
            SegmentMode::Serial => {
                if tid == 0 {
                    let (lo, hi) =
                        (sched.level_ptr[seg.level_lo], sched.level_ptr[seg.level_hi]);
                    for p in lo..hi {
                        solve_row(sched.rows[p] as usize);
                    }
                }
            }
        }
        if s + 1 < nseg {
            pool.color_barrier();
        }
    }
}

/// Backward substitution `Lᵀ z = y` (same levels, walked descending).
pub fn backward(
    tri: &TriFactors,
    sched: &CoarsenedSchedule,
    y: &[f64],
    z: &mut [f64],
    pool: &Pool,
) {
    let n = tri.n();
    assert_eq!(y.len(), n);
    assert_eq!(z.len(), n);
    let zs = SyncSlice::new(z);
    pool.run(&|tid, nt| {
        backward_worker(tri, sched, y, &zs, pool, tid, nt);
    });
}

/// Backward-sweep body for worker `tid` (see [`forward_worker`]).
pub fn backward_worker(
    tri: &TriFactors,
    sched: &CoarsenedSchedule,
    y: &[f64],
    zs: &SyncSlice<f64>,
    pool: &Pool,
    tid: usize,
    nt: usize,
) {
    let row_ptr = tri.upper.row_ptr();
    let cols = tri.upper.cols();
    let vals = tri.upper.vals();
    let solve_row = |i: usize| {
        let mut s = y[i];
        for k in row_ptr[i] as usize..row_ptr[i + 1] as usize {
            s -= vals[k] * unsafe { zs.get(cols[k] as usize) };
        }
        unsafe { zs.set(i, s * tri.diag_inv[i]) };
    };
    for (s, seg) in sched.segments.iter().enumerate().rev() {
        match seg.mode {
            SegmentMode::Barrier => {
                for l in (seg.level_lo..seg.level_hi).rev() {
                    let (lo, hi) = (sched.level_ptr[l], sched.level_ptr[l + 1]);
                    let a = split_point(&sched.bwd_prefix, lo, hi, tid, nt);
                    let b = split_point(&sched.bwd_prefix, lo, hi, tid + 1, nt);
                    for p in a..b {
                        solve_row(sched.rows[p] as usize);
                    }
                    if l > seg.level_lo {
                        pool.color_barrier();
                    }
                }
            }
            SegmentMode::Serial => {
                if tid == 0 {
                    let (lo, hi) =
                        (sched.level_ptr[seg.level_lo], sched.level_ptr[seg.level_hi]);
                    for p in (lo..hi).rev() {
                        solve_row(sched.rows[p] as usize);
                    }
                }
            }
        }
        if s > 0 {
            pool.color_barrier();
        }
    }
}

/// Level-scheduled substitutions over the natural ordering.
pub struct LevelTriSolver {
    pub tri: TriFactors,
    pub sched: CoarsenedSchedule,
}

impl LevelTriSolver {
    pub fn new(tri: TriFactors, sched: CoarsenedSchedule) -> LevelTriSolver {
        LevelTriSolver { tri, sched }
    }
}

impl TriSolver for LevelTriSolver {
    fn forward(&self, r: &[f64], y: &mut [f64], pool: &Pool) {
        forward(&self.tri, &self.sched, r, y, pool);
    }

    fn backward(&self, y: &[f64], z: &mut [f64], pool: &Pool) {
        backward(&self.tri, &self.sched, y, z, pool);
    }

    fn forward_worker(&self, r: &[f64], ys: &SyncSlice<f64>, pool: &Pool, tid: usize, nt: usize) {
        forward_worker(&self.tri, &self.sched, r, ys, pool, tid, nt);
    }

    fn backward_worker(&self, y: &[f64], zs: &SyncSlice<f64>, pool: &Pool, tid: usize, nt: usize) {
        backward_worker(&self.tri, &self.sched, y, zs, pool, tid, nt);
    }

    /// Barrier-separated stages play the role colors play elsewhere, so
    /// the default `syncs_per_sweep` and the fused-loop sync formulas
    /// apply unchanged.
    fn num_colors(&self) -> usize {
        self.sched.stages()
    }

    fn tri_elements(&self) -> usize {
        self.tri.lower.nnz() + self.tri.upper.nnz()
    }

    fn name(&self) -> &'static str {
        "ic0-level"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;
    use crate::schedule::coarsen::{coarsen, CoarsenParams};
    use crate::schedule::levels::LevelSchedule;
    use crate::solver::trisolve_serial;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn grid(nx: usize, ny: usize) -> crate::sparse::csr::Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    fn build(a: &crate::sparse::csr::Csr, params: CoarsenParams) -> LevelTriSolver {
        let tri = TriFactors::from_ic(&ic0(a, 0.0).unwrap());
        let lv = LevelSchedule::build(&tri);
        let sched = coarsen(&lv, &tri, &params);
        LevelTriSolver::new(tri, sched)
    }

    /// No reductions ⇒ not just close, *bitwise* equal to the serial
    /// sweeps, at every thread count and coarsening setting.
    #[test]
    fn level_substitutions_bitwise_match_serial() {
        let a = grid(13, 11);
        let n = a.n();
        let tri = TriFactors::from_ic(&ic0(&a, 0.0).unwrap());
        let mut rng = Rng::new(4);
        let r: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut y_ref = vec![0.0; n];
        trisolve_serial::forward(&tri, &r, &mut y_ref);
        let mut z_ref = vec![0.0; n];
        trisolve_serial::backward(&tri, &y_ref, &mut z_ref);

        for params in [
            CoarsenParams::default(),                  // fully serial here
            CoarsenParams { min_rows: 0, min_nnz: 0 }, // barrier-per-level
            CoarsenParams { min_rows: 6, min_nnz: 0 }, // mixed segments
        ] {
            let solver = build(&a, params);
            for nt in [1usize, 2, 4] {
                let pool = Pool::new(nt);
                let mut y = vec![0.0; n];
                solver.forward(&r, &mut y, &pool);
                assert_eq!(y, y_ref, "fwd nt={nt} params={params:?}");
                let mut z = vec![0.0; n];
                solver.backward(&y, &mut z, &pool);
                assert_eq!(z, z_ref, "bwd nt={nt} params={params:?}");
            }
        }
    }

    #[test]
    fn sync_count_is_stages_minus_one() {
        let a = grid(24, 24);
        for params in [
            CoarsenParams::default(),
            CoarsenParams { min_rows: 0, min_nnz: 0 },
            CoarsenParams { min_rows: 10, min_nnz: 0 },
        ] {
            let solver = build(&a, params);
            let pool = Pool::new(2);
            let n = a.n();
            let r = vec![1.0; n];
            let mut y = vec![0.0; n];
            pool.reset_sync_count();
            solver.forward(&r, &mut y, &pool);
            assert_eq!(
                pool.sync_count() as usize,
                solver.sched.stages() - 1,
                "fwd params={params:?}"
            );
            let mut z = vec![0.0; n];
            pool.reset_sync_count();
            solver.backward(&y, &mut z, &pool);
            assert_eq!(
                pool.sync_count() as usize,
                solver.sched.stages() - 1,
                "bwd params={params:?}"
            );
            assert_eq!(solver.syncs_per_sweep(), solver.sched.stages() - 1);
        }
    }

    #[test]
    fn solver_reports_level_identity() {
        let solver = build(&grid(9, 7), CoarsenParams::default());
        assert_eq!(solver.name(), "ic0-level");
        assert_eq!(solver.kernel_path(), "n/a");
        assert_eq!(solver.num_colors(), solver.sched.stages());
        assert_eq!(
            solver.tri_elements(),
            solver.tri.lower.nnz() + solver.tri.upper.nnz()
        );
    }
}
