//! Gauss–Seidel / SOR sweeps — the other consumers of parallel orderings
//! named by the paper (§1, §2: "the main component of the GS smoother,
//! SOR method and IC/ILU preconditioning"). The ER-condition theorem of
//! §3.1 covers GS/SOR as well: sweeps under two equivalent orderings
//! produce identical iterates, which the tests verify for BMC vs HBMC.
//!
//! A forward SOR sweep is the same color-parallel recurrence as the
//! forward substitution: within a color, rows (MC) / blocks (BMC) /
//! level-1 blocks (HBMC) are independent, so the identical scheduling
//! machinery applies; here rows read both already-updated (lower) and
//! stale (upper) neighbors, which is race-free for the same reason.

use crate::coordinator::pool::{Pool, SyncSlice};
use crate::sparse::csr::Csr;

/// One serial forward SOR sweep: `x_i += ω (b_i − Σ_j a_ij x_j) / a_ii`
/// in natural row order (`ω = 1` → Gauss–Seidel).
pub fn sor_sweep_serial(a: &Csr, b: &[f64], x: &mut [f64], omega: f64) {
    let n = a.n();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);
    for i in 0..n {
        let (cols, vals) = a.row(i);
        let mut s = b[i];
        let mut aii = 0.0;
        for (c, v) in cols.iter().zip(vals) {
            if *c as usize == i {
                aii = *v;
            } else {
                s -= v * x[*c as usize];
            }
        }
        debug_assert!(aii != 0.0, "zero diagonal at row {i}");
        x[i] = (1.0 - omega) * x[i] + omega * s / aii;
    }
}

/// One serial *backward* sweep (for symmetric GS/SSOR smoothing).
pub fn sor_sweep_serial_rev(a: &Csr, b: &[f64], x: &mut [f64], omega: f64) {
    let n = a.n();
    for i in (0..n).rev() {
        let (cols, vals) = a.row(i);
        let mut s = b[i];
        let mut aii = 0.0;
        for (c, v) in cols.iter().zip(vals) {
            if *c as usize == i {
                aii = *v;
            } else {
                s -= v * x[*c as usize];
            }
        }
        x[i] = (1.0 - omega) * x[i] + omega * s / aii;
    }
}

/// One multithreaded forward SOR sweep under a color-block layout
/// (`color_ptr` row ranges; `bs = 1` gives nodal MC, `bs = bs·w` spans an
/// HBMC level-1 block). Blocks within a color run in parallel; rows inside
/// a block run sequentially — exactly the substitution schedule.
pub fn sor_sweep_colored(
    a: &Csr,
    color_ptr: &[usize],
    block: usize,
    b: &[f64],
    x: &mut [f64],
    omega: f64,
    pool: &Pool,
) {
    let n = a.n();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);
    let ncolors = color_ptr.len() - 1;
    let xs = SyncSlice::new(x);
    pool.run(&|tid, nt| {
        let row_ptr = a.row_ptr();
        let cols = a.cols();
        let vals = a.vals();
        for c in 0..ncolors {
            let (lo, hi) = (color_ptr[c], color_ptr[c + 1]);
            let nblocks = (hi - lo).div_ceil(block);
            let blocks = Pool::chunk(nblocks, tid, nt);
            for blk in blocks {
                let start = lo + blk * block;
                let end = (start + block).min(hi);
                for i in start..end {
                    let mut s = b[i];
                    let mut aii = 0.0;
                    for k in row_ptr[i] as usize..row_ptr[i + 1] as usize {
                        let j = cols[k] as usize;
                        if j == i {
                            aii = vals[k];
                        } else {
                            s -= vals[k] * unsafe { xs.get(j) };
                        }
                    }
                    let xi = unsafe { xs.get(i) };
                    unsafe { xs.set(i, (1.0 - omega) * xi + omega * s / aii) };
                }
            }
            if c + 1 < ncolors {
                pool.color_barrier();
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ordering::bmc::bmc_order;
    use crate::ordering::hbmc::hbmc_from_bmc;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn grid(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    #[test]
    fn serial_gs_converges_on_laplace() {
        let a = grid(10, 10);
        let n = a.n();
        let mut b = vec![0.0; n];
        a.mul_vec(&vec![1.0; n], &mut b);
        let mut x = vec![0.0; n];
        for _ in 0..400 {
            sor_sweep_serial(&a, &b, &mut x, 1.0);
        }
        let err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn sor_overrelaxation_beats_gs() {
        let a = grid(12, 12);
        let n = a.n();
        let mut b = vec![0.0; n];
        a.mul_vec(&vec![1.0; n], &mut b);
        let err_after = |omega: f64| {
            let mut x = vec![0.0; n];
            for _ in 0..80 {
                sor_sweep_serial(&a, &b, &mut x, omega);
            }
            x.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max)
        };
        assert!(err_after(1.5) < err_after(1.0));
    }

    #[test]
    fn colored_sweep_matches_serial_on_reordered_system() {
        // On the BMC-reordered matrix, the color-parallel sweep computes
        // exactly the serial sweep (same update order within blocks; all
        // cross-color reads separated by barriers).
        let a0 = grid(9, 7);
        let ord = bmc_order(&a0, 4);
        let a = a0.permute_sym(&ord.perm);
        let n = a.n();
        let mut rng = Rng::new(5);
        let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut x1 = vec![0.0; n];
        let mut x2 = vec![0.0; n];
        let pool = Pool::new(3);
        for _ in 0..5 {
            sor_sweep_serial(&a, &b, &mut x1, 1.0);
            sor_sweep_colored(&a, &ord.color_ptr, 4, &b, &mut x2, 1.0, &pool);
        }
        assert!(crate::util::max_abs_diff(&x1, &x2) < 1e-12);
    }

    #[test]
    fn gs_iterates_identical_under_bmc_and_hbmc() {
        // The ER theorem for GS (§3.1 + appendix): equivalent orderings
        // give the same iterates. Run k sweeps under BMC and under HBMC,
        // map both back to original indices, compare.
        let a0 = grid(12, 10);
        let n0 = a0.n();
        let bmc = bmc_order(&a0, 4);
        let hbmc = hbmc_from_bmc(bmc.clone(), 4);

        let mut rng = Rng::new(9);
        let b0: Vec<f64> = (0..n0).map(|_| rng.range_f64(-1.0, 1.0)).collect();

        let ab = a0.permute_sym(&bmc.perm);
        let ah = a0.permute_sym(&hbmc.perm);
        let bb = bmc.perm.apply_vec(&b0, 0.0);
        let bh = hbmc.perm.apply_vec(&b0, 0.0);
        let mut xb = vec![0.0; ab.n()];
        let mut xh = vec![0.0; ah.n()];
        let pool = Pool::new(2);
        for _ in 0..6 {
            sor_sweep_colored(&ab, &bmc.color_ptr, bmc.bs, &bb, &mut xb, 1.0, &pool);
            sor_sweep_colored(
                &ah,
                &hbmc.color_ptr,
                hbmc.bs * hbmc.w,
                &bh,
                &mut xh,
                1.0,
                &pool,
            );
        }
        let back_b = bmc.perm.unapply_vec(&xb);
        let back_h = hbmc.perm.unapply_vec(&xh);
        assert!(
            crate::util::max_abs_diff(&back_b, &back_h) < 1e-11,
            "GS iterates differ between equivalent orderings"
        );
    }

    #[test]
    fn symmetric_sweep_pair_runs() {
        let a = grid(8, 8);
        let n = a.n();
        let mut b = vec![0.0; n];
        a.mul_vec(&vec![1.0; n], &mut b);
        let mut x = vec![0.0; n];
        for _ in 0..200 {
            sor_sweep_serial(&a, &b, &mut x, 1.0);
            sor_sweep_serial_rev(&a, &b, &mut x, 1.0);
        }
        let err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-8);
    }
}
