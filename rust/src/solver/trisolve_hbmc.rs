//! Vectorized + multithreaded substitutions under HBMC ordering — the
//! paper's core kernel (§4.3, Fig. 4.6).
//!
//! Structure: outer loop over colors (barrier between colors, `n_c − 1`
//! syncs); middle loop over level-1 blocks, partitioned across threads;
//! inner loop over the `bs` sequential steps of a level-1 block, each step
//! being a `w`-wide packed operation over one SELL slice:
//!
//! ```text
//! t[0..w]  = r[row .. row+w]                       (packed load)
//! for k in 0..slice_len:                            (SELL gather loop)
//!     t[j] -= val[k][j] * y[col[k][j]]              (gather + packed FNMA)
//! y[row .. row+w] = t * diag_inv[row .. row+w]      (packed mul + store)
//! ```
//!
//! This is exactly the AVX-512 kernel of Fig. 4.6 (`_mm512_load_pd`,
//! `_mm512_i32logather_pd`, `_mm512_sub_pd(mul)`, `_mm512_mul_pd`,
//! `_mm512_store_pd`). Three implementations are provided:
//!
//! * a const-generic scalar path (`W` ∈ {2,4,8,16}) written so LLVM can
//!   auto-vectorize the multiply/subtract lanes,
//! * an AVX-512F intrinsic path for `w = 8` (the paper's KNL/Skylake code),
//! * an AVX2 intrinsic path for `w = 4` (the paper's Broadwell code),
//!
//! selected at runtime via `is_x86_feature_detected!`. All three are
//! bit-compatible (same operation order per lane) and tested against the
//! serial CSR oracle.
//!
//! Gather safety: within a color, a slice's columns reference either
//! earlier colors (finished before the barrier) or earlier steps of the
//! *same lane* of the same level-1 block (written by this same thread) —
//! that is the level-2 diagonality invariant checked at ordering time — so
//! unsynchronized reads through [`SyncSlice`] are race-free.

use crate::coordinator::pool::{Pool, SyncSlice};
use crate::factor::split::SellTriFactors;
use crate::ordering::hbmc::HbmcOrdering;
use crate::sparse::sell::Sell;

/// Solve-time metadata extracted from an [`HbmcOrdering`] (kept small and
/// POD so benches can build variants cheaply).
#[derive(Debug, Clone)]
pub struct HbmcMeta {
    pub bs: usize,
    pub w: usize,
    pub num_colors: usize,
    /// Row range of color `c`: `color_ptr[c]..color_ptr[c+1]`.
    pub color_ptr: Vec<usize>,
}

impl HbmcMeta {
    pub fn from_ordering(ord: &HbmcOrdering) -> HbmcMeta {
        HbmcMeta {
            bs: ord.bs,
            w: ord.w,
            num_colors: ord.num_colors,
            color_ptr: ord.color_ptr.clone(),
        }
    }

    pub fn n(&self) -> usize {
        *self.color_ptr.last().unwrap()
    }
}

/// Which inner kernel ran (reported by the driver; feeds EXPERIMENTS.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    Scalar,
    Avx2W4,
    Avx512W8,
}

impl KernelPath {
    pub fn name(&self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Avx2W4 => "avx2-w4",
            KernelPath::Avx512W8 => "avx512-w8",
        }
    }
}

/// Select the best available kernel path for width `w`.
pub fn select_path(w: usize, use_intrinsics: bool) -> KernelPath {
    #[cfg(target_arch = "x86_64")]
    {
        if use_intrinsics {
            if w == 8 && std::arch::is_x86_feature_detected!("avx512f") {
                return KernelPath::Avx512W8;
            }
            if w == 4 && std::arch::is_x86_feature_detected!("avx2") {
                return KernelPath::Avx2W4;
            }
        }
    }
    let _ = use_intrinsics;
    KernelPath::Scalar
}

/// Forward substitution `L y = r` under HBMC.
pub fn forward(
    meta: &HbmcMeta,
    factors: &SellTriFactors,
    r: &[f64],
    y: &mut [f64],
    pool: &Pool,
    path: KernelPath,
) {
    let n = meta.n();
    assert_eq!(factors.n(), n);
    assert_eq!(r.len(), n);
    assert_eq!(y.len(), n);
    let ys = SyncSlice::new(y);
    pool.run(&|tid, nt| {
        forward_worker(meta, factors, r, &ys, pool, tid, nt, path);
    });
}

/// Forward-sweep body for worker `tid`, callable from inside an already
/// open pool region (the single-dispatch CG loop). Performs exactly
/// `n_c − 1` color barriers; the caller supplies any trailing barrier
/// before `y` is read across threads.
#[allow(clippy::too_many_arguments)]
pub fn forward_worker(
    meta: &HbmcMeta,
    factors: &SellTriFactors,
    r: &[f64],
    ys: &SyncSlice<f64>,
    pool: &Pool,
    tid: usize,
    nt: usize,
    path: KernelPath,
) {
    sweep(meta, &factors.fwd, &factors.diag_inv, r, ys, pool, tid, nt, path, false);
}

/// Backward substitution `Lᵀ z = y` under HBMC (colors and steps reversed).
pub fn backward(
    meta: &HbmcMeta,
    factors: &SellTriFactors,
    y: &[f64],
    z: &mut [f64],
    pool: &Pool,
    path: KernelPath,
) {
    let n = meta.n();
    assert_eq!(factors.n(), n);
    assert_eq!(y.len(), n);
    assert_eq!(z.len(), n);
    let zs = SyncSlice::new(z);
    pool.run(&|tid, nt| {
        backward_worker(meta, factors, y, &zs, pool, tid, nt, path);
    });
}

/// Backward-sweep body for worker `tid` (see [`forward_worker`]).
#[allow(clippy::too_many_arguments)]
pub fn backward_worker(
    meta: &HbmcMeta,
    factors: &SellTriFactors,
    y: &[f64],
    zs: &SyncSlice<f64>,
    pool: &Pool,
    tid: usize,
    nt: usize,
    path: KernelPath,
) {
    sweep(meta, &factors.bwd, &factors.diag_inv, y, zs, pool, tid, nt, path, true);
}

/// One full color sweep executed by worker `tid` (shared by fwd/bwd; for
/// the backward sweep colors and in-block steps run in reverse). The color
/// index is computed arithmetically — no boxed iterator on this hot path —
/// and the dynamic-width kernel's scratch is allocated once per sweep, not
/// per block.
#[allow(clippy::too_many_arguments)]
fn sweep(
    meta: &HbmcMeta,
    sell: &Sell,
    dinv: &[f64],
    rhs: &[f64],
    out: &SyncSlice<f64>,
    pool: &Pool,
    tid: usize,
    nt: usize,
    path: KernelPath,
    reverse: bool,
) {
    let (bs, w) = (meta.bs, meta.w);
    let bw = bs * w;
    let ncolors = meta.num_colors;
    // Scratch for `block_solve_dyn` only (widths without a const-generic or
    // intrinsic kernel); hoisted out of the per-block loop.
    let mut dyn_scratch = if matches!(w, 2 | 4 | 8 | 16) { Vec::new() } else { vec![0.0f64; w] };
    for ci in 0..ncolors {
        let c = if reverse { ncolors - 1 - ci } else { ci };
        let (lo, hi) = (meta.color_ptr[c], meta.color_ptr[c + 1]);
        let nl1 = (hi - lo) / bw;
        let blocks = Pool::chunk(nl1, tid, nt);
        for b in blocks {
            let row0 = lo + b * bw;
            block_solve(sell, dinv, rhs, out, row0, bs, w, path, reverse, &mut dyn_scratch);
        }
        if ci + 1 < ncolors {
            pool.color_barrier();
        }
    }
}

/// Solve one level-1 block: `bs` sequential `w`-wide steps. `dyn_scratch`
/// is the sweep-lifetime buffer for the dynamic-width fallback (empty for
/// const-generic/intrinsic widths).
#[allow(clippy::too_many_arguments)]
#[inline]
fn block_solve(
    sell: &Sell,
    dinv: &[f64],
    rhs: &[f64],
    out: &SyncSlice<f64>,
    row0: usize,
    bs: usize,
    w: usize,
    path: KernelPath,
    reverse: bool,
    dyn_scratch: &mut [f64],
) {
    match path {
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx512W8 => unsafe {
            block_solve_avx512(sell, dinv, rhs, out, row0, bs, reverse)
        },
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx2W4 => unsafe {
            block_solve_avx2(sell, dinv, rhs, out, row0, bs, reverse)
        },
        #[allow(unreachable_patterns)]
        _ => match w {
            2 => block_solve_scalar::<2>(sell, dinv, rhs, out, row0, bs, reverse),
            4 => block_solve_scalar::<4>(sell, dinv, rhs, out, row0, bs, reverse),
            8 => block_solve_scalar::<8>(sell, dinv, rhs, out, row0, bs, reverse),
            16 => block_solve_scalar::<16>(sell, dinv, rhs, out, row0, bs, reverse),
            _ => block_solve_dyn(sell, dinv, rhs, out, row0, bs, w, reverse, dyn_scratch),
        },
    }
}

/// Const-generic scalar kernel (auto-vectorizable lanes).
fn block_solve_scalar<const W: usize>(
    sell: &Sell,
    dinv: &[f64],
    rhs: &[f64],
    out: &SyncSlice<f64>,
    row0: usize,
    bs: usize,
    reverse: bool,
) {
    let slice_ptr = sell.slice_ptr();
    let slice_len = sell.slice_len();
    let cols = sell.cols();
    let vals = sell.vals();
    for step in 0..bs {
        let l = if reverse { bs - 1 - step } else { step };
        let rowbase = row0 + l * W;
        let slice = rowbase / W;
        let off = slice_ptr[slice] as usize;
        let len = slice_len[slice] as usize;
        let mut t = [0.0f64; W];
        t.copy_from_slice(&rhs[rowbase..rowbase + W]);
        for k in 0..len {
            let base = off + k * W;
            for j in 0..W {
                t[j] -= vals[base + j] * unsafe { out.get(cols[base + j] as usize) };
            }
        }
        for j in 0..W {
            unsafe { out.set(rowbase + j, t[j] * dinv[rowbase + j]) };
        }
    }
}

/// Fallback for arbitrary `w` (not a compile-time width). `t` is the
/// caller's sweep-lifetime scratch (`len == w`) — no per-block allocation.
#[allow(clippy::too_many_arguments)]
fn block_solve_dyn(
    sell: &Sell,
    dinv: &[f64],
    rhs: &[f64],
    out: &SyncSlice<f64>,
    row0: usize,
    bs: usize,
    w: usize,
    reverse: bool,
    t: &mut [f64],
) {
    debug_assert_eq!(t.len(), w);
    let slice_ptr = sell.slice_ptr();
    let slice_len = sell.slice_len();
    let cols = sell.cols();
    let vals = sell.vals();
    for step in 0..bs {
        let l = if reverse { bs - 1 - step } else { step };
        let rowbase = row0 + l * w;
        let slice = rowbase / w;
        let off = slice_ptr[slice] as usize;
        let len = slice_len[slice] as usize;
        t.copy_from_slice(&rhs[rowbase..rowbase + w]);
        for k in 0..len {
            let base = off + k * w;
            for j in 0..w {
                t[j] -= vals[base + j] * unsafe { out.get(cols[base + j] as usize) };
            }
        }
        for j in 0..w {
            unsafe { out.set(rowbase + j, t[j] * dinv[rowbase + j]) };
        }
    }
}

/// AVX-512 kernel for `w = 8` — the paper's Fig. 4.6 inner loop.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn block_solve_avx512(
    sell: &Sell,
    dinv: &[f64],
    rhs: &[f64],
    out: &SyncSlice<f64>,
    row0: usize,
    bs: usize,
    reverse: bool,
) {
    use std::arch::x86_64::*;
    const W: usize = 8;
    let slice_ptr = sell.slice_ptr();
    let slice_len = sell.slice_len();
    let cols = sell.cols();
    let vals = sell.vals();
    let base_ptr = out.as_ptr();
    for step in 0..bs {
        let l = if reverse { bs - 1 - step } else { step };
        let rowbase = row0 + l * W;
        let slice = rowbase / W;
        let off = slice_ptr[slice] as usize;
        let len = slice_len[slice] as usize;
        // (Perf note: software-prefetching the next step's gather targets
        // was tried and measured 3–6% *slower* — the slices are short and
        // the hardware prefetcher already covers the streaming arrays; see
        // EXPERIMENTS.md §Perf.)
        // mtmp = load(r)
        let mut t = _mm512_loadu_pd(rhs.as_ptr().add(rowbase));
        for k in 0..len {
            let b = off + k * W;
            // pos = load_epi32(col); mb = gather(pos, y, 8)
            let vidx = _mm256_loadu_si256(cols.as_ptr().add(b) as *const __m256i);
            let g = _mm512_i32gather_pd::<8>(vidx, base_ptr);
            // mtmp -= mval * mb   (fused)
            let v = _mm512_loadu_pd(vals.as_ptr().add(b));
            t = _mm512_fnmadd_pd(v, g, t);
        }
        // mtmp *= diaginv; store(z)
        let d = _mm512_loadu_pd(dinv.as_ptr().add(rowbase));
        let res = _mm512_mul_pd(t, d);
        _mm512_storeu_pd(out.as_mut_ptr().add(rowbase), res);
    }
}

/// AVX2 kernel for `w = 4` — the paper's Broadwell (AVX2) variant.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn block_solve_avx2(
    sell: &Sell,
    dinv: &[f64],
    rhs: &[f64],
    out: &SyncSlice<f64>,
    row0: usize,
    bs: usize,
    reverse: bool,
) {
    use std::arch::x86_64::*;
    const W: usize = 4;
    let slice_ptr = sell.slice_ptr();
    let slice_len = sell.slice_len();
    let cols = sell.cols();
    let vals = sell.vals();
    let base_ptr = out.as_ptr();
    for step in 0..bs {
        let l = if reverse { bs - 1 - step } else { step };
        let rowbase = row0 + l * W;
        let slice = rowbase / W;
        let off = slice_ptr[slice] as usize;
        let len = slice_len[slice] as usize;
        let mut t = _mm256_loadu_pd(rhs.as_ptr().add(rowbase));
        for k in 0..len {
            let b = off + k * W;
            let vidx = _mm_loadu_si128(cols.as_ptr().add(b) as *const __m128i);
            let g = _mm256_i32gather_pd::<8>(base_ptr, vidx);
            let v = _mm256_loadu_pd(vals.as_ptr().add(b));
            t = _mm256_fnmadd_pd(v, g, t);
        }
        let d = _mm256_loadu_pd(dinv.as_ptr().add(rowbase));
        let res = _mm256_mul_pd(t, d);
        _mm256_storeu_pd(out.as_mut_ptr().add(rowbase), res);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;
    use crate::factor::split::{SellTriFactors, TriFactors};
    use crate::ordering::hbmc::hbmc_order;
    use crate::solver::trisolve_serial;
    use crate::sparse::coo::Coo;
    use crate::sparse::csr::Csr;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 8.0);
            for _ in 0..3 {
                let j = rng.below(n);
                if j != i {
                    c.push_sym(i, j, -0.4);
                }
            }
        }
        c.to_csr()
    }

    fn check_case(n: usize, seed: u64, bs: usize, w: usize, path: KernelPath, nt: usize) {
        let a0 = random_spd(n, seed);
        let ord = hbmc_order(&a0, bs, w);
        let a = a0.permute_sym(&ord.perm);
        let f = ic0(&a, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let sell_tri = SellTriFactors::from_tri(&tri, w);
        let meta = HbmcMeta::from_ordering(&ord);
        let na = a.n();
        let mut rng = Rng::new(seed + 1);
        let r: Vec<f64> = (0..na).map(|_| rng.range_f64(-1.0, 1.0)).collect();

        let mut y_ref = vec![0.0; na];
        trisolve_serial::forward(&tri, &r, &mut y_ref);
        let mut z_ref = vec![0.0; na];
        trisolve_serial::backward(&tri, &y_ref, &mut z_ref);

        let pool = Pool::new(nt);
        let mut y = vec![0.0; na];
        forward(&meta, &sell_tri, &r, &mut y, &pool, path);
        assert!(
            crate::util::max_abs_diff(&y, &y_ref) < 1e-12,
            "fwd n={n} bs={bs} w={w} path={} nt={nt}",
            path.name()
        );
        let mut z = vec![0.0; na];
        backward(&meta, &sell_tri, &y, &mut z, &pool, path);
        assert!(
            crate::util::max_abs_diff(&z, &z_ref) < 1e-12,
            "bwd n={n} bs={bs} w={w} path={} nt={nt}",
            path.name()
        );
    }

    #[test]
    fn scalar_matches_serial_all_widths() {
        for &(bs, w) in &[(2usize, 2usize), (4, 4), (8, 8), (4, 8), (8, 4), (16, 2)] {
            check_case(150, 41, bs, w, KernelPath::Scalar, 1);
        }
    }

    #[test]
    fn scalar_matches_serial_multithreaded() {
        check_case(220, 43, 8, 4, KernelPath::Scalar, 3);
        check_case(220, 44, 4, 8, KernelPath::Scalar, 4);
    }

    #[test]
    fn avx512_matches_serial_if_available() {
        if select_path(8, true) == KernelPath::Avx512W8 {
            check_case(200, 45, 8, 8, KernelPath::Avx512W8, 1);
            check_case(200, 46, 16, 8, KernelPath::Avx512W8, 2);
        } else {
            eprintln!("avx512f unavailable: skipping");
        }
    }

    #[test]
    fn avx2_matches_serial_if_available() {
        if select_path(4, true) == KernelPath::Avx2W4 {
            check_case(200, 47, 8, 4, KernelPath::Avx2W4, 1);
            check_case(200, 48, 32, 4, KernelPath::Avx2W4, 2);
        } else {
            eprintln!("avx2 unavailable: skipping");
        }
    }

    #[test]
    fn sync_count_is_colors_minus_one_per_sweep() {
        let a0 = random_spd(120, 51);
        let ord = hbmc_order(&a0, 4, 4);
        let a = a0.permute_sym(&ord.perm);
        let f = ic0(&a, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let sell_tri = SellTriFactors::from_tri(&tri, 4);
        let meta = HbmcMeta::from_ordering(&ord);
        let pool = Pool::new(2);
        pool.reset_sync_count();
        let r = vec![1.0; a.n()];
        let mut y = vec![0.0; a.n()];
        forward(&meta, &sell_tri, &r, &mut y, &pool, KernelPath::Scalar);
        assert_eq!(pool.sync_count() as usize, meta.num_colors - 1);
        let mut z = vec![0.0; a.n()];
        backward(&meta, &sell_tri, &y, &mut z, &pool, KernelPath::Scalar);
        assert_eq!(pool.sync_count() as usize, 2 * (meta.num_colors - 1));
    }

    #[test]
    fn path_selection_respects_flag() {
        assert_eq!(select_path(8, false), KernelPath::Scalar);
        assert_eq!(select_path(3, true), KernelPath::Scalar);
    }
}
