//! Serial forward/backward substitution on CSR triangles — the correctness
//! oracle every parallel variant is tested against, and the `Natural`
//! ordering's execution path.

use crate::factor::split::TriFactors;

/// Forward substitution `L y = r` (strict lower + diagonal).
pub fn forward(tri: &TriFactors, r: &[f64], y: &mut [f64]) {
    let n = tri.n();
    assert_eq!(r.len(), n);
    assert_eq!(y.len(), n);
    for i in 0..n {
        let (cols, vals) = tri.lower.row(i);
        let mut s = r[i];
        for (c, v) in cols.iter().zip(vals) {
            s -= v * y[*c as usize];
        }
        y[i] = s * tri.diag_inv[i];
    }
}

/// Backward substitution `Lᵀ z = y` (strict upper of `Lᵀ` + diagonal).
pub fn backward(tri: &TriFactors, y: &[f64], z: &mut [f64]) {
    let n = tri.n();
    assert_eq!(y.len(), n);
    assert_eq!(z.len(), n);
    for i in (0..n).rev() {
        let (cols, vals) = tri.upper.row(i);
        let mut s = y[i];
        for (c, v) in cols.iter().zip(vals) {
            s -= v * z[*c as usize];
        }
        z[i] = s * tri.diag_inv[i];
    }
}

/// Full preconditioner application `z = (L Lᵀ)⁻¹ r` via a scratch vector.
pub fn apply(tri: &TriFactors, r: &[f64], scratch: &mut [f64], z: &mut [f64]) {
    forward(tri, r, scratch);
    backward(tri, scratch, z);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn spd(n: usize, seed: u64) -> crate::sparse::csr::Csr {
        let mut rng = Rng::new(seed);
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 8.0);
            for _ in 0..3 {
                let j = rng.below(n);
                if j != i {
                    c.push_sym(i, j, -0.4);
                }
            }
        }
        c.to_csr()
    }

    #[test]
    fn matches_icfactor_apply_serial() {
        let a = spd(60, 13);
        let f = ic0(&a, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let mut rng = Rng::new(14);
        let r: Vec<f64> = (0..60).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut z_ref = vec![0.0; 60];
        f.apply_serial(&r, &mut z_ref);
        let mut scratch = vec![0.0; 60];
        let mut z = vec![0.0; 60];
        apply(&tri, &r, &mut scratch, &mut z);
        assert!(crate::util::max_abs_diff(&z, &z_ref) < 1e-13);
    }

    #[test]
    fn forward_then_multiply_recovers_rhs() {
        let a = spd(40, 21);
        let f = ic0(&a, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let mut rng = Rng::new(22);
        let r: Vec<f64> = (0..40).map(|_| rng.f64()).collect();
        let mut y = vec![0.0; 40];
        forward(&tri, &r, &mut y);
        // L y should equal r: L = strict lower + diag.
        let mut ly = vec![0.0; 40];
        tri.lower.mul_vec(&y, &mut ly);
        for i in 0..40 {
            ly[i] += y[i] / tri.diag_inv[i];
        }
        assert!(crate::util::max_abs_diff(&ly, &r) < 1e-12);
    }

    #[test]
    fn backward_then_multiply_recovers_rhs() {
        let a = spd(40, 31);
        let f = ic0(&a, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let mut rng = Rng::new(32);
        let y: Vec<f64> = (0..40).map(|_| rng.f64()).collect();
        let mut z = vec![0.0; 40];
        backward(&tri, &y, &mut z);
        let mut ltz = vec![0.0; 40];
        tri.upper.mul_vec(&z, &mut ltz);
        for i in 0..40 {
            ltz[i] += z[i] / tri.diag_inv[i];
        }
        assert!(crate::util::max_abs_diff(&ltz, &y) < 1e-12);
    }
}
