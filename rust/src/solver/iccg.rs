//! The assembled ICCG solver — a back-compat convenience wrapper that
//! bundles a [`SolverPlan`] (phase 1: ordering → IC(0) factorization →
//! storage) with a private [`Pool`] (phase 2: execution), for callers that
//! want a single object per (matrix, config) pair.
//!
//! New code serving many right-hand sides should prefer the coordinator's
//! [`SolveSession`](crate::coordinator::session::SolveSession), which adds
//! reporting, batching (`solve_many`) and plan caching on top of the same
//! two-phase split.

use std::sync::Arc;

use crate::config::SolverConfig;
use crate::coordinator::metrics::OpProfile;
use crate::coordinator::pool::Pool;
use crate::error::Result;
use crate::ordering::perm::Perm;
use crate::solver::plan::{ExecOptions, SolverPlan};
use crate::sparse::csr::Csr;

pub use crate::solver::plan::{SetupStats, SolveOutcome};

/// A fully-constructed solver, reusable across right-hand sides.
pub struct IccgSolver {
    plan: Arc<SolverPlan>,
    pool: Pool,
}

impl IccgSolver {
    /// Build the solver for matrix `a` under configuration `cfg`.
    pub fn new(a: &Csr, cfg: &SolverConfig) -> Result<IccgSolver> {
        Ok(IccgSolver::from_plan(Arc::new(SolverPlan::build(a, cfg)?)))
    }

    /// Wrap an existing (possibly cached/shared) plan with a fresh pool.
    pub fn from_plan(plan: Arc<SolverPlan>) -> IccgSolver {
        let pool = Pool::new(plan.cfg.threads);
        IccgSolver { plan, pool }
    }

    /// The underlying immutable plan.
    pub fn plan(&self) -> &Arc<SolverPlan> {
        &self.plan
    }

    /// The configuration the plan was built under.
    pub fn cfg(&self) -> &SolverConfig {
        &self.plan.cfg
    }

    /// Setup-phase statistics.
    pub fn setup(&self) -> &SetupStats {
        &self.plan.setup
    }

    /// Analytic per-iteration op profile (SIMD-ratio metric).
    pub fn ops(&self) -> &OpProfile {
        &self.plan.ops
    }

    /// Augmented (internal) dimension.
    pub fn n_aug(&self) -> usize {
        self.plan.n_aug()
    }

    /// The permutation from original to internal (reordered, padded) space.
    pub fn perm(&self) -> &Perm {
        &self.plan.perm
    }

    /// The reordered matrix (for tests and the PJRT hybrid path).
    pub fn a_perm(&self) -> &Csr {
        &self.plan.a_perm
    }

    /// Apply the preconditioner in the *internal* ordering (tests, hybrid
    /// PJRT cross-checks).
    pub fn apply_precond_internal(&self, r: &[f64], z: &mut [f64]) {
        self.plan.apply_precond_internal(r, z, &self.pool);
    }

    /// Solve `A x = b` (original ordering); `b.len() == n_orig`.
    pub fn solve(&self, b: &[f64]) -> Result<SolveOutcome> {
        self.solve_opts(b, false)
    }

    /// Solve, optionally recording the per-iteration residual history
    /// (Fig. 5.1 data).
    pub fn solve_opts(&self, b: &[f64], record_history: bool) -> Result<SolveOutcome> {
        self.plan
            .execute(&self.pool, b, &ExecOptions { record_history, ..Default::default() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OrderingKind, SpmvKind};
    use crate::sparse::coo::Coo;

    fn laplace2d(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    fn rhs_for_ones(a: &Csr) -> Vec<f64> {
        let mut b = vec![0.0; a.n()];
        a.mul_vec(&vec![1.0; a.n()], &mut b);
        b
    }

    #[test]
    fn all_orderings_solve_to_the_same_solution() {
        let a = laplace2d(16, 16);
        let b = rhs_for_ones(&a);
        for ordering in [
            OrderingKind::Natural,
            OrderingKind::Mc,
            OrderingKind::Bmc,
            OrderingKind::Hbmc,
        ] {
            let cfg = SolverConfig {
                ordering,
                bs: 4,
                w: 4,
                spmv: SpmvKind::Crs,
                threads: 1,
                rtol: 1e-9,
                ..Default::default()
            };
            let solver = IccgSolver::new(&a, &cfg).unwrap();
            let out = solver.solve(&b).unwrap();
            assert!(out.cg.converged, "{ordering:?} failed to converge");
            assert!(
                crate::util::max_abs_diff(&out.x, &vec![1.0; a.n()]) < 1e-6,
                "{ordering:?} wrong solution"
            );
        }
    }

    #[test]
    fn bmc_and_hbmc_have_identical_iteration_counts() {
        // The paper's equivalence claim, checked end-to-end (Table 5.2).
        let a = laplace2d(24, 18);
        let b = rhs_for_ones(&a);
        let mk = |ordering| SolverConfig {
            ordering,
            bs: 8,
            w: 4,
            spmv: SpmvKind::Crs,
            rtol: 1e-8,
            ..Default::default()
        };
        let bmc = IccgSolver::new(&a, &mk(OrderingKind::Bmc)).unwrap();
        let hbmc = IccgSolver::new(&a, &mk(OrderingKind::Hbmc)).unwrap();
        let ob = bmc.solve_opts(&b, true).unwrap();
        let oh = hbmc.solve_opts(&b, true).unwrap();
        assert!(ob.cg.iterations.abs_diff(oh.cg.iterations) <= 1);
        // Residual histories overlap to near machine precision (Fig. 5.1).
        for (rb, rh) in ob.cg.residual_history.iter().zip(&oh.cg.residual_history) {
            assert!((rb - rh).abs() <= 1e-10 * rb.max(*rh).max(1e-30), "{rb} vs {rh}");
        }
    }

    #[test]
    fn sell_spmv_matches_crs_solution() {
        let a = laplace2d(20, 20);
        let b = rhs_for_ones(&a);
        let mk = |spmv| SolverConfig {
            ordering: OrderingKind::Hbmc,
            bs: 8,
            w: 4,
            spmv,
            rtol: 1e-9,
            ..Default::default()
        };
        let crs = IccgSolver::new(&a, &mk(SpmvKind::Crs)).unwrap();
        let sell = IccgSolver::new(&a, &mk(SpmvKind::Sell)).unwrap();
        let oc = crs.solve(&b).unwrap();
        let os = sell.solve(&b).unwrap();
        assert_eq!(oc.cg.iterations, os.cg.iterations);
        assert!(crate::util::max_abs_diff(&oc.x, &os.x) < 1e-8);
        assert!(sell.setup().spmv_elements >= crs.setup().spmv_elements);
    }

    #[test]
    fn multithreaded_matches_single_threaded() {
        let a = laplace2d(20, 12);
        let b = rhs_for_ones(&a);
        let mk = |threads| SolverConfig {
            ordering: OrderingKind::Hbmc,
            bs: 4,
            w: 4,
            threads,
            rtol: 1e-9,
            ..Default::default()
        };
        let s1 = IccgSolver::new(&a, &mk(1)).unwrap();
        let s4 = IccgSolver::new(&a, &mk(4)).unwrap();
        let o1 = s1.solve(&b).unwrap();
        let o4 = s4.solve(&b).unwrap();
        assert_eq!(o1.cg.iterations, o4.cg.iterations);
        assert!(crate::util::max_abs_diff(&o1.x, &o4.x) < 1e-9);
    }

    #[test]
    fn setup_stats_populated() {
        let a = laplace2d(12, 12);
        let cfg = SolverConfig { ordering: OrderingKind::Hbmc, bs: 4, w: 4, ..Default::default() };
        let s = IccgSolver::new(&a, &cfg).unwrap();
        assert_eq!(s.setup().n_orig, 144);
        assert!(s.setup().n_aug >= 144);
        assert!(s.setup().num_colors >= 2);
        assert!(s.setup().tri_elements > 0);
        assert!(s.ops().simd_ratio() > 0.0);
        assert_ne!(s.setup().kernel_path, "n/a");
    }

    #[test]
    fn shared_plan_backs_multiple_solvers() {
        let a = laplace2d(10, 10);
        let cfg = SolverConfig { ordering: OrderingKind::Bmc, bs: 4, w: 4, ..Default::default() };
        let plan = Arc::new(SolverPlan::build(&a, &cfg).unwrap());
        let s1 = IccgSolver::from_plan(plan.clone());
        let s2 = IccgSolver::from_plan(plan.clone());
        assert!(Arc::ptr_eq(s1.plan(), s2.plan()));
        let b = rhs_for_ones(&a);
        let o1 = s1.solve(&b).unwrap();
        let o2 = s2.solve(&b).unwrap();
        assert_eq!(o1.cg.iterations, o2.cg.iterations);
        assert_eq!(o1.x, o2.x);
    }

    #[test]
    fn rhs_dimension_checked() {
        let a = laplace2d(8, 8);
        let solver = IccgSolver::new(&a, &SolverConfig::default()).unwrap();
        assert!(solver.solve(&vec![1.0; 3]).is_err());
    }
}
