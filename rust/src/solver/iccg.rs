//! The assembled ICCG solver: ordering → IC(0) factorization → storage
//! construction → PCG loop, for any [`OrderingKind`] × [`SpmvKind`]
//! combination the paper evaluates.

use anyhow::{Context, Result};
use std::time::Instant;

use crate::config::{OrderingKind, SolverConfig, SpmvKind};
use crate::coordinator::metrics::{per_iteration_ops, OpInputs, OpProfile};
use crate::coordinator::pool::Pool;
use crate::factor::ic0::ic0_auto;
use crate::factor::split::{SellTriFactors, TriFactors};
use crate::ordering::bmc::bmc_order;
use crate::ordering::hbmc::hbmc_order;
use crate::ordering::mc::mc_order;
use crate::ordering::perm::Perm;
use crate::solver::cg::{pcg, CgResult};
use crate::solver::precond::Preconditioner;
use crate::solver::spmv::{spmv_crs, spmv_sell};
use crate::solver::trisolve_hbmc::{select_path, HbmcMeta};
use crate::sparse::csr::Csr;
use crate::sparse::sell::Sell;

/// Setup-phase statistics (reported alongside solve results).
#[derive(Debug, Clone)]
pub struct SetupStats {
    pub ordering_seconds: f64,
    pub factor_seconds: f64,
    pub num_colors: usize,
    pub n_orig: usize,
    /// Augmented dimension (≥ n_orig; includes HBMC/BMC dummy unknowns).
    pub n_aug: usize,
    pub nnz: usize,
    /// Stored elements of the SpMV matrix in its chosen format.
    pub spmv_elements: usize,
    /// Stored elements of the substitution triangles in their chosen format.
    pub tri_elements: usize,
    /// Shift actually used by the factorization (≥ requested on auto-retry).
    pub shift_used: f64,
    /// Inner kernel selected for HBMC ("scalar", "avx2-w4", "avx512-w8").
    pub kernel_path: &'static str,
}

/// A fully-constructed solver, reusable across right-hand sides.
pub struct IccgSolver {
    pub cfg: SolverConfig,
    perm: Perm,
    a_perm: Csr,
    sell_a: Option<Sell>,
    precond: Preconditioner,
    pool: Pool,
    pub setup: SetupStats,
    /// Analytic per-iteration op profile (SIMD-ratio metric).
    pub ops: OpProfile,
}

/// Solution + iteration data, mapped back to the original ordering.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    pub x: Vec<f64>,
    pub cg: CgResult,
    /// Thread synchronizations per substitution sweep (= n_c − 1).
    pub syncs_per_substitution: usize,
}

impl IccgSolver {
    /// Build the solver for matrix `a` under configuration `cfg`.
    pub fn new(a: &Csr, cfg: &SolverConfig) -> Result<IccgSolver> {
        cfg.validate()?;
        let pool = Pool::new(cfg.threads);
        let n_orig = a.n();

        // --- Ordering ---------------------------------------------------
        let t0 = Instant::now();
        let (perm, num_colors, structure): (Perm, usize, Structure) = match cfg.ordering {
            OrderingKind::Natural => (Perm::identity(n_orig), 1, Structure::Natural),
            OrderingKind::Mc => {
                let mc = mc_order(a);
                (mc.perm.clone(), mc.num_colors, Structure::Mc { color_ptr: mc.color_ptr })
            }
            OrderingKind::Bmc => {
                let ord = bmc_order(a, cfg.bs);
                (
                    ord.perm.clone(),
                    ord.num_colors,
                    Structure::Bmc { color_ptr: ord.color_ptr, bs: ord.bs },
                )
            }
            OrderingKind::Hbmc => {
                let ord = hbmc_order(a, cfg.bs, cfg.w);
                let meta = HbmcMeta::from_ordering(&ord);
                (ord.perm.clone(), ord.num_colors, Structure::Hbmc { meta })
            }
        };
        let a_perm = a.permute_sym(&perm);
        let ordering_seconds = t0.elapsed().as_secs_f64();

        // --- Factorization ------------------------------------------------
        let t1 = Instant::now();
        let factor = ic0_auto(&a_perm, cfg.shift).context("IC(0) factorization failed")?;
        let shift_used = factor.shift;
        let tri = TriFactors::from_ic(&factor);
        let factor_seconds = t1.elapsed().as_secs_f64();

        // --- Solver storage -----------------------------------------------
        let tri_nnz = tri.lower.nnz() + tri.upper.nnz();
        let mut kernel_path = "n/a";
        let (precond, tri_elements) = match structure {
            Structure::Natural => (Preconditioner::Serial(tri), tri_nnz),
            Structure::Mc { color_ptr } => (Preconditioner::Mc { tri, color_ptr }, tri_nnz),
            Structure::Bmc { color_ptr, bs } => {
                (Preconditioner::Bmc { tri, color_ptr, bs }, tri_nnz)
            }
            Structure::Hbmc { meta } => {
                let sell = SellTriFactors::from_tri(&tri, cfg.w);
                let stored = sell.stored_elements();
                let path = select_path(cfg.w, cfg.use_intrinsics);
                kernel_path = path.name();
                (Preconditioner::Hbmc { meta, sell, path }, stored)
            }
        };

        let sell_a = match cfg.spmv {
            SpmvKind::Crs => None,
            SpmvKind::Sell => Some(match cfg.sell_sigma {
                Some(sigma) => Sell::from_csr_sigma(&a_perm, cfg.w, sigma),
                None => Sell::from_csr(&a_perm, cfg.w),
            }),
        };
        let spmv_elements = sell_a
            .as_ref()
            .map(|s| s.stored_elements())
            .unwrap_or_else(|| a_perm.nnz());

        let setup = SetupStats {
            ordering_seconds,
            factor_seconds,
            num_colors,
            n_orig,
            n_aug: a_perm.n(),
            nnz: a_perm.nnz(),
            spmv_elements,
            tri_elements,
            shift_used,
            kernel_path,
        };

        let ops = per_iteration_ops(
            cfg,
            &OpInputs {
                n: a_perm.n(),
                nnz: a_perm.nnz(),
                tri_nnz,
                sell_tri_elements: matches!(cfg.ordering, OrderingKind::Hbmc)
                    .then_some(tri_elements),
                sell_a_elements: sell_a.as_ref().map(|s| s.stored_elements()),
            },
        );

        Ok(IccgSolver { cfg: cfg.clone(), perm, a_perm, sell_a, precond, pool, setup, ops })
    }

    /// Augmented (internal) dimension.
    pub fn n_aug(&self) -> usize {
        self.a_perm.n()
    }

    /// The permutation from original to internal (reordered, padded) space.
    pub fn perm(&self) -> &Perm {
        &self.perm
    }

    /// The reordered matrix (for tests and the PJRT hybrid path).
    pub fn a_perm(&self) -> &Csr {
        &self.a_perm
    }

    /// Apply the preconditioner in the *internal* ordering (tests, hybrid
    /// PJRT cross-checks).
    pub fn apply_precond_internal(&self, r: &[f64], z: &mut [f64]) {
        let mut scratch = vec![0.0; self.n_aug()];
        self.precond.apply(r, &mut scratch, z, &self.pool);
    }

    /// Solve `A x = b` (original ordering); `b.len() == n_orig`.
    pub fn solve(&self, b: &[f64]) -> Result<SolveOutcome> {
        self.solve_opts(b, false)
    }

    /// Solve, optionally recording the per-iteration residual history
    /// (Fig. 5.1 data).
    pub fn solve_opts(&self, b: &[f64], record_history: bool) -> Result<SolveOutcome> {
        anyhow::ensure!(b.len() == self.setup.n_orig, "rhs dimension mismatch");
        let n = self.n_aug();
        let b_perm = self.perm.apply_vec(b, 0.0);
        let mut x_perm = vec![0.0f64; n];
        let mut scratch = vec![0.0f64; n];

        let pool = &self.pool;
        let a_perm = &self.a_perm;
        let sell_a = &self.sell_a;
        let precond = &self.precond;
        pool.reset_sync_count();

        let mut spmv = |x: &[f64], y: &mut [f64], times: &mut crate::util::timer::KernelTimes| {
            let t = Instant::now();
            match sell_a {
                Some(s) => spmv_sell(s, x, y, pool),
                None => spmv_crs(a_perm, x, y, pool),
            }
            times.add("spmv", t.elapsed());
        };
        let mut prec = |r: &[f64], z: &mut [f64], times: &mut crate::util::timer::KernelTimes| {
            let t = Instant::now();
            precond.apply(r, &mut scratch, z, pool);
            times.add("trisolve", t.elapsed());
        };

        let cg = pcg(
            &mut spmv,
            &mut prec,
            &b_perm,
            &mut x_perm,
            self.cfg.rtol,
            self.cfg.max_iters,
            record_history,
        );

        let x = self.perm.unapply_vec(&x_perm);
        Ok(SolveOutcome {
            x,
            cg,
            syncs_per_substitution: self.setup.num_colors.saturating_sub(1),
        })
    }
}

enum Structure {
    Natural,
    Mc { color_ptr: Vec<usize> },
    Bmc { color_ptr: Vec<usize>, bs: usize },
    Hbmc { meta: HbmcMeta },
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn laplace2d(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    fn rhs_for_ones(a: &Csr) -> Vec<f64> {
        let mut b = vec![0.0; a.n()];
        a.mul_vec(&vec![1.0; a.n()], &mut b);
        b
    }

    #[test]
    fn all_orderings_solve_to_the_same_solution() {
        let a = laplace2d(16, 16);
        let b = rhs_for_ones(&a);
        for ordering in [
            OrderingKind::Natural,
            OrderingKind::Mc,
            OrderingKind::Bmc,
            OrderingKind::Hbmc,
        ] {
            let cfg = SolverConfig {
                ordering,
                bs: 4,
                w: 4,
                spmv: SpmvKind::Crs,
                threads: 1,
                rtol: 1e-9,
                ..Default::default()
            };
            let solver = IccgSolver::new(&a, &cfg).unwrap();
            let out = solver.solve(&b).unwrap();
            assert!(out.cg.converged, "{ordering:?} failed to converge");
            assert!(
                crate::util::max_abs_diff(&out.x, &vec![1.0; a.n()]) < 1e-6,
                "{ordering:?} wrong solution"
            );
        }
    }

    #[test]
    fn bmc_and_hbmc_have_identical_iteration_counts() {
        // The paper's equivalence claim, checked end-to-end (Table 5.2).
        let a = laplace2d(24, 18);
        let b = rhs_for_ones(&a);
        let mk = |ordering| SolverConfig {
            ordering,
            bs: 8,
            w: 4,
            spmv: SpmvKind::Crs,
            rtol: 1e-8,
            ..Default::default()
        };
        let bmc = IccgSolver::new(&a, &mk(OrderingKind::Bmc)).unwrap();
        let hbmc = IccgSolver::new(&a, &mk(OrderingKind::Hbmc)).unwrap();
        let ob = bmc.solve_opts(&b, true).unwrap();
        let oh = hbmc.solve_opts(&b, true).unwrap();
        assert!(ob.cg.iterations.abs_diff(oh.cg.iterations) <= 1);
        // Residual histories overlap to near machine precision (Fig. 5.1).
        for (rb, rh) in ob.cg.residual_history.iter().zip(&oh.cg.residual_history) {
            assert!((rb - rh).abs() <= 1e-10 * rb.max(*rh).max(1e-30), "{rb} vs {rh}");
        }
    }

    #[test]
    fn sell_spmv_matches_crs_solution() {
        let a = laplace2d(20, 20);
        let b = rhs_for_ones(&a);
        let mk = |spmv| SolverConfig {
            ordering: OrderingKind::Hbmc,
            bs: 8,
            w: 4,
            spmv,
            rtol: 1e-9,
            ..Default::default()
        };
        let crs = IccgSolver::new(&a, &mk(SpmvKind::Crs)).unwrap();
        let sell = IccgSolver::new(&a, &mk(SpmvKind::Sell)).unwrap();
        let oc = crs.solve(&b).unwrap();
        let os = sell.solve(&b).unwrap();
        assert_eq!(oc.cg.iterations, os.cg.iterations);
        assert!(crate::util::max_abs_diff(&oc.x, &os.x) < 1e-8);
        assert!(sell.setup.spmv_elements >= crs.setup.spmv_elements);
    }

    #[test]
    fn multithreaded_matches_single_threaded() {
        let a = laplace2d(20, 12);
        let b = rhs_for_ones(&a);
        let mk = |threads| SolverConfig {
            ordering: OrderingKind::Hbmc,
            bs: 4,
            w: 4,
            threads,
            rtol: 1e-9,
            ..Default::default()
        };
        let s1 = IccgSolver::new(&a, &mk(1)).unwrap();
        let s4 = IccgSolver::new(&a, &mk(4)).unwrap();
        let o1 = s1.solve(&b).unwrap();
        let o4 = s4.solve(&b).unwrap();
        assert_eq!(o1.cg.iterations, o4.cg.iterations);
        assert!(crate::util::max_abs_diff(&o1.x, &o4.x) < 1e-9);
    }

    #[test]
    fn setup_stats_populated() {
        let a = laplace2d(12, 12);
        let cfg = SolverConfig { ordering: OrderingKind::Hbmc, bs: 4, w: 4, ..Default::default() };
        let s = IccgSolver::new(&a, &cfg).unwrap();
        assert_eq!(s.setup.n_orig, 144);
        assert!(s.setup.n_aug >= 144);
        assert!(s.setup.num_colors >= 2);
        assert!(s.setup.tri_elements > 0);
        assert!(s.ops.simd_ratio() > 0.0);
        assert_ne!(s.setup.kernel_path, "n/a");
    }

    #[test]
    fn rhs_dimension_checked() {
        let a = laplace2d(8, 8);
        let solver = IccgSolver::new(&a, &SolverConfig::default()).unwrap();
        assert!(solver.solve(&vec![1.0; 3]).is_err());
    }
}
