//! Preconditioned conjugate gradient (the "CG" of ICCG). The loop is
//! storage- and ordering-agnostic: SpMV and preconditioner come in as
//! closures so the same driver serves MC/BMC/HBMC × CRS/SELL variants.
//!
//! Convergence criterion: relative residual 2-norm `< rtol` (paper §5.1:
//! `10⁻⁷`), measured against `||b||`.

use crate::solver::blas1::{dot, fused_cg_update, norm2, xpby};
use crate::util::timer::KernelTimes;
use std::time::Instant;

/// Outcome of a PCG run.
#[derive(Debug, Clone)]
pub struct CgResult {
    pub iterations: usize,
    pub converged: bool,
    /// Final `||r|| / ||b||`.
    pub final_relres: f64,
    /// Per-iteration relative residuals (index 0 = after first iteration);
    /// populated when `record_history` is set (Fig. 5.1 data).
    pub residual_history: Vec<f64>,
    /// Time spent in each kernel class.
    pub times: KernelTimes,
    /// Wall-clock of the whole iteration loop.
    pub solve_seconds: f64,
}

/// Run preconditioned CG. `spmv(x, y)` computes `y = A x`;
/// `precond(r, z)` computes `z = M⁻¹ r`. `x` holds the initial guess and
/// receives the solution.
#[allow(clippy::too_many_arguments)]
pub fn pcg(
    spmv: &mut dyn FnMut(&[f64], &mut [f64], &mut KernelTimes),
    precond: &mut dyn FnMut(&[f64], &mut [f64], &mut KernelTimes),
    b: &[f64],
    x: &mut [f64],
    rtol: f64,
    max_iters: usize,
    record_history: bool,
) -> CgResult {
    let n = b.len();
    assert_eq!(x.len(), n);
    let mut times = KernelTimes::new();
    let start = Instant::now();

    let bnorm = norm2(b);
    if bnorm == 0.0 {
        x.fill(0.0);
        return CgResult {
            iterations: 0,
            converged: true,
            final_relres: 0.0,
            residual_history: Vec::new(),
            times,
            solve_seconds: start.elapsed().as_secs_f64(),
        };
    }

    let mut r = vec![0.0f64; n];
    let mut z = vec![0.0f64; n];
    let mut p = vec![0.0f64; n];
    let mut q = vec![0.0f64; n];

    // r = b - A x
    spmv(x, &mut q, &mut times);
    let t = Instant::now();
    for i in 0..n {
        r[i] = b[i] - q[i];
    }
    times.add("blas1", t.elapsed());

    precond(&r, &mut z, &mut times);
    let t = Instant::now();
    p.copy_from_slice(&z);
    let mut rz = dot(&r, &z);
    times.add("blas1", t.elapsed());

    let mut history = Vec::new();
    let mut converged = false;
    let mut relres = norm2(&r) / bnorm;
    let mut iters = 0;

    while iters < max_iters {
        iters += 1;
        spmv(&p, &mut q, &mut times);
        let t = Instant::now();
        let pq = dot(&p, &q);
        if pq <= 0.0 || !pq.is_finite() {
            // Non-SPD or breakdown; report divergence.
            times.add("blas1", t.elapsed());
            break;
        }
        let alpha = rz / pq;
        let rr = fused_cg_update(alpha, &p, &q, x, &mut r);
        relres = rr.sqrt() / bnorm;
        times.add("blas1", t.elapsed());
        if record_history {
            history.push(relres);
        }
        if relres < rtol {
            converged = true;
            break;
        }
        precond(&r, &mut z, &mut times);
        let t = Instant::now();
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        xpby(&z, beta, &mut p);
        times.add("blas1", t.elapsed());
    }

    CgResult {
        iterations: iters,
        converged,
        final_relres: relres,
        residual_history: history,
        times,
        solve_seconds: start.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::csr::Csr;

    fn laplace2d(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    #[test]
    fn plain_cg_solves_laplace() {
        let a = laplace2d(12, 12);
        let n = a.n();
        let xstar = vec![1.0; n];
        let mut b = vec![0.0; n];
        a.mul_vec(&xstar, &mut b);
        let mut x = vec![0.0; n];
        let res = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| z.copy_from_slice(r),
            &b,
            &mut x,
            1e-10,
            1000,
            true,
        );
        assert!(res.converged, "relres={}", res.final_relres);
        assert!(crate::util::max_abs_diff(&x, &xstar) < 1e-7);
        assert_eq!(res.residual_history.len(), res.iterations);
        // History is the recorded relres sequence ending below rtol.
        assert!(*res.residual_history.last().unwrap() < 1e-10);
    }

    #[test]
    fn ic_preconditioner_reduces_iterations() {
        use crate::factor::ic0::ic0;
        use crate::factor::split::TriFactors;
        use crate::solver::trisolve_serial;
        let a = laplace2d(20, 20);
        let n = a.n();
        let b = vec![1.0; n];
        let tri = TriFactors::from_ic(&ic0(&a, 0.0).unwrap());
        let mut scratch = vec![0.0; n];

        let mut x0 = vec![0.0; n];
        let plain = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| z.copy_from_slice(r),
            &b,
            &mut x0,
            1e-8,
            5000,
            false,
        );
        let mut x1 = vec![0.0; n];
        let ic = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| trisolve_serial::apply(&tri, r, &mut scratch, z),
            &b,
            &mut x1,
            1e-8,
            5000,
            false,
        );
        assert!(plain.converged && ic.converged);
        assert!(
            ic.iterations < plain.iterations,
            "IC {} vs plain {}",
            ic.iterations,
            plain.iterations
        );
        assert!(crate::util::max_abs_diff(&x0, &x1) < 1e-5);
    }

    #[test]
    fn zero_rhs_is_trivial() {
        let a = laplace2d(4, 4);
        let mut x = vec![5.0; 16];
        let res = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| z.copy_from_slice(r),
            &vec![0.0; 16],
            &mut x,
            1e-8,
            100,
            false,
        );
        assert!(res.converged);
        assert_eq!(res.iterations, 0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn max_iters_respected() {
        let a = laplace2d(16, 16);
        let n = a.n();
        let b = vec![1.0; n];
        let mut x = vec![0.0; n];
        let res = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| z.copy_from_slice(r),
            &b,
            &mut x,
            1e-14,
            3,
            false,
        );
        assert!(!res.converged);
        assert_eq!(res.iterations, 3);
    }
}
