//! Preconditioned conjugate gradient (the "CG" of ICCG), in two execution
//! shapes with bitwise-identical numerics:
//!
//! * [`pcg`] — the legacy per-kernel loop: SpMV and preconditioner come in
//!   as closures (each one a separate `Pool::run` dispatch), BLAS-1 runs
//!   serially on the calling thread. Kept as the reference path and for
//!   callers with bespoke kernels (the PJRT hybrid).
//! * [`pcg_fused`] — the single-dispatch loop: **one** `Pool::run` per
//!   solve. Workers enter a persistent SPMD region and walk the whole
//!   iteration together; [`Pool::phase_barrier`] separates kernel phases,
//!   reductions go through the fixed chunk grid of `blas1` (partials +
//!   left-to-right combine), and every thread recomputes the iteration
//!   scalars (α, β, convergence) redundantly-but-identically from the
//!   combined values — no broadcast, no serial section. Per-iteration
//!   dispatches drop from 3 (SpMV, forward, backward — each a condvar
//!   wake-up plus a completion barrier) to 0; see `ARCHITECTURE.md` for
//!   the sync accounting.
//!
//! Because the chunk-grid reductions are partition-invariant (see
//! `blas1`), the fused loop reproduces the legacy loop *exactly* —
//! identical residual history, iteration count and solution bits — for
//! any thread count (`tests/fused_parity.rs`).
//!
//! Convergence criterion: relative residual 2-norm `< rtol` (paper §5.1:
//! `10⁻⁷`), measured against `||b||`.

use crate::coordinator::pool::{Pool, SyncSlice};
use crate::obs::flight::{FlightRecorder, Phase};
use crate::solver::blas1::{self, dot, fused_cg_update, norm2, xpby};
use crate::solver::spmv::SpmvEngine;
use crate::solver::trisolve::TriSolver;
use crate::util::timer::KernelTimes;
use std::cell::UnsafeCell;
use std::time::Instant;

/// A non-finite or non-positive reduction value caught at one of the CG
/// loop's *existing* per-iteration reduction sites (no extra syncs). Both
/// execution shapes detect identically — in the fused loop every thread
/// computes the same combined scalar and breaks in lockstep — and
/// `SolverPlan::execute` surfaces it as `HbmcError::BreakdownInIteration`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CgBreakdown {
    /// Iteration at which the value was observed (0 = initialization).
    pub iter: usize,
    /// Which reduction broke: `"rz"` (r·M⁻¹r) or `"pq"` (p·Ap).
    pub quantity: &'static str,
}

/// Outcome of a PCG run.
#[derive(Debug, Clone)]
pub struct CgResult {
    pub iterations: usize,
    pub converged: bool,
    /// Final `||r|| / ||b||`.
    pub final_relres: f64,
    /// Per-iteration relative residuals (index 0 = after first iteration);
    /// populated when `record_history` is set (Fig. 5.1 data).
    pub residual_history: Vec<f64>,
    /// Time spent in each kernel class.
    pub times: KernelTimes,
    /// Wall-clock of the whole iteration loop.
    pub solve_seconds: f64,
    /// `Some` when the loop stopped on a poisoned reduction (NaN/Inf
    /// residual, non-positive curvature) rather than convergence or the
    /// iteration cap; see [`CgBreakdown`].
    pub breakdown: Option<CgBreakdown>,
}

/// Run preconditioned CG. `spmv(x, y)` computes `y = A x`;
/// `precond(r, z)` computes `z = M⁻¹ r`. `x` holds the initial guess and
/// receives the solution.
#[allow(clippy::too_many_arguments)]
pub fn pcg(
    spmv: &mut dyn FnMut(&[f64], &mut [f64], &mut KernelTimes),
    precond: &mut dyn FnMut(&[f64], &mut [f64], &mut KernelTimes),
    b: &[f64],
    x: &mut [f64],
    rtol: f64,
    max_iters: usize,
    record_history: bool,
) -> CgResult {
    let n = b.len();
    assert_eq!(x.len(), n);
    let mut times = KernelTimes::new();
    let start = Instant::now();

    let bnorm = norm2(b);
    if bnorm == 0.0 {
        x.fill(0.0);
        return CgResult {
            iterations: 0,
            converged: true,
            final_relres: 0.0,
            residual_history: Vec::new(),
            times,
            solve_seconds: start.elapsed().as_secs_f64(),
            breakdown: None,
        };
    }

    let mut r = vec![0.0f64; n];
    let mut z = vec![0.0f64; n];
    let mut p = vec![0.0f64; n];
    let mut q = vec![0.0f64; n];

    // r = b - A x
    spmv(x, &mut q, &mut times);
    let t = Instant::now();
    for i in 0..n {
        r[i] = b[i] - q[i];
    }
    times.add("blas1", t.elapsed());

    precond(&r, &mut z, &mut times);
    let t = Instant::now();
    p.copy_from_slice(&z);
    let mut rz = dot(&r, &z);
    times.add("blas1", t.elapsed());

    let mut history = Vec::new();
    let mut converged = false;
    let mut relres = norm2(&r) / bnorm;
    let mut iters = 0;
    let mut breakdown = None;

    // A non-finite initial r·z means b, x₀, or the factor is already
    // poisoned (NaN/Inf); the loop could only iterate on NaNs. `rz = 0`
    // stays legal here: an exact initial guess has r = 0.
    if !rz.is_finite() {
        breakdown = Some(CgBreakdown { iter: 0, quantity: "rz" });
        return CgResult {
            iterations: 0,
            converged: false,
            final_relres: relres,
            residual_history: history,
            times,
            solve_seconds: start.elapsed().as_secs_f64(),
            breakdown,
        };
    }

    while iters < max_iters {
        iters += 1;
        spmv(&p, &mut q, &mut times);
        let t = Instant::now();
        let pq = dot(&p, &q);
        if pq <= 0.0 || !pq.is_finite() {
            // Non-SPD or breakdown; recorded, reported as divergence.
            breakdown = Some(CgBreakdown { iter: iters, quantity: "pq" });
            times.add("blas1", t.elapsed());
            break;
        }
        let alpha = rz / pq;
        let rr = fused_cg_update(alpha, &p, &q, x, &mut r);
        relres = rr.sqrt() / bnorm;
        times.add("blas1", t.elapsed());
        if record_history {
            history.push(relres);
        }
        if relres < rtol {
            converged = true;
            break;
        }
        precond(&r, &mut z, &mut times);
        let t = Instant::now();
        let rz_new = dot(&r, &z);
        // Here r ≠ 0 (relres ≥ rtol above), so for an SPD preconditioner
        // rz ≤ 0 is as broken as NaN.
        if !rz_new.is_finite() || rz_new <= 0.0 {
            breakdown = Some(CgBreakdown { iter: iters, quantity: "rz" });
            times.add("blas1", t.elapsed());
            break;
        }
        let beta = rz_new / rz;
        rz = rz_new;
        xpby(&z, beta, &mut p);
        times.add("blas1", t.elapsed());
    }

    CgResult {
        iterations: iters,
        converged,
        final_relres: relres,
        residual_history: history,
        times,
        solve_seconds: start.elapsed().as_secs_f64(),
        breakdown,
    }
}

/// Per-solve state written only by thread 0 inside the region (residual
/// history, kernel timers, final counters) and read by the caller after
/// the region completes.
struct SoloCell<T>(UnsafeCell<T>);

// SAFETY: the region protocol gives thread 0 exclusive access between
// barriers; the caller reads only after `Pool::run` returned (completion
// barrier = happens-after every worker write).
unsafe impl<T: Send> Sync for SoloCell<T> {}

impl<T> SoloCell<T> {
    fn new(v: T) -> SoloCell<T> {
        SoloCell(UnsafeCell::new(v))
    }

    /// Raw pointer for thread-0-only access (deref inside `unsafe`).
    fn as_ptr(&self) -> *mut T {
        self.0.get()
    }

    fn into_inner(self) -> T {
        self.0.into_inner()
    }
}

struct FusedState {
    times: KernelTimes,
    history: Vec<f64>,
    iterations: usize,
    converged: bool,
    relres: f64,
    breakdown: Option<CgBreakdown>,
}

/// Everything the region workers share, borrowed for the duration of the
/// single `Pool::run`.
struct FusedCtx<'a> {
    spmv: &'a SpmvEngine<'a>,
    tri: &'a dyn TriSolver,
    b: &'a [f64],
    xs: &'a SyncSlice<'a, f64>,
    rs: &'a SyncSlice<'a, f64>,
    zs: &'a SyncSlice<'a, f64>,
    ps: &'a SyncSlice<'a, f64>,
    qs: &'a SyncSlice<'a, f64>,
    /// Forward-substitution result (the `scratch` of `TriSolver::apply`).
    ss: &'a SyncSlice<'a, f64>,
    /// SpMV engine scratch (`SpmvEngine::scratch_elems` doubles — empty
    /// except for the buffered symmetric mode). Per-solve, because plans
    /// are `Arc`-shared across concurrent executes.
    spmv_scratch: &'a SyncSlice<'a, f64>,
    /// Chunk-partials buffers. Two, used alternately: a thread may start
    /// writing the *next* reduction's partials while a straggler is still
    /// combining the previous one (there is deliberately no barrier after
    /// a combine), so consecutive reductions must target different
    /// buffers. The steady-state loop's sequence (p·q → `partials`,
    /// update-‖r‖² → `partials2`, r·z → `partials`, then the p-publish
    /// barrier before the next p·q) alternates correctly with at least one
    /// barrier between any write and the combine it could clobber; the
    /// initialization's shared-barrier double reduction is followed by an
    /// explicit extra barrier instead.
    partials: &'a SyncSlice<'a, f64>,
    partials2: &'a SyncSlice<'a, f64>,
    nchunks: usize,
    rtol: f64,
    max_iters: usize,
    record_history: bool,
    pool: &'a Pool,
    state: &'a SoloCell<FusedState>,
    /// Flight recorder for `ExecOptions::profile`; `None` on unprofiled
    /// solves (every profiling touch point then compiles to a null check).
    prof: Option<&'a FlightRecorder>,
}

/// Close a timing bucket on thread 0 and restart every thread's phase
/// clock. Phases are barrier-delimited, so thread 0's elapsed time is a
/// faithful (± one barrier wait) pool-wide figure; the buckets match the
/// legacy loop's ("spmv" / "trisolve" / "blas1").
#[inline]
fn mark(tid: usize, state: &SoloCell<FusedState>, clock: &mut Instant, bucket: &'static str) {
    if tid == 0 {
        // SAFETY: thread 0 is the sole writer of the solo state.
        unsafe { (*state.as_ptr()).times.add(bucket, clock.elapsed()) };
    }
    *clock = Instant::now();
}

/// Stamp one flight-recorder span for the current thread and advance its
/// span clock. Unlike [`mark`] (whose coarse `KernelTimes` bucket is
/// thread-0-only), **every** thread records its own lane, so per-thread
/// skew is visible. The barrier-wait nanoseconds the pool accumulated
/// thread-locally since the previous mark are drained here, attributed to
/// this span and subtracted from its busy time. No-op when unprofiled.
#[inline]
fn prof_mark(
    prof: Option<&FlightRecorder>,
    pool: &Pool,
    tid: usize,
    pclock: &mut u64,
    phase: Phase,
) {
    if let Some(rec) = prof {
        let end = rec.now_ns();
        let wait = pool.take_barrier_wait_ns();
        rec.record(tid, phase, *pclock, end, wait);
        *pclock = end;
    }
}

/// Run preconditioned CG as **one** pool dispatch (see module docs). `x`
/// holds the initial guess and receives the solution. Numerics are
/// bitwise-identical to [`pcg`] driven by the same kernels.
///
/// `prof` is the per-thread flight recorder for profiled solves (see
/// `crate::obs::flight`); pass `None` to record nothing. Profiling adds
/// only clock reads at existing phase boundaries — no barriers, no
/// allocation, no numeric effect — so the two settings produce bitwise-
/// identical solves (`tests/profiling.rs`).
#[allow(clippy::too_many_arguments)]
pub fn pcg_fused(
    spmv: &SpmvEngine,
    tri: &dyn TriSolver,
    b: &[f64],
    x: &mut [f64],
    rtol: f64,
    max_iters: usize,
    record_history: bool,
    pool: &Pool,
    prof: Option<&FlightRecorder>,
) -> CgResult {
    let n = b.len();
    assert_eq!(x.len(), n);
    let start = Instant::now();
    let nchunks = blas1::num_chunks(n);

    let mut r = vec![0.0f64; n];
    let mut z = vec![0.0f64; n];
    let mut p = vec![0.0f64; n];
    let mut q = vec![0.0f64; n];
    let mut scratch = vec![0.0f64; n];
    let mut spmv_scratch = vec![0.0f64; spmv.scratch_elems()];
    let mut partials = vec![0.0f64; nchunks];
    let mut partials2 = vec![0.0f64; nchunks];

    let xs = SyncSlice::new(x);
    let rs = SyncSlice::new(&mut r);
    let zs = SyncSlice::new(&mut z);
    let ps = SyncSlice::new(&mut p);
    let qs = SyncSlice::new(&mut q);
    let ss = SyncSlice::new(&mut scratch);
    let sps = SyncSlice::new(&mut spmv_scratch);
    let pt = SyncSlice::new(&mut partials);
    let pt2 = SyncSlice::new(&mut partials2);
    let state = SoloCell::new(FusedState {
        times: KernelTimes::new(),
        history: Vec::new(),
        iterations: 0,
        converged: false,
        relres: 0.0,
        breakdown: None,
    });

    {
        let cx = FusedCtx {
            spmv,
            tri,
            b,
            xs: &xs,
            rs: &rs,
            zs: &zs,
            ps: &ps,
            qs: &qs,
            ss: &ss,
            spmv_scratch: &sps,
            partials: &pt,
            partials2: &pt2,
            nchunks,
            rtol,
            max_iters,
            record_history,
            pool,
            state: &state,
            prof,
        };
        pool.run(&|tid, nt| fused_worker(&cx, tid, nt));
    }

    let st = state.into_inner();
    CgResult {
        iterations: st.iterations,
        converged: st.converged,
        final_relres: st.relres,
        residual_history: st.history,
        times: st.times,
        solve_seconds: start.elapsed().as_secs_f64(),
        breakdown: st.breakdown,
    }
}

/// Read-only view of a region-shared vector for the current phase.
///
/// # Safety
/// Phase discipline: the pointee must not be written by any thread while
/// the view is in use, and all prior writes must be separated from this
/// read by a [`Pool::phase_barrier`].
#[inline]
unsafe fn view<'s>(s: &'s SyncSlice<'_, f64>, n: usize) -> &'s [f64] {
    debug_assert_eq!(s.len(), n);
    std::slice::from_raw_parts(s.as_ptr(), n)
}

/// The SPMD region body: every thread executes this with the same control
/// flow. All branching scalars (bnorm, pq, rr, rz, α, β) come out of
/// deterministic chunk-grid reductions, so each thread computes bitwise-
/// identical copies and the threads never diverge.
fn fused_worker(cx: &FusedCtx, tid: usize, nt: usize) {
    let pool = cx.pool;
    let n = cx.b.len();
    let nchunks = cx.nchunks;
    // This thread's share of the BLAS-1 chunk grid (reduction + element-
    // wise phases). SpMV uses its own nnz-balanced partition.
    let chunks = Pool::chunk(nchunks, tid, nt);
    let mut clock = Instant::now();
    // Flight-recorder span clock (ns since the recorder epoch at this
    // thread's last mark). Drain the pool's thread-local wait accumulator
    // first so nothing a previous job left behind pollutes the first span.
    let mut pclock = match cx.prof {
        Some(rec) => {
            pool.take_barrier_wait_ns();
            rec.now_ns()
        }
        None => 0,
    };

    // --- bnorm = ‖b‖ -----------------------------------------------------
    blas1::dot_partials(cx.b, cx.b, cx.partials, chunks.clone());
    pool.phase_barrier();
    let bnorm = blas1::combine_partials(cx.partials, nchunks).sqrt();
    mark(tid, cx.state, &mut clock, "blas1");
    prof_mark(cx.prof, pool, tid, &mut pclock, Phase::Blas1);
    if bnorm == 0.0 {
        blas1::fill_chunks(0.0, cx.xs, chunks.clone());
        if tid == 0 {
            // SAFETY: thread-0-only solo state.
            let st = unsafe { &mut *cx.state.as_ptr() };
            st.converged = true;
            st.relres = 0.0;
            st.iterations = 0;
        }
        return;
    }

    // --- r₀ = b − A x ----------------------------------------------------
    // SAFETY (this and every `view` below): phase discipline — the viewed
    // vector's last writes are behind a phase barrier and no thread writes
    // it during the view's phase.
    cx.spmv.worker(unsafe { view(cx.xs, n) }, cx.qs, cx.spmv_scratch, pool, tid, nt);
    pool.phase_barrier();
    mark(tid, cx.state, &mut clock, "spmv");
    prof_mark(cx.prof, pool, tid, &mut pclock, Phase::Spmv);
    blas1::residual_chunks(cx.b, unsafe { view(cx.qs, n) }, cx.rs, chunks.clone());
    pool.phase_barrier();
    mark(tid, cx.state, &mut clock, "blas1");
    prof_mark(cx.prof, pool, tid, &mut pclock, Phase::Blas1);

    // --- z₀ = M⁻¹ r₀, p₀ = z₀, rz = r·z, relres₀ = ‖r‖/‖b‖ ---------------
    cx.tri.forward_worker(unsafe { view(cx.rs, n) }, cx.ss, pool, tid, nt);
    pool.phase_barrier();
    prof_mark(cx.prof, pool, tid, &mut pclock, Phase::TrisolveFwd);
    cx.tri.backward_worker(unsafe { view(cx.ss, n) }, cx.zs, pool, tid, nt);
    pool.phase_barrier();
    mark(tid, cx.state, &mut clock, "trisolve");
    prof_mark(cx.prof, pool, tid, &mut pclock, Phase::TrisolveBwd);
    let (r_view, z_view) = unsafe { (view(cx.rs, n), view(cx.zs, n)) };
    blas1::copy_chunks(z_view, cx.ps, chunks.clone());
    blas1::dot_partials(r_view, z_view, cx.partials, chunks.clone());
    blas1::dot_partials(r_view, r_view, cx.partials2, chunks.clone());
    pool.phase_barrier();
    let mut rz = blas1::combine_partials(cx.partials, nchunks);
    let mut relres = blas1::combine_partials(cx.partials2, nchunks).sqrt() / bnorm;
    // Both partials buffers were just combined; the first loop iteration
    // writes `partials` again, so fence the stragglers' combines off.
    pool.phase_barrier();
    mark(tid, cx.state, &mut clock, "blas1");
    prof_mark(cx.prof, pool, tid, &mut pclock, Phase::Blas1);
    // Poisoned input (NaN b/x₀/factor): every thread sees the same
    // non-finite rz and returns in lockstep (`rz = 0` stays legal — an
    // exact initial guess has r = 0). Mirrors `pcg` exactly.
    if !rz.is_finite() {
        if tid == 0 {
            // SAFETY: thread-0-only solo state.
            let st = unsafe { &mut *cx.state.as_ptr() };
            st.relres = relres;
            st.breakdown = Some(CgBreakdown { iter: 0, quantity: "rz" });
        }
        return;
    }

    let mut iters = 0usize;
    let mut converged = false;

    while iters < cx.max_iters {
        iters += 1;

        // --- q = A p (+ p·q partials) ------------------------------------
        let p_view = unsafe { view(cx.ps, n) };
        cx.spmv.worker(p_view, cx.qs, cx.spmv_scratch, pool, tid, nt);
        match cx.spmv.owned_chunks(tid) {
            Some(own) => {
                // CRS: splits are chunk-aligned, so the p·q partials can be
                // formed in the same sweep, over cache-hot q, pre-barrier
                // (this thread reads only the q rows it just wrote). That
                // in-sweep dot is billed to "spmv" — it genuinely rides
                // the sweep; the combine below goes to "blas1" like the
                // legacy loop's dot.
                blas1::dot_partials(p_view, unsafe { view(cx.qs, n) }, cx.partials, own);
                pool.phase_barrier();
                mark(tid, cx.state, &mut clock, "spmv");
                prof_mark(cx.prof, pool, tid, &mut pclock, Phase::Spmv);
            }
            None => {
                // SELL (σ-sorting may scatter rows) and the symmetric
                // engine (scatters by construction): publish q first.
                pool.phase_barrier();
                mark(tid, cx.state, &mut clock, "spmv");
                prof_mark(cx.prof, pool, tid, &mut pclock, Phase::Spmv);
                blas1::dot_partials(
                    p_view,
                    unsafe { view(cx.qs, n) },
                    cx.partials,
                    chunks.clone(),
                );
                pool.phase_barrier();
            }
        }
        let pq = blas1::combine_partials(cx.partials, nchunks);
        mark(tid, cx.state, &mut clock, "blas1");
        prof_mark(cx.prof, pool, tid, &mut pclock, Phase::Blas1);
        if pq <= 0.0 || !pq.is_finite() {
            // Non-SPD or breakdown; every thread sees the same pq and
            // breaks identically (recorded, reported as divergence, like
            // `pcg`).
            if tid == 0 {
                // SAFETY: thread-0-only solo state.
                unsafe {
                    (*cx.state.as_ptr()).breakdown =
                        Some(CgBreakdown { iter: iters, quantity: "pq" });
                }
            }
            break;
        }
        let alpha = rz / pq;

        // --- x += α p; r −= α q; rr = ‖r‖² -------------------------------
        // `partials2`: a straggler may still be combining p·q from
        // `partials` (see the FusedCtx buffer-discipline note).
        blas1::fused_update_partials(
            alpha,
            p_view,
            unsafe { view(cx.qs, n) },
            cx.xs,
            cx.rs,
            cx.partials2,
            chunks.clone(),
        );
        pool.phase_barrier();
        let rr = blas1::combine_partials(cx.partials2, nchunks);
        relres = rr.sqrt() / bnorm;
        if cx.record_history && tid == 0 {
            // SAFETY: thread-0-only solo state.
            unsafe { (*cx.state.as_ptr()).history.push(relres) };
        }
        mark(tid, cx.state, &mut clock, "blas1");
        prof_mark(cx.prof, pool, tid, &mut pclock, Phase::Blas1);
        if relres < cx.rtol {
            converged = true;
            break;
        }

        // --- z = M⁻¹ r ---------------------------------------------------
        cx.tri.forward_worker(unsafe { view(cx.rs, n) }, cx.ss, pool, tid, nt);
        pool.phase_barrier();
        prof_mark(cx.prof, pool, tid, &mut pclock, Phase::TrisolveFwd);
        cx.tri.backward_worker(unsafe { view(cx.ss, n) }, cx.zs, pool, tid, nt);
        pool.phase_barrier();
        mark(tid, cx.state, &mut clock, "trisolve");
        prof_mark(cx.prof, pool, tid, &mut pclock, Phase::TrisolveBwd);

        // --- β = (r·z)new / (r·z)old; p = z + β p ------------------------
        let (r_view, z_view) = unsafe { (view(cx.rs, n), view(cx.zs, n)) };
        blas1::dot_partials(r_view, z_view, cx.partials, chunks.clone());
        pool.phase_barrier();
        let rz_new = blas1::combine_partials(cx.partials, nchunks);
        // r ≠ 0 here (relres ≥ rtol above): rz ≤ 0 is as broken as NaN.
        // Same combined value on every thread ⇒ lockstep break.
        if !rz_new.is_finite() || rz_new <= 0.0 {
            if tid == 0 {
                // SAFETY: thread-0-only solo state.
                unsafe {
                    (*cx.state.as_ptr()).breakdown =
                        Some(CgBreakdown { iter: iters, quantity: "rz" });
                }
            }
            break;
        }
        let beta = rz_new / rz;
        rz = rz_new;
        blas1::xpby_chunks(z_view, beta, cx.ps, chunks.clone());
        // p must be fully published before the next iteration's SpMV.
        pool.phase_barrier();
        mark(tid, cx.state, &mut clock, "blas1");
        prof_mark(cx.prof, pool, tid, &mut pclock, Phase::Blas1);
    }

    if tid == 0 {
        // SAFETY: thread-0-only solo state.
        let st = unsafe { &mut *cx.state.as_ptr() };
        st.iterations = iters;
        st.converged = converged;
        st.relres = relres;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::csr::Csr;

    fn laplace2d(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    #[test]
    fn plain_cg_solves_laplace() {
        let a = laplace2d(12, 12);
        let n = a.n();
        let xstar = vec![1.0; n];
        let mut b = vec![0.0; n];
        a.mul_vec(&xstar, &mut b);
        let mut x = vec![0.0; n];
        let res = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| z.copy_from_slice(r),
            &b,
            &mut x,
            1e-10,
            1000,
            true,
        );
        assert!(res.converged, "relres={}", res.final_relres);
        assert!(crate::util::max_abs_diff(&x, &xstar) < 1e-7);
        assert_eq!(res.residual_history.len(), res.iterations);
        // History is the recorded relres sequence ending below rtol.
        assert!(*res.residual_history.last().unwrap() < 1e-10);
    }

    #[test]
    fn ic_preconditioner_reduces_iterations() {
        use crate::factor::ic0::ic0;
        use crate::factor::split::TriFactors;
        use crate::solver::trisolve_serial;
        let a = laplace2d(20, 20);
        let n = a.n();
        let b = vec![1.0; n];
        let tri = TriFactors::from_ic(&ic0(&a, 0.0).unwrap());
        let mut scratch = vec![0.0; n];

        let mut x0 = vec![0.0; n];
        let plain = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| z.copy_from_slice(r),
            &b,
            &mut x0,
            1e-8,
            5000,
            false,
        );
        let mut x1 = vec![0.0; n];
        let ic = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| trisolve_serial::apply(&tri, r, &mut scratch, z),
            &b,
            &mut x1,
            1e-8,
            5000,
            false,
        );
        assert!(plain.converged && ic.converged);
        assert!(
            ic.iterations < plain.iterations,
            "IC {} vs plain {}",
            ic.iterations,
            plain.iterations
        );
        assert!(crate::util::max_abs_diff(&x0, &x1) < 1e-5);
    }

    #[test]
    fn zero_rhs_is_trivial() {
        let a = laplace2d(4, 4);
        let mut x = vec![5.0; 16];
        let res = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| z.copy_from_slice(r),
            &vec![0.0; 16],
            &mut x,
            1e-8,
            100,
            false,
        );
        assert!(res.converged);
        assert_eq!(res.iterations, 0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn fused_loop_matches_legacy_bitwise_with_identity_precond() {
        use crate::coordinator::pool::Pool;
        use crate::solver::trisolve::IdentityPrecond;

        let a = laplace2d(20, 17);
        let n = a.n();
        let xstar: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.1).sin()).collect();
        let mut b = vec![0.0; n];
        a.mul_vec(&xstar, &mut b);

        // Legacy per-kernel loop, identity preconditioner.
        let mut x_ref = vec![0.0; n];
        let legacy = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| z.copy_from_slice(r),
            &b,
            &mut x_ref,
            1e-9,
            2000,
            true,
        );
        assert!(legacy.converged);

        let tri = IdentityPrecond;
        for nt in [1usize, 4] {
            let pool = Pool::new(nt);
            let engine = SpmvEngine::crs(&a, nt);
            let mut x = vec![0.0; n];
            let fused = pcg_fused(&engine, &tri, &b, &mut x, 1e-9, 2000, true, &pool, None);
            assert_eq!(fused.iterations, legacy.iterations, "nt={nt}");
            assert_eq!(fused.converged, legacy.converged);
            assert_eq!(fused.final_relres.to_bits(), legacy.final_relres.to_bits());
            assert_eq!(fused.residual_history.len(), legacy.residual_history.len());
            for (f, l) in fused.residual_history.iter().zip(&legacy.residual_history) {
                assert_eq!(f.to_bits(), l.to_bits(), "history diverged at nt={nt}");
            }
            assert!(x.iter().zip(&x_ref).all(|(xa, xb)| xa.to_bits() == xb.to_bits()));
        }
    }

    #[test]
    fn fused_loop_zero_rhs_is_trivial() {
        use crate::coordinator::pool::Pool;
        use crate::solver::trisolve::IdentityPrecond;
        let a = laplace2d(5, 5);
        let pool = Pool::new(2);
        let engine = SpmvEngine::crs(&a, 2);
        let mut x = vec![7.0; 25];
        let res = pcg_fused(
            &engine,
            &IdentityPrecond,
            &vec![0.0; 25],
            &mut x,
            1e-8,
            100,
            false,
            &pool,
            None,
        );
        assert!(res.converged);
        assert_eq!(res.iterations, 0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn nan_rhs_is_a_recorded_breakdown_in_both_loops() {
        use crate::coordinator::pool::Pool;
        use crate::solver::trisolve::IdentityPrecond;
        let a = laplace2d(6, 6);
        let n = a.n();
        let mut b = vec![1.0; n];
        b[3] = f64::NAN;

        let mut x = vec![0.0; n];
        let legacy = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| z.copy_from_slice(r),
            &b,
            &mut x,
            1e-8,
            100,
            false,
        );
        assert!(!legacy.converged);
        assert_eq!(legacy.breakdown, Some(CgBreakdown { iter: 0, quantity: "rz" }));
        assert_eq!(legacy.iterations, 0, "must not iterate on NaNs");

        for nt in [1usize, 3] {
            let pool = Pool::new(nt);
            let engine = SpmvEngine::crs(&a, nt);
            let mut x = vec![0.0; n];
            let fused =
                pcg_fused(&engine, &IdentityPrecond, &b, &mut x, 1e-8, 100, false, &pool, None);
            assert_eq!(fused.breakdown, legacy.breakdown, "nt={nt}");
            assert_eq!(fused.iterations, 0);
            assert!(!fused.converged);
        }
    }

    #[test]
    fn indefinite_matrix_records_pq_breakdown() {
        // -A is negative definite: the very first curvature p·Ap is < 0.
        let a = laplace2d(5, 5);
        let n = a.n();
        let b = vec![1.0; n];
        let mut x = vec![0.0; n];
        let res = pcg(
            &mut |v, y, _| {
                a.mul_vec(v, y);
                y.iter_mut().for_each(|e| *e = -*e);
            },
            &mut |r, z, _| z.copy_from_slice(r),
            &b,
            &mut x,
            1e-8,
            100,
            false,
        );
        assert!(!res.converged);
        assert_eq!(res.breakdown, Some(CgBreakdown { iter: 1, quantity: "pq" }));
    }

    #[test]
    fn clean_solves_report_no_breakdown() {
        let a = laplace2d(8, 8);
        let n = a.n();
        let b = vec![1.0; n];
        let mut x = vec![0.0; n];
        let res = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| z.copy_from_slice(r),
            &b,
            &mut x,
            1e-8,
            1000,
            false,
        );
        assert!(res.converged);
        assert_eq!(res.breakdown, None);
    }

    #[test]
    fn max_iters_respected() {
        let a = laplace2d(16, 16);
        let n = a.n();
        let b = vec![1.0; n];
        let mut x = vec![0.0; n];
        let res = pcg(
            &mut |v, y, _| a.mul_vec(v, y),
            &mut |r, z, _| z.copy_from_slice(r),
            &b,
            &mut x,
            1e-14,
            3,
            false,
        );
        assert!(!res.converged);
        assert_eq!(res.iterations, 3);
    }
}
