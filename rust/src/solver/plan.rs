//! Phase 1 of the two-phase solver: [`SolverPlan`] — the immutable product
//! of setup (permutation, permuted CSR, IC(0) factors, SELL structures,
//! selected kernel path) for one (matrix, configuration) pair.
//!
//! The paper's premise is that HBMC's reordering + factorization cost is
//! amortized over many triangular sweeps; a plan is the unit of that
//! amortization. Build it once with [`SolverPlan::build`], then run
//! arbitrarily many right-hand sides through [`SolverPlan::execute`] (or,
//! one level up, through a [`SolveSession`](crate::coordinator::session::SolveSession),
//! which owns the thread pool and the reporting).
//!
//! Plans are `Send + Sync` and typically shared behind an `Arc` — the
//! coordinator's `PlanCache` hands the same plan to any number of
//! sessions.

use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::Arc;
use std::time::Instant;

use crate::config::{OrderingKind, SolverConfig, SpmvKind};
use crate::coordinator::metrics::{per_iteration_ops, OpInputs, OpProfile};
use crate::coordinator::pool::Pool;
use crate::error::{HbmcError, Result};
use crate::factor::ic0::ic0_auto_with;
use crate::obs::flight::{FlightRecorder, PhaseProfile};
use crate::factor::split::{SellTriFactors, TriFactors};
use crate::ordering::perm::Perm;
use crate::ordering::{order_matrix, OrderedStructure};
use crate::resil::FaultInjector;
use crate::schedule::coarsen::{coarsen, CoarsenParams};
use crate::schedule::cost::ScheduleCost;
use crate::schedule::levels::LevelSchedule;
use crate::solver::cg::{pcg, pcg_fused, CgResult};
use crate::solver::spmv::{spmv_crs_with, spmv_sell, spmv_symm, RowSplits, SpmvEngine, SymmSpmv};
use crate::solver::trisolve::{
    BmcTriSolver, HbmcTriSolver, McTriSolver, SerialTriSolver, TriSolver,
};
use crate::solver::trisolve_hbmc::{select_path, HbmcMeta};
use crate::solver::trisolve_level::LevelTriSolver;
use crate::sparse::csr::Csr;
use crate::sparse::sell::Sell;

/// Process-wide count of plan constructions — lets tests and the serving
/// layer assert amortization ("8 solves, exactly one setup").
static PLAN_BUILDS: AtomicU64 = AtomicU64::new(0);

/// Number of [`SolverPlan::build`] calls since process start.
pub fn plans_built() -> u64 {
    PLAN_BUILDS.load(AtomicOrdering::SeqCst)
}

/// Setup-phase statistics (per-plan; reported once, not per solve).
#[derive(Debug, Clone)]
pub struct SetupStats {
    pub ordering_seconds: f64,
    pub factor_seconds: f64,
    /// SELL conversions + solver-structure assembly.
    pub storage_seconds: f64,
    pub num_colors: usize,
    pub n_orig: usize,
    /// Augmented dimension (≥ n_orig; includes HBMC/BMC dummy unknowns).
    pub n_aug: usize,
    pub nnz: usize,
    /// Stored elements of the SpMV matrix in its chosen format.
    pub spmv_elements: usize,
    /// Stored elements of the substitution triangles in their chosen format.
    pub tri_elements: usize,
    /// Shift actually used by the factorization (≥ requested on auto-retry).
    pub shift_used: f64,
    /// Inner kernel selected for HBMC ("scalar", "avx2-w4", "avx512-w8").
    pub kernel_path: &'static str,
}

impl SetupStats {
    /// Total setup wall time (ordering + factorization + storage).
    pub fn setup_seconds(&self) -> f64 {
        self.ordering_seconds + self.factor_seconds + self.storage_seconds
    }
}

/// Per-solve execution options (everything else is baked into the plan).
#[derive(Debug, Clone, Default)]
pub struct ExecOptions {
    /// Record the per-iteration residual history (Fig. 5.1 data).
    pub record_history: bool,
    /// Override the plan's convergence tolerance for this solve.
    pub rtol: Option<f64>,
    /// Override the plan's iteration cap for this solve.
    pub max_iters: Option<usize>,
    /// Run the legacy per-kernel loop (3 pool dispatches per iteration,
    /// serial BLAS-1) instead of the fused single-dispatch region. The two
    /// paths are bitwise-identical (`tests/fused_parity.rs`); this exists
    /// as the reference/fallback and for A/B benchmarking.
    pub legacy_loop: bool,
    /// Arm the in-region flight recorder (fused path only): per-thread
    /// phase spans + barrier-wait attribution come back on
    /// [`SolveOutcome::profile`]. Numerically inert — profiled solves are
    /// bitwise identical to unprofiled ones (`tests/profiling.rs`) — and
    /// adds only clock reads at existing phase boundaries (< 5% wall
    /// overhead on the quick bench).
    pub profile: bool,
}

/// Solution + iteration data, mapped back to the original ordering.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    pub x: Vec<f64>,
    pub cg: CgResult,
    /// Thread synchronizations per substitution sweep (= n_c − 1).
    pub syncs_per_substitution: usize,
    /// `Pool::run` dispatches this solve performed (1 on the fused path,
    /// ~3 per iteration on the legacy path).
    pub dispatches: u64,
    /// Pool barrier synchronizations this solve performed (color barriers
    /// + fused-loop phase barriers).
    pub pool_syncs: u64,
    /// Drained flight-recorder profile when [`ExecOptions::profile`] was
    /// set (fused path only; the legacy path reports `None`).
    pub profile: Option<PhaseProfile>,
}

/// The immutable product of the setup phase; see module docs.
pub struct SolverPlan {
    pub cfg: SolverConfig,
    /// Fingerprint of the *original* matrix (plan-cache key component).
    pub matrix_fingerprint: u64,
    /// Original → internal (reordered, padded) permutation.
    pub perm: Perm,
    /// The reordered matrix.
    pub a_perm: Csr,
    /// SELL form of the reordered matrix when `cfg.spmv` is SELL.
    pub sell_a: Option<Sell>,
    /// Symmetric (diag + strict lower) operator with its conflict-free
    /// schedule when `cfg.spmv` is SymmCsr.
    pub symm_a: Option<SymmSpmv>,
    /// The ordering-specific substitution engine.
    pub trisolver: Arc<dyn TriSolver>,
    /// Precomputed nnz-balanced CRS row splits for `cfg.threads` (None for
    /// SELL SpMV). `execute` recomputes on the fly when it runs on a pool
    /// of a different width.
    pub crs_splits: Option<RowSplits>,
    /// Level-schedule shape and cost model (Some only for the level path).
    pub schedule: Option<ScheduleCost>,
    pub setup: SetupStats,
    /// Analytic per-iteration op profile (SIMD-ratio metric).
    pub ops: OpProfile,
}

impl SolverPlan {
    /// Run the full setup phase for matrix `a` under `cfg`: ordering →
    /// IC(0) factorization → storage construction → kernel selection.
    pub fn build(a: &Csr, cfg: &SolverConfig) -> Result<SolverPlan> {
        SolverPlan::build_with(a, cfg, None)
    }

    /// [`SolverPlan::build`] with a fault injector threaded into the
    /// factorization (chaos testing; see `crate::resil`). `None` is the
    /// production path and behaves exactly like `build`.
    pub fn build_with(
        a: &Csr,
        cfg: &SolverConfig,
        injector: Option<&FaultInjector>,
    ) -> Result<SolverPlan> {
        cfg.validate()?;
        let n_orig = a.n();
        let matrix_fingerprint = a.fingerprint();

        // --- Ordering ---------------------------------------------------
        let t0 = Instant::now();
        let ordering = order_matrix(a, cfg.ordering, cfg.bs, cfg.w);
        let a_perm = a.permute_sym(&ordering.perm);
        let ordering_seconds = t0.elapsed().as_secs_f64();

        // --- Factorization ----------------------------------------------
        let t1 = Instant::now();
        let factor = ic0_auto_with(&a_perm, cfg.shift, injector)?;
        let shift_used = factor.shift;
        let tri = TriFactors::from_ic(&factor);
        let factor_seconds = t1.elapsed().as_secs_f64();

        // --- Solver storage ----------------------------------------------
        let t2 = Instant::now();
        let tri_nnz = tri.lower.nnz() + tri.upper.nnz();
        let mut schedule = None;
        let trisolver: Arc<dyn TriSolver> = match ordering.structure {
            OrderedStructure::Natural => Arc::new(SerialTriSolver::new(tri)),
            OrderedStructure::Mc { color_ptr } => Arc::new(McTriSolver::new(tri, color_ptr)),
            OrderedStructure::Bmc { color_ptr, bs } => {
                Arc::new(BmcTriSolver::new(tri, color_ptr, bs))
            }
            OrderedStructure::Hbmc(ord) => {
                let sell = SellTriFactors::from_tri(&tri, cfg.w);
                let path = select_path(cfg.w, cfg.use_intrinsics);
                Arc::new(HbmcTriSolver::new(HbmcMeta::from_ordering(&ord), sell, path))
            }
            OrderedStructure::Level => {
                let levels = LevelSchedule::build(&tri);
                let sched = coarsen(&levels, &tri, &CoarsenParams::default());
                schedule = Some(ScheduleCost::analyze(&levels, &sched, &tri));
                Arc::new(LevelTriSolver::new(tri, sched))
            }
        };

        let sell_a = match cfg.spmv {
            SpmvKind::Crs | SpmvKind::SymmCsr => None,
            SpmvKind::Sell => Some(match cfg.sell_sigma {
                Some(sigma) => Sell::from_csr_sigma(&a_perm, cfg.w, sigma),
                None => Sell::from_csr(&a_perm, cfg.w),
            }),
        };
        // `permute_sym` relocates values without rewriting them, so an
        // exactly-symmetric input stays exactly symmetric; an asymmetric
        // matrix surfaces here as a typed `InvalidConfig`.
        let symm_a = match cfg.spmv {
            SpmvKind::SymmCsr => Some(SymmSpmv::build(&a_perm)?),
            _ => None,
        };
        let spmv_elements = match (&sell_a, &symm_a) {
            (Some(s), _) => s.stored_elements(),
            (None, Some(sy)) => sy.matrix().stored_elements(),
            (None, None) => a_perm.nnz(),
        };
        let crs_splits = match cfg.spmv {
            SpmvKind::Crs => Some(RowSplits::balanced(a_perm.row_ptr(), cfg.threads)),
            SpmvKind::Sell | SpmvKind::SymmCsr => None,
        };
        let storage_seconds = t2.elapsed().as_secs_f64();

        let setup = SetupStats {
            ordering_seconds,
            factor_seconds,
            storage_seconds,
            // Barrier-separated substitution stages: the ordering's color
            // count for the reordering paths, the coarsened stage count
            // for the level path (whose ordering-side num_colors is 1).
            num_colors: trisolver.num_colors(),
            n_orig,
            n_aug: a_perm.n(),
            nnz: a_perm.nnz(),
            spmv_elements,
            tri_elements: trisolver.tri_elements(),
            shift_used,
            kernel_path: trisolver.kernel_path(),
        };

        let ops = per_iteration_ops(
            cfg,
            &OpInputs {
                n: a_perm.n(),
                nnz: a_perm.nnz(),
                tri_nnz,
                sell_tri_elements: matches!(cfg.ordering, OrderingKind::Hbmc)
                    .then(|| trisolver.tri_elements()),
                sell_a_elements: sell_a.as_ref().map(|s| s.stored_elements()),
            },
        );

        PLAN_BUILDS.fetch_add(1, AtomicOrdering::SeqCst);
        Ok(SolverPlan {
            cfg: cfg.clone(),
            matrix_fingerprint,
            perm: ordering.perm,
            a_perm,
            sell_a,
            symm_a,
            trisolver,
            crs_splits,
            schedule,
            setup,
            ops,
        })
    }

    /// Original problem dimension.
    pub fn n_orig(&self) -> usize {
        self.setup.n_orig
    }

    /// Augmented (internal) dimension.
    pub fn n_aug(&self) -> usize {
        self.a_perm.n()
    }

    /// SELL processed-element overhead vs CRS nnz (§5.2.2), if SELL used.
    pub fn sell_overhead(&self) -> Option<f64> {
        match self.cfg.spmv {
            SpmvKind::Sell => Some(self.setup.spmv_elements as f64 / self.setup.nnz as f64),
            SpmvKind::Crs | SpmvKind::SymmCsr => None,
        }
    }

    /// Apply the preconditioner in the *internal* ordering (tests, hybrid
    /// PJRT cross-checks).
    pub fn apply_precond_internal(&self, r: &[f64], z: &mut [f64], pool: &Pool) {
        let mut scratch = vec![0.0; self.n_aug()];
        self.trisolver.apply(r, &mut scratch, z, pool);
    }

    /// Phase 2: solve `A x = b` (original ordering, `b.len() == n_orig`)
    /// on a caller-provided pool. Everything allocated here is per-solve;
    /// the plan itself is never mutated, so concurrent `execute` calls on
    /// distinct pools are safe.
    ///
    /// Default path: the fused single-dispatch loop — **one** `Pool::run`
    /// for the whole solve ([`pcg_fused`]). Set
    /// [`ExecOptions::legacy_loop`] for the per-kernel reference path; both
    /// produce bitwise-identical results.
    pub fn execute(&self, pool: &Pool, b: &[f64], opts: &ExecOptions) -> Result<SolveOutcome> {
        if b.len() != self.setup.n_orig {
            return Err(HbmcError::DimensionMismatch {
                expected: self.setup.n_orig,
                got: b.len(),
            });
        }
        let n = self.n_aug();
        let b_perm = self.perm.apply_vec(b, 0.0);
        let mut x_perm = vec![0.0f64; n];

        let a_perm = &self.a_perm;
        let sell_a = &self.sell_a;
        let symm_a = &self.symm_a;
        let trisolver = &self.trisolver;
        pool.reset_sync_count();
        let dispatches_before = pool.dispatch_count();
        let rtol = opts.rtol.unwrap_or(self.cfg.rtol);
        let max_iters = opts.max_iters.unwrap_or(self.cfg.max_iters);
        let mut profile = None;

        let cg = if opts.legacy_loop {
            let mut scratch = vec![0.0f64; n];
            let splits;
            let needs_crs = sell_a.is_none() && symm_a.is_none();
            let crs_splits = match (&self.crs_splits, needs_crs) {
                (Some(sp), true) if sp.nt() == pool.nthreads() => Some(sp),
                (_, true) => {
                    splits = RowSplits::balanced(a_perm.row_ptr(), pool.nthreads());
                    Some(&splits)
                }
                _ => None,
            };
            let mut spmv =
                |x: &[f64], y: &mut [f64], times: &mut crate::util::timer::KernelTimes| {
                    let t = Instant::now();
                    match (sell_a, symm_a) {
                        (Some(s), _) => spmv_sell(s, x, y, pool),
                        (None, Some(sy)) => spmv_symm(sy, x, y, pool),
                        (None, None) => spmv_crs_with(a_perm, x, y, pool, crs_splits.unwrap()),
                    }
                    times.add("spmv", t.elapsed());
                };
            let mut prec = |r: &[f64], z: &mut [f64], times: &mut crate::util::timer::KernelTimes| {
                let t = Instant::now();
                trisolver.apply(r, &mut scratch, z, pool);
                times.add("trisolve", t.elapsed());
            };
            pcg(
                &mut spmv,
                &mut prec,
                &b_perm,
                &mut x_perm,
                rtol,
                max_iters,
                opts.record_history,
            )
        } else {
            let engine = if let Some(sy) = symm_a {
                SpmvEngine::symm(sy)
            } else if let Some(s) = sell_a {
                SpmvEngine::sell(s)
            } else {
                match &self.crs_splits {
                    Some(sp) if sp.nt() == pool.nthreads() => {
                        SpmvEngine::crs_with(a_perm, sp.clone())
                    }
                    _ => SpmvEngine::crs(a_perm, pool.nthreads()),
                }
            };
            // Flight recorder: ~6 spans per thread per iteration; 8 leaves
            // headroom, the cap bounds a pathological `max_iters` at a few
            // MB per thread (overflow folds into exact aggregates).
            let recorder = opts.profile.then(|| {
                pool.set_profiling(true);
                FlightRecorder::new(pool.nthreads(), (8 * (max_iters + 2) + 16).min(1 << 18))
            });
            let cg = pcg_fused(
                &engine,
                trisolver.as_ref(),
                &b_perm,
                &mut x_perm,
                rtol,
                max_iters,
                opts.record_history,
                pool,
                recorder.as_ref(),
            );
            if let Some(rec) = recorder {
                pool.set_profiling(false);
                profile = Some(rec.into_profile(cg.solve_seconds));
            }
            cg
        };

        // A recorded CG breakdown (non-finite or non-positive reduction
        // quantity — NaN rhs, indefinite operator, poisoned factor) is a
        // typed failure, not a "did not converge" report: the iterate is
        // not trustworthy, and the dispatcher's recovery ladder keys on
        // the error variant.
        if let Some(bd) = cg.breakdown {
            return Err(HbmcError::BreakdownInIteration { iter: bd.iter, quantity: bd.quantity });
        }

        let x = self.perm.unapply_vec(&x_perm);
        Ok(SolveOutcome {
            x,
            cg,
            syncs_per_substitution: self.trisolver.syncs_per_sweep(),
            dispatches: pool.dispatch_count() - dispatches_before,
            pool_syncs: pool.sync_count(),
            profile,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn laplace2d(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    fn rhs_for_ones(a: &Csr) -> Vec<f64> {
        let mut b = vec![0.0; a.n()];
        a.mul_vec(&vec![1.0; a.n()], &mut b);
        b
    }

    #[test]
    fn build_populates_setup_and_counts_builds() {
        let a = laplace2d(12, 12);
        let cfg = SolverConfig { ordering: OrderingKind::Hbmc, bs: 4, w: 4, ..Default::default() };
        let before = plans_built();
        let plan = SolverPlan::build(&a, &cfg).unwrap();
        assert_eq!(plans_built(), before + 1);
        assert_eq!(plan.n_orig(), 144);
        assert!(plan.n_aug() >= 144);
        assert!(plan.setup.num_colors >= 2);
        assert!(plan.setup.tri_elements > 0);
        assert!(plan.setup.setup_seconds() > 0.0);
        assert!(plan.ops.simd_ratio() > 0.0);
        assert_ne!(plan.setup.kernel_path, "n/a");
        assert_eq!(plan.matrix_fingerprint, a.fingerprint());
    }

    #[test]
    fn one_plan_serves_many_rhs() {
        let a = laplace2d(16, 12);
        let cfg = SolverConfig {
            ordering: OrderingKind::Bmc,
            bs: 4,
            w: 4,
            spmv: SpmvKind::Crs,
            rtol: 1e-9,
            ..Default::default()
        };
        let plan = SolverPlan::build(&a, &cfg).unwrap();
        let pool = Pool::new(1);
        let b = rhs_for_ones(&a);
        let o1 = plan.execute(&pool, &b, &ExecOptions::default()).unwrap();
        assert!(o1.cg.converged);
        assert!(crate::util::max_abs_diff(&o1.x, &vec![1.0; a.n()]) < 1e-6);
        // Scaled rhs → scaled solution, same plan, no rebuild.
        let before = plans_built();
        let b3: Vec<f64> = b.iter().map(|v| 3.0 * v).collect();
        let o3 = plan.execute(&pool, &b3, &ExecOptions::default()).unwrap();
        assert_eq!(plans_built(), before);
        assert!(crate::util::max_abs_diff(&o3.x, &vec![3.0; a.n()]) < 1e-5);
    }

    #[test]
    fn exec_options_override_tolerances() {
        let a = laplace2d(14, 14);
        let cfg = SolverConfig {
            ordering: OrderingKind::Hbmc,
            bs: 4,
            w: 4,
            rtol: 1e-10,
            ..Default::default()
        };
        let plan = SolverPlan::build(&a, &cfg).unwrap();
        let pool = Pool::new(1);
        let b = rhs_for_ones(&a);
        let strict = plan.execute(&pool, &b, &ExecOptions::default()).unwrap();
        let loose = plan
            .execute(&pool, &b, &ExecOptions { rtol: Some(1e-3), ..Default::default() })
            .unwrap();
        assert!(loose.cg.iterations < strict.cg.iterations);
        let capped = plan
            .execute(&pool, &b, &ExecOptions { max_iters: Some(2), ..Default::default() })
            .unwrap();
        assert_eq!(capped.cg.iterations, 2);
        assert!(!capped.cg.converged);
    }

    #[test]
    fn level_plan_carries_schedule_cost_and_solves() {
        let a = laplace2d(16, 12);
        let cfg = SolverConfig {
            ordering: OrderingKind::Level,
            spmv: SpmvKind::Crs,
            rtol: 1e-9,
            ..Default::default()
        };
        let plan = SolverPlan::build(&a, &cfg).unwrap();
        assert_eq!(plan.trisolver.name(), "ic0-level");
        assert!(plan.perm.is_identity());
        assert_eq!(plan.n_aug(), plan.n_orig());
        let sched = plan.schedule.as_ref().expect("level plan exposes its cost model");
        assert_eq!(plan.setup.num_colors, sched.coarsened_stages);
        assert_eq!(plan.trisolver.syncs_per_sweep(), sched.predicted_syncs_per_sweep);
        let pool = Pool::new(2);
        let b = rhs_for_ones(&a);
        let o = plan.execute(&pool, &b, &ExecOptions::default()).unwrap();
        assert!(o.cg.converged);
        assert!(crate::util::max_abs_diff(&o.x, &vec![1.0; a.n()]) < 1e-6);
        assert_eq!(o.dispatches, 1);
        // Reordering paths carry no schedule.
        let plan = SolverPlan::build(&a, &SolverConfig::default()).unwrap();
        assert!(plan.schedule.is_none());
    }

    #[test]
    fn execute_rejects_wrong_rhs_dimension() {
        let a = laplace2d(8, 8);
        let plan = SolverPlan::build(&a, &SolverConfig::default()).unwrap();
        let pool = Pool::new(1);
        assert!(plan.execute(&pool, &[1.0, 2.0], &ExecOptions::default()).is_err());
    }
}
