//! Dense vector (BLAS-1) kernels for the CG iteration — the
//! straightforwardly-parallel parts of the solver (paper §2). Since the
//! single-dispatch CG redesign they run **inside the persistent pool
//! region**, chunk-partitioned across threads, written as contiguous loops
//! the compiler auto-vectorizes (they count as *packed* ops in the SIMD
//! ratio metric, matching how VTune attributes them in §5.2.1).
//!
//! # Deterministic reductions
//!
//! Every reduction (`dot`, `norm2`, the `‖r‖²` of [`fused_cg_update`]) is
//! defined over a **fixed chunk grid**: the vector is cut into
//! [`CHUNK`]-sized chunks, each chunk is reduced by one canonical kernel
//! (`chunk_dot` — 4-way unrolled — or the sequential fused-update
//! kernel), and the per-chunk partials are combined **left-to-right in
//! chunk order**. Because the grid depends only on `n`, the result is
//! bitwise identical whether the chunks are walked by one thread (the
//! serial entry points below) or partitioned across any number of pool
//! workers (the `*_partials` variants + [`combine_partials`]): thread
//! count, thread scheduling and run-to-run ordering cannot change a single
//! bit. This is what lets the fused single-dispatch CG loop reproduce the
//! legacy per-kernel path exactly (see `tests/fused_parity.rs`).
//!
//! The elementwise kernels (`axpy`, `xpby`, updates) have no reduction and
//! are trivially partition-invariant; their chunked variants use the same
//! per-element expressions as the serial ones.

use crate::coordinator::pool::SyncSlice;
use std::ops::Range;

/// Reduction chunk size (elements). Fixed so that reduction results are
/// independent of the thread partitioning (see module docs). A multiple of
/// every supported SIMD width `w ∈ {2, 4, 8, 16}` and of the SELL chunk
/// sizes, so chunk-aligned row partitions stay SIMD-aligned too.
pub const CHUNK: usize = 1024;

/// Number of reduction chunks covering `0..n`.
#[inline]
pub fn num_chunks(n: usize) -> usize {
    n.div_ceil(CHUNK)
}

/// Element range of chunk `c` in a length-`n` vector (the last chunk may
/// be short).
#[inline]
pub fn chunk_range(c: usize, n: usize) -> Range<usize> {
    (c * CHUNK)..((c + 1) * CHUNK).min(n)
}

/// Canonical per-chunk dot kernel: 4-way unrolled reduction. Keeps the
/// dependency chain short so LLVM vectorizes; the fixed unroll order makes
/// the chunk partial a pure function of its elements.
#[inline]
fn chunk_dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4;
    for i in 0..chunks {
        let b = i * 4;
        acc[0] += x[b] * y[b];
        acc[1] += x[b + 1] * y[b + 1];
        acc[2] += x[b + 2] * y[b + 2];
        acc[3] += x[b + 3] * y[b + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// Canonical per-chunk fused-update kernel: `x += α p; r -= α q`; returns
/// the chunk's `‖r‖²` partial (sequential accumulation within the chunk).
#[inline]
fn chunk_fused_update(alpha: f64, p: &[f64], q: &[f64], x: &mut [f64], r: &mut [f64]) -> f64 {
    let mut rr = 0.0f64;
    for i in 0..p.len() {
        x[i] += alpha * p[i];
        let ri = r[i] - alpha * q[i];
        r[i] = ri;
        rr += ri * ri;
    }
    rr
}

/// `xᵀ y` — canonical chunked reduction (see module docs).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let mut s = 0.0f64;
    for c in 0..num_chunks(n) {
        let r = chunk_range(c, n);
        s += chunk_dot(&x[r.clone()], &y[r]);
    }
    s
}

/// `||x||₂`.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// `y += α x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = x + β y` (the CG `p` update).
#[inline]
pub fn xpby(x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = xi + beta * *yi;
    }
}

/// `y = x`.
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// Fused CG update: `x += α p; r -= α q;` returns `‖r‖²` (canonical
/// chunked reduction). One pass over four arrays instead of three passes
/// (the BLAS-1 share of an ICCG iteration is memory-bound).
#[inline]
pub fn fused_cg_update(alpha: f64, p: &[f64], q: &[f64], x: &mut [f64], r: &mut [f64]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    debug_assert_eq!(p.len(), x.len());
    debug_assert_eq!(p.len(), r.len());
    let n = p.len();
    let mut rr = 0.0f64;
    for c in 0..num_chunks(n) {
        let rng = chunk_range(c, n);
        rr += chunk_fused_update(
            alpha,
            &p[rng.clone()],
            &q[rng.clone()],
            &mut x[rng.clone()],
            &mut r[rng],
        );
    }
    rr
}

// ---------------------------------------------------------------------------
// In-region (tid, nt)-partitioned variants. Contract for all of them: the
// calling thread exclusively owns the chunk indices in `chunks` (use
// `Pool::chunk(num_chunks(n), tid, nt)`), read-only inputs are stable for
// the duration of the phase, and a pool barrier separates the partial
// writes from `combine_partials`.
// ---------------------------------------------------------------------------

/// Write the per-chunk partials of `xᵀ y` for the owned `chunks` into
/// `partials` (indexed by chunk).
pub fn dot_partials(x: &[f64], y: &[f64], partials: &SyncSlice<f64>, chunks: Range<usize>) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    for c in chunks {
        let r = chunk_range(c, n);
        // SAFETY: chunk `c` is owned by this thread (contract above).
        unsafe { partials.set(c, chunk_dot(&x[r.clone()], &y[r])) };
    }
}

/// Combine per-chunk partials left-to-right — the canonical reduction
/// order. Every thread calls this redundantly after the barrier and gets
/// the identical (bitwise) scalar; no broadcast needed.
pub fn combine_partials(partials: &SyncSlice<f64>, nchunks: usize) -> f64 {
    let mut s = 0.0f64;
    for c in 0..nchunks {
        // SAFETY: all partials were published by the preceding barrier.
        s += unsafe { partials.get(c) };
    }
    s
}

/// Chunked fused CG update: `x += α p; r -= α q` over the owned chunks,
/// writing each chunk's `‖r‖²` partial. Bitwise-matches
/// [`fused_cg_update`] once combined.
pub fn fused_update_partials(
    alpha: f64,
    p: &[f64],
    q: &[f64],
    x: &SyncSlice<f64>,
    r: &SyncSlice<f64>,
    partials: &SyncSlice<f64>,
    chunks: Range<usize>,
) {
    debug_assert_eq!(p.len(), q.len());
    let n = p.len();
    for c in chunks {
        let rng = chunk_range(c, n);
        let len = rng.len();
        // SAFETY: chunk `c` (and hence these element ranges of x, r and
        // partials) is owned exclusively by this thread.
        let (xc, rc) = unsafe {
            (
                std::slice::from_raw_parts_mut(x.as_mut_ptr().add(rng.start), len),
                std::slice::from_raw_parts_mut(r.as_mut_ptr().add(rng.start), len),
            )
        };
        let pr = chunk_fused_update(alpha, &p[rng.clone()], &q[rng], xc, rc);
        unsafe { partials.set(c, pr) };
    }
}

/// Chunked `p = z + β p` (same per-element expression as [`xpby`]).
pub fn xpby_chunks(z: &[f64], beta: f64, p: &SyncSlice<f64>, chunks: Range<usize>) {
    let n = z.len();
    for c in chunks {
        for i in chunk_range(c, n) {
            // SAFETY: chunk owned by this thread.
            unsafe { p.set(i, z[i] + beta * p.get(i)) };
        }
    }
}

/// Chunked residual `r = b − q`.
pub fn residual_chunks(b: &[f64], q: &[f64], r: &SyncSlice<f64>, chunks: Range<usize>) {
    debug_assert_eq!(b.len(), q.len());
    let n = b.len();
    for c in chunks {
        for i in chunk_range(c, n) {
            // SAFETY: chunk owned by this thread.
            unsafe { r.set(i, b[i] - q[i]) };
        }
    }
}

/// Chunked copy `dst = src`.
pub fn copy_chunks(src: &[f64], dst: &SyncSlice<f64>, chunks: Range<usize>) {
    let n = src.len();
    for c in chunks {
        for i in chunk_range(c, n) {
            // SAFETY: chunk owned by this thread.
            unsafe { dst.set(i, src[i]) };
        }
    }
}

/// Chunked fill `dst = v`.
pub fn fill_chunks(v: f64, dst: &SyncSlice<f64>, chunks: Range<usize>) {
    let n = dst.len();
    for c in chunks {
        for i in chunk_range(c, n) {
            // SAFETY: chunk owned by this thread.
            unsafe { dst.set(i, v) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::Pool;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..101).map(|i| i as f64 * 0.25).collect();
        let y: Vec<f64> = (0..101).map(|i| 1.0 - i as f64 * 0.5).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9 * naive.abs());
    }

    #[test]
    fn norm_of_unit() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_updates() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn xpby_cg_update() {
        let x = vec![1.0, 1.0];
        let mut y = vec![2.0, 4.0];
        xpby(&x, 0.5, &mut y);
        assert_eq!(y, vec![2.0, 3.0]);
    }

    #[test]
    fn dot_empty_and_short() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[1.0, 1.0, 1.0]), 6.0);
    }

    #[test]
    fn chunk_grid_covers_vector() {
        for n in [0usize, 1, CHUNK - 1, CHUNK, CHUNK + 1, 3 * CHUNK + 7] {
            let mut covered = 0usize;
            for c in 0..num_chunks(n) {
                let r = chunk_range(c, n);
                assert_eq!(r.start, covered);
                covered = r.end;
            }
            assert_eq!(covered, n, "n={n}");
        }
    }

    /// The load-bearing invariant: partitioned partials + left-to-right
    /// combine are bitwise identical to the serial entry points, for any
    /// thread count.
    #[test]
    fn parallel_dot_is_bitwise_identical_to_serial() {
        let n = 3 * CHUNK + 513;
        let x: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.37).sin()).collect();
        let y: Vec<f64> = (0..n).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let serial = dot(&x, &y);
        let nchunks = num_chunks(n);
        for nt in [1usize, 2, 3, 4] {
            let pool = Pool::new(nt);
            let mut partials = vec![0.0f64; nchunks];
            let ps = SyncSlice::new(&mut partials);
            let out = std::sync::Mutex::new(Vec::new());
            pool.run(&|tid, nthreads| {
                dot_partials(&x, &y, &ps, Pool::chunk(nchunks, tid, nthreads));
                pool.phase_barrier();
                let s = combine_partials(&ps, nchunks);
                out.lock().unwrap().push(s);
            });
            for s in out.into_inner().unwrap() {
                assert_eq!(s.to_bits(), serial.to_bits(), "nt={nt}");
            }
        }
    }

    #[test]
    fn parallel_fused_update_is_bitwise_identical_to_serial() {
        let n = 2 * CHUNK + 100;
        let p: Vec<f64> = (0..n).map(|i| ((i * 7 % 13) as f64) - 6.0).collect();
        let q: Vec<f64> = (0..n).map(|i| ((i * 5 % 11) as f64) * 0.1).collect();
        let alpha = 0.371;
        let mut x_ref = vec![1.0f64; n];
        let mut r_ref: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).cos()).collect();
        let rr_ref = fused_cg_update(alpha, &p, &q, &mut x_ref, &mut r_ref);

        let nchunks = num_chunks(n);
        for nt in [1usize, 4] {
            let mut x = vec![1.0f64; n];
            let mut r: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).cos()).collect();
            let mut partials = vec![0.0f64; nchunks];
            let pool = Pool::new(nt);
            let (xs, rs, ps) =
                (SyncSlice::new(&mut x), SyncSlice::new(&mut r), SyncSlice::new(&mut partials));
            let rr_out = std::sync::Mutex::new(0.0f64);
            pool.run(&|tid, nthreads| {
                let chunks = Pool::chunk(nchunks, tid, nthreads);
                fused_update_partials(alpha, &p, &q, &xs, &rs, &ps, chunks);
                pool.phase_barrier();
                let rr = combine_partials(&ps, nchunks);
                if tid == 0 {
                    *rr_out.lock().unwrap() = rr;
                }
            });
            assert_eq!(rr_out.into_inner().unwrap().to_bits(), rr_ref.to_bits(), "nt={nt}");
            assert!(x.iter().zip(&x_ref).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert!(r.iter().zip(&r_ref).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn elementwise_chunk_helpers_match_serial() {
        let n = CHUNK + 37;
        let z: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut p_ref: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5).collect();
        let mut p = p_ref.clone();
        xpby(&z, 0.25, &mut p_ref);
        let pool = Pool::new(3);
        let psync = SyncSlice::new(&mut p);
        let nchunks = num_chunks(n);
        pool.run(&|tid, nt| {
            xpby_chunks(&z, 0.25, &psync, Pool::chunk(nchunks, tid, nt));
        });
        assert_eq!(p, p_ref);

        let b: Vec<f64> = (0..n).map(|i| (i as f64).sqrt()).collect();
        let q: Vec<f64> = (0..n).map(|i| 0.1 * i as f64).collect();
        let mut r = vec![0.0f64; n];
        let rsync = SyncSlice::new(&mut r);
        pool.run(&|tid, nt| {
            residual_chunks(&b, &q, &rsync, Pool::chunk(nchunks, tid, nt));
        });
        assert!(r.iter().zip(b.iter().zip(&q)).all(|(ri, (bi, qi))| *ri == bi - qi));
    }
}
