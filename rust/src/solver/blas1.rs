//! Dense vector (BLAS-1) kernels for the CG iteration. These are the
//! straightforwardly-parallel parts of the solver (paper §2); on this
//! single-core host they run serially but are written as contiguous loops
//! the compiler auto-vectorizes (they count as *packed* ops in the SIMD
//! ratio metric, matching how VTune attributes them in §5.2.1).

/// `xᵀ y`.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    // 4-way unrolled reduction: keeps the dependency chain short so LLVM
    // vectorizes; also gives run-to-run deterministic results.
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4;
    for i in 0..chunks {
        let b = i * 4;
        acc[0] += x[b] * y[b];
        acc[1] += x[b + 1] * y[b + 1];
        acc[2] += x[b + 2] * y[b + 2];
        acc[3] += x[b + 3] * y[b + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// `||x||₂`.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// `y += α x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = x + β y` (the CG `p` update).
#[inline]
pub fn xpby(x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = xi + beta * *yi;
    }
}

/// `y = x`.
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// Fused CG update: `x += α p; r -= α q;` returns `‖r‖²`. One pass over
/// four arrays instead of three passes (perf-pass optimization — the
/// BLAS-1 share of an ICCG iteration is memory-bound).
#[inline]
pub fn fused_cg_update(alpha: f64, p: &[f64], q: &[f64], x: &mut [f64], r: &mut [f64]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    debug_assert_eq!(p.len(), x.len());
    debug_assert_eq!(p.len(), r.len());
    let mut rr = 0.0f64;
    for i in 0..p.len() {
        x[i] += alpha * p[i];
        let ri = r[i] - alpha * q[i];
        r[i] = ri;
        rr += ri * ri;
    }
    rr
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..101).map(|i| i as f64 * 0.25).collect();
        let y: Vec<f64> = (0..101).map(|i| 1.0 - i as f64 * 0.5).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9 * naive.abs());
    }

    #[test]
    fn norm_of_unit() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_updates() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn xpby_cg_update() {
        let x = vec![1.0, 1.0];
        let mut y = vec![2.0, 4.0];
        xpby(&x, 0.5, &mut y);
        assert_eq!(y, vec![2.0, 3.0]);
    }

    #[test]
    fn dot_empty_and_short() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[1.0, 1.0, 1.0]), 6.0);
    }
}
