//! Parallel sparse matrix-vector products — CRS (the paper's baseline
//! format, used by the MC/BMC solvers and by `HBMC (crs_spmv)`) and
//! SELL-w (used by `HBMC (sell_spmv)`, §4.4.2).
//!
//! CRS rows are partitioned by **nonzeros**, not by row count
//! ([`RowSplits::balanced`]): on matrices with skewed row densities
//! (e.g. the `gen/circuit.rs` hub rows) an even row split leaves one
//! thread with a multiple of the others' work, and the per-iteration
//! barrier then bills that imbalance to every thread. Splits are
//! precomputed once per plan and aligned to the BLAS-1 reduction grid
//! ([`blas1::CHUNK`]) so the fused CG loop can produce the `p·q` partials
//! in the same sweep that writes `q`.
//!
//! The third engine is the **symmetric** kernel ([`SymmSpmv`]): diagonal +
//! strict lower triangle only ([`crate::sparse::symm::SymmCsr`]), each
//! stored nonzero updating both `y[i]` and `y[j]` — about half the matrix
//! bytes per iteration. Its scatter side needs a conflict-free schedule
//! ([`crate::ordering::race::RaceSchedule`]); when the graph colors badly
//! it falls back to per-block scatter buffers combined in fixed block
//! order. Both modes are bitwise-deterministic across runs and thread
//! counts.
//!
//! Each format exposes an inner `*_worker(tid-range)` body callable from
//! inside an open pool region (the single-dispatch CG loop); the
//! `spmv_crs` / `spmv_sell` / `spmv_symm` entry points are thin one-`run`
//! wrappers kept for the legacy per-kernel path, benches and tests.

use crate::coordinator::metrics::SpmvSyncShape;
use crate::coordinator::pool::{Pool, SyncSlice};
use crate::error::Result;
use crate::ordering::race::{canonical_blocks, RaceSchedule};
use crate::solver::blas1::CHUNK;
use crate::sparse::csr::Csr;
use crate::sparse::sell::Sell;
use crate::sparse::symm::SymmCsr;
use std::ops::Range;

/// Contiguous per-thread row ranges for CRS SpMV, balanced by nonzeros and
/// (interior boundaries) aligned to [`CHUNK`].
#[derive(Debug, Clone)]
pub struct RowSplits {
    splits: Vec<usize>,
}

impl RowSplits {
    /// Partition `0..n` into `nt` contiguous ranges of approximately equal
    /// nonzeros, computed from the CSR `row_ptr` (which *is* the
    /// cumulative-nnz array — one `partition_point` per boundary, no scan).
    /// Interior boundaries are rounded down to [`CHUNK`] multiples so every
    /// reduction chunk has exactly one owning thread.
    pub fn balanced(row_ptr: &[u32], nt: usize) -> RowSplits {
        assert!(nt >= 1);
        let n = row_ptr.len() - 1;
        let nnz = *row_ptr.last().unwrap() as u64;
        let mut splits = Vec::with_capacity(nt + 1);
        splits.push(0usize);
        for t in 1..nt {
            let target = nnz * t as u64 / nt as u64;
            let row = row_ptr.partition_point(|&v| (v as u64) < target).min(n);
            let aligned = row / CHUNK * CHUNK;
            let prev = *splits.last().unwrap();
            splits.push(aligned.clamp(prev, n));
        }
        splits.push(n);
        RowSplits { splits }
    }

    /// Number of thread ranges.
    pub fn nt(&self) -> usize {
        self.splits.len() - 1
    }

    /// Row range of thread `tid`.
    pub fn rows(&self, tid: usize) -> Range<usize> {
        self.splits[tid]..self.splits[tid + 1]
    }

    /// Reduction-chunk range wholly owned by thread `tid` (valid because
    /// interior boundaries are CHUNK-aligned; the final partial chunk
    /// belongs to the last thread).
    pub fn chunks(&self, tid: usize) -> Range<usize> {
        let r = self.rows(tid);
        let n = *self.splits.last().unwrap();
        let lo = r.start / CHUNK;
        let hi = if r.end == n { n.div_ceil(CHUNK) } else { r.end / CHUNK };
        lo..hi.max(lo)
    }
}

/// CRS SpMV body for worker `tid`: computes rows `rows` of `y = A x`.
pub fn spmv_crs_worker(a: &Csr, x: &[f64], ys: &SyncSlice<f64>, rows: Range<usize>) {
    let row_ptr = a.row_ptr();
    let cols = a.cols();
    let vals = a.vals();
    for i in rows {
        let mut s = 0.0;
        for k in row_ptr[i] as usize..row_ptr[i + 1] as usize {
            s += vals[k] * x[cols[k] as usize];
        }
        unsafe { ys.set(i, s) };
    }
}

/// `y = A x`, CRS storage, rows partitioned across the pool by nonzeros.
pub fn spmv_crs(a: &Csr, x: &[f64], y: &mut [f64], pool: &Pool) {
    let splits = RowSplits::balanced(a.row_ptr(), pool.nthreads());
    spmv_crs_with(a, x, y, pool, &splits);
}

/// [`spmv_crs`] with precomputed splits (one `RowSplits::balanced` per
/// plan instead of per call); `splits.nt()` must equal `pool.nthreads()`.
pub fn spmv_crs_with(a: &Csr, x: &[f64], y: &mut [f64], pool: &Pool, splits: &RowSplits) {
    let n = a.n();
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), n);
    assert_eq!(splits.nt(), pool.nthreads());
    let ys = SyncSlice::new(y);
    pool.run(&|tid, _nt| {
        spmv_crs_worker(a, x, &ys, splits.rows(tid));
    });
}

/// Which SELL inner kernel to run (resolved once per plan/engine, not per
/// call — `is_x86_feature_detected!` is cached but still a branch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SellSimd {
    Scalar,
    Avx2C4,
    Avx512C8,
}

/// Select the widest available SELL kernel for chunk size `c`.
pub fn detect_sell_simd(c: usize) -> SellSimd {
    #[cfg(target_arch = "x86_64")]
    {
        if c == 8 && std::arch::is_x86_feature_detected!("avx512f") {
            return SellSimd::Avx512C8;
        }
        if c == 4 && std::arch::is_x86_feature_detected!("avx2") {
            return SellSimd::Avx2C4;
        }
    }
    let _ = c;
    SellSimd::Scalar
}

/// SELL SpMV body for worker `tid`: computes slices `slices` of `y = A x`.
pub fn spmv_sell_worker(
    s: &Sell,
    x: &[f64],
    ys: &SyncSlice<f64>,
    slices: Range<usize>,
    simd: SellSimd,
) {
    match simd {
        #[cfg(target_arch = "x86_64")]
        SellSimd::Avx512C8 => unsafe { sell_slices_avx512(s, x, ys, slices) },
        #[cfg(target_arch = "x86_64")]
        SellSimd::Avx2C4 => unsafe { sell_slices_avx2(s, x, ys, slices) },
        #[allow(unreachable_patterns)]
        _ => sell_slices_scalar(s, x, ys, slices),
    }
}

/// `y = A x`, SELL-c storage, slices partitioned across the pool. Handles
/// σ-sorted layouts via the internal lane→row map. Dispatches to an
/// AVX-512 (c = 8) or AVX2 (c = 4) gather+FMA inner loop when available —
/// the perf-pass optimization recorded in EXPERIMENTS.md §Perf.
pub fn spmv_sell(s: &Sell, x: &[f64], y: &mut [f64], pool: &Pool) {
    let n = s.n();
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), n);
    let nslices = s.nslices();
    let simd = detect_sell_simd(s.c());
    let ys = SyncSlice::new(y);
    pool.run(&|tid, nt| {
        spmv_sell_worker(s, x, &ys, Pool::chunk(nslices, tid, nt), simd);
    });
}

/// Color-count ceiling for the symmetric engine's scheduled mode: each
/// color costs one barrier per SpMV, so a matrix whose distance-2 coloring
/// exceeds this is cheaper under the buffered fallback (one barrier, at
/// the price of `NBUF·n` scatter-buffer traffic).
pub const MAX_SYMM_COLORS: usize = 64;

/// Scatter buffers in the symmetric engine's buffered fallback — a fixed
/// count (not the thread count!) so the combine order, and therefore every
/// bit of the result, is independent of the pool width.
pub const NBUF: usize = 8;

/// How the symmetric kernel parallelizes its scatter updates.
#[derive(Debug, Clone)]
pub enum SymmMode {
    /// Conflict-free color schedule: within a color every `y` element has
    /// exactly one writing row, so threads scatter in place. One barrier
    /// per color.
    Colored(RaceSchedule),
    /// Per-block scatter buffers over a fixed block grid
    /// (`block_ptr[b]..block_ptr[b+1]` rows own buffer `b`), combined
    /// left-to-right in block order after one barrier.
    Buffered { block_ptr: Vec<usize> },
}

/// Symmetric SpMV operator: [`SymmCsr`] storage plus the parallel schedule
/// chosen at build time. Shared read-only by every solve of a plan; the
/// buffered mode's scratch is per-solve (see [`SymmSpmv::scratch_elems`]).
#[derive(Debug, Clone)]
pub struct SymmSpmv {
    m: SymmCsr,
    mode: SymmMode,
}

impl SymmSpmv {
    /// Build from a full (exactly symmetric) CRS matrix; picks the colored
    /// schedule when it stays under [`MAX_SYMM_COLORS`] colors, else the
    /// buffered fallback.
    pub fn build(a: &Csr) -> Result<SymmSpmv> {
        SymmSpmv::build_with_max_colors(a, MAX_SYMM_COLORS)
    }

    /// [`SymmSpmv::build`] with an explicit color ceiling (tests pass 0 to
    /// force the buffered fallback).
    pub fn build_with_max_colors(a: &Csr, max_colors: usize) -> Result<SymmSpmv> {
        let m = SymmCsr::from_csr(a)?;
        let sched = RaceSchedule::build(a);
        let mode = if sched.num_colors() <= max_colors
            && sched.is_conflict_free(m.row_ptr(), m.cols())
        {
            SymmMode::Colored(sched)
        } else {
            SymmMode::Buffered { block_ptr: canonical_blocks(m.row_ptr(), NBUF) }
        };
        Ok(SymmSpmv { m, mode })
    }

    pub fn matrix(&self) -> &SymmCsr {
        &self.m
    }

    pub fn mode(&self) -> &SymmMode {
        &self.mode
    }

    /// Scratch doubles the caller must provide to [`spmv_symm_worker`]
    /// (zero for the colored schedule; `NBUF·n` scatter buffers for the
    /// buffered fallback). Per-solve, **not** per-plan: plans are shared
    /// `Arc`s executed concurrently.
    pub fn scratch_elems(&self) -> usize {
        match &self.mode {
            SymmMode::Colored(_) => 0,
            SymmMode::Buffered { block_ptr } => (block_ptr.len() - 1) * self.m.n(),
        }
    }

    /// Barrier structure for the sync-accounting model
    /// ([`crate::coordinator::metrics`]).
    pub fn sync_shape(&self) -> SpmvSyncShape {
        match &self.mode {
            SymmMode::Colored(sched) => SpmvSyncShape::SymmColored { colors: sched.num_colors() },
            SymmMode::Buffered { .. } => SpmvSyncShape::SymmBuffered,
        }
    }
}

/// Symmetric SpMV body for worker `tid`, callable inside an open pool
/// region. **Synchronizes internally** (unlike the CRS/SELL workers):
/// `colors` barriers in colored mode, one in buffered mode — see
/// [`SpmvSyncShape`]. The *caller's* next barrier publishes the final
/// writes. Every thread of the region must call this with the same
/// arguments (SPMD contract). `scratch` must hold
/// [`SymmSpmv::scratch_elems`] doubles and must not be read by the caller
/// between calls.
pub fn spmv_symm_worker(
    s: &SymmSpmv,
    x: &[f64],
    ys: &SyncSlice<f64>,
    scratch: &SyncSlice<f64>,
    pool: &Pool,
    tid: usize,
    nt: usize,
) {
    let m = &s.m;
    let n = m.n();
    let diag = m.diag();
    match &s.mode {
        SymmMode::Colored(sched) => {
            // Phase 0: y = D·x (disjoint chunks).
            for i in Pool::chunk(n, tid, nt) {
                unsafe { ys.set(i, diag[i] * x[i]) };
            }
            pool.phase_barrier();
            // One color at a time: within a color every y element has a
            // single writing row (conflict-freedom), and the accumulation
            // order into any y[j] is the fixed color sequence — so how
            // grains are dealt to threads cannot change a single bit.
            let ncolors = sched.num_colors();
            for c in 0..ncolors {
                let grains = sched.grains_of(c);
                let g0 = grains.start;
                for g in Pool::chunk(grains.end - g0, tid, nt) {
                    for &row in sched.grain(g0 + g) {
                        let i = row as usize;
                        let xi = x[i];
                        let (cols, vals) = m.row(i);
                        let mut acc = 0.0;
                        for (&j, &v) in cols.iter().zip(vals) {
                            let j = j as usize;
                            acc += v * x[j];
                            // SAFETY: single writer per element within a
                            // color (RaceSchedule conflict-freedom).
                            unsafe { ys.set(j, ys.get(j) + v * xi) };
                        }
                        unsafe { ys.set(i, ys.get(i) + acc) };
                    }
                }
                if c + 1 < ncolors {
                    pool.phase_barrier();
                }
            }
        }
        SymmMode::Buffered { block_ptr } => {
            let nb = block_ptr.len() - 1;
            debug_assert!(scratch.len() >= nb * n, "buffered symm SpMV needs NBUF·n scratch");
            // Phase A: each thread owns whole blocks (fixed grid, any
            // width): zero the block's buffer, write y[i] for its rows
            // (diagonal + gather), scatter into its own buffer.
            for b in Pool::chunk(nb, tid, nt) {
                let base = b * n;
                for t in 0..n {
                    unsafe { scratch.set(base + t, 0.0) };
                }
                for i in block_ptr[b]..block_ptr[b + 1] {
                    let xi = x[i];
                    let (cols, vals) = m.row(i);
                    let mut acc = diag[i] * xi;
                    for (&j, &v) in cols.iter().zip(vals) {
                        let j = j as usize;
                        acc += v * x[j];
                        unsafe { scratch.set(base + j, scratch.get(base + j) + v * xi) };
                    }
                    // SAFETY: row i belongs to exactly one block.
                    unsafe { ys.set(i, acc) };
                }
            }
            pool.phase_barrier();
            // Phase B: combine buffers left-to-right in fixed block order
            // over disjoint element chunks — the block count (not the
            // thread count) fixes the summation order, so results are
            // bitwise identical for every pool width.
            for j in Pool::chunk(n, tid, nt) {
                let mut v = unsafe { ys.get(j) };
                for b in 0..nb {
                    v += unsafe { scratch.get(b * n + j) };
                }
                unsafe { ys.set(j, v) };
            }
        }
    }
}

/// `y = A x`, symmetric storage — legacy one-`run` wrapper around
/// [`spmv_symm_worker`] (allocates the buffered mode's scratch per call;
/// the fused loop allocates it once per solve instead).
pub fn spmv_symm(s: &SymmSpmv, x: &[f64], y: &mut [f64], pool: &Pool) {
    let n = s.m.n();
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), n);
    let mut scratch = vec![0.0f64; s.scratch_elems()];
    let ys = SyncSlice::new(y);
    let ss = SyncSlice::new(&mut scratch);
    pool.run(&|tid, nt| {
        spmv_symm_worker(s, x, &ys, &ss, pool, tid, nt);
    });
}

/// The SpMV side of a solve, resolved once per `SolverPlan::execute`:
/// format, kernel path and thread partition. The fused CG loop drives it
/// through [`SpmvEngine::worker`].
pub enum SpmvEngine<'a> {
    Crs { a: &'a Csr, splits: RowSplits },
    Sell { s: &'a Sell, simd: SellSimd },
    Symm { s: &'a SymmSpmv },
}

impl<'a> SpmvEngine<'a> {
    pub fn crs(a: &'a Csr, nt: usize) -> SpmvEngine<'a> {
        SpmvEngine::Crs { a, splits: RowSplits::balanced(a.row_ptr(), nt) }
    }

    pub fn crs_with(a: &'a Csr, splits: RowSplits) -> SpmvEngine<'a> {
        SpmvEngine::Crs { a, splits }
    }

    pub fn sell(s: &'a Sell) -> SpmvEngine<'a> {
        SpmvEngine::Sell { s, simd: detect_sell_simd(s.c()) }
    }

    pub fn symm(s: &'a SymmSpmv) -> SpmvEngine<'a> {
        SpmvEngine::Symm { s }
    }

    /// This worker's share of `y = A x`. CRS/SELL run barrier-free; the
    /// symmetric engine synchronizes internally (see [`spmv_symm_worker`])
    /// — either way the *caller's* next barrier publishes `y`. `scratch`
    /// must hold [`SpmvEngine::scratch_elems`] doubles (an empty slice for
    /// CRS/SELL).
    pub fn worker(
        &self,
        x: &[f64],
        ys: &SyncSlice<f64>,
        scratch: &SyncSlice<f64>,
        pool: &Pool,
        tid: usize,
        nt: usize,
    ) {
        match self {
            SpmvEngine::Crs { a, splits } => {
                // Hard assert (mirrors `spmv_crs_with`): a width mismatch
                // would silently leave rows of `y` stale in release builds.
                assert_eq!(splits.nt(), nt, "SpmvEngine splits were built for a different width");
                spmv_crs_worker(a, x, ys, splits.rows(tid));
            }
            SpmvEngine::Sell { s, simd } => {
                spmv_sell_worker(s, x, ys, Pool::chunk(s.nslices(), tid, nt), *simd);
            }
            SpmvEngine::Symm { s } => {
                spmv_symm_worker(s, x, ys, scratch, pool, tid, nt);
            }
        }
    }

    /// Reduction chunks whose `y` rows were written entirely by worker
    /// `tid`, or `None` when ownership is not chunk-coherent (SELL may
    /// scatter σ-sorted rows anywhere; the symmetric kernel scatters by
    /// construction), so the fused loop must barrier before forming `p·q`
    /// partials.
    pub fn owned_chunks(&self, tid: usize) -> Option<Range<usize>> {
        match self {
            SpmvEngine::Crs { splits, .. } => Some(splits.chunks(tid)),
            SpmvEngine::Sell { .. } | SpmvEngine::Symm { .. } => None,
        }
    }

    /// Per-solve scratch doubles this engine's worker needs (only the
    /// buffered symmetric mode uses any).
    pub fn scratch_elems(&self) -> usize {
        match self {
            SpmvEngine::Crs { .. } | SpmvEngine::Sell { .. } => 0,
            SpmvEngine::Symm { s } => s.scratch_elems(),
        }
    }

    /// Barrier structure for the analytic sync model
    /// ([`crate::coordinator::metrics::syncs_per_fused_iteration_shaped`]).
    pub fn sync_shape(&self) -> SpmvSyncShape {
        match self {
            SpmvEngine::Crs { .. } => SpmvSyncShape::Crs,
            SpmvEngine::Sell { .. } => SpmvSyncShape::Sell,
            SpmvEngine::Symm { s } => s.sync_shape(),
        }
    }
}

fn sell_slices_scalar(s: &Sell, x: &[f64], ys: &SyncSlice<f64>, slices: std::ops::Range<usize>) {
    let c = s.c();
    let slice_ptr = s.slice_ptr();
    let slice_len = s.slice_len();
    let cols = s.cols();
    let vals = s.vals();
    let lanes = s.row_of_lane();
    let mut acc = vec![0.0f64; c];
    for si in slices {
        acc.fill(0.0);
        let off = slice_ptr[si] as usize;
        let width = slice_len[si] as usize;
        for k in 0..width {
            let base = off + k * c;
            for lane in 0..c {
                acc[lane] += vals[base + lane] * x[cols[base + lane] as usize];
            }
        }
        for lane in 0..c {
            let r = lanes[si * c + lane];
            if r != u32::MAX {
                unsafe { ys.set(r as usize, acc[lane]) };
            }
        }
    }
}

/// AVX-512 SELL-8 slice kernel: 8-lane gather + FMA (mirrors the HBMC
/// substitution inner loop of Fig. 4.6, without the sequential dependence).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn sell_slices_avx512(
    s: &Sell,
    x: &[f64],
    ys: &SyncSlice<f64>,
    slices: std::ops::Range<usize>,
) {
    use std::arch::x86_64::*;
    const C: usize = 8;
    let slice_ptr = s.slice_ptr();
    let slice_len = s.slice_len();
    let cols = s.cols();
    let vals = s.vals();
    let lanes = s.row_of_lane();
    let xp = x.as_ptr();
    for si in slices {
        let off = slice_ptr[si] as usize;
        let width = slice_len[si] as usize;
        let mut acc = _mm512_setzero_pd();
        for k in 0..width {
            let base = off + k * C;
            let vidx = _mm256_loadu_si256(cols.as_ptr().add(base) as *const __m256i);
            let g = _mm512_i32gather_pd::<8>(vidx, xp);
            let v = _mm512_loadu_pd(vals.as_ptr().add(base));
            acc = _mm512_fmadd_pd(v, g, acc);
        }
        let mut buf = [0.0f64; C];
        _mm512_storeu_pd(buf.as_mut_ptr(), acc);
        for (lane, &val) in buf.iter().enumerate() {
            let r = lanes[si * C + lane];
            if r != u32::MAX {
                ys.set(r as usize, val);
            }
        }
    }
}

/// AVX2 SELL-4 slice kernel.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sell_slices_avx2(
    s: &Sell,
    x: &[f64],
    ys: &SyncSlice<f64>,
    slices: std::ops::Range<usize>,
) {
    use std::arch::x86_64::*;
    const C: usize = 4;
    let slice_ptr = s.slice_ptr();
    let slice_len = s.slice_len();
    let cols = s.cols();
    let vals = s.vals();
    let lanes = s.row_of_lane();
    let xp = x.as_ptr();
    for si in slices {
        let off = slice_ptr[si] as usize;
        let width = slice_len[si] as usize;
        let mut acc = _mm256_setzero_pd();
        for k in 0..width {
            let base = off + k * C;
            let vidx = _mm_loadu_si128(cols.as_ptr().add(base) as *const __m128i);
            let g = _mm256_i32gather_pd::<8>(xp, vidx);
            let v = _mm256_loadu_pd(vals.as_ptr().add(base));
            acc = _mm256_fmadd_pd(v, g, acc);
        }
        let mut buf = [0.0f64; C];
        _mm256_storeu_pd(buf.as_mut_ptr(), acc);
        for (lane, &val) in buf.iter().enumerate() {
            let r = lanes[si * C + lane];
            if r != u32::MAX {
                ys.set(r as usize, val);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn random_csr(n: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(n);
        for i in 0..n {
            coo.push(i, i, 4.0);
            for _ in 0..4 {
                let j = rng.below(n);
                if j != i {
                    coo.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn crs_parallel_matches_serial() {
        let a = random_csr(97, 1);
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..97).map(|_| rng.f64()).collect();
        let mut y_ref = vec![0.0; 97];
        a.mul_vec(&x, &mut y_ref);
        for nt in [1usize, 3, 4] {
            let pool = Pool::new(nt);
            let mut y = vec![0.0; 97];
            spmv_crs(&a, &x, &mut y, &pool);
            assert!(crate::util::max_abs_diff(&y, &y_ref) < 1e-14, "nt={nt}");
        }
    }

    #[test]
    fn sell_parallel_matches_serial() {
        let a = random_csr(120, 5);
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..120).map(|_| rng.f64()).collect();
        let mut y_ref = vec![0.0; 120];
        a.mul_vec(&x, &mut y_ref);
        for &c in &[4usize, 8] {
            let s = Sell::from_csr(&a, c);
            for nt in [1usize, 2] {
                let pool = Pool::new(nt);
                let mut y = vec![0.0; 120];
                spmv_sell(&s, &x, &mut y, &pool);
                assert!(crate::util::max_abs_diff(&y, &y_ref) < 1e-14, "c={c} nt={nt}");
            }
        }
    }

    #[test]
    fn sell_sigma_sorted_matches() {
        let a = random_csr(128, 9);
        let s = Sell::from_csr_sigma(&a, 8, 32);
        let x: Vec<f64> = (0..128).map(|i| (i as f64).sin()).collect();
        let mut y_ref = vec![0.0; 128];
        a.mul_vec(&x, &mut y_ref);
        let pool = Pool::new(2);
        let mut y = vec![0.0; 128];
        spmv_sell(&s, &x, &mut y, &pool);
        assert!(crate::util::max_abs_diff(&y, &y_ref) < 1e-14);
    }

    /// A matrix with one dense "hub" region: a row split would give the
    /// hub's owner most of the nonzeros; the balanced split must not.
    fn skewed_csr(n: usize) -> Csr {
        let mut coo = Coo::new(n);
        for i in 0..n {
            coo.push(i, i, 4.0);
        }
        // First n/8 rows are dense-ish (16 extra entries each).
        let mut rng = Rng::new(77);
        for i in 0..n / 8 {
            for _ in 0..16 {
                let j = rng.below(n);
                if j != i {
                    coo.push(i, j, 0.01);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn balanced_splits_cover_and_balance_nnz() {
        // Large enough that CHUNK-quantized boundaries can still balance
        // (alignment granularity is CHUNK rows).
        let a = skewed_csr(16 * CHUNK);
        let row_ptr = a.row_ptr();
        for nt in [1usize, 2, 3, 4, 7] {
            let sp = RowSplits::balanced(row_ptr, nt);
            assert_eq!(sp.nt(), nt);
            // Cover 0..n contiguously, interior boundaries CHUNK-aligned.
            let mut end = 0usize;
            for t in 0..nt {
                let r = sp.rows(t);
                assert_eq!(r.start, end);
                end = r.end;
                if t + 1 < nt {
                    assert_eq!(r.end % CHUNK, 0, "interior split must be aligned");
                }
            }
            assert_eq!(end, a.n());
            // Chunk ownership covers the whole grid disjointly.
            let mut cend = 0usize;
            for t in 0..nt {
                let c = sp.chunks(t);
                assert_eq!(c.start, cend);
                cend = c.end;
            }
            assert_eq!(cend, a.n().div_ceil(CHUNK));
        }
        // With 2 threads, the nnz share of each side is far closer to even
        // than a naive half-rows split (hub rows all live in the first half).
        let sp = RowSplits::balanced(row_ptr, 2);
        let mid = sp.rows(0).end;
        let nnz = a.nnz() as f64;
        let left = row_ptr[mid] as f64;
        assert!(
            (left / nnz - 0.5).abs() < 0.2,
            "nnz-balanced split is {left}/{nnz}"
        );
        let naive_left = row_ptr[a.n() / 2] as f64;
        assert!((left / nnz - 0.5).abs() < (naive_left / nnz - 0.5).abs());
    }

    #[test]
    fn spmv_crs_with_precomputed_splits_matches() {
        let a = skewed_csr(2 * CHUNK + 100);
        let x: Vec<f64> = (0..a.n()).map(|i| (i as f64 * 0.01).cos()).collect();
        let mut y_ref = vec![0.0; a.n()];
        a.mul_vec(&x, &mut y_ref);
        let pool = Pool::new(3);
        let splits = RowSplits::balanced(a.row_ptr(), 3);
        let mut y = vec![0.0; a.n()];
        spmv_crs_with(&a, &x, &mut y, &pool, &splits);
        assert!(crate::util::max_abs_diff(&y, &y_ref) < 1e-14);
    }

    fn random_sym_csr(n: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(n);
        for i in 0..n {
            coo.push(i, i, 4.0 + rng.f64());
            for _ in 0..4 {
                let j = rng.below(n);
                if j != i {
                    coo.push_sym(i, j, rng.range_f64(-0.3, 0.3));
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn symm_parallel_matches_full_csr() {
        let a = random_sym_csr(257, 13);
        let x: Vec<f64> = (0..a.n()).map(|i| (i as f64 * 0.1).sin() + 1.0).collect();
        let mut y_ref = vec![0.0; a.n()];
        a.mul_vec(&x, &mut y_ref);
        for max_colors in [MAX_SYMM_COLORS, 0] {
            let s = SymmSpmv::build_with_max_colors(&a, max_colors).expect("build");
            for nt in [1usize, 2, 4] {
                let pool = Pool::new(nt);
                let mut y = vec![0.0; a.n()];
                spmv_symm(&s, &x, &mut y, &pool);
                let rel = crate::util::rel_l2_diff(&y, &y_ref);
                assert!(rel < 1e-13, "max_colors={max_colors} nt={nt}: rel={rel}");
            }
        }
    }

    #[test]
    fn symm_is_bitwise_deterministic_across_runs_and_widths() {
        let a = random_sym_csr(310, 29);
        let x: Vec<f64> = (0..a.n()).map(|i| ((i * 7 % 13) as f64).cos()).collect();
        for max_colors in [MAX_SYMM_COLORS, 0] {
            let s = SymmSpmv::build_with_max_colors(&a, max_colors).expect("build");
            match (max_colors, s.mode()) {
                (0, SymmMode::Buffered { .. }) | (MAX_SYMM_COLORS, SymmMode::Colored(_)) => {}
                (mc, m) => panic!("unexpected mode {m:?} for ceiling {mc}"),
            }
            let mut reference: Option<Vec<u64>> = None;
            for nt in [1usize, 2, 4] {
                for _rep in 0..2 {
                    let pool = Pool::new(nt);
                    let mut y = vec![0.0; a.n()];
                    spmv_symm(&s, &x, &mut y, &pool);
                    let bits: Vec<u64> = y.iter().map(|v| v.to_bits()).collect();
                    match &reference {
                        None => reference = Some(bits),
                        Some(r) => {
                            assert_eq!(r, &bits, "max_colors={max_colors} nt={nt}")
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn symm_engine_reports_scratch_and_shape() {
        let a = random_sym_csr(120, 3);
        let colored = SymmSpmv::build(&a).unwrap();
        assert_eq!(colored.scratch_elems(), 0);
        assert!(matches!(
            SpmvEngine::symm(&colored).sync_shape(),
            SpmvSyncShape::SymmColored { colors } if colors >= 1
        ));
        let buffered = SymmSpmv::build_with_max_colors(&a, 0).unwrap();
        assert_eq!(buffered.scratch_elems(), NBUF * a.n());
        assert!(matches!(
            SpmvEngine::symm(&buffered).sync_shape(),
            SpmvSyncShape::SymmBuffered
        ));
        let splits = RowSplits::balanced(a.row_ptr(), 2);
        assert_eq!(SpmvEngine::crs_with(&a, splits).scratch_elems(), 0);
    }
}
