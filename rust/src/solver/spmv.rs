//! Parallel sparse matrix-vector products — CRS (the paper's baseline
//! format, used by the MC/BMC solvers and by `HBMC (crs_spmv)`) and
//! SELL-w (used by `HBMC (sell_spmv)`, §4.4.2).

use crate::coordinator::pool::{Pool, SyncSlice};
use crate::sparse::csr::Csr;
use crate::sparse::sell::Sell;

/// `y = A x`, CRS storage, rows partitioned across the pool.
pub fn spmv_crs(a: &Csr, x: &[f64], y: &mut [f64], pool: &Pool) {
    let n = a.n();
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), n);
    let ys = SyncSlice::new(y);
    pool.run(&|tid, nt| {
        let rows = Pool::chunk(n, tid, nt);
        let row_ptr = a.row_ptr();
        let cols = a.cols();
        let vals = a.vals();
        for i in rows {
            let mut s = 0.0;
            for k in row_ptr[i] as usize..row_ptr[i + 1] as usize {
                s += vals[k] * x[cols[k] as usize];
            }
            unsafe { ys.set(i, s) };
        }
    });
}

/// `y = A x`, SELL-c storage, slices partitioned across the pool. Handles
/// σ-sorted layouts via the internal lane→row map. Dispatches to an
/// AVX-512 (c = 8) or AVX2 (c = 4) gather+FMA inner loop when available —
/// the perf-pass optimization recorded in EXPERIMENTS.md §Perf.
pub fn spmv_sell(s: &Sell, x: &[f64], y: &mut [f64], pool: &Pool) {
    let n = s.n();
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), n);
    let c = s.c();
    let nslices = s.nslices();
    #[cfg(target_arch = "x86_64")]
    let use512 = c == 8 && std::arch::is_x86_feature_detected!("avx512f");
    #[cfg(target_arch = "x86_64")]
    let use2 = c == 4 && std::arch::is_x86_feature_detected!("avx2");
    #[cfg(not(target_arch = "x86_64"))]
    let (use512, use2) = (false, false);
    let ys = SyncSlice::new(y);
    pool.run(&|tid, nt| {
        let slices = Pool::chunk(nslices, tid, nt);
        #[cfg(target_arch = "x86_64")]
        if use512 {
            unsafe { sell_slices_avx512(s, x, &ys, slices.clone()) };
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if use2 {
            unsafe { sell_slices_avx2(s, x, &ys, slices.clone()) };
            return;
        }
        sell_slices_scalar(s, x, &ys, slices);
    });
}

fn sell_slices_scalar(s: &Sell, x: &[f64], ys: &SyncSlice<f64>, slices: std::ops::Range<usize>) {
    let c = s.c();
    let slice_ptr = s.slice_ptr();
    let slice_len = s.slice_len();
    let cols = s.cols();
    let vals = s.vals();
    let lanes = s.row_of_lane();
    let mut acc = vec![0.0f64; c];
    for si in slices {
        acc.fill(0.0);
        let off = slice_ptr[si] as usize;
        let width = slice_len[si] as usize;
        for k in 0..width {
            let base = off + k * c;
            for lane in 0..c {
                acc[lane] += vals[base + lane] * x[cols[base + lane] as usize];
            }
        }
        for lane in 0..c {
            let r = lanes[si * c + lane];
            if r != u32::MAX {
                unsafe { ys.set(r as usize, acc[lane]) };
            }
        }
    }
}

/// AVX-512 SELL-8 slice kernel: 8-lane gather + FMA (mirrors the HBMC
/// substitution inner loop of Fig. 4.6, without the sequential dependence).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn sell_slices_avx512(
    s: &Sell,
    x: &[f64],
    ys: &SyncSlice<f64>,
    slices: std::ops::Range<usize>,
) {
    use std::arch::x86_64::*;
    const C: usize = 8;
    let slice_ptr = s.slice_ptr();
    let slice_len = s.slice_len();
    let cols = s.cols();
    let vals = s.vals();
    let lanes = s.row_of_lane();
    let xp = x.as_ptr();
    for si in slices {
        let off = slice_ptr[si] as usize;
        let width = slice_len[si] as usize;
        let mut acc = _mm512_setzero_pd();
        for k in 0..width {
            let base = off + k * C;
            let vidx = _mm256_loadu_si256(cols.as_ptr().add(base) as *const __m256i);
            let g = _mm512_i32gather_pd::<8>(vidx, xp);
            let v = _mm512_loadu_pd(vals.as_ptr().add(base));
            acc = _mm512_fmadd_pd(v, g, acc);
        }
        let mut buf = [0.0f64; C];
        _mm512_storeu_pd(buf.as_mut_ptr(), acc);
        for (lane, &val) in buf.iter().enumerate() {
            let r = lanes[si * C + lane];
            if r != u32::MAX {
                ys.set(r as usize, val);
            }
        }
    }
}

/// AVX2 SELL-4 slice kernel.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sell_slices_avx2(
    s: &Sell,
    x: &[f64],
    ys: &SyncSlice<f64>,
    slices: std::ops::Range<usize>,
) {
    use std::arch::x86_64::*;
    const C: usize = 4;
    let slice_ptr = s.slice_ptr();
    let slice_len = s.slice_len();
    let cols = s.cols();
    let vals = s.vals();
    let lanes = s.row_of_lane();
    let xp = x.as_ptr();
    for si in slices {
        let off = slice_ptr[si] as usize;
        let width = slice_len[si] as usize;
        let mut acc = _mm256_setzero_pd();
        for k in 0..width {
            let base = off + k * C;
            let vidx = _mm_loadu_si128(cols.as_ptr().add(base) as *const __m128i);
            let g = _mm256_i32gather_pd::<8>(xp, vidx);
            let v = _mm256_loadu_pd(vals.as_ptr().add(base));
            acc = _mm256_fmadd_pd(v, g, acc);
        }
        let mut buf = [0.0f64; C];
        _mm256_storeu_pd(buf.as_mut_ptr(), acc);
        for (lane, &val) in buf.iter().enumerate() {
            let r = lanes[si * C + lane];
            if r != u32::MAX {
                ys.set(r as usize, val);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn random_csr(n: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(n);
        for i in 0..n {
            coo.push(i, i, 4.0);
            for _ in 0..4 {
                let j = rng.below(n);
                if j != i {
                    coo.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn crs_parallel_matches_serial() {
        let a = random_csr(97, 1);
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..97).map(|_| rng.f64()).collect();
        let mut y_ref = vec![0.0; 97];
        a.mul_vec(&x, &mut y_ref);
        for nt in [1usize, 3, 4] {
            let pool = Pool::new(nt);
            let mut y = vec![0.0; 97];
            spmv_crs(&a, &x, &mut y, &pool);
            assert!(crate::util::max_abs_diff(&y, &y_ref) < 1e-14, "nt={nt}");
        }
    }

    #[test]
    fn sell_parallel_matches_serial() {
        let a = random_csr(120, 5);
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..120).map(|_| rng.f64()).collect();
        let mut y_ref = vec![0.0; 120];
        a.mul_vec(&x, &mut y_ref);
        for &c in &[4usize, 8] {
            let s = Sell::from_csr(&a, c);
            for nt in [1usize, 2] {
                let pool = Pool::new(nt);
                let mut y = vec![0.0; 120];
                spmv_sell(&s, &x, &mut y, &pool);
                assert!(crate::util::max_abs_diff(&y, &y_ref) < 1e-14, "c={c} nt={nt}");
            }
        }
    }

    #[test]
    fn sell_sigma_sorted_matches() {
        let a = random_csr(128, 9);
        let s = Sell::from_csr_sigma(&a, 8, 32);
        let x: Vec<f64> = (0..128).map(|i| (i as f64).sin()).collect();
        let mut y_ref = vec![0.0; 128];
        a.mul_vec(&x, &mut y_ref);
        let pool = Pool::new(2);
        let mut y = vec![0.0; 128];
        spmv_sell(&s, &x, &mut y, &pool);
        assert!(crate::util::max_abs_diff(&y, &y_ref) < 1e-14);
    }
}
