//! Enumeration of the valid configuration space the tuner searches.
//!
//! Axes follow the paper's sweep: ordering (MC / BMC / HBMC), block size
//! `bs ∈ {8, 16, 32}` (§5), SIMD width `w` (matched to the machine's
//! vector registers — the cross-machine axis of Table 4.1), SpMV storage
//! (CRS vs SELL §5.2.2 vs the symmetric engine, which halves matrix
//! traffic) with optional SELL-C-σ windows, and thread count up to the
//! detected core count. Every candidate passes
//! [`SolverConfig::validate`], so the HBMC `bs % w == 0` constraint and
//! the SELL σ window rules are honoured by construction.
//!
//! Enumeration **canonicalizes irrelevant axes** before deduplication,
//! driven by one per-axis relevance mask per (ordering, SpMV) pair
//! ([`axis_relevance`]): `bs` does not reach the kernels under
//! Natural/MC/Level ordering, `w` is meaningless for a CRS-SpMV non-HBMC
//! plan, and σ only exists for SELL — leaving those axes free would
//! multiply the measurement budget by configurations that share a
//! `PlanKey`-equivalent execution without adding information. The
//! level-scheduled path deliberately masks *all three* structural axes
//! (its schedule comes from the factor's DAG, not from bs/w), so its
//! sub-grid is exactly |spmv| × |threads| and the scoreboard gains a fifth
//! strategy without exploding.

use std::collections::HashSet;

use crate::config::{OrderingKind, SolverConfig, SpmvKind};
use crate::tune::profile::HardwareSignature;

/// The grid of candidate axes; see module docs. Construct via
/// [`ConfigSpace::for_hardware`] / [`ConfigSpace::quick`] or as a struct
/// literal for custom sweeps.
#[derive(Debug, Clone)]
pub struct ConfigSpace {
    pub orderings: Vec<OrderingKind>,
    /// BMC/HBMC block sizes (the paper sweeps 8, 16, 32).
    pub block_sizes: Vec<usize>,
    /// SIMD widths / SELL slice heights.
    pub widths: Vec<usize>,
    pub spmvs: Vec<SpmvKind>,
    /// SELL-C-σ windows in units of `w` slices (`None` = unsorted SELL-w;
    /// `Some(k)` ⇒ σ = k·w, automatically a valid multiple of every `w`).
    pub sigma_slices: Vec<Option<usize>>,
    /// Pool widths to race (each must be ≥ 1).
    pub threads: Vec<usize>,
}

impl ConfigSpace {
    /// The full per-machine search space: the paper's `bs` sweep, widths
    /// compatible with the detected SIMD level, both SpMV storages, one
    /// σ-sorted SELL variant, and power-of-two thread counts up to the
    /// core count.
    pub fn for_hardware(hw: &HardwareSignature) -> ConfigSpace {
        let mut widths = vec![4];
        if hw.simd.natural_w() == 8 || hw.cores >= 4 {
            widths.push(8);
        }
        ConfigSpace {
            // Level first: its sub-grid is tiny (bs/w/σ are masked), so
            // leading the enumeration guarantees the scheduling strategy
            // is raced even when a candidate cap truncates the tail.
            orderings: vec![
                OrderingKind::Level,
                OrderingKind::Mc,
                OrderingKind::Bmc,
                OrderingKind::Hbmc,
            ],
            block_sizes: vec![8, 16, 32],
            widths,
            spmvs: vec![SpmvKind::Crs, SpmvKind::Sell, SpmvKind::SymmCsr],
            sigma_slices: vec![None, Some(16)],
            threads: thread_ladder(hw.cores),
        }
    }

    /// A deliberately small space for smoke tests and `tune --quick`:
    /// BMC vs HBMC at two block sizes plus the level-scheduled path, one
    /// width, the three SpMV storages, serial plus one multi-threaded
    /// width.
    pub fn quick(hw: &HardwareSignature) -> ConfigSpace {
        ConfigSpace {
            orderings: vec![OrderingKind::Bmc, OrderingKind::Hbmc, OrderingKind::Level],
            block_sizes: vec![8, 16],
            widths: vec![4],
            spmvs: vec![SpmvKind::Crs, SpmvKind::Sell, SpmvKind::SymmCsr],
            sigma_slices: vec![None],
            threads: if hw.cores >= 2 { vec![1, 2] } else { vec![1] },
        }
    }

    /// Materialize the candidate list: `base` first (the incumbent the
    /// racing strategy abandons against — and the guarantee that tuning
    /// can never return something worse than the default), then every
    /// distinct valid grid point, canonicalized and deduplicated.
    pub fn enumerate(&self, base: &SolverConfig) -> Vec<SolverConfig> {
        let mut seen: HashSet<CandidateKey> = HashSet::new();
        let mut out = Vec::new();
        // The incumbent is kept verbatim (the caller runs *this* config),
        // but deduplicated under its *canonical* key so a behaviour-
        // identical grid point (say MC + CRS, where bs/w are inert) is not
        // measured a second time under a different label.
        if base.validate().is_ok() {
            let mut canon = base.clone();
            canonicalize(&mut canon, self);
            seen.insert(CandidateKey::of(&canon));
            out.push(base.clone());
        }
        let mut push = |cfg: SolverConfig| {
            if cfg.validate().is_ok() && seen.insert(CandidateKey::of(&cfg)) {
                out.push(cfg);
            }
        };
        for &ordering in &self.orderings {
            for &bs in &self.block_sizes {
                for &w in &self.widths {
                    for &spmv in &self.spmvs {
                        for &slices in &self.sigma_slices {
                            for &threads in &self.threads {
                                if threads == 0 {
                                    continue;
                                }
                                let mut cfg = SolverConfig {
                                    ordering,
                                    bs,
                                    w,
                                    spmv,
                                    sell_sigma: slices.map(|k| k * w),
                                    threads,
                                    ..base.clone()
                                };
                                canonicalize(&mut cfg, self);
                                push(cfg);
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Number of *distinct* candidates this space yields for `base`.
    pub fn candidate_count(&self, base: &SolverConfig) -> usize {
        self.enumerate(base).len()
    }
}

/// Power-of-two thread counts up to `cores`, always ending in `cores`
/// itself (e.g. 6 cores → `[1, 2, 4, 6]`).
fn thread_ladder(cores: usize) -> Vec<usize> {
    let cores = cores.max(1);
    let mut out = vec![1];
    let mut t = 2;
    while t < cores {
        out.push(t);
        t *= 2;
    }
    if cores > 1 {
        out.push(cores);
    }
    out
}

/// Which structural axes actually reach a kernel for one
/// (ordering, SpMV) pair — the single source of truth `canonicalize`
/// applies uniformly, instead of per-ordering special cases.
#[derive(Debug, Clone, Copy)]
pub struct AxisRelevance {
    /// `bs` shapes the ordering's blocking.
    pub bs: bool,
    /// `w` reaches a kernel (HBMC level-2 width, or SELL slice height).
    pub w: bool,
    /// σ exists (SELL storage) and the path is allowed to sweep it.
    pub sigma: bool,
}

/// Relevance mask for one (ordering, SpMV) pair. Natural/MC have no
/// blocking (`bs` inert); for non-HBMC orderings `w` only matters as the
/// SELL slice height; σ exists only for SELL. The level path masks all
/// three: its parallel structure is the factor DAG's wavefronts, so the
/// tuner races it on |spmv| × |threads| alone.
pub fn axis_relevance(ordering: OrderingKind, spmv: SpmvKind) -> AxisRelevance {
    let sell = spmv == SpmvKind::Sell;
    match ordering {
        OrderingKind::Natural | OrderingKind::Mc => {
            AxisRelevance { bs: false, w: sell, sigma: sell }
        }
        OrderingKind::Bmc => AxisRelevance { bs: true, w: sell, sigma: sell },
        OrderingKind::Hbmc => AxisRelevance { bs: true, w: true, sigma: sell },
        OrderingKind::Level => AxisRelevance { bs: false, w: false, sigma: false },
    }
}

/// Map axes that cannot reach the kernels to fixed values so the dedup set
/// collapses behaviour-identical grid points (see module docs).
fn canonicalize(cfg: &mut SolverConfig, space: &ConfigSpace) {
    let rel = axis_relevance(cfg.ordering, cfg.spmv);
    if !rel.bs {
        cfg.bs = space.block_sizes.first().copied().unwrap_or(cfg.bs);
    }
    if !rel.w {
        cfg.w = space.widths.first().copied().unwrap_or(cfg.w);
    }
    if !rel.sigma {
        cfg.sell_sigma = None;
    }
}

/// Dedup key over exactly the axes that matter post-canonicalization.
#[derive(PartialEq, Eq, Hash)]
struct CandidateKey {
    ordering: OrderingKind,
    bs: usize,
    w: usize,
    spmv: SpmvKind,
    sell_sigma: Option<usize>,
    threads: usize,
    use_intrinsics: bool,
}

impl CandidateKey {
    fn of(cfg: &SolverConfig) -> CandidateKey {
        CandidateKey {
            ordering: cfg.ordering,
            bs: cfg.bs,
            w: cfg.w,
            spmv: cfg.spmv,
            sell_sigma: cfg.sell_sigma,
            threads: cfg.threads,
            use_intrinsics: cfg.use_intrinsics,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::profile::SimdLevel;

    fn hw(simd: SimdLevel, cores: usize) -> HardwareSignature {
        HardwareSignature { simd, cores }
    }

    #[test]
    fn enumerate_puts_base_first_and_validates_everything() {
        let base = SolverConfig::default();
        let space = ConfigSpace::for_hardware(&hw(SimdLevel::Avx2, 4));
        let cands = space.enumerate(&base);
        assert!(!cands.is_empty());
        assert_eq!(cands[0].label(), base.label(), "incumbent must lead the list");
        for c in &cands {
            c.validate().expect("every enumerated candidate must be valid");
        }
    }

    #[test]
    fn hbmc_bs_multiple_of_w_is_honoured() {
        let space = ConfigSpace {
            orderings: vec![OrderingKind::Hbmc],
            block_sizes: vec![8, 12],
            widths: vec![8],
            spmvs: vec![SpmvKind::Crs],
            sigma_slices: vec![None],
            threads: vec![1],
        };
        let cands = space.enumerate(&SolverConfig::default());
        // bs=12 with w=8 violates bs % w == 0 and must be filtered out.
        assert!(cands.iter().all(|c| c.ordering != OrderingKind::Hbmc || c.bs % c.w == 0));
        assert!(cands.iter().any(|c| c.bs == 8));
        assert!(!cands.iter().any(|c| c.bs == 12));
    }

    #[test]
    fn irrelevant_axes_collapse() {
        // MC ordering with CRS SpMV: neither bs nor w reaches a kernel, so
        // the 3×2 (bs, w) sub-grid must collapse to one candidate.
        let space = ConfigSpace {
            orderings: vec![OrderingKind::Mc],
            block_sizes: vec![8, 16, 32],
            widths: vec![4, 8],
            spmvs: vec![SpmvKind::Crs],
            sigma_slices: vec![None, Some(16)],
            threads: vec![1],
        };
        let base = SolverConfig {
            ordering: OrderingKind::Mc,
            bs: 8,
            w: 4,
            spmv: SpmvKind::Crs,
            ..Default::default()
        };
        let cands = space.enumerate(&base);
        assert_eq!(cands.len(), 1, "{:?}", cands.iter().map(|c| c.label()).collect::<Vec<_>>());
    }

    #[test]
    fn sigma_windows_scale_with_w() {
        let space = ConfigSpace {
            orderings: vec![OrderingKind::Hbmc],
            block_sizes: vec![16],
            widths: vec![4, 8],
            spmvs: vec![SpmvKind::Sell],
            sigma_slices: vec![Some(16)],
            threads: vec![1],
        };
        let cands = space.enumerate(&SolverConfig { bs: 16, w: 4, ..Default::default() });
        for c in cands.iter().filter(|c| c.sell_sigma.is_some()) {
            assert_eq!(c.sell_sigma.unwrap() % c.w, 0);
            assert_eq!(c.sell_sigma.unwrap(), 16 * c.w);
        }
    }

    #[test]
    fn incumbent_dedups_under_its_canonical_key() {
        // Base MC + CRS with inert bs=32/w=8: the grid's MC+CRS point
        // canonicalizes to the same behaviour and must NOT be measured as
        // a second candidate alongside the verbatim incumbent.
        let space = ConfigSpace {
            orderings: vec![OrderingKind::Mc],
            block_sizes: vec![8],
            widths: vec![4],
            spmvs: vec![SpmvKind::Crs],
            sigma_slices: vec![None],
            threads: vec![1],
        };
        let base = SolverConfig {
            ordering: OrderingKind::Mc,
            bs: 32,
            w: 8,
            spmv: SpmvKind::Crs,
            ..Default::default()
        };
        let cands = space.enumerate(&base);
        assert_eq!(cands.len(), 1, "{:?}", cands.iter().map(|c| c.label()).collect::<Vec<_>>());
        assert_eq!(cands[0].bs, 32, "the incumbent itself is kept verbatim");
    }

    #[test]
    fn level_sub_grid_is_spmv_times_threads() {
        // All three structural axes are masked for the level path, so a
        // 3 (bs) × 2 (w) × 2 (σ) sub-grid collapses to |spmv| × |threads|.
        let space = ConfigSpace {
            orderings: vec![OrderingKind::Level],
            block_sizes: vec![8, 16, 32],
            widths: vec![4, 8],
            spmvs: vec![SpmvKind::Crs, SpmvKind::Sell, SpmvKind::SymmCsr],
            sigma_slices: vec![None, Some(16)],
            threads: vec![1, 2, 4],
        };
        let base = SolverConfig {
            ordering: OrderingKind::Level,
            bs: 8,
            w: 4,
            spmv: SpmvKind::Crs,
            threads: 1,
            ..Default::default()
        };
        let cands = space.enumerate(&base);
        assert_eq!(
            cands.len(),
            3 * 3,
            "{:?}",
            cands.iter().map(|c| c.label()).collect::<Vec<_>>()
        );
        assert!(cands.iter().all(|c| c.ordering == OrderingKind::Level));
        assert!(cands.iter().all(|c| c.sell_sigma.is_none()));
        assert!(cands.iter().all(|c| c.bs == 8 && c.w == 4));
    }

    #[test]
    fn relevance_mask_matches_kernel_reach() {
        // Spot-check the mask against what each kernel actually consumes.
        let r = axis_relevance(OrderingKind::Mc, SpmvKind::Crs);
        assert!(!r.bs && !r.w && !r.sigma);
        let r = axis_relevance(OrderingKind::Mc, SpmvKind::Sell);
        assert!(!r.bs && r.w && r.sigma);
        let r = axis_relevance(OrderingKind::Bmc, SpmvKind::SymmCsr);
        assert!(r.bs && !r.w && !r.sigma);
        let r = axis_relevance(OrderingKind::Hbmc, SpmvKind::Crs);
        assert!(r.bs && r.w && !r.sigma);
        for spmv in [SpmvKind::Crs, SpmvKind::Sell, SpmvKind::SymmCsr] {
            let r = axis_relevance(OrderingKind::Level, spmv);
            assert!(!r.bs && !r.w && !r.sigma, "level masks every structural axis");
        }
    }

    #[test]
    fn default_grids_lead_with_the_level_path() {
        // The full grid puts Level first so a candidate cap can never
        // starve the scheduling strategy; quick includes it too.
        let full = ConfigSpace::for_hardware(&hw(SimdLevel::Avx2, 4));
        assert_eq!(full.orderings[0], OrderingKind::Level);
        let base = SolverConfig::default();
        assert!(full.enumerate(&base).iter().any(|c| c.ordering == OrderingKind::Level));
        let quick = ConfigSpace::quick(&hw(SimdLevel::Scalar, 2));
        assert!(quick.enumerate(&base).iter().any(|c| c.ordering == OrderingKind::Level));
    }

    #[test]
    fn thread_ladder_covers_cores() {
        assert_eq!(thread_ladder(1), vec![1]);
        assert_eq!(thread_ladder(2), vec![1, 2]);
        assert_eq!(thread_ladder(6), vec![1, 2, 4, 6]);
        assert_eq!(thread_ladder(8), vec![1, 2, 4, 8]);
    }

    #[test]
    fn grids_race_the_symmetric_engine() {
        let base = SolverConfig::default();
        for space in [
            ConfigSpace::for_hardware(&hw(SimdLevel::Avx2, 4)),
            ConfigSpace::quick(&hw(SimdLevel::Scalar, 2)),
        ] {
            let cands = space.enumerate(&base);
            assert!(cands.iter().any(|c| c.spmv == SpmvKind::SymmCsr));
            // σ never leaks onto a symmetric-SpMV candidate.
            assert!(cands.iter().all(|c| c.spmv == SpmvKind::Sell || c.sell_sigma.is_none()));
        }
    }

    #[test]
    fn quick_space_is_small() {
        let base = SolverConfig::default();
        let n = ConfigSpace::quick(&hw(SimdLevel::Scalar, 2)).candidate_count(&base);
        assert!(n <= 32, "quick space must stay CI-sized, got {n}");
    }
}
