//! Timed evaluation of one candidate configuration.
//!
//! Each measurement is an honest plan/solve split: the plan is built once
//! (its setup seconds recorded separately — Table 5.3 protocol), then the
//! right-hand side is solved through a real [`SolveSession`] on the fused
//! single-dispatch path — the exact code the `SolverService` dispatcher
//! runs in production, so tuned numbers transfer. Warmup solves populate
//! caches and branch predictors before the timed trials; the reported
//! time is the **median** trial (robust to one scheduler hiccup, unlike
//! min or mean).
//!
//! Early abandonment: when an incumbent time is supplied, a candidate
//! whose very first timed solve is already `abandon_factor ×` slower is
//! cut off mid-measurement — the racing tuner spends its budget on
//! contenders, not on confirming losers to three decimal places.

use std::sync::Arc;

use crate::config::SolverConfig;
use crate::coordinator::driver::SolveOptions;
use crate::coordinator::metrics::amortized_seconds_per_solve;
use crate::coordinator::session::SolveSession;
use crate::error::Result;
use crate::solver::plan::SolverPlan;
use crate::sparse::csr::Csr;

/// Trial-loop controls (one candidate's measurement budget).
#[derive(Debug, Clone, Copy)]
pub struct MeasureOptions {
    /// Untimed solves before the trials (cache/branch warmup).
    pub warmup: usize,
    /// Timed solves; the reported time is their median. Clamped to ≥ 1.
    pub trials: usize,
    /// A trial exceeding `abandon_factor ×` the incumbent's time aborts
    /// the remaining trials (see module docs); clamped to ≥ 1 so a
    /// candidate can never be abandoned for merely matching the
    /// incumbent. The incumbent itself is measured without a threshold.
    pub abandon_factor: f64,
    /// Attribute the candidate's time across kernel phases with **one
    /// extra untimed profiled solve** after the timed trials. The timed
    /// median is never taken with the recorder on (one-measurement rule),
    /// so enabling this never perturbs the reported time — it only costs
    /// one more solve, which is why screening rounds leave it off.
    pub profile_phases: bool,
}

impl Default for MeasureOptions {
    fn default() -> Self {
        MeasureOptions { warmup: 1, trials: 3, abandon_factor: 3.0, profile_phases: false }
    }
}

/// One candidate's measured behaviour.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub cfg: SolverConfig,
    /// One-time plan-build seconds (ordering + factorization + storage).
    pub setup_seconds: f64,
    /// Median iteration-loop seconds across completed trials.
    pub solve_seconds: f64,
    /// CG iterations of the measured solve.
    pub iterations: usize,
    pub converged: bool,
    /// Final relative residual of the measured solve (diagnostics for the
    /// non-converged case).
    pub final_relres: f64,
    /// Timed trials actually completed (< requested when abandoned).
    pub trials_run: usize,
    /// True when the measurement was cut off against the incumbent.
    pub abandoned: bool,
    /// Wall-time share per kernel phase
    /// ([`PHASE_NAMES`](crate::obs::flight::PHASE_NAMES) order) from the
    /// extra profiled solve; `None` unless
    /// [`MeasureOptions::profile_phases`] was set and the solve completed.
    pub phase_shares: Option<[f64; 5]>,
}

impl Measurement {
    /// The tuner's objective: amortized seconds per solve under the given
    /// reuse expectation (`∞` ⇒ pure time/solve). Non-converging
    /// configurations score `+∞` — a fast loop that never finishes is not
    /// a candidate.
    pub fn score(&self, expected_reuse: f64) -> f64 {
        if !self.converged {
            return f64::INFINITY;
        }
        amortized_seconds_per_solve(self.setup_seconds, self.solve_seconds, expected_reuse)
    }

    /// Display label of the measured configuration.
    pub fn label(&self) -> String {
        format!("{} x{}", self.cfg.label(), self.cfg.threads)
    }
}

/// Measure `cfg` on `(a, b)`: build the plan, open a session, run
/// warmup + timed trials on the fused path. `incumbent_solve` enables
/// early abandonment (see [`MeasureOptions::abandon_factor`]).
///
/// Errors propagate only from the plan build (e.g. a factorization
/// breakdown under this configuration) or a solver error — an *abandoned*
/// measurement is still `Ok`, flagged via [`Measurement::abandoned`].
pub fn measure(
    a: &Csr,
    b: &[f64],
    cfg: &SolverConfig,
    opts: &MeasureOptions,
    incumbent_solve: Option<f64>,
) -> Result<Measurement> {
    let plan = Arc::new(SolverPlan::build(a, cfg)?);
    measure_plan(&plan, b, opts, incumbent_solve)
}

/// [`measure`] on an **already-built** plan — the racing tuner re-times
/// survivors across rounds without re-paying ordering + factorization
/// (setup typically dwarfs one solve). The configuration, including the
/// reported [`Measurement::setup_seconds`], comes from the plan itself.
pub fn measure_plan(
    plan: &Arc<SolverPlan>,
    b: &[f64],
    opts: &MeasureOptions,
    incumbent_solve: Option<f64>,
) -> Result<Measurement> {
    let cfg = plan.cfg.clone();
    let setup_seconds = plan.setup.setup_seconds();
    let session = SolveSession::for_request(Arc::clone(plan), &cfg);
    let threshold = incumbent_solve
        .filter(|t| t.is_finite() && *t > 0.0)
        .map(|t| t * opts.abandon_factor.max(1.0));

    let mut times = Vec::with_capacity(opts.trials.max(1));
    let mut iterations = 0;
    let mut converged = false;
    let mut final_relres = f64::INFINITY;
    let mut abandoned = false;

    for _ in 0..opts.warmup {
        let out = session.solve(b)?;
        iterations = out.report.iterations;
        converged = out.report.converged;
        final_relres = out.report.final_relres;
        if threshold.is_some_and(|t| out.report.solve_seconds > t) {
            // Already hopeless during warmup: record the observed time so
            // the scoreboard stays total-ordered, and stop here.
            return Ok(Measurement {
                cfg,
                setup_seconds,
                solve_seconds: out.report.solve_seconds,
                iterations,
                converged,
                final_relres,
                trials_run: 0,
                abandoned: true,
                phase_shares: None,
            });
        }
    }
    for _ in 0..opts.trials.max(1) {
        let out = session.solve(b)?;
        iterations = out.report.iterations;
        converged = out.report.converged;
        final_relres = out.report.final_relres;
        times.push(out.report.solve_seconds);
        if threshold.is_some_and(|t| out.report.solve_seconds > t) {
            abandoned = true;
            break;
        }
    }
    let trials_run = times.len();
    // One extra *untimed* profiled solve after the trials: the reported
    // median above is never taken with the recorder on, so the phase
    // attribution can never perturb the number the tuner ranks on. A
    // failure here degrades to "no attribution", never to a lost
    // measurement.
    let phase_shares = (opts.profile_phases && !abandoned)
        .then(|| session.solve_with(b, &SolveOptions::profiled()).ok())
        .flatten()
        .and_then(|out| out.report.profile)
        .map(|p| p.phase_shares());
    Ok(Measurement {
        cfg,
        setup_seconds,
        solve_seconds: median(&mut times),
        iterations,
        converged,
        final_relres,
        trials_run,
        abandoned,
        phase_shares,
    })
}

/// Median of a non-empty sample (lower middle for even sizes — trial
/// counts are tiny and a deterministic pick beats interpolation noise).
fn median(xs: &mut [f64]) -> f64 {
    debug_assert!(!xs.is_empty());
    xs.sort_by(|p, q| p.total_cmp(q));
    xs[(xs.len() - 1) / 2]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OrderingKind, Scale};
    use crate::gen::suite;

    fn tiny_cfg(ordering: OrderingKind) -> SolverConfig {
        SolverConfig { ordering, bs: 8, w: 4, rtol: 1e-7, ..Default::default() }
    }

    #[test]
    fn median_is_deterministic() {
        assert_eq!(median(&mut [3.0]), 3.0);
        assert_eq!(median(&mut [5.0, 1.0, 3.0]), 3.0);
        assert_eq!(median(&mut [4.0, 1.0, 3.0, 2.0]), 2.0, "lower middle for even");
    }

    #[test]
    fn measure_produces_complete_record() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let cfg = tiny_cfg(OrderingKind::Hbmc);
        let m = measure(
            &d.matrix,
            &d.b,
            &cfg,
            &MeasureOptions { warmup: 1, trials: 3, ..Default::default() },
            None,
        )
        .unwrap();
        assert!(m.converged);
        assert!(m.iterations > 0);
        assert!(m.setup_seconds > 0.0);
        assert!(m.solve_seconds > 0.0);
        assert!(m.final_relres < 1e-6, "converged relres must be recorded: {}", m.final_relres);
        assert_eq!(m.trials_run, 3);
        assert!(!m.abandoned);
        assert!(m.phase_shares.is_none(), "attribution is opt-in");
        assert!(m.score(f64::INFINITY) == m.solve_seconds);
        assert!(m.score(1.0) > m.solve_seconds, "one-shot score must include setup");
    }

    #[test]
    fn profile_phases_attributes_the_solve() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let cfg = tiny_cfg(OrderingKind::Hbmc);
        let opts = MeasureOptions { trials: 1, profile_phases: true, ..Default::default() };
        let m = measure(&d.matrix, &d.b, &cfg, &opts, None).unwrap();
        let shares = m.phase_shares.expect("profiled measurement carries shares");
        assert!(shares.iter().all(|s| s.is_finite() && *s >= 0.0), "{shares:?}");
        // The recorder covers the whole fused region, so the busy + wait
        // shares account for most of the solve wall time.
        assert!(shares.iter().sum::<f64>() > 0.5, "{shares:?}");
    }

    #[test]
    fn hopeless_incumbent_threshold_abandons() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let cfg = tiny_cfg(OrderingKind::Bmc);
        // An absurdly fast incumbent (1 ns) forces abandonment immediately.
        let m = measure(&d.matrix, &d.b, &cfg, &MeasureOptions::default(), Some(1e-9)).unwrap();
        assert!(m.abandoned);
        assert!(m.trials_run <= 1);
        assert!(m.solve_seconds > 0.0, "abandoned runs still carry their observed time");
    }

    #[test]
    fn non_converging_config_scores_infinite() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let cfg = SolverConfig { max_iters: 2, ..tiny_cfg(OrderingKind::Hbmc) };
        let m = measure(&d.matrix, &d.b, &cfg, &MeasureOptions::default(), None).unwrap();
        assert!(!m.converged);
        assert_eq!(m.score(100.0), f64::INFINITY);
    }
}
