//! The persisted autotuning product: [`TunedProfile`] (the winning
//! structural configuration plus the measurements that justified it) and
//! [`ProfileStore`], a versioned JSON file of profiles keyed by
//! ([`Csr::fingerprint`](crate::sparse::csr::Csr::fingerprint),
//! [`HardwareSignature`]).
//!
//! The key design mirrors the paper's cross-machine result: the best
//! `(ordering, bs, w, spmv, threads)` differs between its three node types,
//! so a profile tuned on one machine must never be applied on another —
//! the hardware signature (detected SIMD level + core count) is part of
//! the lookup key, not advisory metadata.
//!
//! Durability contract (exercised by `tests/tune.rs`):
//!
//! * a **missing** store file is an empty store (first run),
//! * a **corrupt or truncated** file surfaces [`HbmcError::Parse`] —
//!   never a panic, never silently-empty (the caller decides whether to
//!   overwrite),
//! * a well-formed file with a **stale `schema_version`** is *ignored*
//!   (empty store): old profiles are measurements under a scheme we no
//!   longer understand, and re-tuning is cheap relative to serving with a
//!   misread config,
//! * [`save`](ProfileStore::save) writes atomically (temp file + rename)
//!   so a crashed writer cannot leave a half-written store behind.
//!
//! The 64-bit matrix fingerprint is serialized as a hex *string* — JSON
//! numbers are IEEE doubles and silently lose bits above 2^53.

use std::fmt;
use std::path::{Path, PathBuf};
use std::str::FromStr;

use crate::config::{OrderingKind, SolverConfig, SpmvKind};
use crate::error::{HbmcError, Result};
use crate::util::json::{json_string, Json};

/// Store-file schema version; bump on any incompatible field change.
pub const SCHEMA_VERSION: u64 = 1;

/// SIMD capability level of the host, the axis the paper's three machines
/// differ on (AVX2 → w = 4, AVX-512 → w = 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SimdLevel {
    Scalar,
    Avx2,
    Avx512,
}

impl SimdLevel {
    /// Runtime detection (cached by the intrinsics, cheap to call).
    pub fn detect() -> SimdLevel {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        {
            if std::arch::is_x86_feature_detected!("avx512f") {
                return SimdLevel::Avx512;
            }
            if std::arch::is_x86_feature_detected!("avx2") {
                return SimdLevel::Avx2;
            }
        }
        SimdLevel::Scalar
    }

    /// The natural HBMC/SELL width for this level (doubles per vector
    /// register; scalar hosts still benefit from short blocked widths).
    pub fn natural_w(&self) -> usize {
        match self {
            SimdLevel::Scalar => 4,
            SimdLevel::Avx2 => 4,
            SimdLevel::Avx512 => 8,
        }
    }
}

impl FromStr for SimdLevel {
    type Err = HbmcError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(SimdLevel::Scalar),
            "avx2" => Ok(SimdLevel::Avx2),
            "avx512" => Ok(SimdLevel::Avx512),
            other => Err(HbmcError::parse(format!(
                "unknown SIMD level {other:?} (scalar|avx2|avx512)"
            ))),
        }
    }
}

impl fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
        })
    }
}

/// The part of the profile key that describes the machine: detected SIMD
/// level and logical core count. Two hosts with the same signature are
/// treated as interchangeable for tuning purposes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HardwareSignature {
    pub simd: SimdLevel,
    pub cores: usize,
}

impl HardwareSignature {
    /// Detect the current host (SIMD features + available parallelism).
    pub fn detect() -> HardwareSignature {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        HardwareSignature { simd: SimdLevel::detect(), cores }
    }
}

impl fmt::Display for HardwareSignature {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}", self.simd, self.cores)
    }
}

/// Lookup key of one profile: which matrix, on which machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ProfileKey {
    pub fingerprint: u64,
    pub hardware: HardwareSignature,
}

/// The persisted product of one [`tune`](crate::tune::tune_matrix) run:
/// the winning structural configuration plus the measurements behind it.
/// Convergence controls (rtol / max_iters / shift) are deliberately *not*
/// stored — tuning picks the fast shape, never the accuracy contract; they
/// are taken from the config the profile is applied onto
/// ([`apply_to`](TunedProfile::apply_to)).
#[derive(Debug, Clone, PartialEq)]
pub struct TunedProfile {
    pub fingerprint: u64,
    pub hardware: HardwareSignature,
    // --- winning structural configuration --------------------------------
    pub ordering: OrderingKind,
    pub bs: usize,
    pub w: usize,
    pub spmv: SpmvKind,
    pub sell_sigma: Option<usize>,
    pub threads: usize,
    pub use_intrinsics: bool,
    // --- evidence --------------------------------------------------------
    /// Median iteration-loop seconds per solve under the winning config.
    pub solve_seconds: f64,
    /// One-time plan-build seconds under the winning config.
    pub setup_seconds: f64,
    /// CG iterations of the measured solve (config-dependent: orderings
    /// trade iteration count against per-iteration speed).
    pub iterations: usize,
    /// Median seconds per solve under the *default* config the search
    /// started from — the denominator of [`speedup`](TunedProfile::speedup).
    pub baseline_solve_seconds: f64,
    /// Wall-time share per kernel phase under the winning config
    /// ([`PHASE_NAMES`](crate::obs::flight::PHASE_NAMES) order: spmv,
    /// trisolve-fwd, trisolve-bwd, blas1, barrier-wait), from the tuner's
    /// profiled attribution solve. `None` for profiles from store files
    /// written before this field existed (optional on parse — no schema
    /// bump).
    pub phase_shares: Option<[f64; 5]>,
    /// Unix seconds when the profile was produced (0 if clock unavailable).
    pub created_unix: u64,
}

impl TunedProfile {
    pub fn key(&self) -> ProfileKey {
        ProfileKey { fingerprint: self.fingerprint, hardware: self.hardware }
    }

    /// Overlay the tuned structural choice onto `base`, keeping `base`'s
    /// convergence controls (rtol, max_iters, shift) and service-level
    /// queue tuning untouched.
    pub fn apply_to(&self, base: &SolverConfig) -> SolverConfig {
        SolverConfig {
            ordering: self.ordering,
            bs: self.bs,
            w: self.w,
            spmv: self.spmv,
            sell_sigma: self.sell_sigma,
            threads: self.threads,
            use_intrinsics: self.use_intrinsics,
            ..base.clone()
        }
    }

    /// Label of the tuned configuration, e.g. `HBMC(bs=16,w=8,sell) x4`.
    pub fn label(&self) -> String {
        format!("{}(bs={},w={},{}) x{}", self.ordering, self.bs, self.w, self.spmv, self.threads)
    }

    /// Measured baseline-over-tuned time ratio (> 1 means the profile is
    /// faster than the default configuration).
    pub fn speedup(&self) -> f64 {
        if self.solve_seconds > 0.0 {
            self.baseline_solve_seconds / self.solve_seconds
        } else {
            1.0
        }
    }

    /// One profile as a JSON object (fragment of the store document).
    pub fn to_json(&self) -> String {
        let sigma = match self.sell_sigma {
            Some(s) => s.to_string(),
            None => "null".to_string(),
        };
        let shares = match &self.phase_shares {
            Some(s) => {
                let body: Vec<String> = s.iter().map(|v| v.to_string()).collect();
                format!("[{}]", body.join(", "))
            }
            None => "null".to_string(),
        };
        format!(
            "{{\"fingerprint\": {}, \"simd\": {}, \"cores\": {}, \
             \"ordering\": {}, \"bs\": {}, \"w\": {}, \"spmv\": {}, \
             \"sell_sigma\": {sigma}, \"threads\": {}, \"use_intrinsics\": {}, \
             \"solve_seconds\": {}, \"setup_seconds\": {}, \"iterations\": {}, \
             \"baseline_solve_seconds\": {}, \"phase_shares\": {shares}, \
             \"created_unix\": {}}}",
            json_string(&format!("{:#018x}", self.fingerprint)),
            json_string(&self.simd_str()),
            self.hardware.cores,
            json_string(&self.ordering.to_string().to_ascii_lowercase()),
            self.bs,
            self.w,
            json_string(&self.spmv.to_string()),
            self.threads,
            self.use_intrinsics,
            self.solve_seconds,
            self.setup_seconds,
            self.iterations,
            self.baseline_solve_seconds,
            self.created_unix,
        )
    }

    fn simd_str(&self) -> String {
        self.hardware.simd.to_string()
    }

    /// Parse one profile object; any missing/ill-typed member is
    /// [`HbmcError::Parse`].
    pub fn from_json(j: &Json) -> Result<TunedProfile> {
        fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
            j.get(key)
                .ok_or_else(|| HbmcError::parse(format!("profile: missing field {key:?}")))
        }
        fn num(j: &Json, key: &str) -> Result<f64> {
            field(j, key)?
                .as_f64()
                .ok_or_else(|| HbmcError::parse(format!("profile: field {key:?} is not a number")))
        }
        fn uint(j: &Json, key: &str) -> Result<usize> {
            field(j, key)?.as_usize().ok_or_else(|| {
                HbmcError::parse(format!("profile: field {key:?} is not a non-negative integer"))
            })
        }
        fn text<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
            field(j, key)?
                .as_str()
                .ok_or_else(|| HbmcError::parse(format!("profile: field {key:?} is not a string")))
        }
        let fp_text = text(j, "fingerprint")?;
        let fp_hex = fp_text
            .strip_prefix("0x")
            .ok_or_else(|| HbmcError::parse("profile: fingerprint must be a 0x-hex string"))?;
        let fingerprint = u64::from_str_radix(fp_hex, 16)
            .map_err(|_| HbmcError::parse(format!("profile: bad fingerprint {fp_text:?}")))?;
        let sigma_field = field(j, "sell_sigma")?;
        let sell_sigma = if sigma_field.is_null() {
            None
        } else {
            Some(sigma_field.as_usize().ok_or_else(|| {
                HbmcError::parse("profile: sell_sigma must be null or a non-negative integer")
            })?)
        };
        // Optional (added after schema 1 stores existed): absent or null
        // both mean "no attribution recorded" — never a parse error.
        let phase_shares = match j.get("phase_shares") {
            Some(v) if !v.is_null() => {
                let arr = v.as_arr().ok_or_else(|| {
                    HbmcError::parse("profile: phase_shares must be null or an array")
                })?;
                if arr.len() != 5 {
                    return Err(HbmcError::parse(format!(
                        "profile: phase_shares must have 5 entries, got {}",
                        arr.len()
                    )));
                }
                let mut shares = [0.0f64; 5];
                for (i, e) in arr.iter().enumerate() {
                    shares[i] = e.as_f64().ok_or_else(|| {
                        HbmcError::parse("profile: phase_shares entries must be numbers")
                    })?;
                }
                Some(shares)
            }
            _ => None,
        };
        let created = num(j, "created_unix")?;
        Ok(TunedProfile {
            fingerprint,
            hardware: HardwareSignature {
                simd: text(j, "simd")?.parse()?,
                cores: uint(j, "cores")?,
            },
            ordering: text(j, "ordering")?.parse()?,
            bs: uint(j, "bs")?,
            w: uint(j, "w")?,
            spmv: text(j, "spmv")?.parse()?,
            sell_sigma,
            threads: uint(j, "threads")?,
            use_intrinsics: field(j, "use_intrinsics")?
                .as_bool()
                .ok_or_else(|| HbmcError::parse("profile: use_intrinsics must be a boolean"))?,
            solve_seconds: num(j, "solve_seconds")?,
            setup_seconds: num(j, "setup_seconds")?,
            iterations: uint(j, "iterations")?,
            baseline_solve_seconds: num(j, "baseline_solve_seconds")?,
            phase_shares,
            created_unix: if created >= 0.0 { created as u64 } else { 0 },
        })
    }
}

/// Versioned on-disk store of [`TunedProfile`]s; see module docs for the
/// durability contract. One entry per [`ProfileKey`]
/// ([`put`](ProfileStore::put) replaces).
#[derive(Debug, Clone)]
pub struct ProfileStore {
    path: Option<PathBuf>,
    profiles: Vec<TunedProfile>,
}

impl ProfileStore {
    /// An empty, path-less store (never persisted until
    /// [`save_to`](ProfileStore::save_to)).
    pub fn in_memory() -> ProfileStore {
        ProfileStore { path: None, profiles: Vec::new() }
    }

    /// The store location used when none is given explicitly: the
    /// `HBMC_PROFILE_STORE` environment variable, else
    /// `hbmc_profiles.json` in the current directory.
    pub fn default_path() -> PathBuf {
        std::env::var_os("HBMC_PROFILE_STORE")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("hbmc_profiles.json"))
    }

    /// Open a store file. Missing file ⇒ empty store bound to `path`;
    /// malformed content ⇒ [`HbmcError::Parse`]; well-formed but stale
    /// `schema_version` ⇒ empty store (profiles under an old schema are
    /// dropped; the next `save` rewrites the file at [`SCHEMA_VERSION`]).
    pub fn open(path: impl AsRef<Path>) -> Result<ProfileStore> {
        let path = path.as_ref();
        let text = match std::fs::read_to_string(path) {
            Ok(text) => text,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(ProfileStore { path: Some(path.to_path_buf()), profiles: Vec::new() })
            }
            Err(e) => return Err(HbmcError::io(format!("reading {}", path.display()), e)),
        };
        let profiles = Self::parse_document(&text)?;
        Ok(ProfileStore { path: Some(path.to_path_buf()), profiles })
    }

    /// Parse a store document; `Ok(vec![])` for a stale schema version.
    pub fn parse_document(text: &str) -> Result<Vec<TunedProfile>> {
        let doc = Json::parse(text)?;
        let version = doc
            .get("schema_version")
            .and_then(Json::as_u64)
            .ok_or_else(|| HbmcError::parse("profile store: missing schema_version"))?;
        if version != SCHEMA_VERSION {
            // Stale (or future) schema: not corrupt, just unusable —
            // ignore and let the caller re-tune/rewrite.
            return Ok(Vec::new());
        }
        let entries = doc
            .get("profiles")
            .and_then(Json::as_arr)
            .ok_or_else(|| HbmcError::parse("profile store: missing profiles array"))?;
        entries.iter().map(TunedProfile::from_json).collect()
    }

    /// Number of profiles held.
    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    /// The path this store loads from / saves to, if bound to one.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn iter(&self) -> impl Iterator<Item = &TunedProfile> {
        self.profiles.iter()
    }

    /// The profile for `(matrix, machine)`, if one is stored.
    pub fn get(&self, key: &ProfileKey) -> Option<&TunedProfile> {
        self.profiles.iter().find(|p| p.key() == *key)
    }

    /// The profile for `matrix` on *this* machine (fingerprint + detected
    /// [`HardwareSignature`]) — the one-call lookup every consumer of a
    /// store file wants (CLI `solve --auto`, benches via `HBMC_PROFILE`).
    pub fn lookup(&self, matrix: &crate::sparse::csr::Csr) -> Option<&TunedProfile> {
        self.get(&ProfileKey {
            fingerprint: matrix.fingerprint(),
            hardware: HardwareSignature::detect(),
        })
    }

    /// Insert a profile, replacing any entry with the same key.
    pub fn put(&mut self, profile: TunedProfile) {
        let key = profile.key();
        self.profiles.retain(|p| p.key() != key);
        self.profiles.push(profile);
    }

    /// The whole store as a JSON document.
    pub fn to_json_text(&self) -> String {
        let body: Vec<String> =
            self.profiles.iter().map(|p| format!("    {}", p.to_json())).collect();
        format!(
            "{{\n  \"schema_version\": {SCHEMA_VERSION},\n  \"profiles\": [\n{}\n  ]\n}}\n",
            body.join(",\n")
        )
    }

    /// Persist to the bound path ([`open`](ProfileStore::open)'s argument).
    pub fn save(&self) -> Result<()> {
        match &self.path {
            Some(path) => self.save_to(path.clone()),
            None => Err(HbmcError::invalid_config(
                "profile store has no path; use save_to or open it from a file",
            )),
        }
    }

    /// Persist to `path` atomically (temp file in the same directory +
    /// rename), so readers never observe a truncated store.
    pub fn save_to(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json_text())
            .map_err(|e| HbmcError::io(format!("writing {}", tmp.display()), e))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| HbmcError::io(format!("renaming {} into place", tmp.display()), e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(fp: u64) -> TunedProfile {
        TunedProfile {
            fingerprint: fp,
            hardware: HardwareSignature { simd: SimdLevel::Avx2, cores: 4 },
            ordering: OrderingKind::Hbmc,
            bs: 16,
            w: 4,
            spmv: SpmvKind::Sell,
            sell_sigma: Some(64),
            threads: 2,
            use_intrinsics: true,
            solve_seconds: 1.25e-3,
            setup_seconds: 4.0e-2,
            iterations: 137,
            baseline_solve_seconds: 2.5e-3,
            phase_shares: Some([0.35, 0.3, 0.25, 0.05, 0.05]),
            created_unix: 1_753_000_000,
        }
    }

    #[test]
    fn profile_json_round_trips() {
        let p = sample(0xdead_beef_cafe_f00d);
        let j = Json::parse(&p.to_json()).unwrap();
        assert_eq!(TunedProfile::from_json(&j).unwrap(), p);
    }

    #[test]
    fn phase_shares_are_optional_on_parse() {
        // Null round-trips to None...
        let mut p = sample(11);
        p.phase_shares = None;
        let j = Json::parse(&p.to_json()).unwrap();
        assert_eq!(TunedProfile::from_json(&j).unwrap().phase_shares, None);
        // ...and a pre-existing store object without the field parses too
        // (the field was added without a schema bump).
        let legacy = "{\"fingerprint\": \"0x000000000000002a\", \"simd\": \"avx2\", \
                      \"cores\": 4, \"ordering\": \"hbmc\", \"bs\": 16, \"w\": 4, \
                      \"spmv\": \"sell\", \"sell_sigma\": null, \"threads\": 2, \
                      \"use_intrinsics\": true, \"solve_seconds\": 1e-3, \
                      \"setup_seconds\": 1e-2, \"iterations\": 100, \
                      \"baseline_solve_seconds\": 2e-3, \"created_unix\": 0}";
        let j = Json::parse(legacy).unwrap();
        let parsed = TunedProfile::from_json(&j).unwrap();
        assert_eq!(parsed.fingerprint, 0x2a);
        assert_eq!(parsed.phase_shares, None);
        // A malformed array is still a typed parse error.
        let bad = legacy.replace(
            "\"baseline_solve_seconds\": 2e-3",
            "\"baseline_solve_seconds\": 2e-3, \"phase_shares\": [1, 2]",
        );
        let j = Json::parse(&bad).unwrap();
        assert!(matches!(TunedProfile::from_json(&j), Err(HbmcError::Parse(_))));
    }

    #[test]
    fn fingerprint_survives_above_2_pow_53() {
        // A JSON number would lose these low bits; the hex string must not.
        let p = sample(u64::MAX - 1);
        let j = Json::parse(&p.to_json()).unwrap();
        assert_eq!(TunedProfile::from_json(&j).unwrap().fingerprint, u64::MAX - 1);
    }

    #[test]
    fn store_document_round_trips_and_replaces_on_put() {
        let mut store = ProfileStore::in_memory();
        store.put(sample(1));
        store.put(sample(2));
        let mut newer = sample(1);
        newer.bs = 32;
        store.put(newer.clone());
        assert_eq!(store.len(), 2, "same key must replace, not append");
        let parsed = ProfileStore::parse_document(&store.to_json_text()).unwrap();
        assert_eq!(parsed.len(), 2);
        assert!(parsed.contains(&newer));
    }

    #[test]
    fn stale_schema_is_ignored_not_an_error() {
        let text = "{\"schema_version\": 999, \"profiles\": [{\"garbage\": true}]}";
        assert_eq!(ProfileStore::parse_document(text).unwrap(), Vec::new());
    }

    #[test]
    fn corrupt_documents_are_parse_errors() {
        for bad in [
            "",
            "{",
            "[1, 2]",
            "{\"profiles\": []}",                          // missing version
            "{\"schema_version\": 1}",                     // missing profiles
            "{\"schema_version\": 1, \"profiles\": [{}]}", // empty profile
        ] {
            let err = ProfileStore::parse_document(bad).unwrap_err();
            assert!(matches!(err, HbmcError::Parse(_)), "{bad:?} -> {err:?}");
        }
    }

    #[test]
    fn apply_to_keeps_convergence_contract() {
        let p = sample(7);
        let base = SolverConfig { rtol: 1e-11, max_iters: 123, shift: 0.3, ..Default::default() };
        let cfg = p.apply_to(&base);
        assert_eq!(cfg.ordering, OrderingKind::Hbmc);
        assert_eq!((cfg.bs, cfg.w, cfg.threads), (16, 4, 2));
        assert_eq!(cfg.sell_sigma, Some(64));
        assert_eq!(cfg.rtol, 1e-11, "tuning must not change the accuracy contract");
        assert_eq!(cfg.max_iters, 123);
        assert_eq!(cfg.shift, 0.3);
    }

    #[test]
    fn speedup_is_baseline_over_tuned() {
        let p = sample(3);
        assert!((p.speedup() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn simd_level_round_trips() {
        for lvl in [SimdLevel::Scalar, SimdLevel::Avx2, SimdLevel::Avx512] {
            assert_eq!(lvl.to_string().parse::<SimdLevel>().unwrap(), lvl);
        }
        assert!(matches!("sse9".parse::<SimdLevel>(), Err(HbmcError::Parse(_))));
    }
}
