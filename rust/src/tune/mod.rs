//! Autotuning: per-matrix, per-hardware configuration search with a
//! persisted profile store.
//!
//! The paper's headline numbers depend on picking the right ordering
//! parameters *per machine* — it sweeps `bs ∈ {8, 16, 32}` and matches
//! `w` to the SIMD width, and the winner differs across its three node
//! types (Table 4.1). This subsystem replaces "the operator guesses well"
//! with a measured search:
//!
//! * [`space`] — enumerates the valid configuration grid (ordering × `bs`
//!   × `w` × SpMV storage × σ × threads), honouring the HBMC
//!   `bs % w == 0` constraint and the machine's core count, and
//!   collapsing axes that cannot reach a kernel;
//! * [`measure`] — warmup + median timed trials through a real
//!   [`SolveSession`](crate::coordinator::session::SolveSession) on the
//!   fused single-dispatch path, with setup time, iterations and
//!   time/solve recorded separately so reuse-heavy and one-shot workloads
//!   score differently;
//! * [`tuner`] — exhaustive grid for small spaces, successive
//!   halving/racing with early abandonment against the incumbent for
//!   large ones; the incumbent always competes in the final round, so
//!   applying a profile can never regress the caller;
//! * [`profile`] — [`TunedProfile`]s persisted in a versioned JSON store
//!   keyed by ([`Csr::fingerprint`](crate::sparse::csr::Csr::fingerprint),
//!   [`HardwareSignature`] = detected SIMD level + core count).
//!
//! End-to-end, the `SolverService` wires this in as
//! [`tune`](crate::api::SolverService::tune) (search + install + persist)
//! and auto-applies a stored profile to any request that does not carry
//! an explicit config override (opt out per request with
//! [`SolveRequest::no_profile`](crate::api::SolveRequest::no_profile));
//! profile applications are visible as `ServiceStats::profile_hits`. The
//! CLI exposes `hbmc tune` and `hbmc solve --auto`.

pub mod measure;
pub mod profile;
pub mod space;
pub mod tuner;

pub use measure::{measure, measure_plan, MeasureOptions, Measurement};
pub use profile::{HardwareSignature, ProfileKey, ProfileStore, SimdLevel, TunedProfile};
pub use space::ConfigSpace;
pub use tuner::{tune_matrix, TuneOptions, TuneOutcome, TuneStrategy};
