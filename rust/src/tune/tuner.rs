//! The search driver: exhaustive grid for small spaces, successive
//! halving ("racing") for large ones.
//!
//! Both strategies share the invariants that make tuning safe to apply
//! blindly:
//!
//! * the **incumbent** (the configuration the caller already runs —
//!   `base`) is measured first and carried into every later round, so the
//!   winner's score can never exceed the incumbent's on the same
//!   measurements — tuning is monotone: apply the profile, or keep what
//!   you had, never regress;
//! * candidates are **abandoned early** once a single solve shows them
//!   `abandon_factor ×` behind the best time seen so far
//!   ([`measure`](crate::tune::measure::measure)), so a wide grid costs
//!   little more than its plausible region;
//! * a candidate whose *plan build fails* (e.g. IC(0) breakdown under an
//!   aggressive configuration) is skipped, not fatal — only the
//!   incumbent's failure aborts the search.
//!
//! Successive halving: round 1 measures every candidate with one trial,
//! then repeatedly keeps the better-scoring half with a doubled trial
//! budget until at most [`TuneOptions::finalists`] remain; finalists get
//! the full warmup + trials treatment and the best score wins.

use std::sync::Arc;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::config::SolverConfig;
use crate::error::{HbmcError, Result};
use crate::solver::plan::SolverPlan;
use crate::sparse::csr::Csr;
use crate::tune::measure::{measure_plan, MeasureOptions, Measurement};
use crate::tune::profile::{HardwareSignature, TunedProfile};
use crate::tune::space::ConfigSpace;

/// How the candidate list is searched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuneStrategy {
    /// Exhaustive below [`TuneOptions::exhaustive_threshold`] candidates,
    /// racing above.
    Auto,
    /// Full warmup + trials for every candidate.
    Exhaustive,
    /// Successive halving with early abandonment (see module docs).
    Racing,
}

impl std::str::FromStr for TuneStrategy {
    type Err = crate::error::HbmcError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(TuneStrategy::Auto),
            "exhaustive" | "grid" => Ok(TuneStrategy::Exhaustive),
            "racing" | "halving" => Ok(TuneStrategy::Racing),
            other => Err(crate::error::HbmcError::parse(format!(
                "unknown tune strategy {other:?} (auto|exhaustive|racing)"
            ))),
        }
    }
}

impl std::fmt::Display for TuneStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TuneStrategy::Auto => "auto",
            TuneStrategy::Exhaustive => "exhaustive",
            TuneStrategy::Racing => "racing",
        })
    }
}

/// Search controls; the defaults suit a CI-sized matrix. For serving-only
/// scoring set `expected_reuse = f64::INFINITY`.
#[derive(Debug, Clone)]
pub struct TuneOptions {
    /// The candidate grid; `None` ⇒ [`ConfigSpace::for_hardware`] on the
    /// detected machine.
    pub space: Option<ConfigSpace>,
    /// Untimed warmup solves per finalist measurement.
    pub warmup: usize,
    /// Timed trials per finalist measurement (median reported).
    pub trials: usize,
    /// Solves one plan build is expected to amortize over — the knob that
    /// separates reuse-heavy serving (large / infinite) from one-shot
    /// workloads (1).
    pub expected_reuse: f64,
    pub strategy: TuneStrategy,
    /// `Auto` strategy switches to racing above this many candidates.
    pub exhaustive_threshold: usize,
    /// Racing keeps halving until at most this many candidates remain.
    pub finalists: usize,
    /// Early-abandonment multiplier vs the incumbent best time.
    pub abandon_factor: f64,
    /// Hard cap on the enumerated candidate list (incumbent always kept).
    pub max_candidates: usize,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions {
            space: None,
            warmup: 1,
            trials: 3,
            expected_reuse: 100.0,
            strategy: TuneStrategy::Auto,
            exhaustive_threshold: 12,
            finalists: 4,
            abandon_factor: 3.0,
            max_candidates: 96,
        }
    }
}

impl TuneOptions {
    /// CI-sized options: the [`ConfigSpace::quick`] grid, two trials, one
    /// warmup.
    pub fn quick() -> TuneOptions {
        let hw = HardwareSignature::detect();
        TuneOptions {
            space: Some(ConfigSpace::quick(&hw)),
            warmup: 1,
            trials: 2,
            ..Default::default()
        }
    }
}

/// Everything a tune run learned: the persistable profile plus the full
/// scoreboard for reporting.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// The winner, packaged for the [`ProfileStore`](crate::tune::ProfileStore).
    pub profile: TunedProfile,
    /// The incumbent's final-round measurement.
    pub baseline: Measurement,
    /// The winner's final-round measurement (same object the profile was
    /// built from).
    pub winner: Measurement,
    /// All final-round measurements, best score first.
    pub finalists: Vec<Measurement>,
    /// Candidates actually considered (post-dedup, post-cap).
    pub candidates: usize,
    /// Enumerated candidates dropped by [`TuneOptions::max_candidates`]
    /// without being measured — non-zero means the space was not fully
    /// covered (no silent caps).
    pub truncated: usize,
    /// Candidates cut off early against the incumbent.
    pub abandoned: usize,
    /// Candidates dropped by an error — a failed plan build (e.g. IC(0)
    /// breakdown under an aggressive configuration) or a solver error
    /// during measurement.
    pub failed: usize,
}

/// A pool entry: the latest measurement plus the built plan, retained so
/// later racing rounds re-time without re-paying ordering + IC(0).
struct Survivor {
    m: Measurement,
    plan: Arc<SolverPlan>,
}

/// The shared measurement bookkeeping of every search round: abandonment
/// and build-failure counters, the surviving pool, and the running
/// incumbent-best time that drives early abandonment.
struct SearchState {
    pool: Vec<Survivor>,
    abandoned: usize,
    failed: usize,
    incumbent_time: f64,
}

impl SearchState {
    /// Fold one measurement result into the state (see module docs: an
    /// abandoned candidate is counted and dropped, an errored candidate —
    /// failed plan build or solver error — is counted and skipped, a
    /// survivor may lower the incumbent time).
    fn record(&mut self, result: Result<Survivor>) {
        match result {
            Ok(s) if s.m.abandoned => self.abandoned += 1,
            Ok(s) => {
                if s.m.converged {
                    self.incumbent_time = self.incumbent_time.min(s.m.solve_seconds);
                }
                self.pool.push(s);
            }
            Err(_) => self.failed += 1,
        }
    }

    /// The abandonment reference passed to [`measure_plan`]: the incumbent-best
    /// time in reuse-heavy regimes, `None` when setup amortization
    /// dominates the score (small `expected_reuse`) — there a candidate
    /// with a slow solve but cheap setup can still win on the actual
    /// objective, so cutting it off on solve time alone would discard the
    /// winner.
    fn abandon_ref(&self, expected_reuse: f64) -> Option<f64> {
        (!expected_reuse.is_finite() || expected_reuse >= 10.0).then_some(self.incumbent_time)
    }
}

/// Search the configuration space for `(a, b)` starting from `base`; see
/// module docs for the strategy and its invariants. `b` should be a
/// representative right-hand side (the service uses `A·1`).
pub fn tune_matrix(
    a: &Csr,
    b: &[f64],
    base: &SolverConfig,
    opts: &TuneOptions,
) -> Result<TuneOutcome> {
    // An invalid incumbent is the caller's bug, surfaced typed here —
    // enumerate() would otherwise drop it and silently crown an arbitrary
    // grid point "the baseline".
    base.validate()?;
    let hw = HardwareSignature::detect();
    let space = opts.space.clone().unwrap_or_else(|| ConfigSpace::for_hardware(&hw));
    let mut candidates = space.enumerate(base);
    let enumerated = candidates.len();
    candidates.truncate(opts.max_candidates.max(1)); // slot 0 is the incumbent
    let considered = candidates.len();
    let reuse = opts.expected_reuse;

    // The incumbent is measured with the full budget and no threshold; its
    // failure is the caller's failure (their default config doesn't run).
    // Finalists (and the incumbent) additionally attribute their time
    // across kernel phases — the `tune --explain` evidence. Screening
    // rounds skip it (one extra solve per candidate adds up).
    let final_opts = MeasureOptions {
        warmup: opts.warmup,
        trials: opts.trials.max(1),
        profile_phases: true,
        ..screen_opts(opts)
    };
    let baseline_plan = Arc::new(SolverPlan::build(a, &candidates[0])?);
    let baseline = measure_plan(&baseline_plan, b, &final_opts, None)?;
    let mut st = SearchState {
        pool: Vec::new(),
        abandoned: 0,
        failed: 0,
        incumbent_time: baseline.solve_seconds,
    };

    let rest: Vec<SolverConfig> = candidates.drain(1..).collect();
    let use_racing = match opts.strategy {
        TuneStrategy::Exhaustive => false,
        TuneStrategy::Racing => true,
        TuneStrategy::Auto => considered > opts.exhaustive_threshold,
    };

    // Measure the non-incumbent candidates down to a finalist pool.
    if use_racing {
        // Round 1: one untimed-warmup-free trial per candidate.
        let mut round_opts = screen_opts(opts);
        for cfg in &rest {
            st.record(build_one(a, b, cfg, &round_opts, st.abandon_ref(reuse)));
        }
        // Halve with a doubled budget until the finalist pool is reached.
        while st.pool.len() > opts.finalists.max(1) {
            st.pool.sort_by(|p, q| p.m.score(reuse).total_cmp(&q.m.score(reuse)));
            st.pool.truncate(st.pool.len().div_ceil(2).max(opts.finalists.max(1)));
            if st.pool.len() <= opts.finalists.max(1) {
                break;
            }
            round_opts.trials = (round_opts.trials * 2).min(opts.trials.max(1));
            let survivors = std::mem::take(&mut st.pool);
            for s in survivors {
                st.record(retime_one(&s, b, &round_opts, st.abandon_ref(reuse)));
            }
        }
        // Finalists get the full treatment (fresh warmup + full trials).
        let survivors = std::mem::take(&mut st.pool);
        for s in survivors {
            st.record(retime_one(&s, b, &final_opts, st.abandon_ref(reuse)));
        }
    } else {
        for cfg in &rest {
            st.record(build_one(a, b, cfg, &final_opts, st.abandon_ref(reuse)));
        }
    }

    // Final scoreboard: the incumbent always competes.
    let mut finalists: Vec<Measurement> = st.pool.into_iter().map(|s| s.m).collect();
    finalists.push(baseline.clone());
    finalists.sort_by(|p, q| p.score(reuse).total_cmp(&q.score(reuse)));
    let winner = finalists[0].clone();
    if !winner.converged {
        // Every measured candidate (incumbent included) scored +∞: there
        // is nothing meaningful to install, and silently crowning an
        // arbitrary grid point would hand auto-application a regression.
        return Err(HbmcError::NotConverged {
            iterations: winner.iterations,
            relres: winner.final_relres,
        });
    }

    let created_unix = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let profile = TunedProfile {
        fingerprint: a.fingerprint(),
        hardware: hw,
        ordering: winner.cfg.ordering,
        bs: winner.cfg.bs,
        w: winner.cfg.w,
        spmv: winner.cfg.spmv,
        sell_sigma: winner.cfg.sell_sigma,
        threads: winner.cfg.threads,
        use_intrinsics: winner.cfg.use_intrinsics,
        solve_seconds: winner.solve_seconds,
        setup_seconds: winner.setup_seconds,
        iterations: winner.iterations,
        baseline_solve_seconds: baseline.solve_seconds,
        phase_shares: winner.phase_shares,
        created_unix,
    };
    Ok(TuneOutcome {
        profile,
        baseline,
        winner,
        finalists,
        candidates: considered,
        truncated: enumerated - considered,
        abandoned: st.abandoned,
        failed: st.failed,
    })
}

/// Round-1 screening budget: no warmup, one trial, caller's abandonment.
fn screen_opts(opts: &TuneOptions) -> MeasureOptions {
    MeasureOptions {
        warmup: 0,
        trials: 1,
        abandon_factor: opts.abandon_factor,
        profile_phases: false,
    }
}

/// Build one challenger's plan and take its first measurement; the plan is
/// retained in the [`Survivor`] so later rounds only re-time.
fn build_one(
    a: &Csr,
    b: &[f64],
    cfg: &SolverConfig,
    m_opts: &MeasureOptions,
    abandon: Option<f64>,
) -> Result<Survivor> {
    let plan = Arc::new(SolverPlan::build(a, cfg)?);
    let m = measure_plan(&plan, b, m_opts, abandon)?;
    Ok(Survivor { m, plan })
}

/// Re-time a surviving candidate on its already-built plan — no repeated
/// ordering/factorization across racing rounds.
fn retime_one(
    s: &Survivor,
    b: &[f64],
    m_opts: &MeasureOptions,
    abandon: Option<f64>,
) -> Result<Survivor> {
    let m = measure_plan(&s.plan, b, m_opts, abandon)?;
    Ok(Survivor { m, plan: Arc::clone(&s.plan) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OrderingKind, Scale, SpmvKind};
    use crate::gen::suite;

    fn small_space() -> ConfigSpace {
        ConfigSpace {
            orderings: vec![OrderingKind::Bmc, OrderingKind::Hbmc],
            block_sizes: vec![8],
            widths: vec![4],
            spmvs: vec![SpmvKind::Crs, SpmvKind::Sell],
            sigma_slices: vec![None],
            threads: vec![1],
        }
    }

    fn base() -> SolverConfig {
        SolverConfig { ordering: OrderingKind::Hbmc, bs: 8, w: 4, rtol: 1e-7, ..Default::default() }
    }

    #[test]
    fn winner_never_loses_to_the_incumbent() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let opts = TuneOptions {
            space: Some(small_space()),
            trials: 2,
            expected_reuse: f64::INFINITY,
            ..Default::default()
        };
        let out = tune_matrix(&d.matrix, &d.b, &base(), &opts).unwrap();
        assert!(out.winner.converged);
        assert!(
            out.winner.score(f64::INFINITY) <= out.baseline.score(f64::INFINITY),
            "winner {} must not score worse than incumbent {}",
            out.winner.label(),
            out.baseline.label()
        );
        // With reuse = ∞ the score IS time/solve, so the profile's
        // acceptance bound holds exactly.
        assert!(out.profile.solve_seconds <= out.profile.baseline_solve_seconds);
        assert!(out.candidates >= out.finalists.len());
        // Finalists run under the full budget, which includes the phase
        // attribution pass — the winner's breakdown rides on the profile.
        let shares = out.profile.phase_shares.expect("winner carries phase shares");
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9, "{shares:?}");
    }

    #[test]
    fn racing_reaches_a_finalist_pool() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let opts = TuneOptions {
            space: Some(ConfigSpace {
                block_sizes: vec![8, 16],
                threads: vec![1],
                ..small_space()
            }),
            strategy: TuneStrategy::Racing,
            trials: 2,
            finalists: 3,
            ..Default::default()
        };
        let out = tune_matrix(&d.matrix, &d.b, &base(), &opts).unwrap();
        assert!(out.winner.converged);
        // Finalist pool = survivors + the incumbent; the cap applies to
        // the survivors.
        assert!(out.finalists.len() <= opts.finalists + 1, "{}", out.finalists.len());
        assert!(!out.finalists.is_empty());
    }

    #[test]
    fn invalid_base_is_a_typed_error_not_a_panic() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let bad = SolverConfig { rtol: 0.0, ..base() };
        let err = tune_matrix(&d.matrix, &d.b, &bad, &TuneOptions::default()).unwrap_err();
        assert!(matches!(err, HbmcError::InvalidConfig(_)), "{err:?}");
    }

    #[test]
    fn nothing_converging_is_a_typed_error_not_an_arbitrary_winner() {
        // With a 2-iteration cap nothing converges, every score is +∞, and
        // installing any "winner" would hand auto-application a regression.
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let capped = SolverConfig { max_iters: 2, ..base() };
        let opts = TuneOptions { space: Some(small_space()), trials: 1, ..Default::default() };
        let err = tune_matrix(&d.matrix, &d.b, &capped, &opts).unwrap_err();
        assert!(matches!(err, HbmcError::NotConverged { .. }), "{err:?}");
    }

    #[test]
    fn truncation_is_reported_not_silent() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let opts = TuneOptions {
            space: Some(ConfigSpace { block_sizes: vec![8, 16], ..small_space() }),
            trials: 1,
            max_candidates: 2, // incumbent + one challenger
            ..Default::default()
        };
        let out = tune_matrix(&d.matrix, &d.b, &base(), &opts).unwrap();
        assert_eq!(out.candidates, 2, "considered must honour the cap");
        assert!(out.truncated > 0, "the dropped remainder must be visible");
    }

    #[test]
    fn one_shot_scoring_disables_solve_time_abandonment() {
        // expected_reuse = 1 scores setup + solve; a candidate must never
        // be cut off on solve time alone there (cheap-setup configs can
        // win with slower solves).
        let st = SearchState {
            pool: Vec::new(),
            abandoned: 0,
            failed: 0,
            incumbent_time: 1e-3,
        };
        assert_eq!(st.abandon_ref(1.0), None);
        assert_eq!(st.abandon_ref(2.0), None);
        assert_eq!(st.abandon_ref(100.0), Some(1e-3));
        assert_eq!(st.abandon_ref(f64::INFINITY), Some(1e-3));
    }

    #[test]
    fn scoreboard_is_sorted_best_first() {
        let d = suite::dataset("thermal2", Scale::Tiny);
        let opts = TuneOptions { space: Some(small_space()), trials: 1, ..Default::default() };
        let out = tune_matrix(&d.matrix, &d.b, &base(), &opts).unwrap();
        let scores: Vec<f64> =
            out.finalists.iter().map(|m| m.score(opts.expected_reuse)).collect();
        assert!(scores.windows(2).all(|w| w[0] <= w[1]), "{scores:?}");
        assert_eq!(
            out.finalists[0].cfg.label(),
            out.winner.cfg.label(),
            "winner must head the scoreboard"
        );
    }
}
