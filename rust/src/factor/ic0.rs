//! IC(0): incomplete Cholesky with zero fill-in, `A ≈ L Lᵀ` with
//! `pattern(L) = lower(pattern(A))` (paper §2, eq. 2.4).
//!
//! Up-looking row factorization; supports the *shifted* variant used by the
//! paper for the semi-definite `Ieej` problem ("shifted ICCG method, with
//! the shift parameter given as 0.3"): the diagonal is scaled by `1 + σ`
//! before factorization.

use crate::error::{HbmcError, Result};
use crate::resil::FaultInjector;
use crate::sparse::csr::Csr;

/// IC(0) factor: `L` lower-triangular including the diagonal.
#[derive(Debug, Clone)]
pub struct IcFactor {
    /// Strict lower part of `L` (CSR, rows column-sorted).
    pub lower: Csr,
    /// Diagonal `l_ii > 0`.
    pub diag: Vec<f64>,
    /// Precomputed `1 / l_ii` for the substitution hot path.
    pub diag_inv: Vec<f64>,
    /// Shift σ used (0.0 for plain IC(0)).
    pub shift: f64,
}

impl IcFactor {
    pub fn n(&self) -> usize {
        self.diag.len()
    }

    /// nnz of L including the diagonal.
    pub fn nnz(&self) -> usize {
        self.lower.nnz() + self.diag.len()
    }

    /// Dense `L` (tests only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let n = self.n();
        let mut d = self.lower.to_dense();
        for (i, row) in d.iter_mut().enumerate().take(n) {
            row[i] = self.diag[i];
        }
        d
    }

    /// Apply the preconditioner `z = (L Lᵀ)⁻¹ r` serially (reference path;
    /// the parallel paths live in [`crate::solver`]).
    pub fn apply_serial(&self, r: &[f64], z: &mut [f64]) {
        let n = self.n();
        assert_eq!(r.len(), n);
        assert_eq!(z.len(), n);
        // Forward: L y = r  (y stored in z).
        for i in 0..n {
            let (cols, vals) = self.lower.row(i);
            let mut s = r[i];
            for (c, v) in cols.iter().zip(vals) {
                s -= v * z[*c as usize];
            }
            z[i] = s * self.diag_inv[i];
        }
        // Backward: Lᵀ z = y, in place.
        for i in (0..n).rev() {
            let zi = z[i] * self.diag_inv[i];
            z[i] = zi;
            let (cols, vals) = self.lower.row(i);
            for (c, v) in cols.iter().zip(vals) {
                z[*c as usize] -= v * zi;
            }
        }
    }
}

/// Factor `A` (symmetric, column-sorted rows) with IC(0) and diagonal shift
/// `σ`: factors `Ã` where `ã_ii = (1+σ)·a_ii`, `ã_ij = a_ij` off-diagonal.
/// Fails on non-positive pivots (caller may retry with a larger shift —
/// see [`ic0_auto`]).
pub fn ic0(a: &Csr, shift: f64) -> Result<IcFactor> {
    ic0_inner(a, shift, None)
}

/// The actual factorization; `forced_break_row` is the fault-injection
/// hook (`FaultSpec::PivotBreakdown`): reaching that row fails exactly as a
/// genuine non-positive pivot would.
fn ic0_inner(a: &Csr, shift: f64, forced_break_row: Option<usize>) -> Result<IcFactor> {
    let n = a.n();
    let lower_a = a.lower_strict();
    // L has the pattern of strict lower(A); values computed in place.
    let mut l = lower_a.clone();
    let mut diag = vec![0.0f64; n];
    let mut diag_inv = vec![0.0f64; n];

    // Dense scratch holding the current row's working values, plus a marker
    // of which columns are in the row pattern.
    let mut scratch = vec![0.0f64; n];
    let mut in_row = vec![false; n];

    for i in 0..n {
        if forced_break_row == Some(i) {
            return Err(HbmcError::BreakdownInFactorization {
                row: Some(i),
                shift,
                detail: "injected pivot breakdown".into(),
            });
        }
        let (cols, avals) = lower_a.row(i);
        for (c, v) in cols.iter().zip(avals) {
            scratch[*c as usize] = *v;
            in_row[*c as usize] = true;
        }
        let aii = match a.get(i, i) {
            Some(v) => v,
            None => {
                return Err(HbmcError::BreakdownInFactorization {
                    row: Some(i),
                    shift,
                    detail: "missing diagonal entry".into(),
                })
            }
        };
        let mut dii = aii * (1.0 + shift);

        // Ascending over the row pattern: finalize l_ij.
        for &cj in cols {
            let j = cj as usize;
            let mut s = scratch[j];
            // s -= Σ_{k<j} l_jk · l_ik  (l_ik are the already-final
            // scratch entries of this row).
            let (jcols, jvals) = l.row(j);
            for (ck, ljk) in jcols.iter().zip(jvals) {
                let k = *ck as usize;
                if in_row[k] {
                    s -= ljk * scratch[k];
                }
            }
            let lij = s * diag_inv[j];
            scratch[j] = lij;
            dii -= lij * lij;
        }

        if dii <= 0.0 || !dii.is_finite() {
            // Clean scratch before bailing.
            for &c in cols {
                scratch[c as usize] = 0.0;
                in_row[c as usize] = false;
            }
            return Err(HbmcError::BreakdownInFactorization {
                row: Some(i),
                shift,
                detail: format!("non-positive pivot {dii:.3e}"),
            });
        }
        diag[i] = dii.sqrt();
        diag_inv[i] = 1.0 / diag[i];

        // Write back the finalized row and reset scratch.
        {
            let r = lower_a.row_ptr()[i] as usize..lower_a.row_ptr()[i + 1] as usize;
            let lvals = &mut l.vals_mut()[r];
            for (slot, &c) in lvals.iter_mut().zip(cols) {
                *slot = scratch[c as usize];
            }
        }
        for &c in cols {
            scratch[c as usize] = 0.0;
            in_row[c as usize] = false;
        }
    }

    Ok(IcFactor { lower: l, diag, diag_inv, shift })
}

/// The shift schedule [`ic0_auto`] escalates through after the caller's
/// own `σ` fails: doubling from `max(σ, 0.01)`, capped at 10.0. Exposed so
/// callers (and tests) can reason about exactly which shifts a recovery
/// will try — the dispatcher's retry ladder restarts the schedule from the
/// reported last-tried shift.
pub fn escalation_shifts(shift: f64) -> Vec<f64> {
    let mut out = Vec::new();
    let mut s = shift.max(0.01);
    loop {
        s *= 2.0;
        if s > 10.0 {
            return out;
        }
        out.push(s);
    }
}

/// IC(0) with automatic shift escalation: tries `σ`, then the
/// [`escalation_shifts`] schedule until the factorization succeeds. The
/// error's `shift` field reports the shift of the *last attempt actually
/// made* (previously it could name a never-tried value).
pub fn ic0_auto(a: &Csr, shift: f64) -> Result<IcFactor> {
    ic0_auto_with(a, shift, None)
}

/// [`ic0_auto`] with an optional fault injector (chaos testing). A pending
/// `PivotBreakdown` charge is consumed once, at entry, and forces *every*
/// shift attempt of this call to break at its row — so the whole build
/// fails typed and recovery happens in the dispatcher's ladder, not here.
/// A pending `NanFactor` charge poisons one diagonal entry of an otherwise
/// successful factor.
pub fn ic0_auto_with(a: &Csr, shift: f64, inj: Option<&FaultInjector>) -> Result<IcFactor> {
    let forced_row = inj.and_then(|i| i.take_pivot_breakdown());
    let mut f = ic0_auto_forced(a, shift, forced_row)?;
    if let Some(idx) = inj.and_then(|i| i.take_nan_factor()) {
        let n = f.diag.len();
        if n > 0 {
            f.diag[idx % n] = f64::NAN;
            f.diag_inv[idx % n] = f64::NAN;
        }
    }
    Ok(f)
}

fn ic0_auto_forced(a: &Csr, shift: f64, forced_row: Option<usize>) -> Result<IcFactor> {
    let mut last_tried = shift;
    if let Ok(f) = ic0_inner(a, shift, forced_row) {
        return Ok(f);
    }
    for s in escalation_shifts(shift) {
        last_tried = s;
        if let Ok(f) = ic0_inner(a, s, forced_row) {
            return Ok(f);
        }
    }
    Err(HbmcError::BreakdownInFactorization {
        row: None,
        shift: last_tried,
        detail: format!(
            "ic0_auto: no successful shift (last tried {last_tried}, schedule capped at 10.0)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn laplace1d(n: usize) -> Csr {
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 2.0);
        }
        for i in 0..n - 1 {
            c.push_sym(i, i + 1, -1.0);
        }
        c.to_csr()
    }

    #[test]
    fn tridiagonal_ic0_is_exact_cholesky() {
        // For a tridiagonal SPD matrix IC(0) = complete Cholesky.
        let a = laplace1d(6);
        let f = ic0(&a, 0.0).unwrap();
        let l = f.to_dense();
        let n = 6;
        // Check L Lᵀ == A.
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l[i][k] * l[j][k];
                }
                let aij = a.get(i, j).unwrap_or(0.0);
                assert!((s - aij).abs() < 1e-12, "({i},{j}): {s} vs {aij}");
            }
        }
    }

    #[test]
    fn apply_serial_inverts_llt() {
        let a = laplace1d(8);
        let f = ic0(&a, 0.0).unwrap();
        let mut rng = Rng::new(3);
        let x: Vec<f64> = (0..8).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        // r = L Lᵀ x  computed densely.
        let l = f.to_dense();
        let mut ltx = vec![0.0; 8];
        for i in 0..8 {
            for j in 0..8 {
                ltx[i] += l[j][i] * x[j];
            }
        }
        let mut r = vec![0.0; 8];
        for i in 0..8 {
            for j in 0..8 {
                r[i] += l[i][j] * ltx[j];
            }
        }
        let mut z = vec![0.0; 8];
        f.apply_serial(&r, &mut z);
        assert!(crate::util::max_abs_diff(&z, &x) < 1e-10);
    }

    #[test]
    fn shift_scales_diagonal() {
        let a = laplace1d(5);
        let f0 = ic0(&a, 0.0).unwrap();
        let f3 = ic0(&a, 0.3).unwrap();
        assert!(f3.diag[0] > f0.diag[0]);
        assert!((f3.diag[0] * f3.diag[0] - 2.0 * 1.3).abs() < 1e-12);
        assert_eq!(f3.shift, 0.3);
    }

    #[test]
    fn breakdown_detected_and_auto_shift_recovers() {
        // Singular Laplacian (Neumann): plain IC(0) breaks down at the last
        // pivot or yields ~0; shifted succeeds.
        let n = 5;
        let mut c = Coo::new(n);
        for i in 0..n {
            let deg = if i == 0 || i == n - 1 { 1.0 } else { 2.0 };
            c.push(i, i, deg);
        }
        for i in 0..n - 1 {
            c.push_sym(i, i + 1, -1.0);
        }
        let a = c.to_csr();
        assert!(ic0(&a, 0.0).is_err());
        let f = ic0_auto(&a, 0.0).unwrap();
        assert!(f.shift > 0.0);
        assert!(f.diag.iter().all(|&d| d > 0.0));
    }

    #[test]
    fn escalation_schedule_is_pinned_and_reported_shift_was_tried() {
        // From σ = 0 the schedule doubles from 0.01 (0.01 itself is never
        // tried; the caller's σ covers the first attempt).
        assert_eq!(
            escalation_shifts(0.0),
            vec![0.02, 0.04, 0.08, 0.16, 0.32, 0.64, 1.28, 2.56, 5.12]
        );
        // From a caller shift the schedule doubles from that shift.
        assert_eq!(escalation_shifts(0.3), vec![0.6, 1.2, 2.4, 4.8, 9.6]);
        assert_eq!(escalation_shifts(6.0), Vec::<f64>::new());
        // A build where every attempt is forced to fail reports the last
        // shift actually tried — the schedule's tail, not a beyond-cap
        // value.
        let a = laplace1d(4);
        let err = ic0_auto_forced(&a, 0.0, Some(2)).unwrap_err();
        match err {
            HbmcError::BreakdownInFactorization { row, shift, .. } => {
                assert_eq!(row, None);
                assert_eq!(shift, 5.12, "last tried shift");
            }
            other => panic!("unexpected error {other:?}"),
        }
        let err = ic0_auto_forced(&a, 6.0, Some(2)).unwrap_err();
        match err {
            // Schedule empty: the only attempt was the caller's shift.
            HbmcError::BreakdownInFactorization { shift, .. } => assert_eq!(shift, 6.0),
            other => panic!("unexpected error {other:?}"),
        }
    }

    #[test]
    fn injected_faults_force_breakdown_then_clear() {
        use crate::resil::{FaultInjector, FaultSpec};
        let a = laplace1d(6);
        // A one-shot forced breakdown fails the whole auto call...
        let inj = FaultInjector::new(FaultSpec::PivotBreakdown { row: 3 });
        let err = ic0_auto_with(&a, 0.0, Some(&inj)).unwrap_err();
        assert!(matches!(err, HbmcError::BreakdownInFactorization { row: None, .. }), "{err:?}");
        // ...and the retry (charge spent) factors clean.
        let f = ic0_auto_with(&a, 0.0, Some(&inj)).unwrap();
        assert!(f.diag.iter().all(|d| d.is_finite()));
        // NaN poisoning hits exactly one diagonal entry.
        let inj = FaultInjector::new(FaultSpec::NanFactor { index: 8 });
        let f = ic0_auto_with(&a, 0.0, Some(&inj)).unwrap();
        assert!(f.diag[8 % 6].is_nan());
        assert_eq!(f.diag.iter().filter(|d| d.is_nan()).count(), 1);
        let f = ic0_auto_with(&a, 0.0, Some(&inj)).unwrap();
        assert!(f.diag.iter().all(|d| d.is_finite()));
    }

    #[test]
    fn missing_diagonal_errors() {
        let mut c = Coo::new(2);
        c.push(0, 0, 1.0);
        c.push_sym(0, 1, -0.1);
        let a = c.to_csr(); // row 1 has no diagonal
        assert!(ic0(&a, 0.0).is_err());
    }

    #[test]
    fn random_spd_factors_positive() {
        let mut rng = Rng::new(31);
        let n = 120;
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 10.0);
            for _ in 0..3 {
                let j = rng.below(n);
                if j != i {
                    c.push_sym(i, j, -0.5);
                }
            }
        }
        let a = c.to_csr();
        let f = ic0(&a, 0.0).unwrap();
        assert!(f.diag.iter().all(|&d| d > 0.0 && d.is_finite()));
        assert_eq!(f.lower.nnz(), a.lower_strict().nnz());
    }

    #[test]
    fn dummy_identity_rows_factor_to_one() {
        // Augmented-system property: an identity row factors to l_ii = 1.
        let mut c = Coo::new(3);
        c.push(0, 0, 4.0);
        c.push(1, 1, 1.0); // dummy
        c.push(2, 2, 4.0);
        c.push_sym(0, 2, -1.0);
        let f = ic0(&c.to_csr(), 0.0).unwrap();
        assert_eq!(f.diag[1], 1.0);
    }
}
