//! Triangular-factor views: the substitution kernels consume the IC(0)
//! factor as (strict lower CSR, strict upper CSR of `Lᵀ`, inverse
//! diagonal), plus SELL-w forms of both triangles for the HBMC solver.

use crate::factor::ic0::IcFactor;
use crate::sparse::csr::Csr;
use crate::sparse::sell::Sell;

/// CSR views of both substitution triangles.
#[derive(Debug, Clone)]
pub struct TriFactors {
    /// Strict lower of `L` (forward substitution reads rows of this).
    pub lower: Csr,
    /// Strict upper of `Lᵀ` (backward substitution reads rows of this);
    /// `upper[i][j] = l_ji` for `j > i`.
    pub upper: Csr,
    /// `1 / l_ii`.
    pub diag_inv: Vec<f64>,
}

impl TriFactors {
    pub fn from_ic(f: &IcFactor) -> TriFactors {
        TriFactors {
            upper: f.lower.transpose(),
            lower: f.lower.clone(),
            diag_inv: f.diag_inv.clone(),
        }
    }

    pub fn n(&self) -> usize {
        self.diag_inv.len()
    }
}

/// SELL-w views of both triangles for the HBMC vectorized substitutions
/// (§4.4.2: "we naturally set the slice size as w"). Slices align exactly
/// with level-2 blocks because the HBMC dimension is a multiple of `w`.
#[derive(Debug, Clone)]
pub struct SellTriFactors {
    pub w: usize,
    pub fwd: Sell,
    pub bwd: Sell,
    pub diag_inv: Vec<f64>,
}

impl SellTriFactors {
    pub fn from_tri(tri: &TriFactors, w: usize) -> SellTriFactors {
        assert_eq!(tri.n() % w, 0, "HBMC dimension must be a multiple of w");
        SellTriFactors {
            w,
            fwd: Sell::from_csr(&tri.lower, w),
            bwd: Sell::from_csr(&tri.upper, w),
            diag_inv: tri.diag_inv.clone(),
        }
    }

    pub fn n(&self) -> usize {
        self.diag_inv.len()
    }

    /// Stored elements in both triangles (SELL padding included) — feeds
    /// the §5.2.2 processed-elements metric.
    pub fn stored_elements(&self) -> usize {
        self.fwd.stored_elements() + self.bwd.stored_elements()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;
    use crate::sparse::coo::Coo;

    fn sample() -> Csr {
        let n = 8;
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 4.0);
        }
        for i in 0..n - 1 {
            c.push_sym(i, i + 1, -1.0);
        }
        for i in 0..n - 3 {
            c.push_sym(i, i + 3, -0.5);
        }
        c.to_csr()
    }

    #[test]
    fn upper_is_transpose_of_lower() {
        let f = ic0(&sample(), 0.0).unwrap();
        let t = TriFactors::from_ic(&f);
        for i in 0..t.n() {
            let (cols, vals) = t.lower.row(i);
            for (c, v) in cols.iter().zip(vals) {
                assert_eq!(t.upper.get(*c as usize, i), Some(*v));
            }
        }
        assert_eq!(t.lower.nnz(), t.upper.nnz());
    }

    #[test]
    fn sell_views_match_csr() {
        let f = ic0(&sample(), 0.0).unwrap();
        let t = TriFactors::from_ic(&f);
        let s = SellTriFactors::from_tri(&t, 4);
        assert_eq!(s.n(), 8);
        // SpMV through both storage forms agrees (uses strict triangles).
        let x: Vec<f64> = (0..8).map(|i| i as f64 * 0.5 - 1.0).collect();
        let mut y1 = vec![0.0; 8];
        let mut y2 = vec![0.0; 8];
        t.lower.mul_vec(&x, &mut y1);
        s.fwd.mul_vec(&x, &mut y2);
        assert!(crate::util::max_abs_diff(&y1, &y2) < 1e-14);
        assert!(s.stored_elements() >= t.lower.nnz() + t.upper.nnz());
    }

    #[test]
    #[should_panic]
    fn sell_requires_multiple_of_w() {
        let f = ic0(&sample(), 0.0).unwrap();
        let t = TriFactors::from_ic(&f);
        let _ = SellTriFactors::from_tri(&t, 3);
    }
}
