//! Incomplete factorization substrates: IC(0) (optionally diagonally
//! shifted, as the paper's shifted ICCG for the semi-definite `Ieej`
//! problem) and the triangular-factor views consumed by the solvers.

pub mod ic0;
pub mod split;
