//! Solver configuration: ordering choice, block size `bs`, SIMD width `w`,
//! SpMV storage, thread count, convergence controls, plus the three
//! "node-like" presets that stand in for the paper's three test machines
//! (Table 4.1) on this host.

use anyhow::{bail, Result};

/// Which parallel ordering drives the triangular solver.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OrderingKind {
    /// Natural ordering, serial substitutions (sanity baseline; not in the
    /// paper's tables).
    Natural,
    /// Nodal multi-color ordering ("MC").
    Mc,
    /// Block multi-color ordering ("BMC").
    Bmc,
    /// Hierarchical block multi-color ordering ("HBMC") — the paper.
    Hbmc,
}

impl OrderingKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "natural" | "none" => OrderingKind::Natural,
            "mc" => OrderingKind::Mc,
            "bmc" => OrderingKind::Bmc,
            "hbmc" => OrderingKind::Hbmc,
            other => bail!("unknown ordering {other:?} (natural|mc|bmc|hbmc)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OrderingKind::Natural => "natural",
            OrderingKind::Mc => "MC",
            OrderingKind::Bmc => "BMC",
            OrderingKind::Hbmc => "HBMC",
        }
    }
}

/// SpMV storage for the CG matrix-vector product (the paper's
/// `HBMC (crs_spmv)` vs `HBMC (sell_spmv)` distinction).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpmvKind {
    Crs,
    Sell,
}

impl SpmvKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "crs" | "csr" => SpmvKind::Crs,
            "sell" => SpmvKind::Sell,
            other => bail!("unknown spmv kind {other:?} (crs|sell)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpmvKind::Crs => "crs",
            SpmvKind::Sell => "sell",
        }
    }
}

/// Problem scale for the generated datasets (DESIGN.md §3: scaled stand-ins
/// for the paper's SuiteSparse matrices).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scale {
    /// A few thousand unknowns — unit/integration tests.
    Tiny,
    /// Tens of thousands — default for benches on this 1-core host.
    Small,
    /// Hundreds of thousands — closest to the paper's dimensions.
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "tiny" => Scale::Tiny,
            "small" => Scale::Small,
            "full" => Scale::Full,
            other => bail!("unknown scale {other:?} (tiny|small|full)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scale::Tiny => "tiny",
            Scale::Small => "small",
            Scale::Full => "full",
        }
    }
}

/// Full solver configuration.
#[derive(Debug, Clone)]
pub struct SolverConfig {
    pub ordering: OrderingKind,
    /// BMC/HBMC block size (paper sweeps 8, 16, 32).
    pub bs: usize,
    /// SIMD width / HBMC level-2 width / SELL slice height.
    pub w: usize,
    pub spmv: SpmvKind,
    /// SELL-C-σ window for the SpMV matrix (None = unsorted SELL-w).
    pub sell_sigma: Option<usize>,
    pub threads: usize,
    /// Relative residual convergence criterion (paper: 1e-7).
    pub rtol: f64,
    pub max_iters: usize,
    /// Diagonal shift σ for shifted IC (paper: 0.3 for Ieej, else 0).
    pub shift: f64,
    /// Use the explicit AVX-512/AVX2 intrinsic path when available.
    pub use_intrinsics: bool,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            ordering: OrderingKind::Hbmc,
            bs: 32,
            w: 8,
            spmv: SpmvKind::Sell,
            sell_sigma: None,
            threads: 1,
            rtol: 1e-7,
            max_iters: 20_000,
            shift: 0.0,
            use_intrinsics: true,
        }
    }
}

/// A "node-like" preset mirroring one of the paper's three machines
/// (Table 4.1). On this single host the presets differ in `w` (SIMD width)
/// and the intrinsic path, which is the axis the paper's cross-machine
/// story actually varies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodePreset {
    /// Cray XC40, Xeon Phi KNL: AVX-512 → w = 8.
    KnlLike,
    /// Cray CS400, Xeon Broadwell: AVX2 → w = 4.
    BdwLike,
    /// Fujitsu CX2550, Xeon Skylake: AVX-512 → w = 8, intrinsics on.
    SkxLike,
}

impl NodePreset {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "knl" | "knl-like" | "xc40" => NodePreset::KnlLike,
            "bdw" | "bdw-like" | "cs400" | "broadwell" => NodePreset::BdwLike,
            "skx" | "skx-like" | "cx2550" | "skylake" => NodePreset::SkxLike,
            other => bail!("unknown node preset {other:?} (knl|bdw|skx)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            NodePreset::KnlLike => "knl-like (XC40)",
            NodePreset::BdwLike => "bdw-like (CS400)",
            NodePreset::SkxLike => "skx-like (CX2550)",
        }
    }

    /// SIMD width of the preset.
    pub fn w(&self) -> usize {
        match self {
            NodePreset::BdwLike => 4,
            _ => 8,
        }
    }

    /// Apply the preset onto a config.
    pub fn apply(&self, cfg: &mut SolverConfig) {
        cfg.w = self.w();
        cfg.use_intrinsics = true;
    }

    pub fn all() -> [NodePreset; 3] {
        [NodePreset::KnlLike, NodePreset::BdwLike, NodePreset::SkxLike]
    }
}

impl SolverConfig {
    /// Human-readable plan label, e.g. `HBMC(bs=32,w=8,sell)` — used by
    /// reports and the CLI.
    pub fn label(&self) -> String {
        format!(
            "{}(bs={},w={},{})",
            self.ordering.name(),
            self.bs,
            self.w,
            self.spmv.name()
        )
    }

    /// Validate parameter coherence.
    pub fn validate(&self) -> Result<()> {
        if self.bs == 0 || self.w == 0 {
            bail!("bs and w must be positive");
        }
        if self.ordering == OrderingKind::Hbmc && self.bs < 1 {
            bail!("hbmc requires bs >= 1");
        }
        if self.threads == 0 {
            bail!("threads must be >= 1");
        }
        if !(self.rtol > 0.0) {
            bail!("rtol must be > 0");
        }
        if let Some(sigma) = self.sell_sigma {
            if sigma < self.w || sigma % self.w != 0 {
                bail!("sell_sigma must be a positive multiple of w");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert_eq!(OrderingKind::parse("HBMC").unwrap(), OrderingKind::Hbmc);
        assert_eq!(OrderingKind::parse("mc").unwrap(), OrderingKind::Mc);
        assert!(OrderingKind::parse("xyz").is_err());
        assert_eq!(SpmvKind::parse("CSR").unwrap(), SpmvKind::Crs);
        assert_eq!(Scale::parse("full").unwrap(), Scale::Full);
        assert_eq!(NodePreset::parse("skx").unwrap(), NodePreset::SkxLike);
    }

    #[test]
    fn default_is_valid() {
        assert!(SolverConfig::default().validate().is_ok());
    }

    #[test]
    fn presets_set_w() {
        let mut cfg = SolverConfig::default();
        NodePreset::BdwLike.apply(&mut cfg);
        assert_eq!(cfg.w, 4);
        NodePreset::KnlLike.apply(&mut cfg);
        assert_eq!(cfg.w, 8);
    }

    #[test]
    fn validation_catches_bad_sigma() {
        let cfg = SolverConfig { sell_sigma: Some(6), w: 4, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = SolverConfig { sell_sigma: Some(8), w: 4, ..Default::default() };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn validation_catches_zero_threads() {
        let cfg = SolverConfig { threads: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
    }
}
