//! Solver configuration: ordering choice, block size `bs`, SIMD width `w`,
//! SpMV storage, thread count, convergence controls, plus the three
//! "node-like" presets that stand in for the paper's three test machines
//! (Table 4.1) on this host.
//!
//! The validating front door is [`SolverConfig::builder`]: per-field
//! setters, then [`SolverConfigBuilder::build`] runs
//! [`SolverConfig::validate`] so an invalid configuration never reaches the
//! plan builder. The enums implement [`FromStr`]/[`Display`] (CLI flags and
//! report labels go through the standard traits, not ad-hoc `parse`/`name`
//! pairs); an unknown string is [`HbmcError::Parse`].
//!
//! [`QueueConfig`] tunes the asynchronous job dispatcher of the
//! `SolverService` (micro-batch width and flush window); it is
//! service-level state, read once at service construction.

use std::fmt;
use std::str::FromStr;
use std::time::Duration;

use crate::error::{HbmcError, Result};
use crate::resil::{FaultSpec, RetryPolicy};

/// Which parallel ordering drives the triangular solver.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OrderingKind {
    /// Natural ordering, serial substitutions (sanity baseline; not in the
    /// paper's tables).
    Natural,
    /// Nodal multi-color ordering ("MC").
    Mc,
    /// Block multi-color ordering ("BMC").
    Bmc,
    /// Hierarchical block multi-color ordering ("HBMC") — the paper.
    Hbmc,
    /// Level-scheduled (wavefront) trisolve over the natural ordering: no
    /// reordering, so ICCG convergence matches the serial natural solve;
    /// parallelism comes from the factor's dependency DAG
    /// (`crate::schedule`).
    Level,
}

impl FromStr for OrderingKind {
    type Err = HbmcError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "natural" | "none" => Ok(OrderingKind::Natural),
            "mc" => Ok(OrderingKind::Mc),
            "bmc" => Ok(OrderingKind::Bmc),
            "hbmc" => Ok(OrderingKind::Hbmc),
            "level" => Ok(OrderingKind::Level),
            other => Err(HbmcError::parse(format!(
                "unknown ordering {other:?} (natural|mc|bmc|hbmc|level)"
            ))),
        }
    }
}

impl fmt::Display for OrderingKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            OrderingKind::Natural => "natural",
            OrderingKind::Mc => "MC",
            OrderingKind::Bmc => "BMC",
            OrderingKind::Hbmc => "HBMC",
            OrderingKind::Level => "level",
        })
    }
}

/// SpMV storage for the CG matrix-vector product (the paper's
/// `HBMC (crs_spmv)` vs `HBMC (sell_spmv)` distinction, plus the
/// symmetric lower-triangle engine of `solver::spmv::SymmSpmv`, which
/// streams roughly half the matrix bytes per iteration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpmvKind {
    Crs,
    Sell,
    /// Diagonal + strict lower triangle with scatter updates; requires an
    /// exactly symmetric matrix (always true for this solver's SPD inputs).
    SymmCsr,
}

impl FromStr for SpmvKind {
    type Err = HbmcError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "crs" | "csr" => Ok(SpmvKind::Crs),
            "sell" => Ok(SpmvKind::Sell),
            "symmcsr" | "symm-csr" | "symm" => Ok(SpmvKind::SymmCsr),
            other => Err(HbmcError::parse(format!(
                "unknown spmv kind {other:?} (crs|sell|symmcsr)"
            ))),
        }
    }
}

impl fmt::Display for SpmvKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            SpmvKind::Crs => "crs",
            SpmvKind::Sell => "sell",
            SpmvKind::SymmCsr => "symmcsr",
        })
    }
}

/// Problem scale for the generated datasets (DESIGN.md §3: scaled stand-ins
/// for the paper's SuiteSparse matrices).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scale {
    /// A few thousand unknowns — unit/integration tests.
    Tiny,
    /// Tens of thousands — default for benches on this 1-core host.
    Small,
    /// Hundreds of thousands — closest to the paper's dimensions.
    Full,
}

impl FromStr for Scale {
    type Err = HbmcError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "tiny" => Ok(Scale::Tiny),
            "small" => Ok(Scale::Small),
            "full" => Ok(Scale::Full),
            other => Err(HbmcError::parse(format!(
                "unknown scale {other:?} (tiny|small|full)"
            ))),
        }
    }
}

impl fmt::Display for Scale {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Scale::Tiny => "tiny",
            Scale::Small => "small",
            Scale::Full => "full",
        })
    }
}

/// Tuning for the `SolverService` job dispatcher (see `api::queue`): how
/// many compatible queued jobs may be coalesced into one micro-batch, and
/// how long the dispatcher holds an under-full batch open waiting for more.
///
/// These are **service-level** knobs: a service reads them once, from the
/// config it was constructed with. The `queue` field of a per-request
/// config override (`SolveRequest::with_config`) is ignored, and none of
/// these fields participate in the plan-cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueConfig {
    /// Maximum jobs coalesced into one dispatched batch (≥ 1). A batch is
    /// flushed as soon as it reaches this width.
    pub max_batch: usize,
    /// How long the dispatcher keeps an under-full batch open for more
    /// same-key jobs before flushing it. Zero disables the wait (every
    /// batch is whatever is already queued at dispatch time); capped at
    /// one hour by [`SolverConfig::validate`].
    pub max_wait: Duration,
    /// Admission bound on total queued jobs (including jobs staged into an
    /// open batch window). A `submit` that would exceed it fast-rejects
    /// with `HbmcError::Overloaded` instead of enqueueing. `None` (the
    /// default) keeps the queue unbounded; `Some(0)` is rejected by
    /// [`SolverConfig::validate`].
    pub max_queue_depth: Option<usize>,
    /// Admission bound on jobs simultaneously in flight (submitted but not
    /// yet terminal) per `MatrixHandle`. Excess submissions on that handle
    /// fast-reject with `HbmcError::Overloaded`; other handles are
    /// unaffected. `None` (the default) disables the quota; `Some(0)` is
    /// rejected by [`SolverConfig::validate`].
    pub max_inflight_per_handle: Option<usize>,
    /// Lifecycle-trace sampling: every `trace_sample`-th submission records
    /// its full `submitted → … → completed` event trail into the service's
    /// bounded `TraceRecorder` (`SolverService::trace_json`). `0` (the
    /// default) disables tracing; `1` traces every job.
    pub trace_sample: usize,
    /// Consecutive-failure threshold arming a per-`MatrixHandle` circuit
    /// breaker (`resil::CircuitBreaker`): after this many consecutive job
    /// failures on one handle, further submissions for it fast-reject with
    /// `HbmcError::CircuitOpen` until a cooldown and a successful probe.
    /// `None` (the default) disables the breaker; `Some(0)` is rejected by
    /// [`SolverConfig::validate`].
    pub breaker_threshold: Option<u32>,
}

impl Default for QueueConfig {
    fn default() -> Self {
        // 200 µs keeps single blocking solves (which ride the queue too)
        // essentially latency-neutral — tiny next to a multi-ms solve —
        // while still wide enough to coalesce a burst of concurrent
        // submissions into one SIMD-friendly sweep. Admission control and
        // tracing are opt-in: unbounded queue, no quotas, no sampling.
        QueueConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(200),
            max_queue_depth: None,
            max_inflight_per_handle: None,
            trace_sample: 0,
            breaker_threshold: None,
        }
    }
}

/// Full solver configuration.
///
/// Construct through [`SolverConfig::builder`] (validates on `build()`), or
/// as a struct literal for internal/test code that calls
/// [`validate`](SolverConfig::validate) via `SolverPlan::build` anyway.
#[derive(Debug, Clone)]
pub struct SolverConfig {
    pub ordering: OrderingKind,
    /// BMC/HBMC block size (paper sweeps 8, 16, 32). For HBMC, must be a
    /// multiple of `w`.
    pub bs: usize,
    /// SIMD width / HBMC level-2 width / SELL slice height.
    pub w: usize,
    pub spmv: SpmvKind,
    /// SELL-C-σ window for the SpMV matrix (None = unsorted SELL-w).
    pub sell_sigma: Option<usize>,
    pub threads: usize,
    /// Relative residual convergence criterion (paper: 1e-7).
    pub rtol: f64,
    pub max_iters: usize,
    /// Diagonal shift σ for shifted IC (paper: 0.3 for Ieej, else 0).
    pub shift: f64,
    /// Use the explicit AVX-512/AVX2 intrinsic path when available.
    pub use_intrinsics: bool,
    /// Job-queue dispatcher tuning (service-level; see [`QueueConfig`]).
    pub queue: QueueConfig,
    /// Recovery policy for the dispatcher's fallback ladder (per-request;
    /// see [`RetryPolicy`]). Not part of the plan-cache or batch key.
    pub retry: RetryPolicy,
    /// Deterministic fault injection for chaos testing
    /// (`resil::FaultSpec`). Service-level like `queue`: read once at
    /// service construction, `None` (the default) in production — the CLI
    /// additionally refuses `--inject` without `--chaos`.
    pub fault: Option<FaultSpec>,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            ordering: OrderingKind::Hbmc,
            bs: 32,
            w: 8,
            spmv: SpmvKind::Sell,
            sell_sigma: None,
            threads: 1,
            rtol: 1e-7,
            max_iters: 20_000,
            shift: 0.0,
            use_intrinsics: true,
            queue: QueueConfig::default(),
            retry: RetryPolicy::default(),
            fault: None,
        }
    }
}

/// A "node-like" preset mirroring one of the paper's three machines
/// (Table 4.1). On this single host the presets differ in `w` (SIMD width)
/// and the intrinsic path, which is the axis the paper's cross-machine
/// story actually varies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodePreset {
    /// Cray XC40, Xeon Phi KNL: AVX-512 → w = 8.
    KnlLike,
    /// Cray CS400, Xeon Broadwell: AVX2 → w = 4.
    BdwLike,
    /// Fujitsu CX2550, Xeon Skylake: AVX-512 → w = 8, intrinsics on.
    SkxLike,
}

impl FromStr for NodePreset {
    type Err = HbmcError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "knl" | "knl-like" | "xc40" => Ok(NodePreset::KnlLike),
            "bdw" | "bdw-like" | "cs400" | "broadwell" => Ok(NodePreset::BdwLike),
            "skx" | "skx-like" | "cx2550" | "skylake" => Ok(NodePreset::SkxLike),
            other => Err(HbmcError::parse(format!(
                "unknown node preset {other:?} (knl|bdw|skx)"
            ))),
        }
    }
}

impl fmt::Display for NodePreset {
    /// Short canonical name; parses back via [`FromStr`] (round-trip).
    /// See [`describe`](NodePreset::describe) for the paper-machine label.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            NodePreset::KnlLike => "knl-like",
            NodePreset::BdwLike => "bdw-like",
            NodePreset::SkxLike => "skx-like",
        })
    }
}

impl NodePreset {
    /// Human-readable label naming the paper machine (Table 4.1) — for
    /// report titles; not parseable, unlike `Display`.
    pub fn describe(&self) -> &'static str {
        match self {
            NodePreset::KnlLike => "knl-like (XC40)",
            NodePreset::BdwLike => "bdw-like (CS400)",
            NodePreset::SkxLike => "skx-like (CX2550)",
        }
    }

    /// SIMD width of the preset.
    pub fn w(&self) -> usize {
        match self {
            NodePreset::BdwLike => 4,
            _ => 8,
        }
    }

    /// Apply the preset onto a config.
    pub fn apply(&self, cfg: &mut SolverConfig) {
        cfg.w = self.w();
        cfg.use_intrinsics = true;
    }

    pub fn all() -> [NodePreset; 3] {
        [NodePreset::KnlLike, NodePreset::BdwLike, NodePreset::SkxLike]
    }
}

impl SolverConfig {
    /// Start a validating builder seeded with the defaults.
    pub fn builder() -> SolverConfigBuilder {
        SolverConfigBuilder { cfg: SolverConfig::default() }
    }

    /// Human-readable plan label, e.g. `HBMC(bs=32,w=8,sell)` — used by
    /// reports and the CLI.
    pub fn label(&self) -> String {
        format!("{}(bs={},w={},{})", self.ordering, self.bs, self.w, self.spmv)
    }

    /// Validate parameter coherence.
    pub fn validate(&self) -> Result<()> {
        if self.bs == 0 || self.w == 0 {
            return Err(HbmcError::invalid_config("bs and w must be positive"));
        }
        if self.ordering == OrderingKind::Hbmc && self.bs % self.w != 0 {
            return Err(HbmcError::invalid_config(format!(
                "hbmc requires bs to be a multiple of w, got bs={} w={}: each \
                 level-2 block packs w level-1 blocks of bs rows into bs \
                 sequential w-wide steps",
                self.bs, self.w
            )));
        }
        if self.threads == 0 {
            return Err(HbmcError::invalid_config("threads must be >= 1"));
        }
        if !(self.rtol > 0.0) {
            return Err(HbmcError::invalid_config("rtol must be > 0"));
        }
        if let Some(sigma) = self.sell_sigma {
            if sigma == 0 {
                return Err(HbmcError::invalid_config(
                    "sell_sigma = Some(0) is not a sorting window; use None for unsorted SELL-w",
                ));
            }
            if sigma < self.w {
                return Err(HbmcError::invalid_config(format!(
                    "sell_sigma window ({sigma}) is smaller than the slice height w ({}): \
                     a window must cover at least one slice",
                    self.w
                )));
            }
            if sigma % self.w != 0 {
                return Err(HbmcError::invalid_config(format!(
                    "sell_sigma must be a multiple of w, got sigma={sigma} w={}: sorting \
                     windows are built from whole w-row slices",
                    self.w
                )));
            }
            if self.spmv == SpmvKind::SymmCsr {
                return Err(HbmcError::invalid_config(
                    "sell_sigma applies only to SELL storage; the symmetric SpMV engine \
                     (spmv = symmcsr) has no sorting window",
                ));
            }
        }
        if self.queue.max_batch == 0 {
            return Err(HbmcError::invalid_config("queue.max_batch must be >= 1"));
        }
        // Bounded so `Instant::now() + max_wait` in the dispatcher can never
        // overflow (Duration::MAX as a "wait forever" sentinel would
        // otherwise panic the dispatcher thread); an hour is already far
        // beyond any sane batching window.
        if self.queue.max_wait > Duration::from_secs(3600) {
            return Err(HbmcError::invalid_config("queue.max_wait must be <= 1 hour"));
        }
        // A zero admission bound would reject every submission; "no bound"
        // is spelled `None`, so Some(0) can only be a mistake.
        if self.queue.max_queue_depth == Some(0) {
            return Err(HbmcError::invalid_config(
                "queue.max_queue_depth must be >= 1 when set (use None for unbounded)",
            ));
        }
        if self.queue.max_inflight_per_handle == Some(0) {
            return Err(HbmcError::invalid_config(
                "queue.max_inflight_per_handle must be >= 1 when set (use None for no quota)",
            ));
        }
        // A breaker that opens after zero failures would reject everything;
        // "no breaker" is spelled None.
        if self.queue.breaker_threshold == Some(0) {
            return Err(HbmcError::invalid_config(
                "queue.breaker_threshold must be >= 1 when set (use None to disable)",
            ));
        }
        Ok(())
    }
}

/// Fluent, validating constructor for [`SolverConfig`]; obtained from
/// [`SolverConfig::builder`]. Every setter mirrors one field; `build()`
/// runs [`SolverConfig::validate`], so a config obtained through the
/// builder is valid by construction.
#[derive(Debug, Clone)]
pub struct SolverConfigBuilder {
    cfg: SolverConfig,
}

impl SolverConfigBuilder {
    pub fn ordering(mut self, ordering: OrderingKind) -> Self {
        self.cfg.ordering = ordering;
        self
    }

    /// BMC/HBMC block size (for HBMC, a multiple of `w`).
    pub fn bs(mut self, bs: usize) -> Self {
        self.cfg.bs = bs;
        self
    }

    /// SIMD width / HBMC level-2 width / SELL slice height.
    pub fn w(mut self, w: usize) -> Self {
        self.cfg.w = w;
        self
    }

    pub fn spmv(mut self, spmv: SpmvKind) -> Self {
        self.cfg.spmv = spmv;
        self
    }

    /// SELL-C-σ sorting window (must be a multiple of `w`).
    pub fn sell_sigma(mut self, sigma: Option<usize>) -> Self {
        self.cfg.sell_sigma = sigma;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Relative residual convergence criterion.
    pub fn rtol(mut self, rtol: f64) -> Self {
        self.cfg.rtol = rtol;
        self
    }

    pub fn max_iters(mut self, max_iters: usize) -> Self {
        self.cfg.max_iters = max_iters;
        self
    }

    /// Diagonal shift σ for shifted IC.
    pub fn shift(mut self, shift: f64) -> Self {
        self.cfg.shift = shift;
        self
    }

    pub fn use_intrinsics(mut self, on: bool) -> Self {
        self.cfg.use_intrinsics = on;
        self
    }

    /// Maximum jobs the service dispatcher coalesces into one batch (≥ 1).
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.cfg.queue.max_batch = max_batch;
        self
    }

    /// How long the dispatcher holds an under-full batch open for more
    /// same-key jobs before flushing it.
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.cfg.queue.max_wait = max_wait;
        self
    }

    /// Admission bound on total queued jobs (`None` = unbounded); see
    /// [`QueueConfig::max_queue_depth`].
    pub fn max_queue_depth(mut self, depth: Option<usize>) -> Self {
        self.cfg.queue.max_queue_depth = depth;
        self
    }

    /// Per-handle in-flight job quota (`None` = no quota); see
    /// [`QueueConfig::max_inflight_per_handle`].
    pub fn max_inflight_per_handle(mut self, quota: Option<usize>) -> Self {
        self.cfg.queue.max_inflight_per_handle = quota;
        self
    }

    /// Trace every `n`-th submission's lifecycle (`0` disables); see
    /// [`QueueConfig::trace_sample`].
    pub fn trace_sample(mut self, n: usize) -> Self {
        self.cfg.queue.trace_sample = n;
        self
    }

    /// Allow up to `n` recovery attempts per job after its first failure
    /// (`0`, the default, fails fast); see [`RetryPolicy`].
    pub fn max_retries(mut self, n: u32) -> Self {
        self.cfg.retry = RetryPolicy::retries(n);
        self
    }

    /// Arm a per-handle circuit breaker opening after `threshold`
    /// consecutive failures (`None` disables); see
    /// [`QueueConfig::breaker_threshold`].
    pub fn breaker_threshold(mut self, threshold: Option<u32>) -> Self {
        self.cfg.queue.breaker_threshold = threshold;
        self
    }

    /// Arm deterministic fault injection (`None`, the default, disables);
    /// see [`FaultSpec`]. Chaos testing only.
    pub fn fault(mut self, fault: Option<FaultSpec>) -> Self {
        self.cfg.fault = fault;
        self
    }

    /// Apply a machine preset (sets `w` and the intrinsic path).
    pub fn preset(mut self, node: NodePreset) -> Self {
        node.apply(&mut self.cfg);
        self
    }

    /// Validate and produce the config; [`HbmcError::InvalidConfig`] names
    /// the violated invariant.
    pub fn build(self) -> Result<SolverConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_str_round_trips() {
        assert_eq!("HBMC".parse::<OrderingKind>().unwrap(), OrderingKind::Hbmc);
        assert_eq!("mc".parse::<OrderingKind>().unwrap(), OrderingKind::Mc);
        assert!("xyz".parse::<OrderingKind>().is_err());
        assert_eq!("CSR".parse::<SpmvKind>().unwrap(), SpmvKind::Crs);
        assert_eq!("full".parse::<Scale>().unwrap(), Scale::Full);
        assert_eq!("skx".parse::<NodePreset>().unwrap(), NodePreset::SkxLike);
        // Display of *every* variant of each enum parses back to itself.
        for k in [
            OrderingKind::Natural,
            OrderingKind::Mc,
            OrderingKind::Bmc,
            OrderingKind::Hbmc,
            OrderingKind::Level,
        ] {
            assert_eq!(k.to_string().parse::<OrderingKind>().unwrap(), k);
        }
        assert_eq!("LEVEL".parse::<OrderingKind>().unwrap(), OrderingKind::Level);
        for v in [SpmvKind::Crs, SpmvKind::Sell, SpmvKind::SymmCsr] {
            assert_eq!(v.to_string().parse::<SpmvKind>().unwrap(), v);
        }
        assert_eq!("symm".parse::<SpmvKind>().unwrap(), SpmvKind::SymmCsr);
        for s in [Scale::Tiny, Scale::Small, Scale::Full] {
            assert_eq!(s.to_string().parse::<Scale>().unwrap(), s);
        }
        for n in NodePreset::all() {
            assert_eq!(n.to_string().parse::<NodePreset>().unwrap(), n);
            assert!(n.describe().starts_with(&n.to_string()));
        }
    }

    #[test]
    fn unknown_strings_report_parse_errors() {
        let err = "warp".parse::<SpmvKind>().unwrap_err();
        assert!(matches!(err, HbmcError::Parse(_)), "{err:?}");
        assert!(err.to_string().contains("warp"));
        assert!(matches!("rainbow".parse::<OrderingKind>(), Err(HbmcError::Parse(_))));
        assert!(matches!("huge".parse::<Scale>(), Err(HbmcError::Parse(_))));
        assert!(matches!("epyc".parse::<NodePreset>(), Err(HbmcError::Parse(_))));
    }

    #[test]
    fn symmcsr_rejects_sell_sigma() {
        let err = SolverConfig::builder()
            .spmv(SpmvKind::SymmCsr)
            .sell_sigma(Some(32))
            .build()
            .unwrap_err();
        assert!(matches!(err, HbmcError::InvalidConfig(_)), "{err:?}");
        // Without a window the symmetric engine is a valid configuration.
        let cfg = SolverConfig::builder().spmv(SpmvKind::SymmCsr).build().unwrap();
        assert_eq!(cfg.label(), format!("{}(bs={},w={},symmcsr)", cfg.ordering, cfg.bs, cfg.w));
    }

    #[test]
    fn queue_knobs_validate_and_build() {
        let cfg = SolverConfig::builder()
            .max_batch(4)
            .max_wait(Duration::from_millis(2))
            .build()
            .unwrap();
        assert_eq!(cfg.queue.max_batch, 4);
        assert_eq!(cfg.queue.max_wait, Duration::from_millis(2));
        let err = SolverConfig::builder().max_batch(0).build().unwrap_err();
        assert!(matches!(err, HbmcError::InvalidConfig(_)), "{err:?}");
        assert!(err.to_string().contains("max_batch"), "{err}");
        // The window is bounded so the dispatcher's deadline arithmetic
        // can never overflow (Duration::MAX sentinel).
        let err = SolverConfig::builder().max_wait(Duration::from_secs(7200)).build().unwrap_err();
        assert!(err.to_string().contains("max_wait"), "{err}");
    }

    #[test]
    fn admission_knobs_validate_and_build() {
        // Defaults: no bounds, no tracing.
        let cfg = SolverConfig::default();
        assert_eq!(cfg.queue.max_queue_depth, None);
        assert_eq!(cfg.queue.max_inflight_per_handle, None);
        assert_eq!(cfg.queue.trace_sample, 0);
        let cfg = SolverConfig::builder()
            .max_queue_depth(Some(64))
            .max_inflight_per_handle(Some(4))
            .trace_sample(10)
            .build()
            .unwrap();
        assert_eq!(cfg.queue.max_queue_depth, Some(64));
        assert_eq!(cfg.queue.max_inflight_per_handle, Some(4));
        assert_eq!(cfg.queue.trace_sample, 10);
        // Some(0) would reject every submission; "no bound" is None.
        let err = SolverConfig::builder().max_queue_depth(Some(0)).build().unwrap_err();
        assert!(err.to_string().contains("max_queue_depth"), "{err}");
        let err =
            SolverConfig::builder().max_inflight_per_handle(Some(0)).build().unwrap_err();
        assert!(err.to_string().contains("max_inflight_per_handle"), "{err}");
    }

    #[test]
    fn resilience_knobs_validate_and_build() {
        // Defaults: fail fast, no breaker, no injection.
        let cfg = SolverConfig::default();
        assert_eq!(cfg.retry.max_retries, 0);
        assert_eq!(cfg.queue.breaker_threshold, None);
        assert_eq!(cfg.fault, None);
        let cfg = SolverConfig::builder()
            .max_retries(2)
            .breaker_threshold(Some(3))
            .fault(Some("breakdown:5".parse().unwrap()))
            .build()
            .unwrap();
        assert_eq!(cfg.retry, RetryPolicy::retries(2));
        assert_eq!(cfg.queue.breaker_threshold, Some(3));
        assert_eq!(cfg.fault, Some(FaultSpec::PivotBreakdown { row: 5 }));
        // A breaker opening after zero failures rejects everything;
        // "disabled" is None.
        let err = SolverConfig::builder().breaker_threshold(Some(0)).build().unwrap_err();
        assert!(matches!(err, HbmcError::InvalidConfig(_)), "{err:?}");
        assert!(err.to_string().contains("breaker_threshold"), "{err}");
    }

    #[test]
    fn default_is_valid() {
        assert!(SolverConfig::default().validate().is_ok());
    }

    #[test]
    fn builder_builds_and_validates() {
        let cfg = SolverConfig::builder()
            .ordering(OrderingKind::Hbmc)
            .bs(16)
            .w(4)
            .spmv(SpmvKind::Crs)
            .rtol(1e-9)
            .max_iters(100)
            .build()
            .unwrap();
        assert_eq!(cfg.bs, 16);
        assert_eq!(cfg.w, 4);
        assert_eq!(cfg.rtol, 1e-9);
        assert_eq!(cfg.label(), "HBMC(bs=16,w=4,crs)");

        let err = SolverConfig::builder().threads(0).build().unwrap_err();
        assert!(matches!(err, HbmcError::InvalidConfig(_)));
    }

    #[test]
    fn builder_preset_sets_w() {
        let cfg = SolverConfig::builder().preset(NodePreset::BdwLike).bs(16).build().unwrap();
        assert_eq!(cfg.w, 4);
        assert!(cfg.use_intrinsics);
    }

    #[test]
    fn presets_set_w() {
        let mut cfg = SolverConfig::default();
        NodePreset::BdwLike.apply(&mut cfg);
        assert_eq!(cfg.w, 4);
        NodePreset::KnlLike.apply(&mut cfg);
        assert_eq!(cfg.w, 8);
    }

    #[test]
    fn validation_requires_hbmc_bs_multiple_of_w() {
        let cfg = SolverConfig { ordering: OrderingKind::Hbmc, bs: 12, w: 8, ..Default::default() };
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("multiple of w"), "{err}");
        // The same shape is fine for BMC (no level-2 packing).
        let cfg = SolverConfig { ordering: OrderingKind::Bmc, bs: 12, w: 8, ..Default::default() };
        assert!(cfg.validate().is_ok());
        // And fine for HBMC once bs is a multiple.
        let cfg = SolverConfig { ordering: OrderingKind::Hbmc, bs: 16, w: 8, ..Default::default() };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn validation_catches_bad_sigma() {
        let cfg = SolverConfig { sell_sigma: Some(6), w: 4, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = SolverConfig { sell_sigma: Some(8), w: 4, ..Default::default() };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn builder_rejects_zero_and_subslice_sigma_with_typed_errors() {
        // Some(0) is rejected explicitly (it is not "unsorted"; that's None).
        let err = SolverConfig::builder().w(4).sell_sigma(Some(0)).build().unwrap_err();
        assert!(matches!(err, HbmcError::InvalidConfig(_)), "{err:?}");
        assert!(err.to_string().contains("Some(0)"), "{err}");
        // A window smaller than the slice height cannot cover one slice.
        let err = SolverConfig::builder()
            .ordering(OrderingKind::Bmc)
            .w(8)
            .sell_sigma(Some(4))
            .build()
            .unwrap_err();
        assert!(matches!(err, HbmcError::InvalidConfig(_)), "{err:?}");
        assert!(err.to_string().contains("smaller than the slice height"), "{err}");
        // Non-multiple windows name both offending values.
        let err = SolverConfig::builder().w(8).bs(32).sell_sigma(Some(12)).build().unwrap_err();
        assert!(err.to_string().contains("sigma=12"), "{err}");
        assert!(err.to_string().contains("w=8"), "{err}");
        // The boundary case (window == one slice) is valid.
        let cfg = SolverConfig::builder().w(8).bs(32).sell_sigma(Some(8)).build().unwrap();
        assert_eq!(cfg.sell_sigma, Some(8));
    }

    #[test]
    fn validation_catches_zero_threads() {
        let cfg = SolverConfig { threads: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
    }
}
