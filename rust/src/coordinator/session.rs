//! Phase 2 of the two-phase solver: serving. A [`SolveSession`] owns one
//! persistent color-barrier [`Pool`] shared by trisolve + SpMV + BLAS-1 and
//! runs any number of right-hand sides against one immutable
//! [`SolverPlan`] — the production shape of the paper's amortization claim
//! (setup once, sweep many times). [`PlanCache`] adds an LRU plan store
//! keyed by (matrix fingerprint, ordering, bs, w, spmv, σ, shift,
//! intrinsics) so repeated requests against the same few matrices never
//! re-order or re-factor.
//!
//! Sessions are also the batch entry point of the serving tier: the
//! `SolverService` job dispatcher (`api::queue`) opens **one** session per
//! micro-batch and runs every coalesced right-hand side through it —
//! `solve_many` and the dispatcher share the same per-rhs
//! [`solve_with`](SolveSession::solve_with) path, so batched results are
//! bitwise-identical to independent solves.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::Arc;

use crate::config::{OrderingKind, SolverConfig, SpmvKind};
use crate::coordinator::driver::{SolveOptions, SolveReport};
use crate::coordinator::pool::Pool;
use crate::error::Result;
use crate::resil::FaultInjector;
use crate::solver::plan::{ExecOptions, SolverPlan};
use crate::sparse::csr::Csr;

/// Result of one session solve: the solution (moved, never cloned) plus
/// the per-solve report.
#[derive(Debug, Clone)]
pub struct SolveOutput {
    pub x: Vec<f64>,
    pub report: SolveReport,
}

/// A reusable solve endpoint: one plan, one thread pool, many solves.
///
/// Convergence controls (`rtol`, `max_iters`) are *session* state, taken
/// from the requesting config — a plan fetched from the cache may have
/// been built for a different caller's tolerances, and those must not
/// leak into this session's solves.
pub struct SolveSession {
    plan: Arc<SolverPlan>,
    pool: Pool,
    /// Monotonic solve counter (feeds `solve_index`). Relaxed ordering is
    /// sufficient: `fetch_add` is atomic, so indices stay unique, and the
    /// counter is never used to publish other memory.
    solves: AtomicUsize,
    rtol: f64,
    max_iters: usize,
}

impl SolveSession {
    /// Wrap a plan; pool size and tolerances come from the plan's config.
    pub fn new(plan: Arc<SolverPlan>) -> SolveSession {
        let threads = plan.cfg.threads;
        SolveSession::with_threads(plan, threads)
    }

    /// Wrap a (possibly cached) plan with an explicit pool size — lets one
    /// plan serve sessions of different widths.
    pub fn with_threads(plan: Arc<SolverPlan>, threads: usize) -> SolveSession {
        let (rtol, max_iters) = (plan.cfg.rtol, plan.cfg.max_iters);
        SolveSession {
            plan,
            pool: Pool::new(threads),
            solves: AtomicUsize::new(0),
            rtol,
            max_iters,
        }
    }

    /// Wrap a (possibly cached) plan, taking pool width **and** the
    /// convergence controls from the requesting config rather than from
    /// the config the plan was originally built under.
    pub fn for_request(plan: Arc<SolverPlan>, cfg: &SolverConfig) -> SolveSession {
        SolveSession::for_request_with(plan, cfg, None)
    }

    /// [`SolveSession::for_request`] with a fault injector threaded into
    /// the pool (chaos testing; see `crate::resil`). `None` is the
    /// production path and behaves exactly like `for_request`.
    pub fn for_request_with(
        plan: Arc<SolverPlan>,
        cfg: &SolverConfig,
        injector: Option<Arc<FaultInjector>>,
    ) -> SolveSession {
        SolveSession {
            plan,
            pool: Pool::with_injector(cfg.threads, injector),
            solves: AtomicUsize::new(0),
            rtol: cfg.rtol,
            max_iters: cfg.max_iters,
        }
    }

    /// Build the plan and the session in one step (the one-shot path).
    pub fn from_matrix(a: &Csr, cfg: &SolverConfig) -> Result<SolveSession> {
        Ok(SolveSession::new(Arc::new(SolverPlan::build(a, cfg)?)))
    }

    /// The immutable plan backing this session.
    pub fn plan(&self) -> &Arc<SolverPlan> {
        &self.plan
    }

    /// The session's persistent thread pool.
    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// Number of solves completed on this session.
    pub fn solves_completed(&self) -> usize {
        self.solves.load(AtomicOrdering::Relaxed)
    }

    /// Consume the session and tear its pool down with a bounded grace
    /// period, returning how many worker threads had to be detached
    /// (see [`Pool::drain`]). The dispatcher's panic-recovery path calls
    /// this instead of leaking a possibly-desynchronized session.
    pub fn drain(self) -> usize {
        self.pool.drain()
    }

    /// Solve `A x = b` with default options.
    pub fn solve(&self, b: &[f64]) -> Result<SolveOutput> {
        self.solve_with(b, &SolveOptions::default())
    }

    /// Solve with explicit per-solve options. Note `&self`: sessions are
    /// externally immutable, and consecutive solves reuse pool and plan.
    pub fn solve_with(&self, b: &[f64], opts: &SolveOptions) -> Result<SolveOutput> {
        let out = self.plan.execute(
            &self.pool,
            b,
            &ExecOptions {
                record_history: opts.record_history,
                rtol: Some(opts.rtol.unwrap_or(self.rtol)),
                max_iters: Some(opts.max_iters.unwrap_or(self.max_iters)),
                profile: opts.profile,
                ..Default::default()
            },
        )?;
        let solve_index = self.solves.fetch_add(1, AtomicOrdering::Relaxed);
        let mut report = SolveReport::from_parts(&self.plan, out.cg, solve_index);
        report.dispatches = out.dispatches;
        report.pool_syncs = out.pool_syncs;
        report.profile = out.profile;
        if opts.return_solution {
            report.solution = Some(out.x.clone());
        }
        Ok(SolveOutput { x: out.x, report })
    }

    /// Batched serving: run every rhs through the plan sequentially on the
    /// session pool. Results are index-aligned with `rhss` and identical
    /// to the corresponding independent `solve` calls.
    pub fn solve_many<B: AsRef<[f64]>>(&self, rhss: &[B]) -> Result<Vec<SolveOutput>> {
        self.solve_many_with(rhss, &SolveOptions::default())
    }

    /// Batched serving with per-solve options (applied to every rhs).
    pub fn solve_many_with<B: AsRef<[f64]>>(
        &self,
        rhss: &[B],
        opts: &SolveOptions,
    ) -> Result<Vec<SolveOutput>> {
        rhss.iter().map(|b| self.solve_with(b.as_ref(), opts)).collect()
    }
}

/// Cache key: everything that determines a plan's content.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub fingerprint: u64,
    pub ordering: OrderingKind,
    pub bs: usize,
    pub w: usize,
    pub spmv: SpmvKind,
    pub sell_sigma: Option<usize>,
    /// Bit pattern of the requested diagonal shift.
    pub shift_bits: u64,
    pub use_intrinsics: bool,
}

impl PlanKey {
    pub fn new(a: &Csr, cfg: &SolverConfig) -> PlanKey {
        PlanKey::from_fingerprint(a.fingerprint(), cfg)
    }

    /// Build the key from an already-computed matrix fingerprint — lets
    /// callers that hold matrices long-term (the `SolverService` registry)
    /// hash the matrix once at registration instead of per request.
    pub fn from_fingerprint(fingerprint: u64, cfg: &SolverConfig) -> PlanKey {
        PlanKey {
            fingerprint,
            ordering: cfg.ordering,
            bs: cfg.bs,
            w: cfg.w,
            spmv: cfg.spmv,
            sell_sigma: cfg.sell_sigma,
            shift_bits: cfg.shift.to_bits(),
            use_intrinsics: cfg.use_intrinsics,
        }
    }
}

struct CacheEntry {
    plan: Arc<SolverPlan>,
    last_used: u64,
}

/// Point-in-time snapshot of a cache's counters (also surfaced through
/// `SolverService::stats`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub len: usize,
    pub capacity: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// LRU store of built plans — the serving tier's answer to "a few matrices,
/// many right-hand sides". Hit ⇒ no re-ordering, no re-factorization.
pub struct PlanCache {
    capacity: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    entries: HashMap<PlanKey, CacheEntry>,
}

impl PlanCache {
    /// `capacity` ≥ 1: most plans a cache will hold at once.
    pub fn new(capacity: usize) -> PlanCache {
        assert!(capacity >= 1, "plan cache capacity must be >= 1");
        PlanCache {
            capacity,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            entries: HashMap::new(),
        }
    }

    /// Look up a plan by key, touching its LRU position and counting a hit.
    /// Returns `None` (and counts nothing) on miss — the caller decides
    /// whether to build (see [`insert`](PlanCache::insert)); the
    /// `SolverService` uses this split to build outside the cache lock.
    pub fn get(&mut self, key: &PlanKey) -> Option<Arc<SolverPlan>> {
        self.tick += 1;
        let entry = self.entries.get_mut(key)?;
        entry.last_used = self.tick;
        self.hits += 1;
        Some(entry.plan.clone())
    }

    /// Insert a freshly built plan, counting a miss and evicting the
    /// least-recently-used entry if the cache is at capacity. Re-inserting
    /// an existing key replaces the entry without eviction.
    pub fn insert(&mut self, key: PlanKey, plan: Arc<SolverPlan>) {
        self.tick += 1;
        self.misses += 1;
        if !self.entries.contains_key(&key) && self.entries.len() >= self.capacity {
            if let Some(lru) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                self.entries.remove(&lru);
                self.evictions += 1;
            }
        }
        self.entries.insert(key, CacheEntry { plan, last_used: self.tick });
    }

    /// Remove a key outright, returning the evicted plan if it was
    /// present (counted as an eviction — forced removals are part of the
    /// cache's churn accounting). Used by the service dispatcher to drop
    /// a plan implicated in a worker panic, so the next request for the
    /// same key rebuilds instead of touching suspect state.
    pub fn remove(&mut self, key: &PlanKey) -> Option<Arc<SolverPlan>> {
        let entry = self.entries.remove(key)?;
        self.evictions += 1;
        Some(entry.plan)
    }

    /// Fetch the plan for `(a, cfg)`, building (and possibly evicting the
    /// least-recently-used entry) on miss. Returns `(plan, was_hit)`.
    pub fn get_or_build(&mut self, a: &Csr, cfg: &SolverConfig) -> Result<(Arc<SolverPlan>, bool)> {
        let key = PlanKey::new(a, cfg);
        if let Some(plan) = self.get(&key) {
            return Ok((plan, true));
        }
        let plan = Arc::new(SolverPlan::build(a, cfg)?);
        self.insert(key, plan.clone());
        Ok((plan, false))
    }

    /// Open a session on the cached (or freshly built) plan, with the pool
    /// width and convergence controls the *request* asked for (a cache hit
    /// must not inherit another caller's rtol/max_iters).
    pub fn session(&mut self, a: &Csr, cfg: &SolverConfig) -> Result<SolveSession> {
        let (plan, _) = self.get_or_build(a, cfg)?;
        Ok(SolveSession::for_request(plan, cfg))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Snapshot of size and counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            len: self.entries.len(),
            capacity: self.capacity,
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
        }
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scale;
    use crate::gen::suite;

    fn tiny_cfg(ordering: OrderingKind) -> SolverConfig {
        SolverConfig { ordering, bs: 8, w: 4, rtol: 1e-7, ..Default::default() }
    }

    #[test]
    fn session_counts_solves_and_reuses_plan() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let cfg = tiny_cfg(OrderingKind::Hbmc);
        let session = SolveSession::from_matrix(&d.matrix, &cfg).unwrap();
        assert_eq!(session.solves_completed(), 0);
        let o1 = session.solve(&d.b).unwrap();
        let o2 = session.solve(&d.b).unwrap();
        assert_eq!(session.solves_completed(), 2);
        assert_eq!(o1.report.solve_index, 0);
        assert_eq!(o2.report.solve_index, 1);
        assert!(o1.report.converged && o2.report.converged);
        // Same plan, same rhs ⇒ bitwise-identical solutions.
        assert_eq!(o1.x, o2.x);
    }

    #[test]
    fn solve_many_matches_independent_solves() {
        let d = suite::dataset("thermal2", Scale::Tiny);
        let cfg = tiny_cfg(OrderingKind::Bmc);
        let session = SolveSession::from_matrix(&d.matrix, &cfg).unwrap();
        let b2: Vec<f64> = d.b.iter().map(|v| 2.0 * v).collect();
        let b3: Vec<f64> = d.b.iter().map(|v| -0.5 * v).collect();
        let batch = session.solve_many(&[d.b.clone(), b2.clone(), b3.clone()]).unwrap();
        assert_eq!(batch.len(), 3);
        for (rhs, out) in [&d.b, &b2, &b3].into_iter().zip(&batch) {
            let single = session.solve(rhs).unwrap();
            assert_eq!(single.x, out.x, "batched solve must be bitwise-identical");
            assert_eq!(single.report.iterations, out.report.iterations);
        }
    }

    #[test]
    fn cache_hits_on_repeated_config_and_evicts_lru() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let mut cache = PlanCache::new(2);
        let hb = tiny_cfg(OrderingKind::Hbmc);
        let bm = tiny_cfg(OrderingKind::Bmc);
        let mc = tiny_cfg(OrderingKind::Mc);

        let (p1, hit1) = cache.get_or_build(&d.matrix, &hb).unwrap();
        assert!(!hit1);
        let (p2, hit2) = cache.get_or_build(&d.matrix, &hb).unwrap();
        assert!(hit2, "same (matrix, config) must hit");
        assert!(Arc::ptr_eq(&p1, &p2), "hit must return the same plan object");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));

        let _ = cache.get_or_build(&d.matrix, &bm).unwrap();
        assert_eq!(cache.len(), 2);
        // Third distinct key evicts the LRU entry — hbmc (last touched
        // before bmc).
        let _ = cache.get_or_build(&d.matrix, &mc).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        let (_, hbmc_again) = cache.get_or_build(&d.matrix, &hb).unwrap();
        assert!(!hbmc_again, "evicted entry must rebuild");
    }

    #[test]
    fn remove_forces_rebuild_and_counts_eviction() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let mut cache = PlanCache::new(4);
        let cfg = tiny_cfg(OrderingKind::Hbmc);
        let key = PlanKey::new(&d.matrix, &cfg);
        let (built, _) = cache.get_or_build(&d.matrix, &cfg).unwrap();
        let removed = cache.remove(&key).expect("plan was cached");
        assert!(Arc::ptr_eq(&built, &removed));
        assert_eq!(cache.evictions(), 1, "forced removal is an eviction");
        assert_eq!(cache.len(), 0);
        assert!(cache.remove(&key).is_none(), "double remove is a no-op");
        let (_, hit) = cache.get_or_build(&d.matrix, &cfg).unwrap();
        assert!(!hit, "a removed key must rebuild, not hit");
    }

    #[test]
    fn cache_distinguishes_matrices_and_params() {
        let d1 = suite::dataset("g3_circuit", Scale::Tiny);
        let d2 = suite::dataset("thermal2", Scale::Tiny);
        let mut cache = PlanCache::new(8);
        let cfg = tiny_cfg(OrderingKind::Hbmc);
        let (_, h1) = cache.get_or_build(&d1.matrix, &cfg).unwrap();
        let (_, h2) = cache.get_or_build(&d2.matrix, &cfg).unwrap();
        assert!(!h1 && !h2, "different matrices must not collide");
        let mut cfg16 = cfg.clone();
        cfg16.bs = 16;
        let (_, h3) = cache.get_or_build(&d1.matrix, &cfg16).unwrap();
        assert!(!h3, "different bs must not collide");
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn cached_plan_does_not_leak_builders_tolerances() {
        // rtol/max_iters are not part of the cache key (they don't affect
        // plan content), so a hit must still solve with the *requester's*
        // tolerances, not those of whoever built the plan.
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let mut cache = PlanCache::new(2);
        let loose = SolverConfig { rtol: 1e-2, ..tiny_cfg(OrderingKind::Hbmc) };
        let strict = SolverConfig { rtol: 1e-9, ..tiny_cfg(OrderingKind::Hbmc) };
        let s_loose = cache.session(&d.matrix, &loose).unwrap();
        let s_strict = cache.session(&d.matrix, &strict).unwrap();
        assert_eq!(cache.hits(), 1, "structurally identical configs must share the plan");
        assert!(Arc::ptr_eq(s_loose.plan(), s_strict.plan()));
        let o_loose = s_loose.solve(&d.b).unwrap();
        let o_strict = s_strict.solve(&d.b).unwrap();
        assert!(o_loose.report.converged && o_strict.report.converged);
        assert!(o_strict.report.final_relres < 1e-9, "strict session must honor its own rtol");
        assert!(
            o_strict.report.iterations > o_loose.report.iterations,
            "tighter tolerance must not be satisfied by the loose builder's rtol"
        );
    }

    #[test]
    fn cached_session_solves_correctly() {
        let d = suite::dataset("parabolic_fem", Scale::Tiny);
        let cfg = tiny_cfg(OrderingKind::Hbmc);
        let mut cache = PlanCache::new(4);
        let s1 = cache.session(&d.matrix, &cfg).unwrap();
        let s2 = cache.session(&d.matrix, &cfg).unwrap();
        assert!(Arc::ptr_eq(s1.plan(), s2.plan()));
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        let o1 = s1.solve(&d.b).unwrap();
        let o2 = s2.solve(&d.b).unwrap();
        assert!(o1.report.converged);
        assert_eq!(o1.x, o2.x);
    }
}
