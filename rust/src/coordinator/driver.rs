//! One-shot solve orchestration and the report types shared with the
//! session layer. [`solve`] / [`solve_opts`] are thin compatible wrappers
//! (one plan, one single-use [`SolveSession`](crate::coordinator::session::SolveSession),
//! one solve — the exact path a [`SolverService`](crate::api::SolverService)
//! request takes); production callers serving many right-hand sides should
//! hold a service so the setup phase is paid once.
//!
//! Reporting is split to make amortization observable:
//!
//! * [`PlanReport`] — per-plan (setup) metrics: ordering/factorization
//!   time, colors, storage sizes, SIMD statistic. Identical for every solve
//!   that reuses the plan.
//! * [`SolveReport`] — per-solve metrics: iterations, residual, iteration-
//!   loop wall time, kernel breakdown, plus its `PlanReport`.

use std::sync::Arc;

use crate::config::SolverConfig;
use crate::coordinator::metrics::SpmvTraffic;
use crate::coordinator::session::SolveSession;
use crate::error::Result;
use crate::obs::flight::PhaseProfile;
use crate::schedule::cost::ScheduleCost;
use crate::solver::cg::CgResult;
use crate::solver::plan::{SetupStats, SolverPlan};
use crate::sparse::csr::Csr;

/// Per-solve knobs (everything structural lives in the plan).
#[derive(Debug, Clone, Default)]
pub struct SolveOptions {
    /// Record the per-iteration residual history (Fig. 5.1 data).
    pub record_history: bool,
    /// Copy the solution vector into `SolveReport::solution`. Off by
    /// default: at `Scale::Full` this is hundreds of thousands of doubles
    /// per report, and session callers already receive `x` in
    /// `SolveOutput` without any copy.
    pub return_solution: bool,
    /// Override the plan's convergence tolerance for this solve.
    pub rtol: Option<f64>,
    /// Override the plan's iteration cap for this solve.
    pub max_iters: Option<usize>,
    /// Arm the in-region flight recorder: the report comes back with
    /// [`SolveReport::profile`] populated (per-thread phase spans +
    /// barrier-wait attribution; fused path only). Numerically inert —
    /// see `crate::obs::flight`.
    pub profile: bool,
}

impl SolveOptions {
    /// Record the residual history (Fig. 5.1 runs).
    pub fn history() -> SolveOptions {
        SolveOptions { record_history: true, ..Default::default() }
    }

    /// Return the solution vector in the report (one-shot callers).
    pub fn with_solution() -> SolveOptions {
        SolveOptions { return_solution: true, ..Default::default() }
    }

    /// History + solution.
    pub fn full() -> SolveOptions {
        SolveOptions { record_history: true, return_solution: true, ..Default::default() }
    }

    /// Arm the in-region flight recorder (`solve --profile`).
    pub fn profiled() -> SolveOptions {
        SolveOptions { profile: true, ..Default::default() }
    }
}

/// Per-plan (setup-phase) metrics; identical across solves on one plan.
#[derive(Debug, Clone)]
pub struct PlanReport {
    pub config_label: String,
    pub setup: SetupStats,
    /// Analytic packed-FP fraction (§5.2.1 SIMD statistic).
    pub simd_ratio: f64,
    /// Syncs per substitution sweep (= n_c − 1).
    pub syncs_per_substitution: usize,
    /// SELL processed-element overhead vs CRS nnz (§5.2.2), if SELL used.
    pub sell_overhead: Option<f64>,
    /// Analytic per-SpMV memory traffic for the chosen storage format
    /// (roofline numerator; compare against measured bytes moved).
    pub spmv_traffic: SpmvTraffic,
    /// Substitution strategy ("ic0-hbmc", ...).
    pub trisolver: &'static str,
    /// Level-schedule shape and cost model (Some only for the level path):
    /// wavefront count, rows-per-level histogram, coarsened stage count and
    /// the barrier-vs-spin sweep costs behind it.
    pub schedule: Option<ScheduleCost>,
}

impl PlanReport {
    pub fn of(plan: &SolverPlan) -> PlanReport {
        PlanReport {
            config_label: plan.cfg.label(),
            setup: plan.setup.clone(),
            simd_ratio: plan.ops.simd_ratio(),
            syncs_per_substitution: plan.trisolver.syncs_per_sweep(),
            sell_overhead: plan.sell_overhead(),
            spmv_traffic: SpmvTraffic::model(
                plan.cfg.spmv,
                plan.setup.n_aug,
                plan.setup.spmv_elements,
                plan.cfg.w,
            ),
            trisolver: plan.trisolver.name(),
            schedule: plan.schedule.clone(),
        }
    }
}

/// One recovery attempt the dispatcher's retry ladder performed before
/// this solve succeeded (or gave up) — see `crate::resil`.
#[derive(Debug, Clone)]
pub struct RetryAttempt {
    /// What failed: `"panic"`, `"breakdown_factorization"`,
    /// `"breakdown_iteration"` or `"not_converged"` (the label values of
    /// the `hbmc_retries_total{cause=…}` metric family).
    pub cause: &'static str,
    /// What the ladder did about it, human-readable (e.g.
    /// `"re-plan with escalated shift 0.02"`, `"fallback to level
    /// ordering"`, `"pool rebuilt; retried on fresh session"`).
    pub action: String,
}

/// Everything the benches/tables/CLI report about one solve.
#[derive(Debug, Clone)]
pub struct SolveReport {
    pub iterations: usize,
    pub converged: bool,
    pub final_relres: f64,
    /// Iteration-loop wall time (the paper's Table 5.3 "execution time") —
    /// excludes all setup, which is in `plan.setup`.
    pub solve_seconds: f64,
    /// Per-kernel time breakdown (trisolve / spmv / blas1).
    pub kernel_seconds: Vec<(&'static str, f64)>,
    /// Residual history when requested (Fig. 5.1).
    pub residual_history: Vec<f64>,
    /// Solution in the original ordering; populated only when
    /// [`SolveOptions::return_solution`] is set.
    pub solution: Option<Vec<f64>>,
    /// `Pool::run` dispatches this solve performed: 1 on the fused
    /// single-dispatch path, ~3 per iteration on the legacy loop.
    pub dispatches: u64,
    /// Pool barrier synchronizations this solve performed (color barriers
    /// + fused-loop phase barriers).
    pub pool_syncs: u64,
    /// 0-based index of this solve on its plan (amortization counter).
    pub solve_index: usize,
    /// How many times the dispatcher's recovery ladder re-ran this job
    /// before producing this report (0 = first attempt succeeded).
    pub retries: u32,
    /// Per-retry cause + recovery action, in order (empty when
    /// `retries == 0`).
    pub attempts: Vec<RetryAttempt>,
    /// In-region flight-recorder profile (per-thread phase spans,
    /// barrier-wait attribution) when [`SolveOptions::profile`] was set
    /// and the solve ran the fused path; `None` otherwise.
    pub profile: Option<PhaseProfile>,
    /// The setup-phase metrics of the plan this solve ran on.
    pub plan: PlanReport,
}

impl SolveReport {
    pub(crate) fn from_parts(plan: &SolverPlan, cg: CgResult, solve_index: usize) -> SolveReport {
        SolveReport {
            iterations: cg.iterations,
            converged: cg.converged,
            final_relres: cg.final_relres,
            solve_seconds: cg.solve_seconds,
            kernel_seconds: cg.times.iter().map(|(n, d)| (n, d.as_secs_f64())).collect(),
            residual_history: cg.residual_history,
            solution: None,
            // Filled in by the session (the dispatch/sync deltas live on
            // the pool, which `from_parts` does not see).
            dispatches: 0,
            pool_syncs: 0,
            solve_index,
            // Filled in by the dispatcher when its recovery ladder re-ran
            // the job.
            retries: 0,
            attempts: Vec::new(),
            // Filled in by the session (the drained profile rides on the
            // `SolveOutcome`, which `from_parts` does not see).
            profile: None,
            plan: PlanReport::of(plan),
        }
    }

    /// Seconds spent in a kernel bucket.
    pub fn kernel(&self, name: &str) -> f64 {
        self.kernel_seconds
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }
}

/// One-shot convenience: plan + session + one solve, borrowing the matrix
/// (no registration, no copy). The report omits the solution and history;
/// see [`SolveOptions`]. Kept as a thin compatible wrapper — it runs the
/// exact execution path a [`SolverService`](crate::api::SolverService)
/// request takes, so results are bit-identical to the façade; production
/// callers serving many right-hand sides should hold a service themselves.
pub fn solve(a: &Csr, b: &[f64], cfg: &SolverConfig) -> Result<SolveReport> {
    solve_opts(a, b, cfg, &SolveOptions::default())
}

/// One-shot with explicit per-solve options (same thin wrapper).
pub fn solve_opts(
    a: &Csr,
    b: &[f64],
    cfg: &SolverConfig,
    opts: &SolveOptions,
) -> Result<SolveReport> {
    let session = SolveSession::for_request(Arc::new(SolverPlan::build(a, cfg)?), cfg);
    Ok(session.solve_with(b, opts)?.report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OrderingKind, SolverConfig, SpmvKind};
    use crate::gen::suite;

    #[test]
    fn report_has_full_metric_set() {
        let d = suite::dataset("g3_circuit", crate::config::Scale::Tiny);
        let cfg = SolverConfig {
            ordering: OrderingKind::Hbmc,
            bs: 8,
            w: 4,
            spmv: SpmvKind::Sell,
            rtol: 1e-7,
            ..Default::default()
        };
        let rep = solve_opts(&d.matrix, &d.b, &cfg, &SolveOptions::full()).unwrap();
        assert!(rep.converged, "relres={}", rep.final_relres);
        assert!(rep.iterations > 0);
        assert!(rep.solve_seconds > 0.0);
        assert!(rep.plan.simd_ratio > 0.9, "hbmc+sell should be mostly packed");
        assert!(rep.plan.sell_overhead.unwrap() >= 1.0);
        assert_eq!(rep.residual_history.len(), rep.iterations);
        assert!(rep.kernel("trisolve") > 0.0);
        assert!(rep.kernel("spmv") > 0.0);
        assert_eq!(rep.plan.syncs_per_substitution, rep.plan.setup.num_colors - 1);
        assert!(rep.plan.spmv_traffic.total_bytes() > 0);
        assert_eq!(rep.plan.trisolver, "ic0-hbmc");
        assert_eq!(rep.solve_index, 0);
        // rhs was A·1 → solution ≈ 1.
        let sol = rep.solution.as_ref().unwrap();
        let err = sol.iter().map(|x| (x - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-4, "solution error {err}");
    }

    #[test]
    fn level_report_surfaces_the_schedule_cost_model() {
        let d = suite::dataset("g3_circuit", crate::config::Scale::Tiny);
        let cfg = SolverConfig {
            ordering: OrderingKind::Level,
            spmv: SpmvKind::Crs,
            ..Default::default()
        };
        let rep = solve(&d.matrix, &d.b, &cfg).unwrap();
        assert!(rep.converged);
        assert_eq!(rep.plan.trisolver, "ic0-level");
        let sched = rep.plan.schedule.as_ref().expect("level plan report has schedule");
        assert!(sched.levels >= 1);
        assert_eq!(sched.rows_per_level.iter().sum::<usize>(), sched.levels);
        assert_eq!(sched.coarsened_stages, rep.plan.setup.num_colors);
        assert_eq!(sched.predicted_syncs_per_sweep, rep.plan.syncs_per_substitution);
        // Reordering paths carry no schedule in their reports.
        let cfg = SolverConfig { ordering: OrderingKind::Bmc, bs: 8, w: 4, ..Default::default() };
        let rep = solve(&d.matrix, &d.b, &cfg).unwrap();
        assert!(rep.plan.schedule.is_none());
    }

    #[test]
    fn solution_and_history_are_opt_in() {
        let d = suite::dataset("g3_circuit", crate::config::Scale::Tiny);
        let cfg = SolverConfig { ordering: OrderingKind::Bmc, bs: 8, w: 4, ..Default::default() };
        let rep = solve(&d.matrix, &d.b, &cfg).unwrap();
        assert!(rep.converged);
        assert!(rep.solution.is_none(), "solution must not be cloned by default");
        assert!(rep.residual_history.is_empty(), "history must be opt-in");
    }
}
