//! End-to-end solve orchestration: dataset/matrix + config → ordered,
//! factored, storage-built solver → PCG run → [`SolveReport`] with every
//! metric the paper's tables and figures need.

use anyhow::Result;

use crate::config::SolverConfig;
use crate::solver::cg::CgResult;
use crate::solver::iccg::{IccgSolver, SetupStats};
use crate::sparse::csr::Csr;

/// Everything the benches/tables/CLI report about one solve.
#[derive(Debug, Clone)]
pub struct SolveReport {
    pub config_label: String,
    pub iterations: usize,
    pub converged: bool,
    pub final_relres: f64,
    /// Iteration-loop wall time (the paper's Table 5.3 "execution time").
    pub solve_seconds: f64,
    pub setup: SetupStats,
    /// Per-kernel time breakdown (trisolve / spmv / blas1).
    pub kernel_seconds: Vec<(&'static str, f64)>,
    /// Analytic packed-FP fraction (§5.2.1 SIMD statistic).
    pub simd_ratio: f64,
    /// Syncs per substitution sweep (= n_c − 1).
    pub syncs_per_substitution: usize,
    /// SELL processed-element overhead vs CRS nnz (§5.2.2), if SELL used.
    pub sell_overhead: Option<f64>,
    /// Residual history when requested (Fig. 5.1).
    pub residual_history: Vec<f64>,
    /// Solution max-error vs the known x* = 1 when the rhs was A·1.
    pub solution: Vec<f64>,
}

impl SolveReport {
    fn from_parts(label: String, solver: &IccgSolver, cg: CgResult, x: Vec<f64>, syncs: usize) -> SolveReport {
        let sell_overhead = match solver.cfg.spmv {
            crate::config::SpmvKind::Sell => {
                Some(solver.setup.spmv_elements as f64 / solver.setup.nnz as f64)
            }
            crate::config::SpmvKind::Crs => None,
        };
        SolveReport {
            config_label: label,
            iterations: cg.iterations,
            converged: cg.converged,
            final_relres: cg.final_relres,
            solve_seconds: cg.solve_seconds,
            setup: solver.setup.clone(),
            kernel_seconds: cg
                .times
                .iter()
                .map(|(n, d)| (n, d.as_secs_f64()))
                .collect(),
            simd_ratio: solver.ops.simd_ratio(),
            syncs_per_substitution: syncs,
            sell_overhead,
            residual_history: cg.residual_history,
            solution: x,
        }
    }

    /// Seconds spent in a kernel bucket.
    pub fn kernel(&self, name: &str) -> f64 {
        self.kernel_seconds
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }
}

/// One-shot convenience: build + solve.
pub fn solve(a: &Csr, b: &[f64], cfg: &SolverConfig) -> Result<SolveReport> {
    solve_opts(a, b, cfg, false)
}

/// One-shot with residual-history recording (Fig. 5.1).
pub fn solve_opts(a: &Csr, b: &[f64], cfg: &SolverConfig, record_history: bool) -> Result<SolveReport> {
    let solver = IccgSolver::new(a, cfg)?;
    let out = solver.solve_opts(b, record_history)?;
    let label = format!(
        "{}(bs={},w={},{})",
        cfg.ordering.name(),
        cfg.bs,
        cfg.w,
        cfg.spmv.name()
    );
    Ok(SolveReport::from_parts(label, &solver, out.cg, out.x, out.syncs_per_substitution))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OrderingKind, SolverConfig, SpmvKind};
    use crate::gen::suite;

    #[test]
    fn report_has_full_metric_set() {
        let d = suite::dataset("g3_circuit", crate::config::Scale::Tiny);
        let cfg = SolverConfig {
            ordering: OrderingKind::Hbmc,
            bs: 8,
            w: 4,
            spmv: SpmvKind::Sell,
            rtol: 1e-7,
            ..Default::default()
        };
        let rep = solve_opts(&d.matrix, &d.b, &cfg, true).unwrap();
        assert!(rep.converged, "relres={}", rep.final_relres);
        assert!(rep.iterations > 0);
        assert!(rep.solve_seconds > 0.0);
        assert!(rep.simd_ratio > 0.9, "hbmc+sell should be mostly packed");
        assert!(rep.sell_overhead.unwrap() >= 1.0);
        assert_eq!(rep.residual_history.len(), rep.iterations);
        assert!(rep.kernel("trisolve") > 0.0);
        assert!(rep.kernel("spmv") > 0.0);
        assert_eq!(rep.syncs_per_substitution, rep.setup.num_colors - 1);
        // rhs was A·1 → solution ≈ 1.
        let err = rep.solution.iter().map(|x| (x - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-4, "solution error {err}");
    }
}
