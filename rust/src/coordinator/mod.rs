//! Layer-3 coordination: the color-barrier thread pool that implements the
//! paper's multithreading model (§4.4.3 — one sync per color), work
//! scheduling, solver metrics (including the packed-op ratio standing in
//! for the paper's VTune SIMD statistic), the serving layer
//! ([`session`] — reusable `SolveSession`s, batched `solve_many`, the LRU
//! `PlanCache`), the one-shot [`driver`] wrappers and the paper-style
//! report formatting.

pub mod driver;
pub mod experiments;
pub mod metrics;
pub mod pool;
pub mod report;
pub mod schedule;
pub mod session;
