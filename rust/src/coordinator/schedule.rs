//! Work partitioning helpers beyond the plain contiguous chunking in
//! [`crate::coordinator::pool::Pool::chunk`].
//!
//! §4.4.1: "we set the number of BMC blocks assigned to each thread as a
//! multiple of w, except for one of the threads" — so each thread's BMC
//! blocks regroup into whole level-1 blocks and the secondary reordering
//! is thread-local. [`chunk_multiple`] implements that rounding rule.

/// Split `0..len` into `nthreads` contiguous chunks whose sizes are
/// multiples of `mult` (except possibly the last non-empty chunk).
/// Returns the range of chunk `tid`.
pub fn chunk_multiple(len: usize, tid: usize, nthreads: usize, mult: usize) -> std::ops::Range<usize> {
    assert!(mult > 0 && nthreads > 0);
    let units = len.div_ceil(mult); // number of mult-sized units
    let per = units.div_ceil(nthreads);
    let lo = (tid * per * mult).min(len);
    let hi = ((tid + 1) * per * mult).min(len);
    lo..hi
}

/// Static cost-balanced partition of weighted items into `k` contiguous
/// chunks (greedy prefix splitting by average weight) — used to balance
/// level-1 blocks with uneven SELL slice widths across threads.
pub fn balanced_prefix_partition(weights: &[u64], k: usize) -> Vec<std::ops::Range<usize>> {
    assert!(k > 0);
    let total: u64 = weights.iter().sum();
    let target = total as f64 / k as f64;
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    let mut acc = 0u64;
    let mut chunk_idx = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        // Close this chunk once we pass its proportional target, keeping
        // enough items for the remaining chunks.
        let remaining_chunks = k - chunk_idx - 1;
        let remaining_items = weights.len() - i - 1;
        if chunk_idx < k - 1
            && acc as f64 >= target * (chunk_idx + 1) as f64
            && remaining_items >= remaining_chunks
        {
            out.push(start..i + 1);
            start = i + 1;
            chunk_idx += 1;
        }
    }
    out.push(start..weights.len());
    while out.len() < k {
        out.push(weights.len()..weights.len());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_multiple_covers_and_aligns() {
        for len in [0usize, 5, 16, 37, 100] {
            for nt in [1usize, 2, 4] {
                for m in [1usize, 4, 8] {
                    let mut covered = vec![false; len];
                    for tid in 0..nt {
                        let r = chunk_multiple(len, tid, nt, m);
                        if !r.is_empty() {
                            assert_eq!(r.start % m, 0, "len={len} nt={nt} m={m} tid={tid}");
                        }
                        for i in r {
                            assert!(!covered[i]);
                            covered[i] = true;
                        }
                    }
                    assert!(covered.iter().all(|&c| c), "len={len} nt={nt} m={m}");
                }
            }
        }
    }

    #[test]
    fn balanced_partition_covers() {
        let w: Vec<u64> = vec![5, 1, 1, 1, 5, 1, 1, 1, 5];
        let parts = balanced_prefix_partition(&w, 3);
        assert_eq!(parts.len(), 3);
        let mut covered = vec![false; w.len()];
        for p in &parts {
            for i in p.clone() {
                assert!(!covered[i]);
                covered[i] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn balanced_partition_is_roughly_even() {
        let w: Vec<u64> = (0..100).map(|i| 1 + (i % 7) as u64).collect();
        let parts = balanced_prefix_partition(&w, 4);
        let sums: Vec<u64> = parts
            .iter()
            .map(|p| w[p.clone()].iter().sum::<u64>())
            .collect();
        let total: u64 = w.iter().sum();
        for s in &sums {
            assert!((*s as f64) < 0.5 * total as f64, "sums={sums:?}");
        }
    }

    #[test]
    fn more_chunks_than_items() {
        let parts = balanced_prefix_partition(&[3, 3], 4);
        assert_eq!(parts.len(), 4);
        let covered: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(covered, 2);
    }
}
