//! Persistent color-barrier thread pool.
//!
//! The paper's execution model (§4.4.3): the outer substitution loop runs
//! over colors; *within* a color, threads process disjoint sets of rows /
//! blocks / level-1 blocks; after each color all threads synchronize
//! (`n_c − 1` synchronizations per substitution). This pool provides
//! exactly that: [`Pool::run`] executes one closure on every worker
//! (caller participates as worker 0) and [`Pool::color_barrier`] is the
//! intra-job synchronization point, counted so the metrics can report
//! syncs-per-substitution.
//!
//! Since the single-dispatch CG redesign the pool is also the home of the
//! *persistent SPMD region*: `SolverPlan::execute` issues **one** `run`
//! per solve and the workers walk the whole CG iteration together, with
//! [`Pool::phase_barrier`] separating kernel phases (SpMV → reduction →
//! update → sweep …). [`Pool::dispatch_count`] counts `run` calls so the
//! serving metrics can assert "one dispatch per solve".
//!
//! Two reduction primitives exist at different layers: [`Pool::reduce_sum`]
//! combines one partial **per thread** in fixed thread order (run-to-run
//! deterministic for a given width — the general-purpose SPMD reduction
//! for in-region code); the CG loop itself instead reduces over the fixed
//! chunk grid of `solver::blas1` (`dot_partials` + `combine_partials`),
//! because per-thread partials can never be invariant across *thread
//! counts* and the loop's acceptance bar is bitwise parity at any width.
//!
//! Safety: `run` erases the closure's lifetime to hand it to the workers;
//! the completion barrier at the end of `run` guarantees no worker touches
//! the closure after `run` returns, so the borrow never escapes.

use std::cell::{Cell, UnsafeCell};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::resil::FaultInjector;

thread_local! {
    /// Nanoseconds the *current thread* has spent parked in
    /// [`Pool::color_barrier`] since it last called
    /// [`Pool::take_barrier_wait_ns`]. Thread-local so the hot path needs
    /// no `tid` plumbing and no shared writes: each thread accumulates its
    /// own wait and the flight recorder drains it at the next phase mark.
    /// Only written while [`Pool::set_profiling`] is on.
    static BARRIER_WAIT_NS: Cell<u64> = const { Cell::new(0) };
}

/// Process-wide count of worker threads detached (never joined) by
/// [`Pool::drain`] because they failed to park within the grace period —
/// a desynchronized-barrier casualty. Monotonic; chaos tests assert it
/// does not grow across a recovery (clean rebuilds join everything).
static LEAKED_WORKERS: AtomicU64 = AtomicU64::new(0);

/// Read [`LEAKED_WORKERS`]; see [`Pool::drain`].
pub fn leaked_workers() -> u64 {
    LEAKED_WORKERS.load(Ordering::SeqCst)
}

/// One per-thread reduction slot, padded to two cache lines so neighbour
/// threads never false-share while writing partials. Double-buffered
/// (`vals[parity]`): a thread may enter reduction `k + 1` and overwrite one
/// buffer while a straggler is still summing reduction `k` from the other,
/// so a single barrier per [`Pool::reduce_sum`] suffices (see the safety
/// argument there).
#[repr(align(128))]
struct ReduceSlot {
    vals: UnsafeCell<[f64; 2]>,
    /// Reductions completed by the owning thread — selects the buffer
    /// parity. Written only by the owner; the SPMD contract (every thread
    /// performs the same reduction sequence) keeps all counters in step.
    count: UnsafeCell<u64>,
}

// SAFETY: cross-thread access is disciplined by `reduce_sum`'s barrier —
// `vals[p]` is written only by the owner before the barrier and read by
// everyone after it; `count` is owner-thread-only.
unsafe impl Sync for ReduceSlot {}

/// Lifetime-erased job pointer. The pool guarantees the pointee outlives
/// every access (completion barrier in `run`).
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize, usize) + Sync));
unsafe impl Send for JobPtr {}
unsafe impl Sync for JobPtr {}

struct Shared {
    nthreads: usize,
    /// All participants (workers + caller) meet here — used both for the
    /// intra-job color barrier and for job completion.
    barrier: Barrier,
    job: Mutex<(u64, Option<JobPtr>)>, // (epoch, job)
    job_cv: Condvar,
    shutdown: AtomicBool,
    syncs: AtomicU64,
    dispatches: AtomicU64,
    /// Per-thread reduction scratchpad (see [`Pool::reduce_sum`]).
    red: Vec<ReduceSlot>,
    active_jobs: AtomicUsize,
    /// Set when a worker's closure panicked during the current job; the
    /// caller re-raises it after the completion barrier so the panic is
    /// observed on the calling thread instead of silently killing a
    /// worker (which would leave every later `run` waiting forever on a
    /// short barrier).
    worker_panicked: AtomicBool,
    /// Deterministic fault injection (chaos testing; see `crate::resil`).
    /// `None` in production: the only cost is this null check per barrier.
    injector: Option<Arc<FaultInjector>>,
    /// When set (see [`Pool::set_profiling`]), every barrier crossing
    /// stamps the monotonic clock around its wait and accumulates the
    /// elapsed time into the crossing thread's [`BARRIER_WAIT_NS`] cell.
    /// Off by default: the unprofiled barrier path pays one relaxed load.
    profiling: AtomicBool,
}

/// Persistent worker pool; see module docs.
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Create a pool with `nthreads` total workers (including the caller).
    pub fn new(nthreads: usize) -> Pool {
        Pool::with_injector(nthreads, None)
    }

    /// [`Pool::new`] with an armed fault injector: every
    /// [`Pool::color_barrier`] / [`Pool::phase_barrier`] crossing reports
    /// its exact logical barrier index to the injector's panic hook, so a
    /// `FaultSpec::WorkerPanic` fires on **all** threads in lockstep.
    pub fn with_injector(nthreads: usize, injector: Option<Arc<FaultInjector>>) -> Pool {
        assert!(nthreads >= 1);
        let shared = Arc::new(Shared {
            nthreads,
            barrier: Barrier::new(nthreads),
            job: Mutex::new((0, None)),
            job_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            syncs: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
            red: (0..nthreads)
                .map(|_| ReduceSlot {
                    vals: UnsafeCell::new([0.0; 2]),
                    count: UnsafeCell::new(0),
                })
                .collect(),
            active_jobs: AtomicUsize::new(0),
            worker_panicked: AtomicBool::new(false),
            injector,
            profiling: AtomicBool::new(false),
        });
        let handles = (1..nthreads)
            .map(|tid| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("hbmc-worker-{tid}"))
                    .spawn(move || worker_loop(sh, tid))
                    .expect("spawn worker")
            })
            .collect();
        Pool { shared, handles }
    }

    pub fn nthreads(&self) -> usize {
        self.shared.nthreads
    }

    /// Execute `f(tid, nthreads)` on every worker; blocks until all done.
    /// `f` may call [`Pool::color_barrier`] as long as **every** worker
    /// performs the same number of barrier calls (true for color loops).
    pub fn run(&self, f: &(dyn Fn(usize, usize) + Sync)) {
        let n = self.shared.nthreads;
        self.shared.dispatches.fetch_add(1, Ordering::Relaxed);
        if n == 1 {
            f(0, 1);
            return;
        }
        debug_assert_eq!(
            self.shared.active_jobs.swap(1, Ordering::SeqCst),
            0,
            "Pool::run is not reentrant"
        );
        {
            let mut slot = self.shared.job.lock().unwrap();
            // SAFETY: lifetime erased; completion barrier below keeps the
            // borrow alive for the whole job.
            let ptr: JobPtr = unsafe {
                std::mem::transmute::<
                    *const (dyn Fn(usize, usize) + Sync),
                    JobPtr,
                >(f as *const _)
            };
            slot.0 += 1;
            slot.1 = Some(ptr);
            self.shared.job_cv.notify_all();
        }
        // The caller participates as worker 0, but its panic must not skip
        // the completion barrier: the workers always arrive there (their
        // panics are caught in `worker_loop`), and a caller that unwound
        // past it would leave them waiting forever. Catch, complete the
        // protocol, then re-raise.
        let caller_panic =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0, n))).err();
        self.shared.barrier.wait(); // completion
        self.shared.active_jobs.store(0, Ordering::SeqCst);
        let worker_panicked = self.shared.worker_panicked.swap(false, Ordering::SeqCst);
        if let Some(p) = caller_panic {
            std::panic::resume_unwind(p);
        }
        if worker_panicked {
            // Re-raise on the calling thread: the job's output is not
            // trustworthy, and the caller (not a detached worker) is the
            // one positioned to contain it. Note: if the panic happened
            // between color barriers the pool's barrier generations may be
            // desynchronized — treat the pool as poisoned; the service
            // dispatcher recovers by draining the session's pool
            // ([`Pool::drain`]) and rebuilding it on a fresh one.
            panic!("pool worker panicked during job");
        }
    }

    /// Intra-job synchronization point (one per color transition).
    pub fn color_barrier(&self) {
        // Count per-thread waits normalized to whole-pool syncs on read.
        // The increment happens *before* the wait so `prev / nthreads` is
        // the exact logical barrier index, identical on every thread
        // crossing it: the `Barrier` keeps any thread from fetching for
        // barrier `k + 1` until all `nthreads` have fetched for `k`, so the
        // fetches for barrier `k` are exactly `[k·nt, (k+1)·nt)`.
        let prev = self.shared.syncs.fetch_add(1, Ordering::Relaxed);
        if let Some(inj) = &self.shared.injector {
            // May panic (injected worker panic) — and then panics on every
            // thread at the same index, *before* any of them waits, so the
            // barrier generation stays synchronized and the pool remains
            // drainable afterwards.
            inj.barrier_hook(prev / self.shared.nthreads as u64);
        }
        if self.shared.nthreads > 1 {
            if self.shared.profiling.load(Ordering::Relaxed) {
                let t0 = std::time::Instant::now();
                self.shared.barrier.wait();
                let waited = t0.elapsed().as_nanos() as u64;
                BARRIER_WAIT_NS.with(|c| c.set(c.get() + waited));
            } else {
                self.shared.barrier.wait();
            }
        }
    }

    /// Arm (or disarm) barrier-wait timing for subsequent barrier
    /// crossings on this pool. Cheap and raceless to flip between jobs;
    /// flipping it *during* a job would merely start/stop accumulation
    /// mid-flight. Off by default — the unprofiled barrier pays exactly
    /// one relaxed load.
    pub fn set_profiling(&self, on: bool) {
        self.shared.profiling.store(on, Ordering::Relaxed);
    }

    /// Drain the **calling thread's** accumulated barrier-wait
    /// nanoseconds (thread-local; resets to zero). In-region profiling
    /// calls this at every phase mark so each recorded span can report
    /// how much of its interval was barrier parking rather than work;
    /// callers outside a job use it to clear stale state.
    pub fn take_barrier_wait_ns(&self) -> u64 {
        BARRIER_WAIT_NS.with(|c| c.replace(0))
    }

    /// Phase boundary inside a persistent SPMD region (the single-dispatch
    /// CG loop): identical mechanics to [`Pool::color_barrier`], named for
    /// readability at call sites that separate kernel *phases* (SpMV →
    /// reduction → update → sweep) rather than substitution colors. Counted
    /// in [`Pool::sync_count`] like any other barrier.
    #[inline]
    pub fn phase_barrier(&self) {
        self.color_barrier();
    }

    /// Deterministic sum-reduction across the pool, callable only from
    /// inside a job (every thread must call it, in the same sequence — the
    /// usual SPMD contract). Thread `tid` contributes `partial`; every
    /// thread receives the identical total, combined **in fixed thread
    /// order** `0, 1, …, nt−1`, so the result is bitwise run-to-run
    /// deterministic for a given thread count.
    ///
    /// Costs one barrier. Safety of the single barrier: slot writes for
    /// reduction `k` happen-before the barrier of `k`; the earliest a slot
    /// can be overwritten is in reduction `k + 2` (double buffering), whose
    /// write happens-after its caller passed the barrier of `k + 1`, which
    /// in turn happens-after every thread finished reading reduction `k`.
    ///
    /// Note for reductions that must also be invariant across *thread
    /// counts* (the CG loop's dot products): combine per-**chunk** partials
    /// over the fixed grid of [`crate::solver::blas1::CHUNK`]-sized chunks
    /// instead — see `blas1::combine_partials` — because per-thread
    /// partials necessarily depend on the partitioning.
    pub fn reduce_sum(&self, tid: usize, partial: f64) -> f64 {
        let nt = self.shared.nthreads;
        debug_assert!(tid < nt);
        let slot = &self.shared.red[tid];
        // SAFETY: `count` is owner-thread-only; `vals[parity]` is written
        // only by the owner before the barrier below (see module docs).
        let parity = unsafe {
            let count = &mut *slot.count.get();
            let parity = (*count % 2) as usize;
            *count += 1;
            (*slot.vals.get())[parity] = partial;
            parity
        };
        self.color_barrier();
        let mut sum = 0.0;
        for t in 0..nt {
            // SAFETY: published by the barrier; not overwritten until the
            // next-but-one reduction (double buffer).
            sum += unsafe { (*self.shared.red[t].vals.get())[parity] };
        }
        sum
    }

    /// Number of [`Pool::run`] dispatches since construction (condvar
    /// wake-up + completion barrier each) — the serving layer's
    /// "dispatches per solve" metric.
    pub fn dispatch_count(&self) -> u64 {
        self.shared.dispatches.load(Ordering::Relaxed)
    }

    pub fn reset_dispatch_count(&self) {
        self.shared.dispatches.store(0, Ordering::Relaxed);
    }

    /// Number of whole-pool synchronizations since construction/reset
    /// (color barriers only; job-completion barriers excluded).
    pub fn sync_count(&self) -> u64 {
        self.shared.syncs.load(Ordering::Relaxed) / self.shared.nthreads as u64
    }

    pub fn reset_sync_count(&self) {
        self.shared.syncs.store(0, Ordering::Relaxed);
    }

    /// Split `0..len` into `nthreads` contiguous chunks; returns the range
    /// of chunk `tid`.
    pub fn chunk(len: usize, tid: usize, nthreads: usize) -> std::ops::Range<usize> {
        let per = len.div_ceil(nthreads);
        let lo = (tid * per).min(len);
        let hi = ((tid + 1) * per).min(len);
        lo..hi
    }

    /// Tear the pool down with a bounded grace period, reporting how many
    /// workers had to be **detached** (leaked) because they never parked.
    ///
    /// This is the dispatcher's recovery path after a worker panic: signal
    /// shutdown, wake every parked worker, then give each thread ~500 ms
    /// total to exit. Workers that finish are joined; a worker stuck on a
    /// desynchronized barrier generation can never be joined, so its handle
    /// is dropped (the thread is leaked) and counted — both in the return
    /// value and in the process-wide [`leaked_workers`] counter that the
    /// chaos tests assert stays flat across clean recoveries.
    ///
    /// After a *lockstep* panic (all threads panicking at the same barrier
    /// index, which is what the fault injector guarantees) the workers are
    /// parked on the job condvar and drain joins all of them: zero leaks.
    pub fn drain(mut self) -> usize {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            let mut slot = self.shared.job.lock().unwrap();
            slot.0 += 1;
            self.shared.job_cv.notify_all();
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_millis(500);
        let mut leaked = 0usize;
        for h in self.handles.drain(..) {
            loop {
                if h.is_finished() {
                    let _ = h.join();
                    break;
                }
                if std::time::Instant::now() >= deadline {
                    // Detaching leaks the thread (and its Arc<Shared>), but
                    // frees the caller to rebuild instead of hanging.
                    LEAKED_WORKERS.fetch_add(1, Ordering::SeqCst);
                    leaked += 1;
                    drop(h);
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        // `handles` is empty now, so the Drop impl joins nothing.
        leaked
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            let mut slot = self.shared.job.lock().unwrap();
            slot.0 += 1;
            self.shared.job_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>, tid: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut slot = sh.job.lock().unwrap();
            while slot.0 == seen_epoch && !sh.shutdown.load(Ordering::SeqCst) {
                slot = sh.job_cv.wait(slot).unwrap();
            }
            if sh.shutdown.load(Ordering::SeqCst) {
                return;
            }
            seen_epoch = slot.0;
            slot.1
        };
        if let Some(JobPtr(ptr)) = job {
            // SAFETY: `run` keeps the closure alive until the completion
            // barrier below.
            let f = unsafe { &*ptr };
            // A panicking closure must not kill the worker: every later
            // job would then wait forever on a barrier that is one thread
            // short. Catch it, flag it, and still arrive at the completion
            // barrier; `run` re-raises on the caller. Best-effort only:
            // this restores the protocol when the panic happens outside a
            // color loop (or after its last barrier) — or on *every* thread
            // at the same barrier index, which is what the fault injector's
            // lockstep panics guarantee. A worker panicking alone with ≥ 2
            // color barriers still ahead deserts those waits and the one
            // shared `Barrier` stays desynchronized — the remaining
            // participants hang, which a std Barrier cannot express (no
            // poisoning). [`Pool::drain`] bounds that hang: it joins what
            // it can and detaches (counts) the rest.
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(tid, sh.nthreads)))
                .is_err()
            {
                sh.worker_panicked.store(true, Ordering::SeqCst);
            }
            sh.barrier.wait(); // completion
        }
    }
}

/// Shared-slice wrapper allowing disjoint concurrent writes from pool
/// workers (each thread owns a distinct row range; cross-range reads are
/// ordered by [`Pool::color_barrier`]).
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SyncSlice<'_, T> {}
unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        SyncSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read element `i`.
    ///
    /// # Safety
    /// Caller must ensure no concurrent writer to `i` without a barrier in
    /// between.
    #[inline]
    pub unsafe fn get(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        *self.ptr.add(i)
    }

    /// Write element `i`.
    ///
    /// # Safety
    /// Caller must ensure exclusive access to index `i` (disjoint thread
    /// partitions).
    #[inline]
    pub unsafe fn set(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }

    /// Raw base pointer (for the intrinsic gather paths).
    #[inline]
    pub fn as_ptr(&self) -> *const T {
        self.ptr
    }

    /// Mutable raw pointer into a disjoint region.
    ///
    /// # Safety
    /// Same contract as [`SyncSlice::set`].
    #[inline]
    pub unsafe fn as_mut_ptr(&self) -> *mut T {
        self.ptr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn single_thread_runs_inline() {
        let pool = Pool::new(1);
        let hits = AtomicUsize::new(0);
        pool.run(&|tid, n| {
            assert_eq!((tid, n), (0, 1));
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn all_workers_participate() {
        let pool = Pool::new(4);
        let mask = AtomicUsize::new(0);
        pool.run(&|tid, n| {
            assert_eq!(n, 4);
            mask.fetch_or(1 << tid, Ordering::SeqCst);
        });
        assert_eq!(mask.load(Ordering::SeqCst), 0b1111);
    }

    #[test]
    fn color_barrier_orders_phases() {
        // Phase 1 writes each thread's cell; phase 2 reads all cells.
        let pool = Pool::new(3);
        let mut data = vec![0usize; 3];
        let slice = SyncSlice::new(&mut data);
        let ok = AtomicUsize::new(0);
        pool.run(&|tid, n| {
            unsafe { slice.set(tid, tid + 1) };
            pool.color_barrier();
            let sum: usize = (0..n).map(|i| unsafe { slice.get(i) }).sum();
            if sum == 6 {
                ok.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(ok.load(Ordering::SeqCst), 3);
        assert_eq!(pool.sync_count(), 1);
    }

    #[test]
    fn sync_count_accumulates_and_resets() {
        let pool = Pool::new(2);
        pool.run(&|_, _| {
            for _ in 0..5 {
                pool.color_barrier();
            }
        });
        assert_eq!(pool.sync_count(), 5);
        pool.reset_sync_count();
        assert_eq!(pool.sync_count(), 0);
    }

    #[test]
    fn sequential_jobs_reuse_workers() {
        let pool = Pool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..10 {
            pool.run(&|_, _| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 30);
    }

    #[test]
    #[should_panic(expected = "pool worker panicked")]
    fn worker_panic_is_reraised_on_caller() {
        // A worker panic (outside any color loop) must not kill the worker
        // silently: the caller observes it as its own panic after the
        // completion barrier, and the pool's threads stay joinable (Drop
        // runs during this test's unwind).
        let pool = Pool::new(2);
        pool.run(&|tid, _n| {
            if tid == 1 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn chunk_partition_covers_range() {
        for len in [0usize, 1, 7, 100] {
            for nt in [1usize, 2, 3, 8] {
                let mut covered = vec![false; len];
                for tid in 0..nt {
                    for i in Pool::chunk(len, tid, nt) {
                        assert!(!covered[i]);
                        covered[i] = true;
                    }
                }
                assert!(covered.iter().all(|&c| c), "len={len} nt={nt}");
            }
        }
    }

    #[test]
    fn dispatch_count_counts_runs() {
        for nt in [1usize, 3] {
            let pool = Pool::new(nt);
            assert_eq!(pool.dispatch_count(), 0);
            for _ in 0..4 {
                pool.run(&|_, _| {});
            }
            assert_eq!(pool.dispatch_count(), 4, "nt={nt}");
            pool.reset_dispatch_count();
            assert_eq!(pool.dispatch_count(), 0);
        }
    }

    #[test]
    fn reduce_sum_is_deterministic_and_complete() {
        for nt in [1usize, 2, 4] {
            let pool = Pool::new(nt);
            let results = Mutex::new(Vec::new());
            pool.run(&|tid, n| {
                // Two back-to-back reductions exercise the double buffer.
                let a = pool.reduce_sum(tid, (tid + 1) as f64);
                let b = pool.reduce_sum(tid, 0.5);
                results.lock().unwrap().push((a, b, n));
            });
            let expect_a = (nt * (nt + 1) / 2) as f64;
            let expect_b = 0.5 * nt as f64;
            let got = results.lock().unwrap();
            assert_eq!(got.len(), nt);
            for &(a, b, _) in got.iter() {
                assert_eq!(a, expect_a, "nt={nt}");
                assert_eq!(b, expect_b, "nt={nt}");
            }
        }
    }

    #[test]
    fn reduce_sum_repeated_runs_are_bitwise_identical() {
        let pool = Pool::new(4);
        let vals: Vec<f64> = (0..4).map(|t| 0.1 * (t as f64 + 1.0)).collect();
        let mut seen = Vec::new();
        for _ in 0..3 {
            let out = Mutex::new(0.0f64);
            let vals = &vals;
            pool.run(&|tid, _| {
                let s = pool.reduce_sum(tid, vals[tid]);
                if tid == 0 {
                    *out.lock().unwrap() = s;
                }
            });
            seen.push(out.into_inner().unwrap());
        }
        assert!(seen.windows(2).all(|w| w[0].to_bits() == w[1].to_bits()));
    }

    #[test]
    fn caller_panic_completes_the_protocol_and_pool_stays_usable() {
        // Worker 0 (the caller) panics; workers 1..n finish normally. The
        // caller must still arrive at the completion barrier before
        // re-raising, so the pool is not desynchronized and remains both
        // reusable and cleanly drainable.
        let pool = Pool::new(3);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(&|tid, _| {
                if tid == 0 {
                    panic!("caller boom");
                }
            });
        }));
        assert!(r.is_err());
        let hits = AtomicUsize::new(0);
        pool.run(&|_, _| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
        let before = leaked_workers();
        assert_eq!(pool.drain(), 0);
        assert_eq!(leaked_workers(), before);
    }

    #[test]
    fn injected_lockstep_panic_fires_at_the_exact_barrier_and_drains_clean() {
        use crate::resil::{FaultInjector, FaultPhase, FaultSpec};
        for nt in [1usize, 4] {
            let inj = Arc::new(FaultInjector::new(FaultSpec::WorkerPanic {
                phase: FaultPhase::Any,
                barrier: 1,
            }));
            let pool = Pool::with_injector(nt, Some(Arc::clone(&inj)));
            let past = AtomicUsize::new(0);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(&|_, _| {
                    pool.color_barrier(); // index 0 — survives
                    past.fetch_add(1, Ordering::SeqCst);
                    pool.color_barrier(); // index 1 — every thread panics here
                    panic!("must not reach barrier index 2");
                });
            }));
            assert!(r.is_err(), "nt={nt}");
            // All threads crossed barrier 0 and none crossed barrier 1.
            assert_eq!(past.load(Ordering::SeqCst), nt, "nt={nt}");
            // The hook only *reads* the charge; the dispatcher consumes it
            // when it decides to retry. Consume here so the pool is clean.
            assert!(inj.armed());
            assert!(inj.consume_panic());
            let hits = AtomicUsize::new(0);
            pool.run(&|_, _| {
                pool.color_barrier();
                pool.color_barrier();
                hits.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(hits.load(Ordering::SeqCst), nt, "nt={nt}");
            // Lockstep panic kept the barrier generations synchronized, so
            // drain joins every worker: zero leaks.
            let before = leaked_workers();
            assert_eq!(pool.drain(), 0, "nt={nt}");
            assert_eq!(leaked_workers(), before);
        }
    }

    #[test]
    fn barrier_wait_accumulates_only_while_profiling() {
        let pool = Pool::new(4);
        // Clear any stale thread-local state, then run unprofiled: the
        // accumulator must stay at zero.
        pool.take_barrier_wait_ns();
        pool.run(&|_, _| {
            pool.take_barrier_wait_ns();
            for _ in 0..3 {
                pool.color_barrier();
            }
            assert_eq!(pool.take_barrier_wait_ns(), 0);
        });
        // Profiled: a deliberately skewed arrival makes the fast threads
        // park measurably, and take() drains + resets per thread.
        pool.set_profiling(true);
        let waits = Mutex::new(Vec::new());
        pool.run(&|tid, _| {
            pool.take_barrier_wait_ns();
            if tid == 0 {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            pool.color_barrier();
            let w = pool.take_barrier_wait_ns();
            assert_eq!(pool.take_barrier_wait_ns(), 0, "take must reset");
            waits.lock().unwrap().push((tid, w));
        });
        pool.set_profiling(false);
        let waits = waits.into_inner().unwrap();
        assert_eq!(waits.len(), 4);
        // At least one non-straggler thread must have parked for a
        // nontrivial fraction of the straggler's sleep.
        let max_wait = waits.iter().map(|&(_, w)| w).max().unwrap();
        assert!(max_wait >= 5_000_000, "max wait {max_wait}ns too small");
    }

    #[test]
    fn drain_joins_all_workers_after_clean_jobs() {
        let pool = Pool::new(4);
        pool.run(&|_, _| {
            pool.color_barrier();
        });
        let before = leaked_workers();
        assert_eq!(pool.drain(), 0);
        assert_eq!(leaked_workers(), before);
    }

    #[test]
    fn borrowed_state_is_visible() {
        let pool = Pool::new(4);
        let local = vec![1.0f64; 32];
        let mut out = vec![0.0f64; 32];
        let o = SyncSlice::new(&mut out);
        pool.run(&|tid, n| {
            for i in Pool::chunk(32, tid, n) {
                unsafe { o.set(i, local[i] * 2.0) };
            }
        });
        assert!(out.iter().all(|&v| v == 2.0));
    }
}
