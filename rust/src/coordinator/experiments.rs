//! Reproduction harness for every table and figure in the paper's
//! evaluation (§5), shared by the CLI (`hbmc table ...`) and the bench
//! binaries (`cargo bench`). Each function regenerates one artifact:
//!
//! * [`table_5_2`] — iteration counts MC / BMC / HBMC (Table 5.2),
//! * [`fig_5_1`] — BMC vs HBMC residual histories (Fig. 5.1),
//! * [`table_5_3`] — execution times, 4 solvers × bs ∈ {8,16,32}
//!   (Tables 5.3 a/b/c via the node preset),
//! * [`simd_ratio_stat`] — the §5.2.1 packed-instruction statistic,
//! * [`sell_overhead_stat`] — the §5.2.2 processed-elements comparison.

use crate::api::{SolveRequest, SolverService};
use crate::config::{NodePreset, OrderingKind, Scale, SolverConfig, SpmvKind};
use crate::coordinator::driver::SolveReport;
use crate::coordinator::report::{pct, secs, Table};
use crate::error::Result;
use crate::gen::suite;
use crate::solver::plan::SolverPlan;

/// The paper's block-size sweep.
pub const BLOCK_SIZES: [usize; 3] = [8, 16, 32];

fn base_cfg(threads: usize) -> SolverConfig {
    SolverConfig { threads, rtol: 1e-7, max_iters: 50_000, ..Default::default() }
}

/// Table 5.2: iteration counts of MC, BMC and HBMC (bs = 32) on the five
/// datasets. The BMC and HBMC columns must be identical (equivalence).
pub fn table_5_2(scale: Scale, threads: usize) -> Result<(Table, Vec<[usize; 3]>)> {
    let mut t = Table::new(
        "Table 5.2 — number of ICCG iterations (bs = 32, rtol 1e-7)",
        &["Dataset", "MC", "BMC", "HBMC"],
    );
    let mut raw = Vec::new();
    let service = SolverService::with_config(base_cfg(threads))?;
    for d in suite::all(scale) {
        let handle = service.register_matrix(d.matrix);
        let mut iters = [0usize; 3];
        for (slot, ordering) in
            [OrderingKind::Mc, OrderingKind::Bmc, OrderingKind::Hbmc].into_iter().enumerate()
        {
            let cfg = SolverConfig {
                ordering,
                bs: 32,
                w: 4,
                spmv: SpmvKind::Crs,
                shift: d.shift,
                ..base_cfg(threads)
            };
            let req = SolveRequest::new().with_config(cfg);
            let rep = service.solve_with(handle, &d.b, &req)?.report;
            iters[slot] = rep.iterations;
        }
        t.push_row(vec![
            d.name.clone(),
            iters[0].to_string(),
            iters[1].to_string(),
            iters[2].to_string(),
        ]);
        raw.push(iters);
    }
    Ok((t, raw))
}

/// Fig 5.1 data: per-iteration relative residuals for BMC and HBMC on the
/// requested datasets (paper uses G3_circuit and Ieej). Returns
/// `(dataset, bmc_history, hbmc_history)` tuples; CSV rendering is up to
/// the caller.
pub type ConvergenceCurves = Vec<(String, Vec<f64>, Vec<f64>)>;

pub fn fig_5_1(datasets: &[&str], scale: Scale, threads: usize) -> Result<ConvergenceCurves> {
    let mut out = Vec::new();
    let service = SolverService::with_config(base_cfg(threads))?;
    for name in datasets {
        let d = suite::try_dataset(name, scale)?;
        let handle = service.register_matrix(d.matrix);
        let mk = |ordering| SolverConfig {
            ordering,
            bs: 32,
            w: 4,
            spmv: SpmvKind::Crs,
            shift: d.shift,
            ..base_cfg(threads)
        };
        let req = |ordering| SolveRequest::new().with_config(mk(ordering)).record_history();
        let rb = service.solve_with(handle, &d.b, &req(OrderingKind::Bmc))?.report;
        let rh = service.solve_with(handle, &d.b, &req(OrderingKind::Hbmc))?.report;
        out.push((d.name.clone(), rb.residual_history, rh.residual_history));
    }
    Ok(out)
}

/// One cell of Table 5.3.
#[derive(Debug, Clone)]
pub struct Cell {
    pub dataset: String,
    pub solver: String,
    pub bs: usize,
    pub report: SolveReport,
}

/// Table 5.3 (a/b/c by node preset): execution time of MC, BMC(bs),
/// HBMC(crs_spmv)(bs), HBMC(sell_spmv)(bs).
pub fn table_5_3(node: NodePreset, scale: Scale, threads: usize) -> Result<(Table, Vec<Cell>)> {
    let w = node.w();
    let mut t = Table::new(
        &format!("Table 5.3 — ICCG execution time (s), node preset {}", node.describe()),
        &[
            "Dataset", "MC",
            "BMC b8", "BMC b16", "BMC b32",
            "Hcrs b8", "Hcrs b16", "Hcrs b32",
            "Hsell b8", "Hsell b16", "Hsell b32",
        ],
    );
    let mut cells = Vec::new();
    // One plan per cell (distinct configs), but one service + one matrix
    // registration per dataset — the façade the serving tier uses.
    let service = SolverService::with_capacity(base_cfg(threads), 16)?;
    for d in suite::all(scale) {
        let handle = service.register_matrix(d.matrix);
        let mut row = vec![d.name.clone()];
        // MC baseline (CRS SpMV, as in the paper).
        let cfg = SolverConfig {
            ordering: OrderingKind::Mc,
            w,
            spmv: SpmvKind::Crs,
            shift: d.shift,
            ..base_cfg(threads)
        };
        let req = SolveRequest::new().with_config(cfg);
        let rep = service.solve_with(handle, &d.b, &req)?.report;
        row.push(secs(rep.solve_seconds));
        cells.push(Cell { dataset: d.name.clone(), solver: "MC".into(), bs: 0, report: rep });

        for (solver, ordering, spmv) in [
            ("BMC", OrderingKind::Bmc, SpmvKind::Crs),
            ("HBMC(crs)", OrderingKind::Hbmc, SpmvKind::Crs),
            ("HBMC(sell)", OrderingKind::Hbmc, SpmvKind::Sell),
        ] {
            for bs in BLOCK_SIZES {
                let cfg = SolverConfig {
                    ordering,
                    bs,
                    w,
                    spmv,
                    shift: d.shift,
                    ..base_cfg(threads)
                };
                let req = SolveRequest::new().with_config(cfg);
                let rep = service.solve_with(handle, &d.b, &req)?.report;
                row.push(secs(rep.solve_seconds));
                cells.push(Cell {
                    dataset: d.name.clone(),
                    solver: solver.into(),
                    bs,
                    report: rep,
                });
            }
        }
        t.push_row(row);
    }
    Ok((t, cells))
}

/// §5.2.1: packed-FP-operation share, HBMC(sell) vs BMC, per dataset
/// (paper: 99.7% vs 12.7% on G3_circuit/Skylake).
pub fn simd_ratio_stat(scale: Scale, threads: usize) -> Result<Table> {
    let mut t = Table::new(
        "§5.2.1 — packed FP operation share (analytic, per CG iteration)",
        &["Dataset", "BMC (crs)", "HBMC (sell)", "HBMC (crs)"],
    );
    for d in suite::all(scale) {
        let mut vals = Vec::new();
        for (ordering, spmv) in [
            (OrderingKind::Bmc, SpmvKind::Crs),
            (OrderingKind::Hbmc, SpmvKind::Sell),
            (OrderingKind::Hbmc, SpmvKind::Crs),
        ] {
            let cfg = SolverConfig {
                ordering,
                bs: 32,
                w: 8,
                spmv,
                shift: d.shift,
                ..base_cfg(threads)
            };
            // Setup phase only — the ratio is analytic, so build the plan
            // and never run a solve.
            let plan = SolverPlan::build(&d.matrix, &cfg)?;
            vals.push(plan.ops.simd_ratio());
        }
        t.push_row(vec![d.name.clone(), pct(vals[0]), pct(vals[1]), pct(vals[2])]);
    }
    Ok(t)
}

/// §5.2.2: SELL processed-elements overhead vs CRS per dataset and slice
/// width (paper: +40% Audikw_1 vs +10% G3_circuit at w = 8, +28% at w=4).
pub fn sell_overhead_stat(scale: Scale) -> Result<Table> {
    use crate::sparse::sell::Sell;
    let mut t = Table::new(
        "§5.2.2 — SELL stored elements vs CRS nnz",
        &["Dataset", "w=4", "w=8", "w=8 σ=64"],
    );
    for d in suite::all(scale) {
        let nnz = d.matrix.nnz();
        let o4 = Sell::from_csr(&d.matrix, 4).overhead_vs(nnz) - 1.0;
        let o8 = Sell::from_csr(&d.matrix, 8).overhead_vs(nnz) - 1.0;
        let o8s = Sell::from_csr_sigma(&d.matrix, 8, 64).overhead_vs(nnz) - 1.0;
        t.push_row(vec![
            d.name.clone(),
            format!("+{}", pct(o4)),
            format!("+{}", pct(o8)),
            format!("+{}", pct(o8s)),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table52_bmc_equals_hbmc() {
        let (t, raw) = table_5_2(Scale::Tiny, 1).unwrap();
        assert_eq!(raw.len(), 5);
        for (row, iters) in t.rows.iter().zip(&raw) {
            // Tiny-scale ill-conditioned systems amplify FP drift more than
            // the paper's full-size runs (which still show 1714 vs 1715);
            // allow a few iterations of slack here.
            assert!(
                iters[1].abs_diff(iters[2]) <= 2 + iters[1] / 20,
                "BMC ≠ HBMC on {}: {} vs {}",
                row[0],
                iters[1],
                iters[2]
            );
            assert!(iters[0] > 0);
        }
    }

    #[test]
    fn fig51_histories_overlap() {
        let curves = fig_5_1(&["g3_circuit"], Scale::Tiny, 1).unwrap();
        let (_, bmc, hbmc) = &curves[0];
        assert_eq!(bmc.len(), hbmc.len());
        // Mathematically identical; FP reassociation between the two
        // kernel shapes leaves round-off-level drift that ill-conditioned
        // systems amplify late in the run — check the early phase tightly.
        for (a, b) in bmc.iter().zip(hbmc).take(40) {
            assert!((a - b).abs() <= 1e-5 * a.max(*b), "{a} vs {b}");
        }
    }

    #[test]
    fn simd_stat_shows_contrast() {
        let t = simd_ratio_stat(Scale::Tiny, 1).unwrap();
        // HBMC(sell) column ~100%, BMC column much lower, HBMC(crs)
        // in between. (The analytic flop-based ratio compresses the
        // contrast relative to VTune's instruction-based 99.7% vs 12.7% —
        // scalar loops also burn non-FP instructions — but the ordering
        // and the near-100% HBMC(sell) value reproduce.)
        for row in &t.rows {
            let bmc: f64 = row[1].trim_end_matches('%').parse().unwrap();
            let hsell: f64 = row[2].trim_end_matches('%').parse().unwrap();
            let hcrs: f64 = row[3].trim_end_matches('%').parse().unwrap();
            assert!(hsell > 95.0, "{row:?}");
            assert!(bmc < hcrs && hcrs < hsell, "{row:?}");
            assert!(bmc < 60.0, "{row:?}");
        }
    }

    #[test]
    fn sell_overhead_audikw_worst() {
        let t = sell_overhead_stat(Scale::Tiny).unwrap();
        let get = |name: &str| -> f64 {
            let row = t.rows.iter().find(|r| r[0] == name).unwrap();
            row[2].trim_start_matches('+').trim_end_matches('%').parse().unwrap()
        };
        assert!(
            get("audikw_1") > get("g3_circuit"),
            "audikw SELL overhead should exceed g3_circuit"
        );
    }
}
