//! Analytic operation counting — the stand-in for the paper's VTune
//! "percentage of packed floating-point instructions" statistic (§5.2.1:
//! 99.7% for HBMC (sell_spmv) vs 12.7% for BMC).
//!
//! Rather than sampling PMU counters (unavailable here), we count, from the
//! data-structure sizes, how many floating-point operations per CG
//! iteration execute inside `w`-wide packed loops versus scalar loops.
//! The attribution follows how the compiler actually treats each kernel:
//!
//! * HBMC SELL substitutions — packed (the whole inner loop is `w`-wide),
//! * SELL SpMV — packed,
//! * CRS SpMV and MC/BMC substitutions — scalar (irregular row loops),
//! * BLAS-1 (dot/axpy) — packed (contiguous, auto-vectorized).

use crate::config::{OrderingKind, SolverConfig, SpmvKind};

/// Floating-point operations per CG iteration, split by execution style.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpProfile {
    pub packed_flops: u64,
    pub scalar_flops: u64,
}

impl OpProfile {
    /// Fraction of FP work executed as packed (SIMD) operations.
    pub fn simd_ratio(&self) -> f64 {
        let total = self.packed_flops + self.scalar_flops;
        if total == 0 {
            return 0.0;
        }
        self.packed_flops as f64 / total as f64
    }

    pub fn total(&self) -> u64 {
        self.packed_flops + self.scalar_flops
    }
}

/// Inputs for the per-iteration op count.
#[derive(Debug, Clone, Copy)]
pub struct OpInputs {
    /// Augmented dimension.
    pub n: usize,
    /// nnz of the (reordered) matrix.
    pub nnz: usize,
    /// nnz of strict lower + strict upper of L/Lᵀ (CSR substitutions).
    pub tri_nnz: usize,
    /// SELL stored elements of both substitution triangles (HBMC only).
    pub sell_tri_elements: Option<usize>,
    /// SELL stored elements of the SpMV matrix (sell_spmv only).
    pub sell_a_elements: Option<usize>,
}

/// Per-CG-iteration op profile for a solver configuration.
pub fn per_iteration_ops(cfg: &SolverConfig, inp: &OpInputs) -> OpProfile {
    let mut p = OpProfile::default();
    let n = inp.n as u64;

    // SpMV: 2 flops per stored element. The symmetric kernel does 4 flops
    // per stored strict-lower nonzero (gather FMA + scatter FMA) plus 2n
    // for the diagonal — exactly 2·nnz again, in scalar loops (irregular
    // scatter).
    match cfg.spmv {
        SpmvKind::Crs | SpmvKind::SymmCsr => p.scalar_flops += 2 * inp.nnz as u64,
        SpmvKind::Sell => {
            p.packed_flops += 2 * inp.sell_a_elements.expect("sell elements required") as u64
        }
    }

    // Preconditioner: forward + backward substitution.
    match cfg.ordering {
        OrderingKind::Hbmc => {
            let stored = inp.sell_tri_elements.expect("hbmc needs sell triangles") as u64;
            // 2 flops per stored element + 1 packed multiply per row per sweep.
            p.packed_flops += 2 * stored + 2 * n;
        }
        _ => {
            p.scalar_flops += 2 * inp.tri_nnz as u64 + 2 * n;
        }
    }

    // BLAS-1 per iteration: 3 dots (2n each) + 2 axpy (2n) + xpby (2n) +
    // residual update fused in axpy already counted; plus norm ≈ dot.
    p.packed_flops += 6 * 2 * n;
    p
}

/// Barrier structure of an SpMV engine inside the fused loop: how many
/// barriers its worker performs *internally* per product, and whether the
/// loop needs an extra barrier between the q-publish and the `p·q`
/// partials (engines that cannot fuse the dot into their sweep).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpmvSyncShape {
    /// CRS: barrier-free worker, `p·q` partials fused into the sweep.
    Crs,
    /// SELL: barrier-free worker, but σ-sorting breaks chunk ownership so
    /// the dot needs its own barrier-separated pass.
    Sell,
    /// Symmetric colored schedule: one barrier after the diagonal pass
    /// plus one between consecutive colors (= `colors` total), dot in its
    /// own pass.
    SymmColored { colors: usize },
    /// Symmetric buffered fallback: one internal barrier (scatter →
    /// combine), dot in its own pass.
    SymmBuffered,
}

impl SpmvSyncShape {
    /// Barriers the engine's worker performs internally per product.
    pub fn internal_syncs(&self) -> usize {
        match self {
            SpmvSyncShape::Crs | SpmvSyncShape::Sell => 0,
            SpmvSyncShape::SymmColored { colors } => *colors,
            SpmvSyncShape::SymmBuffered => 1,
        }
    }

    /// Extra loop barriers around the `p·q` dot (0 when the partials are
    /// produced in the SpMV sweep itself).
    pub fn pq_extra_syncs(&self) -> usize {
        match self {
            SpmvSyncShape::Crs => 0,
            _ => 1,
        }
    }
}

/// Barrier structure of a substitution engine inside the fused loop: how
/// the trisolver's per-sweep barriers arise. Colored paths pay one barrier
/// per color transition; the level-scheduled path pays one per coarsened
/// stage transition (`schedule::coarsen` merges thin wavefronts, so
/// `coarsened ≤ levels`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrisolveSyncShape {
    /// MC/BMC/HBMC (and the trivial 1-color serial/natural path): barriers
    /// between consecutive colors.
    Colored { colors: usize },
    /// Level-scheduled trisolve: `levels` wavefronts coarsened into
    /// `coarsened` barrier-separated stages.
    Level { levels: usize, coarsened: usize },
}

impl TrisolveSyncShape {
    /// Barrier-separated stages per sweep (what `TriSolver::num_colors`
    /// reports for the matching solver).
    pub fn stages(&self) -> usize {
        match self {
            TrisolveSyncShape::Colored { colors } => *colors,
            TrisolveSyncShape::Level { coarsened, .. } => *coarsened,
        }
    }

    /// Barriers per substitution sweep (= `stages − 1`).
    pub fn syncs_per_sweep(&self) -> usize {
        self.stages().saturating_sub(1)
    }
}

/// Pool synchronizations per steady-state iteration of the **fused**
/// single-dispatch CG loop (`solver::cg::pcg_fused`): the two substitution
/// sweeps' `n_c − 1` color barriers each, plus the six phase barriers
/// (SpMV publish+combine, fused-update combine, forward→backward,
/// backward→dot, r·z combine, p publish), plus one extra q-publish barrier
/// when SELL SpMV cannot fuse the `p·q` partials into its sweep. The
/// legacy loop pays the same color barriers **plus three full dispatches**
/// (condvar wake-up + completion barrier each) per iteration; see the
/// accounting table in ARCHITECTURE.md.
pub fn syncs_per_fused_iteration(num_colors: usize, sell_spmv: bool) -> usize {
    let shape = if sell_spmv { SpmvSyncShape::Sell } else { SpmvSyncShape::Crs };
    syncs_per_fused_iteration_shaped(num_colors, shape)
}

/// [`syncs_per_fused_iteration`] generalized over every engine's barrier
/// shape: the symmetric engine adds its internal barriers on top of the
/// six phase barriers and the per-sweep color barriers.
pub fn syncs_per_fused_iteration_shaped(num_colors: usize, shape: SpmvSyncShape) -> usize {
    syncs_per_fused_iteration_tri(TrisolveSyncShape::Colored { colors: num_colors }, shape)
}

/// The fully-shaped fused-iteration sync model: both substitution sweeps
/// pay the trisolver's per-sweep barriers (color transitions for the
/// reordering paths, coarsened-stage transitions for the level path), plus
/// the six phase barriers and the SpMV engine's own barriers. Because the
/// level solver reports its stage count as `num_colors`, this agrees with
/// [`syncs_per_fused_iteration_shaped`] on every path — the variant exists
/// so call sites can account in the schedule's own vocabulary.
pub fn syncs_per_fused_iteration_tri(tri: TrisolveSyncShape, spmv: SpmvSyncShape) -> usize {
    2 * tri.syncs_per_sweep() + 6 + spmv.pq_extra_syncs() + spmv.internal_syncs()
}

/// Analytic bytes moved from memory per SpMV, split into matrix-structure
/// traffic and vector traffic (`f64` values = 8 B, `u32` indices = 4 B).
/// This is the roofline side of the bench comparisons: the symmetric
/// engine's whole point is a ≈0.5× `matrix_bytes` ratio versus CRS.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpmvTraffic {
    pub matrix_bytes: u64,
    pub vector_bytes: u64,
}

impl SpmvTraffic {
    pub fn total_bytes(&self) -> u64 {
        self.matrix_bytes + self.vector_bytes
    }

    /// Minimum bytes per SpMV for `kind`. `stored` is the format's stored
    /// element count (CRS: nnz; SELL: padded elements; SymmCsr: `n`
    /// diagonal + strict-lower nnz); `w` is the SELL slice height (unused
    /// elsewhere).
    pub fn model(kind: SpmvKind, n: usize, stored: usize, w: usize) -> SpmvTraffic {
        let (n64, stored64) = (n as u64, stored as u64);
        match kind {
            // val + col per element, row_ptr once; read x, write y.
            SpmvKind::Crs => SpmvTraffic {
                matrix_bytes: 12 * stored64 + 4 * (n64 + 1),
                vector_bytes: 16 * n64,
            },
            // val + col per (padded) element, slice_ptr + slice_len per
            // slice, row_of_lane per lane.
            SpmvKind::Sell => {
                let nslices = n.div_ceil(w.max(1)) as u64;
                SpmvTraffic {
                    matrix_bytes: 12 * stored64 + 8 * nslices + 4 * nslices * w as u64,
                    vector_bytes: 16 * n64,
                }
            }
            // Dense diagonal (val only) + strict lower (val + col),
            // row_ptr once; x read, y read-modify-written by the scatter.
            SpmvKind::SymmCsr => {
                let lower = stored64.saturating_sub(n64);
                SpmvTraffic {
                    matrix_bytes: 8 * n64 + 12 * lower + 4 * (n64 + 1),
                    vector_bytes: 24 * n64,
                }
            }
        }
    }
}

/// Cost model the autotuner scores candidates with: the effective seconds
/// per solve when one plan build is amortized over `expected_reuse`
/// solves. `expected_reuse = ∞` scores pure steady-state serving (only
/// time/solve matters — the ROADMAP's "few matrices, many right-hand
/// sides" shape); `expected_reuse = 1` scores a one-shot workload where
/// setup dominates. Non-finite or sub-1 reuse is clamped to the two
/// regimes' boundaries.
pub fn amortized_seconds_per_solve(
    setup_seconds: f64,
    solve_seconds: f64,
    expected_reuse: f64,
) -> f64 {
    if !expected_reuse.is_finite() {
        return solve_seconds;
    }
    solve_seconds + setup_seconds / expected_reuse.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SolverConfig;

    fn inputs() -> OpInputs {
        OpInputs {
            n: 1000,
            nnz: 9000,
            tri_nnz: 8000,
            sell_tri_elements: Some(10_000),
            sell_a_elements: Some(11_000),
        }
    }

    #[test]
    fn hbmc_sell_is_mostly_packed() {
        let cfg = SolverConfig { ordering: OrderingKind::Hbmc, spmv: SpmvKind::Sell, ..Default::default() };
        let p = per_iteration_ops(&cfg, &inputs());
        assert_eq!(p.scalar_flops, 0);
        assert!(p.simd_ratio() > 0.99);
    }

    #[test]
    fn bmc_crs_is_mostly_scalar() {
        let cfg = SolverConfig { ordering: OrderingKind::Bmc, spmv: SpmvKind::Crs, ..Default::default() };
        let p = per_iteration_ops(&cfg, &inputs());
        // Only BLAS-1 is packed: ratio well below 50%.
        assert!(p.simd_ratio() < 0.4, "ratio={}", p.simd_ratio());
        assert!(p.simd_ratio() > 0.0);
    }

    #[test]
    fn hbmc_crs_mixes() {
        let cfg = SolverConfig { ordering: OrderingKind::Hbmc, spmv: SpmvKind::Crs, ..Default::default() };
        let p = per_iteration_ops(&cfg, &inputs());
        let r = p.simd_ratio();
        assert!(r > 0.4 && r < 0.9, "ratio={r}");
    }

    #[test]
    fn empty_profile_ratio_zero() {
        assert_eq!(OpProfile::default().simd_ratio(), 0.0);
    }

    #[test]
    fn fused_sync_model() {
        // Serial/natural ordering (1 color): phase barriers only.
        assert_eq!(syncs_per_fused_iteration(1, false), 6);
        assert_eq!(syncs_per_fused_iteration(1, true), 7);
        // 4 colors: 2·3 color barriers + 6 phase barriers.
        assert_eq!(syncs_per_fused_iteration(4, false), 12);
        // The shaped model reproduces the legacy two shapes exactly…
        assert_eq!(syncs_per_fused_iteration_shaped(4, SpmvSyncShape::Crs), 12);
        assert_eq!(syncs_per_fused_iteration_shaped(1, SpmvSyncShape::Sell), 7);
        // …and adds the symmetric engine's internal barriers: colored pays
        // one per color (diag pass + color transitions), buffered pays one.
        assert_eq!(
            syncs_per_fused_iteration_shaped(1, SpmvSyncShape::SymmColored { colors: 3 }),
            6 + 1 + 3
        );
        assert_eq!(syncs_per_fused_iteration_shaped(1, SpmvSyncShape::SymmBuffered), 6 + 1 + 1);
    }

    #[test]
    fn trisolve_shaped_model_covers_colored_and_level() {
        // Colored shape reproduces the num_colors-based model exactly.
        for colors in [1usize, 2, 4, 9] {
            for shape in [SpmvSyncShape::Crs, SpmvSyncShape::Sell, SpmvSyncShape::SymmBuffered] {
                assert_eq!(
                    syncs_per_fused_iteration_tri(
                        TrisolveSyncShape::Colored { colors },
                        shape
                    ),
                    syncs_per_fused_iteration_shaped(colors, shape)
                );
            }
        }
        // Level shape: barriers come from coarsened stages, not raw levels.
        let lv = TrisolveSyncShape::Level { levels: 40, coarsened: 5 };
        assert_eq!(lv.stages(), 5);
        assert_eq!(lv.syncs_per_sweep(), 4);
        assert_eq!(syncs_per_fused_iteration_tri(lv, SpmvSyncShape::Crs), 2 * 4 + 6);
        // Fully coarsened (one serial stage): phase barriers only, i.e.
        // the same budget as the serial natural path.
        let flat = TrisolveSyncShape::Level { levels: 40, coarsened: 1 };
        assert_eq!(
            syncs_per_fused_iteration_tri(flat, SpmvSyncShape::Crs),
            syncs_per_fused_iteration(1, false)
        );
    }

    #[test]
    fn level_path_ops_are_scalar_like_serial() {
        // The level path runs CSR substitutions over the natural ordering —
        // identical flop attribution to the serial/MC CSR paths.
        let level = SolverConfig {
            ordering: OrderingKind::Level,
            spmv: SpmvKind::Crs,
            ..Default::default()
        };
        let natural = SolverConfig {
            ordering: OrderingKind::Natural,
            spmv: SpmvKind::Crs,
            ..Default::default()
        };
        assert_eq!(per_iteration_ops(&level, &inputs()), per_iteration_ops(&natural, &inputs()));
        let p = per_iteration_ops(&level, &inputs());
        let i = inputs();
        assert_eq!(p.scalar_flops, 2 * i.nnz as u64 + 2 * i.tri_nnz as u64 + 2 * i.n as u64);
    }

    #[test]
    fn symm_flops_equal_full_csr_flops() {
        let cfg = SolverConfig { ordering: OrderingKind::Bmc, spmv: SpmvKind::SymmCsr, ..Default::default() };
        let crs = SolverConfig { ordering: OrderingKind::Bmc, spmv: SpmvKind::Crs, ..Default::default() };
        assert_eq!(per_iteration_ops(&cfg, &inputs()), per_iteration_ops(&crs, &inputs()));
    }

    #[test]
    fn traffic_model_halves_symm_matrix_bytes() {
        // A typical FEM-ish shape: n = 100k, ~7 nnz per row.
        let (n, nnz) = (100_000usize, 700_000usize);
        let crs = SpmvTraffic::model(SpmvKind::Crs, n, nnz, 8);
        let symm_stored = n + (nnz - n) / 2;
        let symm = SpmvTraffic::model(SpmvKind::SymmCsr, n, symm_stored, 8);
        let ratio = symm.matrix_bytes as f64 / crs.matrix_bytes as f64;
        assert!(ratio <= 0.6, "symm/crs matrix-bytes ratio {ratio}");
        assert!(ratio > 0.4, "model sanity: {ratio}");
        // Vector traffic goes the other way (y is read-modify-written).
        assert_eq!(symm.vector_bytes, 24 * n as u64);
        assert_eq!(crs.vector_bytes, 16 * n as u64);
        assert!(symm.total_bytes() < crs.total_bytes());
    }

    #[test]
    fn traffic_model_counts_sell_padding() {
        let s = SpmvTraffic::model(SpmvKind::Sell, 64, 1024, 8);
        let nslices = 8u64;
        assert_eq!(s.matrix_bytes, 12 * 1024 + 8 * nslices + 4 * nslices * 8);
    }

    #[test]
    fn amortized_score_spans_both_regimes() {
        // One-shot: the whole setup is billed to the single solve.
        assert_eq!(amortized_seconds_per_solve(10.0, 1.0, 1.0), 11.0);
        // Heavy reuse: setup nearly vanishes.
        assert!((amortized_seconds_per_solve(10.0, 1.0, 1000.0) - 1.01).abs() < 1e-12);
        // Pure serving: setup ignored entirely.
        assert_eq!(amortized_seconds_per_solve(10.0, 1.0, f64::INFINITY), 1.0);
        // Degenerate reuse clamps to the one-shot regime.
        assert_eq!(amortized_seconds_per_solve(10.0, 1.0, 0.0), 11.0);
    }
}
