//! Analytic operation counting — the stand-in for the paper's VTune
//! "percentage of packed floating-point instructions" statistic (§5.2.1:
//! 99.7% for HBMC (sell_spmv) vs 12.7% for BMC).
//!
//! Rather than sampling PMU counters (unavailable here), we count, from the
//! data-structure sizes, how many floating-point operations per CG
//! iteration execute inside `w`-wide packed loops versus scalar loops.
//! The attribution follows how the compiler actually treats each kernel:
//!
//! * HBMC SELL substitutions — packed (the whole inner loop is `w`-wide),
//! * SELL SpMV — packed,
//! * CRS SpMV and MC/BMC substitutions — scalar (irregular row loops),
//! * BLAS-1 (dot/axpy) — packed (contiguous, auto-vectorized).

use crate::config::{OrderingKind, SolverConfig, SpmvKind};

/// Floating-point operations per CG iteration, split by execution style.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpProfile {
    pub packed_flops: u64,
    pub scalar_flops: u64,
}

impl OpProfile {
    /// Fraction of FP work executed as packed (SIMD) operations.
    pub fn simd_ratio(&self) -> f64 {
        let total = self.packed_flops + self.scalar_flops;
        if total == 0 {
            return 0.0;
        }
        self.packed_flops as f64 / total as f64
    }

    pub fn total(&self) -> u64 {
        self.packed_flops + self.scalar_flops
    }
}

/// Inputs for the per-iteration op count.
#[derive(Debug, Clone, Copy)]
pub struct OpInputs {
    /// Augmented dimension.
    pub n: usize,
    /// nnz of the (reordered) matrix.
    pub nnz: usize,
    /// nnz of strict lower + strict upper of L/Lᵀ (CSR substitutions).
    pub tri_nnz: usize,
    /// SELL stored elements of both substitution triangles (HBMC only).
    pub sell_tri_elements: Option<usize>,
    /// SELL stored elements of the SpMV matrix (sell_spmv only).
    pub sell_a_elements: Option<usize>,
}

/// Per-CG-iteration op profile for a solver configuration.
pub fn per_iteration_ops(cfg: &SolverConfig, inp: &OpInputs) -> OpProfile {
    let mut p = OpProfile::default();
    let n = inp.n as u64;

    // SpMV: 2 flops per stored element.
    match cfg.spmv {
        SpmvKind::Crs => p.scalar_flops += 2 * inp.nnz as u64,
        SpmvKind::Sell => {
            p.packed_flops += 2 * inp.sell_a_elements.expect("sell elements required") as u64
        }
    }

    // Preconditioner: forward + backward substitution.
    match cfg.ordering {
        OrderingKind::Hbmc => {
            let stored = inp.sell_tri_elements.expect("hbmc needs sell triangles") as u64;
            // 2 flops per stored element + 1 packed multiply per row per sweep.
            p.packed_flops += 2 * stored + 2 * n;
        }
        _ => {
            p.scalar_flops += 2 * inp.tri_nnz as u64 + 2 * n;
        }
    }

    // BLAS-1 per iteration: 3 dots (2n each) + 2 axpy (2n) + xpby (2n) +
    // residual update fused in axpy already counted; plus norm ≈ dot.
    p.packed_flops += 6 * 2 * n;
    p
}

/// Pool synchronizations per steady-state iteration of the **fused**
/// single-dispatch CG loop (`solver::cg::pcg_fused`): the two substitution
/// sweeps' `n_c − 1` color barriers each, plus the six phase barriers
/// (SpMV publish+combine, fused-update combine, forward→backward,
/// backward→dot, r·z combine, p publish), plus one extra q-publish barrier
/// when SELL SpMV cannot fuse the `p·q` partials into its sweep. The
/// legacy loop pays the same color barriers **plus three full dispatches**
/// (condvar wake-up + completion barrier each) per iteration; see the
/// accounting table in ARCHITECTURE.md.
pub fn syncs_per_fused_iteration(num_colors: usize, sell_spmv: bool) -> usize {
    2 * num_colors.saturating_sub(1) + 6 + usize::from(sell_spmv)
}

/// Cost model the autotuner scores candidates with: the effective seconds
/// per solve when one plan build is amortized over `expected_reuse`
/// solves. `expected_reuse = ∞` scores pure steady-state serving (only
/// time/solve matters — the ROADMAP's "few matrices, many right-hand
/// sides" shape); `expected_reuse = 1` scores a one-shot workload where
/// setup dominates. Non-finite or sub-1 reuse is clamped to the two
/// regimes' boundaries.
pub fn amortized_seconds_per_solve(
    setup_seconds: f64,
    solve_seconds: f64,
    expected_reuse: f64,
) -> f64 {
    if !expected_reuse.is_finite() {
        return solve_seconds;
    }
    solve_seconds + setup_seconds / expected_reuse.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SolverConfig;

    fn inputs() -> OpInputs {
        OpInputs {
            n: 1000,
            nnz: 9000,
            tri_nnz: 8000,
            sell_tri_elements: Some(10_000),
            sell_a_elements: Some(11_000),
        }
    }

    #[test]
    fn hbmc_sell_is_mostly_packed() {
        let cfg = SolverConfig { ordering: OrderingKind::Hbmc, spmv: SpmvKind::Sell, ..Default::default() };
        let p = per_iteration_ops(&cfg, &inputs());
        assert_eq!(p.scalar_flops, 0);
        assert!(p.simd_ratio() > 0.99);
    }

    #[test]
    fn bmc_crs_is_mostly_scalar() {
        let cfg = SolverConfig { ordering: OrderingKind::Bmc, spmv: SpmvKind::Crs, ..Default::default() };
        let p = per_iteration_ops(&cfg, &inputs());
        // Only BLAS-1 is packed: ratio well below 50%.
        assert!(p.simd_ratio() < 0.4, "ratio={}", p.simd_ratio());
        assert!(p.simd_ratio() > 0.0);
    }

    #[test]
    fn hbmc_crs_mixes() {
        let cfg = SolverConfig { ordering: OrderingKind::Hbmc, spmv: SpmvKind::Crs, ..Default::default() };
        let p = per_iteration_ops(&cfg, &inputs());
        let r = p.simd_ratio();
        assert!(r > 0.4 && r < 0.9, "ratio={r}");
    }

    #[test]
    fn empty_profile_ratio_zero() {
        assert_eq!(OpProfile::default().simd_ratio(), 0.0);
    }

    #[test]
    fn fused_sync_model() {
        // Serial/natural ordering (1 color): phase barriers only.
        assert_eq!(syncs_per_fused_iteration(1, false), 6);
        assert_eq!(syncs_per_fused_iteration(1, true), 7);
        // 4 colors: 2·3 color barriers + 6 phase barriers.
        assert_eq!(syncs_per_fused_iteration(4, false), 12);
    }

    #[test]
    fn amortized_score_spans_both_regimes() {
        // One-shot: the whole setup is billed to the single solve.
        assert_eq!(amortized_seconds_per_solve(10.0, 1.0, 1.0), 11.0);
        // Heavy reuse: setup nearly vanishes.
        assert!((amortized_seconds_per_solve(10.0, 1.0, 1000.0) - 1.01).abs() < 1e-12);
        // Pure serving: setup ignored entirely.
        assert_eq!(amortized_seconds_per_solve(10.0, 1.0, f64::INFINITY), 1.0);
        // Degenerate reuse clamps to the one-shot regime.
        assert_eq!(amortized_seconds_per_solve(10.0, 1.0, 0.0), 11.0);
    }
}
