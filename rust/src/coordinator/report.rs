//! Paper-style plain-text table rendering for the bench harness and CLI.

/// Simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                width[i] = width[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format seconds with 3 significant digits (paper-table style).
pub fn secs(t: f64) -> String {
    if t >= 100.0 {
        format!("{t:.1}")
    } else if t >= 10.0 {
        format!("{t:.2}")
    } else {
        format!("{t:.3}")
    }
}

/// Format a ratio as a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

/// Format a microsecond quantity with a unit that keeps it readable
/// (µs → ms → s), for latency columns in stats/bench tables.
pub fn micros(us: f64) -> String {
    if us >= 1e6 {
        format!("{}s", secs(us / 1e6))
    } else if us >= 1e3 {
        format!("{:.1}ms", us / 1e3)
    } else {
        format!("{us:.0}µs")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "val"]);
        t.push_row(vec!["a".into(), "1".into()]);
        t.push_row(vec!["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        // Column start of "val" aligned across rows.
        let col = lines[1].find("val").unwrap();
        assert_eq!(lines[3].chars().nth(col).unwrap(), '1');
        assert_eq!(&lines[4][col..col + 3], "2.5");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push_row(vec!["only-one".into()]);
    }

    #[test]
    fn number_formats() {
        assert_eq!(secs(123.456), "123.5");
        assert_eq!(secs(12.345), "12.35");
        assert_eq!(secs(1.2345), "1.234");
        assert_eq!(pct(0.997), "99.7%");
        assert_eq!(micros(850.0), "850µs");
        assert_eq!(micros(12_400.0), "12.4ms");
        assert_eq!(micros(2_500_000.0), "2.500s");
    }
}
