//! Job-lifecycle tracing: a bounded ring buffer of structured events.
//!
//! A [`TraceRecorder`] captures the life of sampled jobs as they move
//! through the serving path: `submitted → enqueued → batch_opened →
//! dispatched → completed` (or `failed` / `cancelled` / `shed`).
//! Timestamps are microseconds since the recorder's creation (a monotonic
//! [`Instant`] epoch), so event ordering is meaningful across threads.
//!
//! The buffer is bounded: when full, the oldest event is dropped and a
//! counter incremented, so tracing can stay on in production without
//! growing memory. Sampling is decided once per job at submit time (see
//! `QueueConfig::trace_sample`) — a sampled job carries an
//! `Arc<TraceRecorder>` in its `JobCore` and records every stage; an
//! unsampled job carries `None` and pays nothing beyond that null check.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};
use std::time::Instant;

/// Lifecycle stage names, used verbatim in events and their JSON dump.
pub mod stage {
    pub const SUBMITTED: &str = "submitted";
    pub const ENQUEUED: &str = "enqueued";
    pub const BATCH_OPENED: &str = "batch_opened";
    pub const DISPATCHED: &str = "dispatched";
    pub const COMPLETED: &str = "completed";
    pub const FAILED: &str = "failed";
    pub const CANCELLED: &str = "cancelled";
    pub const SHED: &str = "shed";
    /// A recovery-ladder retry: the detail string carries the action taken
    /// (escalated shift, level fallback, pool rebuild, …).
    pub const RETRIED: &str = "retried";
}

/// One recorded lifecycle event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Job id (the service's monotonically increasing submission index).
    pub job: u64,
    /// Stage name from [`stage`].
    pub stage: &'static str,
    /// Microseconds since the recorder's epoch (monotonic).
    pub t_us: u64,
    /// Stage-specific detail: the `BatchKey` label for `batch_opened`,
    /// an error summary for `failed`, empty otherwise.
    pub detail: String,
}

/// Bounded ring buffer of [`TraceEvent`]s (see module docs).
#[derive(Debug)]
pub struct TraceRecorder {
    epoch: Instant,
    capacity: usize,
    events: Mutex<std::collections::VecDeque<TraceEvent>>,
    dropped: AtomicU64,
}

impl TraceRecorder {
    /// A recorder holding at most `capacity` events (≥ 1).
    pub fn new(capacity: usize) -> TraceRecorder {
        TraceRecorder {
            epoch: Instant::now(),
            capacity: capacity.max(1),
            events: Mutex::new(std::collections::VecDeque::new()),
            dropped: AtomicU64::new(0),
        }
    }

    /// Record one event, evicting the oldest if the buffer is full.
    pub fn record(&self, job: u64, stage: &'static str, detail: String) {
        let t_us = self.epoch.elapsed().as_micros() as u64;
        let mut q = self.events.lock().unwrap_or_else(PoisonError::into_inner);
        if q.len() == self.capacity {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(TraceEvent { job, stage, t_us, detail });
    }

    /// Events currently buffered, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .iter()
            .cloned()
            .collect()
    }

    /// Number of events evicted because the buffer was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap_or_else(PoisonError::into_inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dump the buffer as a JSON array of event objects
    /// (`{"job":…,"stage":"…","t_us":…,"detail":"…"}`), oldest first.
    pub fn to_json(&self) -> String {
        let events = self.events();
        let mut out = String::from("[");
        for (i, e) in events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"job\":{},\"stage\":\"{}\",\"t_us\":{},\"detail\":\"{}\"}}",
                e.job,
                e.stage,
                e.t_us,
                escape_json(&e.detail)
            ));
        }
        out.push(']');
        out
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_in_order_with_monotonic_timestamps() {
        let t = TraceRecorder::new(16);
        t.record(1, stage::SUBMITTED, String::new());
        t.record(1, stage::ENQUEUED, String::new());
        t.record(1, stage::COMPLETED, String::new());
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].stage, "submitted");
        assert_eq!(evs[2].stage, "completed");
        assert!(evs[0].t_us <= evs[1].t_us && evs[1].t_us <= evs[2].t_us);
        assert_eq!(t.dropped(), 0);
        assert!(!t.is_empty());
    }

    #[test]
    fn ring_buffer_evicts_oldest_and_counts_drops() {
        let t = TraceRecorder::new(2);
        t.record(1, stage::SUBMITTED, String::new());
        t.record(2, stage::SUBMITTED, String::new());
        t.record(3, stage::SUBMITTED, String::new());
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].job, 2, "oldest evicted first");
        assert_eq!(t.dropped(), 1);
    }

    #[test]
    fn json_dump_is_well_formed_and_escaped() {
        let t = TraceRecorder::new(4);
        t.record(7, stage::FAILED, "bad \"quote\"\nline".to_string());
        let json = t.to_json();
        assert!(json.starts_with('[') && json.ends_with(']'));
        assert!(json.contains("\"job\":7"));
        assert!(json.contains("\"stage\":\"failed\""));
        assert!(json.contains("bad \\\"quote\\\"\\nline"));
        assert_eq!(TraceRecorder::new(1).to_json(), "[]");
    }
}
