//! Minimal std-only HTTP listener for the `serve --metrics-addr` endpoint.
//!
//! One background thread, one connection at a time, two routes:
//! `GET /metrics` (Prometheus text exposition, rendered fresh per scrape
//! by the closure handed to [`MetricsServer::spawn`]) and `GET /healthz`
//! (`ok`). Anything else is a 404. This is deliberately not a web server —
//! no keep-alive, no TLS, no routing table — just enough HTTP/1.1 for
//! `curl` and a Prometheus scraper, with zero new dependencies.
//!
//! Shutdown is cooperative: `Drop` sets a flag and pokes the listener with
//! a self-connection so `accept` wakes up, then joins the thread.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{HbmcError, Result};
use crate::obs::prometheus::CONTENT_TYPE;

/// Per-connection socket timeout: a stalled client must not wedge the
/// single-threaded accept loop.
const IO_TIMEOUT: Duration = Duration::from_secs(2);

/// Background `/metrics` + `/healthz` listener; see module docs.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `"127.0.0.1:9464"`; port 0 picks a free port) and
    /// serve `metrics()` on `GET /metrics` until the server is dropped.
    /// `/healthz` always answers `200 ok` — use
    /// [`spawn_with_health`](MetricsServer::spawn_with_health) to wire a
    /// real health probe.
    pub fn spawn<F>(addr: &str, metrics: F) -> Result<MetricsServer>
    where
        F: Fn() -> String + Send + Sync + 'static,
    {
        MetricsServer::spawn_with_health(addr, metrics, || (true, "ok\n".to_string()))
    }

    /// [`spawn`](MetricsServer::spawn) with a live health probe: `health()`
    /// returns `(healthy, body)`, served on `GET /healthz` as `200` when
    /// healthy (body `ok` or `degraded: …`) and `503 Service Unavailable`
    /// otherwise — what `SolverService::health` produces, so a load
    /// balancer can stop routing to a service whose circuit breakers have
    /// all opened while scrapes of `/metrics` keep working.
    pub fn spawn_with_health<F, H>(addr: &str, metrics: F, health: H) -> Result<MetricsServer>
    where
        F: Fn() -> String + Send + Sync + 'static,
        H: Fn() -> (bool, String) + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)
            .map_err(|e| HbmcError::io(format!("binding metrics listener on {addr}"), e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| HbmcError::io("resolving metrics listener address", e))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("hbmc-metrics".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop_flag.load(Ordering::Acquire) {
                        break;
                    }
                    if let Ok(stream) = stream {
                        // Per-connection errors (timeouts, disconnects) are
                        // the client's problem; the listener keeps serving.
                        let _ = serve_one(stream, &metrics, &health);
                    }
                }
            })
            .map_err(|e| HbmcError::io("spawning metrics listener thread", e))?;
        Ok(MetricsServer { addr, stop, thread: Some(thread) })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        // Wake the accept loop so it observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn serve_one<F: Fn() -> String, H: Fn() -> (bool, String)>(
    stream: TcpStream,
    metrics: &F,
    health: &H,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let mut reader = BufReader::new(stream);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    // "GET /path HTTP/1.1" — only the path matters here. Remaining headers
    // are left unread; the response closes the connection.
    let path = request_line.split_whitespace().nth(1).unwrap_or("");
    let (status, content_type, body) = match path {
        "/metrics" => ("200 OK", CONTENT_TYPE, metrics()),
        "/healthz" => {
            let (healthy, body) = health();
            let status = if healthy { "200 OK" } else { "503 Service Unavailable" };
            (status, "text/plain; charset=utf-8", body)
        }
        _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
    };
    let mut stream = reader.into_inner();
    stream.write_all(
        format!(
            "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )?;
    stream.flush()
}

/// Blocking one-shot HTTP GET against `addr` (e.g. `"127.0.0.1:9464"`);
/// returns the response body on a 200, an error otherwise. Used by the
/// `stats --from` CLI subcommand and the tests — not a general client.
pub fn http_get(addr: &str, path: &str) -> Result<String> {
    let context = |what: &str| format!("{what} http://{addr}{path}");
    let mut stream = TcpStream::connect(addr).map_err(|e| HbmcError::io(context("connecting to"), e))?;
    stream
        .set_read_timeout(Some(IO_TIMEOUT))
        .and_then(|()| stream.set_write_timeout(Some(IO_TIMEOUT)))
        .map_err(|e| HbmcError::io(context("configuring socket for"), e))?;
    stream
        .write_all(format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").as_bytes())
        .map_err(|e| HbmcError::io(context("sending request to"), e))?;
    let mut response = String::new();
    stream
        .read_to_string(&mut response)
        .map_err(|e| HbmcError::io(context("reading response from"), e))?;
    let (head, body) = response
        .split_once("\r\n\r\n")
        .ok_or_else(|| HbmcError::parse(format!("malformed HTTP response from {addr}{path}")))?;
    let status_line = head.lines().next().unwrap_or("");
    if status_line.split_whitespace().nth(1) != Some("200") {
        return Err(HbmcError::parse(format!("GET {path} on {addr} returned \"{status_line}\"")));
    }
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_metrics_healthz_and_404() {
        let server =
            MetricsServer::spawn("127.0.0.1:0", || "# TYPE up gauge\nup 1\n".to_string()).unwrap();
        let addr = server.local_addr().to_string();
        assert_eq!(http_get(&addr, "/healthz").unwrap(), "ok\n");
        let metrics = http_get(&addr, "/metrics").unwrap();
        assert!(metrics.contains("up 1"), "{metrics}");
        let err = http_get(&addr, "/nope").unwrap_err();
        assert!(err.to_string().contains("404"), "{err}");
        // Repeated scrapes work (no keep-alive state to corrupt).
        assert!(http_get(&addr, "/metrics").unwrap().contains("up 1"));
    }

    #[test]
    fn health_probe_drives_healthz_status() {
        use std::sync::atomic::AtomicBool;
        let healthy = Arc::new(AtomicBool::new(true));
        let flag = Arc::clone(&healthy);
        let server = MetricsServer::spawn_with_health("127.0.0.1:0", String::new, move || {
            if flag.load(Ordering::Relaxed) {
                (true, "degraded: 1 breaker(s) open, 0 half-open\n".to_string())
            } else {
                (false, "unhealthy: all 2 circuit breaker(s) open\n".to_string())
            }
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        // Degraded is still 200 (routable), with the reason in the body.
        let body = http_get(&addr, "/healthz").unwrap();
        assert!(body.starts_with("degraded:"), "{body}");
        // Unhealthy flips to 503, which http_get surfaces as an error.
        healthy.store(false, Ordering::Relaxed);
        let err = http_get(&addr, "/healthz").unwrap_err();
        assert!(err.to_string().contains("503"), "{err}");
        // /metrics keeps serving regardless of health.
        assert!(http_get(&addr, "/metrics").is_ok());
    }

    #[test]
    fn drop_stops_the_listener() {
        let server = MetricsServer::spawn("127.0.0.1:0", String::new).unwrap();
        let addr = server.local_addr().to_string();
        drop(server);
        // The port is released: either connect fails or the read sees EOF
        // without an HTTP response.
        assert!(http_get(&addr, "/healthz").is_err());
    }

    #[test]
    fn bind_failure_is_typed() {
        let err = MetricsServer::spawn("256.0.0.1:0", String::new).unwrap_err();
        assert!(matches!(err, HbmcError::Io { .. }), "{err:?}");
        assert!(err.to_string().contains("metrics listener"));
    }
}
