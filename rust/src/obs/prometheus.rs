//! Prometheus text exposition (format version 0.0.4) rendering for
//! [`MetricsSnapshot`]s, plus tiny `write_*` helpers for families whose
//! values are computed at scrape time (e.g. the service renders its
//! `ServiceStats` counters directly rather than mirroring them into the
//! registry).
//!
//! Rules followed here, per the exposition format spec:
//! * `# HELP` and `# TYPE` appear exactly once per family, immediately
//!   before its first sample, even when the family has several labeled
//!   series.
//! * Counters end in `_total`; histograms expose cumulative
//!   `family_bucket{le="…"}` samples (ending with `le="+Inf"` equal to
//!   `family_count`), plus `family_sum` and `family_count`.
//! * Sample values are rendered with `{}` — integers stay integral,
//!   gauges print the shortest round-trip float.

use crate::obs::metrics::{HistogramSnapshot, MetricsSnapshot, SeriesValue};

/// Content-Type for `/metrics` responses in the text exposition format.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// Append one unlabeled counter family (HELP + TYPE + sample).
pub fn write_counter(out: &mut String, family: &str, help: &str, value: u64) {
    out.push_str(&format!(
        "# HELP {family} {help}\n# TYPE {family} counter\n{family} {value}\n"
    ));
}

/// Append one unlabeled gauge family (HELP + TYPE + sample).
pub fn write_gauge(out: &mut String, family: &str, help: &str, value: f64) {
    out.push_str(&format!(
        "# HELP {family} {help}\n# TYPE {family} gauge\n{family} {value}\n"
    ));
}

fn write_histogram(out: &mut String, family: &str, labels: &str, h: &HistogramSnapshot) {
    let with = |le: &str| {
        if labels.is_empty() {
            format!("{family}_bucket{{le=\"{le}\"}}")
        } else {
            format!("{family}_bucket{{{labels},le=\"{le}\"}}")
        }
    };
    for &(le, cumulative) in &h.buckets {
        out.push_str(&format!("{} {}\n", with(&le.to_string()), cumulative));
    }
    out.push_str(&format!("{} {}\n", with("+Inf"), h.count));
    let suffix = |name: &str| {
        if labels.is_empty() {
            format!("{family}_{name}")
        } else {
            format!("{family}_{name}{{{labels}}}")
        }
    };
    out.push_str(&format!("{} {}\n", suffix("sum"), h.sum));
    out.push_str(&format!("{} {}\n", suffix("count"), h.count));
}

/// Render a whole snapshot. Series are emitted in registration order;
/// consecutive series of one family share a single HELP/TYPE header, so
/// labeled families must be registered contiguously (which the service
/// does).
pub fn render(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    let mut last_family = "";
    for s in &snap.series {
        if s.family != last_family {
            let kind = match s.value {
                SeriesValue::Counter(_) => "counter",
                SeriesValue::Gauge(_) => "gauge",
                SeriesValue::Histogram(_) => "histogram",
            };
            out.push_str(&format!("# HELP {} {}\n# TYPE {} {}\n", s.family, s.help, s.family, kind));
            last_family = &s.family;
        }
        match &s.value {
            SeriesValue::Counter(v) => {
                if s.labels.is_empty() {
                    out.push_str(&format!("{} {v}\n", s.family));
                } else {
                    out.push_str(&format!("{}{{{}}} {v}\n", s.family, s.labels));
                }
            }
            SeriesValue::Gauge(v) => {
                if s.labels.is_empty() {
                    out.push_str(&format!("{} {v}\n", s.family));
                } else {
                    out.push_str(&format!("{}{{{}}} {v}\n", s.family, s.labels));
                }
            }
            SeriesValue::Histogram(h) => write_histogram(&mut out, &s.family, &s.labels, h),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::MetricsRegistry;

    #[test]
    fn labeled_family_shares_one_header() {
        let reg = MetricsRegistry::new();
        let a = reg.counter_with("x_total", "k=\"a\"", "x things");
        let b = reg.counter_with("x_total", "k=\"b\"", "x things");
        a.add(1);
        b.add(2);
        let text = render(&reg.snapshot());
        assert_eq!(text.matches("# TYPE x_total counter").count(), 1);
        assert!(text.contains("x_total{k=\"a\"} 1\n"));
        assert!(text.contains("x_total{k=\"b\"} 2\n"));
    }

    #[test]
    fn histogram_exposition_is_cumulative_and_closed_by_inf() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("wait", "wait time");
        h.observe(1);
        h.observe(5);
        let text = render(&reg.snapshot());
        assert!(text.contains("# TYPE wait histogram"));
        assert!(text.contains("wait_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("wait_bucket{le=\"7\"} 2\n"));
        assert!(text.contains("wait_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("wait_sum 6\n"));
        assert!(text.contains("wait_count 2\n"));
    }

    #[test]
    fn write_helpers_emit_full_families() {
        let mut out = String::new();
        write_counter(&mut out, "a_total", "a help", 9);
        write_gauge(&mut out, "g", "g help", 2.5);
        assert!(out.contains("# TYPE a_total counter\na_total 9\n"));
        assert!(out.contains("# TYPE g gauge\ng 2.5\n"));
        // Integral gauges print without a trailing ".0".
        let mut out = String::new();
        write_gauge(&mut out, "g", "g help", 3.0);
        assert!(out.contains("\ng 3\n"));
    }
}
