//! Observability: metrics, job-lifecycle tracing, Prometheus export, and
//! the `/metrics` HTTP endpoint.
//!
//! This layer is deliberately *passive* with respect to the solver: it
//! never times anything inside the fused one-dispatch CG region (whose
//! determinism and sync counts are part of the paper reproduction) —
//! per-solve phase totals come from the `SolveReport`/`PlanReport` fields
//! the coordinator already produces, and queue-side timestamps are taken
//! outside the dispatch. The hot-path cost of an *unsampled* job is a
//! handful of relaxed atomic adds and one `Option` check.
//!
//! * [`metrics`] — dependency-free counters, gauges, and fixed-bucket
//!   log₂ histograms behind a [`MetricsRegistry`]; lock-free observe path.
//! * [`prometheus`] — text exposition (format 0.0.4) rendering; consumed
//!   by `SolverService::metrics_text`.
//! * [`trace`] — bounded ring-buffer [`TraceRecorder`] of per-job
//!   lifecycle events, sampled per `QueueConfig::trace_sample`.
//! * [`http`] — std-only [`MetricsServer`] serving `GET /metrics` and
//!   `GET /healthz` for `hbmc serve --metrics-addr`.
//!
//! Admission control (the *acting* half of this PR: bounded queue depth,
//! per-handle in-flight quotas, expired-job shedding) lives with the
//! queue and service in [`api`](crate::api); this module only measures.

pub mod http;
pub mod metrics;
pub mod prometheus;
pub mod trace;

pub use http::{http_get, MetricsServer};
pub use metrics::{
    Counter, Gauge, Histogram, HistogramSnapshot, MetricsRegistry, MetricsSnapshot,
    SeriesSnapshot, SeriesValue,
};
pub use trace::{stage, TraceEvent, TraceRecorder};
