//! Observability: metrics, job-lifecycle tracing, in-region kernel
//! profiling, Prometheus export, and the `/metrics` HTTP endpoint.
//!
//! Service-side instrumentation stays *passive*: queue-side timestamps
//! are taken outside the dispatch and the hot-path cost of an *unsampled*
//! job is a handful of relaxed atomic adds and one `Option` check. The
//! fused one-dispatch CG region (whose determinism and sync counts are
//! part of the paper reproduction) is measured only by the **opt-in**
//! [`flight`] recorder, which follows the same discipline from the
//! inside: per-thread preallocated lanes, clock reads at existing phase
//! boundaries, zero added barriers, and bitwise-identical solves with
//! profiling on or off (`tests/profiling.rs`). Unprofiled solves still
//! pay nothing inside the region beyond a null check per mark.
//!
//! * [`metrics`] — dependency-free counters, gauges, and fixed-bucket
//!   log₂ histograms behind a [`MetricsRegistry`]; lock-free observe path.
//! * [`prometheus`] — text exposition (format 0.0.4) rendering; consumed
//!   by `SolverService::metrics_text`.
//! * [`trace`] — bounded ring-buffer [`TraceRecorder`] of per-job
//!   lifecycle events, sampled per `QueueConfig::trace_sample`.
//! * [`flight`] — the barrier-free per-thread [`FlightRecorder`] for the
//!   fused CG region; drained into a [`PhaseProfile`] after the dispatch.
//! * [`chrometrace`] — `chrome://tracing` / Perfetto JSON export of a
//!   drained [`PhaseProfile`].
//! * [`http`] — std-only [`MetricsServer`] serving `GET /metrics` and
//!   `GET /healthz` for `hbmc serve --metrics-addr`.
//!
//! Admission control (the *acting* half of this PR: bounded queue depth,
//! per-handle in-flight quotas, expired-job shedding) lives with the
//! queue and service in [`api`](crate::api); this module only measures.

pub mod chrometrace;
pub mod flight;
pub mod http;
pub mod metrics;
pub mod prometheus;
pub mod trace;

pub use chrometrace::chrome_trace_json;
pub use flight::{FlightRecorder, LaneProfile, Phase, PhaseProfile, PhaseSpan, PHASE_NAMES};
pub use http::{http_get, MetricsServer};
pub use metrics::{
    Counter, Gauge, Histogram, HistogramSnapshot, MetricsRegistry, MetricsSnapshot,
    SeriesSnapshot, SeriesValue,
};
pub use trace::{stage, TraceEvent, TraceRecorder};
