//! Chrome-trace (`chrome://tracing` / Perfetto) export of a drained
//! [`PhaseProfile`](crate::obs::flight::PhaseProfile).
//!
//! Emits the Trace Event Format's JSON-object form: a `traceEvents` array
//! of complete (`"ph":"X"`) events, one process (`pid` 1), one trace row
//! per solver thread (`tid` = lane index). Each recorded span renders as
//! its *busy* part under the phase name, followed — when the span parked
//! in pool barriers — by a separate `barrier-wait` slice covering the
//! tail of the interval, so imbalance is visible as staggered wait blocks
//! rather than inflated kernel bars. Timestamps are microseconds since
//! the recorder epoch; events per thread are monotone and non-overlapping
//! by construction (spans are recorded in order and split, never nested),
//! which `tests/profiling.rs` asserts structurally.

use crate::obs::flight::{PhaseProfile, PHASE_NAMES};

/// Render a profile as a chrome://tracing JSON document. Load the string
/// (saved to a file) in Perfetto or `chrome://tracing` to see the solve
/// as a per-thread timeline.
pub fn chrome_trace_json(profile: &PhaseProfile) -> String {
    let nspans: usize = profile.lanes.iter().map(|l| l.spans.len()).sum();
    let mut out = String::with_capacity(256 + nspans * 96);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    let mut push_event = |out: &mut String, name: &str, tid: usize, ts_ns: u64, dur_ns: u64| {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{:.3},\"dur\":{:.3}}}",
            name,
            tid,
            ts_ns as f64 / 1e3,
            dur_ns as f64 / 1e3,
        ));
    };
    for (tid, lane) in profile.lanes.iter().enumerate() {
        for span in &lane.spans {
            let total = span.end_ns.saturating_sub(span.start_ns);
            let wait = span.wait_ns.min(total);
            let busy = total - wait;
            if busy > 0 {
                push_event(&mut out, span.phase.name(), tid, span.start_ns, busy);
            }
            if wait > 0 {
                push_event(
                    &mut out,
                    PHASE_NAMES[PHASE_NAMES.len() - 1],
                    tid,
                    span.start_ns + busy,
                    wait,
                );
            }
        }
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::flight::{FlightRecorder, Phase};
    use crate::util::json::Json;

    fn sample() -> PhaseProfile {
        let rec = FlightRecorder::new(2, 8);
        rec.record(0, Phase::Spmv, 0, 10_000, 0);
        rec.record(0, Phase::Blas1, 10_000, 30_000, 5_000);
        rec.record(1, Phase::TrisolveFwd, 0, 25_000, 12_000);
        rec.into_profile(3e-5)
    }

    #[test]
    fn output_parses_and_splits_waits() {
        let s = chrome_trace_json(&sample());
        let j = Json::parse(&s).expect("valid JSON");
        let events = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
        // spmv (no wait) + blas1 busy + blas1's wait + trisolve-fwd busy +
        // its wait = 5 events.
        assert_eq!(events.len(), 5);
        for ev in events {
            assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
            assert_eq!(ev.get("pid").and_then(Json::as_f64), Some(1.0));
            let name = ev.get("name").and_then(Json::as_str).unwrap();
            assert!(PHASE_NAMES.contains(&name), "unknown event name {name}");
            assert!(ev.get("ts").and_then(Json::as_f64).is_some());
            assert!(ev.get("dur").and_then(Json::as_f64).unwrap() > 0.0);
        }
        // The wait slice immediately follows its span's busy slice.
        let blas_busy = &events[1];
        let blas_wait = &events[2];
        assert_eq!(blas_wait.get("name").and_then(Json::as_str), Some("barrier-wait"));
        let busy_end = blas_busy.get("ts").and_then(Json::as_f64).unwrap()
            + blas_busy.get("dur").and_then(Json::as_f64).unwrap();
        assert!((blas_wait.get("ts").and_then(Json::as_f64).unwrap() - busy_end).abs() < 1e-9);
    }

    #[test]
    fn per_thread_events_are_monotone_and_non_overlapping() {
        let s = chrome_trace_json(&sample());
        let j = Json::parse(&s).unwrap();
        let events = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        let mut last_end = [0.0f64; 2];
        for ev in events {
            let tid = ev.get("tid").and_then(Json::as_f64).unwrap() as usize;
            let ts = ev.get("ts").and_then(Json::as_f64).unwrap();
            let dur = ev.get("dur").and_then(Json::as_f64).unwrap();
            assert!(ts + 1e-9 >= last_end[tid], "overlap on tid {tid}");
            last_end[tid] = ts + dur;
        }
    }

    #[test]
    fn empty_profile_renders_an_empty_event_list() {
        let rec = FlightRecorder::new(1, 1);
        let s = chrome_trace_json(&rec.into_profile(0.0));
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("traceEvents").and_then(Json::as_arr).unwrap().len(), 0);
    }
}
