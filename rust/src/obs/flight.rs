//! Barrier-free per-thread flight recorder for the fused CG region.
//!
//! The paper's argument is about where time goes *inside* the solver loop
//! — substitution sweeps vs SpMV vs synchronization — so the profiler has
//! to live inside the single-dispatch region without perturbing it. The
//! design rules, in order of importance:
//!
//! 1. **Zero new barriers.** Spans are stamped at *existing* phase
//!    boundaries (the marks the fused worker already performs); nothing
//!    here synchronizes with anything.
//! 2. **Zero in-region allocation.** Every lane's span vector is
//!    preallocated to a fixed capacity before the dispatch; once full,
//!    further spans fold into the per-phase aggregates (which are exact
//!    regardless) and a `dropped` counter — the timeline truncates, the
//!    totals never do.
//! 3. **No sharing.** Each worker owns one cache-line-padded [`Lane`]
//!    indexed by `tid`; no other thread touches it until the dispatch's
//!    completion barrier has passed and [`FlightRecorder::into_profile`]
//!    drains everything on the caller.
//!
//! The clock is one shared [`Instant`] epoch read via
//! [`FlightRecorder::now_ns`] — monotonic, no cross-thread clock skew
//! beyond `Instant`'s own guarantees, and cheap enough (~20 ns) that a
//! handful of reads per CG iteration stays far under the 5% overhead
//! budget. Barrier parking time is measured separately by the pool
//! (thread-locally; see `Pool::take_barrier_wait_ns`) and subtracted from
//! each span, so a span's *busy* time and its *wait* time render as
//! distinct timeline slices.

use std::cell::UnsafeCell;
use std::time::Instant;

/// Number of busy phases tracked (excludes the derived barrier-wait lane).
pub const NUM_PHASES: usize = 4;

/// Canonical event names, in [`Phase`] index order, with the derived
/// "barrier-wait" pseudo-phase last. The chrome-trace exporter, the
/// Prometheus `phase` label and the CLI table all use exactly these.
pub const PHASE_NAMES: [&str; NUM_PHASES + 1] =
    ["spmv", "trisolve-fwd", "trisolve-bwd", "blas1", "barrier-wait"];

/// One busy phase of the fused CG worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Spmv = 0,
    TrisolveFwd = 1,
    TrisolveBwd = 2,
    Blas1 = 3,
}

impl Phase {
    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }

    #[inline]
    pub fn name(self) -> &'static str {
        PHASE_NAMES[self as usize]
    }
}

/// One recorded interval on one thread: `[start_ns, end_ns)` since the
/// recorder's epoch, of which the final `wait_ns` were spent parked in
/// pool barriers (the busy part is `end - start - wait`).
#[derive(Clone, Copy, Debug)]
pub struct PhaseSpan {
    pub phase: Phase,
    pub start_ns: u64,
    pub end_ns: u64,
    pub wait_ns: u64,
}

/// Exact running totals per lane — updated on every record, even after
/// the span ring is full.
#[derive(Clone, Copy, Debug, Default)]
struct LaneAgg {
    /// Busy nanoseconds per phase (span length minus barrier wait).
    phase_ns: [u64; NUM_PHASES],
    /// Barrier-parked nanoseconds, all phases.
    wait_ns: u64,
    /// Spans that exceeded capacity (timeline truncated; totals exact).
    dropped: u64,
}

/// One thread's recording lane, padded to two cache lines so adjacent
/// lanes never false-share.
#[repr(align(128))]
struct Lane {
    spans: UnsafeCell<Vec<PhaseSpan>>,
    agg: UnsafeCell<LaneAgg>,
}

// SAFETY: lane `tid` is written only by pool worker `tid` during the
// dispatch (the fused worker calls `record(tid, ..)` with its own tid
// exclusively); the caller reads only after the dispatch's completion
// barrier, which orders every worker write before the read.
unsafe impl Sync for Lane {}

/// Preallocated per-thread recorder; see module docs. Built once per
/// profiled solve, handed by reference into the fused region, consumed by
/// [`FlightRecorder::into_profile`] after the dispatch returns.
pub struct FlightRecorder {
    epoch: Instant,
    capacity: usize,
    lanes: Vec<Lane>,
}

impl FlightRecorder {
    /// Allocate `nthreads` lanes of `capacity` spans each. Capacity is the
    /// caller's problem (the plan sizes it from `max_iters`, capped so a
    /// pathological iteration bound cannot ask for unbounded memory).
    pub fn new(nthreads: usize, capacity: usize) -> FlightRecorder {
        FlightRecorder {
            epoch: Instant::now(),
            capacity,
            lanes: (0..nthreads)
                .map(|_| Lane {
                    spans: UnsafeCell::new(Vec::with_capacity(capacity)),
                    agg: UnsafeCell::new(LaneAgg::default()),
                })
                .collect(),
        }
    }

    pub fn nthreads(&self) -> usize {
        self.lanes.len()
    }

    /// Nanoseconds since the recorder's epoch (monotonic).
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Record one span on thread `tid`'s lane. Called only by worker `tid`
    /// from inside the dispatched region (see the `Sync` safety note).
    /// Aggregates always update; the span list stops growing at capacity
    /// (allocation-free by construction) and counts the overflow.
    #[inline]
    pub fn record(&self, tid: usize, phase: Phase, start_ns: u64, end_ns: u64, wait_ns: u64) {
        debug_assert!(tid < self.lanes.len());
        let lane = &self.lanes[tid];
        // SAFETY: exclusive owner-thread access during the job; published
        // to the draining caller by the pool's completion barrier.
        unsafe {
            let agg = &mut *lane.agg.get();
            let busy = end_ns.saturating_sub(start_ns).saturating_sub(wait_ns);
            agg.phase_ns[phase.idx()] += busy;
            agg.wait_ns += wait_ns;
            let spans = &mut *lane.spans.get();
            if spans.len() < self.capacity {
                spans.push(PhaseSpan { phase, start_ns, end_ns, wait_ns });
            } else {
                agg.dropped += 1;
            }
        }
    }

    /// Drain everything into an owned, shareable [`PhaseProfile`]. Called
    /// on the dispatching thread after `Pool::run` returned (so every
    /// worker write happened-before this read). `wall_seconds` is the
    /// region's wall time as measured by the caller.
    pub fn into_profile(self, wall_seconds: f64) -> PhaseProfile {
        let lanes = self
            .lanes
            .into_iter()
            .map(|lane| {
                let spans = lane.spans.into_inner();
                let agg = lane.agg.into_inner();
                LaneProfile {
                    phase_seconds: std::array::from_fn(|i| agg.phase_ns[i] as f64 * 1e-9),
                    barrier_wait_seconds: agg.wait_ns as f64 * 1e-9,
                    spans,
                    dropped: agg.dropped,
                }
            })
            .collect();
        PhaseProfile { wall_seconds, lanes }
    }
}

/// One thread's drained profile.
#[derive(Clone, Debug)]
pub struct LaneProfile {
    /// Busy seconds per [`Phase`] (index = `Phase::idx()`).
    pub phase_seconds: [f64; NUM_PHASES],
    /// Seconds parked in pool barriers, all phases.
    pub barrier_wait_seconds: f64,
    /// The recorded timeline (possibly truncated; see `dropped`).
    pub spans: Vec<PhaseSpan>,
    /// Spans beyond capacity — aggregates above still include them.
    pub dropped: u64,
}

impl LaneProfile {
    /// Busy + barrier-wait seconds: everything this lane accounted for.
    pub fn accounted_seconds(&self) -> f64 {
        self.phase_seconds.iter().sum::<f64>() + self.barrier_wait_seconds
    }
}

/// The drained result of one profiled solve: per-thread lanes plus the
/// region's wall time. This is what rides on `SolveReport::profile`, what
/// the chrome-trace exporter renders, and what the metrics layer folds
/// into the `hbmc_kernel_phase_microseconds` family.
#[derive(Clone, Debug)]
pub struct PhaseProfile {
    /// Wall-clock seconds of the profiled region (one `Pool::run`).
    pub wall_seconds: f64,
    pub lanes: Vec<LaneProfile>,
}

impl PhaseProfile {
    pub fn threads(&self) -> usize {
        self.lanes.len()
    }

    /// Seconds summed across threads, indexed like [`PHASE_NAMES`]: four
    /// busy phases, then total barrier wait.
    pub fn phase_totals(&self) -> [f64; NUM_PHASES + 1] {
        let mut out = [0.0; NUM_PHASES + 1];
        for lane in &self.lanes {
            for (i, s) in lane.phase_seconds.iter().enumerate() {
                out[i] += s;
            }
            out[NUM_PHASES] += lane.barrier_wait_seconds;
        }
        out
    }

    /// [`PhaseProfile::phase_totals`] normalized to fractions of their
    /// sum (all zeros when nothing was recorded). The tuner persists this
    /// as the "why the winner won" breakdown.
    pub fn phase_shares(&self) -> [f64; NUM_PHASES + 1] {
        let totals = self.phase_totals();
        let sum: f64 = totals.iter().sum();
        if sum <= 0.0 {
            return [0.0; NUM_PHASES + 1];
        }
        std::array::from_fn(|i| totals[i] / sum)
    }

    /// Max/mean of per-thread barrier-wait seconds — 1.0 means perfectly
    /// balanced arrival, large values mean one straggler phase dominates.
    /// Defined as 1.0 when no wait was recorded (single thread, or a
    /// perfectly synchronous run).
    pub fn barrier_wait_imbalance(&self) -> f64 {
        if self.lanes.is_empty() {
            return 1.0;
        }
        let waits: Vec<f64> = self.lanes.iter().map(|l| l.barrier_wait_seconds).collect();
        let mean = waits.iter().sum::<f64>() / waits.len() as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        waits.iter().copied().fold(0.0, f64::max) / mean
    }

    /// Fraction of `threads × wall_seconds` accounted for by recorded
    /// busy + wait time. The acceptance bar is ≥ 0.9: the marks bracket
    /// the whole worker body, so only the pre-loop setup instants and
    /// clock-read overhead go unaccounted.
    pub fn coverage(&self) -> f64 {
        let denom = self.threads() as f64 * self.wall_seconds;
        if denom <= 0.0 {
            return 0.0;
        }
        self.lanes.iter().map(|l| l.accounted_seconds()).sum::<f64>() / denom
    }

    /// Total spans dropped across lanes (0 ⇒ the timeline is complete).
    pub fn dropped(&self) -> u64 {
        self.lanes.iter().map(|l| l.dropped).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates_exact_aggregates() {
        let rec = FlightRecorder::new(2, 8);
        rec.record(0, Phase::Spmv, 0, 1_000, 0);
        rec.record(0, Phase::Blas1, 1_000, 3_000, 500);
        rec.record(1, Phase::TrisolveFwd, 0, 2_000, 1_000);
        rec.record(1, Phase::TrisolveBwd, 2_000, 2_500, 0);
        let p = rec.into_profile(3e-6);
        assert_eq!(p.threads(), 2);
        let t = p.phase_totals();
        assert!((t[Phase::Spmv.idx()] - 1e-6).abs() < 1e-15);
        assert!((t[Phase::Blas1.idx()] - 1.5e-6).abs() < 1e-15);
        assert!((t[Phase::TrisolveFwd.idx()] - 1e-6).abs() < 1e-15);
        assert!((t[Phase::TrisolveBwd.idx()] - 0.5e-6).abs() < 1e-15);
        assert!((t[NUM_PHASES] - 1.5e-6).abs() < 1e-15);
        assert_eq!(p.lanes[0].spans.len(), 2);
        assert_eq!(p.lanes[1].spans.len(), 2);
        assert_eq!(p.dropped(), 0);
        let shares = p.phase_shares();
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn overflow_drops_spans_but_keeps_totals_exact() {
        let rec = FlightRecorder::new(1, 2);
        for k in 0..5u64 {
            rec.record(0, Phase::Spmv, k * 100, k * 100 + 100, 0);
        }
        let p = rec.into_profile(1.0);
        assert_eq!(p.lanes[0].spans.len(), 2);
        assert_eq!(p.dropped(), 3);
        // All five spans are in the aggregate regardless.
        assert!((p.phase_totals()[Phase::Spmv.idx()] - 500e-9).abs() < 1e-15);
    }

    #[test]
    fn imbalance_is_max_over_mean_and_one_when_flat() {
        let rec = FlightRecorder::new(2, 4);
        rec.record(0, Phase::Blas1, 0, 100, 0);
        rec.record(1, Phase::Blas1, 0, 100, 0);
        assert_eq!(rec.into_profile(1e-7).barrier_wait_imbalance(), 1.0);

        let rec = FlightRecorder::new(2, 4);
        rec.record(0, Phase::Blas1, 0, 100, 30);
        rec.record(1, Phase::Blas1, 0, 100, 10);
        // mean = 20ns, max = 30ns → 1.5.
        let imb = rec.into_profile(1e-7).barrier_wait_imbalance();
        assert!((imb - 1.5).abs() < 1e-12);
    }

    #[test]
    fn coverage_accounts_busy_plus_wait_against_wall() {
        let rec = FlightRecorder::new(1, 4);
        rec.record(0, Phase::Spmv, 0, 900_000_000, 100_000_000);
        let p = rec.into_profile(1.0);
        assert!((p.coverage() - 0.9).abs() < 1e-9);
        assert!((p.lanes[0].accounted_seconds() - 0.9).abs() < 1e-9);
    }

    #[test]
    fn phase_names_match_enum_order() {
        assert_eq!(Phase::Spmv.name(), "spmv");
        assert_eq!(Phase::TrisolveFwd.name(), "trisolve-fwd");
        assert_eq!(Phase::TrisolveBwd.name(), "trisolve-bwd");
        assert_eq!(Phase::Blas1.name(), "blas1");
        assert_eq!(PHASE_NAMES[NUM_PHASES], "barrier-wait");
    }

    #[test]
    fn now_ns_is_monotone() {
        let rec = FlightRecorder::new(1, 1);
        let a = rec.now_ns();
        let b = rec.now_ns();
        assert!(b >= a);
    }
}
