//! Dependency-free metrics primitives: monotonic [`Counter`]s, [`Gauge`]s,
//! and fixed-bucket log₂ [`Histogram`]s, collected in a [`MetricsRegistry`].
//!
//! The hot path is lock-free: every `observe`/`inc` is a handful of
//! `Relaxed` `fetch_add`s on `AtomicU64`s — no mutex, no allocation, no
//! branching on registry state. The registry's mutex guards only metric
//! *registration* (service construction) and [`snapshot`]
//! (`MetricsRegistry::snapshot`), which copies the atomics into plain
//! values for rendering. `Relaxed` is deliberate and sufficient, matching
//! the service's counter policy: each cell is independently monotonic and
//! read only for reporting — nothing establishes happens-before through a
//! metric, so stronger orderings would only add fences to solver threads.
//!
//! Histograms use power-of-two buckets: bucket `k` counts observations in
//! `[2^k, 2^(k+1) − 1]` (bucket 0 additionally absorbs `0`), so the
//! rendered cumulative upper bounds (`le`) are the exact integers
//! `2^(k+1) − 1`. 40 buckets cover `[0, 2^40)` — for microsecond
//! observations that is ~12.7 days, far beyond any solve; larger values
//! saturate into the last bucket. A histogram therefore costs a fixed
//! 42 atomics, is branch-predictable (`leading_zeros` → one `fetch_add`),
//! and needs no configuration per metric.
//!
//! [`snapshot`]: MetricsRegistry::snapshot

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

/// Number of log₂ buckets per [`Histogram`] (see module docs).
pub const HISTOGRAM_BUCKETS: usize = 40;

/// Monotonically increasing counter (Prometheus type `counter`).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time value (Prometheus type `gauge`); stores `f64` bits in an
/// `AtomicU64` so it stays lock-free like everything else here.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket log₂ histogram; see module docs for the bucket layout.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HISTOGRAM_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Bucket index for a value: `floor(log₂ v)`, with 0 and 1 sharing
    /// bucket 0 and everything ≥ `2^(BUCKETS−1)` saturating into the last.
    fn bucket_index(v: u64) -> usize {
        if v <= 1 {
            0
        } else {
            ((63 - v.leading_zeros()) as usize).min(HISTOGRAM_BUCKETS - 1)
        }
    }

    /// Record one observation — three `Relaxed` `fetch_add`s, lock-free.
    pub fn observe(&self, v: u64) {
        self.buckets[Histogram::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Copy the atomics into a plain snapshot for rendering/quantiles.
    /// Buckets are read independently (no global lock), so a snapshot
    /// taken mid-observation may be off by the in-flight observation —
    /// fine for reporting, which is all this is for.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut cumulative = 0;
        let buckets = self
            .buckets
            .iter()
            .enumerate()
            .take(HISTOGRAM_BUCKETS - 1)
            .map(|(k, b)| {
                cumulative += b.load(Ordering::Relaxed);
                // Exact integer upper bound of bucket k: 2^(k+1) − 1.
                ((1u64 << (k + 1)) - 1, cumulative)
            })
            .collect();
        HistogramSnapshot {
            count: self.count(),
            sum: self.sum.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// Plain-value copy of a [`Histogram`]: cumulative counts per finite `le`
/// bound; the `+Inf` cumulative is `count`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    /// `(le, cumulative_count)` per finite bucket bound, ascending.
    pub buckets: Vec<(u64, u64)>,
}

impl HistogramSnapshot {
    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket containing the `q`-quantile (`0 ≤ q ≤ 1`);
    /// `None` when the histogram is empty (there is no meaningful bound to
    /// report — callers render it as absence or 0 explicitly). Quantiles
    /// of a log₂ histogram are bucket-resolution estimates — at most 2×
    /// off — which is what p50/p99 latency tracking needs. Observations
    /// that overflowed into the `+Inf` bucket saturate the answer to the
    /// largest finite bound (`2^(BUCKETS−1) − 1`), including the edge case
    /// where *every* observation overflowed.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        for &(le, cumulative) in &self.buckets {
            if cumulative >= target {
                return Some(le);
            }
        }
        // `target` exceeds every finite cumulative count: the quantile sits
        // in the +Inf overflow bucket. Saturate to the last finite bound.
        self.buckets.last().map(|&(le, _)| le)
    }
}

/// One registered time series: family name, optional label pair rendered
/// verbatim (e.g. `reason="queue_depth"`), help text, and the live metric.
struct Entry {
    family: String,
    labels: String,
    help: String,
    metric: Metric,
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Registry of metrics for one scrape endpoint. Registration returns an
/// `Arc` handle the call site holds on to — the hot path touches only the
/// handle's atomics, never the registry lock (see module docs).
#[derive(Default)]
pub struct MetricsRegistry {
    entries: Mutex<Vec<Entry>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    fn register(&self, family: &str, labels: &str, help: &str, metric: Metric) {
        self.entries
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(Entry {
                family: family.to_string(),
                labels: labels.to_string(),
                help: help.to_string(),
                metric,
            });
    }

    /// Register an unlabeled counter and return its handle.
    pub fn counter(&self, family: &str, help: &str) -> Arc<Counter> {
        self.counter_with(family, "", help)
    }

    /// Register one labeled series of a counter family. `labels` is the
    /// pre-rendered label body, e.g. `reason="queue_depth"`; series of one
    /// family share `HELP`/`TYPE` in the exposition.
    pub fn counter_with(&self, family: &str, labels: &str, help: &str) -> Arc<Counter> {
        let c = Arc::new(Counter::new());
        self.register(family, labels, help, Metric::Counter(Arc::clone(&c)));
        c
    }

    /// Register a gauge and return its handle.
    pub fn gauge(&self, family: &str, help: &str) -> Arc<Gauge> {
        let g = Arc::new(Gauge::new());
        self.register(family, "", help, Metric::Gauge(Arc::clone(&g)));
        g
    }

    /// Register a histogram and return its handle.
    pub fn histogram(&self, family: &str, help: &str) -> Arc<Histogram> {
        self.histogram_with(family, "", help)
    }

    /// Register one labeled series of a histogram family (e.g.
    /// `phase="spmv",ordering="hbmc"`). Like [`counter_with`], series of
    /// one family must be registered contiguously so the exposition
    /// renders a single `HELP`/`TYPE` block for the family.
    ///
    /// [`counter_with`]: MetricsRegistry::counter_with
    pub fn histogram_with(&self, family: &str, labels: &str, help: &str) -> Arc<Histogram> {
        let h = Arc::new(Histogram::new());
        self.register(family, labels, help, Metric::Histogram(Arc::clone(&h)));
        h
    }

    /// Plain-value copy of every registered series, in registration order.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let entries = self.entries.lock().unwrap_or_else(PoisonError::into_inner);
        MetricsSnapshot {
            series: entries
                .iter()
                .map(|e| SeriesSnapshot {
                    family: e.family.clone(),
                    labels: e.labels.clone(),
                    help: e.help.clone(),
                    value: match &e.metric {
                        Metric::Counter(c) => SeriesValue::Counter(c.get()),
                        Metric::Gauge(g) => SeriesValue::Gauge(g.get()),
                        Metric::Histogram(h) => SeriesValue::Histogram(h.snapshot()),
                    },
                })
                .collect(),
        }
    }
}

/// Point-in-time copy of a registry (see [`MetricsRegistry::snapshot`]).
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Every series in registration order (family order is stable).
    pub series: Vec<SeriesSnapshot>,
}

/// One series of a [`MetricsSnapshot`].
#[derive(Debug, Clone)]
pub struct SeriesSnapshot {
    pub family: String,
    pub labels: String,
    pub help: String,
    pub value: SeriesValue,
}

/// The value a series held at snapshot time.
#[derive(Debug, Clone)]
pub enum SeriesValue {
    Counter(u64),
    Gauge(f64),
    Histogram(HistogramSnapshot),
}

impl MetricsSnapshot {
    /// Sum of a counter family across its labeled series, if present.
    pub fn counter(&self, family: &str) -> Option<u64> {
        let mut total = None;
        for s in &self.series {
            if s.family == family {
                if let SeriesValue::Counter(v) = s.value {
                    total = Some(total.unwrap_or(0) + v);
                }
            }
        }
        total
    }

    /// The histogram registered under `family`, if present.
    pub fn histogram(&self, family: &str) -> Option<&HistogramSnapshot> {
        self.series.iter().find_map(|s| match (&s.value, s.family == family) {
            (SeriesValue::Histogram(h), true) => Some(h),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
    }

    #[test]
    fn histogram_bucket_layout_is_exact_log2() {
        // Bucket k covers [2^k, 2^(k+1) − 1]; bucket 0 also takes 0.
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 1);
        assert_eq!(Histogram::bucket_index(4), 2);
        assert_eq!(Histogram::bucket_index(7), 2);
        assert_eq!(Histogram::bucket_index(8), 3);
        assert_eq!(Histogram::bucket_index(u64::MAX), HISTOGRAM_BUCKETS - 1);

        let h = Histogram::new();
        for v in [0, 1, 2, 3, 1000] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 1006);
        // le=1 covers {0,1}; le=3 additionally covers {2,3}.
        assert_eq!(s.buckets[0], (1, 2));
        assert_eq!(s.buckets[1], (3, 4));
        // 1000 lands in [512, 1023]: cumulative reaches 5 at le=1023.
        let le_1023 = s.buckets.iter().find(|&&(le, _)| le == 1023).unwrap();
        assert_eq!(le_1023.1, 5);
        // Bounds are ascending and cumulative counts monotone.
        for pair in s.buckets.windows(2) {
            assert!(pair[0].0 < pair[1].0);
            assert!(pair[0].1 <= pair[1].1);
        }
        assert_eq!(s.buckets.last().unwrap().1, s.count, "finite tail == count");
    }

    #[test]
    fn quantiles_are_bucket_bounds() {
        let h = Histogram::new();
        for v in 0..100 {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.quantile(0.0), Some(1), "lowest non-empty bucket bound");
        // p50 of 0..=99 is ~49 → bucket [32,63].
        assert_eq!(s.quantile(0.5), Some(63));
        // p99 → 99 → bucket [64,127].
        assert_eq!(s.quantile(0.99), Some(127));
        assert!((s.mean() - 49.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_of_empty_histogram_is_none() {
        let empty = Histogram::new().snapshot();
        assert_eq!(empty.quantile(0.0), None);
        assert_eq!(empty.quantile(0.5), None);
        assert_eq!(empty.quantile(1.0), None);
        assert_eq!(empty.mean(), 0.0);
    }

    #[test]
    fn quantile_saturates_when_all_observations_overflow() {
        // Every observation lands in the +Inf bucket (≥ 2^(BUCKETS−1)):
        // no finite cumulative count ever reaches the target, and the
        // defined answer is the largest finite bound.
        let h = Histogram::new();
        h.observe(u64::MAX);
        h.observe(1u64 << 62);
        let s = h.snapshot();
        let last_finite = (1u64 << (HISTOGRAM_BUCKETS - 1)) - 1;
        assert_eq!(s.quantile(0.5), Some(last_finite));
        assert_eq!(s.quantile(1.0), Some(last_finite));
        // Mixed: the median is still finite, the tail saturates.
        let h = Histogram::new();
        h.observe(10);
        h.observe(10);
        h.observe(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s.quantile(0.5), Some(15), "median in a finite bucket");
        assert_eq!(s.quantile(1.0), Some(last_finite), "p100 saturates");
    }

    #[test]
    fn registry_snapshot_preserves_order_and_labels() {
        let reg = MetricsRegistry::new();
        let a = reg.counter_with("hits_total", "kind=\"x\"", "hits");
        let b = reg.counter_with("hits_total", "kind=\"y\"", "hits");
        let g = reg.gauge("depth", "queue depth");
        let h = reg.histogram("wait", "queue wait");
        a.add(3);
        b.add(4);
        g.set(7.0);
        h.observe(10);
        let snap = reg.snapshot();
        assert_eq!(snap.series.len(), 4);
        assert_eq!(snap.series[0].labels, "kind=\"x\"");
        assert_eq!(snap.counter("hits_total"), Some(7), "family sums labeled series");
        assert_eq!(snap.counter("nope"), None);
        assert_eq!(snap.histogram("wait").unwrap().count, 1);
        assert!(matches!(snap.series[2].value, SeriesValue::Gauge(v) if v == 7.0));
    }

    #[test]
    fn handles_are_send_sync() {
        fn assert_send_sync<T: Send + Sync + 'static>() {}
        assert_send_sync::<Arc<Counter>>();
        assert_send_sync::<Arc<Gauge>>();
        assert_send_sync::<Arc<Histogram>>();
        assert_send_sync::<MetricsRegistry>();
    }
}
