//! Deterministic xorshift64* RNG — reproducible across runs and platforms.
//! Used by the matrix generators and the property-test harness.

/// xorshift64* generator (Vigna 2016). Not cryptographic; deterministic.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed (`0` is remapped to a fixed constant).
    pub fn new(seed: u64) -> Self {
        Rng { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`; `n > 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given log-space sigma (mean 1 in log space = 0).
    pub fn log_normal(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn mean_roughly_half() {
        let mut r = Rng::new(11);
        let m: f64 = (0..20_000).map(|_| r.f64()).sum::<f64>() / 20_000.0;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
