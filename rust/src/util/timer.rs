//! Wall-clock timers and a per-kernel time breakdown used by the solver
//! metrics (the offline environment has no `criterion`; benches use these).

use std::time::{Duration, Instant};

/// Simple scoped stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Named accumulation buckets for the solver's kernel breakdown
/// (trisolve-forward, trisolve-backward, spmv, blas1, setup ...).
#[derive(Debug, Default, Clone)]
pub struct KernelTimes {
    entries: Vec<(&'static str, Duration)>,
}

impl KernelTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `d` to the bucket `name`, creating it on first use.
    pub fn add(&mut self, name: &'static str, d: Duration) {
        for e in &mut self.entries {
            if e.0 == name {
                e.1 += d;
                return;
            }
        }
        self.entries.push((name, d));
    }

    /// Time a closure into bucket `name`.
    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    pub fn get(&self, name: &str) -> Duration {
        self.entries
            .iter()
            .find(|e| e.0 == name)
            .map(|e| e.1)
            .unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.entries.iter().map(|e| e.1).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&'static str, Duration)> + '_ {
        self.entries.iter().copied()
    }

    /// Merge another breakdown into this one.
    pub fn merge(&mut self, other: &KernelTimes) {
        for (n, d) in other.iter() {
            self.add(n, d);
        }
    }
}

/// Run `f` repeatedly until at least `min_time` elapsed and `min_iters`
/// iterations were done, returning (best, mean) seconds per call. This is
/// the micro-bench primitive used by `rust/benches/`.
pub fn bench_secs(min_iters: usize, min_time: Duration, mut f: impl FnMut()) -> (f64, f64) {
    // Warmup.
    f();
    let mut best = f64::INFINITY;
    let mut total = 0.0;
    let mut n = 0usize;
    let start = Instant::now();
    while n < min_iters || start.elapsed() < min_time {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        best = best.min(dt);
        total += dt;
        n += 1;
        if n > 1_000_000 {
            break;
        }
    }
    (best, total / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_times_accumulate() {
        let mut kt = KernelTimes::new();
        kt.add("spmv", Duration::from_millis(5));
        kt.add("spmv", Duration::from_millis(7));
        kt.add("dot", Duration::from_millis(1));
        assert_eq!(kt.get("spmv"), Duration::from_millis(12));
        assert_eq!(kt.total(), Duration::from_millis(13));
        assert_eq!(kt.get("missing"), Duration::ZERO);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut kt = KernelTimes::new();
        let v = kt.time("work", || 42);
        assert_eq!(v, 42);
        assert!(kt.get("work") > Duration::ZERO);
    }

    #[test]
    fn merge_combines() {
        let mut a = KernelTimes::new();
        a.add("x", Duration::from_millis(1));
        let mut b = KernelTimes::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.get("x"), Duration::from_millis(3));
        assert_eq!(a.get("y"), Duration::from_millis(3));
    }

    #[test]
    fn bench_runs() {
        let (best, mean) = bench_secs(3, Duration::from_millis(1), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(best > 0.0 && mean >= best);
    }
}
