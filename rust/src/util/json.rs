//! Minimal JSON reader/writer for the tuned-profile store (`tune::profile`).
//!
//! The offline crate set has no `serde`, and the profile store must be a
//! plain JSON file (human-inspectable, CI-artifact-friendly), so we carry a
//! small recursive-descent parser: the full JSON value grammar (objects,
//! arrays, strings with escapes, numbers, booleans, null), bounded nesting
//! depth, and typed [`HbmcError::Parse`] errors with byte offsets — never a
//! panic on malformed input. Numbers are `f64` (IEEE doubles, like
//! JavaScript); values that must survive bit-exactly (the 64-bit matrix
//! fingerprint) are stored as hex *strings* by the profile layer instead.

use crate::error::{HbmcError, Result};

/// Maximum nesting depth accepted by [`Json::parse`] — far above any
/// profile-store document, low enough that hostile input cannot overflow
/// the parser's recursion stack.
const MAX_DEPTH: usize = 64;

/// A parsed JSON value. Object member order is preserved (serialization
/// and round-trip tests stay deterministic); duplicate keys keep the last
/// occurrence on lookup, like every mainstream JSON reader.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one JSON document; trailing non-whitespace is an error (a
    /// truncated or concatenated file must not silently "parse").
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after the JSON document"));
        }
        Ok(v)
    }

    /// Object member by key (last occurrence wins); `None` for non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integral numbers only (rejects fractional values and anything not
    /// exactly representable in the `u64` range).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x <= 2f64.powi(53) => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

/// Escape a string for embedding in a JSON document (quotes included).
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> HbmcError {
        HbmcError::parse(format!("json: {msg} at byte {}", self.pos))
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            match b {
                b' ' | b'\t' | b'\n' | b'\r' => self.pos += 1,
                _ => break,
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8, what: &str) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {what}")))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {word:?}")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'[', "'['")?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'{', "'{'")?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':', "':' after object key")?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            members.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(members));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"', "'\"'")?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Basic-plane only; a lone/paired surrogate
                            // becomes U+FFFD rather than an error — profile
                            // keys never contain astral characters.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            continue;
                        }
                        _ => return Err(self.err("invalid string escape")),
                    }
                    self.pos += 1;
                }
                Some(b) if b < 0x20 => return Err(self.err("raw control byte in string")),
                Some(b) if b < 0x80 => {
                    out.push(b as char);
                    self.pos += 1;
                }
                Some(_) => {
                    // Decode one multi-byte UTF-8 scalar from a ≤ 4-byte
                    // window (never the whole tail — that would make long
                    // strings O(n²)). The input arrived as &str and every
                    // consumption so far was whole scalars, so the window
                    // starts on a boundary; when it cuts a *following*
                    // character short, `valid_up_to` still covers the
                    // leading one.
                    let rest = &self.bytes[self.pos..];
                    let window = &rest[..rest.len().min(4)];
                    let valid = match std::str::from_utf8(window) {
                        Ok(s) => s,
                        Err(e) if e.valid_up_to() > 0 => {
                            std::str::from_utf8(&window[..e.valid_up_to()]).unwrap()
                        }
                        Err(_) => return Err(self.err("invalid utf-8")),
                    };
                    let ch = valid.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let cp = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos = end; // all four digits consumed (the caller `continue`s)
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            match b {
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9' => self.pos += 1,
                _ => break,
            }
        }
        let tok = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        tok.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| HbmcError::parse(format!("json: invalid number {tok:?} at byte {start}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        let v = Json::parse(r#"{"a": [1, 2], "b": {"c": null}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("b").unwrap().get("c").unwrap().is_null());
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn malformed_input_is_parse_error_not_panic() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "\"unterminated", "1.2.3", "[1] extra",
            "{\"a\" 1}", "\u{1}",
        ] {
            let err = Json::parse(bad).unwrap_err();
            assert!(matches!(err, HbmcError::Parse(_)), "{bad:?} -> {err:?}");
        }
    }

    #[test]
    fn depth_is_bounded() {
        let deep = "[".repeat(1000) + &"]".repeat(1000);
        assert!(matches!(Json::parse(&deep), Err(HbmcError::Parse(_))));
    }

    #[test]
    fn string_escaping_round_trips() {
        let s = "quote\" slash\\ tab\t nl\n unicode π";
        let parsed = Json::parse(&json_string(s)).unwrap();
        assert_eq!(parsed.as_str().unwrap(), s);
    }

    #[test]
    fn unicode_escapes_decode() {
        assert_eq!(Json::parse("\"\\u0041\\u00e9\"").unwrap(), Json::Str("Aé".into()));
        assert_eq!(Json::parse(r#""raw π""#).unwrap(), Json::Str("raw π".into()));
        // Adjacent multi-byte scalars exercise the bounded decode window
        // (a 4-byte window cuts the second € short; the first must still
        // decode).
        assert_eq!(Json::parse(r#""€€€ホ""#).unwrap(), Json::Str("€€€ホ".into()));
    }

    #[test]
    fn u64_accessor_rejects_fractions() {
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("4.2").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
    }
}
