//! Tiny descriptive-statistics helpers for the bench harness output.

/// Summary of a sample of measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub stddev: f64,
    pub median: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            stddev: var.sqrt(),
            median,
        }
    }
}

/// Geometric mean of strictly-positive values (used for "who wins by what
/// factor" roll-ups across datasets, as in the paper's summary claims).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn summary_odd_median() {
        let s = Summary::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }
}
