//! Small self-contained utilities (the offline crate set has no `rand`,
//! `serde` or `criterion`, so we carry our own RNG, timers, stats and a
//! minimal key/value text format).

pub mod json;
pub mod kvtext;
pub mod rng;
pub mod stats;
pub mod timer;

/// Stable location for a perf-trajectory artifact (`BENCH_*.json`): the
/// **workspace root** whenever this build tree still exists at runtime,
/// else the current directory. Benches run with CWD = the package dir
/// (`rust/`) while `cargo run` starts from the workspace root; routing both
/// through this helper gives CI one canonical set of artifact paths.
pub fn bench_artifact_path(name: &str) -> std::path::PathBuf {
    match std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
        Some(root) if root.is_dir() => root.join(name),
        _ => std::path::PathBuf::from(name),
    }
}

/// Round `x` up to the next multiple of `m` (`m > 0`).
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Relative L2 difference `||a - b|| / max(||b||, eps)`.
pub fn rel_l2_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    (num.sqrt()) / den.sqrt().max(1e-300)
}

/// Max-norm difference.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 4), 0);
        assert_eq!(round_up(1, 4), 4);
        assert_eq!(round_up(4, 4), 4);
        assert_eq!(round_up(5, 4), 8);
        assert_eq!(round_up(31, 8), 32);
    }

    #[test]
    fn rel_diff_zero_for_equal() {
        let a = vec![1.0, -2.0, 3.0];
        assert_eq!(rel_l2_diff(&a, &a), 0.0);
        assert_eq!(max_abs_diff(&a, &a), 0.0);
    }

    #[test]
    fn rel_diff_scales() {
        let a = vec![1.0, 0.0];
        let b = vec![0.0, 0.0];
        assert!(rel_l2_diff(&a, &b) > 1e200); // guarded by eps floor
        assert_eq!(max_abs_diff(&a, &b), 1.0);
    }
}
