//! Minimal line-oriented key/value interchange format shared with the
//! python build path (`python/compile/aot.py` writes `artifacts/golden.txt`
//! in this format). No `serde` is available offline, and we deliberately
//! avoid a JSON parser: the format is
//!
//! ```text
//! # comment
//! key = scalar
//! key = v0 v1 v2 ...        (whitespace-separated vector)
//! ```
//!
//! Keys are unique; values are parsed on demand as `i64`, `f64`, `String`
//! or vectors thereof.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{HbmcError, Result};

/// Parsed key/value document.
#[derive(Debug, Default, Clone)]
pub struct KvDoc {
    map: HashMap<String, String>,
    /// Insertion order, for deterministic serialization.
    order: Vec<String>,
}

impl KvDoc {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn parse(text: &str) -> Result<KvDoc> {
        let mut doc = KvDoc::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(HbmcError::parse(format!(
                    "kvtext: line {} has no '=': {line:?}",
                    lineno + 1
                )));
            };
            doc.set(k.trim(), v.trim());
        }
        Ok(doc)
    }

    pub fn load(path: &Path) -> Result<KvDoc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| HbmcError::io(format!("reading {}", path.display()), e))?;
        Self::parse(&text)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        if !self.map.contains_key(key) {
            self.order.push(key.to_string());
        }
        self.map.insert(key.to_string(), value.to_string());
    }

    pub fn set_usize_vec(&mut self, key: &str, xs: &[usize]) {
        let s: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
        self.set(key, &s.join(" "));
    }

    pub fn set_f64_vec(&mut self, key: &str, xs: &[f64]) {
        let s: Vec<String> = xs.iter().map(|x| format!("{x:.17e}")).collect();
        self.set(key, &s.join(" "));
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn raw(&self, key: &str) -> Result<&str> {
        self.map
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| HbmcError::parse(format!("kvtext: missing key {key:?}")))
    }

    pub fn str(&self, key: &str) -> Result<String> {
        Ok(self.raw(key)?.to_string())
    }

    pub fn i64(&self, key: &str) -> Result<i64> {
        self.raw(key)?
            .parse()
            .map_err(|_| HbmcError::parse(format!("kvtext: key {key:?} is not an i64")))
    }

    pub fn usize(&self, key: &str) -> Result<usize> {
        Ok(self.i64(key)? as usize)
    }

    pub fn f64(&self, key: &str) -> Result<f64> {
        self.raw(key)?
            .parse()
            .map_err(|_| HbmcError::parse(format!("kvtext: key {key:?} is not an f64")))
    }

    pub fn usize_vec(&self, key: &str) -> Result<Vec<usize>> {
        self.raw(key)?
            .split_whitespace()
            .map(|t| {
                t.parse::<usize>()
                    .map_err(|_| HbmcError::parse(format!("kvtext: {key:?} element {t:?}")))
            })
            .collect()
    }

    pub fn u32_vec(&self, key: &str) -> Result<Vec<u32>> {
        self.raw(key)?
            .split_whitespace()
            .map(|t| {
                t.parse::<u32>()
                    .map_err(|_| HbmcError::parse(format!("kvtext: {key:?} element {t:?}")))
            })
            .collect()
    }

    pub fn f64_vec(&self, key: &str) -> Result<Vec<f64>> {
        self.raw(key)?
            .split_whitespace()
            .map(|t| {
                t.parse::<f64>()
                    .map_err(|_| HbmcError::parse(format!("kvtext: {key:?} element {t:?}")))
            })
            .collect()
    }

    /// Serialize in insertion order.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for k in &self.order {
            out.push_str(k);
            out.push_str(" = ");
            out.push_str(&self.map[k]);
            out.push('\n');
        }
        out
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text())
            .map_err(|e| HbmcError::io(format!("writing {}", path.display()), e))
    }
}

/// Escape-free JSON writer for small reports (metrics dumps). Values are
/// written as-is; callers must pass well-formed fragments for nested values.
pub fn json_object(pairs: &[(&str, String)]) -> String {
    let body: Vec<String> = pairs.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
    format!("{{{}}}", body.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut d = KvDoc::new();
        d.set("name", "g3_circuit");
        d.set_usize_vec("perm", &[2, 0, 1]);
        d.set_f64_vec("vals", &[1.5, -2.25]);
        let d2 = KvDoc::parse(&d.to_text()).unwrap();
        assert_eq!(d2.str("name").unwrap(), "g3_circuit");
        assert_eq!(d2.usize_vec("perm").unwrap(), vec![2, 0, 1]);
        assert_eq!(d2.f64_vec("vals").unwrap(), vec![1.5, -2.25]);
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let d = KvDoc::parse("# hi\n\nx = 3\n").unwrap();
        assert_eq!(d.usize("x").unwrap(), 3);
    }

    #[test]
    fn missing_key_errors() {
        let d = KvDoc::parse("x = 1").unwrap();
        assert!(d.f64("y").is_err());
        assert!(d.contains("x"));
        assert!(!d.contains("y"));
    }

    #[test]
    fn bad_line_errors() {
        assert!(KvDoc::parse("no equals sign").is_err());
    }

    #[test]
    fn json_writer() {
        let s = json_object(&[("a", "1".into()), ("b", "\"x\"".into())]);
        assert_eq!(s, "{\"a\": 1, \"b\": \"x\"}");
    }
}
