//! Crate-wide typed error: [`HbmcError`] is the single error type returned
//! by every public library function (`config`, `sparse`, `factor`,
//! `ordering`, `solver`, `coordinator`, `api`, `runtime`). Binaries may
//! wrap it in a dynamic error type at the edge; the library itself never
//! does.
//!
//! The core variants mirror the failure modes of the two-phase solver:
//!
//! * [`InvalidConfig`](HbmcError::InvalidConfig) — a [`SolverConfig`]
//!   (or a string being parsed into one of its enums) violates an
//!   invariant; produced by `SolverConfig::validate`, the
//!   `SolverConfigBuilder`, and the `FromStr` impls,
//! * [`DimensionMismatch`](HbmcError::DimensionMismatch) — a right-hand
//!   side (or other vector) does not match the matrix dimension,
//! * [`BreakdownInFactorization`](HbmcError::BreakdownInFactorization) —
//!   IC(0) hit a non-positive pivot (or a structurally missing diagonal),
//! * [`NotConverged`](HbmcError::NotConverged) — a solve was asked to
//!   *require* convergence (see `SolveRequest::require_convergence`) and
//!   the iteration cap was reached first,
//! * [`UnknownMatrix`](HbmcError::UnknownMatrix) — a dataset name or
//!   `MatrixHandle` that the registry/service does not know,
//! * [`DeadlineExceeded`](HbmcError::DeadlineExceeded) — an asynchronous
//!   job (see `SolverService::submit`) was still queued when its per-job
//!   deadline expired; it was never dispatched,
//! * [`Cancelled`](HbmcError::Cancelled) — an asynchronous job was
//!   cancelled while still queued (`JobHandle::cancel`),
//! * [`Overloaded`](HbmcError::Overloaded) — admission control rejected a
//!   submission synchronously: the queue was at `max_queue_depth`, or the
//!   handle at `max_inflight_per_handle` (see `QueueConfig`),
//! * [`BreakdownInIteration`](HbmcError::BreakdownInIteration) — the CG
//!   loop caught a non-finite or non-positive reduction (`rz` or `pq`) at
//!   one of its existing per-iteration reduction sites instead of silently
//!   iterating on NaNs to the cap (see `solver::cg`),
//! * [`CircuitOpen`](HbmcError::CircuitOpen) — the per-`MatrixHandle`
//!   circuit breaker tripped on consecutive failures and is rejecting
//!   submissions for that handle while it cools down (see
//!   `resil::CircuitBreaker`),
//! * [`Io`](HbmcError::Io) — an underlying I/O failure, with the path or
//!   operation as context.
//!
//! Three auxiliary variants cover the remaining library surface:
//! [`Parse`](HbmcError::Parse) for malformed input text (MatrixMarket,
//! kvtext artifacts — and unknown enum strings in the `FromStr` impls),
//! [`Runtime`](HbmcError::Runtime) for the PJRT/XLA backend, and
//! [`Internal`](HbmcError::Internal) for violated internal invariants
//! (e.g. a non-injective permutation).
//!
//! `HbmcError` implements [`Clone`] so the job dispatcher can fan one
//! failure (say, a factorization breakdown while building a shared plan)
//! out to every job of a batch; the `Io` variant clones by re-creating the
//! `std::io::Error` from its kind and message.
//!
//! [`SolverConfig`]: crate::config::SolverConfig

use std::fmt;
use std::time::Duration;

/// Crate-wide result alias. The default error parameter keeps
/// `Result<T, OtherError>` spellable where needed (e.g. `FromStr::Err`).
pub type Result<T, E = HbmcError> = std::result::Result<T, E>;

/// Typed error for every public library operation; see module docs.
#[derive(Debug)]
#[non_exhaustive]
pub enum HbmcError {
    /// A solver configuration (or an enum string being parsed into one)
    /// violates an invariant.
    InvalidConfig(String),
    /// A vector's length does not match the matrix dimension.
    DimensionMismatch { expected: usize, got: usize },
    /// IC(0) factorization broke down (non-positive pivot or missing
    /// diagonal). `row` is `None` when the auto-shift retry loop gave up.
    BreakdownInFactorization {
        row: Option<usize>,
        shift: f64,
        detail: String,
    },
    /// The iteration cap was reached on a solve that required convergence.
    NotConverged { iterations: usize, relres: f64 },
    /// Unknown dataset name or stale/foreign `MatrixHandle`.
    UnknownMatrix(String),
    /// An asynchronous job was still queued when its per-job deadline
    /// (`SolveRequest::deadline`) expired; `budget` is the time the job
    /// was given at submission. Jobs already running are never aborted.
    DeadlineExceeded { budget: Duration },
    /// An asynchronous job was cancelled while still queued — by
    /// `JobHandle::cancel`, or rejected because the service was already
    /// shutting down. Either way it was never dispatched.
    Cancelled,
    /// Admission control rejected a submission synchronously — nothing was
    /// enqueued. `depth` is the occupancy that tripped the bound (queue
    /// depth or the handle's in-flight jobs) and `limit` the configured
    /// bound it hit (`QueueConfig::max_queue_depth` /
    /// `max_inflight_per_handle`). The caller should retry after draining
    /// some of its outstanding work.
    Overloaded { depth: usize, limit: usize },
    /// The CG loop caught a non-finite or non-positive reduction value at
    /// one of its existing per-iteration reduction sites. `iter` is the
    /// iteration at which the value was observed (0 = the initial
    /// residual), `quantity` names the reduction (`"rz"` or `"pq"`). The
    /// dispatcher's retry ladder treats this as a poisoned plan or RHS and
    /// rebuilds before retrying (see `resil`).
    BreakdownInIteration { iter: usize, quantity: &'static str },
    /// The per-`MatrixHandle` circuit breaker is open: `failures`
    /// consecutive jobs on handle `handle` failed, so submissions for that
    /// handle are rejected synchronously while the breaker cools down
    /// (see `resil::CircuitBreaker` and `QueueConfig::breaker_threshold`).
    CircuitOpen { handle: u64, failures: u32 },
    /// Underlying I/O failure; `context` names the path or operation.
    Io {
        context: String,
        source: std::io::Error,
    },
    /// Malformed input text (MatrixMarket files, kvtext artifacts).
    Parse(String),
    /// PJRT/XLA backend failure (including "built without the `pjrt`
    /// feature").
    Runtime(String),
    /// An internal invariant was violated (library bug or corrupt input).
    Internal(String),
}

impl HbmcError {
    /// Attach `context` to an I/O error (path, operation).
    pub fn io(context: impl Into<String>, source: std::io::Error) -> HbmcError {
        HbmcError::Io { context: context.into(), source }
    }

    /// Convenience constructor matching the common call shape.
    pub fn invalid_config(msg: impl Into<String>) -> HbmcError {
        HbmcError::InvalidConfig(msg.into())
    }

    /// Convenience constructor for malformed-input errors.
    pub fn parse(msg: impl Into<String>) -> HbmcError {
        HbmcError::Parse(msg.into())
    }
}

impl fmt::Display for HbmcError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HbmcError::InvalidConfig(msg) => write!(f, "invalid configuration: {msg}"),
            HbmcError::DimensionMismatch { expected, got } => {
                write!(f, "dimension mismatch: expected {expected}, got {got}")
            }
            HbmcError::BreakdownInFactorization { row, shift, detail } => match row {
                Some(r) => write!(
                    f,
                    "IC(0) factorization breakdown at row {r} (shift {shift}): {detail}"
                ),
                None => write!(f, "IC(0) factorization breakdown (shift {shift}): {detail}"),
            },
            HbmcError::NotConverged { iterations, relres } => write!(
                f,
                "solver did not converge: {iterations} iterations, relative residual {relres:.3e}"
            ),
            HbmcError::UnknownMatrix(what) => write!(f, "unknown matrix: {what}"),
            HbmcError::DeadlineExceeded { budget } => {
                write!(f, "job deadline exceeded: still queued after its {budget:?} budget")
            }
            HbmcError::Cancelled => write!(f, "job cancelled while queued"),
            HbmcError::Overloaded { depth, limit } => {
                write!(f, "service overloaded: {depth} jobs against a limit of {limit}")
            }
            HbmcError::BreakdownInIteration { iter, quantity } => write!(
                f,
                "CG breakdown at iteration {iter}: non-finite or non-positive {quantity}"
            ),
            HbmcError::CircuitOpen { handle, failures } => write!(
                f,
                "circuit breaker open for matrix handle #{handle} after {failures} consecutive failures"
            ),
            HbmcError::Io { context, source } => {
                if context.is_empty() {
                    write!(f, "I/O error: {source}")
                } else {
                    write!(f, "{context}: {source}")
                }
            }
            HbmcError::Parse(msg) => write!(f, "parse error: {msg}"),
            HbmcError::Runtime(msg) => write!(f, "runtime error: {msg}"),
            HbmcError::Internal(msg) => write!(f, "internal error: {msg}"),
        }
    }
}

// Manual impl because `std::io::Error` is not `Clone`: the `Io` variant is
// reproduced from its kind and rendered message (the `source` chain is cut,
// the text preserved). Needed by the job dispatcher, which fans a single
// batch-level failure out to every `JobHandle` waiting on that batch.
impl Clone for HbmcError {
    fn clone(&self) -> HbmcError {
        match self {
            HbmcError::InvalidConfig(m) => HbmcError::InvalidConfig(m.clone()),
            HbmcError::DimensionMismatch { expected, got } => {
                HbmcError::DimensionMismatch { expected: *expected, got: *got }
            }
            HbmcError::BreakdownInFactorization { row, shift, detail } => {
                HbmcError::BreakdownInFactorization {
                    row: *row,
                    shift: *shift,
                    detail: detail.clone(),
                }
            }
            HbmcError::NotConverged { iterations, relres } => {
                HbmcError::NotConverged { iterations: *iterations, relres: *relres }
            }
            HbmcError::UnknownMatrix(m) => HbmcError::UnknownMatrix(m.clone()),
            HbmcError::DeadlineExceeded { budget } => {
                HbmcError::DeadlineExceeded { budget: *budget }
            }
            HbmcError::Cancelled => HbmcError::Cancelled,
            HbmcError::Overloaded { depth, limit } => {
                HbmcError::Overloaded { depth: *depth, limit: *limit }
            }
            HbmcError::BreakdownInIteration { iter, quantity } => {
                HbmcError::BreakdownInIteration { iter: *iter, quantity }
            }
            HbmcError::CircuitOpen { handle, failures } => {
                HbmcError::CircuitOpen { handle: *handle, failures: *failures }
            }
            HbmcError::Io { context, source } => HbmcError::Io {
                context: context.clone(),
                source: std::io::Error::new(source.kind(), source.to_string()),
            },
            HbmcError::Parse(m) => HbmcError::Parse(m.clone()),
            HbmcError::Runtime(m) => HbmcError::Runtime(m.clone()),
            HbmcError::Internal(m) => HbmcError::Internal(m.clone()),
        }
    }
}

impl std::error::Error for HbmcError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            HbmcError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<std::io::Error> for HbmcError {
    fn from(e: std::io::Error) -> HbmcError {
        HbmcError::Io { context: String::new(), source: e }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::error::Error as _;

    #[test]
    fn display_formats_each_variant() {
        assert_eq!(
            HbmcError::InvalidConfig("bs must be positive".into()).to_string(),
            "invalid configuration: bs must be positive"
        );
        assert_eq!(
            HbmcError::DimensionMismatch { expected: 100, got: 3 }.to_string(),
            "dimension mismatch: expected 100, got 3"
        );
        let b = HbmcError::BreakdownInFactorization {
            row: Some(7),
            shift: 0.3,
            detail: "non-positive pivot -1.0e0".into(),
        };
        assert!(b.to_string().contains("row 7"));
        assert!(b.to_string().contains("0.3"));
        let nc = HbmcError::NotConverged { iterations: 500, relres: 1.25e-3 };
        assert!(nc.to_string().contains("500 iterations"));
        assert!(HbmcError::UnknownMatrix("nope".into()).to_string().contains("nope"));
        assert!(HbmcError::Parse("bad line".into()).to_string().starts_with("parse error"));
        assert!(HbmcError::Runtime("no pjrt".into()).to_string().starts_with("runtime error"));
        let dl = HbmcError::DeadlineExceeded { budget: Duration::from_millis(5) };
        assert!(dl.to_string().contains("deadline exceeded"), "{dl}");
        assert!(HbmcError::Cancelled.to_string().contains("cancelled"));
        let ov = HbmcError::Overloaded { depth: 64, limit: 64 };
        assert_eq!(ov.to_string(), "service overloaded: 64 jobs against a limit of 64");
        let bi = HbmcError::BreakdownInIteration { iter: 3, quantity: "pq" };
        assert_eq!(
            bi.to_string(),
            "CG breakdown at iteration 3: non-finite or non-positive pq"
        );
        let co = HbmcError::CircuitOpen { handle: 5, failures: 4 };
        assert!(co.to_string().contains("handle #5"), "{co}");
        assert!(co.to_string().contains("4 consecutive failures"), "{co}");
    }

    #[test]
    fn clone_preserves_variant_and_message() {
        let orig = HbmcError::NotConverged { iterations: 7, relres: 2.5e-2 };
        assert!(matches!(orig.clone(), HbmcError::NotConverged { iterations: 7, .. }));
        let ov = HbmcError::Overloaded { depth: 9, limit: 8 };
        assert!(matches!(ov.clone(), HbmcError::Overloaded { depth: 9, limit: 8 }));
        let io = HbmcError::io("reading b.mtx", std::io::Error::other("disk on fire"));
        let cloned = io.clone();
        assert!(matches!(cloned, HbmcError::Io { .. }), "{cloned:?}");
        assert!(cloned.to_string().contains("disk on fire"));
        assert!(cloned.to_string().starts_with("reading b.mtx"));
        let bi = HbmcError::BreakdownInIteration { iter: 11, quantity: "rz" };
        assert!(matches!(
            bi.clone(),
            HbmcError::BreakdownInIteration { iter: 11, quantity: "rz" }
        ));
        let co = HbmcError::CircuitOpen { handle: 2, failures: 3 };
        assert!(matches!(co.clone(), HbmcError::CircuitOpen { handle: 2, failures: 3 }));
    }

    #[test]
    fn io_conversion_preserves_source() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: HbmcError = io.into();
        assert!(e.source().is_some());
        assert!(e.to_string().contains("gone"));
        let with_ctx = HbmcError::io("opening a.mtx", std::io::Error::other("denied"));
        assert!(with_ctx.to_string().starts_with("opening a.mtx"));
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync + 'static>() {}
        assert_send_sync::<HbmcError>();
    }
}
