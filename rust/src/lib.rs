//! # hbmc — Hierarchical Block Multi-Color Ordering for the ICCG method
//!
//! Reproduction of Iwashita, Li & Fukaya (2019), *"Hierarchical Block
//! Multi-Color Ordering: A New Parallel Ordering Method for Vectorization
//! and Parallelization of the Sparse Triangular Solver in the ICCG
//! Method"*, grown into a servable two-phase solver.
//!
//! ## Two-phase architecture (plan / execute)
//!
//! The paper's premise is that the expensive reordering + IC(0)
//! factorization setup is amortized over many triangular sweeps. The crate
//! makes that split explicit:
//!
//! * **Phase 1 — plan** ([`solver::plan::SolverPlan::build`]): ordering →
//!   symmetric permutation → IC(0)/shifted-IC factorization → CSR/SELL
//!   storage → kernel-path selection. The result is an immutable
//!   [`SolverPlan`](solver::plan::SolverPlan) holding the permutation, the
//!   permuted matrix, the factor triangles behind a unified
//!   [`TriSolver`](solver::trisolve::TriSolver) trait object, and the
//!   per-plan [`SetupStats`](solver::plan::SetupStats).
//! * **Phase 2 — execute** ([`coordinator::session::SolveSession`]): a
//!   session wraps one `Arc<SolverPlan>` with one persistent color-barrier
//!   thread pool and serves `solve` / batched `solve_many` over arbitrarily
//!   many right-hand sides. An LRU
//!   [`PlanCache`](coordinator::session::PlanCache) keyed by (matrix
//!   fingerprint, ordering, bs, w, spmv, …) removes re-setup across
//!   requests entirely.
//!
//! [`coordinator::driver::solve`] remains as a thin one-shot wrapper
//! (plan + session + single solve) for tests, tables and quick runs.
//!
//! ## Layer map
//!
//! * [`sparse`] — CSR / COO / SELL-C-σ storage and Matrix-Market IO,
//! * [`gen`] — synthetic generators standing in for the paper's five test
//!   matrices (see `DESIGN.md` §3 for the substitution rationale),
//! * [`ordering`] — MC / BMC / HBMC orderings, the ordering-graph / ER
//!   machinery, and the [`order_matrix`](ordering::order_matrix) façade the
//!   plan builder consumes,
//! * [`factor`] — IC(0) and shifted-IC incomplete factorization,
//! * [`solver`] — triangular kernels behind the `TriSolver` trait, CRS &
//!   SELL SpMV, the PCG loop, `SolverPlan` and the `IccgSolver` wrapper,
//! * [`coordinator`] — color-barrier thread pool, sessions + plan cache,
//!   metrics and paper-style reporting,
//! * [`runtime`] — PJRT executor for the AOT JAX/Pallas artifacts
//!   (`pjrt` cargo feature; stubbed offline).
//!
//! ## Quickstart
//!
//! ```no_run
//! use std::sync::Arc;
//! use hbmc::prelude::*;
//!
//! let a = hbmc::gen::suite::dataset("g3_circuit", Scale::Small).matrix;
//! let cfg = SolverConfig { ordering: OrderingKind::Hbmc, bs: 32, w: 8, ..Default::default() };
//!
//! // Phase 1: build the plan once (ordering + factorization + storage).
//! let plan = Arc::new(SolverPlan::build(&a, &cfg).unwrap());
//! println!("setup {:.3}s, {} colors", plan.setup.setup_seconds(), plan.setup.num_colors);
//!
//! // Phase 2: open a session and serve many right-hand sides.
//! let session = SolveSession::new(plan);
//! for scale in [1.0, 2.0, 3.0] {
//!     let b = vec![scale; a.n()];
//!     let out = session.solve(&b).unwrap();
//!     println!("iters={} time={:.3}s", out.report.iterations, out.report.solve_seconds);
//! }
//! ```

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod factor;
pub mod gen;
pub mod ordering;
pub mod runtime;
pub mod solver;
pub mod sparse;
pub mod util;

/// Convenient re-exports for downstream users.
pub mod prelude {
    pub use crate::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
    pub use crate::coordinator::driver::{solve, solve_opts, PlanReport, SolveOptions, SolveReport};
    pub use crate::coordinator::session::{PlanCache, SolveOutput, SolveSession};
    pub use crate::factor::ic0::IcFactor;
    pub use crate::ordering::{bmc::BmcOrdering, hbmc::HbmcOrdering, perm::Perm};
    pub use crate::solver::cg::CgResult;
    pub use crate::solver::plan::{SetupStats, SolverPlan};
    pub use crate::solver::trisolve::TriSolver;
    pub use crate::sparse::csr::Csr;
}
