//! # hbmc — Hierarchical Block Multi-Color Ordering for the ICCG method
//!
//! Reproduction of Iwashita, Li & Fukaya (2019), *"Hierarchical Block
//! Multi-Color Ordering: A New Parallel Ordering Method for Vectorization
//! and Parallelization of the Sparse Triangular Solver in the ICCG
//! Method"*, grown into a servable, thread-safe two-phase solver.
//!
//! ## The front door: builder → service → jobs
//!
//! Production callers go through three typed pieces (the [`api`] layer):
//!
//! 1. [`SolverConfig::builder`](config::SolverConfig::builder) — per-field
//!    setters, validated on `build()`, so an invalid configuration is
//!    rejected before it can reach a kernel;
//! 2. [`SolverService`](api::SolverService) — a `Send + Sync` endpoint
//!    owning the matrix registry, the LRU plan cache (concurrent requests
//!    for the same (matrix, config) key coalesce into **exactly one** plan
//!    build), and an asynchronous job queue:
//!    [`submit`](api::SolverService::submit) returns a
//!    [`JobHandle`](api::JobHandle) immediately, and a dispatcher thread
//!    **micro-batches jobs that share a plan** onto one session, so N
//!    concurrent single-RHS requests share one plan checkout and one
//!    warmed-up pool instead of paying per-request setup N times;
//! 3. [`MatrixHandle`](api::MatrixHandle) +
//!    [`SolveRequest`](api::SolveRequest) — registered matrices are
//!    addressed by copyable handles, and each request may override
//!    tolerances, set a queueing deadline, or swap the whole structural
//!    config without touching the service defaults.
//!
//! Every public library function returns
//! [`Result<T, HbmcError>`](error::HbmcError) — no stringly-typed error
//! crates outside the binary edge.
//!
//! ## Quickstart
//!
//! ```no_run
//! use hbmc::prelude::*;
//! use std::sync::Arc;
//! use std::time::Duration;
//!
//! // 1. A validated configuration (the paper's headline solver).
//! //    `SpmvKind::SymmCsr` instead stores only the lower triangle and
//! //    roughly halves SpMV matrix traffic on bandwidth-bound matrices.
//! let cfg = SolverConfig::builder()
//!     .ordering(OrderingKind::Hbmc)
//!     .bs(32)
//!     .w(8)
//!     .spmv(SpmvKind::Sell)
//!     .rtol(1e-7)
//!     .build()?;
//!
//! // 2. One service for the whole process; register matrices once.
//! let service = Arc::new(SolverService::with_config(cfg)?);
//! let dataset = hbmc::gen::suite::dataset("g3_circuit", Scale::Small);
//! let n = dataset.n();
//! let handle = service.register_matrix(dataset.matrix);
//!
//! // 3. Submit work — from any thread. The job handle is non-blocking
//! //    (`poll`, `cancel`) until you `wait` for the output; jobs from
//! //    concurrent submitters that share this (matrix, config) key are
//! //    micro-batched onto one shared session by the dispatcher.
//! let job = service.submit(handle, &dataset.b, &SolveRequest::new())?;
//! let out = job.wait()?;
//! println!("iters={} time={:.3}s", out.report.iterations, out.report.solve_seconds);
//!
//! // Per-request overrides never disturb the service defaults; a deadline
//! // bounds how long a job may sit queued before it fails typed
//! // (HbmcError::DeadlineExceeded) instead of running late.
//! let strict = SolveRequest::new()
//!     .rtol(1e-10)
//!     .require_convergence()
//!     .deadline(Duration::from_millis(250));
//! let out = service.submit(handle, &vec![1.0; n], &strict)?.wait()?;
//! println!("strict: {} iters; batching: {:?}", out.report.iterations, service.stats().batches);
//!
//! // The blocking calls remain as thin submit + wait wrappers:
//! let out = service.solve(handle, &vec![2.0; n])?;
//!
//! // Resilience: allow the dispatcher's recovery ladder up to two retries
//! // per job (escalated-shift re-plan on factorization breakdown, Level
//! // fallback when a colored ordering stalls, pool rebuild after a worker
//! // panic), and trip a per-matrix circuit breaker after 5 consecutive
//! // failures; see the `resil` module.
//! let resilient = SolverConfig::builder()
//!     .max_retries(2)
//!     .breaker_threshold(Some(5))
//!     .build()?;
//! # let _ = resilient;
//!
//! // 4. Observe: every ServiceStats counter plus queue-wait / batch-width /
//! //    solve-time histograms render as Prometheus text exposition — scrape
//! //    it in-process, or serve it over HTTP with
//! //    `hbmc serve --metrics-addr 127.0.0.1:9184` (endpoints /metrics and
//! //    /healthz). `hbmc stats` pretty-prints the same snapshot.
//! print!("{}", service.metrics_text());
//! # let _ = out;
//! # Ok::<(), HbmcError>(())
//! ```
//!
//! ## Autotuning: stop guessing `bs`/`w`/threads
//!
//! The paper's best `(ordering, bs, w, spmv)` differs per machine; the
//! [`tune`] subsystem measures instead of guessing and persists the
//! winner per (matrix fingerprint, hardware signature):
//!
//! ```no_run
//! use hbmc::prelude::*;
//! # let service = SolverService::new();
//! # let dataset = hbmc::gen::suite::dataset("g3_circuit", Scale::Tiny);
//! # let handle = service.register_matrix(dataset.matrix);
//! // Search the valid config space for this matrix on this machine,
//! // install the winner, and persist it to the attached store.
//! service.attach_profile_store("hbmc_profiles.json")?;
//! let profile = service.tune(handle, &TuneOptions::default())?;
//! println!("tuned: {} ({:.2}x vs default)", profile.label(), profile.speedup());
//!
//! // From now on (and in any later process that attaches the store),
//! // requests without an explicit config override run the tuned config —
//! // visible as ServiceStats::profile_hits. Opt out per request:
//! let out = service.solve(handle, &dataset.b)?;                // tuned
//! let raw = service.solve_with(handle, &dataset.b,
//!                              &SolveRequest::new().no_profile())?; // default
//! # let _ = (out, raw);
//! # Ok::<(), HbmcError>(())
//! ```
//!
//! On the command line: `hbmc tune --dataset g3_circuit` then
//! `hbmc solve --dataset g3_circuit --auto`. The scoreboard races the
//! reordering paths against the level-scheduled one (`--ordering level`):
//! wavefront scheduling over the natural ordering, which keeps the serial
//! solve's ICCG iteration count — see [`schedule`].
//!
//! ## Two-phase architecture (plan / execute)
//!
//! The paper's premise is that the expensive reordering + IC(0)
//! factorization setup is amortized over many triangular sweeps. Beneath
//! the service, the split is explicit and still public:
//!
//! * **Phase 1 — plan** ([`solver::plan::SolverPlan::build`]): ordering →
//!   symmetric permutation → IC(0)/shifted-IC factorization → CSR/SELL
//!   storage → kernel-path selection, producing an immutable
//!   `Arc<SolverPlan>`.
//! * **Phase 2 — execute** ([`coordinator::session::SolveSession`]): one
//!   persistent color-barrier thread pool serving `solve` / `solve_many`
//!   against one plan; the LRU
//!   [`PlanCache`](coordinator::session::PlanCache) keys plans by (matrix
//!   fingerprint, ordering, bs, w, spmv, …).
//!
//! [`coordinator::driver::solve`] remains as a thin one-shot wrapper over
//! the service (plan + session + single solve) for tests and tables.
//!
//! ## Layer map
//!
//! * [`api`] — the typed, concurrent façade (`SolverService`, handles,
//!   requests, the asynchronous job queue + dispatcher),
//! * [`error`] — [`HbmcError`](error::HbmcError), the crate-wide error,
//! * [`sparse`] — CSR / COO / SELL-C-σ storage and Matrix-Market IO,
//! * [`gen`] — synthetic generators standing in for the paper's five test
//!   matrices (see `DESIGN.md` §3 for the substitution rationale),
//! * [`obs`] — observability: dependency-free counters / gauges / log₂
//!   histograms with a Prometheus text renderer, the sampled job-lifecycle
//!   trace ring, and the std-only HTTP listener behind
//!   `hbmc serve --metrics-addr`,
//! * [`ordering`] — MC / BMC / HBMC orderings, the ordering-graph / ER
//!   machinery, and the [`order_matrix`](ordering::order_matrix) façade the
//!   plan builder consumes,
//! * [`factor`] — IC(0) and shifted-IC incomplete factorization,
//! * [`resil`] — resilience: `RetryPolicy` + per-handle circuit breaker
//!   driving the dispatcher's recovery ladder (shift escalation, Level
//!   fallback, pool rebuild), and the deterministic `FaultInjector` chaos
//!   harness behind `--chaos --inject`,
//! * [`schedule`] — level-set (wavefront) construction over the factor's
//!   dependency DAG, the thin-level coarsening pass and its cost model —
//!   the *scheduling* alternative to reordering, raced by the tuner,
//! * [`solver`] — triangular kernels behind the `TriSolver` trait, the
//!   CRS / SELL / symmetric (`SpmvKind::SymmCsr`, conflict-free colored
//!   scatter) SpMV engines, the PCG loop, `SolverPlan` and the
//!   `IccgSolver` wrapper,
//! * [`coordinator`] — color-barrier thread pool, sessions + plan cache,
//!   metrics and paper-style reporting,
//! * [`tune`] — the autotuner: config-space enumeration, measured search
//!   (exhaustive / successive halving), and the persisted per-(matrix,
//!   hardware) profile store the service auto-applies,
//! * [`runtime`] — PJRT executor for the AOT JAX/Pallas artifacts
//!   (`pjrt` cargo feature; stubbed offline).

pub mod api;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod factor;
pub mod gen;
pub mod obs;
pub mod ordering;
pub mod resil;
pub mod runtime;
pub mod schedule;
pub mod solver;
pub mod sparse;
pub mod tune;
pub mod util;

/// Convenient re-exports for downstream users.
pub mod prelude {
    pub use crate::api::{
        JobHandle, JobState, MatrixHandle, ServiceStats, SolveRequest, SolverService,
    };
    pub use crate::config::{
        NodePreset, OrderingKind, QueueConfig, Scale, SolverConfig, SolverConfigBuilder, SpmvKind,
    };
    pub use crate::coordinator::driver::{solve, solve_opts, PlanReport, SolveOptions, SolveReport};
    pub use crate::coordinator::session::{PlanCache, SolveOutput, SolveSession};
    pub use crate::error::HbmcError;
    pub use crate::factor::ic0::IcFactor;
    pub use crate::ordering::{bmc::BmcOrdering, hbmc::HbmcOrdering, perm::Perm};
    pub use crate::resil::{FaultSpec, RetryPolicy};
    pub use crate::solver::cg::CgResult;
    pub use crate::solver::plan::{SetupStats, SolverPlan};
    pub use crate::solver::trisolve::TriSolver;
    pub use crate::sparse::csr::Csr;
    pub use crate::tune::{
        ConfigSpace, HardwareSignature, ProfileStore, TuneOptions, TunedProfile,
    };
}
