//! # hbmc — Hierarchical Block Multi-Color Ordering for the ICCG method
//!
//! Reproduction of Iwashita, Li & Fukaya (2019), *"Hierarchical Block
//! Multi-Color Ordering: A New Parallel Ordering Method for Vectorization and
//! Parallelization of the Sparse Triangular Solver in the ICCG Method"*.
//!
//! The crate is the **Layer-3 coordinator** of a three-layer rust + JAX +
//! Pallas stack:
//!
//! * [`sparse`] — CSR / COO / SELL-C-σ storage and Matrix-Market IO,
//! * [`gen`] — synthetic generators standing in for the paper's five test
//!   matrices (see `DESIGN.md` §3 for the substitution rationale),
//! * [`ordering`] — multi-color (MC), block multi-color (BMC) and the
//!   paper's hierarchical block multi-color (HBMC) orderings, plus the
//!   ordering-graph / ER-condition machinery used to prove equivalence,
//! * [`factor`] — IC(0) and shifted-IC incomplete factorization,
//! * [`solver`] — serial / MC / BMC / HBMC triangular solvers, CRS & SELL
//!   SpMV and the preconditioned CG driver,
//! * [`coordinator`] — color-barrier thread pool, scheduling, metrics and
//!   paper-style reporting,
//! * [`runtime`] — PJRT (xla crate) executor that loads the AOT-compiled
//!   JAX/Pallas artifacts produced by `python/compile/aot.py`.
//!
//! ## Quickstart
//!
//! ```no_run
//! use hbmc::prelude::*;
//!
//! let a = hbmc::gen::suite::dataset("g3_circuit", Scale::Small).matrix;
//! let cfg = SolverConfig { ordering: OrderingKind::Hbmc, bs: 32, w: 8, ..Default::default() };
//! let report = hbmc::coordinator::driver::solve(&a, &vec![1.0; a.n()], &cfg).unwrap();
//! println!("iters={} time={:.3}s", report.iterations, report.solve_seconds);
//! ```

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod factor;
pub mod gen;
pub mod ordering;
pub mod runtime;
pub mod solver;
pub mod sparse;
pub mod util;

/// Convenient re-exports for downstream users.
pub mod prelude {
    pub use crate::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
    pub use crate::coordinator::driver::{solve, SolveReport};
    pub use crate::factor::ic0::IcFactor;
    pub use crate::ordering::{bmc::BmcOrdering, hbmc::HbmcOrdering, perm::Perm};
    pub use crate::solver::cg::CgResult;
    pub use crate::sparse::csr::Csr;
}
