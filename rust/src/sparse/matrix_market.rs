//! MatrixMarket (`.mtx`) reader/writer for square real matrices.
//!
//! Supports `matrix coordinate real {general|symmetric}` and
//! `matrix coordinate pattern {general|symmetric}` (pattern entries get
//! value 1.0). This lets users run the solver on the paper's actual
//! SuiteSparse datasets when they have them; the bundled generators in
//! [`crate::gen`] are the offline stand-ins.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::error::{HbmcError, Result};
use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;

/// Read a square MatrixMarket file into CSR (symmetric files are expanded).
pub fn read(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .map_err(|e| HbmcError::io(format!("opening {}", path.display()), e))?;
    read_from(BufReader::new(f))
}

/// Parse from any reader (unit-testable without touching the filesystem).
pub fn read_from(reader: impl BufRead) -> Result<Csr> {
    let mut lines = reader.lines();
    let header = lines
        .next()
        .ok_or_else(|| HbmcError::parse("matrix market: empty file"))?
        .map_err(|e| HbmcError::io("matrix market: read error", e))?;
    let h: Vec<String> = header.split_whitespace().map(|t| t.to_lowercase()).collect();
    if h.len() < 5 || h[0] != "%%matrixmarket" || h[1] != "matrix" || h[2] != "coordinate" {
        return Err(HbmcError::parse(format!("matrix market: unsupported header {header:?}")));
    }
    let pattern = match h[3].as_str() {
        "real" | "integer" => false,
        "pattern" => true,
        other => {
            return Err(HbmcError::parse(format!("matrix market: unsupported field {other:?}")))
        }
    };
    let symmetric = match h[4].as_str() {
        "general" => false,
        "symmetric" => true,
        other => {
            return Err(HbmcError::parse(format!("matrix market: unsupported symmetry {other:?}")))
        }
    };

    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line.map_err(|e| HbmcError::io("matrix market: read error", e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(t.to_string());
        break;
    }
    let size_line = size_line.ok_or_else(|| HbmcError::parse("matrix market: missing size line"))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| {
            t.parse().map_err(|_| {
                HbmcError::parse(format!("matrix market: bad size line {size_line:?}"))
            })
        })
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        return Err(HbmcError::parse(format!("matrix market: bad size line {size_line:?}")));
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);
    if nrows != ncols {
        return Err(HbmcError::parse(format!(
            "matrix market: only square matrices supported ({nrows}x{ncols})"
        )));
    }

    let mut coo = Coo::with_capacity(nrows, if symmetric { 2 * nnz } else { nnz });
    let mut seen = 0usize;
    for line in lines {
        let line = line.map_err(|e| HbmcError::io("matrix market: read error", e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it
            .next()
            .ok_or_else(|| HbmcError::parse("mm: missing row"))?
            .parse()
            .map_err(|_| HbmcError::parse("mm: bad row"))?;
        let j: usize = it
            .next()
            .ok_or_else(|| HbmcError::parse("mm: missing col"))?
            .parse()
            .map_err(|_| HbmcError::parse("mm: bad col"))?;
        let v: f64 = if pattern {
            1.0
        } else {
            it.next()
                .ok_or_else(|| HbmcError::parse("mm: missing value"))?
                .parse()
                .map_err(|_| HbmcError::parse("mm: bad value"))?
        };
        if i == 0 || j == 0 || i > nrows || j > ncols {
            return Err(HbmcError::parse(format!(
                "matrix market: 1-based index ({i},{j}) out of range"
            )));
        }
        if symmetric {
            coo.push_sym(i - 1, j - 1, v);
        } else {
            coo.push(i - 1, j - 1, v);
        }
        seen += 1;
    }
    if seen != nnz {
        return Err(HbmcError::parse(format!(
            "matrix market: expected {nnz} entries, found {seen}"
        )));
    }
    Ok(coo.to_csr())
}

/// Write CSR as `coordinate real general`.
pub fn write(a: &Csr, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .map_err(|e| HbmcError::io(format!("creating {}", path.display()), e))?,
    );
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "{} {} {}", a.n(), a.n(), a.nnz())?;
    for i in 0..a.n() {
        let (cols, vals) = a.row(i);
        for (c, v) in cols.iter().zip(vals) {
            writeln!(f, "{} {} {:.17e}", i + 1, *c as usize + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_general() {
        let text = "%%MatrixMarket matrix coordinate real general\n% comment\n3 3 4\n1 1 2.0\n2 2 3.0\n3 3 4.0\n1 3 -1.0\n";
        let a = read_from(Cursor::new(text)).unwrap();
        assert_eq!(a.n(), 3);
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.get(0, 2), Some(-1.0));
        assert_eq!(a.get(2, 0), None);
    }

    #[test]
    fn parse_symmetric_expands() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 2.0\n2 1 -1.0\n";
        let a = read_from(Cursor::new(text)).unwrap();
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.get(0, 1), Some(-1.0));
        assert_eq!(a.get(1, 0), Some(-1.0));
    }

    #[test]
    fn parse_pattern() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n2 2 2\n1 1\n2 1\n";
        let a = read_from(Cursor::new(text)).unwrap();
        assert_eq!(a.get(1, 0), Some(1.0));
    }

    #[test]
    fn reject_rectangular_and_bad_counts() {
        assert!(read_from(Cursor::new("%%MatrixMarket matrix coordinate real general\n2 3 1\n1 1 1.0\n")).is_err());
        assert!(read_from(Cursor::new("%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n")).is_err());
        assert!(read_from(Cursor::new("%%MatrixMarket matrix array real general\n2 2 1\n")).is_err());
    }

    #[test]
    fn write_read_roundtrip() {
        let mut coo = Coo::new(3);
        coo.push(0, 0, 1.5);
        coo.push_sym(0, 2, -2.25);
        coo.push(1, 1, 3.0);
        coo.push(2, 2, 9.0);
        let a = coo.to_csr();
        let dir = std::env::temp_dir().join("hbmc_mm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.mtx");
        write(&a, &path).unwrap();
        let b = read(&path).unwrap();
        assert_eq!(a, b);
    }
}
