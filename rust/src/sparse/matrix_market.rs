//! MatrixMarket (`.mtx`) reader/writer for square real matrices.
//!
//! Supports `matrix coordinate real {general|symmetric}` and
//! `matrix coordinate pattern {general|symmetric}` (pattern entries get
//! value 1.0). This lets users run the solver on the paper's actual
//! SuiteSparse datasets when they have them; the bundled generators in
//! [`crate::gen`] are the offline stand-ins.
//!
//! Symmetric files have two read paths: [`read`] mirrors every
//! off-diagonal entry into a full CSR, while [`read_lower`] keeps the
//! stored lower triangle as-is. The latter feeds symmetric-SpMV plans
//! ([`SpmvKind::SymmCsr`](crate::config::SpmvKind)): deduplicating in
//! lower form and mirroring afterwards ([`expand_lower`]) makes the two
//! halves bitwise-identical by construction, so the engine's exact
//! symmetry check can never trip on file quirks.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::error::{HbmcError, Result};
use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;

/// How a `symmetric` file's stored lower triangle is materialized.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SymmetryMode {
    /// Mirror every off-diagonal entry (full CSR; `general` files allowed).
    Expand,
    /// Keep the stored triangle as-is (`general` files rejected).
    KeepLower,
}

/// Read a square MatrixMarket file into CSR (symmetric files are expanded).
pub fn read(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .map_err(|e| HbmcError::io(format!("opening {}", path.display()), e))?;
    read_from(BufReader::new(f))
}

/// Parse from any reader (unit-testable without touching the filesystem).
pub fn read_from(reader: impl BufRead) -> Result<Csr> {
    read_coo(reader, SymmetryMode::Expand)
}

/// Read a `symmetric` MatrixMarket file keeping only the stored lower
/// triangle (diagonal + strict lower) — the input for symmetric-SpMV
/// plans. `general` files and entries above the diagonal are typed
/// parse errors.
pub fn read_lower(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .map_err(|e| HbmcError::io(format!("opening {}", path.display()), e))?;
    read_lower_from(BufReader::new(f))
}

/// [`read_lower`] from any reader.
pub fn read_lower_from(reader: impl BufRead) -> Result<Csr> {
    read_coo(reader, SymmetryMode::KeepLower)
}

/// Mirror a lower-triangular CSR (diagonal + strict lower, as produced by
/// [`read_lower`]) into the full symmetric matrix. Because duplicates were
/// summed in lower form first, `A[i][j]` and `A[j][i]` are bitwise equal
/// by construction. Entries above the diagonal are a typed error.
pub fn expand_lower(l: &Csr) -> Result<Csr> {
    let n = l.n();
    let mut coo = Coo::with_capacity(n, 2 * l.nnz());
    for i in 0..n {
        let (cols, vals) = l.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            let j = c as usize;
            if j > i {
                return Err(HbmcError::parse(format!(
                    "expand_lower: entry ({i},{j}) above the diagonal"
                )));
            }
            if j == i {
                coo.push(i, i, v);
            } else {
                coo.push_sym(i, j, v);
            }
        }
    }
    Ok(coo.to_csr())
}

fn read_coo(reader: impl BufRead, mode: SymmetryMode) -> Result<Csr> {
    let mut lines = reader.lines();
    let header = lines
        .next()
        .ok_or_else(|| HbmcError::parse("matrix market: empty file"))?
        .map_err(|e| HbmcError::io("matrix market: read error", e))?;
    let h: Vec<String> = header.split_whitespace().map(|t| t.to_lowercase()).collect();
    if h.len() < 5 || h[0] != "%%matrixmarket" || h[1] != "matrix" || h[2] != "coordinate" {
        return Err(HbmcError::parse(format!("matrix market: unsupported header {header:?}")));
    }
    let pattern = match h[3].as_str() {
        "real" | "integer" => false,
        "pattern" => true,
        other => {
            return Err(HbmcError::parse(format!("matrix market: unsupported field {other:?}")))
        }
    };
    let symmetric = match h[4].as_str() {
        "general" => false,
        "symmetric" => true,
        other => {
            return Err(HbmcError::parse(format!("matrix market: unsupported symmetry {other:?}")))
        }
    };
    if mode == SymmetryMode::KeepLower && !symmetric {
        return Err(HbmcError::parse(
            "matrix market: read_lower requires a `symmetric` file, got `general`",
        ));
    }

    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line.map_err(|e| HbmcError::io("matrix market: read error", e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(t.to_string());
        break;
    }
    let size_line = size_line.ok_or_else(|| HbmcError::parse("matrix market: missing size line"))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| {
            t.parse().map_err(|_| {
                HbmcError::parse(format!("matrix market: bad size line {size_line:?}"))
            })
        })
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        return Err(HbmcError::parse(format!("matrix market: bad size line {size_line:?}")));
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);
    if nrows != ncols {
        return Err(HbmcError::parse(format!(
            "matrix market: only square matrices supported ({nrows}x{ncols})"
        )));
    }

    let expand = symmetric && mode == SymmetryMode::Expand;
    let mut coo = Coo::with_capacity(nrows, if expand { 2 * nnz } else { nnz });
    let mut seen = 0usize;
    for line in lines {
        let line = line.map_err(|e| HbmcError::io("matrix market: read error", e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it
            .next()
            .ok_or_else(|| HbmcError::parse("mm: missing row"))?
            .parse()
            .map_err(|_| HbmcError::parse("mm: bad row"))?;
        let j: usize = it
            .next()
            .ok_or_else(|| HbmcError::parse("mm: missing col"))?
            .parse()
            .map_err(|_| HbmcError::parse("mm: bad col"))?;
        let v: f64 = if pattern {
            1.0
        } else {
            it.next()
                .ok_or_else(|| HbmcError::parse("mm: missing value"))?
                .parse()
                .map_err(|_| HbmcError::parse("mm: bad value"))?
        };
        if i == 0 || j == 0 || i > nrows || j > ncols {
            return Err(HbmcError::parse(format!(
                "matrix market: 1-based index ({i},{j}) out of range"
            )));
        }
        if mode == SymmetryMode::KeepLower && j > i {
            return Err(HbmcError::parse(format!(
                "matrix market: symmetric file stores entry ({i},{j}) above the diagonal"
            )));
        }
        if expand {
            coo.push_sym(i - 1, j - 1, v);
        } else {
            coo.push(i - 1, j - 1, v);
        }
        seen += 1;
    }
    if seen != nnz {
        return Err(HbmcError::parse(format!(
            "matrix market: expected {nnz} entries, found {seen}"
        )));
    }
    Ok(coo.to_csr())
}

/// Write CSR as `coordinate real general`.
pub fn write(a: &Csr, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .map_err(|e| HbmcError::io(format!("creating {}", path.display()), e))?,
    );
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "{} {} {}", a.n(), a.n(), a.nnz())?;
    for i in 0..a.n() {
        let (cols, vals) = a.row(i);
        for (c, v) in cols.iter().zip(vals) {
            writeln!(f, "{} {} {:.17e}", i + 1, *c as usize + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_general() {
        let text = "%%MatrixMarket matrix coordinate real general\n% comment\n3 3 4\n1 1 2.0\n2 2 3.0\n3 3 4.0\n1 3 -1.0\n";
        let a = read_from(Cursor::new(text)).unwrap();
        assert_eq!(a.n(), 3);
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.get(0, 2), Some(-1.0));
        assert_eq!(a.get(2, 0), None);
    }

    #[test]
    fn parse_symmetric_expands() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 2.0\n2 1 -1.0\n";
        let a = read_from(Cursor::new(text)).unwrap();
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.get(0, 1), Some(-1.0));
        assert_eq!(a.get(1, 0), Some(-1.0));
    }

    #[test]
    fn parse_pattern() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n2 2 2\n1 1\n2 1\n";
        let a = read_from(Cursor::new(text)).unwrap();
        assert_eq!(a.get(1, 0), Some(1.0));
    }

    #[test]
    fn reject_rectangular_and_bad_counts() {
        assert!(read_from(Cursor::new("%%MatrixMarket matrix coordinate real general\n2 3 1\n1 1 1.0\n")).is_err());
        assert!(read_from(Cursor::new("%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n")).is_err());
        assert!(read_from(Cursor::new("%%MatrixMarket matrix array real general\n2 2 1\n")).is_err());
    }

    #[test]
    fn lower_read_round_trips_vs_expanding_reader() {
        // 3x3 symmetric with a duplicate lower entry (summed in COO):
        // the kept-lower triangle, mirrored, must equal the expanding
        // reader's full matrix entry-for-entry.
        let text = "%%MatrixMarket matrix coordinate real symmetric\n3 3 5\n\
                    1 1 4.0\n2 2 5.0\n3 3 6.0\n2 1 -1.5\n3 2 -0.25\n";
        let lower = read_lower_from(Cursor::new(text)).unwrap();
        assert_eq!(lower.nnz(), 5, "lower view keeps stored entries only");
        assert_eq!(lower.get(0, 1), None, "no mirrored upper entries");
        let full = expand_lower(&lower).unwrap();
        let expanded = read_from(Cursor::new(text)).unwrap();
        assert_eq!(full, expanded);
    }

    #[test]
    fn lower_read_rejects_general_and_upper_entries() {
        let general = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 1.0\n";
        assert!(read_lower_from(Cursor::new(general)).is_err());
        // A symmetric file that stores the *upper* triangle is legal
        // MatrixMarket but not a lower view.
        let upper = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 1.0\n1 2 -1.0\n";
        assert!(read_lower_from(Cursor::new(upper)).is_err());
        assert!(read_from(Cursor::new(upper)).is_ok(), "expanding reader accepts it");
    }

    #[test]
    fn expand_lower_rejects_upper_entries() {
        let mut coo = Coo::new(2);
        coo.push(0, 1, 1.0);
        assert!(expand_lower(&coo.to_csr()).is_err());
    }

    #[test]
    fn write_read_roundtrip() {
        let mut coo = Coo::new(3);
        coo.push(0, 0, 1.5);
        coo.push_sym(0, 2, -2.25);
        coo.push(1, 1, 3.0);
        coo.push(2, 2, 9.0);
        let a = coo.to_csr();
        let dir = std::env::temp_dir().join("hbmc_mm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.mtx");
        write(&a, &path).unwrap();
        let b = read(&path).unwrap();
        assert_eq!(a, b);
    }
}
