//! Compressed sparse row storage — the canonical matrix format of the
//! solver stack (the paper's "CRS"). Rows are column-sorted.

use crate::ordering::perm::Perm;

/// Square CSR matrix with `u32` indices and `f64` values.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    n: usize,
    row_ptr: Vec<u32>,
    col: Vec<u32>,
    val: Vec<f64>,
}

impl Csr {
    /// Build from raw parts. Debug-asserts structural sanity.
    pub fn from_parts(n: usize, row_ptr: Vec<u32>, col: Vec<u32>, val: Vec<f64>) -> Csr {
        assert_eq!(row_ptr.len(), n + 1);
        assert_eq!(col.len(), val.len());
        assert_eq!(*row_ptr.last().unwrap() as usize, col.len());
        debug_assert!(col.iter().all(|&c| (c as usize) < n));
        debug_assert!((0..n).all(|i| {
            let r = row_ptr[i] as usize..row_ptr[i + 1] as usize;
            col[r].windows(2).all(|w| w[0] < w[1])
        }), "CSR rows must be strictly column-sorted");
        Csr { n, row_ptr, col, val }
    }

    /// Identity matrix (used for dummy/padding rows in tests).
    pub fn identity(n: usize) -> Csr {
        Csr::from_parts(
            n,
            (0..=n as u32).collect(),
            (0..n as u32).collect(),
            vec![1.0; n],
        )
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.col.len()
    }

    #[inline]
    pub fn row_ptr(&self) -> &[u32] {
        &self.row_ptr
    }

    #[inline]
    pub fn cols(&self) -> &[u32] {
        &self.col
    }

    #[inline]
    pub fn vals(&self) -> &[f64] {
        &self.val
    }

    #[inline]
    pub fn vals_mut(&mut self) -> &mut [f64] {
        &mut self.val
    }

    /// Columns and values of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let r = self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize;
        (&self.col[r.clone()], &self.val[r])
    }

    /// Number of entries in row `i`.
    #[inline]
    pub fn row_len(&self, i: usize) -> usize {
        (self.row_ptr[i + 1] - self.row_ptr[i]) as usize
    }

    /// Value at `(i, j)` if stored.
    pub fn get(&self, i: usize, j: usize) -> Option<f64> {
        let (cols, vals) = self.row(i);
        cols.binary_search(&(j as u32)).ok().map(|k| vals[k])
    }

    /// Diagonal entries (0.0 where the diagonal is not stored).
    pub fn diag(&self) -> Vec<f64> {
        (0..self.n).map(|i| self.get(i, i).unwrap_or(0.0)).collect()
    }

    /// `y = A x` (serial reference; the performant paths live in
    /// [`crate::solver::spmv`]).
    pub fn mul_vec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            let mut s = 0.0;
            for (c, v) in cols.iter().zip(vals) {
                s += v * x[*c as usize];
            }
            y[i] = s;
        }
    }

    /// Structural symmetry check (pattern and values).
    pub fn is_symmetric(&self, tol: f64) -> bool {
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                match self.get(*c as usize, i) {
                    Some(w) => {
                        if (v - w).abs() > tol * v.abs().max(w.abs()).max(1.0) {
                            return false;
                        }
                    }
                    None => return false,
                }
            }
        }
        true
    }

    /// Strict lower-triangular part (cols < row), same row order.
    pub fn lower_strict(&self) -> Csr {
        self.filter(|i, j| j < i)
    }

    /// Lower-triangular including diagonal.
    pub fn lower(&self) -> Csr {
        self.filter(|i, j| j <= i)
    }

    /// Upper-triangular including diagonal.
    pub fn upper(&self) -> Csr {
        self.filter(|i, j| j >= i)
    }

    fn filter(&self, keep: impl Fn(usize, usize) -> bool) -> Csr {
        let mut row_ptr = Vec::with_capacity(self.n + 1);
        let mut col = Vec::new();
        let mut val = Vec::new();
        row_ptr.push(0u32);
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                if keep(i, *c as usize) {
                    col.push(*c);
                    val.push(*v);
                }
            }
            row_ptr.push(col.len() as u32);
        }
        Csr::from_parts(self.n, row_ptr, col, val)
    }

    /// Transpose.
    pub fn transpose(&self) -> Csr {
        let n = self.n;
        let mut cnt = vec![0u32; n + 1];
        for &c in &self.col {
            cnt[c as usize + 1] += 1;
        }
        for i in 0..n {
            cnt[i + 1] += cnt[i];
        }
        let mut col = vec![0u32; self.nnz()];
        let mut val = vec![0f64; self.nnz()];
        let mut cursor = cnt.clone();
        for i in 0..n {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                let p = cursor[*c as usize] as usize;
                col[p] = i as u32;
                val[p] = *v;
                cursor[*c as usize] += 1;
            }
        }
        Csr::from_parts(n, cnt, col, val)
    }

    /// Symmetric permutation `A' = P A Pᵀ`: entry `(i, j)` moves to
    /// `(π(i), π(j))`. `perm` maps old → new index over an equal or larger
    /// index space (`perm.n_new() >= self.n()`); extra rows become
    /// identity rows (the HBMC "dummy unknowns" of §4.3).
    pub fn permute_sym(&self, perm: &Perm) -> Csr {
        assert!(perm.n_old() == self.n, "perm domain must match matrix");
        let n_new = perm.n_new();
        let mut coo = crate::sparse::coo::Coo::with_capacity(n_new, self.nnz() + n_new);
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            let pi = perm.new_of_old(i);
            for (c, v) in cols.iter().zip(vals) {
                coo.push(pi, perm.new_of_old(*c as usize), *v);
            }
        }
        // Dummy rows: identity diagonal, decoupled from the real system.
        let mut is_real = vec![false; n_new];
        for i in 0..self.n {
            is_real[perm.new_of_old(i)] = true;
        }
        for (i, real) in is_real.iter().enumerate() {
            if !real {
                coo.push(i, i, 1.0);
            }
        }
        coo.to_csr()
    }

    /// Dense representation (tests only; O(n²) memory).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; self.n]; self.n];
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                d[i][*c as usize] = *v;
            }
        }
        d
    }

    /// Maximum row length (SELL padding analysis).
    pub fn max_row_len(&self) -> usize {
        (0..self.n).map(|i| self.row_len(i)).max().unwrap_or(0)
    }

    /// Content fingerprint (FNV-1a over dimension, structure and value
    /// bits). Keys the coordinator's plan cache: two matrices with the same
    /// fingerprint share ordering/factorization plans. A full-nnz scan —
    /// O(nnz), but orders of magnitude cheaper than one IC(0) refactor.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut eat = |word: u64| {
            for byte in word.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        eat(self.n as u64);
        for &p in &self.row_ptr {
            eat(p as u64);
        }
        for &c in &self.col {
            eat(c as u64);
        }
        for &v in &self.val {
            eat(v.to_bits());
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn sample() -> Csr {
        // [ 4 -1  0 ]
        // [-1  4 -1 ]
        // [ 0 -1  4 ]
        let mut c = Coo::new(3);
        for i in 0..3 {
            c.push(i, i, 4.0);
        }
        c.push_sym(0, 1, -1.0);
        c.push_sym(1, 2, -1.0);
        c.to_csr()
    }

    #[test]
    fn basic_accessors() {
        let a = sample();
        assert_eq!(a.n(), 3);
        assert_eq!(a.nnz(), 7);
        assert_eq!(a.row_len(1), 3);
        assert_eq!(a.diag(), vec![4.0, 4.0, 4.0]);
        assert_eq!(a.max_row_len(), 3);
    }

    #[test]
    fn mul_vec_matches_dense() {
        let a = sample();
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![0.0; 3];
        a.mul_vec(&x, &mut y);
        assert_eq!(y, vec![4.0 - 2.0, -1.0 + 8.0 - 3.0, -2.0 + 12.0]);
    }

    #[test]
    fn symmetry() {
        let a = sample();
        assert!(a.is_symmetric(1e-14));
        let mut c = Coo::new(2);
        c.push(0, 1, 1.0);
        c.push(0, 0, 1.0);
        c.push(1, 1, 1.0);
        assert!(!c.to_csr().is_symmetric(1e-14));
    }

    #[test]
    fn triangular_parts() {
        let a = sample();
        let l = a.lower();
        assert_eq!(l.nnz(), 5);
        assert_eq!(l.get(1, 0), Some(-1.0));
        assert_eq!(l.get(0, 1), None);
        let ls = a.lower_strict();
        assert_eq!(ls.nnz(), 2);
        let u = a.upper();
        assert_eq!(u.nnz(), 5);
        assert_eq!(u.get(0, 1), Some(-1.0));
    }

    #[test]
    fn transpose_involution() {
        let mut c = Coo::new(3);
        c.push(0, 2, 5.0);
        c.push(1, 0, 2.0);
        c.push(2, 2, 1.0);
        let a = c.to_csr();
        let t = a.transpose();
        assert_eq!(t.get(2, 0), Some(5.0));
        assert_eq!(t.get(0, 1), Some(2.0));
        assert_eq!(t.transpose(), a);
    }

    #[test]
    fn permute_identity_is_noop() {
        let a = sample();
        let p = Perm::identity(3);
        assert_eq!(a.permute_sym(&p), a);
    }

    #[test]
    fn permute_reverse() {
        let a = sample();
        let p = Perm::from_new_of_old(vec![2, 1, 0], 3).unwrap();
        let b = a.permute_sym(&p);
        assert_eq!(b.get(2, 1), Some(-1.0));
        assert_eq!(b.get(0, 2), None);
        // Symmetric permutation of a symmetric matrix stays symmetric.
        assert!(b.is_symmetric(1e-14));
    }

    #[test]
    fn permute_with_padding_adds_identity_rows() {
        let a = sample();
        // Map 3 unknowns into a 5-slot space.
        let p = Perm::padded(vec![0, 2, 4], 5).unwrap();
        let b = a.permute_sym(&p);
        assert_eq!(b.n(), 5);
        assert_eq!(b.get(1, 1), Some(1.0));
        assert_eq!(b.get(3, 3), Some(1.0));
        assert_eq!(b.get(0, 0), Some(4.0));
        assert_eq!(b.get(0, 2), Some(-1.0)); // old (0,1)
    }

    #[test]
    fn identity_matrix() {
        let i = Csr::identity(4);
        assert_eq!(i.nnz(), 4);
        let x = vec![3.0, 1.0, 4.0, 1.5];
        let mut y = vec![0.0; 4];
        i.mul_vec(&x, &mut y);
        assert_eq!(y, x);
    }

    #[test]
    fn fingerprint_distinguishes_structure_and_values() {
        let a = sample();
        assert_eq!(a.fingerprint(), sample().fingerprint(), "must be deterministic");
        let mut b = sample();
        b.vals_mut()[0] = 4.0 + 1e-12;
        assert_ne!(a.fingerprint(), b.fingerprint(), "value bits must matter");
        assert_ne!(a.fingerprint(), Csr::identity(3).fingerprint());
        assert_ne!(Csr::identity(3).fingerprint(), Csr::identity(4).fingerprint());
    }
}
