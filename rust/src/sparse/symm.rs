//! Lower-triangle + diagonal storage for the symmetric SpMV engine.
//!
//! A symmetric matrix streamed through CRS moves every off-diagonal value
//! twice per SpMV (once as `a[i][j]`, once as `a[j][i]`). [`SymmCsr`]
//! stores the diagonal densely plus the **strict lower triangle** in CRS
//! layout; each stored nonzero `(i, j, v)` with `j < i` then contributes
//! to *both* `y[i] += v·x[j]` (gather) and `y[j] += v·x[i]` (scatter),
//! roughly halving the matrix bytes per iteration — the RACE idea of
//! Alappat et al. (see PAPERS.md). The parallel schedule that makes the
//! scatter side safe lives in [`crate::ordering::race`]; the engine itself
//! in [`crate::solver::spmv`]. This module is only the storage view plus a
//! serial reference kernel.
//!
//! Construction is strict: [`SymmCsr::from_csr`] demands **exact** (bitwise)
//! symmetry — the solver pipeline only ever feeds it matrices that are
//! symmetric by construction (generators, `push_sym` readers,
//! `permute_sym`), so a mismatch is a configuration error, not something to
//! paper over with a tolerance.

use crate::error::{HbmcError, Result};
use crate::sparse::csr::Csr;

/// Symmetric matrix as dense diagonal + strict-lower-triangle CRS.
#[derive(Debug, Clone)]
pub struct SymmCsr {
    n: usize,
    diag: Vec<f64>,
    row_ptr: Vec<u32>,
    cols: Vec<u32>,
    vals: Vec<f64>,
}

impl SymmCsr {
    /// Build from a full symmetric CRS matrix. Returns
    /// [`HbmcError::InvalidConfig`] unless every off-diagonal entry has a
    /// bitwise-equal mirror (`a[i][j]` ≡ `a[j][i]`).
    pub fn from_csr(a: &Csr) -> Result<SymmCsr> {
        let n = a.n();
        let mut diag = vec![0.0f64; n];
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0u32);
        for i in 0..n {
            let (ci, vi) = a.row(i);
            for (&j, &v) in ci.iter().zip(vi) {
                let j = j as usize;
                if j == i {
                    diag[i] = v;
                    continue;
                }
                let mirror = a.get(j, i).map(f64::to_bits);
                if mirror != Some(v.to_bits()) {
                    return Err(HbmcError::invalid_config(format!(
                        "SymmCsr requires an exactly symmetric matrix: a[{i}][{j}] = {v:?} \
                         but a[{j}][{i}] = {:?}",
                        a.get(j, i)
                    )));
                }
                if j < i {
                    cols.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(cols.len() as u32);
        }
        Ok(SymmCsr { n, diag, row_ptr, cols, vals })
    }

    /// Build from a lower-triangular CRS (entries with `col ≤ row` only,
    /// e.g. the output of [`crate::sparse::matrix_market::read_lower`] or
    /// [`Csr::lower`]). Returns [`HbmcError::InvalidConfig`] if any entry
    /// lies above the diagonal.
    pub fn from_lower(l: &Csr) -> Result<SymmCsr> {
        let n = l.n();
        let mut diag = vec![0.0f64; n];
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0u32);
        for i in 0..n {
            let (ci, vi) = l.row(i);
            for (&j, &v) in ci.iter().zip(vi) {
                let j = j as usize;
                if j > i {
                    return Err(HbmcError::invalid_config(format!(
                        "SymmCsr::from_lower: entry ({i}, {j}) lies above the diagonal"
                    )));
                }
                if j == i {
                    diag[i] = v;
                } else {
                    cols.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(cols.len() as u32);
        }
        Ok(SymmCsr { n, diag, row_ptr, cols, vals })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of stored strict-lower nonzeros.
    pub fn nnz_lower(&self) -> usize {
        self.vals.len()
    }

    /// Stored elements streamed per SpMV: `n` diagonal values plus the
    /// strict lower triangle (the traffic-model / `OpProfile` unit).
    pub fn stored_elements(&self) -> usize {
        self.n + self.vals.len()
    }

    pub fn diag(&self) -> &[f64] {
        &self.diag
    }

    pub fn row_ptr(&self) -> &[u32] {
        &self.row_ptr
    }

    pub fn cols(&self) -> &[u32] {
        &self.cols
    }

    pub fn vals(&self) -> &[f64] {
        &self.vals
    }

    /// Strict-lower row `i` as `(cols, vals)`.
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let lo = self.row_ptr[i] as usize;
        let hi = self.row_ptr[i + 1] as usize;
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// Serial reference `y = A·x` in natural row order (diagonal pass,
    /// then gather + scatter per strict-lower nonzero). This is the
    /// *numerical* reference for the parallel engine — the parallel
    /// schedule accumulates in a different (color) order, so agreement is
    /// to rounding (≈1e-13 relative), not bitwise.
    pub fn mul_vec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        for i in 0..self.n {
            y[i] = self.diag[i] * x[i];
        }
        for i in 0..self.n {
            let xi = x[i];
            let (ci, vi) = self.row(i);
            let mut acc = 0.0;
            for (&j, &v) in ci.iter().zip(vi) {
                let j = j as usize;
                acc += v * x[j];
                y[j] += v * xi;
            }
            y[i] += acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn random_sym(n: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(n);
        for i in 0..n {
            coo.push(i, i, 4.0 + rng.f64());
            for _ in 0..3 {
                let j = rng.below(n);
                if j != i {
                    coo.push_sym(i, j, -0.5 + rng.f64() * 0.1);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn from_csr_matches_full_mul() {
        for seed in [1u64, 7, 42] {
            let a = random_sym(64, seed);
            let s = SymmCsr::from_csr(&a).expect("symmetric by construction");
            assert_eq!(s.stored_elements(), a.n() + (a.nnz() - a.n()) / 2);
            let x: Vec<f64> = (0..a.n()).map(|i| (i as f64).sin() + 1.0).collect();
            let mut y_full = vec![0.0; a.n()];
            let mut y_symm = vec![0.0; a.n()];
            a.mul_vec(&x, &mut y_full);
            s.mul_vec(&x, &mut y_symm);
            let rel = crate::util::rel_l2_diff(&y_symm, &y_full);
            assert!(rel < 1e-13, "seed {seed}: rel diff {rel}");
        }
    }

    #[test]
    fn from_lower_round_trips_through_lower_view() {
        let a = random_sym(48, 3);
        let via_full = SymmCsr::from_csr(&a).unwrap();
        let via_lower = SymmCsr::from_lower(&a.lower()).unwrap();
        assert_eq!(via_full.row_ptr(), via_lower.row_ptr());
        assert_eq!(via_full.cols(), via_lower.cols());
        assert_eq!(via_full.vals(), via_lower.vals());
        assert_eq!(via_full.diag(), via_lower.diag());
    }

    #[test]
    fn asymmetric_matrix_is_a_typed_error() {
        let mut coo = Coo::new(3);
        coo.push(0, 0, 2.0);
        coo.push(1, 1, 2.0);
        coo.push(2, 2, 2.0);
        coo.push(1, 0, -1.0); // no (0,1) mirror
        let a = coo.to_csr();
        match SymmCsr::from_csr(&a) {
            Err(HbmcError::InvalidConfig(_)) => {}
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }

    #[test]
    fn from_lower_rejects_upper_entries() {
        let mut coo = Coo::new(2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, -1.0);
        coo.push(1, 1, 1.0);
        let u = coo.to_csr();
        match SymmCsr::from_lower(&u) {
            Err(HbmcError::InvalidConfig(_)) => {}
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }
}
