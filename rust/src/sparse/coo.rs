//! Coordinate-format assembly buffer. Generators build matrices here and
//! convert to [`Csr`](crate::sparse::csr::Csr) once; duplicate entries are
//! summed on conversion (finite-element style assembly).

use crate::sparse::csr::Csr;

/// Square COO matrix under assembly.
#[derive(Debug, Clone)]
pub struct Coo {
    n: usize,
    entries: Vec<(u32, u32, f64)>,
}

impl Coo {
    pub fn new(n: usize) -> Self {
        assert!(n < u32::MAX as usize, "COO limited to u32 indices");
        Coo { n, entries: Vec::new() }
    }

    pub fn with_capacity(n: usize, cap: usize) -> Self {
        let mut c = Self::new(n);
        c.entries.reserve(cap);
        c
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn nnz_entries(&self) -> usize {
        self.entries.len()
    }

    /// Add `v` at `(i, j)`; duplicates accumulate.
    #[inline]
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.n && j < self.n, "entry ({i},{j}) out of bounds n={}", self.n);
        self.entries.push((i as u32, j as u32, v));
    }

    /// Add `v` at `(i, j)` and `(j, i)` (symmetric assembly; `i != j`).
    #[inline]
    pub fn push_sym(&mut self, i: usize, j: usize, v: f64) {
        self.push(i, j, v);
        if i != j {
            self.push(j, i, v);
        }
    }

    /// Convert to CSR, summing duplicates and dropping exact zeros created
    /// by cancellation is NOT done (IC(0) pattern must match assembly).
    pub fn to_csr(&self) -> Csr {
        let n = self.n;
        // Counting sort by row, then sort each row by column and merge dups.
        let mut row_count = vec![0u32; n + 1];
        for &(i, _, _) in &self.entries {
            row_count[i as usize + 1] += 1;
        }
        for i in 0..n {
            row_count[i + 1] += row_count[i];
        }
        let mut cols = vec![0u32; self.entries.len()];
        let mut vals = vec![0f64; self.entries.len()];
        let mut cursor = row_count.clone();
        for &(i, j, v) in &self.entries {
            let p = cursor[i as usize] as usize;
            cols[p] = j;
            vals[p] = v;
            cursor[i as usize] += 1;
        }
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut out_cols: Vec<u32> = Vec::with_capacity(self.entries.len());
        let mut out_vals: Vec<f64> = Vec::with_capacity(self.entries.len());
        row_ptr.push(0u32);
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for i in 0..n {
            let (s, e) = (row_count[i] as usize, row_count[i + 1] as usize);
            scratch.clear();
            scratch.extend(cols[s..e].iter().copied().zip(vals[s..e].iter().copied()));
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut k = 0;
            while k < scratch.len() {
                let c = scratch[k].0;
                let mut v = 0.0;
                while k < scratch.len() && scratch[k].0 == c {
                    v += scratch[k].1;
                    k += 1;
                }
                out_cols.push(c);
                out_vals.push(v);
            }
            row_ptr.push(out_cols.len() as u32);
        }
        Csr::from_parts(n, row_ptr, out_cols, out_vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assembly_sums_duplicates() {
        let mut c = Coo::new(3);
        c.push(0, 0, 1.0);
        c.push(0, 0, 2.0);
        c.push(2, 1, -1.0);
        c.push(1, 2, 4.0);
        let a = c.to_csr();
        assert_eq!(a.n(), 3);
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.get(0, 0), Some(3.0));
        assert_eq!(a.get(2, 1), Some(-1.0));
        assert_eq!(a.get(1, 2), Some(4.0));
        assert_eq!(a.get(1, 1), None);
    }

    #[test]
    fn push_sym_mirrors() {
        let mut c = Coo::new(2);
        c.push_sym(0, 1, 5.0);
        c.push_sym(1, 1, 2.0);
        let a = c.to_csr();
        assert_eq!(a.get(0, 1), Some(5.0));
        assert_eq!(a.get(1, 0), Some(5.0));
        assert_eq!(a.get(1, 1), Some(2.0));
    }

    #[test]
    fn rows_sorted() {
        let mut c = Coo::new(4);
        c.push(1, 3, 1.0);
        c.push(1, 0, 1.0);
        c.push(1, 2, 1.0);
        let a = c.to_csr();
        let (cols, _) = a.row(1);
        assert_eq!(cols, &[0, 2, 3]);
    }
}
