//! Sliced-ELL storage (SELL / SELL-C-σ, Kreutzer et al. 2014).
//!
//! Rows are grouped into *slices* of `c` consecutive rows; each slice is
//! stored column-major (`val[off + k*c + lane]`) and padded to the longest
//! row in the slice, which is what makes the inner loop a `c`-wide packed
//! operation — the layout the paper pairs with HBMC (`c = w`).
//!
//! SELL-C-σ additionally sorts rows by length inside windows of `σ` rows to
//! reduce padding; the sort permutation is internal to the format (values
//! are scattered back on SpMV), so it is **only** usable for SpMV, not for
//! triangular solves where row order is semantic.

use crate::sparse::csr::Csr;

/// SELL-C(-σ) matrix.
#[derive(Debug, Clone)]
pub struct Sell {
    n: usize,
    /// Slice height (the paper's `w`).
    c: usize,
    /// Per-slice start offset into `val`/`col` (`len = nslices + 1`).
    slice_ptr: Vec<u32>,
    /// Per-slice width (longest row in the slice).
    slice_len: Vec<u32>,
    /// Column indices, slice-local column-major, padded entries point at
    /// their own row with value 0 (safe gather).
    col: Vec<u32>,
    val: Vec<f64>,
    /// `row_of_lane[slice*c + lane]` = source CSR row (u32::MAX for padding
    /// rows past `n`). Identity when built without σ-sorting.
    row_of_lane: Vec<u32>,
    /// True if rows were σ-sorted (SpMV-only layout).
    sorted: bool,
}

impl Sell {
    /// Build SELL-C from CSR preserving row order (usable for trisolve).
    pub fn from_csr(a: &Csr, c: usize) -> Sell {
        Self::build(a, c, None)
    }

    /// Build SELL-C-σ: sort rows by descending length within windows of
    /// `sigma` rows (`sigma` a multiple of `c`). SpMV-only.
    pub fn from_csr_sigma(a: &Csr, c: usize, sigma: usize) -> Sell {
        assert!(sigma >= c && sigma % c == 0, "sigma must be a multiple of c");
        Self::build(a, c, Some(sigma))
    }

    fn build(a: &Csr, c: usize, sigma: Option<usize>) -> Sell {
        assert!(c > 0);
        let n = a.n();
        let nslices = n.div_ceil(c);
        let mut row_of_lane: Vec<u32> = (0..(nslices * c) as u32).collect();
        if let Some(sigma) = sigma {
            for wstart in (0..n).step_by(sigma) {
                let wend = (wstart + sigma).min(nslices * c);
                row_of_lane[wstart..wend].sort_by_key(|&r| {
                    if (r as usize) < n {
                        usize::MAX - a.row_len(r as usize)
                    } else {
                        usize::MAX
                    }
                });
            }
        }
        let mut slice_ptr = Vec::with_capacity(nslices + 1);
        let mut slice_len = Vec::with_capacity(nslices);
        slice_ptr.push(0u32);
        let mut col = Vec::new();
        let mut val = Vec::new();
        for s in 0..nslices {
            let lanes = &row_of_lane[s * c..(s + 1) * c];
            let width = lanes
                .iter()
                .map(|&r| if (r as usize) < n { a.row_len(r as usize) } else { 0 })
                .max()
                .unwrap_or(0);
            for k in 0..width {
                for &r in lanes {
                    if (r as usize) < n && k < a.row_len(r as usize) {
                        let (cols, vals) = a.row(r as usize);
                        col.push(cols[k]);
                        val.push(vals[k]);
                    } else {
                        // Padding: self-reference (or row 0) with value 0.
                        let safe = if (r as usize) < n { r } else { 0 };
                        col.push(safe);
                        val.push(0.0);
                    }
                }
            }
            slice_len.push(width as u32);
            slice_ptr.push(col.len() as u32);
        }
        let row_of_lane = row_of_lane
            .into_iter()
            .map(|r| if (r as usize) < n { r } else { u32::MAX })
            .collect();
        Sell {
            n,
            c,
            slice_ptr,
            slice_len,
            col,
            val,
            row_of_lane,
            sorted: sigma.is_some(),
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn c(&self) -> usize {
        self.c
    }

    #[inline]
    pub fn nslices(&self) -> usize {
        self.slice_len.len()
    }

    #[inline]
    pub fn slice_ptr(&self) -> &[u32] {
        &self.slice_ptr
    }

    #[inline]
    pub fn slice_len(&self) -> &[u32] {
        &self.slice_len
    }

    #[inline]
    pub fn cols(&self) -> &[u32] {
        &self.col
    }

    #[inline]
    pub fn vals(&self) -> &[f64] {
        &self.val
    }

    #[inline]
    pub fn row_of_lane(&self) -> &[u32] {
        &self.row_of_lane
    }

    #[inline]
    pub fn is_sorted(&self) -> bool {
        self.sorted
    }

    /// Stored (incl. padding) element count — the paper's "number of
    /// processed elements" metric for the SELL-overhead discussion (§5.2.2).
    pub fn stored_elements(&self) -> usize {
        self.val.len()
    }

    /// Padding overhead vs CSR nnz: `stored / nnz`.
    pub fn overhead_vs(&self, nnz: usize) -> f64 {
        self.stored_elements() as f64 / nnz as f64
    }

    /// Serial reference SpMV `y = A x` (performant path in
    /// [`crate::solver::spmv`]).
    pub fn mul_vec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        let c = self.c;
        let mut acc = vec![0.0f64; c];
        for s in 0..self.nslices() {
            acc[..c].fill(0.0);
            let off = self.slice_ptr[s] as usize;
            let width = self.slice_len[s] as usize;
            for k in 0..width {
                let base = off + k * c;
                for lane in 0..c {
                    acc[lane] += self.val[base + lane] * x[self.col[base + lane] as usize];
                }
            }
            for lane in 0..c {
                let r = self.row_of_lane[s * c + lane];
                if r != u32::MAX {
                    y[r as usize] = acc[lane];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn random_csr(n: usize, avg: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(n);
        for i in 0..n {
            coo.push(i, i, 4.0 + rng.f64());
            let deg = rng.below(avg * 2);
            for _ in 0..deg {
                let j = rng.below(n);
                if j != i {
                    coo.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn spmv_matches_csr() {
        for &c in &[2usize, 4, 8] {
            let a = random_csr(50, 4, 42);
            let sell = Sell::from_csr(&a, c);
            let mut rng = Rng::new(7);
            let x: Vec<f64> = (0..50).map(|_| rng.f64()).collect();
            let mut y1 = vec![0.0; 50];
            let mut y2 = vec![0.0; 50];
            a.mul_vec(&x, &mut y1);
            sell.mul_vec(&x, &mut y2);
            assert!(crate::util::max_abs_diff(&y1, &y2) < 1e-13, "c={c}");
        }
    }

    #[test]
    fn sigma_sorting_reduces_padding() {
        let a = random_csr(256, 6, 3);
        let plain = Sell::from_csr(&a, 8);
        let sorted = Sell::from_csr_sigma(&a, 8, 64);
        assert!(sorted.stored_elements() <= plain.stored_elements());
        assert!(sorted.is_sorted() && !plain.is_sorted());
        // Numerics identical.
        let mut rng = Rng::new(9);
        let x: Vec<f64> = (0..256).map(|_| rng.f64()).collect();
        let mut y1 = vec![0.0; 256];
        let mut y2 = vec![0.0; 256];
        plain.mul_vec(&x, &mut y1);
        sorted.mul_vec(&x, &mut y2);
        assert!(crate::util::max_abs_diff(&y1, &y2) < 1e-13);
    }

    #[test]
    fn ragged_tail_slice() {
        // n not a multiple of c.
        let a = random_csr(13, 3, 5);
        let sell = Sell::from_csr(&a, 4);
        assert_eq!(sell.nslices(), 4);
        let x = vec![1.0; 13];
        let mut y1 = vec![0.0; 13];
        let mut y2 = vec![0.0; 13];
        a.mul_vec(&x, &mut y1);
        sell.mul_vec(&x, &mut y2);
        assert!(crate::util::max_abs_diff(&y1, &y2) < 1e-13);
    }

    #[test]
    fn overhead_accounting() {
        let a = random_csr(64, 5, 11);
        let sell = Sell::from_csr(&a, 8);
        assert!(sell.stored_elements() >= a.nnz());
        assert!(sell.overhead_vs(a.nnz()) >= 1.0);
    }

    #[test]
    fn empty_rows_ok() {
        let a = Csr::from_parts(4, vec![0, 1, 1, 1, 2], vec![0, 3], vec![2.0, 5.0]);
        let sell = Sell::from_csr(&a, 4);
        let x = vec![1.0, 1.0, 1.0, 1.0];
        let mut y = vec![9.0; 4];
        sell.mul_vec(&x, &mut y);
        assert_eq!(y, vec![2.0, 0.0, 0.0, 5.0]);
    }
}
