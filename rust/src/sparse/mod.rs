//! Sparse-matrix storage substrates.
//!
//! * [`coo`] — coordinate-format builder (assembly),
//! * [`csr`] — compressed sparse row, the solver's canonical format (the
//!   paper's "CRS"),
//! * [`sell`] — sliced-ELL / SELL-C-σ (Kreutzer et al. 2014), the
//!   SIMD-friendly format the paper uses for HBMC (`slice = w`),
//! * [`symm`] — diagonal + strict-lower-triangle view of a symmetric
//!   matrix, the storage behind the symmetric SpMV engine,
//! * [`matrix_market`] — MatrixMarket IO for external datasets.

pub mod coo;
pub mod csr;
pub mod matrix_market;
pub mod sell;
pub mod symm;
