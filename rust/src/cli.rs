//! Dependency-free command-line parsing (no `clap` in the offline crate
//! set). Grammar: `hbmc <command> [--flag value]... [--switch]...`.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

/// Flags that take no value. (`--retry`, `--breaker-threshold`,
/// `--inject` and `--trace-out` take values, so they must NOT be listed
/// here; `--chaos` is the consent switch that arms `--inject`.)
const SWITCHES: [&str; 10] = [
    "history",
    "verbose",
    "no-intrinsics",
    "help",
    "setup-only",
    "auto",
    "quick",
    "chaos",
    "profile",
    "explain",
];

impl Args {
    /// Parse from an iterator of arguments (program name excluded).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        let mut switches = Vec::new();
        while let Some(tok) = it.next() {
            let Some(name) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument {tok:?}");
            };
            if SWITCHES.contains(&name) {
                switches.push(name.to_string());
            } else {
                let Some(val) = it.next() else {
                    bail!("flag --{name} expects a value");
                };
                flags.insert(name.to_string(), val);
            }
        }
        Ok(Args { command, flags, switches })
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer")),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects a number")),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn command_and_flags() {
        let a = parse("solve --dataset ieej --bs 16 --history").unwrap();
        assert_eq!(a.command, "solve");
        assert_eq!(a.flag("dataset"), Some("ieej"));
        assert_eq!(a.usize_flag("bs", 32).unwrap(), 16);
        assert_eq!(a.usize_flag("w", 8).unwrap(), 8);
        assert!(a.switch("history"));
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn setup_only_and_repeat() {
        let a = parse("solve --dataset ieej --repeat 8 --setup-only").unwrap();
        assert!(a.switch("setup-only"));
        assert_eq!(a.usize_flag("repeat", 1).unwrap(), 8);
    }

    #[test]
    fn tune_and_auto_switches() {
        let a = parse("tune --dataset g3_circuit --quick --store profiles.json").unwrap();
        assert_eq!(a.command, "tune");
        assert!(a.switch("quick"));
        assert_eq!(a.flag("store"), Some("profiles.json"));
        let a = parse("solve --dataset ieej --auto").unwrap();
        assert!(a.switch("auto"));
        assert!(!a.switch("quick"));
    }

    #[test]
    fn serve_admission_and_metrics_flags() {
        // All observability / admission flags take values — none of them
        // may appear in SWITCHES, or `--max-depth 4` would eat "4" as a
        // positional argument.
        let a = parse(
            "serve --dataset ieej --max-depth 4 --max-inflight 2 \
             --metrics-addr 127.0.0.1:9184 --trace 1 --linger-secs 30",
        )
        .unwrap();
        assert_eq!(a.command, "serve");
        assert_eq!(a.usize_flag("max-depth", 0).unwrap(), 4);
        assert_eq!(a.usize_flag("max-inflight", 0).unwrap(), 2);
        assert_eq!(a.flag("metrics-addr"), Some("127.0.0.1:9184"));
        assert_eq!(a.usize_flag("trace", 0).unwrap(), 1);
        assert_eq!(a.usize_flag("linger-secs", 0).unwrap(), 30);
        let a = parse("stats --from 127.0.0.1:9184").unwrap();
        assert_eq!(a.command, "stats");
        assert_eq!(a.flag("from"), Some("127.0.0.1:9184"));
    }

    #[test]
    fn chaos_and_resilience_flags() {
        // --retry / --breaker-threshold / --inject take values; --chaos is
        // the consent switch.
        let a = parse("solve --dataset ieej --chaos --inject panic:fwd:2 --retry 2").unwrap();
        assert!(a.switch("chaos"));
        assert_eq!(a.flag("inject"), Some("panic:fwd:2"));
        assert_eq!(a.usize_flag("retry", 0).unwrap(), 2);
        let a = parse("serve --dataset ieej --breaker-threshold 5").unwrap();
        assert_eq!(a.usize_flag("breaker-threshold", 0).unwrap(), 5);
    }

    #[test]
    fn profiling_flags() {
        // --profile / --explain are switches; --trace-out takes a path and
        // must stay OUT of SWITCHES or it would eat its value.
        let a = parse("solve --dataset ieej --profile --trace-out trace.json").unwrap();
        assert!(a.switch("profile"));
        assert_eq!(a.flag("trace-out"), Some("trace.json"));
        let a = parse("tune --dataset ieej --quick --explain").unwrap();
        assert!(a.switch("explain"));
        assert!(!a.switch("profile"));
    }

    #[test]
    fn empty_is_help() {
        let a = parse("").unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse("solve --dataset").is_err());
        assert!(parse("solve stray").is_err());
        assert!(parse("solve --bs notanum").unwrap().usize_flag("bs", 1).is_err());
    }
}
