//! Deterministic fault injection: [`FaultSpec`] (pure data, parsed from
//! `--inject <spec>` strings) and [`FaultInjector`] (the armed, one-shot
//! runtime hook threaded through `Pool`, `factor::ic0`, and the
//! dispatcher).
//!
//! Faults are pinned to explicit sites — a pool barrier index, a
//! factorization row, a vector index — rather than drawn from a PRNG, so a
//! chaos run is reproducible bit-for-bit: the same spec against the same
//! job stream fires at the same instruction every time. Each injector is
//! armed for exactly one firing; the dispatcher consumes dispatcher-side
//! faults before use, while the worker-side panic hook only *reads* the
//! armed state (all pool threads observe the same value at the same
//! logical barrier and panic in lockstep) and is consumed by the
//! dispatcher's recovery path before the retry.

use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Duration;

use crate::error::HbmcError;

/// Solver phase a [`FaultSpec::WorkerPanic`] is labelled with.
///
/// The label is descriptive (it names the phase the chosen barrier index
/// falls in and is echoed in the panic message); the firing site itself is
/// selected by the barrier index, which is exact and identical on every
/// pool thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPhase {
    /// Forward substitution of the IC(0) triangular solve.
    Fwd,
    /// Backward substitution of the IC(0) triangular solve.
    Bwd,
    /// The SpMV / BLAS-1 segment of the fused loop.
    Spmv,
    /// No particular phase claimed.
    Any,
}

impl fmt::Display for FaultPhase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            FaultPhase::Fwd => "fwd",
            FaultPhase::Bwd => "bwd",
            FaultPhase::Spmv => "spmv",
            FaultPhase::Any => "any",
        })
    }
}

impl FromStr for FaultPhase {
    type Err = HbmcError;
    fn from_str(s: &str) -> Result<FaultPhase, HbmcError> {
        match s {
            "fwd" => Ok(FaultPhase::Fwd),
            "bwd" => Ok(FaultPhase::Bwd),
            "spmv" => Ok(FaultPhase::Spmv),
            "any" => Ok(FaultPhase::Any),
            other => Err(HbmcError::parse(format!(
                "unknown fault phase '{other}' (expected fwd|bwd|spmv|any)"
            ))),
        }
    }
}

/// A deterministic fault, as pure data. Parsed from `--inject` spec
/// strings; `Display` round-trips the spec.
///
/// Spec grammar (one fault per spec):
///
/// | spec                      | fault |
/// |---------------------------|-------|
/// | `panic:<phase>:<barrier>` | every pool thread panics in lockstep at the `<barrier>`-th in-solve pool barrier (0-based) |
/// | `nan-rhs:<index>`         | poison `b[index % n]` of the next dispatched job's RHS copy with NaN |
/// | `nan-factor:<index>`      | poison diagonal entry `index % n` of the next built IC(0) factor with NaN |
/// | `breakdown:<row>`         | force a pivot breakdown at row `<row>` for every IC(0) attempt of the next plan build |
/// | `delay:<micros>`          | sleep the dispatcher for `<micros>` µs before the next batch |
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSpec {
    /// `panic:<phase>:<barrier>` — lockstep worker panic at a pool barrier.
    WorkerPanic { phase: FaultPhase, barrier: u64 },
    /// `nan-rhs:<index>` — NaN-poison one entry of a dispatched RHS copy.
    NanRhs { index: usize },
    /// `nan-factor:<index>` — NaN-poison one diagonal entry of a built factor.
    NanFactor { index: usize },
    /// `breakdown:<row>` — force a non-positive pivot at a fixed row.
    PivotBreakdown { row: usize },
    /// `delay:<micros>` — added dispatcher latency before one batch.
    DispatchDelay { micros: u64 },
}

impl fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultSpec::WorkerPanic { phase, barrier } => write!(f, "panic:{phase}:{barrier}"),
            FaultSpec::NanRhs { index } => write!(f, "nan-rhs:{index}"),
            FaultSpec::NanFactor { index } => write!(f, "nan-factor:{index}"),
            FaultSpec::PivotBreakdown { row } => write!(f, "breakdown:{row}"),
            FaultSpec::DispatchDelay { micros } => write!(f, "delay:{micros}"),
        }
    }
}

impl FromStr for FaultSpec {
    type Err = HbmcError;
    fn from_str(s: &str) -> Result<FaultSpec, HbmcError> {
        fn num<T: FromStr>(part: &str, what: &str) -> Result<T, HbmcError> {
            part.parse().map_err(|_| {
                HbmcError::parse(format!("fault spec: '{part}' is not a valid {what}"))
            })
        }
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["panic", phase, barrier] => Ok(FaultSpec::WorkerPanic {
                phase: phase.parse()?,
                barrier: num(barrier, "barrier index")?,
            }),
            ["nan-rhs", index] => Ok(FaultSpec::NanRhs { index: num(index, "index")? }),
            ["nan-factor", index] => Ok(FaultSpec::NanFactor { index: num(index, "index")? }),
            ["breakdown", row] => Ok(FaultSpec::PivotBreakdown { row: num(row, "row")? }),
            ["delay", micros] => Ok(FaultSpec::DispatchDelay { micros: num(micros, "duration (µs)")? }),
            _ => Err(HbmcError::parse(format!(
                "unknown fault spec '{s}' (expected panic:<phase>:<barrier>, nan-rhs:<i>, \
                 nan-factor:<i>, breakdown:<row>, or delay:<micros>)"
            ))),
        }
    }
}

/// A [`FaultSpec`] armed for a bounded number of firings (normally one).
///
/// Worker-side hooks ([`barrier_hook`](FaultInjector::barrier_hook)) only
/// *read* the armed state so that all pool threads act identically; the
/// single-threaded dispatcher consumes the charge via the `take_*` /
/// [`consume_panic`](FaultInjector::consume_panic) methods. Once spent the
/// injector is inert.
#[derive(Debug)]
pub struct FaultInjector {
    spec: FaultSpec,
    /// Firings left; decremented only by the dispatcher-side consumers.
    remaining: AtomicU32,
}

impl FaultInjector {
    /// Arm `spec` for a single firing.
    pub fn new(spec: FaultSpec) -> FaultInjector {
        FaultInjector::with_count(spec, 1)
    }

    /// Arm `spec` for `count` firings (used by chaos tests that want a
    /// fault to outlive one recovery attempt).
    pub fn with_count(spec: FaultSpec, count: u32) -> FaultInjector {
        FaultInjector { spec, remaining: AtomicU32::new(count) }
    }

    /// The configured fault, regardless of remaining charge.
    pub fn spec(&self) -> FaultSpec {
        self.spec
    }

    /// Whether at least one firing is left.
    pub fn armed(&self) -> bool {
        self.remaining.load(Ordering::Relaxed) > 0
    }

    /// Atomically consume one firing; `false` when already spent.
    fn consume(&self) -> bool {
        self.remaining
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |r| r.checked_sub(1))
            .is_ok()
    }

    /// Worker-side hook, called by the pool with the exact in-solve
    /// barrier index (identical on every participating thread). Panics in
    /// lockstep when an armed [`FaultSpec::WorkerPanic`] matches. Does NOT
    /// consume the charge — the dispatcher's recovery path does, via
    /// [`consume_panic`](FaultInjector::consume_panic), before retrying.
    pub fn barrier_hook(&self, index: u64) {
        if let FaultSpec::WorkerPanic { phase, barrier } = self.spec {
            if index == barrier && self.armed() {
                panic!("injected worker panic (panic:{phase}:{barrier})");
            }
        }
    }

    /// Dispatcher-side: disarm a pending worker-panic fault after it
    /// fired, so the retry runs clean. `true` if a charge was consumed.
    pub fn consume_panic(&self) -> bool {
        matches!(self.spec, FaultSpec::WorkerPanic { .. }) && self.consume()
    }

    /// Dispatcher-side: take a pending RHS-poisoning fault.
    pub fn take_nan_rhs(&self) -> Option<usize> {
        match self.spec {
            FaultSpec::NanRhs { index } if self.consume() => Some(index),
            _ => None,
        }
    }

    /// Factorization-side: take a pending factor-poisoning fault
    /// (consumed by `ic0_auto_with` on a successful factorization).
    pub fn take_nan_factor(&self) -> Option<usize> {
        match self.spec {
            FaultSpec::NanFactor { index } if self.consume() => Some(index),
            _ => None,
        }
    }

    /// Factorization-side: take a pending forced pivot breakdown. Consumed
    /// once per plan build (at `ic0_auto_with` entry), and applied to every
    /// shift attempt of that build so the whole build fails typed and the
    /// dispatcher's ladder — not `ic0_auto`'s internal escalation — handles
    /// recovery.
    pub fn take_pivot_breakdown(&self) -> Option<usize> {
        match self.spec {
            FaultSpec::PivotBreakdown { row } if self.consume() => Some(row),
            _ => None,
        }
    }

    /// Dispatcher-side: take a pending dispatch-latency fault.
    pub fn take_dispatch_delay(&self) -> Option<Duration> {
        match self.spec {
            FaultSpec::DispatchDelay { micros } if self.consume() => {
                Some(Duration::from_micros(micros))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_parse_and_round_trip() {
        let cases = [
            ("panic:fwd:2", FaultSpec::WorkerPanic { phase: FaultPhase::Fwd, barrier: 2 }),
            ("panic:any:0", FaultSpec::WorkerPanic { phase: FaultPhase::Any, barrier: 0 }),
            ("nan-rhs:7", FaultSpec::NanRhs { index: 7 }),
            ("nan-factor:5", FaultSpec::NanFactor { index: 5 }),
            ("breakdown:3", FaultSpec::PivotBreakdown { row: 3 }),
            ("delay:500", FaultSpec::DispatchDelay { micros: 500 }),
        ];
        for (text, spec) in cases {
            assert_eq!(text.parse::<FaultSpec>().unwrap(), spec, "{text}");
            assert_eq!(spec.to_string(), text);
        }
    }

    #[test]
    fn malformed_specs_are_typed_parse_errors() {
        for bad in ["", "panic", "panic:fwd", "panic:sideways:1", "nan-rhs:x", "frob:1"] {
            assert!(
                matches!(bad.parse::<FaultSpec>(), Err(HbmcError::Parse(_))),
                "{bad:?} should fail to parse"
            );
        }
    }

    #[test]
    fn charges_are_one_shot() {
        let inj = FaultInjector::new(FaultSpec::PivotBreakdown { row: 3 });
        assert!(inj.armed());
        assert_eq!(inj.take_pivot_breakdown(), Some(3));
        assert!(!inj.armed());
        assert_eq!(inj.take_pivot_breakdown(), None);
        // A mismatched taker never consumes the charge.
        let inj = FaultInjector::new(FaultSpec::NanRhs { index: 0 });
        assert_eq!(inj.take_pivot_breakdown(), None);
        assert!(inj.armed());
        assert_eq!(inj.take_nan_rhs(), Some(0));
    }

    #[test]
    fn barrier_hook_reads_without_consuming() {
        let inj = FaultInjector::new(FaultSpec::WorkerPanic {
            phase: FaultPhase::Fwd,
            barrier: 2,
        });
        inj.barrier_hook(0); // no match, no panic
        inj.barrier_hook(3);
        assert!(inj.armed(), "reads must not consume");
        let fired = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            inj.barrier_hook(2)
        }));
        assert!(fired.is_err(), "matching index must panic");
        assert!(inj.armed(), "the panic itself must not consume");
        assert!(inj.consume_panic());
        inj.barrier_hook(2); // spent: no panic
        assert!(!inj.consume_panic());
    }

    #[test]
    fn multi_count_injector_fires_repeatedly() {
        let inj = FaultInjector::with_count(FaultSpec::WorkerPanic {
            phase: FaultPhase::Any,
            barrier: 0,
        }, 2);
        assert!(inj.consume_panic());
        assert!(inj.armed());
        assert!(inj.consume_panic());
        assert!(!inj.armed());
    }
}
