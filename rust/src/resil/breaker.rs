//! Per-handle circuit breaker: closed → open on a run of consecutive
//! failures, open → half-open after a fixed number of rejected admits,
//! half-open → closed on one successful probe (or back to open on a failed
//! one).
//!
//! The breaker is deliberately *count-based*, not clock-based: opening
//! after `threshold` consecutive failures, cooling down for `threshold`
//! rejected admissions, and probing with exactly one job makes every
//! transition deterministic under test — no sleeps, no wall-clock reads —
//! while still bounding how much work a poisoned matrix can soak up
//! between probes. Success anywhere resets the failure run.
//!
//! One breaker guards one registered `MatrixHandle` (armed by
//! `QueueConfig::breaker_threshold`); an open breaker degrades that handle
//! only, surfacing as a synchronous `HbmcError::CircuitOpen` at `submit`
//! while other handles keep serving.

use std::sync::Mutex;

/// Observable breaker state; also the `hbmc_breaker_state` gauge encoding
/// via [`gauge_value`](BreakerState::gauge_value).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal operation; failures are being counted.
    Closed,
    /// Cooling down: one probe job is admitted, the rest rejected.
    HalfOpen,
    /// Rejecting all submissions for this handle.
    Open,
}

impl BreakerState {
    /// Gauge encoding: 0 = closed, 1 = half-open, 2 = open.
    pub fn gauge_value(self) -> u64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::HalfOpen => 1,
            BreakerState::Open => 2,
        }
    }
}

#[derive(Debug)]
struct Inner {
    state: BreakerState,
    /// Consecutive failures while closed (reset by any success).
    failures: u32,
    /// Rejected admits left before an open breaker relaxes to half-open.
    cooldown: u32,
    /// Whether the half-open probe slot is taken.
    probe_inflight: bool,
}

/// Deterministic count-based circuit breaker; see module docs.
#[derive(Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    inner: Mutex<Inner>,
}

impl CircuitBreaker {
    /// Breaker opening after `threshold` consecutive failures.
    /// `threshold` must be positive (enforced by config validation).
    pub fn new(threshold: u32) -> CircuitBreaker {
        CircuitBreaker {
            threshold: threshold.max(1),
            inner: Mutex::new(Inner {
                state: BreakerState::Closed,
                failures: 0,
                cooldown: 0,
                probe_inflight: false,
            }),
        }
    }

    /// Ask to admit one job. `Err(failures)` rejects the submission (the
    /// caller maps it to `HbmcError::CircuitOpen`); while open, each
    /// rejection also advances the cooldown toward half-open.
    pub fn admit(&self) -> Result<(), u32> {
        let mut g = self.inner.lock().unwrap();
        match g.state {
            BreakerState::Closed => Ok(()),
            BreakerState::Open => {
                g.cooldown = g.cooldown.saturating_sub(1);
                if g.cooldown == 0 {
                    g.state = BreakerState::HalfOpen;
                    g.probe_inflight = false;
                }
                Err(g.failures)
            }
            BreakerState::HalfOpen => {
                if g.probe_inflight {
                    Err(g.failures)
                } else {
                    g.probe_inflight = true;
                    Ok(())
                }
            }
        }
    }

    /// Record a successful job outcome: closes the breaker and resets the
    /// failure run.
    pub fn record_success(&self) {
        let mut g = self.inner.lock().unwrap();
        g.state = BreakerState::Closed;
        g.failures = 0;
        g.probe_inflight = false;
    }

    /// Record a failed job outcome: extends the failure run and opens the
    /// breaker at the threshold (a failed half-open probe re-opens it).
    pub fn record_failure(&self) {
        let mut g = self.inner.lock().unwrap();
        g.failures = g.failures.saturating_add(1);
        match g.state {
            BreakerState::Closed if g.failures >= self.threshold => {
                g.state = BreakerState::Open;
                g.cooldown = self.threshold;
            }
            BreakerState::HalfOpen => {
                g.state = BreakerState::Open;
                g.cooldown = self.threshold;
                g.probe_inflight = false;
            }
            _ => {}
        }
    }

    /// Current state (for the `hbmc_breaker_state` gauge and `/healthz`).
    pub fn state(&self) -> BreakerState {
        self.inner.lock().unwrap().state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opens_at_threshold_consecutive_failures() {
        let b = CircuitBreaker::new(3);
        for _ in 0..2 {
            assert!(b.admit().is_ok());
            b.record_failure();
            assert_eq!(b.state(), BreakerState::Closed);
        }
        assert!(b.admit().is_ok());
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn success_resets_the_failure_run() {
        let b = CircuitBreaker::new(2);
        b.record_failure();
        b.record_success();
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Closed, "run was reset");
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn open_cools_down_to_a_single_probe() {
        let b = CircuitBreaker::new(2);
        b.record_failure();
        b.record_failure();
        // threshold rejected admits while open...
        assert_eq!(b.admit(), Err(2));
        assert_eq!(b.admit(), Err(2));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // ...then exactly one probe is admitted.
        assert!(b.admit().is_ok());
        assert_eq!(b.admit(), Err(2), "second concurrent probe rejected");
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.admit().is_ok());
    }

    #[test]
    fn failed_probe_reopens() {
        let b = CircuitBreaker::new(1);
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(b.admit().is_err()); // cooldown 1 -> half-open
        assert!(b.admit().is_ok()); // probe
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open, "failed probe re-opens");
    }

    #[test]
    fn gauge_encoding_is_stable() {
        assert_eq!(BreakerState::Closed.gauge_value(), 0);
        assert_eq!(BreakerState::HalfOpen.gauge_value(), 1);
        assert_eq!(BreakerState::Open.gauge_value(), 2);
    }
}
