//! Resilience layer: deterministic fault injection and recovery policy.
//!
//! PR 8 gave the service eyes (`obs/`); this module gives it hands. It has
//! two halves, deliberately kept free of any dependency on `config`/`api`
//! so those layers can depend on it without a cycle:
//!
//! * **Recovery policy** — [`RetryPolicy`] (stored on
//!   `SolverConfig::retry`) bounds how many recovery attempts the
//!   dispatcher's fallback ladder in `api/queue.rs` may make per job, and
//!   [`CircuitBreaker`] (armed per registered matrix by
//!   `QueueConfig::breaker_threshold`) stops a persistently failing handle
//!   from degrading the whole service. The ladder itself lives with the
//!   dispatcher; the mapping from typed error to recovery action is:
//!
//!   | failure                                   | recovery action |
//!   |-------------------------------------------|-----------------|
//!   | `BreakdownInFactorization`                | re-plan with the next escalated shift (doubling schedule, see `factor::ic0::escalation_shifts`) |
//!   | `NotConverged` under a colored ordering   | re-plan on `OrderingKind::Level` (identity permutation ⇒ serial-ordering convergence) |
//!   | `BreakdownInIteration`                    | evict the plan and retry on a clean rebuild |
//!   | worker panic                              | evict the plan, drain + rebuild the poisoned `Pool`, retry on a fresh session |
//!
//! * **Fault injection** — [`FaultSpec`] / [`FaultInjector`] deterministically
//!   inject worker panics at a chosen pool barrier, NaN poisoning of RHS or
//!   factor values, forced pivot breakdown at row *k*, and dispatcher
//!   latency. Injection is config-gated (`SolverConfig` carries an
//!   `Option<FaultSpec>`; the CLI additionally requires `--chaos`): with no
//!   injector configured the hot path carries a single null-pointer check
//!   per pool barrier and nothing inside the kernels, so the fused loop's
//!   dispatch/barrier counts and bitwise outputs are unchanged. Faults are
//!   one-shot and pinned to explicit sites (barrier index, row, vector
//!   index), so every chaos run is reproducible without a PRNG.
//!
//! Recovery actions are observable: the dispatcher emits
//! `hbmc_retries_total{cause=}`, `hbmc_pool_rebuilds_total`, and the
//! `hbmc_breaker_state` gauge (0 = closed, 1 = half-open, 2 = open), plus
//! `retried` trace events, and `/healthz` folds breaker + shed state into
//! its `ok`/`degraded`/`unhealthy` answer.

pub mod breaker;
pub mod inject;

pub use breaker::{BreakerState, CircuitBreaker};
pub use inject::{FaultInjector, FaultPhase, FaultSpec};

/// Bounded recovery policy for the dispatcher's fallback ladder; stored on
/// `SolverConfig::retry` and consulted per job.
///
/// `max_retries` is the number of *recovery* attempts after the first
/// failed solve — `0` (the default) fails fast exactly as before this
/// policy existed. Every retry re-checks the job's deadline first: a job
/// whose budget is already spent fails with `DeadlineExceeded` rather than
/// burning dispatcher time on a doomed attempt, so each attempt runs on
/// whatever remains of the original budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Maximum recovery attempts after the first failure (0 = fail fast).
    pub max_retries: u32,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_retries: 0 }
    }
}

impl RetryPolicy {
    /// Policy allowing `n` recovery attempts.
    pub fn retries(n: u32) -> RetryPolicy {
        RetryPolicy { max_retries: n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_policy_defaults_to_fail_fast() {
        assert_eq!(RetryPolicy::default().max_retries, 0);
        assert_eq!(RetryPolicy::retries(3).max_retries, 3);
        assert_eq!(RetryPolicy::retries(3), RetryPolicy { max_retries: 3 });
    }
}
