//! `hbmc` — launcher for the HBMC ICCG framework.
//!
//! Commands:
//!
//! * `solve`        — build one `SolverPlan`, open a `SolveSession`, run
//!   one or `--repeat N` solves (setup reported once, per-solve metrics
//!   per run); `--setup-only` stops after the plan; `--batch N` submits N
//!   jobs through the async queue instead (micro-batched dispatch)
//! * `serve`        — async serving stress: M client threads × K submits,
//!   prints throughput, batching and admission statistics; with
//!   `--metrics-addr` also serves Prometheus `/metrics` + `/healthz` over
//!   HTTP, `--trace N` samples every Nth job into the lifecycle trace ring
//! * `stats`        — pretty-print `ServiceStats` + histogram snapshot for
//!   a small workload, or scrape a running `--metrics-addr` endpoint
//! * `table`        — regenerate a paper table (5.2 / 5.3 / simd / sell)
//! * `convergence`  — Fig. 5.1 residual curves as CSV
//! * `verify`       — ordering-equivalence + structural invariant checks
//! * `demo-runtime` — load and run the AOT PJRT artifacts
//! * `info`         — dataset statistics
//! * `help`

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use hbmc::api::{SolveRequest, SolverService};
use hbmc::cli::Args;
use hbmc::config::{NodePreset, OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::driver::SolveOptions;
use hbmc::coordinator::experiments;
use hbmc::gen::suite;
use hbmc::tune::{
    tune_matrix, ConfigSpace, HardwareSignature, ProfileStore, TuneOptions, TuneStrategy,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match Args::parse(args).and_then(run) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn cfg_from(args: &Args, shift: f64) -> Result<SolverConfig> {
    let mut builder = SolverConfig::builder()
        .ordering(args.flag_or("ordering", "hbmc").parse::<OrderingKind>()?)
        .bs(args.usize_flag("bs", 32)?)
        .w(args.usize_flag("w", 8)?)
        .spmv(args.flag_or("spmv", "sell").parse::<SpmvKind>()?)
        .threads(args.usize_flag("threads", 1)?)
        .rtol(args.f64_flag("rtol", 1e-7)?)
        .max_iters(args.usize_flag("max-iters", 50_000)?)
        .shift(args.f64_flag("shift", shift)?)
        .use_intrinsics(!args.switch("no-intrinsics"))
        .max_batch(args.usize_flag("max-batch", 32)?)
        .max_wait(Duration::from_micros(args.usize_flag("max-wait-us", 200)? as u64))
        .trace_sample(args.usize_flag("trace", 0)?)
        .max_retries(args.usize_flag("retry", 0)? as u32);
    if let Some(v) = args.flag("max-depth") {
        builder = builder.max_queue_depth(Some(v.parse()?));
    }
    if let Some(v) = args.flag("max-inflight") {
        builder = builder.max_inflight_per_handle(Some(v.parse()?));
    }
    if let Some(v) = args.flag("sell-sigma") {
        builder = builder.sell_sigma(Some(v.parse()?));
    }
    if let Some(node) = args.flag("node") {
        builder = builder.preset(node.parse::<NodePreset>()?);
    }
    if let Some(v) = args.flag("breaker-threshold") {
        builder = builder.breaker_threshold(Some(v.parse()?));
    }
    // Fault injection is double-keyed: `--inject <spec>` names the fault,
    // but is refused unless `--chaos` is also passed — a copy-pasted spec
    // must not arm the injector by accident.
    if let Some(spec) = args.flag("inject") {
        if !args.switch("chaos") {
            bail!("--inject requires --chaos: fault injection must be armed explicitly");
        }
        builder = builder.fault(Some(spec.parse::<hbmc::resil::FaultSpec>()?));
    }
    Ok(builder.build()?)
}

fn run(args: Args) -> Result<()> {
    match args.command.as_str() {
        "solve" => cmd_solve(&args),
        "tune" => cmd_tune(&args),
        "serve" => cmd_serve(&args),
        "stats" => cmd_stats(&args),
        "table" => cmd_table(&args),
        "convergence" => cmd_convergence(&args),
        "verify" => cmd_verify(&args),
        "demo-runtime" => cmd_demo_runtime(),
        "run-hlo" => cmd_run_hlo(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}; see `hbmc help`"),
    }
}

const HELP: &str = "\
hbmc — Hierarchical Block Multi-Color Ordering ICCG framework

USAGE: hbmc <command> [flags]

COMMANDS
  solve        --dataset <name> [--scale tiny|small|full]
               [--ordering natural|mc|bmc|hbmc|level]
               [--bs N] [--w N] [--spmv crs|sell|symmcsr] [--threads N] [--rtol X]
               [--shift X] [--node knl|bdw|skx] [--history] [--no-intrinsics]
               [--mtx <file.mtx>]            (solve a MatrixMarket file instead of a
                                              generated dataset; with --spmv symmcsr the
                                              stored lower triangle is read directly)
               [--repeat N] [--setup-only]   (plan built once, N solves on one session)
               [--profile]                   (in-region flight recorder: per-phase busy
                                              table, barrier-wait imbalance, coverage)
               [--trace-out <file.json>]     (write the last solve's spans as a
                                              chrome://tracing JSON; implies --profile)
               [--batch N]                   (submit N async jobs, micro-batched dispatch)
               [--auto] [--store <path>]     (apply the stored tuned profile for this
                                              matrix + machine, if one exists)
               [--retry N]                   (recovery-ladder budget: re-plan after
                                              breakdowns, rebuild the pool after
                                              worker panics, up to N times)
               [--chaos --inject <spec>]     (arm one deterministic fault, e.g.
                                              panic:fwd:2, breakdown:0, nan-rhs:3,
                                              nan-factor:0, delay:500; --inject is
                                              refused without --chaos)
  tune         --dataset <name> [--scale S] [--store <path>] [--trials N] [--warmup N]
               [--reuse X] [--strategy auto|exhaustive|racing] [--max-candidates N]
               [--quick] [--explain]
               (search ordering/bs/w/spmv/threads for this matrix on this
                machine, persist the winner; --quick = CI-sized space and
                a BENCH_tune.json perf artifact; --explain prints the
                winner's kernel-phase attribution)
  serve        --dataset <name> [--scale S] [--clients M] [--requests K]
               [--max-batch B] [--max-wait-us U] [--deadline-ms D]
               (async stress: M client threads submit K jobs each; prints
                throughput + batching + admission stats)
               [--max-depth N] [--max-inflight N]
                                             (admission bounds: excess submits fail
                                              fast with HbmcError::Overloaded)
               [--breaker-threshold N]       (per-matrix circuit breaker: N consecutive
                                              solver failures open the breaker and
                                              submits fail fast with CircuitOpen;
                                              /healthz reports degraded/unhealthy)
               [--retry N]                   (recovery-ladder budget per job)
               [--metrics-addr H:P]          (serve Prometheus /metrics + /healthz)
               [--trace N]                   (sample every Nth job into the trace
                                              ring; dumped as JSON after the run)
               [--linger-secs T]             (keep the metrics endpoint up T extra
                                              seconds after the run, for scrapes)
  stats        [--from H:P]                  (scrape a running serve endpoint and
                                              print the raw Prometheus text)
               [--dataset <name>] [--scale S] [--requests K]
               (without --from: run K async jobs through a fresh service
                and pretty-print ServiceStats + histogram quantiles)
  table        --id 5.2|5.3|simd|sell [--node knl|bdw|skx] [--scale S] [--threads N]
  convergence  [--datasets a,b] [--scale S] [--out curves.csv]
  verify       [--scale S]          run ordering/equivalence invariants
  demo-runtime                      load + run AOT PJRT artifacts
  info         --dataset <name> [--scale S]
  help

DATASETS: thermal2, parabolic_fem, g3_circuit, audikw_1, ieej
";

fn cmd_solve(args: &Args) -> Result<()> {
    let scale: Scale = args.flag_or("scale", "small").parse()?;
    let name = args.flag_or("dataset", "g3_circuit");
    let repeat = args.usize_flag("repeat", 1)?.max(1);
    // `--mtx` loads a MatrixMarket file instead of a generated dataset.
    // For symmetric-SpMV plans we keep the stored lower triangle and
    // mirror it ourselves: deduplicating in lower form makes the two
    // halves bitwise-identical, which the engine's symmetry check needs.
    let d = match args.flag("mtx") {
        Some(path) => {
            use hbmc::sparse::matrix_market as mm;
            let spmv: SpmvKind = args.flag_or("spmv", "sell").parse()?;
            let p = std::path::Path::new(path);
            let matrix = if spmv == SpmvKind::SymmCsr {
                mm::expand_lower(&mm::read_lower(p)?)?
            } else {
                mm::read(p)?
            };
            hbmc::gen::Dataset::with_unit_solution(path, matrix, args.f64_flag("shift", 0.0)?)
        }
        None => suite::try_dataset(&name, scale)?,
    };
    let mut cfg = cfg_from(args, d.shift)?;
    println!(
        "dataset={} n={} nnz={} ({:.1}/row) scale={scale}",
        d.name,
        d.n(),
        d.nnz(),
        d.nnz_per_row(),
    );

    // --auto: overlay the stored tuned profile for (matrix, machine), if
    // one exists; otherwise run the flags as given and say so.
    if args.switch("auto") {
        let store_path = args
            .flag("store")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(ProfileStore::default_path);
        let store = ProfileStore::open(&store_path)?;
        match store.lookup(&d.matrix) {
            Some(profile) => {
                cfg = profile.apply_to(&cfg);
                println!(
                    "auto: applying tuned profile {} from {} ({:.2}x vs default when tuned)",
                    profile.label(),
                    store_path.display(),
                    profile.speedup()
                );
            }
            None => println!(
                "auto: no profile for this matrix on {} in {} (run `hbmc tune` first); \
                 using the given flags",
                HardwareSignature::detect(),
                store_path.display()
            ),
        }
    }

    // The typed façade: one service, one registered matrix, one session.
    // Phase 1 (plan build) happens inside `session`; phase 2 below.
    let service = SolverService::with_config(cfg.clone())?;
    let handle = service.register_matrix(d.matrix);

    // Resilience path: with `--retry` or an armed `--chaos --inject` fault,
    // route through the async queue so the dispatcher's recovery ladder
    // owns the attempt. A direct session here would consume a one-shot
    // fault during plan warm-up (pivot breakdowns fire at factorization)
    // and an injected worker panic would escape straight to main.
    if cfg.retry.max_retries > 0 || cfg.fault.is_some() {
        let out = service.submit(handle, &d.b, &SolveRequest::new())?.wait()?;
        let rep = &out.report;
        println!(
            "solve: iters={} converged={} relres={:.3e} retries={} time={:.3}s",
            rep.iterations, rep.converged, rep.final_relres, rep.retries, rep.solve_seconds
        );
        for a in &rep.attempts {
            println!("  recovered[{}]: {}", a.cause, a.action);
        }
        let err = out.x.iter().map(|x| (x - 1.0).abs()).fold(0.0, f64::max);
        println!("max |x - 1| = {err:.3e} (rhs was A·1)");
        return Ok(());
    }

    let session = service.session(handle, &cfg)?;
    let plan = session.plan();
    println!(
        "config={} threads={} kernel={} trisolver={}",
        cfg.label(),
        cfg.threads,
        plan.setup.kernel_path,
        plan.trisolver.name()
    );
    println!(
        "setup: ordering {:.3}s factor {:.3}s storage {:.3}s colors={} n_aug={} shift={}",
        plan.setup.ordering_seconds,
        plan.setup.factor_seconds,
        plan.setup.storage_seconds,
        plan.setup.num_colors,
        plan.setup.n_aug,
        plan.setup.shift_used
    );
    println!(
        "simd_ratio={:.1}% syncs/substitution={} sell_overhead={}",
        100.0 * plan.ops.simd_ratio(),
        plan.trisolver.syncs_per_sweep(),
        plan.sell_overhead()
            .map(|o| format!("{:.1}%", 100.0 * (o - 1.0)))
            .unwrap_or("n/a".into())
    );
    if let Some(s) = &plan.schedule {
        println!(
            "schedule: {} levels -> {} stages ({} serial segment(s), {} rows serialized; \
             max level {} rows; sweep cost barrier {:.0} / coarsened {:.0} / spin {:.0})",
            s.levels,
            s.coarsened_stages,
            s.serial_segments,
            s.serialized_rows,
            s.max_level_rows,
            s.barrier_sweep_cost,
            s.coarsened_sweep_cost,
            s.spin_sweep_cost
        );
    }
    if args.switch("setup-only") {
        return Ok(());
    }

    // Async path: `--batch N` submits N jobs through the job queue and lets
    // the dispatcher micro-batch them (all share this plan's key).
    let batch = args.usize_flag("batch", 0)?;
    if batch > 0 {
        let req = SolveRequest::new();
        let t0 = Instant::now();
        let jobs = (0..batch)
            .map(|k| {
                let rhs: Vec<f64> = d.b.iter().map(|v| v * (1.0 + k as f64)).collect();
                service.submit(handle, &rhs, &req)
            })
            .collect::<std::result::Result<Vec<_>, hbmc::api::HbmcError>>()?;
        for (k, job) in jobs.into_iter().enumerate() {
            let out = job.wait()?;
            println!(
                "job[{k}]: iters={} converged={} relres={:.3e} time={:.3}s",
                out.report.iterations,
                out.report.converged,
                out.report.final_relres,
                out.report.solve_seconds
            );
        }
        let wall = t0.elapsed().as_secs_f64();
        let st = service.stats();
        println!(
            "batching: {} solves in {} dispatched batches (mean width {:.2}), \
             {} coalesced rhs, {wall:.3}s wall",
            st.solves,
            st.batches,
            st.mean_batch_width(),
            st.coalesced_rhs
        );
        return Ok(());
    }

    // Phase 2: N solves against the same plan. `--trace-out` implies
    // profiling — a chrome trace needs the recorded spans.
    let opts = SolveOptions {
        record_history: args.switch("history"),
        profile: args.switch("profile") || args.flag("trace-out").is_some(),
        ..Default::default()
    };
    let mut total_solve = 0.0;
    let mut last: Option<hbmc::coordinator::session::SolveOutput> = None;
    for k in 0..repeat {
        let out = session.solve_with(&d.b, &opts)?;
        let rep = &out.report;
        println!(
            "solve[{k}]: iters={} converged={} relres={:.3e} time={:.3}s",
            rep.iterations, rep.converged, rep.final_relres, rep.solve_seconds
        );
        total_solve += rep.solve_seconds;
        last = Some(out);
    }
    let out = last.expect("repeat >= 1");
    for (k, s) in &out.report.kernel_seconds {
        println!("  {k:<10} {s:.3}s");
    }
    // `--profile`: the flight recorder's view of the last solve — per-phase
    // busy totals summed across threads, plus the recorder's own health
    // numbers (coverage of thread-time accounted for, barrier imbalance).
    if let Some(profile) = &out.report.profile {
        let totals = profile.phase_totals();
        let busy: f64 = totals.iter().sum();
        println!(
            "profile: {} thread(s), coverage {:.1}% of thread-time, \
             barrier-wait imbalance {:.2}",
            profile.threads(),
            100.0 * profile.coverage(),
            profile.barrier_wait_imbalance()
        );
        for (name, seconds) in hbmc::obs::PHASE_NAMES.iter().zip(&totals) {
            let share = if busy > 0.0 { 100.0 * seconds / busy } else { 0.0 };
            println!("  {name:<13} {seconds:>10.6}s  {share:>5.1}%");
        }
        if profile.dropped() > 0 {
            println!("  ({} span(s) dropped; aggregates stay exact)", profile.dropped());
        }
        if let Some(path) = args.flag("trace-out") {
            std::fs::write(path, hbmc::obs::chrome_trace_json(profile))
                .with_context(|| format!("writing {path}"))?;
            println!("wrote chrome trace to {path} (open in chrome://tracing or Perfetto)");
        }
    }
    if args.switch("history") {
        for (i, r) in out.report.residual_history.iter().enumerate() {
            println!("iter {:>5}  relres {:.6e}", i + 1, r);
        }
    }
    if repeat > 1 {
        let setup = plan.setup.setup_seconds();
        println!(
            "amortization: setup {:.3}s once + {repeat} solves {:.3}s total \
             ({:.3}s/solve; setup share {:.1}%)",
            setup,
            total_solve,
            total_solve / repeat as f64,
            100.0 * setup / (setup + total_solve)
        );
    }
    let err = out.x.iter().map(|x| (x - 1.0).abs()).fold(0.0, f64::max);
    println!("max |x - 1| = {err:.3e} (rhs was A·1)");
    Ok(())
}

/// Search the valid configuration space for one suite matrix on this
/// machine, print the scoreboard, persist the winner to the profile store,
/// and verify end-to-end that a fresh service auto-applies it. `--quick`
/// shrinks the space to CI size and writes the `BENCH_tune.json`
/// perf-trajectory artifact.
fn cmd_tune(args: &Args) -> Result<()> {
    // Same default scale as `solve`: the documented tune-then-solve-auto
    // flow must key both commands to the same matrix fingerprint.
    let scale: Scale = args.flag_or("scale", "small").parse()?;
    let name = args.flag_or("dataset", "g3_circuit");
    let quick = args.switch("quick");
    let d = suite::try_dataset(&name, scale)?;
    let cfg = cfg_from(args, d.shift)?;
    let hw = HardwareSignature::detect();

    let mut opts = if quick { TuneOptions::quick() } else { TuneOptions::default() };
    opts.trials = args.usize_flag("trials", opts.trials)?;
    opts.warmup = args.usize_flag("warmup", opts.warmup)?;
    opts.expected_reuse = args.f64_flag("reuse", opts.expected_reuse)?;
    opts.max_candidates = args.usize_flag("max-candidates", opts.max_candidates)?;
    if let Some(s) = args.flag("strategy") {
        opts.strategy = s.parse::<TuneStrategy>()?;
    }
    if opts.space.is_none() {
        opts.space = Some(ConfigSpace::for_hardware(&hw));
    }
    println!(
        "tune: dataset={} n={} nnz={} scale={scale} hardware={hw} strategy={} \
         trials={} reuse={}",
        d.name,
        d.n(),
        d.nnz(),
        opts.strategy,
        opts.trials,
        opts.expected_reuse
    );

    let t0 = Instant::now();
    let out = tune_matrix(&d.matrix, &d.b, &cfg, &opts)?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "searched {} candidates in {wall:.2}s ({} abandoned early, {} failed{}); \
         finalists:",
        out.candidates,
        out.abandoned,
        out.failed,
        if out.truncated > 0 {
            format!(", {} beyond --max-candidates NOT searched", out.truncated)
        } else {
            String::new()
        }
    );
    for m in &out.finalists {
        let is_default = m.cfg.label() == out.baseline.cfg.label()
            && m.cfg.threads == out.baseline.cfg.threads;
        println!(
            "  {:<28} solve {:.6}s  setup {:.3}s  iters {:<5} score {:.6}s{}",
            m.label(),
            m.solve_seconds,
            m.setup_seconds,
            m.iterations,
            m.score(opts.expected_reuse),
            if is_default { "  <- default" } else { "" }
        );
    }
    let p = &out.profile;
    println!(
        "winner: {}  ({:.6}s/solve vs default {:.6}s/solve = {:.2}x)",
        p.label(),
        p.solve_seconds,
        p.baseline_solve_seconds,
        p.speedup()
    );
    // `--explain`: where the winner spends its time, from the one profiled
    // attribution solve the measurement harness ran on each finalist.
    if args.switch("explain") {
        match &p.phase_shares {
            Some(shares) => {
                println!("explain: winner phase attribution (one profiled solve):");
                for (name, share) in hbmc::obs::PHASE_NAMES.iter().zip(shares) {
                    println!("  {name:<13} {:>5.1}%", 100.0 * share);
                }
            }
            None => println!("explain: no phase attribution recorded for the winner"),
        }
    }

    // Persist + end-to-end check: a fresh service attached to the store
    // must auto-apply the profile on a default-config solve.
    let store_path = args
        .flag("store")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(ProfileStore::default_path);
    let mut store = ProfileStore::open(&store_path)?;
    store.put(p.clone());
    store.save()?;
    println!("stored profile in {}", store_path.display());
    let service = SolverService::with_config(cfg.clone())?;
    let installed = service.attach_profile_store(&store_path)?;
    let handle = service.register_matrix(d.matrix.clone());
    let check = service.solve(handle, &d.b)?;
    let st = service.stats();
    println!(
        "auto-apply check: {installed} profile(s) loaded, solve ran {} in {:.6}s, \
         profile_hits={}",
        check.report.plan.config_label, check.report.solve_seconds, st.profile_hits
    );

    if quick {
        let path = hbmc::util::bench_artifact_path("BENCH_tune.json");
        let json = format!(
            "{{\n  \"bench\": \"tune-quick\",\n  \
             \"provenance\": \"measured: tune quick bench\",\n  \
             \"dataset\": \"{}\",\n  \"hardware\": \"{hw}\",\n  \
             \"candidates\": {},\n  \"default_config\": \"{}\",\n  \
             \"default_solve_seconds\": {:.6e},\n  \"tuned_config\": \"{}\",\n  \
             \"tuned_solve_seconds\": {:.6e},\n  \"speedup\": {:.4},\n  \
             \"tuned_iterations\": {},\n  \"profile_hits_after_reload\": {}\n}}\n",
            d.name,
            out.candidates,
            out.baseline.cfg.label(),
            p.baseline_solve_seconds,
            p.label(),
            p.solve_seconds,
            p.speedup(),
            p.iterations,
            st.profile_hits,
        );
        std::fs::write(&path, &json)
            .with_context(|| format!("writing {}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// Async serving stress: M client threads submit K single-RHS jobs each
/// against one registered matrix; the dispatcher coalesces compatible jobs
/// into micro-batches. Prints throughput, batching and admission
/// statistics; `--metrics-addr` additionally serves Prometheus `/metrics`
/// and `/healthz` over HTTP for the duration of the run (plus
/// `--linger-secs` afterwards, so external scrapers can catch it).
fn cmd_serve(args: &Args) -> Result<()> {
    let scale: Scale = args.flag_or("scale", "tiny").parse()?;
    let name = args.flag_or("dataset", "g3_circuit");
    let clients = args.usize_flag("clients", 4)?.max(1);
    let requests = args.usize_flag("requests", 8)?.max(1);
    let deadline_ms = args.usize_flag("deadline-ms", 0)?;
    let trace_every = args.usize_flag("trace", 0)?;
    let linger_secs = args.usize_flag("linger-secs", 0)?;
    let d = suite::try_dataset(&name, scale)?;
    let cfg = cfg_from(args, d.shift)?;
    println!(
        "serve: dataset={} n={} nnz={} scale={scale} config={} \
         clients={clients} requests/client={requests} max_batch={} max_wait={:?} \
         max_depth={:?} max_inflight={:?}",
        d.name,
        d.n(),
        d.nnz(),
        cfg.label(),
        cfg.queue.max_batch,
        cfg.queue.max_wait,
        cfg.queue.max_queue_depth,
        cfg.queue.max_inflight_per_handle
    );
    let service = Arc::new(SolverService::with_config(cfg)?);
    let _metrics = match args.flag("metrics-addr") {
        Some(addr) => {
            let svc = Arc::clone(&service);
            let probe = Arc::clone(&service);
            let server = hbmc::obs::MetricsServer::spawn_with_health(
                addr,
                move || svc.metrics_text(),
                move || probe.health(),
            )?;
            println!("metrics: http://{}/metrics (and /healthz)", server.local_addr());
            Some(server)
        }
        None => None,
    };
    let handle = service.register_matrix(d.matrix);
    // Warm the plan once so the stress run measures serving, not setup.
    service.solve(handle, &d.b)?;

    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let service = Arc::clone(&service);
            let b = d.b.clone();
            std::thread::spawn(move || -> (usize, usize, usize, usize) {
                let (mut ok, mut missed, mut rejected, mut completed) =
                    (0usize, 0usize, 0usize, 0usize);
                for k in 0..requests {
                    let f = 1.0 + ((c * requests + k) % 7) as f64;
                    let rhs: Vec<f64> = b.iter().map(|v| v * f).collect();
                    let mut req = SolveRequest::new();
                    if deadline_ms > 0 {
                        req = req.deadline(Duration::from_millis(deadline_ms as u64));
                    }
                    match service.submit(handle, &rhs, &req).and_then(|job| job.wait()) {
                        Ok(out) => {
                            completed += 1;
                            if out.report.converged {
                                ok += 1;
                            }
                        }
                        Err(hbmc::api::HbmcError::DeadlineExceeded { .. }) => missed += 1,
                        Err(hbmc::api::HbmcError::Overloaded { .. }) => rejected += 1,
                        Err(e) => eprintln!("client {c} request {k}: {e}"),
                    }
                }
                (ok, missed, rejected, completed)
            })
        })
        .collect();
    let (mut ok, mut missed, mut rejected, mut completed) = (0usize, 0usize, 0usize, 0usize);
    for t in workers {
        let (o, m, r, s) = t.join().expect("client thread panicked");
        ok += o;
        missed += m;
        rejected += r;
        completed += s;
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = service.stats();
    let total = clients * requests;
    // Throughput counts only requests that actually ran a solve —
    // deadline-missed, overloaded-rejected and errored requests never
    // reached the solver.
    println!(
        "served {ok}/{total} converged, {completed} completed ({missed} deadline-missed, \
         {rejected} overloaded) in {wall:.3}s ({:.1} solves/s)",
        completed as f64 / wall
    );
    println!(
        "batching: {} dispatched batches, mean width {:.2}, {} of {} rhs coalesced \
         (plan builds={}, cache hits={})",
        st.batches,
        st.mean_batch_width(),
        st.coalesced_rhs,
        st.batched_rhs,
        st.builds,
        st.cache.hits
    );
    println!(
        "admission: {} overloaded rejections, {} shed at dispatch, queue depth now {}",
        st.overloaded, st.shed, st.queue_depth
    );
    if trace_every > 0 {
        println!("trace (every {trace_every}th job):");
        println!("{}", service.trace_json());
    }
    if linger_secs > 0 {
        println!("lingering {linger_secs}s for metric scrapes...");
        std::thread::sleep(Duration::from_secs(linger_secs as u64));
    }
    Ok(())
}

/// Pretty-print service statistics. With `--from H:P`, scrape a running
/// `hbmc serve --metrics-addr` endpoint and print the raw Prometheus text
/// it exports; otherwise run a small async workload through a fresh
/// service and print its [`SolverService::stats_text`] snapshot — the
/// human-readable view of the same counters and histograms.
fn cmd_stats(args: &Args) -> Result<()> {
    if let Some(addr) = args.flag("from") {
        let body = hbmc::obs::http_get(addr, "/metrics")?;
        print!("{body}");
        return Ok(());
    }
    let scale: Scale = args.flag_or("scale", "tiny").parse()?;
    let name = args.flag_or("dataset", "g3_circuit");
    let requests = args.usize_flag("requests", 4)?.max(1);
    let d = suite::try_dataset(&name, scale)?;
    let cfg = cfg_from(args, d.shift)?;
    let service = SolverService::with_config(cfg)?;
    let handle = service.register_matrix(d.matrix);
    let jobs = (0..requests)
        .map(|k| {
            let rhs: Vec<f64> = d.b.iter().map(|v| v * (1.0 + k as f64)).collect();
            service.submit(handle, &rhs, &SolveRequest::new())
        })
        .collect::<std::result::Result<Vec<_>, hbmc::api::HbmcError>>()?;
    for job in jobs {
        job.wait()?;
    }
    println!("{}", service.stats_text());
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let scale: Scale = args.flag_or("scale", "small").parse()?;
    let threads = args.usize_flag("threads", 1)?;
    match args.flag_or("id", "5.2").as_str() {
        "5.2" => {
            let (t, _) = experiments::table_5_2(scale, threads)?;
            print!("{}", t.render());
        }
        "5.3" => {
            let node: NodePreset = args.flag_or("node", "skx").parse()?;
            let (t, _) = experiments::table_5_3(node, scale, threads)?;
            print!("{}", t.render());
        }
        "simd" => print!("{}", experiments::simd_ratio_stat(scale, threads)?.render()),
        "sell" => print!("{}", experiments::sell_overhead_stat(scale)?.render()),
        other => bail!("unknown table id {other:?} (5.2|5.3|simd|sell)"),
    }
    Ok(())
}

fn cmd_convergence(args: &Args) -> Result<()> {
    let scale: Scale = args.flag_or("scale", "small").parse()?;
    let list = args.flag_or("datasets", "g3_circuit,ieej");
    let names: Vec<&str> = list.split(',').collect();
    let curves = experiments::fig_5_1(&names, scale, args.usize_flag("threads", 1)?)?;
    let mut csv = String::from("dataset,iteration,bmc_relres,hbmc_relres\n");
    for (name, bmc, hbmc) in &curves {
        for (i, (rb, rh)) in bmc.iter().zip(hbmc).enumerate() {
            csv.push_str(&format!("{name},{},{rb:.9e},{rh:.9e}\n", i + 1));
        }
    }
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, &csv)?;
            println!("wrote {path}");
        }
        None => print!("{csv}"),
    }
    for (name, bmc, hbmc) in &curves {
        let max_dev = bmc
            .iter()
            .zip(hbmc)
            .map(|(a, b)| (a - b).abs() / a.max(*b).max(1e-300))
            .fold(0.0, f64::max);
        println!("# {name}: curves overlap to max relative deviation {max_dev:.2e}");
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    use hbmc::ordering::graph::{er_condition_holds, orderings_equivalent};
    use hbmc::ordering::hbmc::{check_level2_diagonal, hbmc_order};
    let scale: Scale = args.flag_or("scale", "tiny").parse()?;
    let mut failures = 0;
    for d in suite::all(scale) {
        for (bs, w) in [(8usize, 4usize), (32, 8)] {
            let ord = hbmc_order(&d.matrix, bs, w);
            let b = d.matrix.permute_sym(&ord.perm);
            let equiv = orderings_equivalent(&d.matrix, &ord.bmc.perm, &ord.perm);
            let lvl2 = check_level2_diagonal(&b, &ord).is_none();
            let er = er_condition_holds(&b, &hbmc::ordering::perm::Perm::identity(b.n()));
            let ok = equiv && lvl2 && er;
            if !ok {
                failures += 1;
            }
            println!(
                "{:<14} bs={bs:<2} w={w}: equivalence={equiv} level2-diagonal={lvl2} -> {}",
                d.name,
                if ok { "OK" } else { "FAIL" }
            );
        }
    }
    if failures > 0 {
        bail!("{failures} invariant check(s) failed");
    }
    println!("all invariants hold");
    Ok(())
}

fn cmd_demo_runtime() -> Result<()> {
    use hbmc::runtime::artifacts::ArtifactSet;
    use hbmc::runtime::hybrid::HybridPrecond;
    use hbmc::runtime::pjrt::PjrtRuntime;
    let arts = ArtifactSet::locate()?;
    let meta = arts.meta()?;
    println!(
        "artifacts at {} (canonical problem n_aug={} bs={} w={} colors={})",
        arts.dir.display(),
        meta.usize("n_aug")?,
        meta.usize("bs")?,
        meta.usize("w")?,
        meta.usize("num_colors")?
    );
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let pre = HybridPrecond::load(&rt, &arts)?;
    let golden = arts.golden()?;
    let r = golden.f64_vec("precond_r")?;
    let z_expect = golden.f64_vec("precond_z")?;
    let z = pre.apply(&r)?;
    let err = hbmc::util::max_abs_diff(&z, &z_expect);
    println!("precond_hbmc: |z - golden| = {err:.3e}");
    anyhow::ensure!(err < 1e-10, "PJRT output deviates from golden");
    println!("demo-runtime OK");
    Ok(())
}

/// Developer tool: run an HLO-text artifact with a single `f64[n]` input
/// (ramp 0,1,2,…) and print the outputs' head — for debugging artifacts.
fn cmd_run_hlo(args: &Args) -> Result<()> {
    use hbmc::runtime::pjrt::{Arg, PjrtRuntime};
    let path = args.flag("file").context("--file required")?;
    let n = args.usize_flag("n", 8)?;
    let outs = args.usize_flag("outputs", 1)?;
    let rt = PjrtRuntime::cpu()?;
    let exe = rt.load_hlo_text(std::path::Path::new(path), outs)?;
    let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
    let result = exe.run_f64(&[Arg::f64(&x)])?;
    for (i, leaf) in result.iter().enumerate() {
        let head: Vec<f64> = leaf.iter().take(8).copied().collect();
        println!("output[{i}] len={} head={head:?}", leaf.len());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let scale: Scale = args.flag_or("scale", "small").parse()?;
    let name = args.flag_or("dataset", "g3_circuit");
    let d = suite::try_dataset(&name, scale)?;
    println!("dataset      {}", d.name);
    println!("dimension    {}", d.n());
    println!("nnz          {} ({:.1}/row, max {})", d.nnz(), d.nnz_per_row(), d.matrix.max_row_len());
    println!("symmetric    {}", d.matrix.is_symmetric(1e-9));
    println!("shift        {}", d.shift);
    let adj = hbmc::ordering::graph::Adjacency::from_csr(&d.matrix);
    println!("max degree   {}", adj.max_degree());
    Ok(())
}
