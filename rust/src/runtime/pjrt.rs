//! Thin wrapper over the `xla` crate: client construction, HLO-text
//! loading, compilation and execution with `f64`/`i32` buffers.

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT CPU client plus compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// One compiled executable (an AOT-lowered JAX function).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Number of leaves in the result tuple.
    pub num_outputs: usize,
}

/// Argument buffer for execution.
pub enum Arg {
    F64(Vec<f64>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl Arg {
    pub fn f64(data: &[f64]) -> Arg {
        Arg::F64(data.to_vec(), vec![data.len() as i64])
    }

    pub fn f64_shaped(data: &[f64], shape: &[i64]) -> Arg {
        assert_eq!(shape.iter().product::<i64>() as usize, data.len());
        Arg::F64(data.to_vec(), shape.to_vec())
    }

    pub fn i32(data: &[i32]) -> Arg {
        Arg::I32(data.to_vec(), vec![data.len() as i64])
    }

    pub fn i32_shaped(data: &[i32], shape: &[i64]) -> Arg {
        assert_eq!(shape.iter().product::<i64>() as usize, data.len());
        Arg::I32(data.to_vec(), shape.to_vec())
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Arg::F64(data, shape) => xla::Literal::vec1(data).reshape(shape)?,
            Arg::I32(data, shape) => xla::Literal::vec1(data).reshape(shape)?,
        })
    }
}

impl PjrtRuntime {
    /// Construct the CPU client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path, num_outputs: usize) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, num_outputs })
    }
}

impl Executable {
    /// Execute with the given arguments; returns each output leaf as a
    /// flat `f64` vector. The python side lowers with `return_tuple=True`,
    /// so the single device result is a tuple of `num_outputs` leaves.
    pub fn run_f64(&self, args: &[Arg]) -> Result<Vec<Vec<f64>>> {
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let leaves = result.to_tuple()?;
        anyhow::ensure!(
            leaves.len() == self.num_outputs,
            "expected {} outputs, got {}",
            self.num_outputs,
            leaves.len()
        );
        leaves
            .into_iter()
            .map(|l| l.to_vec::<f64>().context("output is not f64"))
            .collect()
    }
}
