//! Thin wrapper over the `xla` crate: client construction, HLO-text
//! loading, compilation and execution with `f64`/`i32` buffers.

use std::path::Path;

use crate::error::{HbmcError, Result};

fn xla_err(context: &str, e: impl std::fmt::Display) -> HbmcError {
    HbmcError::Runtime(format!("{context}: {e}"))
}

/// A PJRT CPU client plus compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// One compiled executable (an AOT-lowered JAX function).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Number of leaves in the result tuple.
    pub num_outputs: usize,
}

/// Argument buffer for execution.
pub enum Arg {
    F64(Vec<f64>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl Arg {
    pub fn f64(data: &[f64]) -> Arg {
        Arg::F64(data.to_vec(), vec![data.len() as i64])
    }

    pub fn f64_shaped(data: &[f64], shape: &[i64]) -> Arg {
        assert_eq!(shape.iter().product::<i64>() as usize, data.len());
        Arg::F64(data.to_vec(), shape.to_vec())
    }

    pub fn i32(data: &[i32]) -> Arg {
        Arg::I32(data.to_vec(), vec![data.len() as i64])
    }

    pub fn i32_shaped(data: &[i32], shape: &[i64]) -> Arg {
        assert_eq!(shape.iter().product::<i64>() as usize, data.len());
        Arg::I32(data.to_vec(), shape.to_vec())
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Arg::F64(data, shape) => xla::Literal::vec1(data)
                .reshape(shape)
                .map_err(|e| xla_err("reshaping f64 argument", e))?,
            Arg::I32(data, shape) => xla::Literal::vec1(data)
                .reshape(shape)
                .map_err(|e| xla_err("reshaping i32 argument", e))?,
        })
    }
}

impl PjrtRuntime {
    /// Construct the CPU client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| xla_err("creating PJRT CPU client", e))?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path, num_outputs: usize) -> Result<Executable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| HbmcError::Runtime(format!("non-utf8 path {}", path.display())))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| xla_err(&format!("parsing HLO text {}", path.display()), e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| xla_err(&format!("compiling {}", path.display()), e))?;
        Ok(Executable { exe, num_outputs })
    }
}

impl Executable {
    /// Execute with the given arguments; returns each output leaf as a
    /// flat `f64` vector. The python side lowers with `return_tuple=True`,
    /// so the single device result is a tuple of `num_outputs` leaves.
    pub fn run_f64(&self, args: &[Arg]) -> Result<Vec<Vec<f64>>> {
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| xla_err("executing", e))?[0][0]
            .to_literal_sync()
            .map_err(|e| xla_err("fetching result literal", e))?;
        let leaves = result.to_tuple().map_err(|e| xla_err("untupling result", e))?;
        if leaves.len() != self.num_outputs {
            return Err(HbmcError::Runtime(format!(
                "expected {} outputs, got {}",
                self.num_outputs,
                leaves.len()
            )));
        }
        leaves
            .into_iter()
            .map(|l| l.to_vec::<f64>().map_err(|e| xla_err("output is not f64", e)))
            .collect()
    }
}
