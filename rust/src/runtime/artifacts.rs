//! Artifact-set discovery and parsing.
//!
//! `make artifacts` (→ `python -m compile.aot`) writes:
//!
//! * `manifest.json` — human-readable build summary,
//! * `meta.txt` — canonical-problem metadata (kvtext),
//! * `golden.txt` — cross-layer golden data (kvtext): the canonical
//!   matrix in COO form, the python-computed HBMC permutation, IC(0)
//!   factor sample, and input/output vectors for the preconditioner —
//!   consumed by `rust/tests/golden_cross_layer.rs`,
//! * `precond_hbmc.hlo.txt` — L2 preconditioner apply (z = (LLᵀ)⁻¹ r),
//! * `spmv_sell.hlo.txt` — L2 SELL SpMV (y = A x),
//! * `pcg_step.hlo.txt` — one fused PCG iteration.

use std::path::{Path, PathBuf};

use crate::error::{HbmcError, Result};
use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::util::kvtext::KvDoc;

/// Handle to a built artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
}

impl ArtifactSet {
    /// Locate artifacts: `$HBMC_ARTIFACTS`, then `./artifacts`, then
    /// upward from the executable.
    pub fn locate() -> Result<ArtifactSet> {
        if let Ok(p) = std::env::var("HBMC_ARTIFACTS") {
            let dir = PathBuf::from(p);
            if dir.join("meta.txt").exists() {
                return Ok(ArtifactSet { dir });
            }
            return Err(HbmcError::Runtime(format!(
                "HBMC_ARTIFACTS={} has no meta.txt",
                dir.display()
            )));
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            let dir = PathBuf::from(cand);
            if dir.join("meta.txt").exists() {
                return Ok(ArtifactSet { dir });
            }
        }
        Err(HbmcError::Runtime(
            "artifact set not found — run `make artifacts` first".into(),
        ))
    }

    pub fn at(dir: &Path) -> ArtifactSet {
        ArtifactSet { dir: dir.to_path_buf() }
    }

    pub fn exists(&self) -> bool {
        self.dir.join("meta.txt").exists()
    }

    pub fn meta(&self) -> Result<KvDoc> {
        KvDoc::load(&self.dir.join("meta.txt"))
    }

    pub fn golden(&self) -> Result<KvDoc> {
        KvDoc::load(&self.dir.join("golden.txt"))
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }
}

/// Rebuild the canonical matrix stored in `golden.txt` (COO triplets under
/// keys `mat_rows`, `mat_cols`, `mat_vals`, dimension `n`).
pub fn canonical_matrix(golden: &KvDoc) -> Result<Csr> {
    let n = golden.usize("n")?;
    let rows = golden.usize_vec("mat_rows")?;
    let cols = golden.usize_vec("mat_cols")?;
    let vals = golden.f64_vec("mat_vals")?;
    if rows.len() != cols.len() || cols.len() != vals.len() {
        return Err(HbmcError::Parse("golden matrix triplet arrays differ in length".into()));
    }
    let mut coo = Coo::with_capacity(n, rows.len());
    for ((i, j), v) in rows.into_iter().zip(cols).zip(vals) {
        coo.push(i, j, v);
    }
    Ok(coo.to_csr())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_matrix_roundtrip() {
        let mut d = KvDoc::new();
        d.set("n", "3");
        d.set_usize_vec("mat_rows", &[0, 1, 2, 0]);
        d.set_usize_vec("mat_cols", &[0, 1, 2, 2]);
        d.set_f64_vec("mat_vals", &[2.0, 3.0, 4.0, -1.0]);
        let a = canonical_matrix(&d).unwrap();
        assert_eq!(a.n(), 3);
        assert_eq!(a.get(0, 2), Some(-1.0));
        assert_eq!(a.get(1, 1), Some(3.0));
    }

    #[test]
    fn locate_fails_cleanly_without_artifacts() {
        let set = ArtifactSet::at(Path::new("/nonexistent"));
        assert!(!set.exists());
        assert!(set.meta().is_err());
    }
}
