//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** — see `/opt/xla-example/README.md`
//! for why text, not serialized protos) and executes them on the XLA CPU
//! client from the rust request path. Python never runs at solve time.

pub mod artifacts;
pub mod hybrid;
pub mod pjrt;
