//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** — see `/opt/xla-example/README.md`
//! for why text, not serialized protos) and executes them on the XLA CPU
//! client from the rust request path. Python never runs at solve time.
//!
//! The executor itself sits behind the `pjrt` cargo feature because it
//! links the `xla` crate (and its native XLA extension). Default builds get
//! [`pjrt_stub`]-backed types with the same API whose constructor returns a
//! clean error, so every caller compiles unchanged offline.

pub mod artifacts;
pub mod hybrid;

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
