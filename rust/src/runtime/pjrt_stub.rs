//! API-compatible stand-in for [`pjrt`](crate::runtime::pjrt) when the
//! crate is built without the `pjrt` feature (the offline default). Every
//! type and signature matches the real module; the only reachable entry
//! point, [`PjrtRuntime::cpu`], reports that the executor is unavailable.

use std::path::Path;

use crate::error::{HbmcError, Result};

/// Uninhabited marker: stub runtimes cannot be constructed, which lets the
/// remaining methods type-check without a real implementation behind them.
enum Never {}

/// A PJRT CPU client plus compiled executables (stub).
pub struct PjrtRuntime {
    never: Never,
}

/// One compiled executable (stub).
pub struct Executable {
    never: Never,
    /// Number of leaves in the result tuple.
    pub num_outputs: usize,
}

/// Argument buffer for execution.
pub enum Arg {
    F64(Vec<f64>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl Arg {
    pub fn f64(data: &[f64]) -> Arg {
        Arg::F64(data.to_vec(), vec![data.len() as i64])
    }

    pub fn f64_shaped(data: &[f64], shape: &[i64]) -> Arg {
        assert_eq!(shape.iter().product::<i64>() as usize, data.len());
        Arg::F64(data.to_vec(), shape.to_vec())
    }

    pub fn i32(data: &[i32]) -> Arg {
        Arg::I32(data.to_vec(), vec![data.len() as i64])
    }

    pub fn i32_shaped(data: &[i32], shape: &[i64]) -> Arg {
        assert_eq!(shape.iter().product::<i64>() as usize, data.len());
        Arg::I32(data.to_vec(), shape.to_vec())
    }
}

impl PjrtRuntime {
    /// Always fails: the crate was compiled without the `pjrt` feature.
    pub fn cpu() -> Result<PjrtRuntime> {
        Err(HbmcError::Runtime(
            "hbmc was built without the `pjrt` feature; rebuild with \
             `cargo build --features pjrt` (requires the XLA extension) \
             to run AOT artifacts"
                .into(),
        ))
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    pub fn load_hlo_text(&self, _path: &Path, _num_outputs: usize) -> Result<Executable> {
        match self.never {}
    }
}

impl Executable {
    pub fn run_f64(&self, _args: &[Arg]) -> Result<Vec<Vec<f64>>> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_missing_feature() {
        let err = PjrtRuntime::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"));
    }

    #[test]
    fn arg_constructors_shape_check() {
        assert!(matches!(Arg::f64(&[1.0, 2.0]), Arg::F64(v, s) if v.len() == 2 && s == vec![2]));
        assert!(matches!(Arg::i32_shaped(&[1, 2, 3, 4], &[2, 2]), Arg::I32(_, s) if s == vec![2, 2]));
    }
}
