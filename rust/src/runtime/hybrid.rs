//! Hybrid execution: the rust CG loop driving AOT-compiled JAX/Pallas
//! kernels through PJRT. This is the path that proves all three layers
//! compose: L1 Pallas kernel → L2 JAX graph → HLO text → L3 rust loop.
//!
//! The AOT executables are specialized to the canonical problem emitted by
//! `python/compile/aot.py` (matrix data baked as constants), so they take
//! only the iteration vectors as runtime inputs.

use crate::error::{HbmcError, Result};
use crate::runtime::artifacts::ArtifactSet;
use crate::runtime::pjrt::{Arg, Executable, PjrtRuntime};

/// PJRT-backed IC(0) preconditioner `z = (L Lᵀ)⁻¹ r` (HBMC-vectorized
/// Pallas kernel inside).
pub struct HybridPrecond {
    exe: Executable,
    pub n: usize,
}

impl HybridPrecond {
    pub fn load(rt: &PjrtRuntime, arts: &ArtifactSet) -> Result<HybridPrecond> {
        let meta = arts.meta()?;
        let n = meta.usize("n_aug")?;
        let exe = rt.load_hlo_text(&arts.hlo_path("precond_hbmc"), 1)?;
        Ok(HybridPrecond { exe, n })
    }

    /// Apply to a vector in the canonical problem's HBMC ordering.
    pub fn apply(&self, r: &[f64]) -> Result<Vec<f64>> {
        if r.len() != self.n {
            return Err(HbmcError::DimensionMismatch { expected: self.n, got: r.len() });
        }
        let mut out = self.exe.run_f64(&[Arg::f64(r)])?;
        Ok(out.remove(0))
    }
}

/// PJRT-backed SpMV `y = A x` (SELL Pallas kernel inside).
pub struct HybridSpmv {
    exe: Executable,
    pub n: usize,
}

impl HybridSpmv {
    pub fn load(rt: &PjrtRuntime, arts: &ArtifactSet) -> Result<HybridSpmv> {
        let meta = arts.meta()?;
        let n = meta.usize("n_aug")?;
        let exe = rt.load_hlo_text(&arts.hlo_path("spmv_sell"), 1)?;
        Ok(HybridSpmv { exe, n })
    }

    pub fn apply(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.n {
            return Err(HbmcError::DimensionMismatch { expected: self.n, got: x.len() });
        }
        let mut out = self.exe.run_f64(&[Arg::f64(x)])?;
        Ok(out.remove(0))
    }
}

/// One fused PCG iteration executed on PJRT:
/// inputs `(x, r, z, p, rz)` → outputs `(x', r', z', p', rz', relres²·bb)`.
/// Matrix, factor and schedule are baked constants.
pub struct HybridPcgStep {
    exe: Executable,
    pub n: usize,
}

impl HybridPcgStep {
    pub fn load(rt: &PjrtRuntime, arts: &ArtifactSet) -> Result<HybridPcgStep> {
        let meta = arts.meta()?;
        let n = meta.usize("n_aug")?;
        let exe = rt.load_hlo_text(&arts.hlo_path("pcg_step"), 6)?;
        Ok(HybridPcgStep { exe, n })
    }

    /// Run one iteration. `state = (x, r, p, rz)`; `z` is recomputed
    /// inside the executable (a dead input would be eliminated by jax).
    #[allow(clippy::type_complexity)]
    pub fn step(
        &self,
        x: &[f64],
        r: &[f64],
        p: &[f64],
        rz: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, f64, f64)> {
        let out = self.exe.run_f64(&[
            Arg::f64(x),
            Arg::f64(r),
            Arg::f64(p),
            Arg::f64_shaped(&[rz], &[]),
        ])?;
        let mut it = out.into_iter();
        let x = it.next().unwrap();
        let r = it.next().unwrap();
        let z = it.next().unwrap();
        let p = it.next().unwrap();
        let rz = it.next().unwrap()[0];
        let rr = it.next().unwrap()[0];
        Ok((x, r, z, p, rz, rr))
    }
}
