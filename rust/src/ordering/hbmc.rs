//! Hierarchical block multi-color ordering (HBMC) — the paper's
//! contribution (§4).
//!
//! Starting from BMC, each group of `w` consecutive same-color blocks forms
//! a *level-1 block* (eq. 4.1). Inside a level-1 block the unknowns are
//! reordered by `bs` "pick-up" rounds: round `l` takes the `l`-th unknown
//! of each of the `w` member blocks (Fig. 4.3). The resulting *level-2
//! blocks* (rows `l·w .. (l+1)·w` of a level-1 block) couple only
//! lane-to-same-lane — the `w × w` blocks of eq. (4.7) are **diagonal** —
//! so each of the `bs` sequential substitution steps is `w` independent
//! lanes, i.e. directly SIMD-vectorizable.
//!
//! The secondary reordering is local to each level-1 block and preserves
//! the pick-up order inside every BMC block, so the ordering graph is
//! unchanged (eqs. 4.2, 4.3): HBMC is *equivalent* to BMC — identical
//! convergence — which the test suite checks both via the ER condition and
//! via iteration-exact residual histories.
//!
//! Colors whose block count is not a multiple of `w` are padded with
//! all-dummy blocks so every color holds a whole number of level-1 blocks
//! ("the assumption is satisfied using some dummy unknowns", §4.3).

use crate::ordering::blocking::build_blocks;
use crate::ordering::bmc::{bmc_order_with_blocking, BmcOrdering};
use crate::ordering::graph::Adjacency;
use crate::ordering::perm::Perm;
use crate::sparse::csr::Csr;
use crate::util::round_up;

/// HBMC ordering result.
#[derive(Debug, Clone)]
pub struct HbmcOrdering {
    /// Original → HBMC-ordered augmented index.
    pub perm: Perm,
    /// BMC space → HBMC space (the secondary reordering π of §4.2); kept
    /// for the equivalence machinery and tests.
    pub secondary: Perm,
    /// The underlying BMC ordering (same blocking, same coloring).
    pub bmc: BmcOrdering,
    pub bs: usize,
    /// SIMD width — size of a level-2 diagonal block.
    pub w: usize,
    pub num_colors: usize,
    /// Row range of color `c` in HBMC space; multiples of `bs·w`.
    pub color_ptr: Vec<usize>,
    /// Level-1 blocks per color (`n̄(c)` in the paper).
    pub l1_per_color: Vec<usize>,
}

impl HbmcOrdering {
    /// Augmented dimension (multiple of `bs·w` per color).
    pub fn n(&self) -> usize {
        self.perm.n_new()
    }

    /// Total level-1 blocks (= degree of thread parallelism summed over colors).
    pub fn num_l1_blocks(&self) -> usize {
        self.l1_per_color.iter().sum()
    }

    /// Decompose an HBMC row index into `(color, l1_block_in_color, step, lane)`.
    pub fn locate(&self, row: usize) -> (usize, usize, usize, usize) {
        let c = match self.color_ptr.binary_search(&row) {
            Ok(c) if c < self.num_colors => c,
            Ok(c) => c - 1,
            Err(c) => c - 1,
        };
        let local = row - self.color_ptr[c];
        let l1 = local / (self.bs * self.w);
        let within = local % (self.bs * self.w);
        (c, l1, within / self.w, within % self.w)
    }
}

/// Apply HBMC with block size `bs` and SIMD width `w` to the pattern of `a`.
pub fn hbmc_order(a: &Csr, bs: usize, w: usize) -> HbmcOrdering {
    let adj = Adjacency::from_csr(a);
    let blocking = build_blocks(&adj, bs);
    let bmc = bmc_order_with_blocking(&adj, &blocking);
    hbmc_from_bmc(bmc, w)
}

/// Derive HBMC from an existing BMC ordering (the secondary reordering of
/// §4.2). Exposed so benchmarks can share one BMC across both solvers.
pub fn hbmc_from_bmc(bmc: BmcOrdering, w: usize) -> HbmcOrdering {
    assert!(w > 0);
    let bs = bmc.bs;
    let ncolors = bmc.num_colors;

    // HBMC color layout: pad each color's block count to a multiple of w.
    let mut color_ptr = Vec::with_capacity(ncolors + 1);
    let mut l1_per_color = Vec::with_capacity(ncolors);
    color_ptr.push(0usize);
    for c in 0..ncolors {
        let nb = round_up(bmc.blocks_per_color[c], w);
        l1_per_color.push(nb / w);
        color_ptr.push(color_ptr[c] + nb * bs);
    }
    let n_hbmc = *color_ptr.last().unwrap();

    // Secondary reordering π : BMC index → HBMC index.
    // BMC index of (color c, block k, slot l)  = bmc.color_ptr[c] + k·bs + l
    // HBMC index of the same unknown           =
    //   color_ptr[c] + (k / w)·bs·w + l·w + (k mod w)            (Fig. 4.3)
    let mut sec = vec![0u32; bmc.n()];
    for c in 0..ncolors {
        let nb = bmc.blocks_per_color[c];
        for k in 0..nb {
            for l in 0..bs {
                let from = bmc.color_ptr[c] + k * bs + l;
                let to = color_ptr[c] + (k / w) * bs * w + l * w + (k % w);
                sec[from] = to as u32;
            }
        }
    }
    let secondary = Perm::padded(sec, n_hbmc).expect("hbmc secondary is injective");
    let perm = bmc.perm.then(&secondary);

    HbmcOrdering {
        perm,
        secondary,
        bs,
        w,
        num_colors: ncolors,
        color_ptr,
        l1_per_color,
        bmc,
    }
}

/// Check the level-2 structural invariant on the HBMC-reordered matrix:
/// inside a level-1 block, every entry couples a row and column with the
/// *same lane* (the `w × w` blocks of eq. 4.7 are diagonal). Returns the
/// first violating entry.
pub fn check_level2_diagonal(b: &Csr, ord: &HbmcOrdering) -> Option<(usize, usize)> {
    let bw = ord.bs * ord.w;
    for c in 0..ord.num_colors {
        let (lo, hi) = (ord.color_ptr[c], ord.color_ptr[c + 1]);
        for i in lo..hi {
            let (l1_i, lane_i) = ((i - lo) / bw, (i - lo) % ord.w);
            let (cols, _) = b.row(i);
            for &j in cols {
                let j = j as usize;
                if j == i || j < lo || j >= hi {
                    continue; // other color: handled by color structure
                }
                let (l1_j, lane_j) = ((j - lo) / bw, (j - lo) % ord.w);
                if l1_i == l1_j {
                    if lane_i != lane_j {
                        return Some((i, j)); // in-block cross-lane coupling
                    }
                } else {
                    return Some((i, j)); // same color, different level-1 block
                }
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ordering::graph::orderings_equivalent;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn grid(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    fn random_spd(n: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 8.0);
            for _ in 0..3 {
                let j = rng.below(n);
                if j != i {
                    c.push_sym(i, j, -0.4);
                }
            }
        }
        c.to_csr()
    }

    #[test]
    fn level2_blocks_are_diagonal_grid() {
        let a = grid(12, 12);
        for &(bs, w) in &[(2usize, 4usize), (4, 4), (8, 2)] {
            let ord = hbmc_order(&a, bs, w);
            let b = a.permute_sym(&ord.perm);
            assert_eq!(check_level2_diagonal(&b, &ord), None, "bs={bs} w={w}");
        }
    }

    #[test]
    fn level2_blocks_are_diagonal_random() {
        for seed in [4, 5] {
            let a = random_spd(200, seed);
            let ord = hbmc_order(&a, 8, 4);
            let b = a.permute_sym(&ord.perm);
            assert_eq!(check_level2_diagonal(&b, &ord), None, "seed={seed}");
        }
    }

    #[test]
    fn hbmc_equivalent_to_bmc_by_ordering_graph() {
        // The theorem of §4.2.1: BMC and HBMC have identical ordering
        // graphs on the original matrix.
        for seed in [7, 8] {
            let a = random_spd(150, seed);
            let ord = hbmc_order(&a, 4, 4);
            assert!(
                orderings_equivalent(&a, &ord.bmc.perm, &ord.perm),
                "seed={seed}"
            );
        }
    }

    #[test]
    fn color_ranges_are_multiples_of_bsw() {
        let a = grid(9, 9); // odd sizes force padding
        let ord = hbmc_order(&a, 4, 4);
        for c in 0..ord.num_colors {
            let len = ord.color_ptr[c + 1] - ord.color_ptr[c];
            assert_eq!(len % (4 * 4), 0);
            assert_eq!(len, ord.l1_per_color[c] * 16);
        }
        assert_eq!(ord.n(), *ord.color_ptr.last().unwrap());
    }

    #[test]
    fn secondary_preserves_in_block_order() {
        // Eq. (4.3): unknowns of the same BMC block keep their order.
        let a = random_spd(120, 11);
        let ord = hbmc_order(&a, 8, 4);
        let bmc = &ord.bmc;
        for c in 0..bmc.num_colors {
            for k in 0..bmc.blocks_per_color[c] {
                let mut prev = None;
                for l in 0..bmc.bs {
                    let from = bmc.color_ptr[c] + k * bmc.bs + l;
                    let to = ord.secondary.new_of_old(from);
                    if let Some(p) = prev {
                        assert!(to > p, "order flip inside BMC block");
                    }
                    prev = Some(to);
                }
            }
        }
    }

    #[test]
    fn secondary_is_local_to_level1_blocks() {
        // Eq. (4.2): unknowns in different level-1 blocks keep order.
        let a = grid(10, 10);
        let ord = hbmc_order(&a, 4, 2);
        let bw = ord.bs * ord.w;
        for c in 0..ord.num_colors {
            let nb = ord.bmc.blocks_per_color[c];
            for k in 0..nb {
                for l in 0..ord.bs {
                    let from = ord.bmc.color_ptr[c] + k * ord.bs + l;
                    let to = ord.secondary.new_of_old(from);
                    // Same level-1 block in both spaces.
                    let l1_from = (from - ord.bmc.color_ptr[c]) / bw;
                    let l1_to = (to - ord.color_ptr[c]) / bw;
                    assert_eq!(l1_from, l1_to);
                }
            }
        }
    }

    #[test]
    fn locate_roundtrip() {
        let a = grid(8, 8);
        let ord = hbmc_order(&a, 4, 2);
        for row in 0..ord.n() {
            let (c, l1, step, lane) = ord.locate(row);
            assert_eq!(
                ord.color_ptr[c] + l1 * ord.bs * ord.w + step * ord.w + lane,
                row
            );
        }
    }

    #[test]
    fn interleave_matches_fig_4_3() {
        // Fig 4.3 example: bs=2, w=4 — after reordering, the first level-1
        // block is [b1[0], b2[0], b3[0], b4[0], b1[1], b2[1], b3[1], b4[1]].
        let a = grid(16, 4); // gives ≥4 blocks of size 2 in color 0
        let ord = hbmc_order(&a, 2, 4);
        let bmc = &ord.bmc;
        if bmc.blocks_per_color[0] >= 4 {
            for k in 0..4usize {
                for l in 0..2usize {
                    let from = bmc.color_ptr[0] + k * 2 + l;
                    let to = ord.secondary.new_of_old(from);
                    assert_eq!(to, ord.color_ptr[0] + l * 4 + k);
                }
            }
        } else {
            panic!("test fixture too small: {} blocks", bmc.blocks_per_color[0]);
        }
    }
}
