//! Parallel orderings: multi-color (MC), block multi-color (BMC,
//! Iwashita–Nakashima–Takahashi 2012) and the paper's contribution,
//! hierarchical block multi-color ordering (HBMC).
//!
//! [`graph`] implements the *ordering graph* and the ER (equivalent
//! reordering) condition of §3.1, eq. (3.5) — the tool used to prove that
//! HBMC converges identically to BMC.

pub mod blocking;
pub mod bmc;
pub mod coloring;
pub mod graph;
pub mod hbmc;
pub mod mc;
pub mod perm;
