//! Parallel orderings: multi-color (MC), block multi-color (BMC,
//! Iwashita–Nakashima–Takahashi 2012) and the paper's contribution,
//! hierarchical block multi-color ordering (HBMC).
//!
//! [`graph`] implements the *ordering graph* and the ER (equivalent
//! reordering) condition of §3.1, eq. (3.5) — the tool used to prove that
//! HBMC converges identically to BMC.
//!
//! [`order_matrix`] is the layer's façade for the plan builder
//! ([`crate::solver::plan`]): one call that runs the requested ordering and
//! returns the permutation plus the ordering-specific structure a
//! triangular solver needs, so no upper layer re-implements the
//! per-[`OrderingKind`](crate::config::OrderingKind) dispatch.

//! [`race`] is a different kind of ordering: not a solver reordering but a
//! conflict-free row *schedule* (recursive algebraic coloring) for the
//! symmetric SpMV engine in [`crate::solver::spmv`].

pub mod blocking;
pub mod bmc;
pub mod coloring;
pub mod graph;
pub mod hbmc;
pub mod mc;
pub mod perm;
pub mod race;

use crate::config::OrderingKind;
use crate::sparse::csr::Csr;

use self::bmc::bmc_order;
use self::hbmc::{hbmc_order, HbmcOrdering};
use self::mc::mc_order;
use self::perm::Perm;

/// Ordering-specific structure consumed by the triangular-solver layer.
pub enum OrderedStructure {
    /// Natural ordering: serial substitutions, no color structure.
    Natural,
    /// Nodal multi-color: rows of color `c` span `color_ptr[c]..color_ptr[c+1]`.
    Mc { color_ptr: Vec<usize> },
    /// Block multi-color: blocks of `bs` consecutive rows per color.
    Bmc { color_ptr: Vec<usize>, bs: usize },
    /// Hierarchical block multi-color: full ordering retained (the solver
    /// extracts its `HbmcMeta` and the level-2 layout from it).
    Hbmc(HbmcOrdering),
    /// Level-scheduled trisolve: identity permutation, no color structure —
    /// the solver layer builds the wavefront schedule itself, since the
    /// IC(0) factor whose DAG is scheduled does not exist at ordering time
    /// (`num_colors` is likewise a solver-side quantity here).
    Level,
}

/// Product of the ordering phase: permutation into the (possibly padded)
/// internal space, color count, and the solver-facing structure.
pub struct OrderingPlan {
    pub perm: Perm,
    pub num_colors: usize,
    pub structure: OrderedStructure,
}

/// Run the ordering `kind` on `a` (`bs`/`w` are the BMC/HBMC parameters;
/// ignored where not applicable).
pub fn order_matrix(a: &Csr, kind: OrderingKind, bs: usize, w: usize) -> OrderingPlan {
    match kind {
        OrderingKind::Natural => OrderingPlan {
            perm: Perm::identity(a.n()),
            num_colors: 1,
            structure: OrderedStructure::Natural,
        },
        OrderingKind::Mc => {
            let mc = mc_order(a);
            OrderingPlan {
                perm: mc.perm,
                num_colors: mc.num_colors,
                structure: OrderedStructure::Mc { color_ptr: mc.color_ptr },
            }
        }
        OrderingKind::Bmc => {
            let ord = bmc_order(a, bs);
            OrderingPlan {
                perm: ord.perm.clone(),
                num_colors: ord.num_colors,
                structure: OrderedStructure::Bmc { color_ptr: ord.color_ptr, bs: ord.bs },
            }
        }
        OrderingKind::Hbmc => {
            let ord = hbmc_order(a, bs, w);
            OrderingPlan {
                perm: ord.perm.clone(),
                num_colors: ord.num_colors,
                structure: OrderedStructure::Hbmc(ord),
            }
        }
        OrderingKind::Level => OrderingPlan {
            perm: Perm::identity(a.n()),
            num_colors: 1,
            structure: OrderedStructure::Level,
        },
    }
}

#[cfg(test)]
mod facade_tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn grid(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    #[test]
    fn facade_matches_direct_calls() {
        let a = grid(10, 8);
        let natural = order_matrix(&a, OrderingKind::Natural, 4, 4);
        assert!(natural.perm.is_identity());
        assert_eq!(natural.num_colors, 1);
        assert!(matches!(natural.structure, OrderedStructure::Natural));

        let mc = order_matrix(&a, OrderingKind::Mc, 4, 4);
        let direct = mc_order(&a);
        assert_eq!(mc.num_colors, direct.num_colors);
        assert_eq!(mc.perm.new_of_old_slice(), direct.perm.new_of_old_slice());

        let bmc = order_matrix(&a, OrderingKind::Bmc, 4, 4);
        let direct = bmc_order(&a, 4);
        assert_eq!(bmc.num_colors, direct.num_colors);
        match &bmc.structure {
            OrderedStructure::Bmc { color_ptr, bs } => {
                assert_eq!(*bs, 4);
                assert_eq!(*color_ptr, direct.color_ptr);
            }
            _ => panic!("wrong structure"),
        }

        let h = order_matrix(&a, OrderingKind::Hbmc, 4, 4);
        match &h.structure {
            OrderedStructure::Hbmc(ord) => {
                assert_eq!(ord.num_colors, h.num_colors);
                assert_eq!(h.perm.new_of_old_slice(), ord.perm.new_of_old_slice());
            }
            _ => panic!("wrong structure"),
        }
    }
}
