//! Nodal multi-color ordering (the paper's "MC" baseline): greedy-color the
//! nodes, then renumber color-by-color preserving index order inside each
//! color. Rows of one color are mutually independent, so each color's slice
//! of a substitution is fully parallel (and expressible as an SpMV).

use crate::ordering::coloring::greedy_color;
use crate::ordering::graph::Adjacency;
use crate::ordering::perm::Perm;
use crate::sparse::csr::Csr;

/// MC ordering result.
#[derive(Debug, Clone)]
pub struct McOrdering {
    /// Original → MC-ordered index (no padding: `n_new == n_old`).
    pub perm: Perm,
    pub num_colors: usize,
    /// Row range of color `c` is `color_ptr[c]..color_ptr[c+1]`.
    pub color_ptr: Vec<usize>,
}

/// Apply nodal multi-color ordering to the pattern of `a`.
pub fn mc_order(a: &Csr) -> McOrdering {
    let adj = Adjacency::from_csr(a);
    let col = greedy_color(adj.n(), |v| adj.neighbors(v).to_vec());
    let groups = col.groups();
    let mut new_of_old = vec![0u32; adj.n()];
    let mut color_ptr = Vec::with_capacity(groups.len() + 1);
    color_ptr.push(0);
    let mut next = 0u32;
    for g in &groups {
        for &v in g {
            new_of_old[v as usize] = next;
            next += 1;
        }
        color_ptr.push(next as usize);
    }
    McOrdering {
        perm: Perm::from_new_of_old(new_of_old, adj.n()).expect("mc perm is a bijection"),
        num_colors: col.num_colors,
        color_ptr,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn grid(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    #[test]
    fn red_black_on_grid() {
        let a = grid(6, 6);
        let mc = mc_order(&a);
        assert_eq!(mc.num_colors, 2);
        assert_eq!(mc.color_ptr, vec![0, 18, 36]);
    }

    #[test]
    fn colors_are_independent_sets() {
        let a = grid(5, 7);
        let mc = mc_order(&a);
        let b = a.permute_sym(&mc.perm);
        // Inside a color range, no off-diagonal entries.
        for c in 0..mc.num_colors {
            for i in mc.color_ptr[c]..mc.color_ptr[c + 1] {
                let (cols, _) = b.row(i);
                for &j in cols {
                    let j = j as usize;
                    assert!(
                        j == i || j < mc.color_ptr[c] || j >= mc.color_ptr[c + 1],
                        "intra-color edge ({i},{j}) in color {c}"
                    );
                }
            }
        }
    }

    #[test]
    fn perm_is_bijection_covering_all() {
        let a = grid(4, 4);
        let mc = mc_order(&a);
        assert_eq!(mc.perm.n_old(), 16);
        assert_eq!(mc.perm.n_new(), 16);
        assert_eq!(*mc.color_ptr.last().unwrap(), 16);
    }
}
