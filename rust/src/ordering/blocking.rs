//! Block construction for BMC — "the simplest [heuristic] among the
//! heuristics introduced in [13], in which the unknown with the minimal
//! number is picked up for the newly generated block" (paper §5.1).
//!
//! A block is seeded with the minimum-index unassigned node, then grown by
//! repeatedly absorbing the minimum-index unassigned node adjacent to the
//! current block, until it holds `bs` nodes or the frontier is exhausted
//! (blocks at region boundaries may come up short; they are padded with
//! dummy slots downstream). Deterministic, in lock-step with
//! `python/compile/ordering.py`.

use std::collections::BTreeSet;

use crate::ordering::graph::Adjacency;

/// Block partition of `[0, n)`: each inner vec holds the original node
/// indices of one block, in pick-up order; `len <= bs`.
#[derive(Debug, Clone)]
pub struct Blocking {
    pub bs: usize,
    pub blocks: Vec<Vec<u32>>,
}

impl Blocking {
    /// Total real (non-dummy) nodes across blocks.
    pub fn num_nodes(&self) -> usize {
        self.blocks.iter().map(|b| b.len()).sum()
    }

    /// Verify partition: each node appears exactly once.
    pub fn is_partition(&self, n: usize) -> bool {
        let mut seen = vec![false; n];
        for b in &self.blocks {
            for &v in b {
                if seen[v as usize] {
                    return false;
                }
                seen[v as usize] = true;
            }
        }
        seen.iter().all(|&s| s)
    }
}

/// Build blocks of size `bs` with the min-index greedy heuristic of [13].
pub fn build_blocks(adj: &Adjacency, bs: usize) -> Blocking {
    assert!(bs > 0);
    let n = adj.n();
    let mut assigned = vec![false; n];
    let mut blocks: Vec<Vec<u32>> = Vec::with_capacity(n.div_ceil(bs));
    // `next_start` scans for the minimal unassigned seed in O(n) total.
    let mut next_start = 0usize;
    while next_start < n {
        if assigned[next_start] {
            next_start += 1;
            continue;
        }
        let seed = next_start;
        let mut block = Vec::with_capacity(bs);
        // Frontier of candidate nodes (unassigned neighbors of the block),
        // ordered by index — BTreeSet gives min extraction + dedup.
        let mut frontier: BTreeSet<u32> = BTreeSet::new();
        assigned[seed] = true;
        block.push(seed as u32);
        for &u in adj.neighbors(seed) {
            if !assigned[u as usize] {
                frontier.insert(u);
            }
        }
        while block.len() < bs {
            let Some(&v) = frontier.iter().next() else {
                break; // region exhausted: short block
            };
            frontier.remove(&v);
            assigned[v as usize] = true;
            block.push(v);
            for &u in adj.neighbors(v as usize) {
                if !assigned[u as usize] {
                    frontier.insert(u);
                }
            }
        }
        blocks.push(block);
    }
    Blocking { bs, blocks }
}

/// Adjacency of the block quotient graph: blocks `p`, `q` are adjacent iff
/// some node of `p` neighbors some node of `q`. Returns per-block sorted
/// neighbor lists.
pub fn block_graph(adj: &Adjacency, blocking: &Blocking) -> Vec<Vec<u32>> {
    let n = adj.n();
    let mut block_of = vec![u32::MAX; n];
    for (bi, b) in blocking.blocks.iter().enumerate() {
        for &v in b {
            block_of[v as usize] = bi as u32;
        }
    }
    let mut nbrs: Vec<Vec<u32>> = vec![Vec::new(); blocking.blocks.len()];
    for (bi, b) in blocking.blocks.iter().enumerate() {
        for &v in b {
            for &u in adj.neighbors(v as usize) {
                let bu = block_of[u as usize];
                debug_assert!(bu != u32::MAX);
                if bu as usize != bi {
                    nbrs[bi].push(bu);
                }
            }
        }
        nbrs[bi].sort_unstable();
        nbrs[bi].dedup();
    }
    nbrs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn chain_adj(n: usize) -> Adjacency {
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 2.0);
        }
        for i in 0..n - 1 {
            c.push_sym(i, i + 1, -1.0);
        }
        Adjacency::from_csr(&c.to_csr())
    }

    #[test]
    fn chain_blocks_are_contiguous() {
        let adj = chain_adj(12);
        let b = build_blocks(&adj, 4);
        assert_eq!(b.blocks.len(), 3);
        assert_eq!(b.blocks[0], vec![0, 1, 2, 3]);
        assert_eq!(b.blocks[1], vec![4, 5, 6, 7]);
        assert!(b.is_partition(12));
    }

    #[test]
    fn short_tail_block() {
        let adj = chain_adj(10);
        let b = build_blocks(&adj, 4);
        assert_eq!(b.blocks.len(), 3);
        assert_eq!(b.blocks[2].len(), 2);
        assert!(b.is_partition(10));
    }

    #[test]
    fn disconnected_components_give_short_blocks() {
        // Two disjoint edges: 0-1, 2-3, bs=3 → blocks [0,1] and [2,3].
        let mut c = Coo::new(4);
        for i in 0..4 {
            c.push(i, i, 1.0);
        }
        c.push_sym(0, 1, -1.0);
        c.push_sym(2, 3, -1.0);
        let adj = Adjacency::from_csr(&c.to_csr());
        let b = build_blocks(&adj, 3);
        assert_eq!(b.blocks, vec![vec![0, 1], vec![2, 3]]);
    }

    #[test]
    fn partition_on_random_graph() {
        let mut rng = Rng::new(23);
        let n = 300;
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 1.0);
            for _ in 0..2 {
                let j = rng.below(n);
                if j != i {
                    c.push_sym(i, j, -1.0);
                }
            }
        }
        let adj = Adjacency::from_csr(&c.to_csr());
        for &bs in &[2usize, 8, 32] {
            let b = build_blocks(&adj, bs);
            assert!(b.is_partition(n), "bs={bs}");
            assert!(b.blocks.iter().all(|blk| blk.len() <= bs));
        }
    }

    #[test]
    fn block_graph_chain() {
        let adj = chain_adj(8);
        let b = build_blocks(&adj, 4);
        let g = block_graph(&adj, &b);
        assert_eq!(g.len(), 2);
        assert_eq!(g[0], vec![1]);
        assert_eq!(g[1], vec![0]);
    }

    #[test]
    fn block_graph_no_self_loops() {
        let adj = chain_adj(16);
        let b = build_blocks(&adj, 4);
        for (bi, nb) in block_graph(&adj, &b).iter().enumerate() {
            assert!(!nb.contains(&(bi as u32)));
        }
    }
}
