//! Ordering-graph machinery (paper §3.1).
//!
//! The *ordering graph* of a symmetric-pattern matrix is the directed graph
//! with an edge `i₁ → i₂` whenever `a_{i₁,i₂} ≠ 0 ∨ a_{i₂,i₁} ≠ 0` and
//! `i₁` precedes `i₂` in the ordering. A reordering `π` is *equivalent*
//! (same IC(0)/GS/SOR solution process) iff it preserves every edge
//! direction — the ER condition, eq. (3.5):
//!
//! `sgn(i₁ − i₂) = sgn(π(i₁) − π(i₂))` for all connected pairs.

use crate::ordering::perm::Perm;
use crate::sparse::csr::Csr;

/// Symmetrized adjacency (neighbor lists, diagonal excluded) of a matrix
/// pattern. All ordering heuristics work on this view.
#[derive(Debug, Clone)]
pub struct Adjacency {
    n: usize,
    ptr: Vec<u32>,
    nbr: Vec<u32>,
}

impl Adjacency {
    /// Build from a CSR pattern, symmetrizing `pattern(A) ∪ pattern(Aᵀ)`.
    pub fn from_csr(a: &Csr) -> Adjacency {
        let n = a.n();
        // Collect undirected edges (i < j).
        let mut deg = vec![0u32; n + 1];
        let mut edges: Vec<(u32, u32)> = Vec::with_capacity(a.nnz());
        for i in 0..n {
            let (cols, _) = a.row(i);
            for &c in cols {
                let j = c as usize;
                if j == i {
                    continue;
                }
                let (lo, hi) = if i < j { (i, j) } else { (j, i) };
                edges.push((lo as u32, hi as u32));
            }
        }
        edges.sort_unstable();
        edges.dedup();
        for &(i, j) in &edges {
            deg[i as usize + 1] += 1;
            deg[j as usize + 1] += 1;
        }
        for i in 0..n {
            deg[i + 1] += deg[i];
        }
        let mut nbr = vec![0u32; 2 * edges.len()];
        let mut cursor = deg.clone();
        for &(i, j) in &edges {
            nbr[cursor[i as usize] as usize] = j;
            cursor[i as usize] += 1;
            nbr[cursor[j as usize] as usize] = i;
            cursor[j as usize] += 1;
        }
        // Sort each neighbor list for deterministic traversal.
        for i in 0..n {
            nbr[deg[i] as usize..deg[i + 1] as usize].sort_unstable();
        }
        Adjacency { n, ptr: deg, nbr }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Neighbors of node `i` (sorted, no self-loop).
    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.nbr[self.ptr[i] as usize..self.ptr[i + 1] as usize]
    }

    pub fn degree(&self, i: usize) -> usize {
        self.neighbors(i).len()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|i| self.degree(i)).max().unwrap_or(0)
    }

    /// Total undirected edge count.
    pub fn num_edges(&self) -> usize {
        self.nbr.len() / 2
    }
}

/// Check the ER condition (eq. 3.5) for `π` against the natural ordering of
/// `a`: every connected pair must keep its relative order. `π` may map into
/// a padded space (HBMC dummies) — dummies have no edges so they never
/// violate the condition.
pub fn er_condition_holds(a: &Csr, perm: &Perm) -> bool {
    violating_pair(a, perm).is_none()
}

/// First connected pair whose order flips under `π` (diagnostics for
/// tests/CLI); `None` iff the ER condition holds.
pub fn violating_pair(a: &Csr, perm: &Perm) -> Option<(usize, usize)> {
    let adj = Adjacency::from_csr(a);
    for i in 0..adj.n() {
        let pi = perm.new_of_old(i);
        for &j in adj.neighbors(i) {
            let j = j as usize;
            if j <= i {
                continue;
            }
            let pj = perm.new_of_old(j);
            // i < j, so we need π(i) < π(j).
            if pi >= pj {
                return Some((i, j));
            }
        }
    }
    None
}

/// Are two orderings of the same matrix equivalent (identical ordering
/// graphs, §3.1)? I.e. does every connected pair keep the same relative
/// order under `p1` and `p2`?
pub fn orderings_equivalent(a: &Csr, p1: &Perm, p2: &Perm) -> bool {
    let adj = Adjacency::from_csr(a);
    for i in 0..adj.n() {
        let (p1i, p2i) = (p1.new_of_old(i), p2.new_of_old(i));
        for &j in adj.neighbors(i) {
            let j = j as usize;
            if j <= i {
                continue;
            }
            let s1 = p1i < p1.new_of_old(j);
            let s2 = p2i < p2.new_of_old(j);
            if s1 != s2 {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    /// 1D chain 0-1-2-3.
    fn chain(n: usize) -> Csr {
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 2.0);
        }
        for i in 0..n - 1 {
            c.push_sym(i, i + 1, -1.0);
        }
        c.to_csr()
    }

    #[test]
    fn adjacency_of_chain() {
        let a = chain(4);
        let adj = Adjacency::from_csr(&a);
        assert_eq!(adj.neighbors(0), &[1]);
        assert_eq!(adj.neighbors(1), &[0, 2]);
        assert_eq!(adj.num_edges(), 3);
        assert_eq!(adj.max_degree(), 2);
    }

    #[test]
    fn adjacency_symmetrizes_pattern() {
        // Non-symmetric pattern: edge stored one way only.
        let mut c = Coo::new(3);
        c.push(0, 0, 1.0);
        c.push(1, 1, 1.0);
        c.push(2, 2, 1.0);
        c.push(2, 0, 5.0);
        let adj = Adjacency::from_csr(&c.to_csr());
        assert_eq!(adj.neighbors(0), &[2]);
        assert_eq!(adj.neighbors(2), &[0]);
    }

    #[test]
    fn identity_satisfies_er() {
        let a = chain(5);
        assert!(er_condition_holds(&a, &Perm::identity(5)));
    }

    #[test]
    fn swap_of_connected_violates_er() {
        let a = chain(3);
        // Swap nodes 0 and 1 (connected): violates.
        let p = Perm::from_new_of_old(vec![1, 0, 2], 3).unwrap();
        assert!(!er_condition_holds(&a, &p));
        assert_eq!(violating_pair(&a, &p), Some((0, 1)));
    }

    #[test]
    fn swap_of_disconnected_is_equivalent() {
        let _a = chain(4); // 0-1-2-3: nodes 0 and 2 are NOT adjacent
        // Reorder 0 and 2 relative to each other without flipping any edge:
        // new order: 2 < 1? no — must keep 1<2 and 2<3 and 0<1.
        // Take π = identity except move 0 between nowhere — the only safe
        // non-identity for a path is... none adjacent-preserving for 0,2
        // because 0<1<2 forces order. Use a star instead.
        let mut c = Coo::new(4);
        for i in 0..4 {
            c.push(i, i, 2.0);
        }
        c.push_sym(0, 3, -1.0);
        c.push_sym(1, 3, -1.0);
        c.push_sym(2, 3, -1.0);
        let star = c.to_csr();
        // 0,1,2 mutually independent: permute them among themselves.
        let p = Perm::from_new_of_old(vec![2, 0, 1, 3], 4).unwrap();
        assert!(er_condition_holds(&star, &p));
        assert!(orderings_equivalent(&star, &Perm::identity(4), &p));
    }

    #[test]
    fn padded_perm_er() {
        let a = chain(3);
        // Keep order 0<1<2 but spread into 6 slots.
        let p = Perm::padded(vec![0, 2, 5], 6).unwrap();
        assert!(er_condition_holds(&a, &p));
        // Flip 1 and 2 into slots out of order.
        let q = Perm::padded(vec![0, 5, 2], 6).unwrap();
        assert!(!er_condition_holds(&a, &q));
    }
}
