//! Greedy graph coloring — the coloring heuristic the paper uses for all
//! solvers ("the greedy algorithm was used for all the solvers", §5.1).
//!
//! Vertices are visited in natural index order and each takes the smallest
//! color unused by its already-colored neighbors. Deterministic, and kept
//! in lock-step with the python oracle (`python/compile/ordering.py`).

/// Result of a coloring: per-vertex color id in `[0, num_colors)`.
#[derive(Debug, Clone)]
pub struct Coloring {
    pub color: Vec<u32>,
    pub num_colors: usize,
}

impl Coloring {
    /// Vertices grouped by color, preserving index order within a color.
    pub fn groups(&self) -> Vec<Vec<u32>> {
        let mut g = vec![Vec::new(); self.num_colors];
        for (v, &c) in self.color.iter().enumerate() {
            g[c as usize].push(v as u32);
        }
        g
    }

    /// Verify properness against a neighbor oracle.
    pub fn is_proper(&self, neighbors: impl Fn(usize) -> Vec<u32>) -> bool {
        (0..self.color.len()).all(|v| {
            neighbors(v)
                .iter()
                .all(|&u| u as usize == v || self.color[u as usize] != self.color[v])
        })
    }
}

/// Greedy-color `n` vertices given a neighbor oracle.
pub fn greedy_color(n: usize, neighbors: impl Fn(usize) -> Vec<u32>) -> Coloring {
    let mut color = vec![u32::MAX; n];
    let mut used: Vec<u32> = Vec::new(); // scratch: colors used by neighbors
    let mut num_colors = 0usize;
    for v in 0..n {
        used.clear();
        for &u in &neighbors(v) {
            let cu = color[u as usize];
            if cu != u32::MAX {
                used.push(cu);
            }
        }
        used.sort_unstable();
        used.dedup();
        // Smallest color not in `used`.
        let mut c = 0u32;
        for &u in &used {
            if u == c {
                c += 1;
            } else if u > c {
                break;
            }
        }
        color[v] = c;
        num_colors = num_colors.max(c as usize + 1);
    }
    Coloring { color, num_colors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ordering::graph::Adjacency;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn grid5pt(nx: usize, ny: usize) -> Adjacency {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        Adjacency::from_csr(&c.to_csr())
    }

    #[test]
    fn grid_is_two_colorable() {
        let adj = grid5pt(8, 8);
        let col = greedy_color(adj.n(), |v| adj.neighbors(v).to_vec());
        assert_eq!(col.num_colors, 2, "5-pt grid is bipartite → red/black");
        assert!(col.is_proper(|v| adj.neighbors(v).to_vec()));
    }

    #[test]
    fn proper_on_random_graph() {
        let mut rng = Rng::new(17);
        let n = 200;
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 1.0);
            for _ in 0..3 {
                let j = rng.below(n);
                if j != i {
                    c.push_sym(i, j, -1.0);
                }
            }
        }
        let adj = Adjacency::from_csr(&c.to_csr());
        let col = greedy_color(adj.n(), |v| adj.neighbors(v).to_vec());
        assert!(col.is_proper(|v| adj.neighbors(v).to_vec()));
        assert!(col.num_colors <= adj.max_degree() + 1, "greedy bound");
    }

    #[test]
    fn empty_graph_one_color() {
        let col = greedy_color(5, |_| Vec::new());
        assert_eq!(col.num_colors, 1);
        assert!(col.color.iter().all(|&c| c == 0));
    }

    #[test]
    fn groups_partition() {
        let adj = grid5pt(4, 4);
        let col = greedy_color(adj.n(), |v| adj.neighbors(v).to_vec());
        let groups = col.groups();
        let total: usize = groups.iter().map(|g| g.len()).sum();
        assert_eq!(total, 16);
        for g in &groups {
            assert!(g.windows(2).all(|w| w[0] < w[1]), "index order kept");
        }
    }
}
