//! Recursive algebraic coloring for the symmetric SpMV engine (RACE-style,
//! Alappat et al. — see PAPERS.md).
//!
//! The symmetric kernel updates, per stored strict-lower nonzero `(i, j)`,
//! both `y[i]` and `y[j]`: row `i`'s *write set* is `{i} ∪ {j : j < i,
//! a[i][j] ≠ 0}`. Two rows may execute concurrently only if their write
//! sets are disjoint. Since `W(i) ⊆ {i} ∪ N(i)` in the matrix adjacency
//! graph, any overlap between `W(i₁)` and `W(i₂)` forces
//! `dist(i₁, i₂) ≤ 2`; a **distance-2 coloring** (same color ⇒ distance
//! ≥ 3) is therefore exactly sufficient for conflict-freedom.
//!
//! RACE proper recursively bisects BFS level groups and assigns level
//! groups to threads — but a thread-count-dependent schedule can never be
//! bitwise-reproducible across pool widths, which is this repo's
//! acceptance bar (see `tests/fused_parity.rs`). This pass keeps RACE's
//! bandwidth-friendly *traversal* (BFS levels, so same-color rows are
//! close in memory) and its *recursive work subdivision* (per-color rows
//! are split by recursive nnz-halving into grains), but derives the colors
//! with a deterministic greedy distance-2 sweep in BFS-level order —
//! independent of the thread count. Within a color every `y` element has
//! exactly one writing row, so how grains are dealt to threads cannot
//! change any accumulation order: results are bitwise identical across
//! runs *and* thread counts by construction.
//!
//! When the graph colors badly (dense rows ⇒ more than
//! [`crate::solver::spmv::MAX_SYMM_COLORS`] colors), the engine falls back
//! to per-thread scatter buffers combined over [`canonical_blocks`] in
//! fixed block order — see `solver/spmv.rs`.

use std::ops::Range;

use crate::ordering::graph::Adjacency;
use crate::sparse::csr::Csr;

/// Target grain weight (nnz) for the recursive per-color subdivision:
/// small enough that every pool width finds load balance inside one
/// color, large enough to amortize scheduling.
const GRAIN_TARGET_NNZ: usize = 2048;

/// Conflict-free row schedule for the symmetric SpMV kernel: a fixed
/// sequence of colors, each holding rows (ascending) whose write sets are
/// pairwise disjoint, subdivided into contiguous grains for parallel
/// execution.
#[derive(Debug, Clone)]
pub struct RaceSchedule {
    /// Row indices, concatenated color by color; rows ascend within each
    /// color (the canonical order — independent of traversal and threads).
    rows: Vec<u32>,
    /// `rows[color_ptr[c]..color_ptr[c+1]]` is color `c`.
    color_ptr: Vec<usize>,
    /// `rows[grain_ptr[g]..grain_ptr[g+1]]` is grain `g` (grains never
    /// cross a color boundary).
    grain_ptr: Vec<usize>,
    /// Grains of color `c` are `color_grains[c]..color_grains[c+1]`.
    color_grains: Vec<usize>,
}

impl RaceSchedule {
    /// Build the schedule from any CRS whose *pattern* is symmetric (full
    /// or lower-triangular storage give the same adjacency and therefore
    /// the same schedule). Deterministic: no randomness, no dependence on
    /// thread count.
    pub fn build(a: &Csr) -> RaceSchedule {
        let n = a.n();
        let adj = Adjacency::from_csr(a);

        // 1. BFS levels, deterministic roots (lowest unvisited index) and
        //    sorted neighbor expansion.
        let mut level = vec![u32::MAX; n];
        let mut queue = std::collections::VecDeque::new();
        for root in 0..n {
            if level[root] != u32::MAX {
                continue;
            }
            level[root] = 0;
            queue.push_back(root as u32);
            while let Some(u) = queue.pop_front() {
                let lu = level[u as usize];
                for &v in adj.neighbors(u as usize) {
                    if level[v as usize] == u32::MAX {
                        level[v as usize] = lu + 1;
                        queue.push_back(v);
                    }
                }
            }
        }

        // 2. Traversal order: stable sort by (level, index) — RACE's
        //    locality-preserving sweep.
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_by_key(|&v| level[v as usize]);

        // 3. Greedy distance-2 coloring in that order: forbid the colors
        //    of every already-colored vertex within distance ≤ 2.
        let mut color = vec![u32::MAX; n];
        let mut num_colors = 0usize;
        // forbidden[c] == stamp ⇒ color c is taken near the current vertex.
        let mut forbidden: Vec<u32> = Vec::new();
        for (stamp, &v) in order.iter().enumerate() {
            let stamp = stamp as u32 + 1;
            let v = v as usize;
            let mut mark = |u: usize, forbidden: &mut Vec<u32>| {
                let c = color[u];
                if c != u32::MAX {
                    let c = c as usize;
                    if c >= forbidden.len() {
                        forbidden.resize(c + 1, 0);
                    }
                    forbidden[c] = stamp;
                }
            };
            for &u in adj.neighbors(v) {
                mark(u as usize, &mut forbidden);
                for &w in adj.neighbors(u as usize) {
                    mark(w as usize, &mut forbidden);
                }
            }
            let mut c = 0usize;
            while c < forbidden.len() && forbidden[c] == stamp {
                c += 1;
            }
            color[v] = c as u32;
            num_colors = num_colors.max(c + 1);
        }

        // 4. Canonical per-color row lists: ascending by construction
        //    (index sweep), independent of the traversal that colored them.
        let mut count = vec![0usize; num_colors + 1];
        for &c in &color {
            count[c as usize + 1] += 1;
        }
        for c in 0..num_colors {
            count[c + 1] += count[c];
        }
        let color_ptr = count.clone();
        let mut rows = vec![0u32; n];
        let mut cursor = count;
        for i in 0..n {
            let c = color[i] as usize;
            rows[cursor[c]] = i as u32;
            cursor[c] += 1;
        }

        // 5. Recursive nnz-halving grains inside each color (row weight =
        //    its stored-nonzero count; works for full or lower storage).
        let weight = |r: u32| a.row_len(r as usize) + 1;
        let mut grain_ptr = vec![0usize];
        let mut color_grains = vec![0usize];
        for c in 0..num_colors {
            let (lo, hi) = (color_ptr[c], color_ptr[c + 1]);
            split_grains(&rows, lo, hi, &weight, &mut grain_ptr);
            color_grains.push(grain_ptr.len() - 1);
        }

        RaceSchedule { rows, color_ptr, grain_ptr, color_grains }
    }

    pub fn num_colors(&self) -> usize {
        self.color_ptr.len() - 1
    }

    pub fn num_grains(&self) -> usize {
        self.grain_ptr.len() - 1
    }

    /// Rows of color `c`, ascending.
    pub fn color_rows(&self, c: usize) -> &[u32] {
        &self.rows[self.color_ptr[c]..self.color_ptr[c + 1]]
    }

    /// Grain indices belonging to color `c`.
    pub fn grains_of(&self, c: usize) -> Range<usize> {
        self.color_grains[c]..self.color_grains[c + 1]
    }

    /// Rows of grain `g`.
    pub fn grain(&self, g: usize) -> &[u32] {
        &self.rows[self.grain_ptr[g]..self.grain_ptr[g + 1]]
    }

    /// Verify conflict-freedom against a strict-lower structure
    /// (`row_ptr` / `cols` as in [`crate::sparse::symm::SymmCsr`]): within
    /// each color, no `y` element — row index or scattered column — may
    /// have two writers.
    pub fn is_conflict_free(&self, row_ptr: &[u32], cols: &[u32]) -> bool {
        let n = self.rows.len();
        let mut writer = vec![u32::MAX; n];
        for c in 0..self.num_colors() {
            let stamp = c as u32;
            for &i in self.color_rows(c) {
                let iu = i as usize;
                if writer[iu] == stamp {
                    return false;
                }
                writer[iu] = stamp;
                for &j in &cols[row_ptr[iu] as usize..row_ptr[iu + 1] as usize] {
                    let ju = j as usize;
                    if writer[ju] == stamp {
                        return false;
                    }
                    writer[ju] = stamp;
                }
            }
        }
        true
    }
}

/// Recursively halve `rows[lo..hi]` by cumulative weight until each grain
/// is at or below [`GRAIN_TARGET_NNZ`] (or a single row), appending grain
/// end offsets to `grain_ptr` (which must currently end with `lo`… i.e.
/// the caller's running position).
fn split_grains(
    rows: &[u32],
    lo: usize,
    hi: usize,
    weight: &impl Fn(u32) -> usize,
    grain_ptr: &mut Vec<usize>,
) {
    if lo == hi {
        return;
    }
    let total: usize = rows[lo..hi].iter().map(|&r| weight(r)).sum();
    if total <= GRAIN_TARGET_NNZ || hi - lo == 1 {
        grain_ptr.push(hi);
        return;
    }
    // Split at the first prefix reaching half the weight (≥ 1 row on each
    // side).
    let mut acc = 0usize;
    let mut mid = lo;
    for k in lo..hi - 1 {
        acc += weight(rows[k]);
        if acc * 2 >= total {
            mid = k + 1;
            break;
        }
    }
    if mid == lo {
        mid = lo + 1;
    }
    split_grains(rows, lo, mid, weight, grain_ptr);
    split_grains(rows, mid, hi, weight, grain_ptr);
}

/// Fixed, thread-count-independent partition of `0..n` rows into `nb`
/// contiguous nnz-balanced blocks (cumulative-weight bisection on the
/// strict-lower `row_ptr`). This is the canonical block grid for the
/// engine's buffered fallback: each block owns one scatter buffer, and the
/// combine sums buffers in fixed block order — so the result is bitwise
/// identical for every pool width.
pub fn canonical_blocks(row_ptr: &[u32], nb: usize) -> Vec<usize> {
    let n = row_ptr.len() - 1;
    let nnz = *row_ptr.last().unwrap_or(&0) as usize;
    let mut block_ptr = Vec::with_capacity(nb + 1);
    block_ptr.push(0usize);
    for b in 1..nb {
        let target = (nnz * b).div_ceil(nb) as u32;
        // First row boundary at or past the weight target, kept monotone
        // with the previous block boundary.
        let pos = row_ptr.partition_point(|&p| p < target).min(n).max(block_ptr[b - 1]);
        block_ptr.push(pos);
    }
    block_ptr.push(n);
    block_ptr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::symm::SymmCsr;
    use crate::util::rng::Rng;

    fn random_sym(n: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(n);
        for i in 0..n {
            coo.push(i, i, 4.0 + rng.f64());
            for _ in 0..4 {
                let j = rng.below(n);
                if j != i {
                    coo.push_sym(i, j, -0.1 * rng.f64());
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn schedule_covers_every_row_once() {
        let a = random_sym(200, 11);
        let s = RaceSchedule::build(&a);
        let mut seen = vec![false; a.n()];
        for c in 0..s.num_colors() {
            let rows = s.color_rows(c);
            assert!(rows.windows(2).all(|w| w[0] < w[1]), "rows ascend within color");
            for &r in rows {
                assert!(!seen[r as usize]);
                seen[r as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
        // Grains tile the same sequence.
        let mut flat = Vec::new();
        for g in 0..s.num_grains() {
            flat.extend_from_slice(s.grain(g));
        }
        let mut by_color = Vec::new();
        for c in 0..s.num_colors() {
            assert_eq!(
                s.grains_of(c).map(|g| s.grain(g).len()).sum::<usize>(),
                s.color_rows(c).len()
            );
            by_color.extend_from_slice(s.color_rows(c));
        }
        assert_eq!(flat, by_color);
    }

    #[test]
    fn schedule_is_conflict_free() {
        for seed in [1u64, 5, 9] {
            let a = random_sym(300, seed);
            let s = RaceSchedule::build(&a);
            let m = SymmCsr::from_csr(&a).unwrap();
            assert!(s.is_conflict_free(m.row_ptr(), m.cols()), "seed {seed}");
        }
    }

    #[test]
    fn lower_storage_yields_identical_schedule() {
        let a = random_sym(150, 21);
        let full = RaceSchedule::build(&a);
        let lower = RaceSchedule::build(&a.lower());
        assert_eq!(full.rows, lower.rows);
        assert_eq!(full.color_ptr, lower.color_ptr);
    }

    #[test]
    fn conflict_detector_catches_violation() {
        // A path 0–1–2: rows 1 and 2 both write y[1] (row 2's lower col 1,
        // row 1 itself), so a schedule putting them in one color must fail.
        let mut coo = Coo::new(3);
        for i in 0..3 {
            coo.push(i, i, 2.0);
        }
        coo.push_sym(1, 0, -1.0);
        coo.push_sym(2, 1, -1.0);
        let a = coo.to_csr();
        let m = SymmCsr::from_csr(&a).unwrap();
        let bad = RaceSchedule {
            rows: vec![1, 2, 0],
            color_ptr: vec![0, 2, 3],
            grain_ptr: vec![0, 2, 3],
            color_grains: vec![0, 1, 2],
        };
        assert!(!bad.is_conflict_free(m.row_ptr(), m.cols()));
        let good = RaceSchedule::build(&a);
        assert!(good.is_conflict_free(m.row_ptr(), m.cols()));
    }

    #[test]
    fn canonical_blocks_tile_and_balance() {
        let a = random_sym(500, 33);
        let m = SymmCsr::from_csr(&a).unwrap();
        let bp = canonical_blocks(m.row_ptr(), 8);
        assert_eq!(bp.len(), 9);
        assert_eq!(bp[0], 0);
        assert_eq!(*bp.last().unwrap(), a.n());
        assert!(bp.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn tridiagonal_colors_like_a_path_power() {
        // Path graph: distance-2 coloring of a path needs exactly 3 colors
        // (its square is a union of short cliques).
        let n = 64;
        let mut coo = Coo::new(n);
        for i in 0..n {
            coo.push(i, i, 2.0);
        }
        for i in 1..n {
            coo.push_sym(i, i - 1, -1.0);
        }
        let a = coo.to_csr();
        let s = RaceSchedule::build(&a);
        assert_eq!(s.num_colors(), 3);
    }
}
