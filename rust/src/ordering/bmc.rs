//! Block multi-color ordering (BMC) — Iwashita, Nakashima & Takahashi,
//! IPDPS 2012 (the paper's ref. [13]); the baseline HBMC builds on.
//!
//! Nodes are grouped into blocks of `bs` (min-index heuristic, see
//! [`crate::ordering::blocking`]), the block quotient graph is greedy-
//! colored, and unknowns are renumbered color-by-color, block-by-block,
//! preserving pick-up order inside each block. Short blocks are padded to
//! exactly `bs` with decoupled dummy unknowns so every color occupies a
//! multiple of `bs` rows — this keeps BMC and HBMC the *same* augmented
//! linear system, making their iteration-by-iteration equivalence exact.

use crate::ordering::blocking::{block_graph, build_blocks, Blocking};
use crate::ordering::coloring::greedy_color;
use crate::ordering::graph::Adjacency;
use crate::ordering::perm::Perm;
use crate::sparse::csr::Csr;

/// BMC ordering result.
#[derive(Debug, Clone)]
pub struct BmcOrdering {
    /// Original → BMC-ordered augmented index (`n_new` a multiple of `bs`).
    pub perm: Perm,
    pub bs: usize,
    pub num_colors: usize,
    /// Row range of color `c`: `color_ptr[c]..color_ptr[c+1]`; multiples of `bs`.
    pub color_ptr: Vec<usize>,
    /// Number of blocks in each color.
    pub blocks_per_color: Vec<usize>,
}

impl BmcOrdering {
    /// Augmented dimension.
    pub fn n(&self) -> usize {
        self.perm.n_new()
    }

    /// Total number of `bs`-sized blocks.
    pub fn num_blocks(&self) -> usize {
        self.blocks_per_color.iter().sum()
    }
}

/// Apply BMC with block size `bs` to the pattern of `a`.
pub fn bmc_order(a: &Csr, bs: usize) -> BmcOrdering {
    let adj = Adjacency::from_csr(a);
    let blocking = build_blocks(&adj, bs);
    bmc_order_with_blocking(&adj, &blocking)
}

/// BMC given a precomputed blocking (shared with HBMC so both orderings use
/// the identical block structure).
pub fn bmc_order_with_blocking(adj: &Adjacency, blocking: &Blocking) -> BmcOrdering {
    let bs = blocking.bs;
    let bg = block_graph(adj, blocking);
    let coloring = greedy_color(blocking.blocks.len(), |b| bg[b].clone());
    let groups = coloring.groups(); // block ids per color, creation order

    let n_new: usize = groups.iter().map(|g| g.len() * bs).sum();
    let mut new_of_old = vec![0u32; adj.n()];
    let mut color_ptr = Vec::with_capacity(groups.len() + 1);
    let mut blocks_per_color = Vec::with_capacity(groups.len());
    color_ptr.push(0usize);
    let mut next = 0usize;
    for g in &groups {
        for &b in g {
            let block = &blocking.blocks[b as usize];
            for (slot, &v) in block.iter().enumerate() {
                new_of_old[v as usize] = (next + slot) as u32;
            }
            next += bs; // short blocks leave dummy slots at the tail
        }
        color_ptr.push(next);
        blocks_per_color.push(g.len());
    }
    BmcOrdering {
        perm: Perm::padded(new_of_old, n_new).expect("bmc perm is injective"),
        bs,
        num_colors: coloring.num_colors,
        color_ptr,
        blocks_per_color,
    }
}

/// Assert the BMC independence invariant on the reordered matrix: within a
/// color, entries never connect two *different* blocks. Returns the first
/// violating entry for diagnostics.
pub fn check_block_independence(b: &Csr, ord: &BmcOrdering) -> Option<(usize, usize)> {
    for c in 0..ord.num_colors {
        let (lo, hi) = (ord.color_ptr[c], ord.color_ptr[c + 1]);
        for i in lo..hi {
            let blk_i = (i - lo) / ord.bs;
            let (cols, _) = b.row(i);
            for &j in cols {
                let j = j as usize;
                if j != i && j >= lo && j < hi && (j - lo) / ord.bs != blk_i {
                    return Some((i, j));
                }
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ordering::graph::er_condition_holds;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn grid(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    fn random_spd(n: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 8.0);
            for _ in 0..2 {
                let j = rng.below(n);
                if j != i {
                    c.push_sym(i, j, -0.5);
                }
            }
        }
        c.to_csr()
    }

    #[test]
    fn block_independence_on_grid() {
        let a = grid(10, 10);
        let ord = bmc_order(&a, 4);
        let b = a.permute_sym(&ord.perm);
        assert_eq!(check_block_independence(&b, &ord), None);
        assert_eq!(ord.n() % 4, 0);
        assert_eq!(*ord.color_ptr.last().unwrap(), ord.n());
    }

    #[test]
    fn block_independence_on_random() {
        for seed in [1, 2, 3] {
            let a = random_spd(150, seed);
            for &bs in &[2usize, 8, 16] {
                let ord = bmc_order(&a, bs);
                let b = a.permute_sym(&ord.perm);
                assert_eq!(check_block_independence(&b, &ord), None, "seed={seed} bs={bs}");
            }
        }
    }

    #[test]
    fn colors_counted_and_ranges_multiple_of_bs() {
        let a = grid(12, 12);
        let ord = bmc_order(&a, 8);
        assert!(ord.num_colors >= 2);
        for c in 0..ord.num_colors {
            assert_eq!((ord.color_ptr[c + 1] - ord.color_ptr[c]) % 8, 0);
            assert_eq!(ord.color_ptr[c + 1] - ord.color_ptr[c], 8 * ord.blocks_per_color[c]);
        }
    }

    #[test]
    fn fewer_colors_than_nodal_mc_keeps_er_within_blocks() {
        // BMC itself is NOT equivalent to natural ordering — but pick-up
        // order inside blocks must be preserved relative to... nothing to
        // check against natural order. Instead check perm validity.
        let a = grid(8, 8);
        let ord = bmc_order(&a, 4);
        assert_eq!(ord.perm.n_old(), 64);
        // Every real node mapped, dummies only in short blocks.
        let mapped: std::collections::HashSet<usize> =
            (0..64).map(|i| ord.perm.new_of_old(i)).collect();
        assert_eq!(mapped.len(), 64);
    }

    #[test]
    fn bmc_is_equivalent_to_itself_padded() {
        // Sanity: the identity secondary reordering satisfies ER on the
        // BMC-ordered matrix.
        let a = random_spd(80, 9);
        let ord = bmc_order(&a, 8);
        let b = a.permute_sym(&ord.perm);
        assert!(er_condition_holds(&b, &Perm::identity(b.n())));
    }
}
