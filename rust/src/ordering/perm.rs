//! Permutations (reorderings) of unknowns, possibly into a *larger* index
//! space: HBMC pads each color to a multiple of `bs·w` with decoupled
//! "dummy unknowns" (paper §4.3), which we model as injective maps
//! `old → new` with identity rows on the unused new slots.

use crate::error::{HbmcError, Result};

/// Sentinel marking a padded (dummy) slot in `old_of_new`.
pub const DUMMY: u32 = u32::MAX;

/// Injective index map `π : [0, n_old) → [0, n_new)`, `n_old ≤ n_new`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Perm {
    new_of_old: Vec<u32>,
    old_of_new: Vec<u32>,
}

impl Perm {
    /// Identity permutation.
    pub fn identity(n: usize) -> Perm {
        Perm {
            new_of_old: (0..n as u32).collect(),
            old_of_new: (0..n as u32).collect(),
        }
    }

    /// Build from the `old → new` map; must be a bijection on `[0, n_new)`.
    pub fn from_new_of_old(new_of_old: Vec<u32>, n_new: usize) -> Result<Perm> {
        Self::padded(new_of_old, n_new)
    }

    /// Build from an injective `old → new` map into `[0, n_new)`; slots not
    /// hit become dummies.
    pub fn padded(new_of_old: Vec<u32>, n_new: usize) -> Result<Perm> {
        if new_of_old.len() > n_new {
            return Err(HbmcError::Internal(format!(
                "perm: n_old {} exceeds n_new {}",
                new_of_old.len(),
                n_new
            )));
        }
        let mut old_of_new = vec![DUMMY; n_new];
        for (old, &new) in new_of_old.iter().enumerate() {
            if new as usize >= n_new {
                return Err(HbmcError::Internal(format!(
                    "perm: image {new} out of range {n_new}"
                )));
            }
            if old_of_new[new as usize] != DUMMY {
                return Err(HbmcError::Internal(format!("perm: image {new} hit twice")));
            }
            old_of_new[new as usize] = old as u32;
        }
        Ok(Perm { new_of_old, old_of_new })
    }

    #[inline]
    pub fn n_old(&self) -> usize {
        self.new_of_old.len()
    }

    #[inline]
    pub fn n_new(&self) -> usize {
        self.old_of_new.len()
    }

    #[inline]
    pub fn new_of_old(&self, old: usize) -> usize {
        self.new_of_old[old] as usize
    }

    /// Old index occupying new slot `new`, or `None` for a dummy slot.
    #[inline]
    pub fn old_of_new(&self, new: usize) -> Option<usize> {
        match self.old_of_new[new] {
            DUMMY => None,
            o => Some(o as usize),
        }
    }

    pub fn new_of_old_slice(&self) -> &[u32] {
        &self.new_of_old
    }

    /// Is `π` the identity on an unpadded space?
    pub fn is_identity(&self) -> bool {
        self.n_old() == self.n_new()
            && self.new_of_old.iter().enumerate().all(|(i, &p)| i as u32 == p)
    }

    /// Compose: `self` then `next` (`next ∘ self`); `next` must act on
    /// `self`'s image space.
    pub fn then(&self, next: &Perm) -> Perm {
        assert_eq!(next.n_old(), self.n_new(), "composition domain mismatch");
        let new_of_old: Vec<u32> = self
            .new_of_old
            .iter()
            .map(|&m| next.new_of_old[m as usize])
            .collect();
        Perm::padded(new_of_old, next.n_new()).expect("composition of injective maps")
    }

    /// Scatter a vector into the new index space (dummies get `fill`).
    pub fn apply_vec(&self, x: &[f64], fill: f64) -> Vec<f64> {
        assert_eq!(x.len(), self.n_old());
        let mut y = vec![fill; self.n_new()];
        for (old, &new) in self.new_of_old.iter().enumerate() {
            y[new as usize] = x[old];
        }
        y
    }

    /// Gather a vector back from the new index space (inverse of
    /// [`Perm::apply_vec`], dropping dummy slots).
    pub fn unapply_vec(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(y.len(), self.n_new());
        self.new_of_old.iter().map(|&new| y[new as usize]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_roundtrip() {
        let p = Perm::identity(5);
        assert!(p.is_identity());
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(p.unapply_vec(&p.apply_vec(&x, 0.0)), x);
    }

    #[test]
    fn bijection_validation() {
        assert!(Perm::from_new_of_old(vec![0, 0], 2).is_err());
        assert!(Perm::from_new_of_old(vec![0, 5], 2).is_err());
        assert!(Perm::from_new_of_old(vec![1, 0], 2).is_ok());
        assert!(Perm::padded(vec![0, 1, 2], 2).is_err());
    }

    #[test]
    fn padded_map() {
        let p = Perm::padded(vec![3, 0], 4).unwrap();
        assert_eq!(p.n_old(), 2);
        assert_eq!(p.n_new(), 4);
        assert_eq!(p.new_of_old(0), 3);
        assert_eq!(p.old_of_new(3), Some(0));
        assert_eq!(p.old_of_new(1), None);
        let y = p.apply_vec(&[7.0, 8.0], 0.0);
        assert_eq!(y, vec![8.0, 0.0, 0.0, 7.0]);
        assert_eq!(p.unapply_vec(&y), vec![7.0, 8.0]);
    }

    #[test]
    fn composition() {
        let a = Perm::from_new_of_old(vec![1, 0, 2], 3).unwrap();
        let b = Perm::padded(vec![2, 0, 3], 4).unwrap();
        let c = a.then(&b);
        // old 0 -> 1 -> 0 ; old 1 -> 0 -> 2 ; old 2 -> 2 -> 3
        assert_eq!(c.new_of_old(0), 0);
        assert_eq!(c.new_of_old(1), 2);
        assert_eq!(c.new_of_old(2), 3);
        assert_eq!(c.n_new(), 4);
    }
}
