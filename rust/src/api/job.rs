//! Asynchronous job handles for the [`SolverService`] queue.
//!
//! [`SolverService::submit`] enqueues one right-hand side and returns a
//! [`JobHandle`] immediately; the dispatcher thread (see `api::queue`)
//! later runs the job — possibly coalesced with other jobs for the same
//! plan into one micro-batch — and publishes the result here. A handle
//! supports:
//!
//! * [`poll`](JobHandle::poll) — non-blocking state inspection,
//! * [`wait`](JobHandle::wait) — block until terminal, consuming the
//!   handle and yielding the solve's `Result<SolveOutput>`,
//! * [`cancel`](JobHandle::cancel) — abort a job that is **still queued**
//!   (running jobs always finish; cancelling them is a no-op).
//!
//! A per-job deadline (`SolveRequest::deadline`) is checked at dispatch
//! time: a job still queued when its deadline passes is *shed* — it fails
//! with [`HbmcError::DeadlineExceeded`] instead of running. (A deadline
//! that is already zero at submission never reaches the queue; `submit`
//! rejects it synchronously.)
//!
//! A `JobCore` additionally carries the observability and admission state
//! attached at submission: its submit timestamp (queue-wait histogram),
//! an optional [`InflightGuard`] holding one slot of the handle's
//! `max_inflight_per_handle` quota (released at the first terminal
//! transition, with `Drop` as a backstop), and an optional reference to
//! the service's `TraceRecorder` when this job was sampled for lifecycle
//! tracing.
//!
//! [`SolverService`]: crate::api::SolverService
//! [`SolverService::submit`]: crate::api::SolverService::submit

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use super::service::mlock;
use crate::coordinator::session::SolveOutput;
use crate::error::{HbmcError, Result};
use crate::obs::trace::{stage, TraceRecorder};

/// Lifecycle of an asynchronous solve job.
///
/// `Queued → Running → Succeeded | Failed` is the normal path;
/// `Cancelled` and `DeadlineExceeded` are terminal states a job can reach
/// only from `Queued` (running jobs always finish).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Waiting in the service queue for the dispatcher.
    Queued,
    /// Dispatched into a batch; the solver is (or is about to be) running.
    Running,
    /// Finished; `wait()` yields `Ok(SolveOutput)`.
    Succeeded,
    /// Finished; `wait()` yields the solve's typed error.
    Failed,
    /// Cancelled while queued; `wait()` yields [`HbmcError::Cancelled`].
    Cancelled,
    /// Deadline expired while queued; `wait()` yields
    /// [`HbmcError::DeadlineExceeded`].
    DeadlineExceeded,
}

impl JobState {
    /// Whether the job has reached a final state (its result is available).
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }
}

/// Process-wide job id allocator. Relaxed suffices: ids only need to be
/// unique (atomicity), nothing is ordered by them.
static NEXT_JOB_ID: AtomicU64 = AtomicU64::new(1);

struct Slot {
    state: JobState,
    /// Present exactly from the transition into a terminal state until
    /// `wait()` takes it.
    result: Option<Result<SolveOutput>>,
}

/// One slot of a handle's `max_inflight_per_handle` quota, held from
/// submission until the job reaches a terminal state.
///
/// Release is idempotent (an atomic swap guards the decrement) and happens
/// at the terminal transition *under the job's slot lock, before the
/// condvar notification* — so by the time a waiter observes the terminal
/// state, the slot is free and an immediate resubmit cannot spuriously see
/// the quota still full. `Drop` is only a backstop for jobs that die
/// without a terminal transition (e.g. a future panic path).
pub(crate) struct InflightGuard {
    slots: Arc<AtomicUsize>,
    released: AtomicBool,
}

impl InflightGuard {
    /// Claim one slot against `limit`, or return the occupancy that made
    /// the claim fail. Lock-free CAS loop: concurrent submits race for the
    /// last slot and exactly one wins.
    pub(crate) fn acquire(
        slots: &Arc<AtomicUsize>,
        limit: usize,
    ) -> std::result::Result<InflightGuard, usize> {
        let mut current = slots.load(AtomicOrdering::Relaxed);
        loop {
            if current >= limit {
                return Err(current);
            }
            match slots.compare_exchange_weak(
                current,
                current + 1,
                AtomicOrdering::AcqRel,
                AtomicOrdering::Relaxed,
            ) {
                Ok(_) => {
                    return Ok(InflightGuard {
                        slots: Arc::clone(slots),
                        released: AtomicBool::new(false),
                    })
                }
                Err(observed) => current = observed,
            }
        }
    }

    /// Return the slot (idempotent; see type docs).
    fn release(&self) {
        if !self.released.swap(true, AtomicOrdering::AcqRel) {
            self.slots.fetch_sub(1, AtomicOrdering::AcqRel);
        }
    }
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.release();
    }
}

/// State shared between a [`JobHandle`] and the dispatcher.
pub(crate) struct JobCore {
    id: u64,
    slot: Mutex<Slot>,
    cv: Condvar,
    /// Absolute dispatch deadline, derived from the submitted budget.
    deadline: Option<Instant>,
    /// The originally requested budget (for the error message).
    budget: Option<Duration>,
    /// Submission timestamp (queue-wait histogram; trace ordering).
    submitted_at: Instant,
    /// Held slot of the handle's in-flight quota, if one is configured.
    inflight: Option<InflightGuard>,
    /// The service's trace ring when this job was sampled; `None` (the
    /// common case) costs one pointer check per lifecycle transition.
    trace: Option<Arc<TraceRecorder>>,
}

impl JobCore {
    pub(crate) fn new(
        budget: Option<Duration>,
        inflight: Option<InflightGuard>,
        trace: Option<Arc<TraceRecorder>>,
    ) -> Arc<JobCore> {
        Arc::new(JobCore {
            id: NEXT_JOB_ID.fetch_add(1, AtomicOrdering::Relaxed),
            slot: Mutex::new(Slot { state: JobState::Queued, result: None }),
            cv: Condvar::new(),
            // checked_add: a huge budget (e.g. Duration::MAX as a "no
            // deadline" sentinel) saturates to no deadline instead of
            // panicking in `submit`.
            deadline: budget.and_then(|d| Instant::now().checked_add(d)),
            budget,
            submitted_at: Instant::now(),
            inflight,
            trace,
        })
    }

    /// How long this job has been (or was) queued since submission.
    pub(crate) fn queue_wait(&self) -> Duration {
        self.submitted_at.elapsed()
    }

    /// Record a lifecycle event if this job is being traced.
    pub(crate) fn note(&self, stage: &'static str) {
        if let Some(t) = &self.trace {
            t.record(self.id, stage, String::new());
        }
    }

    /// Like [`note`](JobCore::note) with a detail string; the closure runs
    /// only when the job is actually traced.
    pub(crate) fn note_with(&self, stage: &'static str, detail: impl FnOnce() -> String) {
        if let Some(t) = &self.trace {
            t.record(self.id, stage, detail());
        }
    }

    /// Release admission state at a terminal transition. Must be called
    /// while still holding the slot lock (see [`InflightGuard`]).
    fn settle(&self) {
        if let Some(g) = &self.inflight {
            g.release();
        }
    }

    pub(crate) fn state(&self) -> JobState {
        mlock(&self.slot).state
    }

    /// Whether this job carries a dispatch deadline (drives the
    /// dispatcher's flush-early policy for latency-sensitive jobs).
    pub(crate) fn has_deadline(&self) -> bool {
        self.deadline.is_some()
    }

    /// Whether the job's deadline has already passed — the retry ladder's
    /// gate: a recovery attempt must not start on borrowed time.
    pub(crate) fn past_deadline(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Dispatcher entry check: flip `Queued → Running` and return `true`,
    /// unless the job was cancelled meanwhile (skip it) or its deadline
    /// has passed (fail it here, typed, without running).
    pub(crate) fn try_start(&self) -> bool {
        let mut slot = mlock(&self.slot);
        if slot.state != JobState::Queued {
            return false;
        }
        if let Some(deadline) = self.deadline {
            if Instant::now() >= deadline {
                slot.state = JobState::DeadlineExceeded;
                slot.result = Some(Err(HbmcError::DeadlineExceeded {
                    budget: self.budget.unwrap_or_default(),
                }));
                self.settle();
                self.note(stage::SHED);
                drop(slot);
                self.cv.notify_all();
                return false;
            }
        }
        slot.state = JobState::Running;
        self.note(stage::DISPATCHED);
        true
    }

    /// Publish the result of a job previously started with
    /// [`try_start`](JobCore::try_start).
    pub(crate) fn finish(&self, result: Result<SolveOutput>) {
        let mut slot = mlock(&self.slot);
        if slot.state != JobState::Running {
            return;
        }
        match &result {
            Ok(_) => self.note(stage::COMPLETED),
            Err(e) => self.note_with(stage::FAILED, || e.to_string()),
        }
        slot.state = if result.is_ok() { JobState::Succeeded } else { JobState::Failed };
        slot.result = Some(result);
        self.settle();
        drop(slot);
        self.cv.notify_all();
    }

    /// The single `Queued → Cancelled` transition, shared by
    /// [`JobHandle::cancel`] and the shutdown-reject path in the queue.
    /// Returns whether the transition happened (`false` once the job is
    /// running or terminal).
    pub(crate) fn cancel_queued(&self) -> bool {
        let mut slot = mlock(&self.slot);
        if slot.state != JobState::Queued {
            return false;
        }
        slot.state = JobState::Cancelled;
        slot.result = Some(Err(HbmcError::Cancelled));
        self.settle();
        self.note(stage::CANCELLED);
        drop(slot);
        self.cv.notify_all();
        true
    }
}

/// Handle to one submitted solve job; see module docs. Obtained from
/// `SolverService::submit`. Dropping the handle without calling
/// [`wait`](JobHandle::wait) abandons the result but never the job — an
/// already-queued job still runs (or is skipped via `cancel`).
pub struct JobHandle {
    core: Arc<JobCore>,
}

impl JobHandle {
    pub(crate) fn new(core: Arc<JobCore>) -> JobHandle {
        JobHandle { core }
    }

    /// Unique id of this job (diagnostics, log correlation).
    pub fn id(&self) -> u64 {
        self.core.id
    }

    /// Non-blocking snapshot of the job's state.
    pub fn poll(&self) -> JobState {
        self.core.state()
    }

    /// Abort the job if it is still queued: it will never run, and
    /// [`wait`](JobHandle::wait) returns [`HbmcError::Cancelled`]. Returns
    /// `false` (and changes nothing) once the job is running or terminal —
    /// in-flight solves always finish.
    pub fn cancel(&self) -> bool {
        self.core.cancel_queued()
    }

    /// Block until the job reaches a terminal state and return its result.
    /// Consumes the handle — a job's output is moved out exactly once.
    pub fn wait(self) -> Result<SolveOutput> {
        let mut slot = mlock(&self.core.slot);
        while !slot.state.is_terminal() {
            slot = self.core.cv.wait(slot).unwrap_or_else(PoisonError::into_inner);
        }
        slot.result
            .take()
            .unwrap_or_else(|| Err(HbmcError::Internal("job result already consumed".into())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_queued_running_finished() {
        let core = JobCore::new(None, None, None);
        let handle = JobHandle::new(Arc::clone(&core));
        assert_eq!(handle.poll(), JobState::Queued);
        assert!(!JobState::Queued.is_terminal() && !JobState::Running.is_terminal());
        assert!(core.try_start());
        assert_eq!(handle.poll(), JobState::Running);
        assert!(!handle.cancel(), "running jobs must not be cancellable");
        assert!(!core.try_start(), "a job starts at most once");
        // A finished job is terminal and hands its (here: failed) result out.
        core.finish(Err(HbmcError::Internal("kernel exploded".into())));
        assert_eq!(handle.poll(), JobState::Failed);
        assert!(matches!(handle.wait(), Err(HbmcError::Internal(_))));
    }

    #[test]
    fn cancel_wins_over_dispatch() {
        let core = JobCore::new(None, None, None);
        let handle = JobHandle::new(Arc::clone(&core));
        assert!(handle.cancel());
        assert!(!handle.cancel(), "second cancel is a no-op");
        assert!(!core.try_start(), "dispatcher must skip a cancelled job");
        assert_eq!(handle.poll(), JobState::Cancelled);
        assert!(matches!(handle.wait(), Err(HbmcError::Cancelled)));
    }

    #[test]
    fn expired_deadline_fails_at_dispatch() {
        let core = JobCore::new(Some(Duration::ZERO), None, None);
        let handle = JobHandle::new(Arc::clone(&core));
        assert!(!core.try_start(), "expired job must not start");
        assert_eq!(handle.poll(), JobState::DeadlineExceeded);
        assert!(matches!(handle.wait(), Err(HbmcError::DeadlineExceeded { .. })));
    }

    #[test]
    fn inflight_guard_bounds_and_releases_idempotently() {
        let slots = Arc::new(AtomicUsize::new(0));
        let g1 = InflightGuard::acquire(&slots, 2).unwrap();
        let _g2 = InflightGuard::acquire(&slots, 2).unwrap();
        assert_eq!(InflightGuard::acquire(&slots, 2).unwrap_err(), 2, "quota full");
        g1.release();
        g1.release(); // idempotent: a second release must not double-free
        assert_eq!(slots.load(AtomicOrdering::Relaxed), 1);
        let g3 = InflightGuard::acquire(&slots, 2).unwrap();
        drop(g3); // Drop is the backstop release path
        assert_eq!(slots.load(AtomicOrdering::Relaxed), 1);
        drop(g1); // already released explicitly — Drop must not decrement again
        assert_eq!(slots.load(AtomicOrdering::Relaxed), 1);
    }

    #[test]
    fn terminal_transitions_release_the_quota_slot() {
        let slots = Arc::new(AtomicUsize::new(0));
        // finish() releases.
        let core = JobCore::new(None, Some(InflightGuard::acquire(&slots, 1).unwrap()), None);
        assert!(InflightGuard::acquire(&slots, 1).is_err(), "slot held while queued");
        assert!(core.try_start());
        core.finish(Err(HbmcError::Cancelled));
        assert_eq!(slots.load(AtomicOrdering::Relaxed), 0, "finish frees the slot");
        // cancel_queued() releases, even with the handle still alive.
        let core = JobCore::new(None, Some(InflightGuard::acquire(&slots, 1).unwrap()), None);
        let handle = JobHandle::new(Arc::clone(&core));
        assert!(core.cancel_queued());
        assert_eq!(slots.load(AtomicOrdering::Relaxed), 0, "cancel frees the slot");
        drop(handle);
        // expired-deadline shedding releases.
        let core = JobCore::new(
            Some(Duration::ZERO),
            Some(InflightGuard::acquire(&slots, 1).unwrap()),
            None,
        );
        assert!(!core.try_start());
        assert_eq!(slots.load(AtomicOrdering::Relaxed), 0, "shed frees the slot");
        drop(core);
        assert_eq!(slots.load(AtomicOrdering::Relaxed), 0, "Drop backstop is idempotent");
    }

    #[test]
    fn traced_job_records_its_lifecycle() {
        let trace = Arc::new(TraceRecorder::new(16));
        let core = JobCore::new(None, None, Some(Arc::clone(&trace)));
        assert!(core.try_start());
        core.finish(Err(HbmcError::Internal("boom".into())));
        let stages: Vec<&str> = trace.events().iter().map(|e| e.stage).collect();
        assert_eq!(stages, vec!["dispatched", "failed"]);
        assert!(trace.events()[1].detail.contains("boom"));
        // Untraced jobs record nothing.
        let silent = JobCore::new(None, None, None);
        assert!(silent.try_start());
        silent.finish(Err(HbmcError::Cancelled));
        assert_eq!(trace.len(), 2);
        assert!(silent.queue_wait() > Duration::ZERO);
    }
}
