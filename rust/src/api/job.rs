//! Asynchronous job handles for the [`SolverService`] queue.
//!
//! [`SolverService::submit`] enqueues one right-hand side and returns a
//! [`JobHandle`] immediately; the dispatcher thread (see `api::queue`)
//! later runs the job — possibly coalesced with other jobs for the same
//! plan into one micro-batch — and publishes the result here. A handle
//! supports:
//!
//! * [`poll`](JobHandle::poll) — non-blocking state inspection,
//! * [`wait`](JobHandle::wait) — block until terminal, consuming the
//!   handle and yielding the solve's `Result<SolveOutput>`,
//! * [`cancel`](JobHandle::cancel) — abort a job that is **still queued**
//!   (running jobs always finish; cancelling them is a no-op).
//!
//! A per-job deadline (`SolveRequest::deadline`) is checked at dispatch
//! time: a job still queued when its deadline passes fails with
//! [`HbmcError::DeadlineExceeded`] instead of running.
//!
//! [`SolverService`]: crate::api::SolverService
//! [`SolverService::submit`]: crate::api::SolverService::submit

use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use super::service::mlock;
use crate::coordinator::session::SolveOutput;
use crate::error::{HbmcError, Result};

/// Lifecycle of an asynchronous solve job.
///
/// `Queued → Running → Succeeded | Failed` is the normal path;
/// `Cancelled` and `DeadlineExceeded` are terminal states a job can reach
/// only from `Queued` (running jobs always finish).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Waiting in the service queue for the dispatcher.
    Queued,
    /// Dispatched into a batch; the solver is (or is about to be) running.
    Running,
    /// Finished; `wait()` yields `Ok(SolveOutput)`.
    Succeeded,
    /// Finished; `wait()` yields the solve's typed error.
    Failed,
    /// Cancelled while queued; `wait()` yields [`HbmcError::Cancelled`].
    Cancelled,
    /// Deadline expired while queued; `wait()` yields
    /// [`HbmcError::DeadlineExceeded`].
    DeadlineExceeded,
}

impl JobState {
    /// Whether the job has reached a final state (its result is available).
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }
}

/// Process-wide job id allocator. Relaxed suffices: ids only need to be
/// unique (atomicity), nothing is ordered by them.
static NEXT_JOB_ID: AtomicU64 = AtomicU64::new(1);

struct Slot {
    state: JobState,
    /// Present exactly from the transition into a terminal state until
    /// `wait()` takes it.
    result: Option<Result<SolveOutput>>,
}

/// State shared between a [`JobHandle`] and the dispatcher.
pub(crate) struct JobCore {
    id: u64,
    slot: Mutex<Slot>,
    cv: Condvar,
    /// Absolute dispatch deadline, derived from the submitted budget.
    deadline: Option<Instant>,
    /// The originally requested budget (for the error message).
    budget: Option<Duration>,
}

impl JobCore {
    pub(crate) fn new(budget: Option<Duration>) -> Arc<JobCore> {
        Arc::new(JobCore {
            id: NEXT_JOB_ID.fetch_add(1, AtomicOrdering::Relaxed),
            slot: Mutex::new(Slot { state: JobState::Queued, result: None }),
            cv: Condvar::new(),
            // checked_add: a huge budget (e.g. Duration::MAX as a "no
            // deadline" sentinel) saturates to no deadline instead of
            // panicking in `submit`.
            deadline: budget.and_then(|d| Instant::now().checked_add(d)),
            budget,
        })
    }

    pub(crate) fn state(&self) -> JobState {
        mlock(&self.slot).state
    }

    /// Whether this job carries a dispatch deadline (drives the
    /// dispatcher's flush-early policy for latency-sensitive jobs).
    pub(crate) fn has_deadline(&self) -> bool {
        self.deadline.is_some()
    }

    /// Dispatcher entry check: flip `Queued → Running` and return `true`,
    /// unless the job was cancelled meanwhile (skip it) or its deadline
    /// has passed (fail it here, typed, without running).
    pub(crate) fn try_start(&self) -> bool {
        let mut slot = mlock(&self.slot);
        if slot.state != JobState::Queued {
            return false;
        }
        if let Some(deadline) = self.deadline {
            if Instant::now() >= deadline {
                slot.state = JobState::DeadlineExceeded;
                slot.result = Some(Err(HbmcError::DeadlineExceeded {
                    budget: self.budget.unwrap_or_default(),
                }));
                drop(slot);
                self.cv.notify_all();
                return false;
            }
        }
        slot.state = JobState::Running;
        true
    }

    /// Publish the result of a job previously started with
    /// [`try_start`](JobCore::try_start).
    pub(crate) fn finish(&self, result: Result<SolveOutput>) {
        let mut slot = mlock(&self.slot);
        if slot.state != JobState::Running {
            return;
        }
        slot.state = if result.is_ok() { JobState::Succeeded } else { JobState::Failed };
        slot.result = Some(result);
        drop(slot);
        self.cv.notify_all();
    }

    /// The single `Queued → Cancelled` transition, shared by
    /// [`JobHandle::cancel`] and the shutdown-reject path in the queue.
    /// Returns whether the transition happened (`false` once the job is
    /// running or terminal).
    pub(crate) fn cancel_queued(&self) -> bool {
        let mut slot = mlock(&self.slot);
        if slot.state != JobState::Queued {
            return false;
        }
        slot.state = JobState::Cancelled;
        slot.result = Some(Err(HbmcError::Cancelled));
        drop(slot);
        self.cv.notify_all();
        true
    }
}

/// Handle to one submitted solve job; see module docs. Obtained from
/// `SolverService::submit`. Dropping the handle without calling
/// [`wait`](JobHandle::wait) abandons the result but never the job — an
/// already-queued job still runs (or is skipped via `cancel`).
pub struct JobHandle {
    core: Arc<JobCore>,
}

impl JobHandle {
    pub(crate) fn new(core: Arc<JobCore>) -> JobHandle {
        JobHandle { core }
    }

    /// Unique id of this job (diagnostics, log correlation).
    pub fn id(&self) -> u64 {
        self.core.id
    }

    /// Non-blocking snapshot of the job's state.
    pub fn poll(&self) -> JobState {
        self.core.state()
    }

    /// Abort the job if it is still queued: it will never run, and
    /// [`wait`](JobHandle::wait) returns [`HbmcError::Cancelled`]. Returns
    /// `false` (and changes nothing) once the job is running or terminal —
    /// in-flight solves always finish.
    pub fn cancel(&self) -> bool {
        self.core.cancel_queued()
    }

    /// Block until the job reaches a terminal state and return its result.
    /// Consumes the handle — a job's output is moved out exactly once.
    pub fn wait(self) -> Result<SolveOutput> {
        let mut slot = mlock(&self.core.slot);
        while !slot.state.is_terminal() {
            slot = self.core.cv.wait(slot).unwrap_or_else(PoisonError::into_inner);
        }
        slot.result
            .take()
            .unwrap_or_else(|| Err(HbmcError::Internal("job result already consumed".into())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_queued_running_finished() {
        let core = JobCore::new(None);
        let handle = JobHandle::new(Arc::clone(&core));
        assert_eq!(handle.poll(), JobState::Queued);
        assert!(!JobState::Queued.is_terminal() && !JobState::Running.is_terminal());
        assert!(core.try_start());
        assert_eq!(handle.poll(), JobState::Running);
        assert!(!handle.cancel(), "running jobs must not be cancellable");
        assert!(!core.try_start(), "a job starts at most once");
        // A finished job is terminal and hands its (here: failed) result out.
        core.finish(Err(HbmcError::Internal("kernel exploded".into())));
        assert_eq!(handle.poll(), JobState::Failed);
        assert!(matches!(handle.wait(), Err(HbmcError::Internal(_))));
    }

    #[test]
    fn cancel_wins_over_dispatch() {
        let core = JobCore::new(None);
        let handle = JobHandle::new(Arc::clone(&core));
        assert!(handle.cancel());
        assert!(!handle.cancel(), "second cancel is a no-op");
        assert!(!core.try_start(), "dispatcher must skip a cancelled job");
        assert_eq!(handle.poll(), JobState::Cancelled);
        assert!(matches!(handle.wait(), Err(HbmcError::Cancelled)));
    }

    #[test]
    fn expired_deadline_fails_at_dispatch() {
        let core = JobCore::new(Some(Duration::ZERO));
        let handle = JobHandle::new(Arc::clone(&core));
        assert!(!core.try_start(), "expired job must not start");
        assert_eq!(handle.poll(), JobState::DeadlineExceeded);
        assert!(matches!(handle.wait(), Err(HbmcError::DeadlineExceeded { .. })));
    }
}
