//! The service job queue and its dispatcher thread (std-only: one
//! `Mutex<VecDeque>` + `Condvar`, no extra dependencies).
//!
//! `SolverService::submit` validates a request and pushes a [`QueuedJob`];
//! the dispatcher drains the queue and **micro-batches jobs that share a
//! [`BatchKey`]** — same plan (`PlanKey`) and same session-relevant config
//! (pool width, convergence controls) — into one batched sweep on a single
//! [`SolveSession`]. N concurrent single-RHS requests for one matrix thus
//! share one plan checkout and one warmed-up pool, running back-to-back
//! over cache-hot factors (each solve's kernels are already SIMD-wide
//! internally) instead of paying per-request session setup N times.
//!
//! Batching policy (tuned by [`QueueConfig`]): a batch opens with the
//! oldest queued job, greedily absorbs every compatible queued job in
//! arrival order, and flushes when it reaches `max_batch` jobs or has been
//! open for `max_wait` — whichever comes first. Deadline-carrying jobs are
//! latency-sensitive, so a window never idles while one is queued (in this
//! batch or behind it): it flushes immediately instead. Per-job
//! cancellation and deadlines are honoured *lazily*, when the dispatcher
//! actually reaches each job (`JobCore::try_start`) — a late member of a
//! wide batch stays cancellable while earlier members solve; running jobs
//! always finish.
//!
//! Shutdown (wired into `SolverService::drop`) is graceful: the flag stops
//! new submissions, the dispatcher flushes everything still queued, then
//! exits and is joined.
//!
//! Two deliberate scope limits of this design:
//!
//! * **One dispatcher thread per service.** Batches — including batches
//!   for *different* keys — run one after another. That is exactly right
//!   for the target workload (many requests, few matrices, solver
//!   parallelism inside the batch via `cfg.threads`), but callers serving
//!   many *distinct* (matrix, config) keys with single-threaded configs
//!   should hold per-key `SolverService::session` handles (the documented
//!   queue-bypass path) to run keys in parallel.
//! * **Backpressure is fail-fast, never blocking.** By default the queue
//!   is unbounded; with `QueueConfig::max_queue_depth` set, a `push` that
//!   would exceed the bound returns [`HbmcError::Overloaded`] immediately
//!   (`submit` surfaces it synchronously — it never blocks the caller or
//!   silently drops the job). Depth accounting includes jobs *staged* into
//!   an open batch window, so the bound cannot be dodged by racing the
//!   dispatcher's absorb pass. Jobs whose deadline has already expired by
//!   the time the dispatcher reaches them are **shed** — failed typed, via
//!   `JobCore::try_start`, counted in `ServiceStats::shed` — rather than
//!   silently run. Per-handle quotas (`max_inflight_per_handle`) are
//!   enforced one level up, in `SolverService::submit`.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::Instant;

use crate::config::{OrderingKind, QueueConfig, SolverConfig};
use crate::coordinator::driver::{RetryAttempt, SolveOptions};
use crate::coordinator::session::{PlanKey, SolveOutput, SolveSession};
use crate::error::{HbmcError, Result};
use crate::factor::ic0::escalation_shifts;

use super::job::{JobCore, JobState};
use super::service::{mlock, Registered, ServiceCore};
use crate::obs::trace::stage;

/// Everything that must agree for two jobs to run on one session: the plan
/// identity plus the session-level knobs `SolveSession::for_request` takes
/// from the config. Per-solve [`SolveOptions`] (history, solution copy,
/// rtol/max_iters *overrides*) may differ within a batch — they are applied
/// per right-hand side.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub(crate) struct BatchKey {
    plan: PlanKey,
    threads: usize,
    rtol_bits: u64,
    max_iters: usize,
}

impl BatchKey {
    pub(crate) fn new(plan: PlanKey, cfg: &SolverConfig) -> BatchKey {
        BatchKey {
            plan,
            threads: cfg.threads,
            rtol_bits: cfg.rtol.to_bits(),
            max_iters: cfg.max_iters,
        }
    }
}

/// One submitted right-hand side, waiting for dispatch. The registry entry
/// is captured at submit time, so unregistering the matrix afterwards does
/// not affect jobs already queued.
pub(crate) struct QueuedJob {
    pub(crate) core: Arc<JobCore>,
    pub(crate) key: BatchKey,
    pub(crate) rhs: Vec<f64>,
    pub(crate) cfg: SolverConfig,
    pub(crate) options: SolveOptions,
    pub(crate) require_convergence: bool,
    pub(crate) reg: Registered,
}

struct QueueState {
    jobs: VecDeque<QueuedJob>,
    shutdown: bool,
    /// Queued jobs carrying a deadline (maintained on push/remove). The
    /// dispatcher flushes an open batch window early whenever this is
    /// non-zero, so a latency-sensitive job never waits out another
    /// batch's window on an otherwise idle service.
    deadline_jobs: usize,
    /// Jobs pulled out of `jobs` into an open batch window but not yet
    /// claimed for dispatch. Counted so `depth()` — and with it both the
    /// `max_queue_depth` admission bound and the `queue_depth` gauge —
    /// stays live while the dispatcher sits in `wait_timeout` holding a
    /// half-built batch (previously those jobs vanished from the depth).
    staged: usize,
}

/// The shared queue; one per service, drained by one dispatcher thread.
pub(crate) struct JobQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    cfg: QueueConfig,
    // Monotonic statistics counters, surfaced through `ServiceStats`.
    // `Relaxed` is deliberate and sufficient: each counter is independently
    // monotonic and read only for reporting — no other memory is published
    // through them (job results synchronize via the job-state mutexes, the
    // queue via `state`). Stronger orderings would only add fences.
    batches: AtomicU64,
    batched_rhs: AtomicU64,
    coalesced_rhs: AtomicU64,
}

impl JobQueue {
    pub(crate) fn new(cfg: QueueConfig) -> JobQueue {
        JobQueue {
            state: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                shutdown: false,
                deadline_jobs: 0,
                staged: 0,
            }),
            cv: Condvar::new(),
            cfg,
            batches: AtomicU64::new(0),
            batched_rhs: AtomicU64::new(0),
            coalesced_rhs: AtomicU64::new(0),
        }
    }

    /// Enqueue a job (or fail it immediately if the service is shutting
    /// down — a race only reachable through handles outliving the service).
    /// A shutdown-rejected job surfaces as [`HbmcError::Cancelled`]: it was
    /// never dispatched, exactly like a caller-cancelled one.
    ///
    /// With `max_queue_depth` configured, a push that would exceed the
    /// bound fails fast with [`HbmcError::Overloaded`] — the depth check
    /// and the insert happen under one lock acquisition, so the bound is
    /// exact even under concurrent submitters.
    pub(crate) fn push(&self, job: QueuedJob) -> Result<()> {
        {
            let mut st = mlock(&self.state);
            if st.shutdown {
                drop(st);
                job.core.cancel_queued();
                return Ok(());
            }
            if let Some(limit) = self.cfg.max_queue_depth {
                let depth = st.jobs.len() + st.staged;
                if depth >= limit {
                    return Err(HbmcError::Overloaded { depth, limit });
                }
            }
            if job.core.has_deadline() {
                st.deadline_jobs += 1;
            }
            st.jobs.push_back(job);
        }
        self.cv.notify_all();
        Ok(())
    }

    /// Stop accepting jobs and wake the dispatcher so it can flush and exit.
    pub(crate) fn shutdown(&self) {
        mlock(&self.state).shutdown = true;
        self.cv.notify_all();
    }

    /// Jobs currently queued *or staged into an open batch window* — the
    /// live depth the admission bound and the `queue_depth` gauge both see.
    pub(crate) fn depth(&self) -> usize {
        let st = mlock(&self.state);
        st.jobs.len() + st.staged
    }

    /// Return one staged job's slot to the depth accounting (the job is
    /// about to be dispatched or dropped; either way it no longer occupies
    /// queue capacity).
    fn unstage(&self) {
        let mut st = mlock(&self.state);
        st.staged = st.staged.saturating_sub(1);
    }

    pub(crate) fn batches(&self) -> u64 {
        self.batches.load(AtomicOrdering::Relaxed)
    }

    pub(crate) fn batched_rhs(&self) -> u64 {
        self.batched_rhs.load(AtomicOrdering::Relaxed)
    }

    pub(crate) fn coalesced_rhs(&self) -> u64 {
        self.coalesced_rhs.load(AtomicOrdering::Relaxed)
    }

    /// Block for the next batch: the oldest queued job plus every
    /// compatible job that arrives before the flush (see module docs).
    /// `None` means shutdown with the queue fully drained.
    fn next_batch(&self) -> Option<Vec<QueuedJob>> {
        let mut st = mlock(&self.state);
        let head = loop {
            if let Some(job) = st.jobs.pop_front() {
                if job.core.has_deadline() {
                    st.deadline_jobs = st.deadline_jobs.saturating_sub(1);
                }
                // A job that is already terminal (cancelled while queued)
                // must not open a batch window that would stall unrelated
                // jobs behind it — drop it and keep looking.
                if job.core.state().is_terminal() {
                    continue;
                }
                st.staged += 1;
                break job;
            }
            if st.shutdown {
                return None;
            }
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        };
        let mut batch = vec![head];
        let flush_at = Instant::now() + self.cfg.max_wait;
        // Scan offset: everything before it is known-incompatible with
        // this batch. Valid across wakeups because only the dispatcher
        // removes queue entries and pushes only append — so the absorb
        // pass is O(new arrivals), not O(depth) per wakeup.
        let mut scanned = 0;
        loop {
            // Absorb compatible queued jobs in arrival order.
            let mut i = scanned;
            while i < st.jobs.len() && batch.len() < self.cfg.max_batch {
                if st.jobs[i].key == batch[0].key {
                    if let Some(job) = st.jobs.remove(i) {
                        if job.core.has_deadline() {
                            st.deadline_jobs = st.deadline_jobs.saturating_sub(1);
                        }
                        st.staged += 1;
                        batch.push(job);
                    }
                } else {
                    i += 1;
                }
            }
            scanned = i;
            if batch.len() >= self.cfg.max_batch || st.shutdown {
                break;
            }
            // A deadline marks a latency-sensitive job: if this batch — or
            // ANY job still queued behind it — carries one, flush without
            // waiting out the window (coalescing under load still happens
            // via the backlog absorbed above), so an idle service never
            // expires a job inside its own batching delay.
            if st.deadline_jobs > 0 || batch.iter().any(|job| job.core.has_deadline()) {
                break;
            }
            let now = Instant::now();
            if now >= flush_at {
                break;
            }
            let (guard, _timeout) = self
                .cv
                .wait_timeout(st, flush_at - now)
                .unwrap_or_else(PoisonError::into_inner);
            st = guard;
        }
        Some(batch)
    }
}

/// Body of the dispatcher thread: drain batches until graceful shutdown.
pub(crate) fn dispatcher_loop(queue: Arc<JobQueue>, core: Arc<ServiceCore>) {
    while let Some(batch) = queue.next_batch() {
        run_batch(&queue, &core, batch);
    }
}

/// Run one batch: filter out jobs cancelled or expired while queued, then
/// one plan checkout + one session for everything that remains, with the
/// `crate::resil` recovery ladder wrapped around both:
///
/// * a factorization breakdown at batch open re-plans with an escalated
///   shift (the `ic0_auto` doubling schedule), bounded by the job's
///   `RetryPolicy`;
/// * a CG breakdown mid-iteration evicts the plan and retries the job on
///   a rebuilt session;
/// * a `NotConverged` failure under a colored ordering retries once on a
///   level-scheduled plan (natural-ordering convergence);
/// * a panic that surfaces on this thread (plan build, single-threaded
///   solve, or a worker panic re-raised by `Pool::run`) evicts the plan,
///   **drains** the poisoned pool with a bounded timeout instead of
///   leaking it (`Pool::drain`; leaked stragglers are counted, never
///   joined), and — retry budget permitting — rebuilds the session and
///   retries the job once, continuing the batch on the fresh session.
///
/// Every retry is recorded in the job's `SolveReport` (`retries` /
/// `attempts`), in `hbmc_retries_total{cause=…}`, and as a `retried`
/// trace event. Terminal outcomes feed the per-handle circuit breaker.
fn run_batch(queue: &JobQueue, core: &ServiceCore, batch: Vec<QueuedJob>) {
    // Jobs are claimed *lazily*: `claim` (→ `try_start`) runs when the
    // dispatcher reaches each job, not at batch formation. A late member
    // of a wide batch therefore stays cancellable — and its deadline keeps
    // counting — for the whole time earlier members are solving.
    let mut jobs = batch.into_iter();
    let first = loop {
        match jobs.next() {
            Some(job) if claim(queue, core, &job) => break job,
            Some(_) => continue, // cancelled or shed while queued
            None => return,      // nothing left to run: not a batch at all
        }
    };
    queue.batches.fetch_add(1, AtomicOrdering::Relaxed);
    first.core.note_with(stage::BATCH_OPENED, || format!("{:?}", first.key));
    // Chaos hook: deterministic dispatcher latency, consumed here on the
    // single dispatcher thread (never inside a solve).
    if let Some(delay) = core.injector().and_then(|inj| inj.take_dispatch_delay()) {
        std::thread::sleep(delay);
    }
    // Open the batch session, walking the shift-escalation rung of the
    // ladder when the factorization breaks down. `plan_key` tracks the
    // config the live session was actually built under, so later
    // evictions hit the right cache entry; `inherited` attempts are
    // stamped into every report served off a recovered session.
    let retry_budget = first.cfg.retry.max_retries as usize;
    let mut open_cfg = first.cfg.clone();
    let mut plan_key = PlanKey::from_fingerprint(first.reg.fingerprint, &open_cfg);
    let mut inherited: Vec<RetryAttempt> = Vec::new();
    let session = loop {
        match open_session(core, &first.reg, &open_cfg) {
            Ok(Ok(session)) => break session,
            Ok(Err(e)) => {
                let escalate = match &e {
                    HbmcError::BreakdownInFactorization { .. }
                        if inherited.len() < retry_budget && !first.core.past_deadline() =>
                    {
                        // Next rung of the doubling schedule above the
                        // *configured* shift (the auto-search already
                        // exhausted the schedule above the failed one).
                        escalation_shifts(open_cfg.shift).first().copied()
                    }
                    _ => None,
                };
                let Some(next) = escalate else {
                    // Fan the batch-level failure out to every waiting
                    // handle (and the breaker — a factorization failure is
                    // a statement about the matrix).
                    settle(core, &first, Err(e.clone()));
                    for job in jobs {
                        if claim(queue, core, &job) {
                            settle(core, &job, Err(e.clone()));
                        }
                    }
                    return;
                };
                let action = format!("re-plan with escalated shift {next}");
                core.obs.record_retry("breakdown_factorization");
                first.core.note_with(stage::RETRIED, || action.clone());
                inherited.push(RetryAttempt { cause: "breakdown_factorization", action });
                open_cfg.shift = next;
                plan_key = PlanKey::from_fingerprint(first.reg.fingerprint, &open_cfg);
            }
            Err(_) => {
                let internal =
                    || HbmcError::Internal("plan build panicked during dispatch".into());
                settle(core, &first, Err(internal()));
                for job in jobs {
                    if claim(queue, core, &job) {
                        settle(core, &job, Err(internal()));
                    }
                }
                return;
            }
        }
    };
    // The session slot: recovery rungs may drain + replace the session
    // mid-batch; `None` means it was lost to an unrecoverable panic.
    let mut session = Some(session);
    let mut width: u64 = 0;
    let mut poisoned = false;
    let mut current = Some(first);
    while let Some(job) = current.take() {
        // Counters tick before the job runs, so any caller whose wait()
        // has returned already observes its own job in the statistics.
        width += 1;
        queue.batched_rhs.fetch_add(1, AtomicOrdering::Relaxed);
        if width == 2 {
            queue.coalesced_rhs.fetch_add(2, AtomicOrdering::Relaxed);
        } else if width > 2 {
            queue.coalesced_rhs.fetch_add(1, AtomicOrdering::Relaxed);
        }
        match run_job_with_recovery(core, &mut session, &mut plan_key, &inherited, &job) {
            JobEnd::Done(result) => settle(core, &job, result),
            JobEnd::Poisoned(e) => {
                settle(core, &job, Err(e));
                poisoned = true;
                break;
            }
        }
        // Claim the next still-live member only now (lazy, see above).
        current = jobs.by_ref().find(|job| claim(queue, core, job));
    }
    core.obs.batch_width.observe(width);
    if poisoned {
        // The session was lost (drained after a panic the retry policy
        // could not absorb) and the plan already evicted. Fail the rest of
        // the batch typed; the next submission for this key rebuilds both.
        for job in jobs {
            if claim(queue, core, &job) {
                settle(
                    core,
                    &job,
                    Err(HbmcError::Internal(
                        "batch aborted: an earlier job's solver panicked".into(),
                    )),
                );
            }
        }
    }
}

/// The outcome of one job under the recovery ladder.
enum JobEnd {
    /// The job reached a terminal result; the batch session is intact
    /// (possibly rebuilt) and serves the remaining members.
    Done(Result<SolveOutput>),
    /// The job failed *and* the batch session was lost (drained after an
    /// unrecoverable panic) — abort the rest of the batch.
    Poisoned(HbmcError),
}

/// Plan + session for `(reg, cfg)` under a panic guard (the plan build
/// runs factorization kernels on this thread). The outer `Err` is a build
/// panic; the session inherits the service's fault injector.
fn open_session(
    core: &ServiceCore,
    reg: &Registered,
    cfg: &SolverConfig,
) -> std::thread::Result<Result<SolveSession>> {
    catch_unwind(AssertUnwindSafe(|| {
        core.plan_for(reg, cfg)
            .map(|plan| SolveSession::for_request_with(plan, cfg, core.injector().cloned()))
    }))
}

/// Run one job to a terminal result, walking the per-job rungs of the
/// recovery ladder (see `run_batch` docs). Bounded by the job's
/// `RetryPolicy` and its deadline; each retry is recorded in the report's
/// `retries`/`attempts`, the `hbmc_retries_total` family, and the trace.
fn run_job_with_recovery(
    core: &ServiceCore,
    session: &mut Option<SolveSession>,
    plan_key: &mut PlanKey,
    inherited: &[RetryAttempt],
    job: &QueuedJob,
) -> JobEnd {
    let budget = job.cfg.retry.max_retries as usize;
    let mut attempts: Vec<RetryAttempt> = inherited.to_vec();
    let mut panic_retried = false;
    // Chaos hook: poison a CLONE of this job's rhs — the queued rhs stays
    // clean, so the retry that follows the detected breakdown is healthy.
    let mut rhs_override: Option<Vec<f64>> = None;
    if let Some(idx) = core.injector().and_then(|inj| inj.take_nan_rhs()) {
        let mut r = job.rhs.clone();
        if !r.is_empty() {
            let k = idx % r.len();
            r[k] = f64::NAN;
        }
        rhs_override = Some(r);
    }
    loop {
        let Some(live) = session.as_ref() else {
            return JobEnd::Poisoned(HbmcError::Internal(
                "batch session unavailable after recovery failure".into(),
            ));
        };
        let rhs: &[f64] = rhs_override.as_deref().unwrap_or(&job.rhs);
        let outcome = catch_unwind(AssertUnwindSafe(|| run_one(core, live, job, rhs)));
        rhs_override = None; // retries always run on the clean rhs
        let err = match outcome {
            Ok(Ok(mut out)) => {
                out.report.retries = attempts.len() as u32;
                out.report.attempts = attempts;
                return JobEnd::Done(Ok(out));
            }
            Ok(Err(e)) => e,
            Err(_) => {
                // A panic surfaced here: a worker panic re-raised by
                // `Pool::run`, or the solver itself on this thread. The
                // pool's barrier protocol may be desynchronized, so the
                // session must not serve another solve — drain it (bounded
                // join; stragglers are counted and detached, never joined)
                // and evict the plan its workers were reading.
                if let Some(inj) = core.injector() {
                    inj.consume_panic();
                }
                core.evict_plan(plan_key);
                let old = session.take().expect("session checked live above");
                let leaked = old.drain();
                core.obs.pool_rebuilds.inc();
                if panic_retried || attempts.len() >= budget || job.core.past_deadline() {
                    return JobEnd::Poisoned(HbmcError::Internal(
                        "solver panicked during dispatch".into(),
                    ));
                }
                match open_session(core, &job.reg, &job.cfg) {
                    Ok(Ok(fresh)) => {
                        *session = Some(fresh);
                        *plan_key = PlanKey::from_fingerprint(job.reg.fingerprint, &job.cfg);
                        let action = if leaked == 0 {
                            "pool rebuilt; retried on fresh session".to_string()
                        } else {
                            format!(
                                "pool rebuilt ({leaked} worker(s) leaked); \
                                 retried on fresh session"
                            )
                        };
                        core.obs.record_retry("panic");
                        job.core.note_with(stage::RETRIED, || action.clone());
                        attempts.push(RetryAttempt { cause: "panic", action });
                        panic_retried = true;
                        continue;
                    }
                    Ok(Err(e)) => return JobEnd::Poisoned(e),
                    Err(_) => {
                        return JobEnd::Poisoned(HbmcError::Internal(
                            "plan build panicked during dispatch".into(),
                        ))
                    }
                }
            }
        };
        // Typed-error rungs. Anything unmatched — or matched with no retry
        // budget left or an expired deadline — is final.
        let retryable = attempts.len() < budget && !job.core.past_deadline();
        match err {
            HbmcError::BreakdownInIteration { iter, quantity } if retryable => {
                // The iterate went non-finite: the factor (or a poisoned
                // input) is suspect. Evict the plan so the rebuild below
                // re-factorizes instead of re-checking the suspect Arc out
                // of the cache, then retry on the rebuilt session.
                core.evict_plan(plan_key);
                let fresh = match open_session(core, &job.reg, &job.cfg) {
                    Ok(Ok(s)) => s,
                    Ok(Err(e)) => return JobEnd::Done(Err(e)),
                    Err(_) => {
                        return JobEnd::Done(Err(HbmcError::Internal(
                            "plan build panicked during dispatch".into(),
                        )))
                    }
                };
                if let Some(old) = session.take() {
                    // Healthy pool (the breakdown was detected in lockstep,
                    // no panic) — drain joins every worker immediately.
                    old.drain();
                }
                *session = Some(fresh);
                *plan_key = PlanKey::from_fingerprint(job.reg.fingerprint, &job.cfg);
                let action = format!(
                    "plan evicted after non-finite {quantity} at iteration {iter}; \
                     retried on rebuilt session"
                );
                core.obs.record_retry("breakdown_iteration");
                job.core.note_with(stage::RETRIED, || action.clone());
                attempts.push(RetryAttempt { cause: "breakdown_iteration", action });
            }
            HbmcError::NotConverged { iterations, relres }
                if retryable
                    && matches!(
                        job.cfg.ordering,
                        OrderingKind::Mc | OrderingKind::Bmc | OrderingKind::Hbmc
                    ) =>
            {
                // A colored ordering trades convergence for parallelism
                // (§5.2 of the paper); fall back once to the level-
                // scheduled path, which keeps natural-ordering convergence.
                // One-shot on a throwaway session: the batch session keeps
                // serving the remaining members under the original config.
                let mut level_cfg = job.cfg.clone();
                level_cfg.ordering = OrderingKind::Level;
                let action = format!(
                    "fallback to level ordering after stalling at relres {relres:.3e} \
                     ({iterations} iterations)"
                );
                core.obs.record_retry("not_converged");
                job.core.note_with(stage::RETRIED, || action.clone());
                attempts.push(RetryAttempt { cause: "not_converged", action });
                let fallback = match open_session(core, &job.reg, &level_cfg) {
                    Ok(Ok(s)) => s,
                    Ok(Err(e)) => return JobEnd::Done(Err(e)),
                    Err(_) => {
                        return JobEnd::Done(Err(HbmcError::Internal(
                            "plan build panicked during dispatch".into(),
                        )))
                    }
                };
                let out =
                    catch_unwind(AssertUnwindSafe(|| run_one(core, &fallback, job, &job.rhs)));
                // Tear the throwaway session down with the bounded drain
                // either way; after a panic its pool must not be joined
                // unbounded by Drop.
                match out {
                    Ok(Ok(mut o)) => {
                        fallback.drain();
                        o.report.retries = attempts.len() as u32;
                        o.report.attempts = attempts;
                        return JobEnd::Done(Ok(o));
                    }
                    Ok(Err(e)) => {
                        fallback.drain();
                        return JobEnd::Done(Err(e));
                    }
                    Err(_) => {
                        if let Some(inj) = core.injector() {
                            inj.consume_panic();
                        }
                        fallback.drain();
                        return JobEnd::Done(Err(HbmcError::Internal(
                            "solver panicked during dispatch".into(),
                        )));
                    }
                }
            }
            other => return JobEnd::Done(Err(other)),
        }
    }
}

/// Fold a terminal job outcome into the handle's circuit breaker, then
/// resolve the waiting handle. Cancellations, deadline expiries and
/// admission rejections say nothing about the matrix, so they never trip
/// the breaker.
fn settle(core: &ServiceCore, job: &QueuedJob, result: Result<SolveOutput>) {
    match &result {
        Ok(_) => core.record_outcome(job.reg.id, true),
        Err(e) if breaker_counts(e) => core.record_outcome(job.reg.id, false),
        Err(_) => {}
    }
    job.core.finish(result);
}

/// Whether a job failure counts against the per-handle circuit breaker.
fn breaker_counts(e: &HbmcError) -> bool {
    !matches!(
        e,
        HbmcError::Cancelled
            | HbmcError::DeadlineExceeded { .. }
            | HbmcError::Overloaded { .. }
    )
}

/// Claim one batch member for dispatch: return its staged depth slot, then
/// run `JobCore::try_start`. A successful claim records the job's queue
/// wait; a failed claim counts as a shed when `try_start` expired the
/// job's deadline (cancelled jobs are not sheds — the caller asked).
fn claim(queue: &JobQueue, core: &ServiceCore, job: &QueuedJob) -> bool {
    queue.unstage();
    if job.core.try_start() {
        core.obs
            .queue_wait_us
            .observe(job.core.queue_wait().as_micros() as u64);
        true
    } else {
        if job.core.state() == JobState::DeadlineExceeded {
            core.obs.shed.inc();
        }
        false
    }
}

fn run_one(
    core: &ServiceCore,
    session: &SolveSession,
    job: &QueuedJob,
    rhs: &[f64],
) -> Result<SolveOutput> {
    let out = session.solve_with(rhs, &job.options)?;
    core.note_solve();
    core.note_dispatches(out.report.dispatches);
    core.obs.record_solve(&out.report);
    if job.require_convergence && !out.report.converged {
        return Err(HbmcError::NotConverged {
            iterations: out.report.iterations,
            relres: out.report.final_relres,
        });
    }
    Ok(out)
}
